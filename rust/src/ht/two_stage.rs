//! The complete two-stage Hessenberg-triangular reduction — the paper's
//! headline algorithm (ParaHT in §4) in its sequential form. The parallel
//! form lives in `coordinator::{stage1_par, stage2_par}` and shares all the
//! numerical kernels with this driver.
//!
//! The sequential driver itself now lives in [`crate::api::reduce_seq`]
//! (it is the oracle path of the `HtSession` front door); this module
//! keeps the [`HtDecomposition`] result type and a deprecated shim for the
//! old entry point.

use crate::config::Config;
use crate::error::Result;
use crate::linalg::matrix::Matrix;
use crate::linalg::verify::HtVerification;

/// Result of a Hessenberg-triangular reduction:
/// `A₀ = Q H Zᵀ`, `B₀ = Q T Zᵀ` with `H` Hessenberg, `T` upper triangular.
#[derive(Clone, Debug)]
pub struct HtDecomposition {
    /// Hessenberg factor `H`.
    pub h: Matrix,
    /// Upper-triangular factor `T`.
    pub t: Matrix,
    /// Left orthogonal factor `Q`.
    pub q: Matrix,
    /// Right orthogonal factor `Z`.
    pub z: Matrix,
    /// Wall-clock seconds spent in stage 1.
    pub stage1_secs: f64,
    /// Wall-clock seconds spent in stage 2.
    pub stage2_secs: f64,
}

impl HtDecomposition {
    /// Verify against the original pencil.
    pub fn verify(&self, a0: &Matrix, b0: &Matrix) -> HtVerification {
        HtVerification::compute(a0, b0, &self.q, &self.z, &self.h, &self.t, 1)
    }

    /// Total reduction time.
    pub fn total_secs(&self) -> f64 {
        self.stage1_secs + self.stage2_secs
    }
}

/// Reduce the pencil `(a, b)` to Hessenberg-triangular form with the
/// sequential two-stage algorithm. `b` need not be triangular: a QR-based
/// pre-triangularization is applied first (accumulated into `Q`).
///
/// Thin shim: the implementation moved verbatim to
/// [`crate::api::reduce_seq`] (the sequential oracle behind
/// `HtSession::reduce` at `threads = 1`); this wrapper delegates with zero
/// behavioral change.
#[deprecated(
    since = "0.2.0",
    note = "use `paraht::api::HtSession` (builder front door) or `paraht::api::reduce_seq`; \
            removal target 0.3.0 — see EXPERIMENTS.md §API for the migration table"
)]
pub fn reduce_to_hessenberg_triangular(
    a: &Matrix,
    b: &Matrix,
    cfg: &Config,
) -> Result<HtDecomposition> {
    crate::api::reduce_seq(a, b, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    // The oracle implementation under its historical name — these tests
    // exercise the sequential driver itself, not the deprecated shim.
    use crate::api::reduce_seq as reduce_to_hessenberg_triangular;
    use crate::linalg::verify::max_below_band;
    use crate::pencil::random::{random_pencil, random_pencil_general};
    use crate::pencil::saddle::saddle_pencil;
    use crate::util::rng::Rng;

    #[test]
    fn full_two_stage_random() {
        let mut rng = Rng::new(90);
        let p = random_pencil(80, &mut rng);
        let cfg = Config { r: 8, p: 4, q: 4, ..Config::default() };
        let d = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
        assert!(max_below_band(&d.h, 1) < 1e-12 * d.h.norm_fro());
        assert_eq!(max_below_band(&d.t, 0), 0.0);
        d.verify(&p.a, &p.b).assert_ok(1e-11);
    }

    #[test]
    fn general_b_pretriangularized() {
        let mut rng = Rng::new(91);
        let p = random_pencil_general(40, &mut rng);
        let cfg = Config { r: 4, p: 3, q: 3, ..Config::default() };
        let d = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
        d.verify(&p.a, &p.b).assert_ok(1e-11);
    }

    #[test]
    fn saddle_point_pencil_reduces() {
        // The two-stage algorithm is oblivious to infinite eigenvalues
        // (§4, Fig. 11 discussion) — singular B must work identically.
        let mut rng = Rng::new(92);
        let p = saddle_pencil(60, 0.25, &mut rng);
        let cfg = Config { r: 8, p: 3, q: 4, ..Config::default() };
        let d = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
        assert!(max_below_band(&d.h, 1) < 1e-12 * d.h.norm_fro());
        d.verify(&p.a, &p.b).assert_ok(1e-11);
    }

    #[test]
    fn rejects_bad_shapes_and_config() {
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(4, 4);
        assert!(reduce_to_hessenberg_triangular(&a, &b, &Config::default()).is_err());
        let a = Matrix::identity(4);
        let mut cfg = Config::default();
        cfg.p = 0;
        assert!(reduce_to_hessenberg_triangular(&a, &a, &cfg).is_err());
    }

    #[test]
    fn identity_pencil_stays_identity_like() {
        let n = 12;
        let a = Matrix::identity(n);
        let b = Matrix::identity(n);
        let cfg = Config { r: 3, p: 2, q: 2, ..Config::default() };
        let d = reduce_to_hessenberg_triangular(&a, &b, &cfg).unwrap();
        d.verify(&a, &b).assert_ok(1e-12);
    }
}
