//! Storage for the reflectors of a stage-2 sweep group.
//!
//! The blocked algorithm (Algs. 3–4) generates the reflectors
//! `Q̂ₖʲ, Ẑₖʲ` for `q` consecutive sweeps `j ∈ [j1, j1+q)` before applying
//! most of their updates. Reflectors near the bottom edge degenerate
//! (segment shorter than 2) and are stored as `None`; the apply phase and
//! the parallel driver both read this store.

use crate::linalg::householder::Reflector;

/// Reflectors of one sweep group.
pub struct GroupReflectors {
    /// First sweep of the group (0-based).
    pub j1: usize,
    /// Number of sweeps in the group (`≤ q`; the last group is partial).
    pub qg: usize,
    /// Bandwidth `r`.
    pub r: usize,
    /// Problem size.
    pub n: usize,
    /// Chase steps allocated per sweep (upper bound over the group).
    pub nblocks: usize,
    qhat: Vec<Option<Reflector>>,
    zhat: Vec<Option<Reflector>>,
}

impl GroupReflectors {
    /// Allocate an empty store. `nblocks` follows Algorithm 3:
    /// `2 + floor((n − j1 − 2)/r)` steps for the group's first sweep
    /// (an upper bound for the later ones).
    pub fn new(n: usize, r: usize, j1: usize, qg: usize) -> GroupReflectors {
        let nblocks = if n >= j1 + 2 { 2 + (n - j1 - 2) / r } else { 0 };
        GroupReflectors {
            j1,
            qg,
            r,
            n,
            nblocks,
            qhat: (0..qg * nblocks).map(|_| None).collect(),
            zhat: (0..qg * nblocks).map(|_| None).collect(),
        }
    }

    #[inline]
    fn idx(&self, j: usize, k: usize) -> usize {
        debug_assert!(j >= self.j1 && j < self.j1 + self.qg);
        debug_assert!(k < self.nblocks);
        (j - self.j1) * self.nblocks + k
    }

    /// Store the pair for sweep `j`, chase step `k`.
    pub fn set(&mut self, j: usize, k: usize, q: Reflector, z: Reflector) {
        let i = self.idx(j, k);
        self.qhat[i] = Some(q);
        self.zhat[i] = Some(z);
    }

    /// Left reflector `Q̂ₖʲ` if it exists.
    pub fn q(&self, j: usize, k: usize) -> Option<&Reflector> {
        if k >= self.nblocks {
            return None;
        }
        self.qhat[self.idx(j, k)].as_ref()
    }

    /// Right reflector `Ẑₖʲ` if it exists.
    pub fn z(&self, j: usize, k: usize) -> Option<&Reflector> {
        if k >= self.nblocks {
            return None;
        }
        self.zhat[self.idx(j, k)].as_ref()
    }

    /// Number of stored (non-degenerate) reflector pairs.
    pub fn stored(&self) -> usize {
        self.qhat.iter().filter(|r| r.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(len: usize) -> Reflector {
        Reflector { v: vec![1.0; len], tau: 0.5 }
    }

    #[test]
    fn set_get_roundtrip() {
        let mut s = GroupReflectors::new(40, 4, 3, 5);
        assert!(s.nblocks >= 9);
        assert!(s.q(3, 0).is_none());
        s.set(4, 2, dummy(4), dummy(4));
        assert!(s.q(4, 2).is_some());
        assert!(s.z(4, 2).is_some());
        assert!(s.q(4, 3).is_none());
        assert_eq!(s.stored(), 1);
        // Out-of-range k is None, not a panic.
        assert!(s.q(4, 999).is_none());
    }
}
