//! Stage 2, blocked (Algorithms 3 + 4): generate the reflectors of `q`
//! consecutive sweeps while touching a minimal band, then apply the delayed
//! updates reordered — grouped by chase index `k` and accumulated into
//! compact-WY block reflectors (the Bischof–Sun–Lang reordering, §3.2).
//!
//! ## Range bookkeeping (0-based, half-open; `// paper:` = 1-based incl.)
//!
//! Geometry of sweep `j`, chase step `k` is identical to Algorithm 2:
//! `i1 = j+kr+1`, `i2e = min(j+(k+1)r+1, n)`, `i3e = min(j+(k+2)r+1, n)`,
//! `jb = j` for `k = 0` else `j+(k-1)r+1`.
//!
//! *Generate* (Alg. 3): before producing `Q̂ₖʲ`, the catch-up loop applies
//! every previous sweep's `Q̂ₖ^ĵ` (`ĵ ∈ [j1, j)`) to the one new column of
//! `A` (`jb`) and the one new column of `B` (`i1+r-1`) that enter the
//! band this sweep. `Ẑₖʲ` is then applied to the minimal row ranges
//! `[i4, i3e)` of `A` and `[i4, i2e)` of `B`, with
//! `i4 = j1+1+max(0, (k+j−j1−q)·r)` (equations (4)/(5) of the paper; the
//! appendix listing prints a `+2` offset that conflicts with them — see
//! `gen_right_row_start`).
//!
//! *Apply* (Alg. 4): for each `k` (bottom-up) first the "ragged" rows
//! `[s5, e4(j))` that differ per sweep are updated reflector-by-reflector,
//! then rows `[0, s5)` — common to all `q` reflectors — get the accumulated
//! WY block `Ẑₖ = Ẑₖ^{j1}⋯Ẑₖ^{j1+q-1}`; symmetrically the trailing columns
//! get `Q̂ₖᵀ`. The apply column/row starts are *one past* the last
//! generate-updated line: the appendix prints the boundary column itself,
//! but coverage analysis (each line must receive each reflector exactly
//! once; see DESIGN.md §7) fixes the off-by-one, and the equality test
//! against the unblocked Algorithm 2 confirms it.

use super::reflector_store::GroupReflectors;
use crate::linalg::householder::Reflector;
use crate::linalg::matrix::{MatMut, Matrix};
use crate::linalg::rq::RqFactor;
use crate::linalg::wy::{Side, WyRep};
use crate::linalg::Trans;

/// Chase-step geometry (0-based, half-open).
#[inline]
fn geom(n: usize, r: usize, j: usize, k: usize) -> (usize, usize, usize, usize) {
    let jb = if k == 0 { j } else { j + (k - 1) * r + 1 };
    let i1 = j + k * r + 1;
    let i2e = (j + (k + 1) * r + 1).min(n);
    let i3e = (j + (k + 2) * r + 1).min(n);
    (jb, i1, i2e, i3e)
}

/// Row where the *generate-phase* right update starts (paper's `i4`,
/// 0-based). We follow the derived equations (4)/(5):
/// `r1A(k,j) = j1 + 1 + max(0, kr − r − (j1+q−1−j)r)
///           = j1 + 1 + max(0, (k + j − j1 − q)·r)`.
/// (The appendix listing prints `(k + j − j1 − q + 2)·r`; with that offset
/// the generate phase leaves the sub-diagonal rows of each reduced `B`
/// column stale while later generate steps read them — the equation form
/// interlocks exactly: `i4(j, k−1) = i4(j−1, k)`.)
#[inline]
fn gen_right_row_start(j1: usize, qg: usize, r: usize, j: usize, k: usize) -> usize {
    let t = k as i64 + j as i64 - j1 as i64 - qg as i64;
    j1 + 1 + if t > 0 { t as usize * r } else { 0 }
}

/// Generate phase (Algorithm 3) for the sweep group `[j1, j1+qg)`:
/// produces all reflectors while updating only the minimal band of
/// `(A, B)`. `Q`/`Z` are untouched — the apply phase accumulates them.
pub fn generate_group(
    mut a: MatMut<'_>,
    mut b: MatMut<'_>,
    n: usize,
    r: usize,
    j1: usize,
    qg: usize,
) -> GroupReflectors {
    let mut store = GroupReflectors::new(n, r, j1, qg);
    let nblocks = store.nblocks;
    for j in j1..j1 + qg {
        for k in 0..nblocks {
            let (jb, i1, i2e, _i3e) = geom(n, r, j, k);
            if jb >= n {
                break;
            }

            // Catch-up: apply previous sweeps' Q̂ₖ^ĵ to the new columns
            // (paper l.9–18). This must run even when the *current* sweep's
            // step degenerates at the bottom edge — the column `jb` still
            // needs the earlier sweeps' reflectors (that is why Alg. 3
            // iterates `2 + ⌊(n−j−1)/r⌋` steps, more than Alg. 2).
            for jh in j1..j {
                let (_, h1, h2e, _) = geom(n, r, jh, k);
                if h2e < h1 + 2 {
                    continue;
                }
                if let Some(qr) = store.q(jh, k) {
                    // A(î1:î2, jb)
                    qr.apply_left(a.rb_mut().sub(h1..h2e, jb..jb + 1));
                    // B(î1:î2, i1+r-1) — paper guard: i1 + r - 1 ≤ n.
                    let cb = i1 + r - 1;
                    if cb < n {
                        qr.apply_left(b.rb_mut().sub(h1..h2e, cb..cb + 1));
                    }
                }
            }

            if i1 >= n || i2e < i1 + 2 {
                continue; // degenerate step: no reflector.
            }

            // Generate Q̂ₖʲ reducing A(i1:i2, jb); its action on that column
            // is known exactly: [β, 0, …, 0].
            let x: Vec<f64> = (i1..i2e).map(|i| a.at(i, jb)).collect();
            let (qk, beta) = Reflector::reducing(&x);
            a.set(i1, jb, beta);
            for i in i1 + 1..i2e {
                a.set(i, jb, 0.0);
            }
            // paper l.21: B(i1:i2, i1:i2) = Q̂ₖʲ B(i1:i2, i1:i2)
            qk.apply_left(b.rb_mut().sub(i1..i2e, i1..i2e));

            // Opposite reflector from the RQ of the B block (l.22–23).
            let blk = b.rb().sub(i1..i2e, i1..i2e).to_owned();
            let rq = RqFactor::compute(&blk);
            let row = rq.q_top_rows(1);
            let xv: Vec<f64> = (0..i2e - i1).map(|c| row[(0, c)]).collect();
            let (zk, _) = Reflector::reducing(&xv);

            // Minimal right updates (l.24–25).
            let i4 = gen_right_row_start(j1, qg, r, j, k);
            let (_, _, i2e2, i3e2) = geom(n, r, j, k);
            if i4 < i3e2 {
                zk.apply_right(a.rb_mut().sub(i4..i3e2, i1..i2e));
            }
            if i4 < i2e2 {
                zk.apply_right(b.rb_mut().sub(i4..i2e2, i1..i2e));
            }
            // First block column of B is reduced below the diagonal.
            for i in i1 + 1..i2e {
                b.set(i, i1, 0.0);
            }

            store.set(j, k, qk, zk);
        }
    }
    store
}

/// Build the compact-WY representation of the staircase product
/// `R_k = R_k^{j1} ⋯ R_k^{j1+qg-1}` for chase step `k`, where sweep `j`'s
/// reflector acts on rows `i1(j,k)..i2e(j,k)` — offset `j − j1` inside the
/// union span. Returns `(span_start, WY)` or `None` if no reflector exists.
fn staircase_wy(
    refl: impl Fn(usize) -> Option<Reflector>,
    n: usize,
    r: usize,
    j1: usize,
    qg: usize,
    k: usize,
) -> Option<(usize, WyRep)> {
    let ci1 = j1 + k * r + 1;
    // Collect existing reflectors in sweep order.
    let mut cols: Vec<(usize, Reflector)> = Vec::new();
    let mut span_end = ci1;
    for j in j1..j1 + qg {
        if let Some(h) = refl(j) {
            let (_, i1, i2e, _) = geom(n, r, j, k);
            debug_assert_eq!(i2e - i1, h.v.len());
            span_end = span_end.max(i2e);
            cols.push((i1 - ci1, h));
        }
    }
    if cols.is_empty() {
        return None;
    }
    let m = span_end - ci1;
    let kk = cols.len();
    let mut v = Matrix::zeros(m, kk);
    let mut taus = vec![0.0; kk];
    for (c, (off, h)) in cols.iter().enumerate() {
        for (l, &vl) in h.v.iter().enumerate() {
            v[(off + l, c)] = vl;
        }
        taus[c] = h.tau;
    }
    Some((ci1, WyRep::from_reflectors(v, &taus)))
}

/// Apply phase (Algorithm 4): all delayed updates for the group, reordered
/// by chase index with WY accumulation, plus the `Q`/`Z` accumulation.
pub fn apply_group(
    mut a: MatMut<'_>,
    mut b: MatMut<'_>,
    mut q: MatMut<'_>,
    mut z: MatMut<'_>,
    store: &GroupReflectors,
) {
    let n = store.n;
    let nblocks = store.nblocks;

    // ---- Right (Ẑ) updates, k bottom-up (paper l.2-18). ----
    for k in (0..nblocks).rev() {
        z_ragged_for(store, k, a.rb_mut(), b.rb_mut());
        if let Some(za) = z_apply_for(store, k) {
            let s5w = za.s5.min(n);
            if s5w > 0 {
                za.wy.apply(Side::Right, Trans::No, a.rb_mut().sub(0..s5w, za.ci1..za.ci2e));
                za.wy.apply(Side::Right, Trans::No, b.rb_mut().sub(0..s5w, za.ci1..za.ci2e));
            }
            za.wy.apply(Side::Right, Trans::No, z.rb_mut().sub(0..n, za.ci1..za.ci2e));
        }
    }

    // ---- Left (Q̂) updates, k bottom-up (paper l.19-28). ----
    for k in (0..nblocks).rev() {
        if let Some(qa) = q_apply_for(store, k) {
            if qa.c5 < n {
                qa.wy.apply(Side::Left, Trans::Yes, a.rb_mut().sub(qa.ci1..qa.ci2e, qa.c5..n));
            }
            if qa.c6 < n {
                qa.wy.apply(Side::Left, Trans::Yes, b.rb_mut().sub(qa.ci1..qa.ci2e, qa.c6..n));
            }
            qa.wy.apply(Side::Right, Trans::No, q.rb_mut().sub(0..n, qa.ci1..qa.ci2e));
        }
    }
}

/// Ragged per-sweep `Ẑ` rows for chase `k` (paper l.4-10): rows
/// `[s5, e4(j))` that differ per sweep, applied reflector-by-reflector.
/// Empty for `j = j1`. Operates on full-matrix views of `A` and `B`.
pub fn z_ragged_for(store: &GroupReflectors, k: usize, mut a: MatMut<'_>, mut b: MatMut<'_>) {
    let (n, r, j1, qg) = (store.n, store.r, store.j1, store.qg);
    let s5 = z_wy_row_end(store, k);
    for j in j1 + 1..j1 + qg {
        if let Some(zk) = store.z(j, k) {
            let (_, i1, i2e, _) = geom(n, r, j, k);
            let e4 = gen_right_row_start(j1, qg, r, j, k);
            if e4 > s5 {
                zk.apply_right(a.rb_mut().sub(s5..e4.min(n), i1..i2e));
                zk.apply_right(b.rb_mut().sub(s5..e4.min(n), i1..i2e));
            }
        }
    }
}

/// Upper (exclusive) row bound of the accumulated-WY `Ẑ` region for chase
/// `k`: `s5 = j1 + 1 + max(0, (k − q)·r)` — the generate right-update start
/// of the group's first sweep, so WY rows `[0, s5)` + ragged `[s5, e4(j))`
/// + generate `[e4, i3e)` tile the rows exactly.
pub fn z_wy_row_end(store: &GroupReflectors, k: usize) -> usize {
    let t5 = k as i64 - store.qg as i64;
    store.j1 + 1 + if t5 > 0 { t5 as usize * store.r } else { 0 }
}

/// The accumulated `Ẑₖ` block update for chase `k` (paper l.11-17).
pub struct ZApply {
    /// Column span start of the staircase WY.
    pub ci1: usize,
    /// Column span end (exclusive).
    pub ci2e: usize,
    /// Rows `[0, s5)` receive the WY (plus all of `Z`).
    pub s5: usize,
    /// The staircase block reflector `Ẑₖ = Ẑₖ^{j1}⋯Ẑₖ^{j1+q-1}`.
    pub wy: WyRep,
}

/// Build the `Ẑₖ` WY update for chase `k`, if any reflector exists.
pub fn z_apply_for(store: &GroupReflectors, k: usize) -> Option<ZApply> {
    let (n, r, j1, qg) = (store.n, store.r, store.j1, store.qg);
    let (ci1, wy) = staircase_wy(|j| store.z(j, k).cloned(), n, r, j1, qg, k)?;
    let ci2e = ci1 + wy.m();
    Some(ZApply { ci1, ci2e, s5: z_wy_row_end(store, k), wy })
}

/// The accumulated `Q̂ₖ` block update for chase `k` (paper l.20-27).
pub struct QApply {
    /// Row span start of the staircase WY (acts on rows of `A`/`B`).
    pub ci1: usize,
    /// Row span end (exclusive).
    pub ci2e: usize,
    /// `A` columns `[c5, n)` receive `Q̂ₖᵀ`.
    pub c5: usize,
    /// `B` columns `[c6, n)` receive `Q̂ₖᵀ`.
    pub c6: usize,
    /// The staircase block reflector `Q̂ₖ`.
    pub wy: WyRep,
}

/// Build the `Q̂ₖ` WY update for chase `k`, if any reflector exists.
pub fn q_apply_for(store: &GroupReflectors, k: usize) -> Option<QApply> {
    let (n, r, j1, qg) = (store.n, store.r, store.j1, store.qg);
    let (ci1, wy) = staircase_wy(|j| store.q(j, k).cloned(), n, r, j1, qg, k)?;
    let ci2e = ci1 + wy.m();
    // One past the last generate-updated column jb(j1+qg-1, k) / block span.
    let c5 = j1 + qg - 1 + if k == 0 { 0 } else { (k - 1) * r + 1 } + 1;
    let c6 = (j1 + qg + (k + 1) * r).min(n);
    Some(QApply { ci1, ci2e, c5, c6, wy })
}

/// Sequential blocked stage 2: reduce an r-Hessenberg-triangular pencil to
/// Hessenberg-triangular form with sweep groups of size `q`
/// (paper defaults: `r = 16`, `q = 8`).
pub fn reduce_blocked(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    r: usize,
    qsize: usize,
) {
    let n = a.rows();
    if n < 3 {
        return;
    }
    let mut j1 = 0;
    while j1 < n - 2 {
        let qg = qsize.min(n - 2 - j1);
        let store = generate_group(a.as_mut(), b.as_mut(), n, r, j1, qg);
        apply_group(a.as_mut(), b.as_mut(), q.as_mut(), z.as_mut(), &store);
        j1 += qg;
    }
}

/// Upper bound on chase steps per sweep (shared with the parallel driver).
pub fn max_chase_steps(n: usize, r: usize, j1: usize) -> usize {
    if n >= j1 + 2 {
        2 + (n - j1 - 2) / r
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::ht::stage2_unblocked::chase_steps;
    use crate::ht::stage1::reduce_to_banded;
    use crate::ht::stage2_unblocked::reduce_unblocked;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    fn banded(n: usize, r: usize, seed: u64) -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let pencil = random_pencil(n, &mut rng);
        let (a0, b0) = (pencil.a.clone(), pencil.b.clone());
        let mut a = pencil.a;
        let mut b = pencil.b;
        let mut q = Matrix::identity(n);
        let mut z = Matrix::identity(n);
        let cfg = Config { r, p: 3, ..Config::default() };
        reduce_to_banded(&mut a, &mut b, &mut q, &mut z, &cfg);
        (a0, b0, a, b, q, z)
    }

    fn max_diff(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = 0.0f64;
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                d = d.max((x[(i, j)] - y[(i, j)]).abs());
            }
        }
        d
    }

    /// The core validation: blocked (Alg 3+4) must equal unblocked (Alg 2)
    /// to rounding — same reflector sequence, reordered arithmetic.
    #[test]
    fn blocked_equals_unblocked() {
        for &(n, r, q) in &[(30usize, 4usize, 3usize), (40, 4, 8), (35, 5, 4), (50, 16, 8), (26, 3, 1)] {
            let (_a0, _b0, a_in, b_in, q_in, z_in) = banded(n, r, 77);
            let (mut a1, mut b1, mut q1, mut z1) = (a_in.clone(), b_in.clone(), q_in.clone(), z_in.clone());
            reduce_unblocked(&mut a1, &mut b1, &mut q1, &mut z1, r);
            let (mut a2, mut b2, mut q2, mut z2) = (a_in.clone(), b_in.clone(), q_in.clone(), z_in.clone());
            reduce_blocked(&mut a2, &mut b2, &mut q2, &mut z2, r, q);
            let scale = a1.norm_fro();
            assert!(max_diff(&a1, &a2) < 1e-11 * scale, "A mismatch n={n} r={r} q={q}: {:.3e}", max_diff(&a1, &a2));
            assert!(max_diff(&b1, &b2) < 1e-11 * scale, "B mismatch n={n} r={r} q={q}: {:.3e}", max_diff(&b1, &b2));
            assert!(max_diff(&q1, &q2) < 1e-11, "Q mismatch n={n} r={r} q={q}: {:.3e}", max_diff(&q1, &q2));
            assert!(max_diff(&z1, &z2) < 1e-11, "Z mismatch n={n} r={r} q={q}: {:.3e}", max_diff(&z1, &z2));
        }
    }

    #[test]
    fn blocked_produces_valid_ht() {
        let (a0, b0, mut a, mut b, mut q, mut z) = banded(60, 6, 78);
        reduce_blocked(&mut a, &mut b, &mut q, &mut z, 6, 4);
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro());
        assert_eq!(max_below_band(&b, 0), 0.0);
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
    }

    #[test]
    fn paper_parameters_r16_q8() {
        let (a0, b0, mut a, mut b, mut q, mut z) = banded(140, 16, 79);
        reduce_blocked(&mut a, &mut b, &mut q, &mut z, 16, 8);
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
    }

    #[test]
    fn partial_last_group() {
        // n chosen so the last group has fewer than q sweeps.
        let (a0, b0, mut a, mut b, mut q, mut z) = banded(29, 4, 80);
        reduce_blocked(&mut a, &mut b, &mut q, &mut z, 4, 8);
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
    }

    #[test]
    fn q_one_equals_unblocked_exactly_in_structure() {
        // q = 1: no delayed cross-sweep updates; still must be valid.
        let (a0, b0, mut a, mut b, mut q, mut z) = banded(25, 3, 81);
        reduce_blocked(&mut a, &mut b, &mut q, &mut z, 3, 1);
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
    }

    #[test]
    fn geometry_helpers() {
        // geom matches the unblocked chase_steps where steps exist.
        let n = 40;
        let r = 4;
        for j in 0..5 {
            for st in chase_steps(n, r, j) {
                let (jb, i1, i2e, i3e) = geom(n, r, st.j, st.k);
                assert_eq!((jb, i1, i2e, i3e), (st.jb, st.i1, st.i2e, st.i3e));
            }
        }
        assert!(max_chase_steps(40, 4, 0) >= chase_steps(40, 4, 0).len());
    }
}
