//! Single-shift QZ iteration on a Hessenberg-triangular pencil
//! (Moler & Stewart, 1973) — the downstream consumer that motivates the
//! whole reduction (§1: "The most common use for such a decomposition is
//! as a preprocessing step for the QZ algorithm").
//!
//! This is a deliberately basic real single-shift implementation: it
//! converges for pencils with real spectra (the end-to-end example builds
//! such pencils by construction) and demonstrates that the HT reduction's
//! output is a valid QZ input. It is not a production generalized Schur
//! solver (no double-shift for complex pairs, no infinite-eigenvalue
//! swapping).

use crate::error::{Error, Result};
use crate::linalg::givens::Givens;
use crate::linalg::matrix::Matrix;

/// Result of the QZ iteration.
pub struct QzResult {
    /// Generalized eigenvalues as `(re, im)` pairs (β≈0 ⇒ infinite,
    /// reported as (NaN, 0)). Complex pairs come from converged 2×2 blocks
    /// of the real quasi-triangular Schur form.
    pub eigenvalues: Vec<(f64, f64)>,
    /// Iterations used.
    pub iterations: usize,
}

/// Eigenvalues of the trailing 2×2 of `H·T⁻¹` at rows/cols `(i0, i0+1)`:
/// returns `(tr/2, disc)` with `disc = (tr/2)² − det`.
fn block2_shift(h: &Matrix, t: &Matrix, i0: usize) -> Option<(f64, f64)> {
    let i1 = i0 + 1;
    let (t00, t01, t11) = (t[(i0, i0)], t[(i0, i1)], t[(i1, i1)]);
    if t00.abs() < 1e-300 || t11.abs() < 1e-300 {
        return None;
    }
    let m00 = h[(i0, i0)] / t00;
    let m01 = (h[(i0, i1)] - m00 * t01) / t11;
    let m10 = h[(i1, i0)] / t00;
    let m11 = (h[(i1, i1)] - m10 * t01) / t11;
    let tr = m00 + m11;
    let det = m00 * m11 - m01 * m10;
    Some((tr / 2.0, tr * tr / 4.0 - det))
}

/// Run single-shift QZ on an HT pencil in place; `q`, `z` accumulate.
/// `H` must be Hessenberg and `T` upper triangular on entry.
pub fn qz(
    h: &mut Matrix,
    t: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    max_iters: usize,
) -> Result<QzResult> {
    let n = h.rows();
    let norm = h.norm_fro().max(1e-300);
    let tol = 1e-13 * norm;
    let mut hi = n.saturating_sub(1);
    let mut iters = 0;
    // Subdiagonals left nonzero on purpose (converged complex 2×2 blocks).
    let mut complex_blocks: Vec<usize> = Vec::new();

    while hi > 0 {
        // Deflate converged subdiagonals from the bottom.
        while hi > 0 && h[(hi, hi - 1)].abs() < tol {
            h[(hi, hi - 1)] = 0.0;
            hi -= 1;
        }
        if hi == 0 {
            break;
        }
        // Active window [lo, hi]: walk up to the nearest zero subdiagonal.
        let mut lo = hi;
        while lo > 0 && h[(lo, lo - 1)].abs() >= tol {
            lo -= 1;
        }

        iters += 1;
        if iters > max_iters {
            return Err(Error::numerical(format!(
                "QZ failed to converge in {max_iters} iterations (window {lo}..={hi})"
            )));
        }

        // Wilkinson shift: eigenvalue of the trailing 2×2 of H·T⁻¹ closest
        // to the Rayleigh quotient. A 2×2 window whose block eigenvalues
        // are complex is a converged block of the real quasi-triangular
        // Schur form — deflate it as-is (single real shifts cannot split a
        // complex pair).
        let beta = t[(hi, hi)];
        let rayleigh = if beta.abs() > 1e-300 { h[(hi, hi)] / beta } else { 0.0 };
        let mut sigma = rayleigh;
        if let Some((mid, disc)) = block2_shift(h, t, hi - 1) {
            if disc >= 0.0 {
                let sq = disc.sqrt();
                let (r1, r2) = (mid + sq, mid - sq);
                sigma = if (r1 - rayleigh).abs() < (r2 - rayleigh).abs() { r1 } else { r2 };
            } else if hi == lo + 1 {
                // Converged complex 2×2 block: record and move past it.
                complex_blocks.push(lo);
                if lo == 0 {
                    break;
                }
                hi = lo - 1;
                continue;
            } else {
                sigma = mid; // aim at the pair's real part to split it off
            }
        }
        if iters % 12 == 0 {
            sigma = sigma * 1.0625 + 0.001 * h.norm_fro() / (n as f64); // exceptional
        }

        // First column of (H − σT) in the window: rows lo, lo+1.
        let x0 = h[(lo, lo)] - sigma * t[(lo, lo)];
        let x1 = h[(lo + 1, lo)];
        let (g, _) = Givens::make(x0, x1);
        g.apply_left(h.as_mut(), lo, lo + 1, lo..n);
        g.apply_left(t.as_mut(), lo, lo + 1, lo..n);
        g.apply_right(q.as_mut(), lo, lo + 1, 0..n);

        // Chase: restore T's triangularity, then H's Hessenberg form.
        for i in lo..hi {
            // T fill at (i+1, i): zero with right rotation of cols (i+1, i).
            let (gr, _) = Givens::make(t[(i + 1, i + 1)], t[(i + 1, i)]);
            let top = (i + 3).min(n);
            gr.apply_right(t.as_mut(), i + 1, i, 0..top.max(i + 2));
            t[(i + 1, i)] = 0.0;
            gr.apply_right(h.as_mut(), i + 1, i, 0..n);
            gr.apply_right(z.as_mut(), i + 1, i, 0..n);

            // H bulge at (i+2, i): zero with left rotation of rows
            // (i+1, i+2).
            if i + 2 <= hi {
                let (gl, _) = Givens::make(h[(i + 1, i)], h[(i + 2, i)]);
                gl.apply_left(h.as_mut(), i + 1, i + 2, i..n);
                h[(i + 2, i)] = 0.0;
                gl.apply_left(t.as_mut(), i + 1, i + 2, i + 1..n);
                gl.apply_right(q.as_mut(), i + 1, i + 2, 0..n);
            }
        }
    }

    // Eigenvalues from the quasi-triangular pencil diagonal.
    let mut eigenvalues = Vec::with_capacity(n);
    let mut i = 0;
    while i < n {
        if complex_blocks.contains(&i) {
            if let Some((mid, disc)) = block2_shift(h, t, i) {
                let im = (-disc).max(0.0).sqrt();
                eigenvalues.push((mid, im));
                eigenvalues.push((mid, -im));
            } else {
                eigenvalues.push((f64::NAN, 0.0));
                eigenvalues.push((f64::NAN, 0.0));
            }
            i += 2;
        } else {
            let beta = t[(i, i)];
            if beta.abs() < 1e-300 {
                eigenvalues.push((f64::NAN, 0.0)); // infinite eigenvalue
            } else {
                eigenvalues.push((h[(i, i)] / beta, 0.0));
            }
            i += 1;
        }
    }
    Ok(QzResult { eigenvalues, iterations: iters })
}

/// Build a pencil with a prescribed *real* spectrum: `A = Q₀ T_A Z₀ᵀ`,
/// `B = Q₀ T_B Z₀ᵀ` with random triangulars whose diagonal ratios are the
/// requested eigenvalues and random orthogonal `Q₀`, `Z₀`.
pub fn pencil_with_spectrum(eigs: &[f64], rng: &mut crate::util::rng::Rng) -> (Matrix, Matrix) {
    let n = eigs.len();
    let mut ta = Matrix::zeros(n, n);
    let mut tb = Matrix::zeros(n, n);
    // Damped couplings: random dense triangulars have exponentially
    // ill-conditioned eigenproblems; 0.25-scaled off-diagonals keep the
    // prescribed spectrum numerically meaningful at n in the hundreds.
    for j in 0..n {
        for i in 0..j {
            ta[(i, j)] = 0.25 * rng.normal();
            tb[(i, j)] = 0.25 * rng.normal();
        }
        let b = 1.0 + rng.uniform(); // β in [1, 2): well conditioned
        tb[(j, j)] = b;
        ta[(j, j)] = eigs[j] * b;
    }
    let q0 = crate::linalg::qr::QrFactor::compute(&Matrix::randn(n, n, rng)).form_q();
    let z0 = crate::linalg::qr::QrFactor::compute(&Matrix::randn(n, n, rng)).form_q();
    let a = crate::linalg::matmul_t(
        &crate::linalg::matmul(&q0, &ta),
        crate::linalg::Trans::No,
        &z0,
        crate::linalg::Trans::Yes,
    );
    let b = crate::linalg::matmul_t(
        &crate::linalg::matmul(&q0, &tb),
        crate::linalg::Trans::No,
        &z0,
        crate::linalg::Trans::Yes,
    );
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::reduce_seq as reduce_to_hessenberg_triangular;
    use crate::config::Config;
    use crate::util::rng::Rng;

    #[test]
    fn qz_recovers_known_spectrum_after_ht_reduction() {
        let mut rng = Rng::new(600);
        let want: Vec<f64> = (1..=16).map(|i| i as f64 / 2.0).collect();
        let (a, b) = pencil_with_spectrum(&want, &mut rng);
        let cfg = Config { r: 4, p: 3, q: 3, ..Config::default() };
        let d = reduce_to_hessenberg_triangular(&a, &b, &cfg).unwrap();
        let (mut h, mut t) = (d.h.clone(), d.t.clone());
        let (mut q, mut z) = (d.q.clone(), d.z.clone());
        let res = qz(&mut h, &mut t, &mut q, &mut z, 500).unwrap();
        let mut got: Vec<f64> = res
            .eigenvalues
            .iter()
            .map(|&(re, im)| {
                assert!(im.abs() < 1e-6, "unexpected complex eigenvalue ({re}, {im})");
                re
            })
            .collect();
        got.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut want = want.clone();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6 * w.abs().max(1.0), "eig {g} vs {w}");
        }
        // The accumulated Q, Z still reconstruct the original pencil.
        crate::linalg::verify::HtVerification::compute(&a, &b, &q, &z, &h, &t, 1)
            .assert_ok(1e-10);
    }

    #[test]
    fn qz_diverges_gracefully_on_complex_spectrum() {
        // A rotation pencil has complex eigenvalues: single-shift QZ must
        // hit max_iters, not loop forever.
        let n = 6;
        let mut h = Matrix::zeros(n, n);
        for i in 0..n - 1 {
            h[(i + 1, i)] = 1.0;
            h[(i, i + 1)] = -1.0;
        }
        h[(0, n - 1)] = 1.0; // not Hessenberg-relevant; keep square
        let mut t = Matrix::identity(n);
        let mut q = Matrix::identity(n);
        let mut z = Matrix::identity(n);
        let r = qz(&mut h, &mut t, &mut q, &mut z, 30);
        assert!(r.is_err());
    }
}
