//! Stage 2, unblocked (Algorithm 2): bulge-chasing reduction of an
//! r-Hessenberg-triangular pencil to Hessenberg-triangular form.
//!
//! One *sweep* `j` reduces column `j` of `A` to Hessenberg form and chases
//! the resulting fill ("bulge") off the bottom of the pencil:
//!
//! * `Q̂ₖ` (left) reduces `A(i1:i2, j_b)` — for `k = 0` the Hessenberg
//!   column itself, for `k ≥ 1` the bulge column — and fills the diagonal
//!   block `B(i1:i2, i1:i2)`.
//! * `Ẑₖ` (right) is the *opposite reflector*: RQ-factor that `B` block and
//!   reduce the first row of its orthogonal factor `Q̃`; applying `Ẑₖ` to
//!   the block columns restores `B`'s first block column and pushes a new
//!   bulge into `A(i2+1:i3, i1:i2)` — handled at chase step `k+1`.
//!
//! This is the reference implementation: the blocked Algorithm 3+4 must
//! produce *exactly* the same reflector sequence (tested), and the flop
//! count is the paper's `10 n³ + O(n²)`.

use crate::linalg::householder::Reflector;
use crate::linalg::matrix::Matrix;
use crate::linalg::rq::RqFactor;

/// Geometry of chase step `k` of sweep `j` (paper lines 6–9, 0-based
/// half-open). `None` when the step degenerates (segment shorter than 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaseStep {
    /// Sweep (column being reduced), 0-based.
    pub j: usize,
    /// Chase index `k ≥ 0`.
    pub k: usize,
    /// Column reduced by `Q̂ₖ`: `j` for `k = 0`, else the bulge column.
    pub jb: usize,
    /// Reflector row range start.
    pub i1: usize,
    /// Reflector row range end (exclusive).
    pub i2e: usize,
    /// Right-update row extent (exclusive): fill reaches `i3e`.
    pub i3e: usize,
}

/// Compute the chase geometry for sweep `j` (0-based), bandwidth `r`,
/// problem size `n`. Mirrors paper Algorithm 2 lines 4–9.
pub fn chase_steps(n: usize, r: usize, j: usize) -> Vec<ChaseStep> {
    // paper (1-based): n_blocks = 1 + floor((n - j - 2)/r); here j is
    // 0-based so n - j - 3 ≥ 0 must hold for at least one step.
    if j + 3 > n {
        return Vec::new();
    }
    let nblocks = 1 + (n - j - 3) / r;
    let mut steps = Vec::new();
    for k in 0..nblocks {
        let jb = j + if k == 0 { 0 } else { (k - 1) * r + 1 };
        let i1 = j + k * r + 1;
        let i2e = (j + 1 + (k + 1) * r).min(n);
        let i3e = (j + 1 + (k + 2) * r).min(n);
        if i2e <= i1 + 1 {
            // Segment of length ≤ 1: nothing to reduce.
            continue;
        }
        steps.push(ChaseStep { j, k, jb, i1, i2e, i3e });
    }
    steps
}

/// Generate the left reflector `Q̂ₖ` for a chase step from the current `A`.
pub fn left_reflector(a: &Matrix, st: &ChaseStep) -> Reflector {
    let x: Vec<f64> = (st.i1..st.i2e).map(|i| a[(i, st.jb)]).collect();
    Reflector::reducing(&x).0
}

/// Generate the opposite right reflector `Ẑₖ` from the current `B` block
/// (paper lines 14–15): RQ-factor `B(i1:i2, i1:i2)` and reduce the first
/// row of `Q̃`.
pub fn right_reflector(b: &Matrix, st: &ChaseStep) -> Reflector {
    let blk = b.sub(st.i1..st.i2e, st.i1..st.i2e).to_owned();
    let rq = RqFactor::compute(&blk);
    let row = rq.q_top_rows(1); // 1×s
    let x: Vec<f64> = (0..st.i2e - st.i1).map(|c| row[(0, c)]).collect();
    Reflector::reducing(&x).0
}

/// Apply one full chase step to the pencil and the accumulated `Q`, `Z`
/// (paper lines 10–18), flushing the annihilated entries to exact zeros.
pub fn apply_chase_step(
    st: &ChaseStep,
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
) -> (Reflector, Reflector) {
    let n = a.rows();
    let (i1, i2e, i3e, jb) = (st.i1, st.i2e, st.i3e, st.jb);

    let qk = left_reflector(a, st);
    // paper l.11: A(i1:i2, jb:n) = Q̂ A(i1:i2, jb:n)
    qk.apply_left(a.sub_mut(i1..i2e, jb..n));
    // paper l.12: B(i1:i2, i1:n) = Q̂ B(i1:i2, i1:n)
    qk.apply_left(b.sub_mut(i1..i2e, i1..n));
    // paper l.13: Q(:, i1:i2) = Q(:, i1:i2) Q̂
    qk.apply_right(q.sub_mut(0..n, i1..i2e));
    // The reduced column is exactly zero below i1.
    for i in i1 + 1..i2e {
        a[(i, jb)] = 0.0;
    }

    let zk = right_reflector(b, st);
    // paper l.16: A(1:i3, i1:i2) = A(1:i3, i1:i2) Ẑ
    zk.apply_right(a.sub_mut(0..i3e, i1..i2e));
    // paper l.17: B(1:i2, i1:i2) = B(1:i2, i1:i2) Ẑ
    zk.apply_right(b.sub_mut(0..i2e, i1..i2e));
    // paper l.18: Z(:, i1:i2) = Z(:, i1:i2) Ẑ
    zk.apply_right(z.sub_mut(0..n, i1..i2e));
    // First block column of B is reduced (opposite-reflector property).
    for i in i1 + 1..i2e {
        b[(i, i1)] = 0.0;
    }

    (qk, zk)
}

/// Sequential unblocked stage 2: reduce an r-Hessenberg-triangular pencil
/// to Hessenberg-triangular form, accumulating into `q`, `z`.
pub fn reduce_unblocked(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    r: usize,
) {
    let n = a.rows();
    if n < 3 {
        return;
    }
    for j in 0..n - 2 {
        for st in chase_steps(n, r, j) {
            apply_chase_step(&st, a, b, q, z);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::ht::stage1::reduce_to_banded;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    /// Random pencil already in r-HT form (via stage 1).
    fn banded_pencil(n: usize, r: usize, seed: u64) -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let pencil = random_pencil(n, &mut rng);
        let (a0, b0) = (pencil.a.clone(), pencil.b.clone());
        let mut a = pencil.a;
        let mut b = pencil.b;
        let mut q = Matrix::identity(n);
        let mut z = Matrix::identity(n);
        let cfg = Config { r, p: 3, ..Config::default() };
        reduce_to_banded(&mut a, &mut b, &mut q, &mut z, &cfg);
        (a0, b0, a, b, q, z)
    }

    #[test]
    fn chase_geometry_first_sweep() {
        // n = 20, r = 4, j = 0: blocks at i1 = 1, 5, 9, 13, 17.
        let steps = chase_steps(20, 4, 0);
        assert_eq!(steps[0], ChaseStep { j: 0, k: 0, jb: 0, i1: 1, i2e: 5, i3e: 9 });
        assert_eq!(steps[1].jb, 1); // bulge column for k = 1
        assert_eq!(steps[1].i1, 5);
        // Last step clipped at n.
        let last = steps.last().unwrap();
        assert_eq!(last.i2e, 20);
        assert_eq!(last.i3e, 20);
    }

    #[test]
    fn chase_geometry_degenerate() {
        assert!(chase_steps(3, 4, 1).is_empty()); // j + 3 > n
        let steps = chase_steps(4, 4, 0);
        assert_eq!(steps.len(), 1);
        // n=5, r=2, j=2 (last sweep): single short step.
        let steps = chase_steps(5, 2, 2);
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].i1, 3);
        assert_eq!(steps[0].i2e, 5);
    }

    #[test]
    fn reduces_banded_to_hessenberg_small() {
        let (a0, b0, mut a, mut b, mut q, mut z) = banded_pencil(30, 4, 21);
        reduce_unblocked(&mut a, &mut b, &mut q, &mut z, 4);
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro(), "not Hessenberg: {:.3e}", max_below_band(&a, 1));
        assert_eq!(max_below_band(&b, 0), 0.0);
        let v = HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1);
        v.assert_ok(1e-11);
    }

    #[test]
    fn two_stage_paper_parameters() {
        let (a0, b0, mut a, mut b, mut q, mut z) = banded_pencil(120, 16, 22);
        reduce_unblocked(&mut a, &mut b, &mut q, &mut z, 16);
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro());
        assert_eq!(max_below_band(&b, 0), 0.0);
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
    }

    #[test]
    fn odd_sizes_and_bandwidths() {
        for &(n, r) in &[(23usize, 3usize), (31, 5), (17, 7), (11, 2)] {
            let (a0, b0, mut a, mut b, mut q, mut z) = banded_pencil(n, r, 23);
            reduce_unblocked(&mut a, &mut b, &mut q, &mut z, r);
            assert!(max_below_band(&a, 1) < 1e-11 * a.norm_fro(), "n={n} r={r}");
            assert_eq!(max_below_band(&b, 0), 0.0);
            HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-10);
        }
    }

    #[test]
    fn r1_input_is_already_hessenberg() {
        // With r = 1 stage 1 output is already Hessenberg; every chase step
        // degenerates and stage 2 must leave the pencil unchanged... but
        // r = 1 gives segments of length ≤ 2; steps still run and must
        // preserve correctness.
        let (a0, b0, mut a, mut b, mut q, mut z) = banded_pencil(15, 2, 24);
        reduce_unblocked(&mut a, &mut b, &mut q, &mut z, 2);
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
    }
}
