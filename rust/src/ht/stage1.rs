//! Stage 1: blocked reduction to r-Hessenberg-triangular form
//! (Algorithm 1 of the paper; originally Dackland & Kågström / Kågström,
//! Kressner, Quintana-Ortí²).
//!
//! One panel iteration (paper Fig. 1), for panel columns `j .. j+n_b`:
//!
//! 1. **Left**: split `A(j+n_b : n, panel)` into overlapping `p·n_b × n_b`
//!    blocks (overlap `n_b` rows) and QR-factor them bottom-up; each block
//!    reflector `Q̂ₖ` is applied to the trailing columns of `A`, the rows of
//!    `B`, and accumulated into `Q`. Afterwards the panel is upper
//!    triangular below row `j + n_b` ⇒ `A` is r-Hessenberg in those columns
//!    with `r = n_b`.
//! 2. **Right**: the row mixing filled `p·n_b`-sized diagonal blocks of `B`.
//!    For each block (bottom-up), RQ-factor it, LQ-factor the first `n_b`
//!    rows of the orthogonal factor `Q̃`, and apply the *opposite* block
//!    reflector `Ẑ` from the right — reducing exactly the first `n_b`
//!    columns of the block (Watkins' trick) at the cost of `n_b` (not
//!    `p·n_b`) reflectors. Fill-in left in later columns of each block
//!    slides down `n_b` rows per panel and falls off the matrix edge.
//!
//! Paper index ranges are 1-based inclusive; here everything is 0-based
//! half-open (`// paper:` comments give the original).

use crate::config::Config;
use crate::linalg::matrix::{MatMut, MatRef, Matrix};
use crate::linalg::qr::{lq, QrFactor};
use crate::linalg::rq::RqFactor;
use crate::linalg::wy::{Side, WyRep};
use crate::linalg::Trans;

/// Plan of one panel iteration: the block row ranges shared by the left and
/// right passes. Extracted so the parallel driver (coordinator) can build
/// its task graph from the same geometry.
#[derive(Clone, Debug)]
pub struct PanelPlan {
    /// Panel start column `j` (0-based).
    pub j: usize,
    /// Panel end column (exclusive): `j + n_b` clipped to `n`.
    pub je: usize,
    /// Per-block `(i1, i2e)` row ranges, `k = 0` topmost.
    pub blocks: Vec<(usize, usize)>,
}

/// Compute the panel iteration plan for problem size `n`, bandwidth
/// `r = n_b` and block multiplier `p` (paper lines 3–9 of Algorithm 1).
pub fn panel_plans(n: usize, nb: usize, p: usize) -> Vec<PanelPlan> {
    let mut plans = Vec::new();
    let mut j = 0;
    // paper: for j = 1 : nb : n-2
    while j + 2 < n {
        let je = (j + nb).min(n);
        // paper: n_blocks = ceil((n - nb - j + 1)/((p-1) nb)), 1-based j.
        let remaining = n as i64 - nb as i64 - j as i64;
        if remaining > 0 {
            let step = (p - 1) * nb;
            let nblocks = ((remaining as usize) + step - 1) / step;
            let blocks = (0..nblocks)
                .map(|k| {
                    let i1 = j + nb + k * step;
                    let i2e = (i1 + p * nb).min(n);
                    (i1, i2e)
                })
                .collect();
            plans.push(PanelPlan { j, je, blocks });
        }
        j += nb;
    }
    plans
}

/// The two block reflectors produced while processing one block of one
/// panel: `q_wy` reduces the panel rows from the left; `z_wy` is the
/// opposite reflector removing `B`'s fill from the right.
pub struct BlockReflectors {
    /// Left block reflector `Q̂ₖ` (WY form), order `i2e - i1`.
    pub q_wy: WyRep,
    /// Right opposite block reflector `Ẑₖ` (WY form), order `i2e - i1`.
    pub z_wy: WyRep,
}

/// Factor a panel block (a view of `A(i1:i2e, j:je)`) in place: compute the
/// QR, overwrite the block with `R̂` (exact zeros below the diagonal) and
/// return the WY form of `Q̂`. (Paper lines 10–11.)
pub fn factor_panel_block(mut blk: MatMut<'_>) -> WyRep {
    let owned = blk.rb().to_owned();
    let f = QrFactor::compute_inplace(owned);
    // Write back R̂; exact zeros below the diagonal.
    let r = f.r();
    for jj in 0..blk.cols() {
        for ii in 0..blk.rows() {
            blk.set(ii, jj, if ii <= jj && ii < r.rows() { r[(ii, jj)] } else { 0.0 });
        }
    }
    f.wy()
}

/// Generate the opposite reflector `Ẑ` for a `B` diagonal block (a view of
/// `B(i1:i2e, i1:i2e)`; paper lines 19–20): RQ-factor it, take the first
/// `t = min(n_b, s)` rows of `Q̃`, LQ-factor them; the LQ's orthogonal
/// factor applied from the right reduces the first `t` columns of the block.
pub fn opposite_reflector(blk: MatRef<'_>, nb: usize) -> WyRep {
    let s = blk.rows();
    let t = nb.min(s);
    let owned = blk.to_owned();
    let rq = RqFactor::compute(&owned);
    let g = rq.q_top_rows(t); // t×s
    // g = L · Q̂ with Q̂ = Q_qrᵀ (QR of gᵀ). The transformation applied to
    // columns is Ẑ_app = Q̂ᵀ = Q_qr, i.e. the WY applied with Trans::No.
    let (_l, wy) = lq(&g);
    wy
}

/// Zero out the (numerically tiny) sub-diagonal entries of the first `t`
/// columns of a `B` diagonal block after the opposite reflector has been
/// applied. The opposite-reflector argument guarantees they are
/// `O(eps·‖B‖)`; flushing them keeps `B`'s triangular invariant exact.
pub fn flush_b_subdiagonal(mut blk: MatMut<'_>, t: usize) {
    let s = blk.rows();
    for c in 0..t.min(s) {
        for i in (c + 1)..s {
            blk.set(i, c, 0.0);
        }
    }
}

/// Sequential stage 1: reduce `(A, B)` (with `B` upper triangular) to
/// r-Hessenberg-triangular form, accumulating the transformations into `q`
/// and `z` (`A₀ = Q A Zᵀ`, `B₀ = Q B Zᵀ` maintained as an invariant).
pub fn reduce_to_banded(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    cfg: &Config,
) {
    let n = a.rows();
    let nb = cfg.r;
    let p = cfg.p;
    assert_eq!(a.cols(), n);
    assert_eq!(b.rows(), n);
    assert_eq!(b.cols(), n);

    for plan in panel_plans(n, nb, p) {
        let (j, je) = (plan.j, plan.je);

        // ---- Left pass: QR blocks bottom-up (paper lines 7–15). ----
        // The trailing updates go through `apply_par`, which splits the
        // free dimension over the persistent process-global worker team
        // (`coordinator::pool::global`, `cfg.threads` executors) and is
        // bitwise identical to the sequential apply (slicing-invariant
        // kernels) — so this driver stays the exact oracle for the
        // coordinator's task graph while saturating cores when the graph
        // itself is not used. Because the team outlives the call, the many
        // small per-block applies reuse hot worker pack buffers instead of
        // paying thread startup per apply as the old scoped model did.
        for &(i1, i2e) in plan.blocks.iter().rev() {
            if i2e <= i1 {
                continue;
            }
            let q_wy = factor_panel_block(a.sub_mut(i1..i2e, j..je));
            // paper l.12: A(i1:i2, j2+1:n) = Q̂ᵀ A(i1:i2, j2+1:n)
            q_wy.apply_par(Side::Left, Trans::Yes, a.sub_mut(i1..i2e, je..n), cfg.threads);
            // paper l.13: B(i1:i2, i1:n) = Q̂ᵀ B(i1:i2, i1:n)
            q_wy.apply_par(Side::Left, Trans::Yes, b.sub_mut(i1..i2e, i1..n), cfg.threads);
            // paper l.14: Q(1:n, i1:i2) = Q(1:n, i1:i2) Q̂
            q_wy.apply_par(Side::Right, Trans::No, q.sub_mut(0..n, i1..i2e), cfg.threads);
        }

        // ---- Right pass: opposite reflectors bottom-up (lines 16–24). ----
        for &(i1, i2e) in plan.blocks.iter().rev() {
            let s = i2e - i1;
            if s == 0 {
                continue;
            }
            let t = nb.min(s);
            let z_wy = opposite_reflector(b.sub(i1..i2e, i1..i2e), nb);
            // paper l.21: A(1:n, i1:i2) = A(1:n, i1:i2) Ẑ
            z_wy.apply_par(Side::Right, Trans::No, a.sub_mut(0..n, i1..i2e), cfg.threads);
            // paper l.22: B(1:i2, i1:i2) = B(1:i2, i1:i2) Ẑ
            z_wy.apply_par(Side::Right, Trans::No, b.sub_mut(0..i2e, i1..i2e), cfg.threads);
            // paper l.23: Z(1:n, i1:i2) = Z(1:n, i1:i2) Ẑ
            z_wy.apply_par(Side::Right, Trans::No, z.sub_mut(0..n, i1..i2e), cfg.threads);
            flush_b_subdiagonal(b.sub_mut(i1..i2e, i1..i2e), t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    fn run_stage1(n: usize, r: usize, p: usize, seed: u64) -> (Matrix, Matrix, HtVerification) {
        let mut rng = Rng::new(seed);
        let pencil = random_pencil(n, &mut rng);
        let (a0, b0) = (pencil.a.clone(), pencil.b.clone());
        let mut a = pencil.a;
        let mut b = pencil.b;
        let mut q = Matrix::identity(n);
        let mut z = Matrix::identity(n);
        let cfg = Config { r, p, ..Config::default() };
        reduce_to_banded(&mut a, &mut b, &mut q, &mut z, &cfg);
        let v = HtVerification::compute(&a0, &b0, &q, &z, &a, &b, r);
        (a, b, v)
    }

    #[test]
    fn reduces_to_banded_form_small() {
        let (a, b, v) = run_stage1(40, 4, 3, 11);
        assert!(max_below_band(&a, 4) < 1e-12 * a.norm_fro(), "A not 4-Hessenberg: {:.3e}", max_below_band(&a, 4));
        assert_eq!(max_below_band(&b, 0), 0.0, "B not triangular");
        v.assert_ok(1e-12);
    }

    #[test]
    fn reduces_paper_parameters() {
        // r = 16, p = 8 as in the paper (§4), scaled-down n.
        let (a, b, v) = run_stage1(200, 16, 8, 12);
        assert!(max_below_band(&a, 16) < 1e-12 * a.norm_fro());
        assert_eq!(max_below_band(&b, 0), 0.0);
        v.assert_ok(1e-12);
    }

    #[test]
    fn non_divisible_sizes() {
        // n not a multiple of nb, blocks clipped at the edge.
        for &(n, r, p) in &[(37usize, 5usize, 3usize), (53, 7, 4), (29, 4, 2)] {
            let (a, b, v) = run_stage1(n, r, p, 13);
            assert!(max_below_band(&a, r) < 1e-12 * a.norm_fro(), "n={n} r={r} p={p}");
            assert_eq!(max_below_band(&b, 0), 0.0);
            v.assert_ok(1e-12);
        }
    }

    #[test]
    fn panel_plans_geometry() {
        let plans = panel_plans(30, 4, 3);
        // First panel: j=0, blocks start at 4, step 8, width ≤ 12.
        assert_eq!(plans[0].j, 0);
        assert_eq!(plans[0].je, 4);
        assert_eq!(plans[0].blocks[0], (4, 16));
        assert_eq!(plans[0].blocks[1], (12, 24));
        // Consecutive blocks overlap by nb rows.
        for plan in &plans {
            for w in plan.blocks.windows(2) {
                let (_, e0) = w[0];
                let (s1, _) = w[1];
                if e0 < 30 {
                    assert_eq!(e0 - s1, 4, "overlap must be nb");
                }
            }
            // Last block reaches n when any block exists.
            if let Some(&(_, e)) = plan.blocks.last() {
                assert_eq!(e, 30);
            }
        }
    }

    #[test]
    fn tiny_matrix_is_noop_or_valid() {
        // n <= 2: loop body never runs; n slightly above r: single panel.
        let (a, b, v) = run_stage1(10, 8, 3, 14);
        assert!(max_below_band(&a, 8) < 1e-12 * a.norm_fro().max(1.0));
        assert_eq!(max_below_band(&b, 0), 0.0);
        v.assert_ok(1e-12);
    }
}
