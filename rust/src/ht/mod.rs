//! The paper's algorithms: stage 1 (blocked reduction to r-Hessenberg-
//! triangular form, Alg. 1), stage 2 (bulge-chasing reduction to
//! Hessenberg-triangular form: unblocked Alg. 2 and blocked Algs. 3–4)
//! and the combined two-stage driver.

pub mod qz;
pub mod reflector_store;
pub mod stage1;
pub mod stage2_blocked;
pub mod stage2_unblocked;
pub mod two_stage;

pub use two_stage::HtDecomposition;
#[allow(deprecated)] // the shim stays re-exported until downstream code migrates
pub use two_stage::reduce_to_hessenberg_triangular;
