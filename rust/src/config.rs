//! Algorithm configuration (the paper's tuning parameters).

use crate::error::{Error, Result};

/// Tuning parameters of the two-stage reduction.
///
/// Paper defaults (§4): `r = 16`, `p = 8`, `q = 8`; HouseHT uses `n_b = 64`.
#[derive(Clone, Debug)]
pub struct Config {
    /// Stage-1 target bandwidth / stage-1 panel width `n_b` (the paper sets
    /// `r = n_b`; column `j` of the r-Hessenberg result has its last nonzero
    /// in row `j + r`).
    pub r: usize,
    /// Stage-1 block-height multiplier: QR blocks are `p·n_b × n_b`.
    pub p: usize,
    /// Stage-2 sweep-group size (columns per generate/apply round).
    pub q: usize,
    /// Number of worker threads (real execution) / virtual cores (simulation).
    pub threads: usize,
    /// Number of row/column slices per apply task (0 = auto: ~2× threads).
    pub slices: usize,
    /// Whether stage-2 lookahead tasks are enabled (§3.3). Ablation switch.
    pub lookahead: bool,
    /// Offload large WY applications to the PJRT runtime when available.
    pub use_pjrt: bool,
    /// RNG seed for workload generation.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            r: 16,
            p: 8,
            q: 8,
            threads: 1,
            slices: 0,
            lookahead: true,
            use_pjrt: false,
            seed: 0x5EED,
        }
    }
}

impl Config {
    /// Validate parameter consistency.
    pub fn validate(&self) -> Result<()> {
        if self.r < 2 {
            return Err(Error::config("r must be >= 2"));
        }
        if self.p < 2 {
            return Err(Error::config("p must be >= 2 (blocks are p*nb x nb)"));
        }
        if self.q < 1 {
            return Err(Error::config("q must be >= 1"));
        }
        if self.threads < 1 {
            return Err(Error::config("threads must be >= 1"));
        }
        Ok(())
    }

    /// Effective slice count for apply tasks.
    pub fn effective_slices(&self) -> usize {
        if self.slices > 0 {
            self.slices
        } else {
            (2 * self.threads).max(4)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_tuning() {
        let c = Config::default();
        assert_eq!((c.r, c.p, c.q), (16, 8, 8));
        assert!(c.validate().is_ok());
    }

    #[test]
    fn rejects_bad_params() {
        let mut c = Config::default();
        c.p = 1;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.r = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn auto_slices() {
        let mut c = Config::default();
        c.threads = 8;
        assert_eq!(c.effective_slices(), 16);
        c.slices = 3;
        assert_eq!(c.effective_slices(), 3);
    }
}
