//! Algorithm configuration (the paper's tuning parameters).

use crate::error::{Error, Result};
use crate::linalg::kernels::{Kernel, KernelChoice};

/// Tuning parameters of the two-stage reduction.
///
/// Paper defaults (§4): `r = 16`, `p = 8`, `q = 8`; HouseHT uses `n_b = 64`.
#[derive(Clone, Debug)]
pub struct Config {
    /// Stage-1 target bandwidth / stage-1 panel width `n_b` (the paper sets
    /// `r = n_b`; column `j` of the r-Hessenberg result has its last nonzero
    /// in row `j + r`).
    pub r: usize,
    /// Stage-1 block-height multiplier: QR blocks are `p·n_b × n_b`.
    pub p: usize,
    /// Stage-2 sweep-group size (columns per generate/apply round).
    pub q: usize,
    /// Number of worker threads (real execution) / virtual cores (simulation).
    pub threads: usize,
    /// Number of row/column slices per apply task (0 = auto: ~2× threads).
    pub slices: usize,
    /// Whether stage-2 lookahead tasks are enabled (§3.3). Ablation switch.
    pub lookahead: bool,
    /// Work-assisting dynamic panel scheduling
    /// ([`crate::coordinator::assist`]): executors claim panel indices
    /// from a shared atomic counter at run time instead of receiving a
    /// static split up front. Changes *who* computes each panel, never the
    /// panel contents, so results stay bitwise identical to static runs
    /// (pinned by `tests/equivalence.rs`). Default off; the
    /// `PALLAS_ASSIST` env knob flips the process-wide default.
    pub dynamic_schedule: bool,
    /// Offload large WY applications to the PJRT runtime when available.
    pub use_pjrt: bool,
    /// GEMM microkernel selection ([`crate::linalg::kernels`]). `Auto`
    /// (the default) defers to the `PALLAS_KERNEL` env knob / runtime
    /// feature detection; an explicit choice overrides both (clamped to
    /// scalar when the requested SIMD is unavailable). Changes per-term
    /// rounding (fused vs unfused), never the accumulation order, so
    /// results for a *fixed* kernel stay bitwise invariant across
    /// threads/slicing/scheduling; across kernels they differ by O(eps).
    pub kernel: KernelChoice,
    /// RNG seed for workload generation.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            r: 16,
            p: 8,
            q: 8,
            threads: 1,
            slices: 0,
            lookahead: true,
            dynamic_schedule: false,
            use_pjrt: false,
            kernel: KernelChoice::Auto,
            seed: 0x5EED,
        }
    }
}

/// Hard cap on worker threads / virtual cores — a thread budget guarding
/// against typo'd configs spawning thousands of OS threads.
pub const MAX_THREADS: usize = 4096;

/// Hard cap on `p·q` (stage-1 block multiplier × stage-2 group size): the
/// coordinator allocates per-group reflector arenas and task fan-out
/// proportional to these, so a pathological product is a config error, not
/// something to discover as an OOM mid-run.
pub const MAX_BLOCK_PRODUCT: usize = 65_536;

/// Hard cap on explicit slice counts.
pub const MAX_SLICES: usize = 65_536;

impl Config {
    /// Validate parameter consistency (problem-size-independent checks).
    /// Every driver entry point calls this (and [`Config::validate_for`])
    /// before touching a matrix, so inconsistent blocking parameters
    /// surface as [`Error::Config`] instead of panics or silent nonsense.
    pub fn validate(&self) -> Result<()> {
        if self.r < 2 {
            return Err(Error::config(format!("r must be >= 2 (got {})", self.r)));
        }
        if self.p < 2 {
            return Err(Error::config(format!(
                "p must be >= 2 (blocks are p*nb x nb; got {})",
                self.p
            )));
        }
        if self.q < 1 {
            return Err(Error::config("q must be >= 1"));
        }
        if self.threads < 1 {
            return Err(Error::config("threads must be >= 1"));
        }
        if self.threads > MAX_THREADS {
            return Err(Error::config(format!(
                "threads = {} exceeds the thread budget ({MAX_THREADS})",
                self.threads
            )));
        }
        match self.p.checked_mul(self.q) {
            None => {
                return Err(Error::config(format!(
                    "p*q overflows (p = {}, q = {})",
                    self.p, self.q
                )))
            }
            Some(pq) if pq > MAX_BLOCK_PRODUCT => {
                return Err(Error::config(format!(
                    "p*q = {pq} exceeds the scheduler task budget ({MAX_BLOCK_PRODUCT}); \
                     the coordinator's arenas and fan-out scale with p·q"
                )));
            }
            Some(_) => {}
        }
        if self.slices > MAX_SLICES {
            return Err(Error::config(format!(
                "slices = {} exceeds {MAX_SLICES}",
                self.slices
            )));
        }
        Ok(())
    }

    /// Validate against a concrete problem size `n`: everything in
    /// [`Config::validate`] plus the blocking-vs-size consistency checks.
    /// `r >= n` would make stage 1 a silent no-op (no bandwidth to reduce
    /// to) — reject it instead. Blocks larger than the matrix
    /// (`p·r > n`) are legal: the panel plans clip them at the edge.
    pub fn validate_for(&self, n: usize) -> Result<()> {
        self.validate()?;
        if n >= 3 && self.r >= n {
            return Err(Error::config(format!(
                "stage-1 bandwidth r = {} must be smaller than the problem size n = {n}",
                self.r
            )));
        }
        Ok(())
    }

    /// The band-clipped configuration for a concrete problem size: `r`
    /// reduced to `min(r, n - 1)` (floor 2) when the configured band does
    /// not fit the pencil. This is the one shared definition of the
    /// small-pencil clipping rule — `api::HtSession` (via
    /// `HtSessionBuilder::clip_band`) and the serving layer's
    /// [`crate::serve::ShardRouter`] both route through it, so a cache key
    /// computed from the clipped config always matches the config the
    /// reduction actually ran with. Pencils with `n < 3` are no-ops for
    /// every stage and come back unchanged.
    pub fn clipped_for(&self, n: usize) -> Config {
        let mut cfg = self.clone();
        if n >= 3 && cfg.r >= n {
            cfg.r = (n - 1).max(2);
        }
        cfg
    }

    /// The concrete microkernel this configuration runs with: `Auto`
    /// resolves through the process default (`PALLAS_KERNEL`, then runtime
    /// feature detection), an explicit choice through
    /// [`Kernel::detect`] (which clamps unavailable SIMD requests to
    /// scalar). Driver entry points install this on the executing threads;
    /// the serving layer mixes its id into cache keys and fingerprints so
    /// results computed under different kernels never alias.
    pub fn resolved_kernel(&self) -> Kernel {
        match self.kernel {
            KernelChoice::Auto => crate::linalg::kernels::process_default(),
            choice => Kernel::detect(choice),
        }
    }

    /// Whether two configs share the *result-determining* tuning: `r`,
    /// `p`, `q`, `lookahead`, and the resolved GEMM kernel — the exact
    /// field set the serving cache keys on
    /// ([`crate::serve::pencil_fingerprint`]). Capacity knobs (`threads`,
    /// `slices`, `dynamic_schedule`) are output-invariant by the
    /// determinism contract and deliberately ignored. The network front
    /// door uses this to decide whether a client's explicit wire tuning
    /// matches the tuning the serving queue is pinned to.
    pub fn same_tuning(&self, other: &Config) -> bool {
        self.r == other.r
            && self.p == other.p
            && self.q == other.q
            && self.lookahead == other.lookahead
            && self.resolved_kernel() == other.resolved_kernel()
    }

    /// Effective slice count for apply tasks.
    pub fn effective_slices(&self) -> usize {
        if self.slices > 0 {
            self.slices
        } else {
            (2 * self.threads).max(4)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_tuning() {
        let c = Config::default();
        assert_eq!((c.r, c.p, c.q), (16, 8, 8));
        assert!(c.validate().is_ok());
        assert!(!c.dynamic_schedule, "work assisting must be opt-in");
        assert_eq!(c.kernel, KernelChoice::Auto, "kernel selection defaults to auto");
    }

    #[test]
    fn kernel_choice_resolves_and_survives_clipping() {
        // An explicit scalar request resolves to the scalar kernel on every
        // platform, and the process-default path (Auto) returns one of the
        // runtime-available variants.
        let c = Config { kernel: KernelChoice::Scalar, ..Config::default() };
        assert_eq!(c.resolved_kernel(), Kernel::Scalar);
        assert!(c.clipped_for(10).kernel == KernelChoice::Scalar, "clipping must not drop the kernel");
        let auto = Config::default().resolved_kernel();
        assert!(Kernel::all_available().contains(&auto));
    }

    #[test]
    fn dynamic_schedule_gate_passes_validation_and_survives_clipping() {
        let c = Config { dynamic_schedule: true, ..Config::default() };
        assert!(c.validate().is_ok());
        assert!(c.clipped_for(10).dynamic_schedule, "clipping must not drop the gate");
    }

    #[test]
    fn rejects_bad_params() {
        let mut c = Config::default();
        c.p = 1;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.r = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_r_zero_and_one() {
        for r in [0usize, 1] {
            let c = Config { r, ..Config::default() };
            let e = c.validate().unwrap_err();
            assert!(matches!(e, crate::Error::Config(_)), "r={r}: {e}");
        }
    }

    #[test]
    fn rejects_thread_budget_violations() {
        // threads over the hard budget.
        let c = Config { threads: MAX_THREADS + 1, ..Config::default() };
        assert!(c.validate().is_err());
        // p*q exceeding the scheduler task budget.
        let c = Config { p: 1024, q: 1024, ..Config::default() };
        let e = c.validate().unwrap_err();
        assert!(format!("{e}").contains("task budget"), "{e}");
        // p*q overflow does not panic — it errors.
        let c = Config { p: usize::MAX, q: 2, ..Config::default() };
        assert!(c.validate().is_err());
        // slices cap.
        let c = Config { slices: MAX_SLICES + 1, ..Config::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_block_size_exceeding_n() {
        // r >= n: no bandwidth left to reduce to.
        let c = Config { r: 16, ..Config::default() };
        assert!(c.validate_for(16).is_err());
        assert!(c.validate_for(10).is_err());
        assert!(c.validate_for(17).is_ok());
        // Oversized p·r blocks are clipped, not rejected.
        let c = Config { r: 4, p: 8, ..Config::default() };
        assert!(c.validate_for(12).is_ok());
        // Tiny problems (n < 3) are no-ops for every algorithm: accept.
        let c = Config::default();
        assert!(c.validate_for(2).is_ok());
        assert!(c.validate_for(0).is_ok());
    }

    #[test]
    fn validate_errors_are_config_variant() {
        let c = Config { q: 0, ..Config::default() };
        assert!(matches!(c.validate().unwrap_err(), crate::Error::Config(_)));
        let c = Config { threads: 0, ..Config::default() };
        assert!(matches!(c.validate().unwrap_err(), crate::Error::Config(_)));
    }

    #[test]
    fn clipped_for_matches_clip_band_rule() {
        let c = Config { r: 16, ..Config::default() };
        // Band does not fit: clipped to n - 1.
        assert_eq!(c.clipped_for(10).r, 9);
        assert!(c.clipped_for(10).validate_for(10).is_ok());
        // Band fits: unchanged.
        assert_eq!(c.clipped_for(40).r, 16);
        // Tiny no-op pencils come back unchanged (floor at r = 2 for n = 3).
        assert_eq!(c.clipped_for(2).r, 16);
        assert_eq!(c.clipped_for(3).r, 2);
    }

    #[test]
    fn same_tuning_tracks_result_determining_fields_only() {
        let base = Config { r: 8, p: 4, q: 4, ..Config::default() };
        // Capacity knobs don't split tunings.
        let capacity =
            Config { threads: 16, slices: 9, dynamic_schedule: true, ..base.clone() };
        assert!(base.same_tuning(&capacity));
        // Every result-determining field does.
        for other in [
            Config { r: 9, ..base.clone() },
            Config { p: 5, ..base.clone() },
            Config { q: 5, ..base.clone() },
            Config { lookahead: false, ..base.clone() },
        ] {
            assert!(!base.same_tuning(&other), "{other:?}");
        }
        // Kernel comparison is at the resolved level: Auto vs the explicit
        // spelling of what Auto resolves to are the same tuning.
        let explicit = Config { kernel: base.resolved_kernel().choice(), ..base.clone() };
        assert!(base.same_tuning(&explicit));
    }

    #[test]
    fn auto_slices() {
        let mut c = Config::default();
        c.threads = 8;
        assert_eq!(c.effective_slices(), 16);
        c.slices = 3;
        assert_eq!(c.effective_slices(), 3);
    }
}
