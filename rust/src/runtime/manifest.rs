//! Artifact manifest parsing.
//!
//! `make artifacts` (the build-time python step) writes
//! `artifacts/manifest.txt` with one line per AOT-lowered HLO module:
//!
//! ```text
//! name kind m n k relative-path
//! ```
//!
//! where `kind ∈ {left, right, panel}` and `(m, n, k)` are the bucket's
//! `C` dimensions and reflector count. No JSON parser ships in the offline
//! crate set, so the format is deliberately line-oriented.

use crate::error::{Error, Result};
use std::path::{Path, PathBuf};

/// Bucket kind.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BucketKind {
    /// `C ← QᵀC` (C is m×n, reflectors span the m side).
    Left,
    /// `C ← C·Q` (C is m×n, reflectors span the n side).
    Right,
    /// Fused stage-1 panel step.
    Panel,
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// Bucket name (`wy_left_128x16_n128`, …).
    pub name: String,
    /// Kind of computation.
    pub kind: BucketKind,
    /// Rows of the C bucket.
    pub m: usize,
    /// Columns of the C bucket.
    pub n: usize,
    /// Reflector count (WY width).
    pub k: usize,
    /// Absolute path to the HLO text file.
    pub path: PathBuf,
}

/// Parse `manifest.txt` in `dir`.
pub fn load_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split_whitespace().collect();
        if parts.len() != 6 {
            return Err(Error::runtime(format!(
                "manifest line {}: expected 6 fields, got {}",
                lineno + 1,
                parts.len()
            )));
        }
        let kind = match parts[1] {
            "left" => BucketKind::Left,
            "right" => BucketKind::Right,
            "panel" => BucketKind::Panel,
            other => return Err(Error::runtime(format!("manifest: unknown kind {other}"))),
        };
        let parse = |s: &str| -> Result<usize> {
            s.parse().map_err(|_| Error::runtime(format!("manifest: bad integer {s}")))
        };
        specs.push(ArtifactSpec {
            name: parts[0].to_string(),
            kind,
            m: parse(parts[2])?,
            n: parse(parts[3])?,
            k: parse(parts[4])?,
            path: dir.join(parts[5]),
        });
    }
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_wellformed() {
        let dir = std::env::temp_dir().join("paraht_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "# comment\nfoo left 128 128 16 foo.hlo.txt\nbar right 256 128 16 bar.hlo.txt\n",
        )
        .unwrap();
        let specs = load_manifest(&dir).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].kind, BucketKind::Left);
        assert_eq!(specs[1].m, 256);
        assert!(specs[1].path.ends_with("bar.hlo.txt"));
    }

    #[test]
    fn rejects_malformed() {
        let dir = std::env::temp_dir().join("paraht_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "foo left 128\n").unwrap();
        assert!(load_manifest(&dir).is_err());
        std::fs::write(dir.join("manifest.txt"), "foo sideways 1 2 3 x.txt\n").unwrap();
        assert!(load_manifest(&dir).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // When `make artifacts` has run, the real manifest must parse.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.txt").exists() {
            let specs = load_manifest(&dir).unwrap();
            assert!(specs.len() >= 5);
            assert!(specs.iter().any(|s| s.kind == BucketKind::Panel));
        }
    }
}
