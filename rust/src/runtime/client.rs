//! PJRT runtime: load the AOT HLO-text artifacts, compile them once on the
//! CPU PJRT client, and execute them from the rust hot path.
//!
//! Python never runs here — the interchange is the HLO text produced by
//! `python/compile/aot.py` at build time.
//!
//! This crate is pure-std; no XLA FFI is linked. The [`backend`] module is
//! the single swap-in point for a real PJRT binding: everything above it
//! (manifest parsing, shape buckets, padding/packing, the offload routing
//! in [`super::bucket`]) is backend-agnostic and fully tested. The stub
//! backend parses artifacts but reports `Error::Runtime` on compile, so
//! `PjrtRuntime::load` fails cleanly when no real backend is present —
//! callers (`paraht validate --pjrt`, the offload tests) treat that as
//! "artifacts not usable in this build" and skip.

use super::manifest::{load_manifest, ArtifactSpec, BucketKind};
use crate::error::{Error, Result};
use crate::linalg::matrix::{MatMut, MatRef};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// The swap-in point for a real PJRT FFI binding.
///
/// A real implementation compiles the HLO text at `spec.path` and returns
/// an executable whose `run` consumes row-major `f64` buffers. The stub
/// shipped here refuses to compile, keeping the crate dependency-free.
mod backend {
    use super::ArtifactSpec;
    use crate::error::{Error, Result};

    /// A compiled executable handle. The stub variant can never be
    /// constructed (`compile` always errors), so `run` is unreachable in
    /// practice; both stay defined to fix the interface a real backend
    /// must provide.
    pub struct Executable(());

    impl Executable {
        /// Execute on row-major f64 inputs; returns the flat row-major output.
        #[allow(dead_code)] // reachable only with a real backend linked
        pub fn run(&self, _inputs: &[(&[f64], [usize; 2])]) -> Result<Vec<f64>> {
            Err(Error::runtime("PJRT stub backend cannot execute"))
        }
    }

    /// Compile one artifact. The stub always fails with a runtime error.
    pub fn compile(spec: &ArtifactSpec) -> Result<Executable> {
        Err(Error::runtime(format!(
            "PJRT backend not linked in this build; cannot compile artifact '{}' ({}). \
             The pure-std crate ships with a stub backend — see runtime/client.rs.",
            spec.name,
            spec.path.display()
        )))
    }
}

/// A compiled artifact.
pub struct Compiled {
    /// Its manifest entry.
    pub spec: ArtifactSpec,
    exe: backend::Executable,
}

/// The PJRT runtime: client + compiled executable per artifact.
///
/// Executions are serialized through a mutex: a CPU PJRT client is
/// thread-safe, but serializing keeps buffer lifetimes simple and the
/// offload path is not the default hot path on this substrate.
pub struct PjrtRuntime {
    compiled: HashMap<String, Compiled>,
    lock: Mutex<()>,
}

impl PjrtRuntime {
    /// Load every artifact in `dir` (must contain `manifest.txt`).
    ///
    /// Fails with `Error::Runtime` when no real PJRT backend is linked (the
    /// default pure-std build) — callers should treat that as "offload
    /// unavailable" and use the native WY kernels.
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let specs = load_manifest(dir)?;
        let mut compiled = HashMap::new();
        for spec in specs {
            let exe = backend::compile(&spec)?;
            compiled.insert(spec.name.clone(), Compiled { spec, exe });
        }
        Ok(PjrtRuntime { compiled, lock: Mutex::new(()) })
    }

    /// Names of the loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }

    /// Find a bucket of the given kind with `m × n` C-shape and width `k`.
    pub fn find_bucket(&self, kind: BucketKind, m: usize, n: usize, k: usize) -> Option<&Compiled> {
        self.compiled
            .values()
            .find(|c| c.spec.kind == kind && c.spec.m == m && c.spec.n == n && c.spec.k == k)
    }

    /// Smallest bucket of `kind` that fits `(m, n, k)` (for padding).
    pub fn fitting_bucket(
        &self,
        kind: BucketKind,
        m: usize,
        n: usize,
        k: usize,
    ) -> Option<&Compiled> {
        self.compiled
            .values()
            .filter(|c| c.spec.kind == kind && c.spec.m >= m && c.spec.n >= n && c.spec.k >= k)
            .min_by_key(|c| c.spec.m * c.spec.n)
    }

    /// Execute an artifact on row-major f64 input buffers with the given
    /// shapes; returns the output as a flat row-major vec.
    pub fn execute(&self, name: &str, inputs: &[(&[f64], [usize; 2])]) -> Result<Vec<f64>> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| Error::runtime(format!("unknown artifact {name}")))?;
        let _guard = self.lock.lock().unwrap();
        c.exe.run(inputs)
    }
}

/// Copy a col-major view into a row-major buffer padded to `pm × pn`.
pub fn pack_row_major(c: MatRef<'_>, pm: usize, pn: usize) -> Vec<f64> {
    let mut buf = vec![0.0; pm * pn];
    for i in 0..c.rows() {
        for j in 0..c.cols() {
            buf[i * pn + j] = c.at(i, j);
        }
    }
    buf
}

/// Copy the top-left of a row-major `pm × pn` buffer back into a view.
pub fn unpack_row_major(buf: &[f64], pn: usize, mut c: MatMut<'_>) {
    for i in 0..c.rows() {
        for j in 0..c.cols() {
            c.set(i, j, buf[i * pn + j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    #[test]
    fn pack_unpack_roundtrip() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        let buf = pack_row_major(m.as_ref(), 5, 6);
        assert_eq!(buf[6 + 1], 11.0);
        assert_eq!(buf[2 * 6 + 3], 23.0);
        assert_eq!(buf[4 * 6 + 5], 0.0); // padding
        let mut back = Matrix::zeros(3, 4);
        unpack_row_major(&buf, 6, back.as_mut());
        assert_eq!(back, m);
    }

    #[test]
    fn stub_backend_fails_to_load_cleanly() {
        let dir = std::env::temp_dir().join("paraht_stub_backend_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), "foo left 128 128 16 foo.hlo.txt\n").unwrap();
        let err = PjrtRuntime::load(&dir).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("PJRT backend not linked"), "{msg}");
    }
}
