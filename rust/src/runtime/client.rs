//! PJRT runtime: load the AOT HLO-text artifacts, compile them once on the
//! CPU PJRT client, and execute them from the rust hot path.
//!
//! Python never runs here — the interchange is the HLO text produced by
//! `python/compile/aot.py` at build time (see /opt/xla-example/load_hlo).

use super::manifest::{load_manifest, ArtifactSpec, BucketKind};
use crate::error::{Error, Result};
use crate::linalg::matrix::{MatMut, MatRef};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// A compiled artifact.
pub struct Compiled {
    /// Its manifest entry.
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: client + compiled executable per artifact.
///
/// Executions are serialized through a mutex: the CPU PJRT client is
/// thread-safe, but serializing keeps buffer lifetimes simple and the
/// offload path is not the default hot path on this substrate (DESIGN.md
/// §Perf discusses when offload pays off).
pub struct PjrtRuntime {
    _client: xla::PjRtClient,
    compiled: HashMap<String, Compiled>,
    lock: Mutex<()>,
}

impl PjrtRuntime {
    /// Load every artifact in `dir` (must contain `manifest.txt`).
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::runtime(format!("PjRtClient::cpu: {e:?}")))?;
        let specs = load_manifest(dir)?;
        let mut compiled = HashMap::new();
        for spec in specs {
            let proto = xla::HloModuleProto::from_text_file(
                spec.path
                    .to_str()
                    .ok_or_else(|| Error::runtime("non-utf8 artifact path"))?,
            )
            .map_err(|e| Error::runtime(format!("parse {}: {e:?}", spec.name)))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::runtime(format!("compile {}: {e:?}", spec.name)))?;
            compiled.insert(spec.name.clone(), Compiled { spec, exe });
        }
        Ok(PjrtRuntime { _client: client, compiled, lock: Mutex::new(()) })
    }

    /// Names of the loaded artifacts.
    pub fn names(&self) -> Vec<&str> {
        self.compiled.keys().map(|s| s.as_str()).collect()
    }

    /// Find a bucket of the given kind with `m × n` C-shape and width `k`.
    pub fn find_bucket(&self, kind: BucketKind, m: usize, n: usize, k: usize) -> Option<&Compiled> {
        self.compiled
            .values()
            .find(|c| c.spec.kind == kind && c.spec.m == m && c.spec.n == n && c.spec.k == k)
    }

    /// Smallest bucket of `kind` that fits `(m, n, k)` (for padding).
    pub fn fitting_bucket(
        &self,
        kind: BucketKind,
        m: usize,
        n: usize,
        k: usize,
    ) -> Option<&Compiled> {
        self.compiled
            .values()
            .filter(|c| c.spec.kind == kind && c.spec.m >= m && c.spec.n >= n && c.spec.k >= k)
            .min_by_key(|c| c.spec.m * c.spec.n)
    }

    /// Execute an artifact on row-major f64 input buffers with the given
    /// shapes; returns the first tuple element as a flat row-major vec.
    pub fn execute(&self, name: &str, inputs: &[(&[f64], [usize; 2])]) -> Result<Vec<f64>> {
        let c = self
            .compiled
            .get(name)
            .ok_or_else(|| Error::runtime(format!("unknown artifact {name}")))?;
        let _guard = self.lock.lock().unwrap();
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs {
            let lit = xla::Literal::vec1(buf)
                .reshape(&[shape[0] as i64, shape[1] as i64])
                .map_err(|e| Error::runtime(format!("reshape: {e:?}")))?;
            literals.push(lit);
        }
        let result = c
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::runtime(format!("execute {name}: {e:?}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::runtime(format!("to_literal: {e:?}")))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let first = out
            .to_tuple1()
            .map_err(|e| Error::runtime(format!("to_tuple1: {e:?}")))?;
        first
            .to_vec::<f64>()
            .map_err(|e| Error::runtime(format!("to_vec: {e:?}")))
    }
}

/// Copy a col-major view into a row-major buffer padded to `pm × pn`.
pub fn pack_row_major(c: MatRef<'_>, pm: usize, pn: usize) -> Vec<f64> {
    let mut buf = vec![0.0; pm * pn];
    for i in 0..c.rows() {
        for j in 0..c.cols() {
            buf[i * pn + j] = c.at(i, j);
        }
    }
    buf
}

/// Copy the top-left of a row-major `pm × pn` buffer back into a view.
pub fn unpack_row_major(buf: &[f64], pn: usize, mut c: MatMut<'_>) {
    for i in 0..c.rows() {
        for j in 0..c.cols() {
            c.set(i, j, buf[i * pn + j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;

    #[test]
    fn pack_unpack_roundtrip() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        let buf = pack_row_major(m.as_ref(), 5, 6);
        assert_eq!(buf[0 * 6 + 1], 1.0);
        assert_eq!(buf[2 * 6 + 3], 23.0);
        assert_eq!(buf[4 * 6 + 5], 0.0); // padding
        let mut back = Matrix::zeros(3, 4);
        unpack_row_major(&buf, 6, back.as_mut());
        assert_eq!(back, m);
    }
}
