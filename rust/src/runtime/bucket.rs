//! Shape-bucketed WY offload: route compact-WY block-reflector
//! applications through the AOT-compiled PJRT executables.
//!
//! The PJRT executables are fixed-shape; panels are padded to the smallest
//! fitting bucket (zero-padding is exact for WY applies: padded `V` rows /
//! `T` columns contribute nothing) and the long dimension of `C` is
//! processed in bucket-sized chunks. Parity with the native
//! `linalg::wy::WyRep::apply` path is asserted by tests and by the
//! `paraht validate --pjrt` CLI command.

use super::client::{pack_row_major, unpack_row_major, PjrtRuntime};
use super::manifest::BucketKind;
use crate::error::{Error, Result};
use crate::linalg::matrix::MatMut;
use crate::linalg::wy::WyRep;

/// WY offload executor over a loaded runtime.
pub struct WyOffload<'r> {
    rt: &'r PjrtRuntime,
}

impl<'r> WyOffload<'r> {
    /// Wrap a runtime.
    pub fn new(rt: &'r PjrtRuntime) -> WyOffload<'r> {
        WyOffload { rt }
    }

    /// `C ← QᵀC` through the bucketed executables. `C.rows()` must equal
    /// the reflector order `wy.m()`.
    pub fn apply_left_t(&self, wy: &WyRep, mut c: MatMut<'_>) -> Result<()> {
        let m = wy.m();
        let k = wy.k();
        assert_eq!(c.rows(), m, "offload left: C rows != wy order");
        let ncols = c.cols();
        // Chunk the column dimension by the widest fitting bucket.
        let mut j = 0;
        while j < ncols {
            let want = ncols - j;
            let bucket = self
                .rt
                .fitting_bucket(BucketKind::Left, m, want.min(128), k)
                .or_else(|| self.rt.fitting_bucket(BucketKind::Left, m, 128, k))
                .ok_or_else(|| {
                    Error::runtime(format!("no left bucket fits m={m} k={k}"))
                })?;
            let (pm, pn, pk) = (bucket.spec.m, bucket.spec.n, bucket.spec.k);
            let take = want.min(pn);
            let name = bucket.spec.name.clone();

            let cbuf = pack_row_major(c.rb().sub(0..m, j..j + take), pm, pn);
            let vbuf = pack_row_major(wy.v.as_ref(), pm, pk);
            let tbuf = pack_row_major(wy.t.as_ref(), pk, pk);
            let out = self.rt.execute(
                &name,
                &[(&cbuf, [pm, pn]), (&vbuf, [pm, pk]), (&tbuf, [pk, pk])],
            )?;
            unpack_row_major(&out, pn, c.rb_mut().sub(0..m, j..j + take));
            j += take;
        }
        Ok(())
    }

    /// `C ← C·Q` through the bucketed executables. `C.cols()` must equal
    /// the reflector order `wy.m()`.
    pub fn apply_right(&self, wy: &WyRep, mut c: MatMut<'_>) -> Result<()> {
        let m = wy.m();
        let k = wy.k();
        assert_eq!(c.cols(), m, "offload right: C cols != wy order");
        let nrows = c.rows();
        let mut i = 0;
        while i < nrows {
            let want = nrows - i;
            let bucket = self
                .rt
                .fitting_bucket(BucketKind::Right, want.min(128), m, k)
                .or_else(|| self.rt.fitting_bucket(BucketKind::Right, 128, m, k))
                .ok_or_else(|| {
                    Error::runtime(format!("no right bucket fits m={m} k={k}"))
                })?;
            let (pm, pn, pk) = (bucket.spec.m, bucket.spec.n, bucket.spec.k);
            let take = want.min(pm);
            let name = bucket.spec.name.clone();

            let cbuf = pack_row_major(c.rb().sub(i..i + take, 0..m), pm, pn);
            let vbuf = pack_row_major(wy.v.as_ref(), pn, pk);
            let tbuf = pack_row_major(wy.t.as_ref(), pk, pk);
            let out = self.rt.execute(
                &name,
                &[(&cbuf, [pm, pn]), (&vbuf, [pn, pk]), (&tbuf, [pk, pk])],
            )?;
            unpack_row_major(&out, pn, c.rb_mut().sub(i..i + take, 0..m));
            i += take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::linalg::qr::QrFactor;
    use crate::linalg::wy::Side;
    use crate::linalg::Trans;
    use crate::util::rng::Rng;
    use std::path::Path;

    fn runtime() -> Option<PjrtRuntime> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping PJRT test: run `make artifacts` first");
            return None;
        }
        Some(PjrtRuntime::load(&dir).expect("runtime loads"))
    }

    fn random_wy(m: usize, k: usize, seed: u64) -> WyRep {
        let mut rng = Rng::new(seed);
        let a = Matrix::randn(m, k, &mut rng);
        QrFactor::compute_inplace(a).wy()
    }

    #[test]
    fn pjrt_left_matches_native() {
        let Some(rt) = runtime() else { return };
        let off = WyOffload::new(&rt);
        let mut rng = Rng::new(200);
        for (m, k, nc) in [(128usize, 16usize, 128usize), (128, 16, 300), (100, 16, 70)] {
            let wy = random_wy(m, k, 201);
            let c0 = Matrix::randn(m, nc, &mut rng);
            let mut native = c0.clone();
            wy.apply(Side::Left, Trans::Yes, native.as_mut());
            let mut offl = c0.clone();
            off.apply_left_t(&wy, offl.as_mut()).unwrap();
            let mut d = 0.0f64;
            for j in 0..nc {
                for i in 0..m {
                    d = d.max((native[(i, j)] - offl[(i, j)]).abs());
                }
            }
            assert!(d < 1e-12, "left parity m={m} nc={nc}: {d:.3e}");
        }
    }

    #[test]
    fn pjrt_right_matches_native() {
        let Some(rt) = runtime() else { return };
        let off = WyOffload::new(&rt);
        let mut rng = Rng::new(202);
        for (m, k, nr) in [(128usize, 16usize, 128usize), (128, 16, 300), (96, 16, 50)] {
            let wy = random_wy(m, k, 203);
            let c0 = Matrix::randn(nr, m, &mut rng);
            let mut native = c0.clone();
            wy.apply(Side::Right, Trans::No, native.as_mut());
            let mut offl = c0.clone();
            off.apply_right(&wy, offl.as_mut()).unwrap();
            let mut d = 0.0f64;
            for j in 0..m {
                for i in 0..nr {
                    d = d.max((native[(i, j)] - offl[(i, j)]).abs());
                }
            }
            assert!(d < 1e-12, "right parity m={m} nr={nr}: {d:.3e}");
        }
    }
}
