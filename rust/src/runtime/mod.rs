//! PJRT runtime (L3 ↔ L1/L2 bridge): load the AOT HLO-text artifacts
//! produced by `python/compile/aot.py`, compile them once on the PJRT CPU
//! client, and execute block-reflector updates from the rust hot path
//! through shape buckets. Python never runs at request time.

pub mod bucket;
pub mod client;
pub mod manifest;

pub use bucket::WyOffload;
pub use client::PjrtRuntime;
pub use manifest::{ArtifactSpec, BucketKind};
