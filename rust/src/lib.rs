//! # paraht — Parallel two-stage Hessenberg-triangular reduction
//!
//! Reproduction of Steel & Vandebril, *"Parallel two-stage reduction to
//! Hessenberg-triangular form"* (2023).
//!
//! Given a pencil `(A, B)` with `A, B ∈ R^{n×n}`, the library computes unitary
//! `Q`, `Z`, a Hessenberg `H` and an upper-triangular `T` such that
//! `A = Q H Zᵀ`, `B = Q T Zᵀ` — the standard preprocessing step for the QZ
//! algorithm for generalized eigenvalue problems.
//!
//! The documented front door is [`api::HtSession`]: a builder-configured,
//! long-lived session that validates the [`Config`] once, keeps the
//! persistent worker team and per-size workspaces warm, and exposes
//! [`api::HtSession::reduce`] (one pencil) and
//! [`api::HtSession::reduce_batch`] (many small pencils, one per worker).
//! The older free functions (`coordinator::driver::run_paraht`,
//! `ht::reduce_to_hessenberg_triangular`) survive as thin deprecated
//! shims over the session.
//!
//! For *many* pencils, the serving layer ([`serve`]) stacks a shard
//! router (N sessions, size-class routing), an async bounded submission
//! queue (per-shard dispatcher threads, ticket futures) and a
//! content-hash result cache on top of the session — same bitwise
//! contract, sustained throughput.
//!
//! The system is a three-layer stack (see ARCHITECTURE.md for the full
//! module tour):
//! * **L3 (rust)** — this crate: the paper's parallel *coordinator* (task
//!   graph, dynamic scheduler, slicing) plus the full dense-linear-algebra
//!   substrate it needs (GEMM, Householder/WY, QR/RQ/LQ, Givens).
//! * **L2 (JAX)** — `python/compile/model.py`: block-reflector update graphs,
//!   AOT-lowered to HLO text artifacts.
//! * **L1 (Pallas)** — `python/compile/kernels/`: tiled WY block-reflector
//!   kernels, validated against a pure-jnp oracle.
#![warn(missing_docs)]
// Every `unsafe` block must carry a `// SAFETY:` comment stating the
// invariant it relies on; CI promotes this to an error (`-D warnings`).
// The concurrency auditor (`coordinator::audit`) checks the view-range
// half of those claims at run time.
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod api;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod ht;
pub mod linalg;
pub mod pencil;
pub mod runtime;
pub mod serve;
pub mod tune;
pub mod util;

pub use api::{HtSession, HtSessionBuilder, TraceRecorder, TraceSink};
pub use config::Config;
pub use error::{Error, Result};
pub use ht::two_stage::HtDecomposition;
pub use linalg::matrix::Matrix;
pub use serve::{
    NetClient, NetConfig, NetServer, ServeConfig, ShardRouter, ShardSupervisor, SubmitQueue,
    SupervisorConfig,
};
pub use tune::{Autotuner, ProfileHandle, TunedProfile, TuneOptions};
