//! Row/column slice partitioning for the apply tasks (paper Fig. 3/8) and
//! the shared-matrix handle the tasks operate through.

#[cfg(any(feature = "audit", debug_assertions))]
use super::audit;
use super::access::MatId;
use crate::linalg::matrix::{MatMut, MatRef, Matrix};
use std::ops::Range;

/// Split `range` into at most `parts` contiguous chunks of balanced size.
pub fn partition(range: Range<usize>, parts: usize) -> Vec<Range<usize>> {
    let len = range.end.saturating_sub(range.start);
    if len == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(len);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut s = range.start;
    for i in 0..parts {
        let sz = base + usize::from(i < extra);
        out.push(s..s + sz);
        s += sz;
    }
    out
}

/// Split `range` into at most `parts` chunks of at least `min_chunk`
/// elements (fewer chunks when the range is small) — keeps per-task work
/// meaningful so the dataflow graph stays compact while parallelism still
/// grows with the problem size.
pub fn partition_capped(range: Range<usize>, parts: usize, min_chunk: usize) -> Vec<Range<usize>> {
    let len = range.end.saturating_sub(range.start);
    let eff = parts.min(len / min_chunk.max(1)).max(1);
    partition(range, eff)
}

/// Split `range` into chunks of at most `chunk` elements.
pub fn partition_by_width(range: Range<usize>, chunk: usize) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    let mut s = range.start;
    while s < range.end {
        let e = (s + chunk).min(range.end);
        out.push(s..e);
        s = e;
    }
    out
}

/// A matrix shared across scheduler tasks.
///
/// Tasks construct disjoint views at run time; the dataflow edges derived
/// from declared [`Access`](crate::coordinator::access::Access) regions
/// guarantee that concurrently-running tasks touch disjoint regions, which
/// makes the aliased view construction sound (the generalized
/// `split_at_mut` argument).
pub struct SharedMat {
    ptr: *mut f64,
    rows: usize,
    cols: usize,
    /// Audit identity: which declared matrix this handle is, if any.
    /// `None` (untagged) handles are invisible to the concurrency auditor.
    id: Option<MatId>,
}

// SAFETY: `SharedMat` is a bounds-carrying raw pointer into a caller-owned
// `Matrix` buffer; it performs no interior mutation itself. Sending or
// sharing the *handle* across threads is free — all aliasing discipline
// lives with the `unsafe` view constructors below, whose callers (the task
// graph) guarantee that concurrently-running tasks touch disjoint regions.
unsafe impl Send for SharedMat {}
// SAFETY: see the `Send` impl above — `&SharedMat` only exposes the view
// constructors, which carry the aliasing obligation themselves.
unsafe impl Sync for SharedMat {}

impl SharedMat {
    /// Wrap a matrix. The caller must keep `m` alive and un-borrowed for
    /// the lifetime of the scheduler run. The handle is *untagged*: the
    /// concurrency auditor (`coordinator::audit`) cannot see views made
    /// through it. Graph builders should use [`SharedMat::tagged`].
    pub fn new(m: &mut Matrix) -> SharedMat {
        SharedMat { ptr: m.data_mut().as_mut_ptr(), rows: m.rows(), cols: m.cols(), id: None }
    }

    /// Wrap a matrix and tag it with its declared identity, so the
    /// concurrency auditor can match views made through this handle
    /// against the issuing task's declared [`MatId`] regions.
    pub fn tagged(m: &mut Matrix, id: MatId) -> SharedMat {
        SharedMat { id: Some(id), ..SharedMat::new(m) }
    }

    /// The audit identity this handle was constructed with, if any.
    pub fn id(&self) -> Option<MatId> {
        self.id
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Mutable view of a region.
    ///
    /// # Safety
    /// The caller must guarantee:
    /// * `r.end <= self.rows()` and `c.end <= self.cols()` (checked by a
    ///   `debug_assert!` only);
    /// * no concurrently-running task accesses an overlapping region, and
    ///   no other live view of this matrix on *this* task overlaps `r × c`
    ///   mutably — here discharged by the task graph's region edges (each
    ///   task views only rectangles inside its declared regions, and
    ///   conflicting declarations order the tasks). The concurrency
    ///   auditor (`coordinator::audit`) checks both halves of that
    ///   argument at runtime when enabled.
    pub unsafe fn view(&self, r: Range<usize>, c: Range<usize>) -> MatMut<'_> {
        debug_assert!(r.end <= self.rows && c.end <= self.cols);
        #[cfg(any(feature = "audit", debug_assertions))]
        audit::on_view(self.id, &r, &c, true);
        MatMut::from_raw_parts(
            self.ptr.add(r.start + c.start * self.rows),
            r.end - r.start,
            c.end - c.start,
            self.rows,
        )
    }

    /// Immutable view of a region.
    ///
    /// # Safety
    /// As [`SharedMat::view`], with concurrent reads of the same region
    /// allowed (no concurrently-running task may *write* an overlapping
    /// region).
    pub unsafe fn view_ref(&self, r: Range<usize>, c: Range<usize>) -> MatRef<'_> {
        debug_assert!(r.end <= self.rows && c.end <= self.cols);
        #[cfg(any(feature = "audit", debug_assertions))]
        audit::on_view(self.id, &r, &c, false);
        MatRef::from_raw_parts(
            self.ptr.add(r.start + c.start * self.rows) as *const f64,
            r.end - r.start,
            c.end - c.start,
            self.rows,
        )
    }

    /// Whole-matrix mutable view, for tasks whose *algorithm* (not the
    /// view rectangle) bounds the touched region — e.g. the stage-2
    /// generate phase, which receives full-matrix `MatMut`s and stays
    /// inside its band by construction. The concurrency auditor records
    /// the issuing task's *declared* regions for this view instead of the
    /// full rectangle (declaration-granularity trust; see
    /// `coordinator::audit`'s module docs).
    ///
    /// # Safety
    /// As [`SharedMat::view`], where the "actual rectangle" obligation is
    /// the set of elements the callee really touches: the caller asserts
    /// that everything reachable through this view that is actually
    /// accessed lies inside the issuing task's declared write regions.
    pub unsafe fn view_full(&self) -> MatMut<'_> {
        #[cfg(any(feature = "audit", debug_assertions))]
        audit::on_view_full(self.id);
        MatMut::from_raw_parts(self.ptr, self.rows, self.cols, self.rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_balanced() {
        let p = partition(0..10, 3);
        assert_eq!(p, vec![0..4, 4..7, 7..10]);
        let total: usize = p.iter().map(|r| r.len()).sum();
        assert_eq!(total, 10);
        assert_eq!(partition(5..5, 3), Vec::<Range<usize>>::new());
        assert_eq!(partition(0..2, 5).len(), 2, "no empty chunks");
    }

    #[test]
    fn partition_widths() {
        assert_eq!(partition_by_width(0..10, 4), vec![0..4, 4..8, 8..10]);
        assert_eq!(partition_by_width(3..3, 4).len(), 0);
    }

    #[test]
    fn shared_mat_views() {
        let mut m = Matrix::from_fn(4, 4, |i, j| (i * 10 + j) as f64);
        let sh = SharedMat::new(&mut m);
        // SAFETY: single-threaded test; the views are in bounds and the
        // mutable view does not overlap the (already dropped) read view.
        unsafe {
            let v = sh.view_ref(1..3, 2..4);
            assert_eq!(v.at(0, 0), 12.0);
            let mut w = sh.view(0..1, 0..1);
            w.set(0, 0, 99.0);
        }
        assert_eq!(m[(0, 0)], 99.0);
    }

    #[test]
    fn tagged_handles_carry_identity() {
        let mut m = Matrix::zeros(2, 2);
        assert_eq!(SharedMat::new(&mut m).id(), None, "plain handles are untagged");
        assert_eq!(SharedMat::tagged(&mut m, MatId::Q).id(), Some(MatId::Q));
    }
}
