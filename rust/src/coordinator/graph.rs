//! Dataflow task graph: tasks + derived dependency edges.
//!
//! Tasks are submitted in the order the sequential algorithm would execute
//! them; edges are derived from conflicting region accesses (see
//! [`super::access`]). The graph can then be executed sequentially (with
//! per-task timing for simulator calibration) or by the worker pool's
//! dynamic scheduler.

use super::access::Access;
use std::time::Duration;

/// Task classification — the paper's task names, used for metrics and for
/// the per-class breakdowns in EXPERIMENTS.md.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum TaskClass {
    /// Stage 1: generate left reflectors (panel QR chain).
    GL,
    /// Stage 1: apply left reflectors to `A` (column slice).
    LA,
    /// Stage 1: apply left reflectors to `B` (column slice).
    LB,
    /// Stage 1: accumulate into `Q` (row slice).
    LQ,
    /// Stage 1: generate right (opposite) reflectors, incl. the band part
    /// of the `B` update.
    GR,
    /// Stage 1: apply right reflectors to `B` (row slice above the band).
    RB,
    /// Stage 1: apply right reflectors to `A` (row slice).
    RA,
    /// Stage 1: accumulate into `Z` (row slice).
    RZ,
    /// Stage 2: generate phase of a sweep group.
    Gen2,
    /// Stage 2: lookahead update (band needed by the next generate).
    Look2,
    /// Stage 2: trailing update (row/column slice).
    Upd2,
    /// Stage 2: `Q`/`Z` accumulation slice.
    Acc2,
    /// Baseline: sequential portion (rotation generation + B maintenance).
    BaseSeq,
    /// Baseline: parallel-BLAS-like batched update slice.
    BaseBlas,
    /// Data-parallel kernel slice (one `C` panel of a `gemm_par` /
    /// `WyRep::apply_par` call) — no dependencies, pure throughput.
    Gemm,
}

/// A node in the task graph.
pub struct TaskNode<'a> {
    /// Class label.
    pub class: TaskClass,
    /// Declared accesses (used to derive edges).
    pub accesses: Vec<Access>,
    /// Work closure. `Option` so executors can take it.
    pub run: Option<Box<dyn FnOnce() + Send + 'a>>,
    /// Predecessor task ids.
    pub deps: Vec<usize>,
    /// Successor task ids (filled by `finalize`).
    pub succs: Vec<usize>,
}

/// The dataflow graph.
pub struct TaskGraph<'a> {
    /// All tasks in submission order.
    pub tasks: Vec<TaskNode<'a>>,
    /// Epoch boundaries (task indices); conflict scans are limited to the
    /// last [`EPOCH_WINDOW`] epochs — see [`TaskGraph::new_epoch`].
    epochs: Vec<usize>,
}

/// Number of trailing epochs scanned for conflicts.
const EPOCH_WINDOW: usize = 3;

impl<'a> Default for TaskGraph<'a> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'a> TaskGraph<'a> {
    /// Empty graph.
    pub fn new() -> Self {
        TaskGraph { tasks: Vec::new(), epochs: Vec::new() }
    }

    /// Mark an epoch boundary (one per stage-1 panel / stage-2 sweep
    /// group). Conflict scanning in [`TaskGraph::add`] is then limited to
    /// the last [`EPOCH_WINDOW`] epochs, turning the O(T²) dataflow build
    /// into O(T·window).
    ///
    /// Soundness: every panel/group *collectively rewrites the whole
    /// trailing region it touches*, so any conflict with a task more than
    /// `EPOCH_WINDOW` epochs back is transitively ordered through the
    /// intermediate epochs' writes. Callers that cannot guarantee this
    /// must simply not call `new_epoch`.
    pub fn new_epoch(&mut self) {
        self.epochs.push(self.tasks.len());
    }

    fn scan_start(&self) -> usize {
        if self.epochs.len() < EPOCH_WINDOW {
            0
        } else {
            self.epochs[self.epochs.len() - EPOCH_WINDOW]
        }
    }

    /// Submit a task; edges to earlier conflicting tasks (within the epoch
    /// window) are derived. Returns the task id.
    pub fn add(
        &mut self,
        class: TaskClass,
        accesses: Vec<Access>,
        run: impl FnOnce() + Send + 'a,
    ) -> usize {
        let id = self.tasks.len();
        let start = self.scan_start();
        let mut deps = Vec::new();
        for (off, prev) in self.tasks[start..].iter().enumerate() {
            // No transitive reduction — keeping all direct conflicts is
            // correct and simple.
            if prev
                .accesses
                .iter()
                .any(|pa| accesses.iter().any(|na| pa.conflicts(na)))
            {
                deps.push(start + off);
            }
        }
        self.tasks.push(TaskNode {
            class,
            accesses,
            run: Some(Box::new(run)),
            deps,
            succs: Vec::new(),
        });
        id
    }

    /// Fill successor lists (call once after all submissions).
    pub fn finalize(&mut self) {
        let edges: Vec<(usize, usize)> = self
            .tasks
            .iter()
            .enumerate()
            .flat_map(|(id, t)| t.deps.iter().map(move |&d| (d, id)))
            .collect();
        for (from, to) in edges {
            self.tasks[from].succs.push(to);
        }
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether the graph is empty.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Execute sequentially in submission order (which is always a valid
    /// topological order), timing each task. Returns the per-task trace.
    pub fn run_sequential(mut self) -> TaskTrace {
        // Audit scope (if active) — built before the deps are moved into
        // the trace; sequential order trivially satisfies happens-before,
        // but the *containment* half of the audit is order-independent and
        // the race scan still validates the declared edges.
        #[cfg(any(feature = "audit", debug_assertions))]
        let scope = super::audit::scope_for(&self);
        let mut trace = TaskTrace::default();
        for (_id, t) in self.tasks.iter_mut().enumerate() {
            let f = t.run.take().expect("task already taken");
            #[cfg(any(feature = "audit", debug_assertions))]
            let _audit = super::audit::enter_task(scope.as_ref(), _id);
            let start = std::time::Instant::now();
            f();
            trace.durations.push(start.elapsed());
            trace.classes.push(t.class);
            trace.deps.push(std::mem::take(&mut t.deps));
        }
        #[cfg(any(feature = "audit", debug_assertions))]
        super::audit::check_scope(scope);
        trace
    }

    /// Extract the dependency structure without running (for simulation of
    /// a graph whose costs come from a model instead of a measurement).
    pub fn structure(&self) -> (Vec<TaskClass>, Vec<Vec<usize>>) {
        (
            self.tasks.iter().map(|t| t.class).collect(),
            self.tasks.iter().map(|t| t.deps.clone()).collect(),
        )
    }
}

/// Execution record of a graph: per-task durations + structure. Feed to
/// [`super::sim::simulate_makespan`] to predict parallel runtime on P
/// virtual workers — the substitution for the paper's 28-core machine.
#[derive(Default, Clone)]
pub struct TaskTrace {
    /// Wall-clock duration of each task (sequential execution).
    pub durations: Vec<Duration>,
    /// Class of each task.
    pub classes: Vec<TaskClass>,
    /// Direct dependencies of each task.
    pub deps: Vec<Vec<usize>>,
}

impl TaskTrace {
    /// Total sequential time.
    pub fn total(&self) -> Duration {
        self.durations.iter().sum()
    }

    /// Sum of durations for one class.
    pub fn class_total(&self, class: TaskClass) -> Duration {
        self.durations
            .iter()
            .zip(&self.classes)
            .filter(|(_, c)| **c == class)
            .map(|(d, _)| *d)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::access::{Access, MatId};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn derives_raw_dependencies() {
        let order = AtomicUsize::new(0);
        let seen = std::sync::Mutex::new(Vec::new());
        let mut g = TaskGraph::new();
        let t0 = g.add(TaskClass::GL, vec![Access::write(MatId::A, 0..10, 0..4)], || {
            seen.lock().unwrap().push((0, order.fetch_add(1, Ordering::SeqCst)));
        });
        // Reads what t0 wrote → edge.
        let t1 = g.add(TaskClass::LA, vec![Access::read(MatId::A, 5..8, 0..2)], || {
            seen.lock().unwrap().push((1, order.fetch_add(1, Ordering::SeqCst)));
        });
        // Disjoint → no edge.
        let t2 = g.add(TaskClass::LA, vec![Access::write(MatId::A, 0..10, 7..9)], || {
            seen.lock().unwrap().push((2, order.fetch_add(1, Ordering::SeqCst)));
        });
        assert_eq!(g.tasks[t1].deps, vec![t0]);
        assert!(g.tasks[t2].deps.is_empty());
        g.finalize();
        let trace = g.run_sequential();
        assert_eq!(trace.durations.len(), 3);
        drop(trace);
    }

    #[test]
    fn war_and_waw_edges() {
        let mut g = TaskGraph::new();
        let t0 = g.add(TaskClass::LA, vec![Access::read(MatId::B, 0..5, 0..5)], || {});
        let t1 = g.add(TaskClass::LB, vec![Access::write(MatId::B, 0..5, 0..5)], || {});
        let t2 = g.add(TaskClass::GR, vec![Access::write(MatId::B, 2..3, 2..3)], || {});
        assert_eq!(g.tasks[t1].deps, vec![t0], "WAR");
        // t2 conflicts with both the read (t0) and the write (t1); no
        // transitive reduction is performed.
        assert_eq!(g.tasks[t2].deps, vec![t0, t1], "WAW");
    }

    #[test]
    fn trace_class_totals() {
        let mut g = TaskGraph::new();
        g.add(TaskClass::GL, vec![], || std::thread::sleep(Duration::from_millis(1)));
        g.add(TaskClass::LA, vec![], || {});
        g.finalize();
        let tr = g.run_sequential();
        assert!(tr.class_total(TaskClass::GL) >= Duration::from_millis(1));
        assert!(tr.total() >= tr.class_total(TaskClass::GL));
    }
}
