//! Phase recorder: barrier-structured traces for the *comparator*
//! algorithms.
//!
//! ParaHT's own parallel execution is simulated from its real task DAG
//! (`stage1_par`/`stage2_par` traces). The comparators (`DGGHD3`,
//! `HouseHT`, `IterHT`) parallelize differently in the paper's experiments:
//! through parallel BLAS inside each blocked operation, with an implicit
//! barrier per call and a sequential remainder (§1: "If we rely only on the
//! parallelization of the matrix-matrix multiplications, then 40% of the
//! work will not be parallelized"; §2.3: "This results in the same amount
//! of parallelism, but there are fewer synchronization points").
//!
//! The recorder captures each phase of a sequential run as either a
//! *sequential* event or a *sliceable* (parallel-BLAS) event; `to_trace`
//! expands sliceable events into `s` equal slice tasks between barriers.
//! The equal split is a model (perfect intra-BLAS balance — generous to
//! the comparators); see DESIGN.md §5.

use super::graph::{TaskClass, TaskTrace};
use crate::util::timer::Timer;
use std::time::Duration;

/// One recorded phase.
#[derive(Clone, Copy, Debug)]
pub struct PhaseEvent {
    /// Task class for breakdowns.
    pub class: TaskClass,
    /// Measured duration.
    pub secs: f64,
    /// Whether parallel BLAS could slice this phase.
    pub sliceable: bool,
}

/// Recorder for a sequential baseline run.
#[derive(Default)]
pub struct PhaseRecorder {
    /// Recorded events in execution order.
    pub events: Vec<PhaseEvent>,
}

impl PhaseRecorder {
    /// New, empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a phase by timing the closure.
    pub fn record<R>(&mut self, class: TaskClass, sliceable: bool, f: impl FnOnce() -> R) -> R {
        let t = Timer::start();
        let r = f();
        self.events.push(PhaseEvent { class, secs: t.secs(), sliceable });
        r
    }

    /// Total recorded time.
    pub fn total_secs(&self) -> f64 {
        self.events.iter().map(|e| e.secs).sum()
    }

    /// Fraction of time in sliceable (parallel-BLAS) phases.
    pub fn sliceable_fraction(&self) -> f64 {
        let t = self.total_secs();
        if t == 0.0 {
            return 0.0;
        }
        self.events.iter().filter(|e| e.sliceable).map(|e| e.secs).sum::<f64>() / t
    }

    /// Expand into a barrier-structured [`TaskTrace`]: every event depends
    /// on all tasks of the previous event; sliceable events become
    /// `slices` equal tasks.
    pub fn to_trace(&self, slices: usize) -> TaskTrace {
        let slices = slices.max(1);
        let mut trace = TaskTrace::default();
        let mut prev: Vec<usize> = Vec::new();
        for ev in &self.events {
            let parts = if ev.sliceable { slices } else { 1 };
            let dur = Duration::from_secs_f64(ev.secs / parts as f64);
            let mut cur = Vec::with_capacity(parts);
            for _ in 0..parts {
                let id = trace.durations.len();
                trace.durations.push(dur);
                trace.classes.push(ev.class);
                trace.deps.push(prev.clone());
                cur.push(id);
            }
            prev = cur;
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sim::simulate_makespan;

    #[test]
    fn records_and_expands() {
        let mut rec = PhaseRecorder::new();
        rec.record(TaskClass::BaseSeq, false, || std::thread::sleep(Duration::from_millis(2)));
        rec.record(TaskClass::BaseBlas, true, || std::thread::sleep(Duration::from_millis(4)));
        let tr = rec.to_trace(4);
        assert_eq!(tr.durations.len(), 1 + 4);
        // Barrier structure: every BLAS slice depends on the seq task.
        for i in 1..5 {
            assert_eq!(tr.deps[i], vec![0]);
        }
        // Amdahl: with 4 workers the BLAS part quarters, the seq part not.
        let s1 = simulate_makespan(&tr, 1).makespan;
        let s4 = simulate_makespan(&tr, 4).makespan;
        assert!(s4 < s1);
        assert!(s4 >= tr.durations[0].as_secs_f64());
    }

    #[test]
    fn fractions() {
        let mut rec = PhaseRecorder::new();
        rec.events.push(PhaseEvent { class: TaskClass::BaseSeq, secs: 1.0, sliceable: false });
        rec.events.push(PhaseEvent { class: TaskClass::BaseBlas, secs: 3.0, sliceable: true });
        assert!((rec.sliceable_fraction() - 0.75).abs() < 1e-12);
        assert!((rec.total_secs() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn empty_recorder_empty_trace() {
        let rec = PhaseRecorder::new();
        let tr = rec.to_trace(8);
        assert!(tr.durations.is_empty());
        assert_eq!(rec.sliceable_fraction(), 0.0);
    }
}
