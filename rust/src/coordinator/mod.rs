//! The paper's L3 contribution: the parallel coordinator.
//!
//! * [`access`]/[`graph`] — dataflow task graph (tasks declare read/write
//!   regions; edges derived from conflicts) generalizing Figs. 2 and 7.
//! * [`pool`] — persistent worker team (threads spawned once, parked on a
//!   condvar, fed by a batch job queue) running the dependency-counting
//!   dynamic scheduler; shared by the task graphs and the data-parallel
//!   kernel panels.
//! * [`sim`] — discrete-event makespan simulator: replays a measured task
//!   trace on P virtual workers (the substitution for the paper's 28-core
//!   machine; DESIGN.md §5).
//! * [`assist`] — work-assisting panel claiming: the atomic claim-counter
//!   loop behind `Config::dynamic_schedule` (each claimed index = one
//!   panel; claiming decides *who* computes a panel, never the
//!   accumulation order inside it).
//! * [`slices`] — row/column slicing of the apply tasks (Figs. 3, 8).
//! * `audit` (compiled under `--features audit` or `debug_assertions`) —
//!   shadow access tracker enforcing the declared-region contract behind
//!   the unsafe `SharedMat` views: containment of every actual view in
//!   its task's declarations, and happens-before ordering of every
//!   overlapping access pair.
//! * [`stage1_par`]/[`stage2_par`] — task-graph builders for both stages.
//! * [`baseline_par`] — task-graph builders modelling the comparators'
//!   parallel-BLAS execution.
//! * [`driver`] — the ParaHT entry point: real threads or simulation.

pub mod access;
pub mod assist;
#[cfg(any(feature = "audit", debug_assertions))]
pub mod audit;
pub mod graph;
pub mod pool;
pub mod sim;
pub mod slices;
pub mod stage1_par;
pub mod stage2_par;
pub mod recorder;
pub mod driver;
