//! Parallel stage 2 (§3.3 of the paper): per sweep group, a sequential
//! *generate* task plus *lookahead* and *update* tasks (Figs. 7, 8).
//!
//! The generate task of group `g+1` needs only an `O(rq)` band of `(A, B)`
//! updated; the apply work of group `g` is therefore split into
//! *lookahead* tasks covering that band (class [`TaskClass::Look2`]) and
//! trailing *update* slices ([`TaskClass::Upd2`], row slices for the `Ẑ`
//! side, column slices for the `Q̂` side) plus `Q`/`Z` accumulation slices
//! ([`TaskClass::Acc2`]). The dependency that makes generation overlap the
//! trailing updates — the whole point of §3.3 — falls out of the declared
//! regions: `Gen2(g+1)` conflicts with the lookahead tasks but not with the
//! trailing slices.

use super::access::{Access, MatId};
use super::graph::{TaskClass, TaskGraph, TaskTrace};
use super::pool;
use super::slices::{partition_capped, SharedMat};
use super::stage1_par::ExecMode;
use crate::config::Config;
use crate::ht::reflector_store::GroupReflectors;
use crate::ht::stage2_blocked::{
    generate_group, max_chase_steps, q_apply_for, z_apply_for, z_ragged_for, QApply, ZApply,
};
use crate::linalg::matrix::Matrix;
use crate::linalg::wy::Side;
use crate::linalg::Trans;
use std::sync::{Arc, Mutex};

/// Reflector-store slots plus per-(group, k) caches of the accumulated WY
/// updates — built once (in the lookahead task) and shared by every
/// update/accumulation slice, instead of re-running `larft` per slice.
pub struct Stage2Arena {
    slots: Vec<Mutex<Option<GroupReflectors>>>,
    zcache: Vec<Vec<Mutex<Option<Arc<ZApply>>>>>,
    qcache: Vec<Vec<Mutex<Option<Arc<QApply>>>>>,
}

impl Stage2Arena {
    /// Allocate the reflector-store/WY-cache arena for a sweep-group set.
    /// Geometry-only (`n`, `r` and the group list): the session front door
    /// (`api::HtSession`) caches one arena per problem size and
    /// [`Stage2Arena::reset`]s it between reductions.
    pub fn new(n: usize, r: usize, groups: &[(usize, usize)]) -> Stage2Arena {
        fn mk<T>(count: usize) -> Vec<Mutex<Option<T>>> {
            (0..count).map(|_| Mutex::new(None)).collect()
        }
        Stage2Arena {
            slots: groups.iter().map(|_| Mutex::new(None)).collect(),
            zcache: groups.iter().map(|&(j1, _)| mk(max_chase_steps(n, r, j1))).collect(),
            qcache: groups.iter().map(|&(j1, _)| mk(max_chase_steps(n, r, j1))).collect(),
        }
    }

    /// Clear every store slot and cached WY application (interior
    /// mutability — callable between runs while the arena stays shared).
    /// The update/accumulation tasks consult the caches with `if let
    /// Some(..)`, so a stale entry from a previous pencil must never
    /// survive into the next run.
    pub fn reset(&self) {
        for slot in &self.slots {
            *slot.lock().unwrap() = None;
        }
        for row in &self.zcache {
            for slot in row {
                *slot.lock().unwrap() = None;
            }
        }
        for row in &self.qcache {
            for slot in row {
                *slot.lock().unwrap() = None;
            }
        }
    }
}

/// Geometry of the generate task's touched band, as rectangle unions
/// (one per chase step) — used both for the `Gen2` access declaration and
/// to size the lookahead split.
fn generate_accesses(n: usize, r: usize, j1: usize, qg: usize) -> Vec<Access> {
    let mut acc = Vec::new();
    let kmax = max_chase_steps(n, r, j1);
    for k in 0..kmax {
        let jb = j1 + if k == 0 { 0 } else { (k - 1) * r + 1 };
        let col_end_a = (j1 + qg + (k + 1) * r).min(n);
        let row_end_a = (j1 + qg + (k + 2) * r).min(n);
        let b_col_start = (j1 + k * r + 1).min(n);
        // The generate phase touches chase step k only from row
        // s5(k) = j1 + 1 + max(0, (k−q)·r) down (its minimal right-update
        // start; the catch-ups and reflector reads all lie below too).
        // Declaring tight rows is what lets Gen2(g+1) skip the trailing
        // Upd2 slices of group g — the §3.3 lookahead overlap.
        let t5 = k as i64 - qg as i64;
        let row_start = (j1 + 1 + if t5 > 0 { t5 as usize * r } else { 0 }).min(n);
        if jb < n {
            acc.push(Access::write(MatId::A, row_start..row_end_a.max(row_start), jb..col_end_a));
        }
        if b_col_start < n {
            let b_row_end = (j1 + qg + (k + 1) * r).min(n);
            acc.push(Access::write(
                MatId::B,
                row_start..b_row_end.max(row_start),
                b_col_start..col_end_a,
            ));
        }
    }
    acc
}

/// Build the stage-2 task graph.
pub fn build_graph<'a>(
    a: &'a SharedMat,
    b: &'a SharedMat,
    q: &'a SharedMat,
    z: &'a SharedMat,
    arena: &'a Stage2Arena,
    groups: &'a [(usize, usize)],
    cfg: &Config,
) -> TaskGraph<'a> {
    let n = a.rows();
    let ng = groups.len();
    let r = cfg.r;
    // Oversplit under the dynamic gate — finer slices for the graph's
    // ready FIFO to balance with, bitwise-identical results (see
    // `coordinator::assist` and the stage-1 builder's note).
    let nslices = super::assist::slice_goal(cfg);
    // Band depth the next generate may touch above/left of the WY regions:
    // group g+1's rects start ~(r − q) rows above this group's s5(k) in the
    // same columns, so a slack of 2(r + q) is comfortably safe while
    // keeping the lookahead tasks (which sit on the critical path between
    // consecutive generates) small.
    let look_depth = 2 * (r + cfg.q);
    let mut g = TaskGraph::new();

    for (gi, &(j1, qg)) in groups.iter().enumerate() {
        let slot = &arena.slots[gi];
        g.new_epoch();

        // ---- Gen2: generate the group's reflectors (sequential task). ----
        let mut gen_acc = generate_accesses(n, r, j1, qg);
        gen_acc.push(Access::write(MatId::Slots, gi..gi + 1, 0..1));
        g.add(TaskClass::Gen2, gen_acc, move || {
            // SAFETY: `generate_group` needs whole-matrix views (the band
            // geometry lives in the algorithm), but every element it
            // touches lies inside this task's declared per-chase-step band
            // rectangles (`generate_accesses`); the auditor records those
            // declarations for these views.
            let av = unsafe { a.view_full() };
            // SAFETY: as above, for the `B` band rectangles.
            let bv = unsafe { b.view_full() };
            let store = generate_group(av, bv, n, r, j1, qg);
            *slot.lock().unwrap() = Some(store);
        });

        let kmax = max_chase_steps(n, r, j1);

        // ---- Right (Ẑ) side, k bottom-up. ----
        for k in (0..kmax).rev() {
            // Geometry (recomputed cheaply; the store itself lives in the
            // slot and is only available at run time).
            let ci1 = j1 + k * r + 1;
            if ci1 >= n {
                continue;
            }
            let ci2e = (j1 + qg + (k + 1) * r).min(n);
            let t5 = k as i64 - qg as i64;
            let s5 = (j1 + 1 + if t5 > 0 { t5 as usize * r } else { 0 }).min(n);
            let e4max = (j1 + 1 + (k as i64 + 1).max(0) as usize * r).min(n); // e4(j_last)

            // Lookahead task: ragged rows + the band part of the WY rows.
            let look_lo = s5.saturating_sub(look_depth).min(s5);
            g.add(
                TaskClass::Look2,
                vec![
                    Access::read(MatId::Slots, gi..gi + 1, 0..1),
                    Access::write(MatId::Slots, ng + gi..ng + gi + 1, k..k + 1),
                    Access::write(MatId::A, look_lo..e4max.max(s5), ci1..ci2e),
                    Access::write(MatId::B, look_lo..e4max.max(s5), ci1..ci2e),
                ],
                move || {
                    let guard = slot.lock().unwrap();
                    let store = guard.as_ref().expect("Gen2 fills slot");
                    // SAFETY: `z_ragged_for` takes whole-matrix views but
                    // touches only rows [s5, e4(j)) × the staircase
                    // columns ⊆ this task's declared band rectangle
                    // (declaration-granularity; see `SharedMat::view_full`).
                    let av = unsafe { a.view_full() };
                    // SAFETY: as above, for `B`.
                    let bv = unsafe { b.view_full() };
                    z_ragged_for(store, k, av, bv);
                    if let Some(za) = z_apply_for(store, k) {
                        let za = Arc::new(za);
                        if za.s5 > look_lo {
                            // SAFETY: [look_lo, s5) × [ci1, ci2e) ⊆ the
                            // declared write A[look_lo..max(e4max, s5),
                            // ci1..ci2e] (za.* match the builder's
                            // geometry; s5 is clamped to n).
                            za.wy.apply(Side::Right, Trans::No, unsafe {
                                a.view(look_lo..za.s5.min(n), za.ci1..za.ci2e)
                            });
                            // SAFETY: same rectangle, declared on `B`.
                            za.wy.apply(Side::Right, Trans::No, unsafe {
                                b.view(look_lo..za.s5.min(n), za.ci1..za.ci2e)
                            });
                        }
                        *arena.zcache[gi][k].lock().unwrap() = Some(za);
                    }
                },
            );

            // Trailing WY rows [0, look_lo), row-sliced.
            for rows in partition_capped(0..look_lo, nslices, 64) {
                let rr = rows.clone();
                g.add(
                    TaskClass::Upd2,
                    vec![
                        Access::read(MatId::Slots, ng + gi..ng + gi + 1, k..k + 1),
                        Access::write(MatId::A, rows.clone(), ci1..ci2e),
                        Access::write(MatId::B, rows, ci1..ci2e),
                    ],
                    move || {
                        let za = arena.zcache[gi][k].lock().unwrap().clone();
                        if let Some(za) = za {
                            // SAFETY: rr × [ci1, ci2e) is this slice's
                            // declared write on A (za.ci* equal the
                            // builder's ci1/ci2e); row slices disjoint.
                            za.wy.apply(Side::Right, Trans::No, unsafe {
                                a.view(rr.clone(), za.ci1..za.ci2e)
                            });
                            // SAFETY: same rectangle, declared on `B`.
                            za.wy.apply(Side::Right, Trans::No, unsafe {
                                b.view(rr.clone(), za.ci1..za.ci2e)
                            });
                        }
                    },
                );
            }

        }

        // ---- Z accumulation: one task per row slice, all chase steps
        // batched (k bottom-up) — keeps task granularity meaningful.
        for rows in partition_capped(0..n, nslices, 64) {
            let rr = rows.clone();
            g.add(
                TaskClass::Acc2,
                vec![
                    Access::read(MatId::Slots, ng + gi..ng + gi + 1, 0..kmax.max(1)),
                    Access::write(MatId::Z, rows, (j1 + 1).min(n)..n),
                ],
                move || {
                    for k in (0..kmax).rev() {
                        let za = arena.zcache[gi][k].lock().unwrap().clone();
                        if let Some(za) = za {
                            // SAFETY: rr × [ci1, ci2e) ⊆ the declared
                            // write Z[rows, j1+1..n] (ci1 = j1+kr+1 ≥
                            // j1+1, ci2e ≤ n); row slices disjoint.
                            za.wy.apply(Side::Right, Trans::No, unsafe {
                                z.view(rr.clone(), za.ci1..za.ci2e)
                            });
                        }
                    }
                },
            );
        }

        // ---- Left (Q̂) side, k bottom-up. ----
        for k in (0..kmax).rev() {
            let ci1 = j1 + k * r + 1;
            if ci1 >= n {
                continue;
            }
            let ci2e = (j1 + qg + (k + 1) * r).min(n);
            let c5 = (j1 + qg + if k == 0 { 0 } else { (k - 1) * r + 1 }).min(n);
            let c_look = (c5 + look_depth).min(n);

            // Lookahead: the band columns [c5, c_look).
            g.add(
                TaskClass::Look2,
                vec![
                    Access::read(MatId::Slots, gi..gi + 1, 0..1),
                    Access::write(MatId::Slots, 2 * ng + gi..2 * ng + gi + 1, k..k + 1),
                    Access::write(MatId::A, ci1..ci2e, c5..c_look),
                    Access::write(MatId::B, ci1..ci2e, c5..c_look),
                ],
                move || {
                    let guard = slot.lock().unwrap();
                    let store = guard.as_ref().unwrap();
                    if let Some(qa) = q_apply_for(store, k) {
                        let qa = Arc::new(qa);
                        let ce = c_look.min(n);
                        if qa.c5 < ce {
                            // SAFETY: [ci1, ci2e) × [c5, ce) ⊆ the
                            // declared write A[ci1..ci2e, c5..c_look]
                            // (qa.c5 ≥ the builder's clamped c5).
                            qa.wy.apply(Side::Left, Trans::Yes, unsafe {
                                a.view(qa.ci1..qa.ci2e, qa.c5..ce)
                            });
                        }
                        if qa.c6 < ce {
                            // SAFETY: [ci1, ci2e) × [c6, ce) ⊆ the
                            // declared write B[ci1..ci2e, c5..c_look]
                            // (c6 ≥ c5 for every k).
                            qa.wy.apply(Side::Left, Trans::Yes, unsafe {
                                b.view(qa.ci1..qa.ci2e, qa.c6..ce)
                            });
                        }
                        *arena.qcache[gi][k].lock().unwrap() = Some(qa);
                    }
                },
            );

            // Trailing columns [c_look, n), column-sliced.
            for cols in partition_capped(c_look..n, nslices, 64) {
                let cc = cols.clone();
                g.add(
                    TaskClass::Upd2,
                    vec![
                        Access::read(MatId::Slots, 2 * ng + gi..2 * ng + gi + 1, k..k + 1),
                        Access::write(MatId::A, ci1..ci2e, cols.clone()),
                        Access::write(MatId::B, ci1..ci2e, cols),
                    ],
                    move || {
                        let qa = arena.qcache[gi][k].lock().unwrap().clone();
                        if let Some(qa) = qa {
                            let c0a = qa.c5.max(cc.start);
                            if c0a < cc.end {
                                // SAFETY: [ci1, ci2e) × [c0a, cc.end) ⊆
                                // this slice's declared write
                                // A[ci1..ci2e, cols] (c0a ≥ cc.start).
                                qa.wy.apply(Side::Left, Trans::Yes, unsafe {
                                    a.view(qa.ci1..qa.ci2e, c0a..cc.end)
                                });
                            }
                            let c0b = qa.c6.max(cc.start);
                            if c0b < cc.end {
                                // SAFETY: as above for `B` (c0b ≥
                                // cc.start).
                                qa.wy.apply(Side::Left, Trans::Yes, unsafe {
                                    b.view(qa.ci1..qa.ci2e, c0b..cc.end)
                                });
                            }
                        }
                    },
                );
            }

        }

        // ---- Q accumulation: one task per row slice, all chase steps
        // batched (k bottom-up).
        for rows in partition_capped(0..n, nslices, 64) {
            let rr = rows.clone();
            g.add(
                TaskClass::Acc2,
                vec![
                    Access::read(MatId::Slots, 2 * ng + gi..2 * ng + gi + 1, 0..kmax.max(1)),
                    Access::write(MatId::Q, rows, (j1 + 1).min(n)..n),
                ],
                move || {
                    for k in (0..kmax).rev() {
                        let qa = arena.qcache[gi][k].lock().unwrap().clone();
                        if let Some(qa) = qa {
                            // SAFETY: rr × [ci1, ci2e) ⊆ the declared
                            // write Q[rows, j1+1..n] (ci1 = j1+kr+1 ≥
                            // j1+1, ci2e ≤ n); row slices disjoint.
                            qa.wy.apply(Side::Right, Trans::No, unsafe {
                                q.view(rr.clone(), qa.ci1..qa.ci2e)
                            });
                        }
                    }
                },
            );
        }
    }
    g.finalize();
    g
}

/// Sweep-group list for a problem of size `n` (paper default `q = 8`).
pub fn sweep_groups(n: usize, qsize: usize) -> Vec<(usize, usize)> {
    let mut groups = Vec::new();
    if n < 3 {
        return groups;
    }
    let mut j1 = 0;
    while j1 < n - 2 {
        let qg = qsize.min(n - 2 - j1);
        groups.push((j1, qg));
        j1 += qg;
    }
    groups
}

/// Parallel (or traced) stage 2: same result as
/// [`crate::ht::stage2_blocked::reduce_blocked`].
pub fn reduce_blocked_par(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    cfg: &Config,
    mode: ExecMode,
) -> Option<TaskTrace> {
    let n = a.rows();
    let groups = sweep_groups(n, cfg.q);
    let arena = Stage2Arena::new(n, cfg.r, &groups);
    // Tagged handles: the concurrency auditor (when active) matches every
    // view against the issuing task's declared regions for that MatId.
    let sa = SharedMat::tagged(a, MatId::A);
    let sb = SharedMat::tagged(b, MatId::B);
    let sq = SharedMat::tagged(q, MatId::Q);
    let sz = SharedMat::tagged(z, MatId::Z);
    let graph = build_graph(&sa, &sb, &sq, &sz, &arena, &groups, cfg);
    match mode {
        ExecMode::Threads(t) => {
            // Same persistent team as stage 1 (`pool::global`): group
            // after group reuses workers whose pack buffers were warmed by
            // the stage-1 panels.
            pool::global().run_graph(graph, t);
            None
        }
        ExecMode::Trace => Some(graph.run_sequential()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ht::stage1::reduce_to_banded;
    use crate::ht::stage2_blocked::reduce_blocked;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    fn banded(n: usize, r: usize, seed: u64) -> (Matrix, Matrix, Matrix, Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let pencil = random_pencil(n, &mut rng);
        let (a0, b0) = (pencil.a.clone(), pencil.b.clone());
        let mut a = pencil.a;
        let mut b = pencil.b;
        let mut q = Matrix::identity(n);
        let mut z = Matrix::identity(n);
        let cfg = Config { r, p: 3, ..Config::default() };
        reduce_to_banded(&mut a, &mut b, &mut q, &mut z, &cfg);
        (a0, b0, a, b, q, z)
    }

    fn max_diff(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = 0.0f64;
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                d = d.max((x[(i, j)] - y[(i, j)]).abs());
            }
        }
        d
    }

    fn compare(n: usize, r: usize, q: usize, threads: usize, seed: u64) {
        let (_a0, _b0, a_in, b_in, q_in, z_in) = banded(n, r, seed);
        let (mut a1, mut b1, mut q1, mut z1) =
            (a_in.clone(), b_in.clone(), q_in.clone(), z_in.clone());
        reduce_blocked(&mut a1, &mut b1, &mut q1, &mut z1, r, q);
        let (mut a2, mut b2, mut q2, mut z2) = (a_in, b_in, q_in, z_in);
        let cfg = Config { r, q, threads, ..Config::default() };
        reduce_blocked_par(&mut a2, &mut b2, &mut q2, &mut z2, &cfg, ExecMode::Threads(threads));
        assert_eq!(max_diff(&a1, &a2), 0.0, "A differs (n={n} r={r} q={q})");
        assert_eq!(max_diff(&b1, &b2), 0.0, "B differs");
        assert_eq!(max_diff(&q1, &q2), 0.0, "Q differs");
        assert_eq!(max_diff(&z1, &z2), 0.0, "Z differs");
    }

    #[test]
    fn parallel_equals_blocked_small() {
        compare(30, 4, 3, 4, 170);
    }

    #[test]
    fn parallel_equals_blocked_more() {
        compare(50, 5, 4, 3, 171);
        compare(40, 4, 8, 2, 172);
    }

    #[test]
    fn trace_mode_valid_and_has_lookahead() {
        // n large enough that trailing updates exist beyond the lookahead
        // band (look_depth = 2qr + 2r must be well below n).
        let (a0, b0, mut a, mut b, mut q, mut z) = banded(150, 4, 173);
        let cfg = Config { r: 4, q: 3, threads: 4, ..Config::default() };
        let trace =
            reduce_blocked_par(&mut a, &mut b, &mut q, &mut z, &cfg, ExecMode::Trace).unwrap();
        assert!(max_below_band(&a, 1) < 1e-12 * a.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
        for cl in [TaskClass::Gen2, TaskClass::Look2, TaskClass::Upd2, TaskClass::Acc2] {
            assert!(trace.classes.contains(&cl), "missing {cl:?}");
        }
        // The DAG must expose parallelism: critical path < total work.
        let s = crate::coordinator::sim::simulate_makespan(&trace, 1_000_000);
        assert!(s.critical_path < s.total_work);
    }
}
