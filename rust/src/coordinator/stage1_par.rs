//! Parallel stage 1 (§2.3 of the paper): the per-panel task decomposition
//! `G_L → {L_A, L_B, L_Q}`, `L_B → G_R → {R_A, R_Z}`, with the apply tasks
//! split into column slices (`L_A`, `L_B` — left updates touch each column
//! independently) and row slices (`L_Q`, `R_A`, `R_Z` — right updates touch
//! each row independently), exactly Fig. 3.
//!
//! Dependencies — including the cross-panel pipelining of Fig. 2 (the next
//! panel's `G_L` can start as soon as the slices covering its columns are
//! done, while trailing slices of the previous panel still run) — are
//! derived automatically from the declared regions.
//!
//! Reflector handoff between the generate and apply tasks goes through
//! mutex slots; their ordering is modelled as accesses to the pseudo-matrix
//! [`MatId::Slots`] (row `2·panel` for `Q̂` slots, `2·panel+1` for `Ẑ`).

use super::access::{Access, MatId};
use super::graph::{TaskClass, TaskGraph, TaskTrace};
use super::pool;
use super::slices::{partition_capped, SharedMat};
use crate::config::Config;
use crate::ht::stage1::{factor_panel_block, flush_b_subdiagonal, opposite_reflector, panel_plans};
use crate::linalg::matrix::Matrix;
use crate::linalg::wy::{Side, WyRep};
use crate::linalg::Trans;
use std::sync::Mutex;

/// How to execute a built task graph.
#[derive(Clone, Copy, Debug)]
pub enum ExecMode {
    /// Real worker threads.
    Threads(usize),
    /// Sequential execution with per-task timing (simulator calibration).
    Trace,
}

/// Reflector slot arena for one stage-1 run (owned outside the graph so
/// task closures can borrow it).
pub struct Stage1Arena {
    slots: Vec<Vec<Mutex<Option<WyRep>>>>, // [2*panel + side][block]
}

impl Stage1Arena {
    /// Allocate the slot arena for a panel-plan set. Geometry-only: the
    /// session front door (`api::HtSession`) caches one arena per problem
    /// size and [`Stage1Arena::reset`]s it between reductions.
    pub fn new(plans: &[crate::ht::stage1::PanelPlan]) -> Stage1Arena {
        let mut slots = Vec::with_capacity(2 * plans.len());
        for plan in plans {
            let nb = plan.blocks.len();
            slots.push((0..nb).map(|_| Mutex::new(None)).collect());
            slots.push((0..nb).map(|_| Mutex::new(None)).collect());
        }
        Stage1Arena { slots }
    }

    /// Clear every reflector slot (interior mutability — callable between
    /// runs while the arena stays shared). Generate tasks refill the slots
    /// their apply tasks read, but clearing keeps no stale `WyRep` alive
    /// across reductions.
    pub fn reset(&self) {
        for row in &self.slots {
            for slot in row {
                *slot.lock().unwrap() = None;
            }
        }
    }
}

/// Build the stage-1 task graph over shared matrices.
#[allow(clippy::too_many_arguments)]
pub fn build_graph<'a>(
    a: &'a SharedMat,
    b: &'a SharedMat,
    q: &'a SharedMat,
    z: &'a SharedMat,
    arena: &'a Stage1Arena,
    plans: &'a [crate::ht::stage1::PanelPlan],
    cfg: &Config,
) -> TaskGraph<'a> {
    let n = a.rows();
    let nb = cfg.r;
    // Under the dynamic gate the slice goal is oversplit: the graph's
    // shared ready FIFO is already a dynamic scheduler for these
    // dependency-carrying tasks, so finer slices (same bits — the apply
    // kernels are slicing-invariant) are all it needs to absorb the
    // triangular-slice imbalance. See `coordinator::assist`.
    let nslices = super::assist::slice_goal(cfg);
    let mut g = TaskGraph::new();

    for (pi, plan) in plans.iter().enumerate() {
        let (j, je) = (plan.j, plan.je);
        if plan.blocks.is_empty() {
            continue;
        }
        g.new_epoch();
        let blocks = &plan.blocks;
        let qrow = 2 * pi;
        let zrow = 2 * pi + 1;
        let nblk = blocks.len();
        let panel_top = j + nb; // first row below the target band

        // ---- G_L: factor the panel's QR chain bottom-up. ----
        g.add(
            TaskClass::GL,
            vec![
                Access::write(MatId::A, panel_top..n, j..je),
                Access::write(MatId::Slots, qrow..qrow + 1, 0..nblk),
            ],
            move || {
                for (k, &(i1, i2e)) in blocks.iter().enumerate().rev() {
                    if i2e <= i1 {
                        continue;
                    }
                    // SAFETY: [i1, i2e) × [j, je) ⊆ the declared write
                    // A[panel_top.., j..je] (blocks start at panel_top);
                    // region edges exclude concurrent overlap.
                    let wy = factor_panel_block(unsafe { a.view(i1..i2e, j..je) });
                    *arena.slots[qrow][k].lock().unwrap() = Some(wy);
                }
            },
        );

        // ---- L_A: column slices of A(panel rows, je..n). ----
        for cols in partition_capped(je..n, nslices, 32) {
            let c = cols.clone();
            g.add(
                TaskClass::LA,
                vec![
                    Access::read(MatId::Slots, qrow..qrow + 1, 0..nblk),
                    Access::write(MatId::A, panel_top..n, cols),
                ],
                move || {
                    for (k, &(i1, i2e)) in blocks.iter().enumerate().rev() {
                        if i2e <= i1 {
                            continue;
                        }
                        let slot = arena.slots[qrow][k].lock().unwrap();
                        let wy = slot.as_ref().expect("GL must have filled slot");
                        // SAFETY: [i1, i2e) × c ⊆ the declared write
                        // A[panel_top.., cols]; this slice owns `c`
                        // exclusively via the region edges.
                        wy.apply(Side::Left, Trans::Yes, unsafe { a.view(i1..i2e, c.clone()) });
                    }
                },
            );
        }

        // ---- L_B: column slices of B(panel rows, panel_top..n). ----
        for cols in partition_capped(panel_top..n, nslices, 32) {
            let c = cols.clone();
            g.add(
                TaskClass::LB,
                vec![
                    Access::read(MatId::Slots, qrow..qrow + 1, 0..nblk),
                    Access::write(MatId::B, panel_top..n, cols),
                ],
                move || {
                    for (k, &(i1, i2e)) in blocks.iter().enumerate().rev() {
                        if i2e <= i1 || c.end <= i1 {
                            continue;
                        }
                        let c0 = c.start.max(i1);
                        let slot = arena.slots[qrow][k].lock().unwrap();
                        let wy = slot.as_ref().unwrap();
                        // SAFETY: [i1, i2e) × [c0, c.end) ⊆ the declared
                        // write B[panel_top.., cols] (c0 = max(c.start, i1)
                        // only shrinks the slice's own column span).
                        wy.apply(Side::Left, Trans::Yes, unsafe { a_or(b).view(i1..i2e, c0..c.end) });
                    }
                },
            );
        }

        // ---- L_Q: row slices of Q(:, block columns). ----
        for rows in partition_capped(0..n, nslices, 32) {
            let rr = rows.clone();
            g.add(
                TaskClass::LQ,
                vec![
                    Access::read(MatId::Slots, qrow..qrow + 1, 0..nblk),
                    Access::write(MatId::Q, rows, panel_top..n),
                ],
                move || {
                    for (k, &(i1, i2e)) in blocks.iter().enumerate().rev() {
                        if i2e <= i1 {
                            continue;
                        }
                        let slot = arena.slots[qrow][k].lock().unwrap();
                        let wy = slot.as_ref().unwrap();
                        // SAFETY: rr × [i1, i2e) ⊆ the declared write
                        // Q[rows, panel_top..n]; row slices are disjoint.
                        wy.apply(Side::Right, Trans::No, unsafe { q.view(rr.clone(), i1..i2e) });
                    }
                },
            );
        }

        // ---- G_R: opposite reflectors, per block (bottom-up). ----
        // The RQ of block k must see block k+1's Ẑ applied to their shared
        // columns, so the generate tasks chain; but the bulk of each Ẑ's
        // B-update (rows above the next block's RQ window) is sliced into
        // parallel tasks — the paper's "only the simple parallelization of
        // the matrix-matrix multiplications is possible" for G_R (§2.3).
        for (k, &(i1, i2e)) in blocks.iter().enumerate().rev() {
            let s = i2e - i1;
            if s == 0 {
                continue;
            }
            let t = nb.min(s);
            // Rows the *next* generate (block k-1, and ultimately the next
            // panel) reads: keep them in the generate task itself.
            let band_lo = if k == 0 { j.saturating_sub(nb) } else { blocks[k - 1].0 };
            g.add(
                TaskClass::GR,
                vec![
                    Access::write(MatId::B, band_lo..i2e, i1..i2e),
                    Access::write(MatId::Slots, zrow..zrow + 1, k..k + 1),
                ],
                move || {
                    // SAFETY: a read of [i1, i2e)² ⊆ this task's declared
                    // write B[band_lo..i2e, i1..i2e] (band_lo ≤ i1) —
                    // reading one's own exclusive region.
                    let wy = opposite_reflector(unsafe { b.view_ref(i1..i2e, i1..i2e) }, nb);
                    // SAFETY: exactly the declared write region.
                    wy.apply(Side::Right, Trans::No, unsafe { b.view(band_lo..i2e, i1..i2e) });
                    // SAFETY: [i1, i2e)² ⊆ the declared write region.
                    flush_b_subdiagonal(unsafe { b.view(i1..i2e, i1..i2e) }, t);
                    *arena.slots[zrow][k].lock().unwrap() = Some(wy);
                },
            );
            // Parallel part of the B update: rows [0, band_lo).
            for rows in partition_capped(0..band_lo, nslices, 32) {
                let rr = rows.clone();
                g.add(
                    TaskClass::RB,
                    vec![
                        Access::read(MatId::Slots, zrow..zrow + 1, k..k + 1),
                        Access::write(MatId::B, rows, i1..i2e),
                    ],
                    move || {
                        let slot = arena.slots[zrow][k].lock().unwrap();
                        let wy = slot.as_ref().unwrap();
                        // SAFETY: rr × [i1, i2e) is exactly the declared
                        // write B[rows, i1..i2e]; row slices are disjoint
                        // and sit above the generate task's band.
                        wy.apply(Side::Right, Trans::No, unsafe { b.view(rr.clone(), i1..i2e) });
                    },
                );
            }
        }

        // ---- R_A: row slices of A(:, block columns). ----
        for rows in partition_capped(0..n, nslices, 32) {
            let rr = rows.clone();
            g.add(
                TaskClass::RA,
                vec![
                    Access::read(MatId::Slots, zrow..zrow + 1, 0..nblk),
                    Access::write(MatId::A, rows, panel_top..n),
                ],
                move || {
                    for (k, &(i1, i2e)) in blocks.iter().enumerate().rev() {
                        if i2e <= i1 {
                            continue;
                        }
                        let slot = arena.slots[zrow][k].lock().unwrap();
                        let wy = slot.as_ref().unwrap();
                        // SAFETY: rr × [i1, i2e) ⊆ the declared write
                        // A[rows, panel_top..n] (i1 ≥ panel_top).
                        wy.apply(Side::Right, Trans::No, unsafe { a.view(rr.clone(), i1..i2e) });
                    }
                },
            );
        }

        // ---- R_Z: row slices of Z(:, block columns). ----
        for rows in partition_capped(0..n, nslices, 32) {
            let rr = rows.clone();
            g.add(
                TaskClass::RZ,
                vec![
                    Access::read(MatId::Slots, zrow..zrow + 1, 0..nblk),
                    Access::write(MatId::Z, rows, panel_top..n),
                ],
                move || {
                    for (k, &(i1, i2e)) in blocks.iter().enumerate().rev() {
                        if i2e <= i1 {
                            continue;
                        }
                        let slot = arena.slots[zrow][k].lock().unwrap();
                        let wy = slot.as_ref().unwrap();
                        // SAFETY: rr × [i1, i2e) ⊆ the declared write
                        // Z[rows, panel_top..n] (i1 ≥ panel_top).
                        wy.apply(Side::Right, Trans::No, unsafe { z.view(rr.clone(), i1..i2e) });
                    }
                },
            );
        }
    }
    g.finalize();
    g
}

/// Type helper: L_B applies to `b`, not `a` (keeps the closure above tidy).
#[inline]
fn a_or(b: &SharedMat) -> &SharedMat {
    b
}

/// Parallel (or traced) stage 1: same result as
/// [`crate::ht::stage1::reduce_to_banded`].
pub fn reduce_to_banded_par(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    cfg: &Config,
    mode: ExecMode,
) -> Option<TaskTrace> {
    let n = a.rows();
    let plans = panel_plans(n, cfg.r, cfg.p);
    let arena = Stage1Arena::new(&plans);
    // Tagged handles: the concurrency auditor (when active) matches every
    // view against the issuing task's declared regions for that MatId.
    let sa = SharedMat::tagged(a, MatId::A);
    let sb = SharedMat::tagged(b, MatId::B);
    let sq = SharedMat::tagged(q, MatId::Q);
    let sz = SharedMat::tagged(z, MatId::Z);
    let graph = build_graph(&sa, &sb, &sq, &sz, &arena, &plans, cfg);
    match mode {
        ExecMode::Threads(t) => {
            // Execute on the persistent process-global team (this caller
            // + up to t-1 pool helpers): the same workers serve every
            // panel of this stage, stage 2, and the data-parallel trailing
            // updates, so their thread-local GEMM pack buffers stay hot
            // for the whole reduction.
            pool::global().run_graph(graph, t);
            None
        }
        ExecMode::Trace => Some(graph.run_sequential()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ht::stage1::reduce_to_banded;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    fn max_diff(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = 0.0f64;
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                d = d.max((x[(i, j)] - y[(i, j)]).abs());
            }
        }
        d
    }

    fn compare_modes(n: usize, r: usize, p: usize, threads: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let pencil = random_pencil(n, &mut rng);
        let cfg = Config { r, p, threads, ..Config::default() };

        let (mut a1, mut b1) = (pencil.a.clone(), pencil.b.clone());
        let (mut q1, mut z1) = (Matrix::identity(n), Matrix::identity(n));
        reduce_to_banded(&mut a1, &mut b1, &mut q1, &mut z1, &cfg);

        let (mut a2, mut b2) = (pencil.a.clone(), pencil.b.clone());
        let (mut q2, mut z2) = (Matrix::identity(n), Matrix::identity(n));
        reduce_to_banded_par(&mut a2, &mut b2, &mut q2, &mut z2, &cfg, ExecMode::Threads(threads));

        // Identical task bodies in a valid topological order ⇒ identical
        // floating-point results, bit for bit.
        assert_eq!(max_diff(&a1, &a2), 0.0, "A differs");
        assert_eq!(max_diff(&b1, &b2), 0.0, "B differs");
        assert_eq!(max_diff(&q1, &q2), 0.0, "Q differs");
        assert_eq!(max_diff(&z1, &z2), 0.0, "Z differs");
    }

    #[test]
    fn parallel_equals_sequential_small() {
        compare_modes(40, 4, 3, 4, 160);
    }

    #[test]
    fn parallel_equals_sequential_paper_params() {
        compare_modes(120, 16, 8, 3, 161);
    }

    #[test]
    fn parallel_equals_sequential_odd() {
        compare_modes(53, 5, 3, 5, 162);
    }

    #[test]
    fn trace_mode_produces_valid_result_and_trace() {
        let n = 60;
        let mut rng = Rng::new(163);
        let pencil = random_pencil(n, &mut rng);
        let (a0, b0) = (pencil.a.clone(), pencil.b.clone());
        let (mut a, mut b) = (pencil.a, pencil.b);
        let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
        let cfg = Config { r: 6, p: 3, threads: 4, ..Config::default() };
        let trace = reduce_to_banded_par(&mut a, &mut b, &mut q, &mut z, &cfg, ExecMode::Trace)
            .expect("trace mode returns a trace");
        assert!(max_below_band(&a, 6) < 1e-12 * a.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 6).assert_ok(1e-11);
        assert!(!trace.durations.is_empty());
        // Every task class of Fig. 2 must be present.
        for cl in [TaskClass::GL, TaskClass::LA, TaskClass::LB, TaskClass::LQ, TaskClass::GR, TaskClass::RA, TaskClass::RZ] {
            assert!(trace.classes.contains(&cl), "missing class {cl:?}");
        }
        // Simulation sanity on the real trace.
        let s1 = crate::coordinator::sim::simulate_makespan(&trace, 1);
        let s8 = crate::coordinator::sim::simulate_makespan(&trace, 8);
        assert!(s8.makespan <= s1.makespan);
        assert!(s8.makespan >= s1.critical_path - 1e-12);
    }
}
