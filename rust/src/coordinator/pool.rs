//! Persistent worker pool + dynamic scheduler: execute [`TaskGraph`]s and
//! data-parallel task lists on a long-lived team of worker threads.
//!
//! Two layers:
//!
//! * **The pool** ([`WorkerPool`]): OS threads spawned once, parked on a
//!   condvar, fed by a queue of *batches*, joined on drop. Keeping the team
//!   alive across calls is what lets the thread-local GEMM pack buffers
//!   (`linalg::gemm`) amortize over a whole reduction, and removes the
//!   per-call thread-startup cost the scoped-spawn model paid on every
//!   `gemm_par` / `apply_par` (the ROADMAP item this replaces; cf. the
//!   long-lived worker teams assumed by arXiv:1710.08538 / 1709.00302).
//! * **The batch scheduler** ([`Batch`], classic dependency counting — the
//!   paper's dynamic scheduler, §2.3): every task carries a
//!   pending-predecessor count; executors pull ready tasks from a shared
//!   FIFO, run them, decrement successors, and enqueue those that become
//!   ready. Load imbalance between slices (e.g. the triangular `L_B`
//!   slices) is absorbed by the shared queue.
//!
//! Dependency-free batches can alternatively run under the work-assisting
//! drain ([`super::assist`], `Schedule::Dynamic`): executors claim task
//! indices from a shared atomic counter instead of pulling from the FIFO —
//! one `fetch_add` per task, no queue traffic. Same caller-participation,
//! panic and lifetime rules as the FIFO path; the submitter still blocks
//! until `remaining == 0`.
//!
//! **Caller participation.** The thread that submits a batch executes it
//! too: [`WorkerPool::run_graph`] enqueues the batch for up to
//! `threads - 1` pool workers ("helpers") and then drains it itself, so a
//! `threads = t` run has up to `t` executors and *always* makes progress
//! even when every pool worker is busy or the pool has zero workers.
//! Submitting from inside a job (nested parallelism) therefore cannot
//! deadlock: the inner submitter drains its own batch alone in the worst
//! case. Unlike the old scoped-spawn model (which really spawned `t` OS
//! threads per call, oversubscribing cores when `t` exceeded them),
//! effective concurrency is additionally capped at `1 + worker_count` —
//! raise `PALLAS_POOL_THREADS` if a larger team than
//! `available_parallelism()` is genuinely wanted. Results are unaffected
//! either way (see Determinism below); only scheduling changes.
//!
//! **Determinism.** The pool changes only *where* tasks run, never *what*
//! they compute: dependency edges still force a valid topological order,
//! and the data-parallel entry points keep the exact panel split of the
//! scoped-spawn implementation, so `tests/equivalence.rs` continues to pin
//! every parallel run bitwise to the sequential oracle. Part of "what they
//! compute" is the GEMM microkernel variant
//! ([`crate::linalg::kernels`]): every batch captures the submitter's
//! thread-current kernel at submission and installs it around each task,
//! so pool workers — whose own thread-local state is whatever the
//! *previous* batch left — always run under the submitter's kernel and
//! the per-kernel bitwise contract survives work stealing, nested
//! submission and batch mode.
//!
//! **Panics.** A panicking job poisons its batch: the first payload is
//! captured, the remaining tasks are drained *without running* (their
//! closures are dropped), every executor detaches cleanly, and the payload
//! is re-raised on the submitting thread by `resume_unwind`. Pool workers
//! never die to a job panic — the pool stays usable.
//!
//! **Shutdown protocol** (documented order; see also EXPERIMENTS.md §Perf):
//!
//! 1. `Drop` (or an explicit [`WorkerPool::shutdown`]) takes the pool by
//!    exclusive access, so no `run_graph`/`run_tasks` call can be in
//!    flight — every queued batch is already drained (`remaining == 0`).
//! 2. The `shutdown` flag is set *under the pool mutex* and `notify_all`
//!    is issued: a parked worker is either already waiting (woken, sees the
//!    flag) or between its queue check and `wait` (the flag write is
//!    ordered before its re-check by the mutex) — no lost wakeup.
//! 3. Workers finishing a batch re-acquire the pool mutex, observe the
//!    flag, and exit their loop.
//! 4. Every `JoinHandle` is joined; after `shutdown`/`drop` returns, no
//!    pool thread survives (asserted by `drop_joins_all_workers`).

use super::assist::{ClaimCounter, Schedule};
#[cfg(any(feature = "audit", debug_assertions))]
use super::audit;
use super::graph::{TaskClass, TaskGraph};
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// A lifetime-erased job. See [`erase`] for the soundness argument.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Erase a job's borrow lifetime so it can sit in a batch shared with the
/// `'static` pool workers.
///
/// # Safety
/// Sound because [`WorkerPool::run_graph`] does not return until
/// `remaining == 0`, i.e. until every closure in the batch has been taken
/// and either run or dropped. Helpers that still hold the batch `Arc`
/// afterwards only touch its owned fields (queue, counters, condvar),
/// never the (by then empty) closure slots — so no erased borrow is ever
/// dereferenced after the true lifetime ends.
fn erase<'a>(f: Box<dyn FnOnce() + Send + 'a>) -> Job {
    // SAFETY: only the lifetime is transmuted — the vtable and data
    // pointers are unchanged. The submitter blocks until `remaining == 0`
    // (every closure taken and run or dropped), so no erased borrow
    // outlives its true lifetime; see the doc comment above.
    unsafe {
        std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Box<dyn FnOnce() + Send + 'static>>(f)
    }
}

/// One submitted task graph, in execution form: the dependency-counting
/// scheduler state shared by the submitting thread and its helpers.
struct Batch {
    /// Ready-task FIFO.
    ready: Mutex<VecDeque<usize>>,
    /// Wakes executors blocked on an empty FIFO.
    cv: Condvar,
    /// Tasks not yet completed; `0` means the batch is done.
    remaining: AtomicUsize,
    /// Pending-predecessor count per task.
    pending: Vec<AtomicUsize>,
    /// Task closures (`take`n exactly once each).
    runs: Vec<Mutex<Option<Job>>>,
    /// Successor lists.
    succs: Vec<Vec<usize>>,
    /// Set on the first job panic: remaining tasks are drained unrun.
    poisoned: AtomicBool,
    /// First panic payload, re-raised on the submitting thread.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    /// Pool workers currently attached to this batch.
    helpers: AtomicUsize,
    /// Cap on attached pool workers (`threads - 1`; the submitter is the
    /// extra executor).
    max_helpers: usize,
    /// Work-assisting mode ([`super::assist`]): when set, executors claim
    /// task indices from this counter instead of pulling from the ready
    /// FIFO. Only valid for dependency-free batches (`pending`/`succs`
    /// empty) — the counter has no notion of edges.
    assist: Option<ClaimCounter>,
    /// The submitter's GEMM kernel at submission time
    /// ([`crate::linalg::kernels::current`]), installed around every task
    /// so helpers compute with the same microkernel as the submitting
    /// thread (see the module's Determinism notes).
    kernel: crate::linalg::kernels::Kernel,
    /// Concurrency-audit scope ([`super::audit`]) for this batch, if the
    /// auditor is active and the graph declared accesses. Executors enter
    /// the per-task context around each closure; the submitter runs the
    /// end-of-batch check.
    #[cfg(any(feature = "audit", debug_assertions))]
    scope: Option<std::sync::Arc<audit::AuditScope>>,
}

/// Abort bomb for scheduler-internal panics. Job panics are caught and
/// poisoned inside [`Batch::work`]; anything else unwinding out of that
/// frame is a scheduler bug (an invariant `expect`, a poisoned-mutex
/// `unwrap`) for which unwinding is *unsound*, not just wrong: on a helper
/// it would skip the `remaining` decrement and hang the submitter forever,
/// and on the submitter it would free stack frames that the lifetime-erased
/// closures still held by `'static` workers borrow (see [`erase`]).
/// Aborting the process is the only safe response.
struct AbortOnUnwind;

impl Drop for AbortOnUnwind {
    fn drop(&mut self) {
        if std::thread::panicking() {
            eprintln!(
                "paraht worker pool: internal scheduler panic; aborting to preserve \
                 soundness (coordinator::pool::Batch::work)"
            );
            std::process::abort();
        }
    }
}

impl Batch {
    /// Execute tasks until the batch is drained. Runs on the submitting
    /// thread and on every helper; returns when `remaining == 0`.
    fn work(&self) {
        // Disarmed by the normal return (drop without an active panic);
        // see `AbortOnUnwind` for why internal panics must not escape.
        let _guard = AbortOnUnwind;
        if let Some(counter) = &self.assist {
            self.work_assisted(counter);
            return;
        }
        loop {
            // Pull a ready task or wait; exit when all tasks are done.
            let task = {
                let mut q = self.ready.lock().unwrap();
                loop {
                    if self.remaining.load(Ordering::Acquire) == 0 {
                        return;
                    }
                    if let Some(t) = q.pop_front() {
                        break t;
                    }
                    q = self.cv.wait(q).unwrap();
                }
            };

            self.run_task(task);

            // Mark done, wake successors. This block must run even for
            // cancelled tasks or the drain deadlocks.
            let mut newly_ready = Vec::new();
            for &s in &self.succs[task] {
                if self.pending[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                    newly_ready.push(s);
                }
            }
            let left = self.remaining.fetch_sub(1, Ordering::AcqRel) - 1;
            if !newly_ready.is_empty() {
                let mut q = self.ready.lock().unwrap();
                for t in newly_ready {
                    q.push_back(t);
                }
                drop(q);
                self.cv.notify_all();
            } else if left == 0 {
                // Wake-for-exit must synchronize with waiters through the
                // queue mutex: an executor that observed `remaining != 0`
                // and an empty queue may be between that check and
                // `cv.wait`. Taking (and releasing) the lock orders this
                // notification after its check, so either it re-checks and
                // sees 0, or it is already waiting and receives the
                // notification. A bare `notify_all` here loses that race
                // and deadlocks.
                drop(self.ready.lock().unwrap());
                self.cv.notify_all();
            }
        }
    }

    /// Work-assisting drain ([`super::assist`]): claim task indices from
    /// the shared counter until it is exhausted, then wait for the panels
    /// claimed by *other* executors to finish. No ready-FIFO traffic per
    /// task — one `fetch_add` claims, one `fetch_sub` completes. Valid
    /// only for dependency-free batches (every task immediately runnable).
    fn work_assisted(&self, counter: &ClaimCounter) {
        while let Some(task) = counter.claim() {
            self.run_task(task);
            let left = self.remaining.fetch_sub(1, Ordering::AcqRel) - 1;
            if left == 0 {
                // Wake-for-exit: same fence-through-the-mutex protocol as
                // the FIFO path (see the comment there) — an executor that
                // drained the counter may be between its `remaining` check
                // and `cv.wait`.
                drop(self.ready.lock().unwrap());
                self.cv.notify_all();
            }
        }
        // Every panel is claimed, but claimed ≠ completed: other executors
        // may still be running theirs, and the submitter must not return
        // while lifetime-erased closures are live (see `erase`).
        let mut q = self.ready.lock().unwrap();
        while self.remaining.load(Ordering::Acquire) != 0 {
            q = self.cv.wait(q).unwrap();
        }
    }

    /// Take-and-run machinery shared by the FIFO and assisted drains: run
    /// the task's closure — or drop it unrun if the batch is poisoned —
    /// capturing the first panic payload.
    fn run_task(&self, task: usize) {
        let f = self.runs[task].lock().unwrap().take().expect("task run twice");
        // Attribute the closure's `SharedMat` views to this task id (and
        // clear any outer context when the batch is unaudited — nested
        // data-parallel views must not attribute to the enclosing task).
        #[cfg(any(feature = "audit", debug_assertions))]
        let _audit = audit::enter_task(self.scope.as_ref(), task);
        // Run under the submitter's GEMM kernel, whatever this thread's
        // own thread-local state is (restored on drop — including when the
        // closure panics, so a poisoned batch cannot leak an override).
        let _kernel = crate::linalg::kernels::enter(self.kernel);
        let result = if self.poisoned.load(Ordering::Acquire) {
            // Batch already failing: cancel (drop) instead of running.
            // The drop itself is guarded too — a closure owning a value
            // with a panicking `Drop` must not kill the worker mid-drain
            // (that would leak the task's `remaining` decrement and hang
            // the submitter).
            catch_unwind(AssertUnwindSafe(move || drop(f)))
        } else {
            catch_unwind(AssertUnwindSafe(f))
        };
        if let Err(payload) = result {
            self.poisoned.store(true, Ordering::Release);
            let mut slot = self.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
    }
}

/// Pool state shared between the owner and the parked workers.
struct PoolShared {
    state: Mutex<PoolState>,
    /// Parks idle workers; notified on batch submission and on shutdown.
    cv: Condvar,
}

struct PoolState {
    /// Active batches helpers can attach to (the job queue).
    queue: VecDeque<Arc<Batch>>,
    /// Set once by [`WorkerPool::shutdown`]/drop; workers exit when idle.
    shutdown: bool,
}

/// A persistent team of worker threads (see the module docs for the
/// execution model, panic semantics and shutdown protocol).
///
/// Most code uses the lazily-initialized process-global team ([`global`])
/// via [`run_parallel`] / [`run_data_parallel`]; explicit pools exist for
/// tests and for embedders that need their own team lifetime.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

/// Body of one pool worker: park on the condvar until a batch needs help
/// (or shutdown), drain it, detach, repeat.
fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let batch = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(b) = claim_batch(&mut st.queue) {
                    break b;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        batch.work();
        batch.helpers.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Find a queued batch with unfinished work and a free helper slot,
/// garbage-collecting finished batches in passing. Called under the pool
/// mutex.
fn claim_batch(queue: &mut VecDeque<Arc<Batch>>) -> Option<Arc<Batch>> {
    let mut i = 0;
    while i < queue.len() {
        if queue[i].remaining.load(Ordering::Acquire) == 0 {
            let _ = queue.remove(i);
            continue;
        }
        let b = &queue[i];
        let mut h = b.helpers.load(Ordering::Relaxed);
        while h < b.max_helpers {
            match b.helpers.compare_exchange_weak(h, h + 1, Ordering::AcqRel, Ordering::Relaxed) {
                Ok(_) => return Some(b.clone()),
                Err(cur) => h = cur,
            }
        }
        i += 1;
    }
    None
}

impl WorkerPool {
    /// Spawn a pool with `workers` parked threads. `workers == 0` is valid:
    /// every batch is then drained entirely by its submitting thread.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { queue: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("paraht-pool-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Number of pool worker threads (excluding submitting callers).
    pub fn worker_count(&self) -> usize {
        self.handles.len()
    }

    /// Execute the (finalized) graph with `threads` total executors: this
    /// caller plus up to `threads - 1` pool helpers. Blocks until every
    /// task has run; re-raises the first job panic, if any.
    pub fn run_graph(&self, mut graph: TaskGraph<'_>, threads: usize) {
        let n = graph.len();
        if n == 0 {
            return;
        }
        // Audit scope (if active): snapshot declarations + reachability
        // before the closures are taken out of the graph.
        #[cfg(any(feature = "audit", debug_assertions))]
        let scope = audit::scope_for(&graph);
        if threads <= 1 {
            // Degenerate case: run in submission order on the caller.
            for (_id, t) in graph.tasks.iter_mut().enumerate() {
                #[cfg(any(feature = "audit", debug_assertions))]
                let _audit = audit::enter_task(scope.as_ref(), _id);
                (t.run.take().unwrap())();
            }
            #[cfg(any(feature = "audit", debug_assertions))]
            audit::check_scope(scope);
            return;
        }

        // Pending-predecessor counts + take closures and successor lists
        // out of the graph (lifetime-erased; see `erase`).
        let mut pending: Vec<AtomicUsize> = Vec::with_capacity(n);
        let mut runs: Vec<Mutex<Option<Job>>> = Vec::with_capacity(n);
        let mut succs: Vec<Vec<usize>> = Vec::with_capacity(n);
        let mut initial: Vec<usize> = Vec::new();
        for (id, t) in graph.tasks.iter_mut().enumerate() {
            pending.push(AtomicUsize::new(t.deps.len()));
            runs.push(Mutex::new(t.run.take().map(erase)));
            succs.push(std::mem::take(&mut t.succs));
            if t.deps.is_empty() {
                initial.push(id);
            }
        }
        let batch = Arc::new(Batch {
            ready: Mutex::new(initial.into_iter().collect()),
            cv: Condvar::new(),
            remaining: AtomicUsize::new(n),
            pending,
            runs,
            succs,
            poisoned: AtomicBool::new(false),
            panic: Mutex::new(None),
            helpers: AtomicUsize::new(0),
            max_helpers: threads - 1,
            assist: None,
            kernel: crate::linalg::kernels::current(),
            #[cfg(any(feature = "audit", debug_assertions))]
            scope,
        });
        self.execute_batch(batch);
    }

    /// Publish a batch to the parked workers, participate in draining it,
    /// garbage-collect the queue entry and re-raise any job panic on this
    /// thread. Never-published batches (no workers, or a 0-helper cap)
    /// skip the global mutex entirely — both on publish and on cleanup.
    fn execute_batch(&self, batch: Arc<Batch>) {
        // Publish, then participate. Helpers drain the batch concurrently
        // with us; `work` returns for everyone once `remaining == 0`.
        let published = batch.max_helpers > 0 && !self.handles.is_empty();
        if published {
            self.shared.state.lock().unwrap().queue.push_back(batch.clone());
            self.shared.cv.notify_all();
        }
        batch.work();

        // Drained: remove our queue entry (a helper's GC may have beaten
        // us to it), then surface any job panic on this thread.
        if published {
            let mut st = self.shared.state.lock().unwrap();
            if let Some(pos) = st.queue.iter().position(|b| Arc::ptr_eq(b, &batch)) {
                let _ = st.queue.remove(pos);
            }
        }
        if let Some(p) = batch.panic.lock().unwrap().take() {
            std::panic::resume_unwind(p);
        }
        // Audit verdict last, on the submitting thread: every closure has
        // run (remaining == 0) and no job panicked, so the recorded access
        // log is complete.
        #[cfg(any(feature = "audit", debug_assertions))]
        if let Some(scope) = &batch.scope {
            scope.check();
        }
    }

    /// Execute independent closures — the data-parallel entry used by
    /// `linalg::gemm::gemm_par` and `WyRep::apply_par` — under the
    /// process-default schedule (`PALLAS_ASSIST`; static unless set). See
    /// [`WorkerPool::run_tasks_sched`].
    pub fn run_tasks<'a>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 'a>>, threads: usize) {
        self.run_tasks_sched(tasks, threads, Schedule::from_env());
    }

    /// Execute independent closures under an explicit schedule.
    ///
    /// * [`Schedule::Static`] — semantically a degenerate task graph (no
    ///   accesses → no edges → every task immediately ready); sharing
    ///   [`WorkerPool::run_graph`] keeps one scheduler for dataflow and
    ///   data-parallel work.
    /// * [`Schedule::Dynamic`] — work assisting: the tasks share a
    ///   [`ClaimCounter`] and every executor claims indices until it
    ///   drains, so load imbalance between tasks is absorbed without any
    ///   per-task queue traffic. Tasks still run exactly once each with
    ///   the same panic/poisoning semantics as the graph path.
    ///
    /// `threads <= 1` (or a single task) runs inline on the caller with no
    /// scheduling overhead — in submission order, under either schedule.
    pub fn run_tasks_sched<'a>(
        &self,
        tasks: Vec<Box<dyn FnOnce() + Send + 'a>>,
        threads: usize,
        sched: Schedule,
    ) {
        if tasks.is_empty() {
            return;
        }
        if threads <= 1 || tasks.len() == 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let workers = threads.min(tasks.len());
        if !sched.is_dynamic() {
            let mut g = TaskGraph::new();
            for t in tasks {
                g.add(TaskClass::Gemm, Vec::new(), t);
            }
            g.finalize();
            self.run_graph(g, workers);
            return;
        }

        // Work-assisting batch: no graph, no ready FIFO — just the erased
        // closures and a claim counter over their indices. The FIFO mutex
        // and condvar stay in the struct solely for the wake-for-exit
        // handshake in `work_assisted`.
        let n = tasks.len();
        let runs: Vec<Mutex<Option<Job>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(erase(t)))).collect();
        let batch = Arc::new(Batch {
            ready: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            remaining: AtomicUsize::new(n),
            pending: Vec::new(),
            runs,
            succs: Vec::new(),
            poisoned: AtomicBool::new(false),
            panic: Mutex::new(None),
            helpers: AtomicUsize::new(0),
            max_helpers: workers - 1,
            assist: Some(ClaimCounter::new(n)),
            kernel: crate::linalg::kernels::current(),
            // Data-parallel batches declare no regions: nothing to audit
            // (the claim counter carries its own uniqueness shadow).
            #[cfg(any(feature = "audit", debug_assertions))]
            scope: None,
        });
        self.execute_batch(batch);
    }

    /// Explicit shutdown: park → set flag → wake → join (the documented
    /// protocol; `Drop` runs the same sequence). Consuming `self` makes the
    /// "no batch in flight" precondition a compile-time fact.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            // Workers catch job panics, so join failure is unreachable;
            // don't double-panic during drop if it somehow happens.
            let _ = h.join();
        }
    }
}

static GLOBAL_POOL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-global worker team, spawned on first use and kept for the
/// process lifetime (never dropped, so its thread-local GEMM pack buffers
/// survive across every reduction in the process).
///
/// Sizing: `PALLAS_POOL_THREADS` (total team size *including* the
/// submitting caller; parsed and clamped by [`crate::util::env`], which
/// also honors the legacy `PARAHT_POOL_THREADS` alias) when set, otherwise
/// `available_parallelism()`; the pool spawns one fewer OS thread than the
/// team size because every run's caller is an executor.
/// `PALLAS_POOL_THREADS=1` therefore means "no pool threads, run
/// everything inline".
pub fn global() -> &'static WorkerPool {
    GLOBAL_POOL.get_or_init(|| {
        let team = crate::util::env::pool_threads().unwrap_or_else(|| {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        });
        WorkerPool::new(team.saturating_sub(1))
    })
}

/// Execute the (finalized) graph on the process-global pool with `threads`
/// executors (caller + helpers). Blocks until every task has run.
pub fn run_parallel(graph: TaskGraph<'_>, threads: usize) {
    global().run_graph(graph, threads);
}

/// Execute independent closures on the process-global pool under the
/// process-default schedule — see [`WorkerPool::run_tasks`].
pub fn run_data_parallel<'a>(tasks: Vec<Box<dyn FnOnce() + Send + 'a>>, threads: usize) {
    global().run_tasks(tasks, threads);
}

/// Execute independent closures on the process-global pool under an
/// explicit schedule — see [`WorkerPool::run_tasks_sched`].
pub fn run_data_parallel_sched<'a>(
    tasks: Vec<Box<dyn FnOnce() + Send + 'a>>,
    threads: usize,
    sched: Schedule,
) {
    global().run_tasks_sched(tasks, threads, sched);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::access::{Access, MatId};
    use crate::coordinator::graph::TaskClass;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex as StdMutex;

    #[test]
    fn runs_all_tasks_respecting_deps() {
        let log = StdMutex::new(Vec::new());
        let counter = AtomicUsize::new(0);
        let mut g = TaskGraph::new();
        // Chain via region conflicts: t0 → t1 → t2, t3 independent.
        g.add(TaskClass::GL, vec![Access::write(MatId::A, 0..4, 0..4)], || {
            log.lock().unwrap().push((0, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.add(TaskClass::LA, vec![Access::write(MatId::A, 2..6, 2..6)], || {
            log.lock().unwrap().push((1, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.add(TaskClass::LB, vec![Access::read(MatId::A, 3..4, 3..4)], || {
            log.lock().unwrap().push((2, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.add(TaskClass::LQ, vec![Access::write(MatId::Q, 0..4, 0..4)], || {
            log.lock().unwrap().push((3, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.finalize();
        run_parallel(g, 4);
        let l = log.into_inner().unwrap();
        assert_eq!(l.len(), 4);
        let pos = |task: usize| l.iter().find(|(t, _)| *t == task).unwrap().1;
        assert!(pos(0) < pos(1), "t0 before t1");
        assert!(pos(1) < pos(2), "t1 before t2");
    }

    #[test]
    fn parallel_equals_sequential_result() {
        // Many tasks incrementing disjoint counters; total must match.
        let cells: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let mut g = TaskGraph::new();
        for i in 0..64usize {
            let cell = &cells[i];
            g.add(
                TaskClass::Upd2,
                vec![Access::write(MatId::A, i..i + 1, 0..1)],
                move || {
                    cell.fetch_add(i + 1, Ordering::SeqCst);
                },
            );
        }
        g.finalize();
        run_parallel(g, 3);
        let total: usize = cells.iter().map(|c| c.load(Ordering::SeqCst)).sum();
        assert_eq!(total, (1..=64).sum::<usize>());
    }

    #[test]
    fn single_thread_fallback() {
        let c = AtomicUsize::new(0);
        let mut g = TaskGraph::new();
        for _ in 0..5 {
            g.add(TaskClass::Upd2, vec![], || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        g.finalize();
        run_parallel(g, 1);
        assert_eq!(c.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn empty_graph_ok() {
        let g = TaskGraph::new();
        run_parallel(g, 4);
    }

    #[test]
    fn data_parallel_runs_every_task() {
        for threads in [1usize, 2, 4, 9] {
            let cells: Vec<AtomicUsize> = (0..17).map(|_| AtomicUsize::new(0)).collect();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = cells
                .iter()
                .map(|c| Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            run_data_parallel(tasks, threads);
            assert!(cells.iter().all(|c| c.load(Ordering::SeqCst) == 1), "threads={threads}");
        }
        run_data_parallel(Vec::new(), 4); // empty is a no-op
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.worker_count(), 3);
        // 1 local clone + 1 in the pool struct + 3 moved into workers.
        let shared = pool.shared.clone();
        assert_eq!(Arc::strong_count(&shared), 5);
        // Run real work through it first.
        let c = AtomicUsize::new(0);
        let mut g = TaskGraph::new();
        for _ in 0..16 {
            g.add(TaskClass::Gemm, vec![], || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        g.finalize();
        pool.run_graph(g, 4);
        assert_eq!(c.load(Ordering::SeqCst), 16);
        pool.shutdown();
        // Every worker joined ⇒ every worker's Arc clone dropped.
        assert_eq!(Arc::strong_count(&shared), 1, "shutdown must join every worker");
    }

    #[test]
    fn zero_worker_pool_drains_on_caller() {
        let pool = WorkerPool::new(0);
        let c = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..9)
            .map(|_| {
                Box::new(|| {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_tasks(tasks, 4);
        assert_eq!(c.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn panic_in_one_job_fails_batch_without_deadlock() {
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut g = TaskGraph::new();
            for i in 0..32usize {
                let done = &done;
                g.add(TaskClass::Gemm, vec![], move || {
                    if i == 5 {
                        panic!("boom in job 5");
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                });
            }
            g.finalize();
            pool.run_graph(g, 3);
        }));
        assert!(result.is_err(), "job panic must propagate to the submitter");
        // The batch drained (no deadlock above) and the pool survives.
        let c = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..10)
            .map(|_| {
                Box::new(|| {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_tasks(tasks, 3);
        assert_eq!(c.load(Ordering::SeqCst), 10, "pool must stay usable after a job panic");
    }

    #[test]
    fn nested_submission_makes_progress() {
        // A job that submits to the same pool: caller participation
        // guarantees the inner batch drains even with every worker busy.
        let pool = WorkerPool::new(1);
        let c = AtomicUsize::new(0);
        let mut g = TaskGraph::new();
        {
            let pool = &pool;
            let c = &c;
            g.add(TaskClass::Gemm, vec![], move || {
                let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                    .map(|_| {
                        Box::new(|| {
                            c.fetch_add(1, Ordering::SeqCst);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_tasks(inner, 2);
            });
        }
        g.finalize();
        pool.run_graph(g, 2);
        assert_eq!(c.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn pool_reused_across_batches_by_same_team() {
        // Consecutive batches on one pool complete and see consistent
        // results (the pack-buffer-amortization scenario in miniature).
        let pool = WorkerPool::new(2);
        for round in 0..8usize {
            let cells: Vec<AtomicUsize> = (0..24).map(|_| AtomicUsize::new(0)).collect();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = cells
                .iter()
                .map(|cell| {
                    Box::new(move || {
                        cell.fetch_add(round + 1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks(tasks, 3);
            assert!(
                cells.iter().all(|c| c.load(Ordering::SeqCst) == round + 1),
                "round {round}"
            );
        }
    }

    #[test]
    fn assisted_runs_every_task_exactly_once() {
        let pool = WorkerPool::new(3);
        for threads in [2usize, 4, 7, 16] {
            let cells: Vec<AtomicUsize> = (0..23).map(|_| AtomicUsize::new(0)).collect();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = cells
                .iter()
                .map(|c| {
                    Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks_sched(tasks, threads, Schedule::Dynamic);
            assert!(cells.iter().all(|c| c.load(Ordering::SeqCst) == 1), "threads={threads}");
        }
        pool.run_tasks_sched(Vec::new(), 4, Schedule::Dynamic); // empty is a no-op
    }

    #[test]
    fn assisted_zero_worker_pool_drains_on_caller() {
        // No helpers: the submitter claims every panel itself.
        let pool = WorkerPool::new(0);
        let c = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..9)
            .map(|_| {
                Box::new(|| {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_tasks_sched(tasks, 4, Schedule::Dynamic);
        assert_eq!(c.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn assisted_panic_poisons_batch_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32usize)
                .map(|i| {
                    Box::new(move || {
                        if i == 5 {
                            panic!("boom in assisted job 5");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks_sched(tasks, 3, Schedule::Dynamic);
        }));
        assert!(result.is_err(), "assisted job panic must propagate to the submitter");
        // The batch drained (no deadlock above) and the pool stays usable
        // — on both schedules.
        for sched in [Schedule::Static, Schedule::Dynamic] {
            let c = AtomicUsize::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..10)
                .map(|_| {
                    Box::new(|| {
                        c.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks_sched(tasks, 3, sched);
            assert_eq!(c.load(Ordering::SeqCst), 10, "pool must stay usable ({sched:?})");
        }
    }

    #[test]
    fn assisted_nested_submission_makes_progress() {
        // An assisted job that submits an assisted batch to the same pool:
        // caller participation holds on the claim-counter path too.
        let pool = WorkerPool::new(1);
        let c = AtomicUsize::new(0);
        {
            let pool = &pool;
            let c = &c;
            let outer: Vec<Box<dyn FnOnce() + Send + '_>> = (0..3)
                .map(|_| {
                    Box::new(move || {
                        let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                            .map(|_| {
                                Box::new(|| {
                                    c.fetch_add(1, Ordering::SeqCst);
                                })
                                    as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        pool.run_tasks_sched(inner, 2, Schedule::Dynamic);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks_sched(outer, 2, Schedule::Dynamic);
        }
        assert_eq!(c.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn assisted_uneven_task_costs_complete() {
        // Wildly uneven task durations: the claim loop must still complete
        // every task and return only when all are done (the imbalance
        // scenario the scheduler exists for).
        let pool = WorkerPool::new(3);
        let done = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..12usize)
            .map(|i| {
                let done = &done;
                Box::new(move || {
                    if i == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_tasks_sched(tasks, 4, Schedule::Dynamic);
        assert_eq!(done.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn batch_tasks_run_under_the_submitters_kernel() {
        use crate::linalg::kernels::{self, Kernel};
        // Workers' own thread-local state is unrelated to the submitter's;
        // the batch capture must make every task observe the submitter's
        // kernel — on both schedules.
        let pool = WorkerPool::new(2);
        for sched in [Schedule::Static, Schedule::Dynamic] {
            let ok = AtomicUsize::new(0);
            kernels::with_kernel(Kernel::Scalar, || {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
                    .map(|_| {
                        let ok = &ok;
                        Box::new(move || {
                            if kernels::current() == Kernel::Scalar {
                                ok.fetch_add(1, Ordering::SeqCst);
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_tasks_sched(tasks, 3, sched);
            });
            assert_eq!(ok.load(Ordering::SeqCst), 16, "{sched:?}");
        }
    }

    #[test]
    fn global_pool_is_a_singleton() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
    }
}
