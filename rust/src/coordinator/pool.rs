//! Dynamic scheduler: execute a [`TaskGraph`] on a pool of worker threads.
//!
//! Classic dependency-counting design (the "dynamic scheduler" the paper
//! relies on, §2.3): every task carries a pending-predecessor count; workers
//! pull ready tasks from a shared FIFO, run them, and decrement their
//! successors, enqueueing those that become ready. Load imbalance between
//! slices (e.g. the triangular `L_B` slices) is absorbed by the shared
//! queue — "we chose to let the dynamic scheduler handle these load
//! imbalances."

use super::graph::{TaskClass, TaskGraph};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

struct SchedState {
    ready: Mutex<VecDeque<usize>>,
    cv: Condvar,
    remaining: AtomicUsize,
}

/// Execute the (finalized) graph on `threads` workers. Blocks until every
/// task has run.
pub fn run_parallel(mut graph: TaskGraph<'_>, threads: usize) {
    let n = graph.len();
    if n == 0 {
        return;
    }
    if threads <= 1 {
        // Degenerate case: run in submission order on the caller.
        for t in &mut graph.tasks {
            (t.run.take().unwrap())();
        }
        return;
    }

    // Pending-predecessor counts + take closures and successor lists out.
    let mut pending: Vec<AtomicUsize> = Vec::with_capacity(n);
    let mut runs: Vec<Mutex<Option<Box<dyn FnOnce() + Send + '_>>>> = Vec::with_capacity(n);
    let mut succs: Vec<Vec<usize>> = Vec::with_capacity(n);
    let mut initial: Vec<usize> = Vec::new();
    for (id, t) in graph.tasks.iter_mut().enumerate() {
        pending.push(AtomicUsize::new(t.deps.len()));
        runs.push(Mutex::new(t.run.take()));
        succs.push(std::mem::take(&mut t.succs));
        if t.deps.is_empty() {
            initial.push(id);
        }
    }

    let state = SchedState {
        ready: Mutex::new(initial.into_iter().collect()),
        cv: Condvar::new(),
        remaining: AtomicUsize::new(n),
    };

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                loop {
                    // Pull a ready task or wait; exit when all tasks done.
                    let task = {
                        let mut q = state.ready.lock().unwrap();
                        loop {
                            if state.remaining.load(Ordering::Acquire) == 0 {
                                return;
                            }
                            if let Some(t) = q.pop_front() {
                                break t;
                            }
                            q = state.cv.wait(q).unwrap();
                        }
                    };

                    let f = runs[task].lock().unwrap().take().expect("task run twice");
                    f();

                    // Mark done, wake successors.
                    let mut newly_ready = Vec::new();
                    for &s in &succs[task] {
                        if pending[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                            newly_ready.push(s);
                        }
                    }
                    let left = state.remaining.fetch_sub(1, Ordering::AcqRel) - 1;
                    if !newly_ready.is_empty() {
                        let mut q = state.ready.lock().unwrap();
                        for t in newly_ready {
                            q.push_back(t);
                        }
                        drop(q);
                        state.cv.notify_all();
                    } else if left == 0 {
                        // Wake-for-exit must synchronize with waiters through
                        // the queue mutex: a worker that observed
                        // `remaining != 0` and an empty queue may be between
                        // that check and `cv.wait`. Taking (and releasing)
                        // the lock orders this notification after its check,
                        // so either it re-checks and sees 0, or it is already
                        // waiting and receives the notification. A bare
                        // `notify_all` here loses that race and deadlocks.
                        drop(state.ready.lock().unwrap());
                        state.cv.notify_all();
                    }
                }
            });
        }
    });
}

/// Execute independent closures on the worker pool — the data-parallel
/// entry used by `linalg::gemm::gemm_par` and `WyRep::apply_par` to
/// saturate cores when the dataflow graph itself yields too few slices.
///
/// Semantically a degenerate task graph (no accesses → no edges → every
/// task immediately ready); sharing [`run_parallel`] keeps one scheduler
/// implementation for both dataflow and data-parallel work. `threads <= 1`
/// (or a single task) runs inline on the caller with no graph overhead.
pub fn run_data_parallel<'a>(tasks: Vec<Box<dyn FnOnce() + Send + 'a>>, threads: usize) {
    if tasks.is_empty() {
        return;
    }
    if threads <= 1 || tasks.len() == 1 {
        for t in tasks {
            t();
        }
        return;
    }
    let workers = threads.min(tasks.len());
    let mut g = TaskGraph::new();
    for t in tasks {
        g.add(TaskClass::Gemm, Vec::new(), t);
    }
    g.finalize();
    run_parallel(g, workers);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::access::{Access, MatId};
    use crate::coordinator::graph::TaskClass;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex as StdMutex;

    #[test]
    fn runs_all_tasks_respecting_deps() {
        let log = StdMutex::new(Vec::new());
        let counter = AtomicUsize::new(0);
        let mut g = TaskGraph::new();
        // Chain via region conflicts: t0 → t1 → t2, t3 independent.
        g.add(TaskClass::GL, vec![Access::write(MatId::A, 0..4, 0..4)], || {
            log.lock().unwrap().push((0, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.add(TaskClass::LA, vec![Access::write(MatId::A, 2..6, 2..6)], || {
            log.lock().unwrap().push((1, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.add(TaskClass::LB, vec![Access::read(MatId::A, 3..4, 3..4)], || {
            log.lock().unwrap().push((2, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.add(TaskClass::LQ, vec![Access::write(MatId::Q, 0..4, 0..4)], || {
            log.lock().unwrap().push((3, counter.fetch_add(1, Ordering::SeqCst)));
        });
        g.finalize();
        run_parallel(g, 4);
        let l = log.into_inner().unwrap();
        assert_eq!(l.len(), 4);
        let pos = |task: usize| l.iter().find(|(t, _)| *t == task).unwrap().1;
        assert!(pos(0) < pos(1), "t0 before t1");
        assert!(pos(1) < pos(2), "t1 before t2");
    }

    #[test]
    fn parallel_equals_sequential_result() {
        // Many tasks incrementing disjoint counters; total must match.
        let cells: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        let mut g = TaskGraph::new();
        for i in 0..64usize {
            let cell = &cells[i];
            g.add(
                TaskClass::Upd2,
                vec![Access::write(MatId::A, i..i + 1, 0..1)],
                move || {
                    cell.fetch_add(i + 1, Ordering::SeqCst);
                },
            );
        }
        g.finalize();
        run_parallel(g, 3);
        let total: usize = cells.iter().map(|c| c.load(Ordering::SeqCst)).sum();
        assert_eq!(total, (1..=64).sum::<usize>());
    }

    #[test]
    fn single_thread_fallback() {
        let c = AtomicUsize::new(0);
        let mut g = TaskGraph::new();
        for _ in 0..5 {
            g.add(TaskClass::Upd2, vec![], || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        g.finalize();
        run_parallel(g, 1);
        assert_eq!(c.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn empty_graph_ok() {
        let g = TaskGraph::new();
        run_parallel(g, 4);
    }

    #[test]
    fn data_parallel_runs_every_task() {
        for threads in [1usize, 2, 4, 9] {
            let cells: Vec<AtomicUsize> = (0..17).map(|_| AtomicUsize::new(0)).collect();
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = cells
                .iter()
                .map(|c| Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            run_data_parallel(tasks, threads);
            assert!(cells.iter().all(|c| c.load(Ordering::SeqCst) == 1), "threads={threads}");
        }
        run_data_parallel(Vec::new(), 4); // empty is a no-op
    }
}
