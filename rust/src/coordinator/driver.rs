//! The ParaHT driver layer: the speedup-curve helpers and comparator trace
//! collection used by the figure benchmarks, plus the deprecated
//! [`run_paraht`] shim (the reduction entry point itself moved to the
//! session front door, [`crate::api::HtSession`]).

use super::graph::TaskTrace;
use super::recorder::PhaseRecorder;
use super::sim::Simulator;
use super::stage1_par::ExecMode;
use crate::api::HtSession;
use crate::baselines::one_stage::{OneStageOpts, OppositeMethod};
use crate::baselines::{dgghd3, iterht, moler_stewart, one_stage};
use crate::config::Config;
use crate::error::Result;
use crate::linalg::matrix::Matrix;
use crate::linalg::verify::HtVerification;
use crate::util::timer::Timer;

/// Outcome of a ParaHT run through the coordinator.
pub struct ParaHtRun {
    /// Hessenberg factor.
    pub h: Matrix,
    /// Triangular factor.
    pub t: Matrix,
    /// Left orthogonal factor.
    pub q: Matrix,
    /// Right orthogonal factor.
    pub z: Matrix,
    /// Wall-clock seconds for stage 1 / stage 2 (of this execution).
    pub stage_secs: (f64, f64),
    /// Task traces (trace mode only): stage 1 and stage 2.
    pub traces: Option<(TaskTrace, TaskTrace)>,
}

impl ParaHtRun {
    /// Verify against the original pencil.
    pub fn verify(&self, a0: &Matrix, b0: &Matrix) -> HtVerification {
        HtVerification::compute(a0, b0, &self.q, &self.z, &self.h, &self.t, 1)
    }
}

/// Run the two-stage ParaHT reduction through the coordinator.
///
/// Thin shim over the session front door: `ExecMode::Threads(t)` maps to a
/// one-shot [`HtSession`] at `t` threads, `ExecMode::Trace` to a
/// trace-capturing session — identical kernels in the same valid
/// topological order, so results are unchanged bit for bit (additionally,
/// a non-triangular `B` is now pre-triangularized like the sequential
/// oracle instead of being a silent precondition violation).
#[deprecated(
    since = "0.2.0",
    note = "use `paraht::api::HtSession` (builder front door); removal target 0.3.0 — \
            see EXPERIMENTS.md §API for the migration table"
)]
pub fn run_paraht(a: &Matrix, b: &Matrix, cfg: &Config, mode: ExecMode) -> Result<ParaHtRun> {
    let builder = HtSession::builder().config(cfg.clone());
    let builder = match mode {
        // The old driver built the graph from cfg (cfg.threads feeds the
        // auto slice count) but executed with the mode's thread count.
        // Pinning the resolved slice count before overriding threads
        // preserves the exact old task granularity; Threads(0) behaved
        // like a degenerate sequential run, so keep that too.
        ExecMode::Threads(t) => builder.slices(cfg.effective_slices()).threads(t.max(1)),
        // Trace always executed sequentially on the cfg-built graph;
        // capture_traces forces the sequential path on its own, so
        // cfg.threads stays intact and the trace granularity matches the
        // old mode exactly.
        ExecMode::Trace => builder.capture_traces(true),
    };
    let mut session = builder.build()?;
    let d = session.reduce(a, b)?;
    let traces = session.take_traces();
    Ok(ParaHtRun {
        h: d.h,
        t: d.t,
        q: d.q,
        z: d.z,
        stage_secs: (d.stage1_secs, d.stage2_secs),
        traces,
    })
}

/// Simulated speedup data for one algorithm: per-P makespans plus the
/// sequential total.
#[derive(Clone, Debug)]
pub struct SpeedupCurve {
    /// Algorithm label.
    pub name: &'static str,
    /// Sequential (P = 1) time in seconds.
    pub t1: f64,
    /// `(P, simulated seconds)` points.
    pub points: Vec<(usize, f64)>,
}

impl SpeedupCurve {
    /// Speedup over a reference sequential time.
    pub fn speedup_over(&self, t_ref: f64) -> Vec<(usize, f64)> {
        self.points.iter().map(|&(p, t)| (p, t_ref / t)).collect()
    }
}

/// Simulate a ParaHT trace pair over the worker counts. One memoized
/// [`Simulator`] per stage: the whole sweep costs at most `max(ps)` greedy
/// replays per stage instead of `Σ ps` (the quadratic blow-up the ROADMAP
/// flagged for large experiment sweeps).
pub fn paraht_curve(traces: &(TaskTrace, TaskTrace), ps: &[usize]) -> SpeedupCurve {
    let t1 = traces.0.total().as_secs_f64() + traces.1.total().as_secs_f64();
    let mut sim1 = Simulator::new(&traces.0);
    let mut sim2 = Simulator::new(&traces.1);
    let points = ps
        .iter()
        .map(|&p| (p, sim1.result(p).makespan + sim2.result(p).makespan))
        .collect();
    SpeedupCurve { name: "ParaHT", t1, points }
}

/// Simulate a barrier-structured comparator trace over the worker counts.
/// The recorder trace depends only on the slice count `slices.max(p)`, so
/// one memoized [`Simulator`] is kept per distinct slice count (a single
/// one for the common `max(ps) <= slices` case).
pub fn recorder_curve(
    name: &'static str,
    rec: &PhaseRecorder,
    ps: &[usize],
    slices: usize,
) -> SpeedupCurve {
    let t1 = rec.total_secs();
    let mut sims: Vec<(usize, Simulator)> = Vec::new();
    let points = ps
        .iter()
        .map(|&p| {
            let sc = slices.max(p);
            let idx = match sims.iter().position(|(c, _)| *c == sc) {
                Some(i) => i,
                None => {
                    sims.push((sc, Simulator::new(&rec.to_trace(sc))));
                    sims.len() - 1
                }
            };
            (p, sims[idx].1.result(p).makespan)
        })
        .collect();
    SpeedupCurve { name, t1, points }
}

/// Sequential LAPACK normalizer: Moler–Stewart runtime on this pencil.
pub fn lapack_seq_time(a: &Matrix, b: &Matrix) -> f64 {
    let n = a.rows();
    let (mut a, mut b) = (a.clone(), b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    let t = Timer::start();
    moler_stewart::reduce(&mut a, &mut b, &mut q, &mut z);
    t.secs()
}

/// Traced DGGHD3 comparator run.
pub fn dgghd3_recorded(a: &Matrix, b: &Matrix) -> PhaseRecorder {
    let n = a.rows();
    let (mut a, mut b) = (a.clone(), b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    let mut rec = PhaseRecorder::new();
    dgghd3::reduce_recorded(&mut a, &mut b, &mut q, &mut z, &mut rec);
    rec
}

/// Traced HouseHT comparator run (never fails; refinement cost included).
pub fn househt_recorded(a: &Matrix, b: &Matrix) -> PhaseRecorder {
    let n = a.rows();
    let (mut a, mut b) = (a.clone(), b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    let mut rec = PhaseRecorder::new();
    let opts = OneStageOpts { method: OppositeMethod::SolveWithFallback, ..Default::default() };
    let _ = one_stage::reduce_recorded(&mut a, &mut b, &mut q, &mut z, &opts, &mut rec);
    rec
}

/// Traced IterHT comparator run. `Err` reproduces the paper's
/// non-convergence on pencils with many infinite eigenvalues.
pub fn iterht_recorded(a: &Matrix, b: &Matrix) -> Result<(PhaseRecorder, usize)> {
    let n = a.rows();
    let (mut am, mut bm) = (a.clone(), b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    let opts = OneStageOpts {
        method: OppositeMethod::Solve,
        residual_tol: iterht::IterHtOpts::default().tol,
        ..Default::default()
    };
    let mut rec = PhaseRecorder::new();
    let max_iters = iterht::IterHtOpts::default().max_iters;
    for iter in 1..=max_iters {
        match one_stage::reduce_recorded(&mut am, &mut bm, &mut q, &mut z, &opts, &mut rec) {
            Ok(_) => return Ok((rec, iter)),
            Err(_) => continue,
        }
    }
    Err(crate::Error::numerical(format!(
        "IterHT failed to converge within {max_iters} iterations of iterative refinement"
    )))
}

#[cfg(test)]
#[allow(deprecated)] // the run_paraht tests double as shim coverage
mod tests {
    use super::*;
    use crate::pencil::random::random_pencil;
    use crate::pencil::saddle::saddle_pencil;
    use crate::util::rng::Rng;

    #[test]
    fn paraht_threads_produces_valid_ht() {
        let mut rng = Rng::new(180);
        let p = random_pencil(60, &mut rng);
        let cfg = Config { r: 6, p: 3, q: 4, threads: 4, ..Config::default() };
        let run = run_paraht(&p.a, &p.b, &cfg, ExecMode::Threads(4)).unwrap();
        run.verify(&p.a, &p.b).assert_ok(1e-11);
        assert!(run.traces.is_none());
    }

    #[test]
    fn paraht_trace_and_curve() {
        let mut rng = Rng::new(181);
        let p = random_pencil(80, &mut rng);
        let cfg = Config { r: 8, p: 3, q: 4, threads: 1, ..Config::default() };
        let run = run_paraht(&p.a, &p.b, &cfg, ExecMode::Trace).unwrap();
        run.verify(&p.a, &p.b).assert_ok(1e-11);
        let traces = run.traces.expect("trace mode");
        let curve = paraht_curve(&traces, &[1, 2, 4, 8]);
        // Monotone improvement.
        for w in curve.points.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
        // P=1 simulation equals total work.
        assert!((curve.points[0].1 - curve.t1).abs() < 1e-9);
    }

    #[test]
    fn comparator_curves_have_amdahl_shape() {
        let mut rng = Rng::new(182);
        let p = random_pencil(60, &mut rng);
        let rec = dgghd3_recorded(&p.a, &p.b);
        assert!(rec.sliceable_fraction() > 0.3, "dgghd3 BLAS fraction {:.2}", rec.sliceable_fraction());
        let curve = recorder_curve("DGGHD3", &rec, &[1, 4, 16], 16);
        let s16 = curve.t1 / curve.points[2].1;
        // Amdahl: bounded by 1/(1-f).
        let f = rec.sliceable_fraction();
        assert!(s16 <= 1.0 / (1.0 - f) + 0.35, "s16={s16} f={f}");
        assert!(s16 > 1.0);
    }

    #[test]
    fn iterht_recorded_fails_on_saddle() {
        let mut rng = Rng::new(183);
        let p = saddle_pencil(40, 0.25, &mut rng);
        assert!(iterht_recorded(&p.a, &p.b).is_err());
        // But HouseHT completes.
        let rec = househt_recorded(&p.a, &p.b);
        assert!(rec.total_secs() > 0.0);
    }
}
