//! Discrete-event makespan simulator — the substitution for the paper's
//! 28-core Xeon (see DESIGN.md §5).
//!
//! Input: a [`TaskTrace`] (per-task durations measured during a sequential
//! execution of the *real* task graph) and a virtual worker count `P`.
//! The simulator replays the DAG under greedy FIFO list scheduling — the
//! same policy as the real dynamic scheduler in [`super::pool`] — and
//! reports the makespan. Speedup curves (Figs. 9–11) are then
//! `T_ref / makespan(P)`.
//!
//! Guarantees (tested): `makespan(1) = Σ durations`; monotone non-increasing
//! in `P`; bounded below by the critical path and by `total/P`.

use super::graph::{TaskClass, TaskTrace};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::time::Duration;

/// Result of one simulation.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Simulated wall-clock (seconds).
    pub makespan: f64,
    /// Sum of all task durations (seconds) — the P=1 time.
    pub total_work: f64,
    /// Critical-path length (seconds) — the P=∞ bound.
    pub critical_path: f64,
    /// Average worker utilization in [0, 1].
    pub utilization: f64,
}

/// Reusable makespan simulator for one trace: the DAG structure, critical
/// path and — crucially — the per-worker-count greedy replays are computed
/// once and memoized across queries.
///
/// Monotonicity: plain greedy list scheduling is subject to Graham's
/// scheduling anomalies — adding workers can *increase* the makespan on
/// adversarial DAGs, which would break the documented contract (and the
/// speedup curves built on it). The real pool is work-conserving but free
/// to leave workers idle when the ready queue is short, so a `p`-worker
/// machine can realize any `p' ≤ p` greedy schedule by parking workers.
/// We therefore report the best greedy schedule over effective worker
/// counts `1..=p` — monotone non-increasing in `p` by construction, still
/// a feasible `p`-worker schedule. For `p ≥ #tasks` greedy is exact (every
/// task starts the moment its dependencies finish), so the makespan is the
/// critical path and no sweep is needed.
///
/// The one-shot [`simulate_makespan`] needs up to `p` greedy replays for
/// the best-over-`1..=p` sweep; a P-sweep of one-shot calls is therefore
/// quadratic in the largest P. `Simulator` keeps the prefix minima, so a
/// whole sweep costs at most `max(P)` replays total — and stops replaying
/// entirely once the critical-path lower bound is reached.
pub struct Simulator {
    dur: Vec<f64>,
    indeg0: Vec<usize>,
    succs: Vec<Vec<usize>>,
    total_work: f64,
    critical_path: f64,
    /// `best[w-1]` = min greedy makespan over effective worker counts
    /// `1..=w` (prefix minima, grown lazily).
    best: Vec<f64>,
    /// Set once the prefix minimum hits the critical path: no further
    /// replay can improve, so larger counts are filled without simulating.
    saturated: bool,
}

impl Simulator {
    /// Build the simulator for a trace (copies the structure out, so the
    /// trace may be dropped).
    pub fn new(trace: &TaskTrace) -> Simulator {
        let n = trace.durations.len();
        let dur: Vec<f64> = trace.durations.iter().map(Duration::as_secs_f64).collect();
        let total_work: f64 = dur.iter().sum();
        let mut indeg0 = vec![0usize; n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (id, deps) in trace.deps.iter().enumerate() {
            indeg0[id] = deps.len();
            for &d in deps {
                succs[d].push(id);
            }
        }
        // Critical path (longest path; submission order is topological).
        let mut cp = vec![0.0f64; n];
        for id in 0..n {
            let start: f64 = trace.deps[id].iter().map(|&d| cp[d]).fold(0.0, f64::max);
            cp[id] = start + dur[id];
        }
        let critical_path = cp.iter().cloned().fold(0.0, f64::max);
        Simulator {
            dur,
            indeg0,
            succs,
            total_work,
            critical_path,
            best: Vec::new(),
            saturated: false,
        }
    }

    /// Critical-path length (the `P = ∞` bound).
    pub fn critical_path(&self) -> f64 {
        self.critical_path
    }

    /// Total work (the `P = 1` time).
    pub fn total_work(&self) -> f64 {
        self.total_work
    }

    /// Grow the memoized prefix minima up to worker count `p`.
    fn ensure(&mut self, p: usize) {
        while self.best.len() < p {
            let w = self.best.len() + 1;
            let prev = self.best.last().copied().unwrap_or(f64::INFINITY);
            let val = if self.saturated {
                prev
            } else {
                prev.min(greedy_fifo_makespan(&self.dur, &self.indeg0, &self.succs, w))
            };
            if val <= self.critical_path {
                self.saturated = true;
            }
            self.best.push(val);
        }
    }

    /// Simulate `p` workers (memoized; same value as [`simulate_makespan`]).
    pub fn result(&mut self, p: usize) -> SimResult {
        assert!(p >= 1);
        let n = self.dur.len();
        if n == 0 {
            return SimResult { makespan: 0.0, total_work: 0.0, critical_path: 0.0, utilization: 1.0 };
        }
        let makespan = if p >= n {
            self.critical_path
        } else {
            self.ensure(p);
            self.best[p - 1]
        };
        SimResult {
            makespan,
            total_work: self.total_work,
            critical_path: self.critical_path,
            utilization: if makespan > 0.0 {
                self.total_work / (makespan * p as f64)
            } else {
                1.0
            },
        }
    }
}

/// One-shot convenience wrapper around [`Simulator`]. Sweeping many `p`
/// over the same trace should construct one `Simulator` and query it
/// instead (each one-shot call rebuilds the structure and replays up to
/// `p` greedy schedules).
pub fn simulate_makespan(trace: &TaskTrace, p: usize) -> SimResult {
    Simulator::new(trace).result(p)
}

/// One greedy FIFO list-scheduling replay on exactly `workers` workers:
/// event-driven, ready FIFO in dependency-release order (matching the
/// pool), worker completion min-heap.
fn greedy_fifo_makespan(dur: &[f64], indeg0: &[usize], succs: &[Vec<usize>], workers: usize) -> f64 {
    let n = dur.len();
    let mut indeg = indeg0.to_vec();
    let mut ready: VecDeque<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    // Heap of (finish_time, task) as Reverse for min-heap. f64 ordering via
    // bit pattern: non-negative f64s order as their u64 bits.
    let mut running: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut free_workers = workers;
    let mut now = 0.0f64;
    let mut done = 0usize;
    let key = |t: f64| -> u64 { t.to_bits() };

    while done < n {
        // Start as many ready tasks as possible.
        while free_workers > 0 {
            if let Some(t) = ready.pop_front() {
                running.push(Reverse((key(now + dur[t]), t)));
                free_workers -= 1;
            } else {
                break;
            }
        }
        // Advance to the next completion.
        let Reverse((fk, t)) = running.pop().expect("deadlock: no running tasks");
        now = f64::from_bits(fk);
        free_workers += 1;
        done += 1;
        for &s in &succs[t] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push_back(s);
            }
        }
    }
    now
}

/// Sum the simulated time attributable to one task class (for the phase
/// breakdowns of Fig. 10): the fraction of total work in that class.
pub fn class_fraction(trace: &TaskTrace, class: TaskClass) -> f64 {
    let total = trace.total().as_secs_f64();
    if total == 0.0 {
        return 0.0;
    }
    trace.class_total(class).as_secs_f64() / total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_trace(durs_ms: &[u64], deps: Vec<Vec<usize>>) -> TaskTrace {
        TaskTrace {
            durations: durs_ms.iter().map(|&m| Duration::from_millis(m)).collect(),
            classes: vec![TaskClass::Upd2; durs_ms.len()],
            deps,
        }
    }

    #[test]
    fn p1_equals_total_work() {
        let tr = mk_trace(&[10, 20, 30], vec![vec![], vec![0], vec![0]]);
        let r = simulate_makespan(&tr, 1);
        assert!((r.makespan - 0.060).abs() < 1e-9);
        assert!((r.total_work - 0.060).abs() < 1e-9);
    }

    #[test]
    fn parallel_chain_vs_fanout() {
        // Pure chain: no speedup.
        let chain = mk_trace(&[10, 10, 10], vec![vec![], vec![0], vec![1]]);
        let r = simulate_makespan(&chain, 4);
        assert!((r.makespan - 0.030).abs() < 1e-9);
        assert!((r.critical_path - 0.030).abs() < 1e-9);
        // Fan-out: perfect speedup.
        let fan = mk_trace(&[10, 10, 10, 10], vec![vec![], vec![], vec![], vec![]]);
        let r2 = simulate_makespan(&fan, 4);
        assert!((r2.makespan - 0.010).abs() < 1e-9);
        assert!((r2.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn monotone_in_p_and_bounded() {
        // Random-ish DAG.
        let mut deps = vec![vec![]];
        for i in 1..40usize {
            deps.push(vec![i / 2, i.saturating_sub(3)]);
        }
        let durs: Vec<u64> = (1..=40).map(|i| (i * 7 % 13 + 1) as u64).collect();
        let tr = mk_trace(&durs, deps);
        let mut last = f64::INFINITY;
        for p in [1, 2, 4, 8, 16] {
            let r = simulate_makespan(&tr, p);
            assert!(r.makespan <= last + 1e-12, "not monotone at p={p}");
            assert!(r.makespan + 1e-12 >= r.critical_path, "below critical path");
            assert!(r.makespan + 1e-12 >= r.total_work / p as f64, "beats work bound");
            last = r.makespan;
        }
    }

    #[test]
    fn two_workers_pack_correctly() {
        // Tasks 3,3,3 independent on 2 workers → makespan 6.
        let tr = mk_trace(&[3, 3, 3], vec![vec![], vec![], vec![]]);
        let r = simulate_makespan(&tr, 2);
        assert!((r.makespan - 0.006).abs() < 1e-9);
    }

    #[test]
    fn memoized_sweep_matches_one_shot() {
        // A shared Simulator must return exactly the one-shot values, in
        // any query order, including repeats and the p >= n shortcut.
        let mut deps = vec![vec![]];
        for i in 1..30usize {
            deps.push(vec![i / 3]);
        }
        let durs: Vec<u64> = (1..=30).map(|i| (i * 5 % 11 + 1) as u64).collect();
        let tr = mk_trace(&durs, deps);
        let mut sim = Simulator::new(&tr);
        for p in [16usize, 2, 8, 2, 1, 64, 4] {
            let memo = sim.result(p);
            let fresh = simulate_makespan(&tr, p);
            assert_eq!(memo.makespan, fresh.makespan, "p={p}");
            assert_eq!(memo.critical_path, fresh.critical_path);
            assert_eq!(memo.total_work, fresh.total_work);
        }
        assert!((sim.total_work() - tr.total().as_secs_f64()).abs() < 1e-12);
        assert!(sim.critical_path() <= sim.total_work() + 1e-12);
    }

    #[test]
    fn empty_trace_simulator() {
        let tr = mk_trace(&[], vec![]);
        let mut sim = Simulator::new(&tr);
        let r = sim.result(3);
        assert_eq!(r.makespan, 0.0);
        assert_eq!(r.utilization, 1.0);
    }
}
