//! Work-assisting panel claiming: the atomic chunk-claiming loop that lets
//! executors pick up panels *dynamically* instead of receiving a static
//! assignment up front.
//!
//! The paper's stage-1 trailing updates and stage-2 group applies carve a
//! matrix into panels and hand one contiguous span to each executor. A
//! static split is optimal only when every panel costs the same; the
//! triangular slices (`L_B`, the lookahead blocks) and cache effects make
//! real panel costs uneven, so the last executor to finish sets the pace —
//! classic tail imbalance. Work assisting replaces the up-front assignment
//! with a shared [`ClaimCounter`]: each executor repeatedly claims the next
//! unclaimed panel index with one `fetch_add` until the counter drains.
//! Fast executors simply claim more panels; nobody waits on a straggler's
//! leftover assignment.
//!
//! **Determinism.** Claiming decides *who* computes a panel, never the
//! accumulation order inside it. Every panel's contents are a pure function
//! of the panel bounds, and the bitwise slicing-invariance contract in
//! [`crate::linalg::gemm`] (each output element accumulates in ascending-k
//! order into its own scalar accumulator) makes the results independent of
//! how the output is carved into panels at all. Dynamic runs are therefore
//! bitwise identical to static runs and to the sequential oracle —
//! `tests/equivalence.rs` pins this at 1/2/4/7 threads.
//!
//! **Scope.** The claim counter schedules *independent* task lists (the
//! data-parallel entry points: `gemm_par`, `WyRep::apply_par`,
//! `pool::run_data_parallel`, batch mode). Dependency-carrying task graphs
//! already get dynamic scheduling from the pool's shared ready FIFO; for
//! those, the gate instead oversplits the slice goal ([`slice_goal`]) so
//! the FIFO has finer panels to balance with.
//!
//! Gating: off by default. `Config::dynamic_schedule` turns it on per run;
//! the `PALLAS_ASSIST` env knob ([`crate::util::env::assist`]) flips the
//! process-wide default for entry points that take no config.

#[cfg(any(feature = "audit", debug_assertions))]
use std::sync::atomic::AtomicBool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::config::Config;

/// How a data-parallel task list is assigned to executors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Static split: panels are assigned up front (one contiguous span per
    /// executor) — the historical behavior, and the default.
    Static,
    /// Work assisting: executors claim panel indices from a shared
    /// [`ClaimCounter`] at run time; panels are oversplit ([`oversplit`])
    /// so there is slack for the fast executors to absorb.
    Dynamic,
}

impl Schedule {
    /// Whether this schedule claims panels dynamically.
    pub fn is_dynamic(self) -> bool {
        matches!(self, Schedule::Dynamic)
    }

    /// The process-wide default schedule: [`Schedule::Dynamic`] when the
    /// `PALLAS_ASSIST` env knob is set, else [`Schedule::Static`]. Read
    /// once and cached — the knob is a process-level default, not a
    /// per-call switch (per-call control is `Config::dynamic_schedule` and
    /// the explicit `*_sched` entry points).
    pub fn from_env() -> Schedule {
        static ASSIST: OnceLock<bool> = OnceLock::new();
        if *ASSIST.get_or_init(crate::util::env::assist) {
            Schedule::Dynamic
        } else {
            Schedule::Static
        }
    }

    /// The schedule a config selects: the explicit gate wins, else the
    /// process default.
    pub fn for_config(cfg: &Config) -> Schedule {
        if cfg.dynamic_schedule {
            Schedule::Dynamic
        } else {
            Schedule::from_env()
        }
    }
}

/// Oversplit factor for dynamic panel splits: aim for this many panels per
/// executor so the claim loop has slack to balance with. More panels →
/// finer balancing but more claim/dispatch overhead; 4 is the conventional
/// sweet spot for chunk-claiming loops over near-uniform work.
pub const OVERSPLIT: usize = 4;

/// Panel-count goal for a dynamic split with `parts` executors.
pub fn oversplit(parts: usize) -> usize {
    parts.saturating_mul(OVERSPLIT).max(1)
}

/// Slice-count goal for the stage-1/stage-2 graph builders: the config's
/// effective slice count, oversplit when the dynamic gate is on (the graph
/// FIFO then has finer panels to balance with). An explicit `slices`
/// setting is honored as-is — it is a measurement knob, not a hint.
pub fn slice_goal(cfg: &Config) -> usize {
    let base = cfg.effective_slices();
    if cfg.slices == 0 && Schedule::for_config(cfg).is_dynamic() {
        oversplit(base)
    } else {
        base
    }
}

/// A shared claim counter over `total` panels: each [`ClaimCounter::claim`]
/// hands out the next unclaimed index exactly once, across any number of
/// concurrent executors.
///
/// This is the whole scheduler — one `fetch_add` per panel, no locks, no
/// per-executor state. Indices are claimed in ascending order, which keeps
/// the common case (executors racing through a panel list) cache-friendly:
/// adjacent panels go to whoever is free, and a straggler holds up exactly
/// the panel it is computing, never a span.
pub struct ClaimCounter {
    next: AtomicUsize,
    total: usize,
    /// Concurrency-audit shadow (`coordinator::audit`): one flag per
    /// panel, set on hand-out. A second hand-out of the same index —
    /// which would run a panel twice and corrupt the accumulation — trips
    /// an assert with the offending index. `None` when the auditor is
    /// inactive; absent entirely from release builds without the feature.
    #[cfg(any(feature = "audit", debug_assertions))]
    handed: Option<Vec<AtomicBool>>,
}

impl ClaimCounter {
    /// A counter over panel indices `0..total`.
    pub fn new(total: usize) -> ClaimCounter {
        ClaimCounter {
            next: AtomicUsize::new(0),
            total,
            #[cfg(any(feature = "audit", debug_assertions))]
            handed: super::audit::active()
                .then(|| (0..total).map(|_| AtomicBool::new(false)).collect()),
        }
    }

    /// Number of panels this counter hands out.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Claim the next panel index, or `None` once all panels are claimed.
    /// Relaxed ordering suffices: the counter only allocates indices; the
    /// batch's `remaining` counter (with acquire/release) is what
    /// publishes task *effects* to the waiting submitter.
    pub fn claim(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i < self.total {
            #[cfg(any(feature = "audit", debug_assertions))]
            if let Some(handed) = &self.handed {
                assert!(
                    !handed[i].swap(true, Ordering::Relaxed),
                    "concurrency audit failed: claim counter handed out panel index {i} twice"
                );
            }
            Some(i)
        } else {
            None
        }
    }

    /// Cancel all unclaimed panels: subsequent [`ClaimCounter::claim`]
    /// calls return `None`. In-flight panels are unaffected. (`fetch_max`,
    /// not `store`: a racing `claim` may have pushed `next` past `total`
    /// already, and winding it back would hand indices out twice.)
    pub fn cancel(&self) {
        self.next.fetch_max(self.total, Ordering::Relaxed);
    }

    /// Whether every panel has been claimed (claimed ≠ completed: panels
    /// may still be running on other executors).
    pub fn drained(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.total
    }
}

/// The work-assisting loop: claim panels until the counter drains, running
/// `body` on each claimed index. The batch scheduler inlines a variant of
/// this (with panic poisoning); this standalone form is for direct use and
/// for tests.
pub fn assist_loop(counter: &ClaimCounter, mut body: impl FnMut(usize)) {
    while let Some(i) = counter.claim() {
        body(i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn claims_each_index_exactly_once_single_thread() {
        let c = ClaimCounter::new(5);
        let mut got = Vec::new();
        assist_loop(&c, |i| got.push(i));
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert!(c.drained());
        assert_eq!(c.claim(), None, "exhausted counter stays exhausted");
    }

    #[test]
    fn zero_panels_is_immediately_exhausted() {
        let c = ClaimCounter::new(0);
        assert!(c.drained());
        assert_eq!(c.claim(), None);
        let mut ran = false;
        assist_loop(&c, |_| ran = true);
        assert!(!ran, "no body call for an empty counter");
    }

    #[test]
    fn one_panel_goes_to_exactly_one_claimer() {
        let c = ClaimCounter::new(1);
        assert_eq!(c.claim(), Some(0));
        assert_eq!(c.claim(), None);
        assert_eq!(c.claim(), None);
    }

    #[test]
    fn more_workers_than_panels_exhausts_cleanly() {
        // 7 workers race over 3 panels: every panel claimed exactly once,
        // the surplus workers observe exhaustion and do nothing.
        let c = ClaimCounter::new(3);
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|s| {
            for _ in 0..7 {
                s.spawn(|| {
                    assist_loop(&c, |i| {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    })
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
        assert!(c.drained());
    }

    #[test]
    fn concurrent_claims_partition_the_index_space() {
        // Heavier race: claims across threads must partition 0..N with no
        // duplicate and no gap.
        const N: usize = 997; // prime, so no thread-count divides it
        let c = ClaimCounter::new(N);
        let hits: Vec<AtomicUsize> = (0..N).map(|_| AtomicUsize::new(0)).collect();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    assist_loop(&c, |i| {
                        hits[i].fetch_add(1, Ordering::SeqCst);
                    })
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn cancel_stops_further_claims() {
        let c = ClaimCounter::new(100);
        assert_eq!(c.claim(), Some(0));
        c.cancel();
        assert_eq!(c.claim(), None);
        assert!(c.drained());
        // Cancel is idempotent and never winds the counter back.
        c.cancel();
        assert_eq!(c.claim(), None);
    }

    #[test]
    fn oversplit_scales_and_never_returns_zero() {
        assert_eq!(oversplit(0), 1);
        assert_eq!(oversplit(1), OVERSPLIT);
        assert_eq!(oversplit(4), 4 * OVERSPLIT);
        assert_eq!(oversplit(usize::MAX), usize::MAX, "saturates, no overflow");
    }

    #[test]
    fn schedule_selection_honors_the_config_gate() {
        let off = Config::default();
        assert!(!off.dynamic_schedule, "gate must default off");
        let on = Config { dynamic_schedule: true, ..Config::default() };
        assert_eq!(Schedule::for_config(&on), Schedule::Dynamic);
        assert!(Schedule::Dynamic.is_dynamic());
        assert!(!Schedule::Static.is_dynamic());
    }

    #[test]
    fn slice_goal_oversplits_only_under_the_gate_with_auto_slices() {
        let base = Config { threads: 4, ..Config::default() };
        assert_eq!(slice_goal(&base), base.effective_slices());
        let dynamic = Config { threads: 4, dynamic_schedule: true, ..Config::default() };
        assert_eq!(slice_goal(&dynamic), oversplit(dynamic.effective_slices()));
        // Explicit slice counts are a measurement knob: honored verbatim.
        let pinned = Config { slices: 8, dynamic_schedule: true, ..Config::default() };
        assert_eq!(slice_goal(&pinned), 8);
    }
}
