//! Data-access declarations for dataflow dependency analysis.
//!
//! Every task declares the matrix regions it reads and writes; the graph
//! builder derives edges from conflicting accesses (RAW, WAR, WAW) in
//! submission order. This generalizes the hand-drawn dependency graphs of
//! the paper (Figs. 2 and 7): the panel pipelining of stage 1 and the
//! lookahead of stage 2 emerge from the declared regions instead of being
//! wired by hand.

use std::ops::Range;

/// Identifies one of the shared matrices of a reduction.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum MatId {
    /// The pencil's `A` (becomes `H`).
    A,
    /// The pencil's `B` (becomes `T`).
    B,
    /// Left orthogonal accumulator.
    Q,
    /// Right orthogonal accumulator.
    Z,
    /// Side-channel slot storage (reflector handoff between tasks).
    Slots,
}

/// A rectangular region of a matrix.
#[derive(Clone, Debug)]
pub struct Region {
    /// Which matrix.
    pub mat: MatId,
    /// Row range (half-open).
    pub rows: Range<usize>,
    /// Column range (half-open).
    pub cols: Range<usize>,
}

impl Region {
    /// Convenience constructor.
    pub fn new(mat: MatId, rows: Range<usize>, cols: Range<usize>) -> Region {
        Region { mat, rows, cols }
    }

    /// Whether the region is empty (describes no matrix elements).
    ///
    /// Zero-width ranges (`k..k`) are empty wherever they sit — including
    /// at matrix boundaries (`0..0`, `n..n`) — and reversed ranges
    /// (`hi..lo`) count as empty too rather than as a huge span, so a
    /// builder clamping `end` below `start` degrades to "no access", not
    /// to a spurious conflict.
    pub fn is_empty(&self) -> bool {
        self.rows.start >= self.rows.end || self.cols.start >= self.cols.end
    }

    /// Whether two regions overlap (same matrix, intersecting rectangles).
    ///
    /// Empty regions intersect nothing — without the explicit guards, a
    /// zero-width range sitting strictly inside another region's span
    /// (e.g. `5..5` vs `0..10`) would satisfy the half-open interval
    /// comparisons and report a phantom overlap. Symmetric by
    /// construction: `a.intersects(&b) == b.intersects(&a)`.
    pub fn intersects(&self, other: &Region) -> bool {
        self.mat == other.mat
            && !self.is_empty()
            && !other.is_empty()
            && self.rows.start < other.rows.end
            && other.rows.start < self.rows.end
            && self.cols.start < other.cols.end
            && other.cols.start < self.cols.end
    }

    /// Whether `other` lies entirely inside this region.
    ///
    /// An empty `other` is vacuously contained (it touches no elements);
    /// a non-empty `other` needs the same matrix and both of its ranges
    /// inside this region's ranges. An empty `self` therefore contains
    /// only empty regions.
    pub fn contains(&self, other: &Region) -> bool {
        other.is_empty()
            || (self.mat == other.mat
                && self.rows.start <= other.rows.start
                && other.rows.end <= self.rows.end
                && self.cols.start <= other.cols.start
                && other.cols.end <= self.cols.end)
    }
}

/// A declared access: region + read/write mode.
#[derive(Clone, Debug)]
pub struct Access {
    /// The region touched.
    pub region: Region,
    /// True for writes (exclusive), false for reads (shared).
    pub write: bool,
}

impl Access {
    /// Declare a read.
    pub fn read(mat: MatId, rows: Range<usize>, cols: Range<usize>) -> Access {
        Access { region: Region::new(mat, rows, cols), write: false }
    }

    /// Declare a write.
    pub fn write(mat: MatId, rows: Range<usize>, cols: Range<usize>) -> Access {
        Access { region: Region::new(mat, rows, cols), write: true }
    }

    /// Whether two accesses conflict (overlap and at least one writes).
    pub fn conflicts(&self, other: &Access) -> bool {
        (self.write || other.write) && self.region.intersects(&other.region)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intersection_logic() {
        let a = Region::new(MatId::A, 0..5, 0..5);
        let b = Region::new(MatId::A, 4..9, 4..9);
        let c = Region::new(MatId::A, 5..9, 0..5);
        let d = Region::new(MatId::B, 0..5, 0..5);
        assert!(a.intersects(&b));
        assert!(!a.intersects(&c)); // touching edge, half-open
        assert!(!a.intersects(&d)); // different matrix
        assert!(!Region::new(MatId::A, 3..3, 0..5).intersects(&a)); // empty
    }

    #[test]
    fn zero_width_ranges_at_boundaries_are_empty_and_inert() {
        let full = Region::new(MatId::A, 0..10, 0..10);
        for r in [0..0, 5..5, 10..10, 7..3] {
            let z = Region::new(MatId::A, r.clone(), 0..10);
            assert!(z.is_empty(), "{r:?} must be empty");
            assert!(!z.intersects(&full) && !full.intersects(&z));
            assert!(full.contains(&z), "empty regions are vacuously contained");
        }
    }

    #[test]
    fn containment_semantics() {
        let outer = Region::new(MatId::A, 2..8, 1..9);
        assert!(outer.contains(&Region::new(MatId::A, 2..8, 1..9)), "self");
        assert!(outer.contains(&Region::new(MatId::A, 3..7, 4..5)), "strict inner");
        assert!(!outer.contains(&Region::new(MatId::A, 1..8, 1..9)), "row overhang");
        assert!(!outer.contains(&Region::new(MatId::A, 2..8, 1..10)), "col overhang");
        assert!(!outer.contains(&Region::new(MatId::B, 3..7, 4..5)), "wrong matrix");
        let empty = Region::new(MatId::A, 4..4, 4..4);
        assert!(!empty.contains(&Region::new(MatId::A, 4..5, 4..5)), "empty holds nothing");
        assert!(empty.contains(&Region::new(MatId::B, 9..9, 0..3)), "empty in empty, vacuous");
    }

    #[test]
    fn conflict_rules() {
        let r1 = Access::read(MatId::A, 0..5, 0..5);
        let r2 = Access::read(MatId::A, 0..5, 0..5);
        let w1 = Access::write(MatId::A, 2..3, 2..3);
        let w2 = Access::write(MatId::A, 7..9, 7..9);
        assert!(!r1.conflicts(&r2), "read-read never conflicts");
        assert!(r1.conflicts(&w1), "read-write conflicts");
        assert!(w1.conflicts(&r1));
        assert!(!w1.conflicts(&w2), "disjoint writes fine");
    }
}
