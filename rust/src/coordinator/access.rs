//! Data-access declarations for dataflow dependency analysis.
//!
//! Every task declares the matrix regions it reads and writes; the graph
//! builder derives edges from conflicting accesses (RAW, WAR, WAW) in
//! submission order. This generalizes the hand-drawn dependency graphs of
//! the paper (Figs. 2 and 7): the panel pipelining of stage 1 and the
//! lookahead of stage 2 emerge from the declared regions instead of being
//! wired by hand.

use std::ops::Range;

/// Identifies one of the shared matrices of a reduction.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum MatId {
    /// The pencil's `A` (becomes `H`).
    A,
    /// The pencil's `B` (becomes `T`).
    B,
    /// Left orthogonal accumulator.
    Q,
    /// Right orthogonal accumulator.
    Z,
    /// Side-channel slot storage (reflector handoff between tasks).
    Slots,
}

/// A rectangular region of a matrix.
#[derive(Clone, Debug)]
pub struct Region {
    /// Which matrix.
    pub mat: MatId,
    /// Row range (half-open).
    pub rows: Range<usize>,
    /// Column range (half-open).
    pub cols: Range<usize>,
}

impl Region {
    /// Convenience constructor.
    pub fn new(mat: MatId, rows: Range<usize>, cols: Range<usize>) -> Region {
        Region { mat, rows, cols }
    }

    /// Whether the region is empty.
    pub fn is_empty(&self) -> bool {
        self.rows.start >= self.rows.end || self.cols.start >= self.cols.end
    }

    /// Whether two regions overlap (same matrix, intersecting rectangles).
    pub fn intersects(&self, other: &Region) -> bool {
        self.mat == other.mat
            && !self.is_empty()
            && !other.is_empty()
            && self.rows.start < other.rows.end
            && other.rows.start < self.rows.end
            && self.cols.start < other.cols.end
            && other.cols.start < self.cols.end
    }
}

/// A declared access: region + read/write mode.
#[derive(Clone, Debug)]
pub struct Access {
    /// The region touched.
    pub region: Region,
    /// True for writes (exclusive), false for reads (shared).
    pub write: bool,
}

impl Access {
    /// Declare a read.
    pub fn read(mat: MatId, rows: Range<usize>, cols: Range<usize>) -> Access {
        Access { region: Region::new(mat, rows, cols), write: false }
    }

    /// Declare a write.
    pub fn write(mat: MatId, rows: Range<usize>, cols: Range<usize>) -> Access {
        Access { region: Region::new(mat, rows, cols), write: true }
    }

    /// Whether two accesses conflict (overlap and at least one writes).
    pub fn conflicts(&self, other: &Access) -> bool {
        (self.write || other.write) && self.region.intersects(&other.region)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intersection_logic() {
        let a = Region::new(MatId::A, 0..5, 0..5);
        let b = Region::new(MatId::A, 4..9, 4..9);
        let c = Region::new(MatId::A, 5..9, 0..5);
        let d = Region::new(MatId::B, 0..5, 0..5);
        assert!(a.intersects(&b));
        assert!(!a.intersects(&c)); // touching edge, half-open
        assert!(!a.intersects(&d)); // different matrix
        assert!(!Region::new(MatId::A, 3..3, 0..5).intersects(&a)); // empty
    }

    #[test]
    fn conflict_rules() {
        let r1 = Access::read(MatId::A, 0..5, 0..5);
        let r2 = Access::read(MatId::A, 0..5, 0..5);
        let w1 = Access::write(MatId::A, 2..3, 2..3);
        let w2 = Access::write(MatId::A, 7..9, 7..9);
        assert!(!r1.conflicts(&r2), "read-read never conflicts");
        assert!(r1.conflicts(&w1), "read-write conflicts");
        assert!(w1.conflicts(&r1));
        assert!(!w1.conflicts(&w2), "disjoint writes fine");
    }
}
