//! Concurrency audit: a shadow access tracker for the unsafe scheduler
//! core.
//!
//! The soundness of [`super::slices::SharedMat`] rests on a pencil-and-
//! paper argument: every task's `unsafe { view(...) }` rectangles stay
//! inside its *declared* [`Region`]s, and the dataflow edges derived from
//! those declarations order every pair of conflicting tasks (the
//! generalized `split_at_mut` argument, see ARCHITECTURE.md §"Auditing the
//! unsafe core"). Nothing in the type system checks either half; an
//! off-by-one range in a hand-written view is silent UB. This module turns
//! both halves into enforced, runtime-checked contracts:
//!
//! * **Containment** — every actual view rectangle, recorded at
//!   [`SharedMat::view`](super::slices::SharedMat::view) /
//!   [`view_ref`](super::slices::SharedMat::view_ref) time together with
//!   the issuing task id and mutability, must lie inside one of that
//!   task's declared regions (a mutable view needs a declared *write*
//!   region).
//! * **Disjointness / happens-before** — for any two recorded accesses to
//!   overlapping rectangles with at least one write, the issuing tasks
//!   must be ordered by a dependency path (reachability is precomputed
//!   from the graph's edges as a transitive-closure bitset). A dropped
//!   edge — including one dropped by the epoch-window optimization in
//!   [`super::graph::TaskGraph::new_epoch`] — is reported as a *named
//!   race* ("task X writes A[..], task Y reads A[..], no path X → Y")
//!   instead of a nondeterministic wrong answer.
//!
//! **Activation.** The module is compiled under
//! `cfg(any(feature = "audit", debug_assertions))` and is entirely absent
//! from release builds without the feature (the hooks in `slices.rs` /
//! `pool.rs` / `graph.rs` compile to nothing — zero overhead). When
//! compiled, the runtime gate [`active`] resolves, in order: a
//! programmatic [`set_override`] (used by the negative tests), the
//! `PALLAS_AUDIT` env knob ([`crate::util::env::audit`]), and finally the
//! build default — **on** when the `audit` feature is enabled, **off** in
//! plain debug builds (so `PALLAS_AUDIT=1` opts a dev build in, and
//! `PALLAS_AUDIT=0` can silence an `--features audit` build).
//!
//! **Granularity caveat.** Tasks that legitimately operate through
//! full-matrix views (the stage-2 generate phase hands `generate_group` a
//! whole-matrix `MatMut` and lets the *algorithm* stay inside its band)
//! use [`SharedMat::view_full`](super::slices::SharedMat::view_full),
//! which records the task's *declared* rectangles instead of the
//! full-matrix rectangle. Those tasks are audited at declaration
//! granularity: the race check still covers them (their declarations are
//! what the edges were derived from), but containment is trusted rather
//! than measured. Untagged `SharedMat`s (constructed with
//! [`SharedMat::new`](super::slices::SharedMat::new)) are invisible to the
//! auditor entirely.

use super::access::{Access, MatId, Region};
use super::graph::{TaskClass, TaskGraph};
use std::cell::RefCell;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicI8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ---------------------------------------------------------------------
// Activation gate
// ---------------------------------------------------------------------

/// Tri-state programmatic override: 0 = defer to env/build default,
/// 1 = forced on, -1 = forced off.
static OVERRIDE: AtomicI8 = AtomicI8::new(0);

/// Total accesses recorded process-wide (all scopes). Lets tests assert
/// the hooks actually fired (e.g. the audit-on parity run in
/// `tests/equivalence.rs` proves it audited *something*).
static RECORDED_TOTAL: AtomicUsize = AtomicUsize::new(0);

/// Force the auditor on/off programmatically, or restore the default
/// resolution with `None`. Process-global; intended for tests (the
/// negative tests force it on regardless of features and environment).
pub fn set_override(on: Option<bool>) {
    let v = match on {
        Some(true) => 1,
        Some(false) => -1,
        None => 0,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// Whether the auditor is active: [`set_override`] wins, then the
/// `PALLAS_AUDIT` env knob (read once), then the build default (`true`
/// under `--features audit`, `false` in plain debug builds).
pub fn active() -> bool {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => return true,
        -1 => return false,
        _ => {}
    }
    static DEFAULT: OnceLock<bool> = OnceLock::new();
    *DEFAULT.get_or_init(|| crate::util::env::audit().unwrap_or(cfg!(feature = "audit")))
}

/// Process-wide count of recorded view accesses (monotone; test aid).
pub fn recorded_total() -> usize {
    RECORDED_TOTAL.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------
// Reachability (transitive closure over the dependency edges)
// ---------------------------------------------------------------------

/// Row-per-task reachability bitset: bit `x` of row `y` says "there is a
/// dependency path x → y". Built in one topological pass (submission
/// order *is* topological: every dep id is smaller than its task's id),
/// `row[id] = bit(d) | row[d]` over the direct deps `d`.
struct Reach {
    words: Vec<u64>,
    stride: usize,
}

impl Reach {
    fn build(deps: &[Vec<usize>]) -> Reach {
        let t = deps.len();
        let stride = t.div_ceil(64);
        let mut words = vec![0u64; t * stride];
        for id in 0..t {
            for &d in &deps[id] {
                debug_assert!(d < id, "graph edges must point backwards in submission order");
                let (lo_d, lo_id) = (d * stride, id * stride);
                for w in 0..stride {
                    let v = words[lo_d + w];
                    words[lo_id + w] |= v;
                }
                words[lo_id + d / 64] |= 1u64 << (d % 64);
            }
        }
        Reach { words, stride }
    }

    /// Whether a dependency path `x → y` exists (`x` strictly before `y`).
    fn ordered(&self, x: usize, y: usize) -> bool {
        (self.words[y * self.stride + x / 64] >> (x % 64)) & 1 == 1
    }
}

// ---------------------------------------------------------------------
// Scope: one audited graph run
// ---------------------------------------------------------------------

/// Per-task metadata snapshot (taken at scope build, before executors
/// consume the graph).
struct TaskMeta {
    class: TaskClass,
    accesses: Vec<Access>,
}

/// One recorded actual access.
struct Rec {
    task: usize,
    write: bool,
    region: Region,
}

#[derive(Default)]
struct ScopeState {
    recorded: Vec<Rec>,
    violations: Vec<String>,
}

/// Shadow tracker for one graph execution: declared accesses + edge
/// reachability, plus the mutex-guarded log of actual view rectangles.
/// Shared (`Arc`) between the submitting thread and every helper; checked
/// once at end of run by [`AuditScope::check`].
pub struct AuditScope {
    tasks: Vec<TaskMeta>,
    reach: Reach,
    state: Mutex<ScopeState>,
}

/// Build the audit scope for a graph run, or `None` when the auditor is
/// inactive or the graph carries no declared accesses (degenerate
/// data-parallel batches — nothing to check against).
pub fn scope_for(graph: &TaskGraph<'_>) -> Option<Arc<AuditScope>> {
    if !active() || graph.tasks.iter().all(|t| t.accesses.is_empty()) {
        return None;
    }
    Some(AuditScope::build(graph))
}

/// Cap on individually formatted violations per scope — a systematically
/// broken graph would otherwise produce megabytes of diagnostics.
const MAX_REPORTED: usize = 24;

impl AuditScope {
    /// Snapshot the graph's declared accesses and dependency reachability.
    /// Unconditional (ignores [`active`]) so tests can drive the scope
    /// directly.
    pub fn build(graph: &TaskGraph<'_>) -> Arc<AuditScope> {
        let deps: Vec<Vec<usize>> = graph.tasks.iter().map(|t| t.deps.clone()).collect();
        let tasks = graph
            .tasks
            .iter()
            .map(|t| TaskMeta { class: t.class, accesses: t.accesses.clone() })
            .collect();
        Arc::new(AuditScope { tasks, reach: Reach::build(&deps), state: Mutex::new(ScopeState::default()) })
    }

    /// Record one actual view rectangle for `task`, checking containment
    /// against the task's declarations immediately. Empty rectangles are
    /// ignored (they touch no element).
    fn record(&self, task: usize, mat: MatId, rows: Range<usize>, cols: Range<usize>, write: bool) {
        let region = Region::new(mat, rows, cols);
        if region.is_empty() {
            return;
        }
        RECORDED_TOTAL.fetch_add(1, Ordering::Relaxed);
        let meta = &self.tasks[task];
        // A mutable view needs a declared *write* region around it; an
        // immutable view may sit inside any declared region (reading your
        // own exclusive write region is fine).
        let contained =
            meta.accesses.iter().any(|a| (a.write || !write) && a.region.contains(&region));
        let mut st = self.state.lock().unwrap();
        if !contained {
            st.violations.push(format!(
                "containment: task {task} ({:?}) {} {} outside every declared {}region: [{}]",
                meta.class,
                verb(write),
                rect(&region),
                if write { "write " } else { "" },
                meta.accesses
                    .iter()
                    .filter(|a| a.write || !write)
                    .map(|a| rect(&a.region))
                    .collect::<Vec<_>>()
                    .join(", "),
            ));
        }
        st.recorded.push(Rec { task, write, region });
    }

    /// Record a full-matrix view at declaration granularity: every
    /// declared region of `task` on `mat` enters the log with its declared
    /// mutability (see the module docs' granularity caveat).
    fn record_declared(&self, task: usize, mat: MatId) {
        let regions: Vec<(Region, bool)> = self.tasks[task]
            .accesses
            .iter()
            .filter(|a| a.region.mat == mat && !a.region.is_empty())
            .map(|a| (a.region.clone(), a.write))
            .collect();
        if regions.is_empty() {
            return;
        }
        RECORDED_TOTAL.fetch_add(regions.len(), Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        for (region, write) in regions {
            st.recorded.push(Rec { task, write, region });
        }
    }

    /// End-of-run check: pairwise race scan over the recorded accesses
    /// (same matrix, overlapping rectangles, at least one write, different
    /// tasks ⇒ a dependency path must order them). Panics with the full
    /// diagnostic report if any violation — containment or race — was
    /// found. Runs on the submitting thread after the batch drained.
    pub fn check(&self) {
        let (recorded, mut violations) = {
            let mut st = self.state.lock().unwrap();
            (std::mem::take(&mut st.recorded), std::mem::take(&mut st.violations))
        };
        // Bucket by matrix so the quadratic scan never crosses matrices.
        let mut by_mat: HashMap<MatId, Vec<usize>> = HashMap::new();
        for (i, r) in recorded.iter().enumerate() {
            by_mat.entry(r.region.mat).or_default().push(i);
        }
        // One report per unordered task pair (two sliced tasks can overlap
        // in many recorded rectangles; one diagnostic is enough).
        let mut reported: Vec<(usize, usize)> = Vec::new();
        for idxs in by_mat.values() {
            for (pos, &i) in idxs.iter().enumerate() {
                for &j in &idxs[pos + 1..] {
                    let (x, y) = (&recorded[i], &recorded[j]);
                    if x.task == y.task || (!x.write && !y.write) {
                        continue;
                    }
                    if !x.region.intersects(&y.region) {
                        continue;
                    }
                    // Edges point backwards in submission order, so the
                    // only possible path runs lower-id → higher-id.
                    let ((first, second), (lo, hi)) = if x.task < y.task {
                        ((x, y), (x.task, y.task))
                    } else {
                        ((y, x), (y.task, x.task))
                    };
                    if self.reach.ordered(lo, hi) || reported.contains(&(lo, hi)) {
                        continue;
                    }
                    reported.push((lo, hi));
                    violations.push(format!(
                        "race: task {} ({:?}) {} {}, task {} ({:?}) {} {}, no path {} → {}",
                        first.task,
                        self.tasks[first.task].class,
                        verb(first.write),
                        rect(&first.region),
                        second.task,
                        self.tasks[second.task].class,
                        verb(second.write),
                        rect(&second.region),
                        lo,
                        hi,
                    ));
                }
            }
        }
        if violations.is_empty() {
            return;
        }
        let total = violations.len();
        if total > MAX_REPORTED {
            violations.truncate(MAX_REPORTED);
            violations.push(format!("... and {} more", total - MAX_REPORTED));
        }
        panic!("concurrency audit failed: {total} violation(s)\n  {}", violations.join("\n  "));
    }
}

/// Run a scope's end-of-run check, if one was built (convenience for the
/// executors' tail position).
pub fn check_scope(scope: Option<Arc<AuditScope>>) {
    if let Some(s) = scope {
        s.check();
    }
}

fn verb(write: bool) -> &'static str {
    if write {
        "writes"
    } else {
        "reads"
    }
}

fn rect(r: &Region) -> String {
    format!("{:?}[{}..{}, {}..{}]", r.mat, r.rows.start, r.rows.end, r.cols.start, r.cols.end)
}

// ---------------------------------------------------------------------
// Task context (thread-local) + view hooks
// ---------------------------------------------------------------------

thread_local! {
    /// The (scope, task id) the current thread is executing for, if any.
    static CTX: RefCell<Option<(Arc<AuditScope>, usize)>> = const { RefCell::new(None) };
}

/// RAII guard from [`enter_task`]: restores the previous context on drop,
/// so nested submission (a task running an inner data-parallel batch)
/// attributes inner views to the inner context — or to nothing — and the
/// outer task's attribution resumes afterwards.
pub struct TaskGuard {
    prev: Option<(Arc<AuditScope>, usize)>,
}

/// Set the current thread's audit context to (`scope`, `task`) for the
/// duration of the returned guard. With `scope == None` the context is
/// cleared (views in unaudited batches attribute to nothing).
pub fn enter_task(scope: Option<&Arc<AuditScope>>, task: usize) -> TaskGuard {
    let next = scope.map(|s| (s.clone(), task));
    TaskGuard { prev: CTX.with(|c| c.replace(next)) }
}

impl Drop for TaskGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CTX.with(|c| *c.borrow_mut() = prev);
    }
}

/// View hook called by `SharedMat::view` / `view_ref`: records the
/// rectangle against the current thread's task context. No-op for
/// untagged matrices (`mat == None`) or outside any audited task.
pub fn on_view(mat: Option<MatId>, rows: &Range<usize>, cols: &Range<usize>, write: bool) {
    let Some(mat) = mat else { return };
    CTX.with(|c| {
        if let Some((scope, task)) = c.borrow().as_ref() {
            scope.record(*task, mat, rows.clone(), cols.clone(), write);
        }
    });
}

/// Full-view hook called by `SharedMat::view_full`: records the current
/// task's *declared* rectangles on `mat` (declaration granularity — see
/// the module docs).
pub fn on_view_full(mat: Option<MatId>) {
    let Some(mat) = mat else { return };
    CTX.with(|c| {
        if let Some((scope, task)) = c.borrow().as_ref() {
            scope.record_declared(*task, mat);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
        payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".into())
    }

    /// Graph: t0 → t1 (conflict edge), t2 disjoint. Used by several tests.
    fn diamondish() -> TaskGraph<'static> {
        let mut g = TaskGraph::new();
        g.add(TaskClass::GL, vec![Access::write(MatId::A, 0..4, 0..4)], || {});
        g.add(TaskClass::LA, vec![Access::read(MatId::A, 0..4, 0..4)], || {});
        g.add(TaskClass::LB, vec![Access::write(MatId::B, 0..4, 0..4)], || {});
        g.finalize();
        g
    }

    #[test]
    fn reachability_closure_is_transitive() {
        let deps = vec![vec![], vec![0], vec![1], vec![]];
        let r = Reach::build(&deps);
        assert!(r.ordered(0, 1));
        assert!(r.ordered(1, 2));
        assert!(r.ordered(0, 2), "transitive path 0 → 1 → 2");
        assert!(!r.ordered(0, 3));
        assert!(!r.ordered(2, 1), "reachability is directional");
    }

    #[test]
    fn reachability_scales_past_one_word() {
        // > 64 tasks forces stride > 1: a linear chain must stay fully
        // ordered end to end.
        let n = 150;
        let deps: Vec<Vec<usize>> = (0..n).map(|i| if i == 0 { vec![] } else { vec![i - 1] }).collect();
        let r = Reach::build(&deps);
        assert!(r.ordered(0, n - 1));
        assert!(r.ordered(63, 64), "word boundary");
        assert!(r.ordered(64, 65));
        assert!(!r.ordered(n - 1, 0));
    }

    #[test]
    fn contained_views_pass() {
        let g = diamondish();
        let scope = AuditScope::build(&g);
        scope.record(0, MatId::A, 1..3, 1..3, true);
        scope.record(1, MatId::A, 0..4, 0..4, false);
        scope.check(); // ordered pair (edge 0 → 1): no panic
    }

    #[test]
    fn write_view_requires_declared_write_region() {
        let g = diamondish();
        let scope = AuditScope::build(&g);
        // Task 1 only declared a *read* of A; a mutable view is a
        // containment violation even though the rectangle matches.
        scope.record(1, MatId::A, 0..4, 0..4, true);
        let err = catch_unwind(AssertUnwindSafe(|| scope.check())).unwrap_err();
        let msg = panic_message(err);
        assert!(msg.contains("containment"), "{msg}");
        assert!(msg.contains("task 1"), "{msg}");
        assert!(msg.contains("A[0..4, 0..4]"), "{msg}");
    }

    #[test]
    fn out_of_bounds_view_is_reported_with_rect() {
        let g = diamondish();
        let scope = AuditScope::build(&g);
        scope.record(0, MatId::A, 0..5, 0..4, true); // one row too far
        let err = catch_unwind(AssertUnwindSafe(|| scope.check())).unwrap_err();
        let msg = panic_message(err);
        assert!(msg.contains("A[0..5, 0..4]"), "{msg}");
        assert!(msg.contains("GL"), "names the task class: {msg}");
    }

    #[test]
    fn unordered_overlapping_writes_are_a_named_race() {
        // Two tasks, disjoint *declarations* (so no edge), but actual
        // views that overlap: the race scan must name both tasks.
        let mut g = TaskGraph::new();
        g.add(TaskClass::Upd2, vec![Access::write(MatId::A, 0..2, 0..8)], || {});
        g.add(TaskClass::Upd2, vec![Access::write(MatId::A, 4..6, 0..8)], || {});
        g.finalize();
        let scope = AuditScope::build(&g);
        scope.record(0, MatId::A, 0..2, 0..8, true);
        scope.record(1, MatId::A, 1..2, 0..8, true); // overlaps task 0
        let err = catch_unwind(AssertUnwindSafe(|| scope.check())).unwrap_err();
        let msg = panic_message(err);
        assert!(msg.contains("race"), "{msg}");
        assert!(msg.contains("no path 0 → 1"), "{msg}");
        // The containment breach (task 1's view outside its declaration)
        // is reported too.
        assert!(msg.contains("containment"), "{msg}");
    }

    #[test]
    fn read_read_overlap_is_not_a_race() {
        let mut g = TaskGraph::new();
        g.add(TaskClass::LA, vec![Access::read(MatId::A, 0..4, 0..4)], || {});
        g.add(TaskClass::LB, vec![Access::read(MatId::A, 0..4, 0..4)], || {});
        g.finalize();
        let scope = AuditScope::build(&g);
        scope.record(0, MatId::A, 0..4, 0..4, false);
        scope.record(1, MatId::A, 0..4, 0..4, false);
        scope.check(); // reads never race
    }

    #[test]
    fn empty_views_are_ignored() {
        let g = diamondish();
        let scope = AuditScope::build(&g);
        let before = recorded_total();
        scope.record(2, MatId::A, 3..3, 0..4, true); // empty: outside declarations, wrong mat — all moot
        scope.record(2, MatId::A, 0..4, 2..2, true);
        assert_eq!(recorded_total(), before, "empty rectangles are not recorded");
        scope.check();
    }

    #[test]
    fn full_view_records_declared_regions() {
        let g = diamondish();
        let scope = AuditScope::build(&g);
        scope.record_declared(0, MatId::A);
        scope.record_declared(0, MatId::B); // t0 declared nothing on B: no-op
        let st = scope.state.lock().unwrap();
        assert_eq!(st.recorded.len(), 1);
        assert!(st.recorded[0].write);
        assert_eq!(st.recorded[0].region.rows, 0..4);
        drop(st);
        scope.check();
    }

    #[test]
    fn task_context_nests_and_restores() {
        let g = diamondish();
        let scope = AuditScope::build(&g);
        let outer = enter_task(Some(&scope), 0);
        on_view(Some(MatId::A), &(0..2), &(0..2), true);
        {
            // Inner unaudited batch: context cleared, views unattributed.
            let _inner = enter_task(None, 7);
            on_view(Some(MatId::A), &(0..999), &(0..999), true);
        }
        // Restored: this one attributes to task 0 again.
        on_view(Some(MatId::A), &(2..4), &(2..4), true);
        drop(outer);
        on_view(Some(MatId::A), &(0..999), &(0..999), true); // no context: dropped
        let st = scope.state.lock().unwrap();
        assert_eq!(st.recorded.len(), 2, "only the two in-context views recorded");
        drop(st);
        scope.check();
    }

    // NOTE: `scope_for`'s activation gating (and the override) is
    // exercised in `tests/audit.rs`, which owns its process — flipping the
    // global override here would race the other lib tests' graph runs.

    #[test]
    fn report_is_capped() {
        let mut g = TaskGraph::new();
        g.add(TaskClass::GL, vec![Access::write(MatId::A, 0..1, 0..1)], || {});
        g.finalize();
        let scope = AuditScope::build(&g);
        for i in 0..(MAX_REPORTED + 10) {
            scope.record(0, MatId::A, i + 1..i + 2, 0..1, true); // all outside the declaration
        }
        let err = catch_unwind(AssertUnwindSafe(|| scope.check())).unwrap_err();
        let msg = panic_message(err);
        assert!(msg.contains("and 10 more"), "{msg}");
    }
}
