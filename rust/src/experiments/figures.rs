//! Regeneration of every figure in the paper's §4 evaluation.
//!
//! Absolute times come from *this* substrate (from-scratch GEMM, one
//! measured core, simulated P workers — DESIGN.md §5); every reported
//! number is a *relative* quantity exactly like the paper's plots, so the
//! comparison is curve shape: who wins, by what factor, where crossovers
//! fall.

use super::common::*;
use crate::coordinator::driver::{
    dgghd3_recorded, househt_recorded, iterht_recorded, lapack_seq_time, recorder_curve,
};
use crate::linalg::matrix::Matrix;
use crate::pencil::random::random_pencil;
use crate::pencil::saddle::saddle_pencil;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// One algorithm's speedup-vs-threads series (Fig. 9a).
#[derive(Clone, Debug)]
pub struct ThreadSeries {
    /// Algorithm name.
    pub name: &'static str,
    /// `(threads, speedup over sequential LAPACK)` points; NaN = failed.
    pub points: Vec<(usize, f64)>,
}

/// Fig. 9a: parallel speedup (vs sequential LAPACK) for a random pencil,
/// as a function of the number of threads.
pub fn fig9a(n: usize, seed: u64) -> Vec<ThreadSeries> {
    let mut rng = Rng::new(seed);
    let pencil = random_pencil(n, &mut rng);
    let cfg = scaled_config(n);
    let t_lapack = lapack_seq_time(&pencil.a, &pencil.b);
    let ps = PAPER_THREADS;

    let mut out = Vec::new();

    // ParaHT: real task-DAG simulation.
    let (curve, _, _) = paraht_speedup_curve(&pencil, &cfg, ps);
    out.push(ThreadSeries {
        name: "ParaHT",
        points: curve.points.iter().map(|&(p, t)| (p, t_lapack / t)).collect(),
    });

    // DGGHD3 with parallel BLAS (barrier model).
    let rec = dgghd3_recorded(&pencil.a, &pencil.b);
    let c = recorder_curve("DGGHD3", &rec, ps, 32);
    out.push(ThreadSeries {
        name: "DGGHD3",
        points: c.points.iter().map(|&(p, t)| (p, t_lapack / t)).collect(),
    });

    // HouseHT / IterHT, capped at 14 threads like the paper.
    let rec = househt_recorded(&pencil.a, &pencil.b);
    let c = recorder_curve("HouseHT", &rec, ps, 32);
    out.push(ThreadSeries {
        name: "HouseHT",
        points: c
            .points
            .iter()
            .map(|&(p, t)| (p, t_lapack / if p > COMPARATOR_CAP { c.points.iter().find(|x| x.0 == COMPARATOR_CAP).map(|x| x.1).unwrap_or(t) } else { t }))
            .collect(),
    });

    match iterht_recorded(&pencil.a, &pencil.b) {
        Ok((rec, _iters)) => {
            let c = recorder_curve("IterHT", &rec, ps, 32);
            out.push(ThreadSeries {
                name: "IterHT",
                points: c
                    .points
                    .iter()
                    .map(|&(p, t)| (p, t_lapack / if p > COMPARATOR_CAP { c.points.iter().find(|x| x.0 == COMPARATOR_CAP).map(|x| x.1).unwrap_or(t) } else { t }))
                    .collect(),
            });
        }
        Err(_) => out.push(ThreadSeries {
            name: "IterHT",
            points: ps.iter().map(|&p| (p, f64::NAN)).collect(),
        }),
    }
    out
}

/// Kernel-speed-normalized one-core comparison of ParaHT vs sequential
/// LAPACK (Moler–Stewart), from *measured* flop counts.
///
/// With `t = flops / throughput`, the wall-clock ratio decomposes as
/// `t_ParaHT / t_LAPACK = (f_P / f_L) · (thr_L / thr_P)`: it conflates the
/// algorithmic flop overhead with the per-flop speed of the kernels each
/// algorithm runs on (our WY/GEMM kernels are per-flop faster than the
/// rotation kernels, which is why the raw wall ratio can drop below the
/// paper's 21.33/14). Dividing out the measured throughputs leaves the
/// pure flop ratio `f_P / f_L` — the kernel-independent quantity the paper
/// predicts (≈ 21.33/14 at the §4 tuning, ≈ 24/14 at the scaled
/// `r=8, p=4, q=4` tuning used below `n = 768`).
#[derive(Clone, Copy, Debug)]
pub struct OneCoreNormalized {
    /// Pencil size.
    pub n: usize,
    /// Measured ParaHT (sequential two-stage) flop count.
    pub paraht_flops: u64,
    /// Measured Moler–Stewart flop count.
    pub lapack_flops: u64,
    /// `paraht_flops / lapack_flops` — the kernel-independent one-core
    /// cost ratio (always > 1: the two-stage algorithm trades extra flops
    /// for parallelism).
    pub flop_ratio: f64,
    /// Raw wall-clock ratio `t_paraht / t_lapack` (kernel-dependent, noisy).
    pub wall_ratio: f64,
    /// Measured ParaHT per-flop throughput, GFLOP/s.
    pub paraht_gflops: f64,
    /// Measured Moler–Stewart per-flop throughput, GFLOP/s.
    pub lapack_gflops: f64,
}

/// Measure the one-core ParaHT-vs-LAPACK comparison in flop-normalized
/// form (closes the ROADMAP fig9a open item: the wall-clock ratio was
/// kernel-speed-dependent and could only be bounded loosely).
pub fn fig9a_one_core_normalized(n: usize, seed: u64) -> OneCoreNormalized {
    let mut rng = Rng::new(seed);
    let pencil = random_pencil(n, &mut rng);
    let cfg = scaled_config(n);
    // Counting must be on for the measurement, but the global toggle is
    // not ours to keep: restore whatever the caller had (the GEMM bench
    // deliberately disables counting for clean timings). The guard
    // restores on unwind too — a failed verify assert must not leak the
    // forced-on state into concurrently running tests.
    struct RestoreFlops(bool);
    impl Drop for RestoreFlops {
        fn drop(&mut self) {
            crate::util::flops::set_enabled(self.0);
        }
    }
    let _restore = RestoreFlops(crate::util::flops::enabled());
    crate::util::flops::set_enabled(true);

    // ParaHT: the sequential two-stage oracle, counted.
    let t = Timer::start();
    let (d, fp) = crate::util::flops::count(|| {
        crate::api::reduce_seq(&pencil.a, &pencil.b, &cfg).expect("paraht oracle")
    });
    let t_para = t.secs();
    // Sanity side-check only — this helper runs before the benches write
    // their JSON artifacts, so it must never panic on a residual; the
    // reduction's validity is pinned hard by the test suites.
    let worst = d.verify(&pencil.a, &pencil.b).worst();
    if worst > 1e-9 {
        eprintln!("warning: one-core normalized run residual {worst:.3e} (> 1e-9)");
    }

    // Sequential LAPACK (Moler–Stewart), counted.
    let (mut a, mut b) = (pencil.a.clone(), pencil.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    let t = Timer::start();
    let ((), fl) = crate::util::flops::count(|| {
        crate::baselines::moler_stewart::reduce(&mut a, &mut b, &mut q, &mut z)
    });
    let t_lapack = t.secs();

    OneCoreNormalized {
        n,
        paraht_flops: fp,
        lapack_flops: fl,
        flop_ratio: fp as f64 / fl as f64,
        wall_ratio: t_para / t_lapack,
        paraht_gflops: fp as f64 / t_para / 1e9,
        lapack_gflops: fl as f64 / t_lapack / 1e9,
    }
}

/// One row of Fig. 9b / Fig. 11: ParaHT's speedup over each comparator at
/// one pencil size.
#[derive(Clone, Debug)]
pub struct SizeRow {
    /// Pencil size.
    pub n: usize,
    /// Speedup of ParaHT over sequential-BLAS-parallel LAPACK (DGGHD3).
    pub over_lapack: f64,
    /// Speedup over HouseHT.
    pub over_househt: f64,
    /// Speedup over IterHT (NaN when IterHT fails).
    pub over_iterht: f64,
}

fn size_sweep(sizes: &[usize], saddle: bool, threads: usize, seed: u64) -> Vec<SizeRow> {
    let mut rows = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let mut rng = Rng::new(seed + i as u64);
        let pencil = if saddle {
            saddle_pencil(n, 0.25, &mut rng)
        } else {
            random_pencil(n, &mut rng)
        };
        let cfg = scaled_config(n);
        let ps = [threads];

        let (curve, _, _) = paraht_speedup_curve(&pencil, &cfg, &ps);
        let t_para = curve.points[0].1;

        // Comparators at min(threads, cap) — the paper's fair comparison.
        let pc = [threads.min(COMPARATOR_CAP)];
        let rec = dgghd3_recorded(&pencil.a, &pencil.b);
        let t_lapack = recorder_curve("DGGHD3", &rec, &pc, 32).points[0].1;
        let rec = househt_recorded(&pencil.a, &pencil.b);
        let t_hht = recorder_curve("HouseHT", &rec, &pc, 32).points[0].1;
        let t_iter = match iterht_recorded(&pencil.a, &pencil.b) {
            Ok((rec, _)) => recorder_curve("IterHT", &rec, &pc, 32).points[0].1,
            Err(_) => f64::NAN,
        };

        rows.push(SizeRow {
            n,
            over_lapack: t_lapack / t_para,
            over_househt: t_hht / t_para,
            over_iterht: t_iter / t_para,
        });
    }
    rows
}

/// Fig. 9b: ParaHT's speedup over the comparators for varying (random)
/// pencil sizes, at the full machine width.
pub fn fig9b(sizes: &[usize], threads: usize, seed: u64) -> Vec<SizeRow> {
    size_sweep(sizes, false, threads, seed)
}

/// Fig. 11: the same sweep on saddle-point pencils with 25% infinite
/// eigenvalues. IterHT fails to converge (NaN column), HouseHT pays
/// refinement, ParaHT and LAPACK are unaffected.
pub fn fig11(sizes: &[usize], threads: usize, seed: u64) -> Vec<SizeRow> {
    size_sweep(sizes, true, threads, seed)
}

/// Fig. 10 data: per-phase parallel speedup and relative runtime.
#[derive(Clone, Debug)]
pub struct PhaseData {
    /// Pencil size.
    pub n: usize,
    /// `(P, stage-1 speedup, stage-2 speedup, total speedup)`.
    pub speedups: Vec<(usize, f64, f64, f64)>,
    /// Sequential share of runtime spent in stage 1 / stage 2.
    pub stage1_fraction: f64,
    /// Stage-2 share.
    pub stage2_fraction: f64,
}

/// Fig. 10: speedup and relative runtime of the two phases.
pub fn fig10(sizes: &[usize], seed: u64) -> Vec<PhaseData> {
    let ps = PAPER_THREADS;
    sizes
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let mut rng = Rng::new(seed + i as u64);
            let pencil = random_pencil(n, &mut rng);
            let cfg = scaled_config(n);
            let (pts, t1, t2) = paraht_stage_makespans(&pencil, &cfg, ps);
            let speedups = pts
                .iter()
                .map(|&(p, m1, m2)| (p, t1 / m1, t2 / m2, (t1 + t2) / (m1 + m2)))
                .collect();
            PhaseData {
                n,
                speedups,
                stage1_fraction: t1 / (t1 + t2),
                stage2_fraction: t2 / (t1 + t2),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9a_shape() {
        let series = fig9a(96, 300);
        assert_eq!(series.len(), 4);
        let para = &series[0];
        assert_eq!(para.name, "ParaHT");
        // ParaHT speedup grows with P (DAG parallelism).
        let s1 = para.points[0].1;
        let s_last = para.points.last().unwrap().1;
        assert!(s_last > s1, "ParaHT must scale: {s1} -> {s_last}");
        // On one thread ParaHT pays the 21.33/14 extra-flop ratio vs
        // LAPACK (§4). On this substrate the WY kernels are per-flop
        // faster than the rotation kernels, so the measured wall-clock
        // ratio can approach or slightly pass 1 (see
        // benches/fig9a_threads.rs) — assert only that it is not
        // implausibly fast. The kernel-independent (flop-normalized) bound
        // lives in `fig9a_one_core_ratio_kernel_normalized` below.
        assert!(s1 < 1.6, "one-core ParaHT implausibly fast vs LAPACK: {s1}");
    }

    #[test]
    fn fig9a_one_core_ratio_kernel_normalized() {
        // Normalizing by measured per-flop kernel throughput reduces the
        // one-core comparison to the flop ratio, which is deterministic in
        // isolation — so unlike the wall-clock bound above this one is
        // two-sided. The counter is process-global, though, and sibling
        // lib tests add to it concurrently (steadily, not just in bursts),
        // which drags a contaminated ratio toward 1. So: n = 160 keeps
        // each window near 10⁸ flops (the exposure the flop-table test
        // tolerates inside ±30% bands), and the measurement retries up to
        // four times, passing on the first attempt whose ratio lands in
        // band — late retries run against a quieter suite, while a real
        // flop-accounting regression fails every attempt deterministically.
        //
        // Paper (scaled tuning r=8, p=4): stage 1 ≈ 14 n³ + stage 2 ≈
        // 10 n³ vs one-stage ≈ 14 n³ → ratio ≈ 1.7, with lower-order
        // terms still visible at n = 160 (the flop-table test at n ≥ 192
        // pins the same measurement inside (1.3, 2.2)).
        let mut last_ratio = f64::NAN;
        let mut in_band = false;
        for _attempt in 0..4 {
            let m = fig9a_one_core_normalized(160, 300);
            // Throughputs are well-defined and finite on every attempt.
            assert!(m.paraht_gflops.is_finite() && m.paraht_gflops > 0.0);
            assert!(m.lapack_gflops.is_finite() && m.lapack_gflops > 0.0);
            assert!(m.wall_ratio.is_finite() && m.wall_ratio > 0.0);
            last_ratio = m.flop_ratio;
            if last_ratio > 1.1 && last_ratio < 2.8 {
                in_band = true;
                break;
            }
        }
        assert!(
            in_band,
            "flop-normalized one-core ratio outside (1.1, 2.8) on every attempt: \
             last {last_ratio:.3}"
        );
    }

    #[test]
    fn fig9b_shape() {
        let rows = fig9b(&[72, 120], 28, 301);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.over_lapack.is_finite() && r.over_lapack > 0.0);
            assert!(r.over_househt.is_finite());
        }
    }

    #[test]
    fn fig10_fractions_sum() {
        let data = fig10(&[96], 302);
        let d = &data[0];
        assert!((d.stage1_fraction + d.stage2_fraction - 1.0).abs() < 1e-12);
        // §4: "most of the runtime of the algorithm is spent inside phase 2
        // despite phase 1 requiring slightly more flops".
        assert!(d.stage2_fraction > 0.35, "stage 2 fraction {:.2}", d.stage2_fraction);
    }

    #[test]
    fn fig11_iterht_fails() {
        let rows = fig11(&[64], 28, 303);
        assert!(rows[0].over_iterht.is_nan(), "IterHT must fail on saddle pencils");
        assert!(rows[0].over_lapack.is_finite());
        assert!(rows[0].over_househt.is_finite());
    }
}
