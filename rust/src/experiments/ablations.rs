//! Ablations over the design choices the paper calls out.
//!
//! * `p` sweep (§2.2: "modest values between 5 and 12 are usually
//!   optimal") — stage-1 flop coefficient `(28p+14)/(3(p−1))` decreases
//!   with `p` while fill-in/block sizes grow.
//! * `q` sweep (§3.2/§4: `q = 8`) — larger groups amortize WY overhead but
//!   delay updates.
//! * lookahead on/off (§3.3) — measured as the simulated makespan of the
//!   stage-2 DAG with and without the lookahead split.
//! * blocked vs unblocked stage 2 (Algs. 3+4 vs Alg. 2) — sequential time.

use crate::config::Config;
use crate::coordinator::sim::simulate_makespan;
use crate::coordinator::stage1_par::ExecMode;
use crate::coordinator::stage2_par::reduce_blocked_par;
use crate::ht::{stage1, stage2_blocked, stage2_unblocked};
use crate::linalg::matrix::Matrix;
use crate::pencil::random::random_pencil;
use crate::util::rng::Rng;
use crate::util::timer::Timer;

/// Stage-1 cost vs `p`.
pub fn p_sweep(n: usize, r: usize, ps: &[usize], seed: u64) -> Vec<(usize, f64, f64)> {
    let mut rng = Rng::new(seed);
    let pencil = random_pencil(n, &mut rng);
    ps.iter()
        .map(|&p| {
            let (mut a, mut b) = (pencil.a.clone(), pencil.b.clone());
            let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
            let cfg = Config { r, p, ..Config::default() };
            crate::util::flops::set_enabled(true);
            let t = Timer::start();
            let ((), f) = crate::util::flops::count(|| {
                stage1::reduce_to_banded(&mut a, &mut b, &mut q, &mut z, &cfg)
            });
            (p, t.secs(), f as f64 / (n as f64).powi(3))
        })
        .collect()
}

/// Stage-2 sequential time vs `q` (q = 0 row encodes the unblocked Alg. 2).
pub fn q_sweep(n: usize, r: usize, qs: &[usize], seed: u64) -> Vec<(usize, f64)> {
    let mut rng = Rng::new(seed);
    let pencil = random_pencil(n, &mut rng);
    // Pre-reduce to banded once.
    let (mut a0, mut b0) = (pencil.a.clone(), pencil.b.clone());
    let (mut q0, mut z0) = (Matrix::identity(n), Matrix::identity(n));
    let cfg = Config { r, p: 4, ..Config::default() };
    stage1::reduce_to_banded(&mut a0, &mut b0, &mut q0, &mut z0, &cfg);

    let mut out = Vec::new();
    // Unblocked reference.
    {
        let (mut a, mut b) = (a0.clone(), b0.clone());
        let (mut q, mut z) = (q0.clone(), z0.clone());
        let t = Timer::start();
        stage2_unblocked::reduce_unblocked(&mut a, &mut b, &mut q, &mut z, r);
        out.push((0, t.secs()));
    }
    for &qq in qs {
        let (mut a, mut b) = (a0.clone(), b0.clone());
        let (mut q, mut z) = (q0.clone(), z0.clone());
        let t = Timer::start();
        stage2_blocked::reduce_blocked(&mut a, &mut b, &mut q, &mut z, r, qq);
        out.push((qq, t.secs()));
    }
    out
}

/// Stage-2 simulated makespan with/without lookahead, at `threads` workers.
///
/// "Without lookahead" contracts the graph: the lookahead split is removed
/// by simulating the same trace with the `Look2` tasks' edges intact but
/// the band updates merged into the generate chain — approximated here by
/// serializing every Look2 task with its group's Gen2 (which is what not
/// splitting them would do).
pub fn lookahead_ablation(n: usize, cfg: &Config, threads: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let pencil = random_pencil(n, &mut rng);
    let (mut a, mut b) = (pencil.a.clone(), pencil.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    stage1::reduce_to_banded(&mut a, &mut b, &mut q, &mut z, cfg);

    let trace = reduce_blocked_par(&mut a, &mut b, &mut q, &mut z, cfg, ExecMode::Trace)
        .expect("trace mode");

    let with_look = simulate_makespan(&trace, threads).makespan;

    // Serialize lookahead into the generate chain: chain all Look2 tasks of
    // consecutive groups behind their Gen2 — emulate by adding each Look2's
    // duration onto a strictly serial Gen2 spine.
    let mut serial = trace.clone();
    let mut last_gen: Option<usize> = None;
    for i in 0..serial.classes.len() {
        match serial.classes[i] {
            crate::coordinator::graph::TaskClass::Gen2 => {
                if let Some(lg) = last_gen {
                    serial.deps[i].push(lg);
                }
                last_gen = Some(i);
            }
            crate::coordinator::graph::TaskClass::Look2 => {
                // Lookahead work joins the serial spine.
                if let Some(lg) = last_gen {
                    serial.deps[i].push(lg);
                }
                last_gen = Some(i);
            }
            _ => {}
        }
    }
    let without_look = simulate_makespan(&serial, threads).makespan;
    (with_look, without_look)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p_sweep_flops_follow_formula() {
        // The coefficient (28p+14)/(3(p−1)) decreases with p; at small n
        // the p=4 vs p=8 gap drowns in edge effects, so assert the robust
        // p=2 → {4, 8} drops only.
        let rows = p_sweep(160, 8, &[2, 4, 8], 500);
        assert!(rows[0].2 > rows[1].2, "p=2 coeff {} > p=4 {}", rows[0].2, rows[1].2);
        assert!(rows[0].2 > rows[2].2, "p=2 coeff {} > p=8 {}", rows[0].2, rows[2].2);
    }

    #[test]
    fn q_sweep_runs_all() {
        let rows = q_sweep(96, 4, &[2, 8], 501);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].0, 0);
        for (_, t) in &rows {
            assert!(*t > 0.0);
        }
    }

    #[test]
    fn lookahead_helps_or_equal() {
        let cfg = Config { r: 4, q: 3, ..Config::default() };
        let (with_look, without) = lookahead_ablation(140, &cfg, 8, 502);
        assert!(
            with_look <= without * 1.02,
            "lookahead must not hurt: {with_look:.4} vs {without:.4}"
        );
    }
}
