//! Flop-count verification table (§2.2 and §3.1 of the paper).
//!
//! The paper gives closed-form costs (including `Q`/`Z` accumulation):
//!
//! * stage 1: `(28p + 14) / (3(p−1)) · n³` → `11.33 n³` at `p = 8`
//! * stage 2: `10 n³`
//! * two-stage total: `21.33 n³`
//! * one-stage Moler–Stewart: `14 n³` ("an increase of more than 40%")
//!
//! We measure with the global flop counters and report measured/n³ next to
//! the formulas. Agreement is asymptotic — lower-order `O(n²)` terms and
//! the `r²n²` RQ cost (explicitly called out in §3.1) shrink as n grows.

use crate::config::Config;
use crate::ht::{stage1, stage2_blocked};
use crate::linalg::matrix::Matrix;
use crate::pencil::random::random_pencil;
use crate::util::{flops, rng::Rng};

/// Measured vs predicted flop coefficients (`flops / n³`).
#[derive(Clone, Debug)]
pub struct FlopRow {
    /// Problem size.
    pub n: usize,
    /// Measured stage-1 coefficient.
    pub stage1: f64,
    /// Measured stage-2 coefficient.
    pub stage2: f64,
    /// Measured one-stage (Moler–Stewart) coefficient.
    pub one_stage: f64,
}

/// Paper's predicted stage-1 coefficient for a given `p`.
pub fn stage1_coeff(p: usize) -> f64 {
    (28.0 * p as f64 + 14.0) / (3.0 * (p as f64 - 1.0))
}

/// Measure the flop table at the given sizes (paper tuning `r=16, p=8,
/// q=8` scaled down via `r=8, p=4` below n=768 — coefficients are
/// p-dependent; we report against `stage1_coeff(p)` for the p used).
pub fn measure(sizes: &[usize], r: usize, p: usize, q: usize, seed: u64) -> Vec<FlopRow> {
    let mut rows = Vec::new();
    for (i, &n) in sizes.iter().enumerate() {
        let mut rng = Rng::new(seed + i as u64);
        let pencil = random_pencil(n, &mut rng);
        let n3 = (n as f64).powi(3);
        let cfg = Config { r, p, q, ..Config::default() };

        flops::set_enabled(true);

        // Stage 1.
        let (mut a, mut b) = (pencil.a.clone(), pencil.b.clone());
        let (mut qm, mut zm) = (Matrix::identity(n), Matrix::identity(n));
        let ((), f1) = flops::count(|| stage1::reduce_to_banded(&mut a, &mut b, &mut qm, &mut zm, &cfg));

        // Stage 2 (on the banded result).
        let ((), f2) =
            flops::count(|| stage2_blocked::reduce_blocked(&mut a, &mut b, &mut qm, &mut zm, r, q));

        // One-stage Moler–Stewart.
        let (mut a, mut b) = (pencil.a.clone(), pencil.b.clone());
        let (mut qm, mut zm) = (Matrix::identity(n), Matrix::identity(n));
        let ((), f3) =
            flops::count(|| crate::baselines::moler_stewart::reduce(&mut a, &mut b, &mut qm, &mut zm));

        rows.push(FlopRow {
            n,
            stage1: f1 as f64 / n3,
            stage2: f2 as f64 / n3,
            one_stage: f3 as f64 / n3,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coefficients_match_paper_formulas() {
        assert!((stage1_coeff(8) - 11.333).abs() < 0.01);
        // Measured coefficients approach the formulas as n grows. At these
        // test sizes lower-order terms still matter: accept a band.
        let rows = measure(&[192, 288], 8, 4, 4, 400);
        let c1 = stage1_coeff(4);
        for row in &rows {
            assert!(
                (row.stage1 - c1).abs() / c1 < 0.35,
                "stage1 coeff n={}: got {:.2}, formula {:.2}",
                row.n,
                row.stage1,
                c1
            );
            assert!(
                (row.stage2 - 10.0).abs() / 10.0 < 0.45,
                "stage2 coeff n={}: got {:.2} vs 10",
                row.n,
                row.stage2
            );
            assert!(
                (row.one_stage - 14.0).abs() / 14.0 < 0.30,
                "one-stage coeff n={}: got {:.2} vs 14",
                row.n,
                row.one_stage
            );
        }
        // Convergence: larger n closer to the asymptote for stage 2.
        let d0 = (rows[0].stage2 - 10.0).abs();
        let d1 = (rows[1].stage2 - 10.0).abs();
        assert!(d1 <= d0 * 1.15, "stage-2 coeff should approach 10: {d0:.2} -> {d1:.2}");
    }

    #[test]
    fn two_stage_overhead_vs_one_stage() {
        // Paper: two-stage needs >40% more flops than one-stage.
        let rows = measure(&[224], 8, 4, 4, 401);
        let total = rows[0].stage1 + rows[0].stage2;
        let ratio = total / rows[0].one_stage;
        assert!(ratio > 1.3, "two-stage/one-stage flop ratio {ratio:.2}");
        assert!(ratio < 2.2, "ratio implausibly large: {ratio:.2}");
    }
}
