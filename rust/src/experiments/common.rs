//! Shared plumbing for the figure-regeneration experiments (§4).

use crate::api::HtSession;
use crate::config::Config;
use crate::coordinator::driver::{paraht_curve, SpeedupCurve};
use crate::coordinator::graph::TaskTrace;
use crate::linalg::matrix::Matrix;
use crate::pencil::random::Pencil;

/// Thread counts matching the paper's Fig. 9a sweep (their machine has
/// 28 cores; they also report the 14-thread saturation point of the
/// comparators).
pub const PAPER_THREADS: &[usize] = &[1, 2, 4, 7, 14, 21, 28];

/// The paper's comparator thread cap (§4: "we limit HouseHT and IterHT to
/// 14 threads to get a fair comparison").
pub const COMPARATOR_CAP: usize = 14;

/// Default ParaHT tuning (paper §4: r=16, p=8, q=8). The slice count is
/// pinned above the largest simulated worker count so the task graph's
/// parallelism is not artificially capped by the tracing config.
pub fn paper_config() -> Config {
    Config { slices: 32, ..Config::default() }
}

/// A scaled-down tuning for small experiment sizes (same structure, more
/// panels/groups at small n so the task graphs stay representative).
pub fn scaled_config(n: usize) -> Config {
    if n >= 768 {
        paper_config()
    } else {
        Config { r: 8, p: 4, q: 4, slices: 32, ..Config::default() }
    }
}

/// Run one verified trace-capturing reduction through the session front
/// door and return the per-stage task traces (what `ExecMode::Trace` used
/// to produce).
pub fn paraht_traces(pencil: &Pencil, cfg: &Config) -> (TaskTrace, TaskTrace) {
    let mut session = HtSession::builder()
        .config(cfg.clone())
        .capture_traces(true)
        .build()
        .expect("valid experiment config");
    let d = session.reduce(&pencil.a, &pencil.b).expect("paraht run");
    let v = d.verify(&pencil.a, &pencil.b);
    assert!(
        v.worst() < 1e-9,
        "ParaHT verification failed: worst residual {:.3e}",
        v.worst()
    );
    session.take_traces().expect("trace-capturing session records traces")
}

/// Run ParaHT in trace mode and return its simulated speedup curve.
pub fn paraht_speedup_curve(pencil: &Pencil, cfg: &Config, ps: &[usize]) -> (SpeedupCurve, f64, f64) {
    let traces = paraht_traces(pencil, cfg);
    let t1 = traces.0.total().as_secs_f64();
    let t2 = traces.1.total().as_secs_f64();
    (paraht_curve(&traces, ps), t1, t2)
}

/// Simulated per-stage makespans of a ParaHT trace. Unlike
/// [`paraht_speedup_curve`] this does *not* verify the reduction: fig10's
/// bench contract is that its JSON artifact is written before any
/// assertion can fire, so data collection here must not panic on a
/// residual.
pub fn paraht_stage_makespans(
    pencil: &Pencil,
    cfg: &Config,
    ps: &[usize],
) -> (Vec<(usize, f64, f64)>, f64, f64) {
    let mut session = HtSession::builder()
        .config(cfg.clone())
        .capture_traces(true)
        .build()
        .expect("valid experiment config");
    session.reduce(&pencil.a, &pencil.b).expect("paraht run");
    let traces = session.take_traces().expect("trace-capturing session records traces");
    // One memoized simulator per stage across the whole P sweep.
    let mut sim1 = crate::coordinator::sim::Simulator::new(&traces.0);
    let mut sim2 = crate::coordinator::sim::Simulator::new(&traces.1);
    let pts = ps
        .iter()
        .map(|&p| (p, sim1.result(p).makespan, sim2.result(p).makespan))
        .collect();
    (
        pts,
        traces.0.total().as_secs_f64(),
        traces.1.total().as_secs_f64(),
    )
}

/// Pretty-print a table: header + rows of (label, values).
pub fn print_table(title: &str, header: &[String], rows: &[(String, Vec<f64>)]) {
    println!("\n== {title} ==");
    print!("{:<22}", "");
    for h in header {
        print!("{h:>12}");
    }
    println!();
    for (label, vals) in rows {
        print!("{label:<22}");
        for v in vals {
            if v.is_nan() {
                print!("{:>12}", "fail");
            } else {
                print!("{v:>12.2}");
            }
        }
        println!();
    }
}

/// Geometric-ish sanity check helper used by bench asserts.
pub fn monotone_nonincreasing(xs: &[f64], slack: f64) -> bool {
    xs.windows(2).all(|w| w[1] <= w[0] * (1.0 + slack))
}

/// Whether the benches run in *soft* mode (`PALLAS_BENCH_SOFT=1`; parsing
/// and the legacy `PARAHT_BENCH_SOFT` alias live in [`crate::util::env`]):
/// the timing-sensitive shape assertions (blocked-beats-unblocked,
/// scaling-grows-with-n, parallel-speedup floors) print a `SOFT-FAIL`
/// warning instead of aborting. For CI and slow/noisy hardware, where
/// wall-clock ratios are not trustworthy; structural assertions (flop
/// counts, IterHT divergence, finiteness) stay hard in either mode.
pub fn bench_soft() -> bool {
    crate::util::env::bench_soft()
}

/// Tolerance multiplier for timing thresholds (`PALLAS_BENCH_TOL`, alias
/// `PARAHT_BENCH_TOL`; default 1.0 — see [`crate::util::env`]). A value of
/// `t > 1` relaxes every timing-sensitive bench threshold by that factor
/// (e.g. `PALLAS_BENCH_TOL=1.5` accepts a 1.5× miss) without disabling the
/// check outright the way soft mode does.
pub fn bench_tol() -> f64 {
    crate::util::env::bench_tol()
}

/// Format a float for the `BENCH_*.json` artifacts: JSON has no NaN/Inf
/// literal, so non-finite values (e.g. IterHT divergence ratios) become
/// `null`.
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

/// Write a `BENCH_*.json` perf artifact: shared envelope (schema version,
/// bench name, soft/tolerance mode — so a trajectory reader can discount
/// soft-mode runs) plus the bench-specific `body`. `body` must be a
/// comma-separated JSON field list indented two spaces, *without* a
/// trailing comma. The default path is overridden by `PALLAS_BENCH_OUT`
/// (legacy alias `PARAHT_BENCH_OUT`). Returns the path written.
pub fn write_bench_json(default_name: &str, bench: &str, body: &str) -> String {
    use std::fmt::Write as _;
    let path = crate::util::env::bench_out(default_name);
    let mut j = String::new();
    j.push_str("{\n  \"schema_version\": 1,\n");
    let _ = writeln!(j, "  \"bench\": \"{bench}\",");
    let _ = writeln!(j, "  \"soft_mode\": {},", bench_soft());
    let _ = writeln!(j, "  \"tolerance\": {},", bench_tol());
    j.push_str(body);
    if !body.ends_with('\n') {
        j.push('\n');
    }
    j.push_str("}\n");
    std::fs::write(&path, &j).unwrap_or_else(|e| panic!("write {path}: {e}"));
    println!("wrote {path}");
    path
}

/// Check a timing-sensitive bench claim: panics like `assert!` by default,
/// warns in soft mode (see [`bench_soft`]). Returns whether it held.
pub fn bench_check(cond: bool, msg: &str) -> bool {
    if cond {
        true
    } else if bench_soft() {
        eprintln!("SOFT-FAIL (PALLAS_BENCH_SOFT=1, not aborting): {msg}");
        false
    } else {
        panic!("{msg} (set PALLAS_BENCH_SOFT=1 to warn instead, or raise PALLAS_BENCH_TOL)");
    }
}

/// Identity matrix shorthand used by example drivers.
pub fn eye(n: usize) -> Matrix {
    Matrix::identity(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_check_passes_silently() {
        // A holding condition never consults the env (safe under parallel
        // test execution, which must not set PALLAS_BENCH_* globally).
        assert!(bench_check(true, "never shown"));
    }

    #[test]
    fn bench_tol_is_at_least_one() {
        assert!(bench_tol() >= 1.0);
    }

    #[test]
    fn json_num_handles_non_finite() {
        assert_eq!(json_num(1.5), "1.500000");
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
    }

    #[test]
    fn monotone_helper() {
        assert!(monotone_nonincreasing(&[3.0, 2.0, 2.0, 1.0], 0.0));
        assert!(!monotone_nonincreasing(&[1.0, 2.0], 0.0));
        assert!(monotone_nonincreasing(&[1.0, 1.05], 0.1));
    }
}
