//! Shared plumbing for the figure-regeneration experiments (§4).

use crate::config::Config;
use crate::coordinator::driver::{paraht_curve, run_paraht, SpeedupCurve};
use crate::coordinator::stage1_par::ExecMode;
use crate::linalg::matrix::Matrix;
use crate::pencil::random::Pencil;

/// Thread counts matching the paper's Fig. 9a sweep (their machine has
/// 28 cores; they also report the 14-thread saturation point of the
/// comparators).
pub const PAPER_THREADS: &[usize] = &[1, 2, 4, 7, 14, 21, 28];

/// The paper's comparator thread cap (§4: "we limit HouseHT and IterHT to
/// 14 threads to get a fair comparison").
pub const COMPARATOR_CAP: usize = 14;

/// Default ParaHT tuning (paper §4: r=16, p=8, q=8). The slice count is
/// pinned above the largest simulated worker count so the task graph's
/// parallelism is not artificially capped by the tracing config.
pub fn paper_config() -> Config {
    Config { slices: 32, ..Config::default() }
}

/// A scaled-down tuning for small experiment sizes (same structure, more
/// panels/groups at small n so the task graphs stay representative).
pub fn scaled_config(n: usize) -> Config {
    if n >= 768 {
        paper_config()
    } else {
        Config { r: 8, p: 4, q: 4, slices: 32, ..Config::default() }
    }
}

/// Run ParaHT in trace mode and return its simulated speedup curve.
pub fn paraht_speedup_curve(pencil: &Pencil, cfg: &Config, ps: &[usize]) -> (SpeedupCurve, f64, f64) {
    let run = run_paraht(&pencil.a, &pencil.b, cfg, ExecMode::Trace).expect("paraht run");
    let v = run.verify(&pencil.a, &pencil.b);
    assert!(
        v.worst() < 1e-9,
        "ParaHT verification failed: worst residual {:.3e}",
        v.worst()
    );
    let traces = run.traces.expect("trace mode");
    let t1 = traces.0.total().as_secs_f64();
    let t2 = traces.1.total().as_secs_f64();
    (paraht_curve(&traces, ps), t1, t2)
}

/// Simulated per-stage makespans of a ParaHT trace.
pub fn paraht_stage_makespans(
    pencil: &Pencil,
    cfg: &Config,
    ps: &[usize],
) -> (Vec<(usize, f64, f64)>, f64, f64) {
    let run = run_paraht(&pencil.a, &pencil.b, cfg, ExecMode::Trace).expect("paraht run");
    let traces = run.traces.expect("trace mode");
    let pts = ps
        .iter()
        .map(|&p| {
            let m1 = crate::coordinator::sim::simulate_makespan(&traces.0, p).makespan;
            let m2 = crate::coordinator::sim::simulate_makespan(&traces.1, p).makespan;
            (p, m1, m2)
        })
        .collect();
    (
        pts,
        traces.0.total().as_secs_f64(),
        traces.1.total().as_secs_f64(),
    )
}

/// Pretty-print a table: header + rows of (label, values).
pub fn print_table(title: &str, header: &[String], rows: &[(String, Vec<f64>)]) {
    println!("\n== {title} ==");
    print!("{:<22}", "");
    for h in header {
        print!("{h:>12}");
    }
    println!();
    for (label, vals) in rows {
        print!("{label:<22}");
        for v in vals {
            if v.is_nan() {
                print!("{:>12}", "fail");
            } else {
                print!("{v:>12.2}");
            }
        }
        println!();
    }
}

/// Geometric-ish sanity check helper used by bench asserts.
pub fn monotone_nonincreasing(xs: &[f64], slack: f64) -> bool {
    xs.windows(2).all(|w| w[1] <= w[0] * (1.0 + slack))
}

/// Identity matrix shorthand used by example drivers.
pub fn eye(n: usize) -> Matrix {
    Matrix::identity(n)
}
