//! Figure/table regeneration (§4 of the paper), shared by the CLI
//! (`paraht experiment …`) and the bench targets (`cargo bench`).

pub mod ablations;
pub mod common;
pub mod figures;
pub mod flops_table;
