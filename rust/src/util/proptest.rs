//! Seeded case-sweep property-testing harness (proptest is unavailable
//! offline).
//!
//! `for_each_case` runs a property over `cases` deterministic seeds; on
//! failure it reports the case index and seed so the case can be replayed
//! exactly. The shape generators draw matrix dimensions from the case RNG
//! with a deliberate bias toward the adversarial end of the space:
//! degenerate 1×k / k×1 shapes, tall/wide aspect ratios, and sizes around
//! blocking boundaries.

use super::rng::Rng;
use crate::linalg::matrix::Matrix;

/// Run `prop` for `cases` seeded cases. `prop` returns `Err(msg)` to fail.
/// Panics with the failing seed + message.
pub fn for_each_case(cases: usize, base_seed: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (case {i}, seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper: check a relative error against a tolerance, with context.
pub fn check_rel(what: &str, err: f64, tol: f64) -> Result<(), String> {
    if !(err <= tol) {
        return Err(format!("{what}: rel err {err:.3e} > tol {tol:.1e}"));
    }
    Ok(())
}

/// Assert helper: check an absolute error against a tolerance, with context.
pub fn check_abs(what: &str, err: f64, tol: f64) -> Result<(), String> {
    if !(err.abs() <= tol) {
        return Err(format!("{what}: abs err {err:.3e} > tol {tol:.1e}"));
    }
    Ok(())
}

/// Assert helper: check a boolean condition, with context.
pub fn check_that(what: &str, ok: bool) -> Result<(), String> {
    if ok {
        Ok(())
    } else {
        Err(format!("{what}: condition violated"))
    }
}

/// Draw a square dimension in `[1, max]`.
pub fn gen_square_dim(rng: &mut Rng, max: usize) -> usize {
    1 + rng.below(max.max(1))
}

/// Draw a rectangular `(rows, cols)` pair, each in `[1, max]`, with a bias
/// toward tall and wide aspect ratios (one dimension re-drawn small 2/3 of
/// the time: 1/3 tall-ish, 1/3 wide-ish, 1/3 unconstrained).
pub fn gen_rect_dims(rng: &mut Rng, max: usize) -> (usize, usize) {
    let m = 1 + rng.below(max.max(1));
    let n = 1 + rng.below(max.max(1));
    match rng.below(3) {
        0 => (m, 1 + rng.below(4.min(max.max(1)))), // tall-ish: few columns
        1 => (1 + rng.below(4.min(max.max(1))), n), // wide-ish: few rows
        _ => (m, n),
    }
}

/// Draw a degenerate shape: single row, single column, 1×1, or tiny square.
pub fn gen_degenerate_dims(rng: &mut Rng, max: usize) -> (usize, usize) {
    match rng.below(4) {
        0 => (1, 1 + rng.below(max.max(1))),
        1 => (1 + rng.below(max.max(1)), 1),
        2 => (1, 1),
        _ => {
            let s = 1 + rng.below(3);
            (s, s)
        }
    }
}

/// Draw a shape for a sweep: mostly rectangular, 1-in-4 degenerate.
pub fn gen_shape(rng: &mut Rng, max: usize) -> (usize, usize) {
    if rng.below(4) == 0 {
        gen_degenerate_dims(rng, max)
    } else {
        gen_rect_dims(rng, max)
    }
}

/// Random standard-normal matrix of a drawn shape.
pub fn gen_matrix(rng: &mut Rng, max: usize) -> Matrix {
    let (m, n) = gen_shape(rng, max);
    Matrix::randn(m, n, rng)
}

/// Random standard-normal square matrix with drawn order in `[1, max]`.
pub fn gen_square_matrix(rng: &mut Rng, max: usize) -> Matrix {
    let s = gen_square_dim(rng, max);
    Matrix::randn(s, s, rng)
}

/// Relative Frobenius difference `‖X − Y‖_F / max(‖Y‖_F, tiny)` — the
/// residual every factor-reconstruct property checks.
pub fn rel_diff(x: &Matrix, y: &Matrix) -> f64 {
    assert_eq!(x.rows(), y.rows());
    assert_eq!(x.cols(), y.cols());
    let mut d = 0.0;
    for j in 0..x.cols() {
        for i in 0..x.rows() {
            d += (x[(i, j)] - y[(i, j)]).powi(2);
        }
    }
    d.sqrt() / y.norm_fro().max(1e-300)
}

/// Largest absolute entrywise difference. NaN-propagating: if any pair
/// differs by NaN (e.g. one side diverged to NaN), the result is NaN —
/// `f64::max` would silently discard it and report spurious equality.
pub fn max_abs_diff(x: &Matrix, y: &Matrix) -> f64 {
    assert_eq!(x.rows(), y.rows());
    assert_eq!(x.cols(), y.cols());
    let mut d = 0.0f64;
    for j in 0..x.cols() {
        for i in 0..x.rows() {
            let e = (x[(i, j)] - y[(i, j)]).abs();
            if e > d || e.is_nan() {
                d = e; // NaN is sticky: e > NaN is false for finite e
            }
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let counter = std::cell::Cell::new(0);
        for_each_case(10, 1, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        assert_eq!(counter.get(), 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        for_each_case(3, 2, |r| {
            if r.uniform() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn check_rel_works() {
        assert!(check_rel("x", 1e-14, 1e-12).is_ok());
        assert!(check_rel("x", 1e-10, 1e-12).is_err());
        assert!(check_rel("x", f64::NAN, 1e-12).is_err());
    }

    #[test]
    fn check_abs_and_that() {
        assert!(check_abs("x", -1e-14, 1e-12).is_ok());
        assert!(check_abs("x", 1e-3, 1e-12).is_err());
        assert!(check_abs("x", f64::NAN, 1e-12).is_err());
        assert!(check_that("x", true).is_ok());
        assert!(check_that("x", false).is_err());
    }

    #[test]
    fn shape_generators_in_bounds() {
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..500 {
            let s = gen_square_dim(&mut rng, 30);
            assert!((1..=30).contains(&s));
            let (m, n) = gen_rect_dims(&mut rng, 30);
            assert!((1..=30).contains(&m) && (1..=30).contains(&n));
            let (m, n) = gen_degenerate_dims(&mut rng, 30);
            assert!(m >= 1 && n >= 1 && (m == 1 || n == 1 || (m == n && m <= 3)));
            let (m, n) = gen_shape(&mut rng, 30);
            assert!((1..=30).contains(&m) && (1..=30).contains(&n));
        }
    }

    #[test]
    fn generators_hit_degenerate_shapes() {
        // The sweep must actually produce 1-row and 1-column cases.
        let mut rng = crate::util::rng::Rng::new(10);
        let (mut saw_row, mut saw_col) = (false, false);
        for _ in 0..300 {
            let (m, n) = gen_shape(&mut rng, 20);
            saw_row |= m == 1 && n > 1;
            saw_col |= n == 1 && m > 1;
        }
        assert!(saw_row && saw_col, "degenerate shapes never drawn");
    }

    #[test]
    fn diff_helpers() {
        let a = Matrix::from_rows(2, 2, &[1., 2., 3., 4.]);
        let mut b = a.clone();
        assert_eq!(rel_diff(&a, &b), 0.0);
        assert_eq!(max_abs_diff(&a, &b), 0.0);
        b[(1, 1)] = 5.0;
        assert!((max_abs_diff(&a, &b) - 1.0).abs() < 1e-15);
        assert!(rel_diff(&a, &b) > 0.0);
    }

    #[test]
    fn matrix_generators() {
        let mut rng = crate::util::rng::Rng::new(11);
        let m = gen_matrix(&mut rng, 12);
        assert!(m.rows() >= 1 && m.cols() >= 1);
        let s = gen_square_matrix(&mut rng, 12);
        assert_eq!(s.rows(), s.cols());
    }
}
