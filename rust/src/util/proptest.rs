//! Minimal property-testing helper (proptest is unavailable offline).
//!
//! `for_each_case` runs a property over `cases` deterministic seeds; on
//! failure it reports the seed so the case can be replayed exactly. Tests
//! over matrix shapes draw dimensions from the provided RNG.

use super::rng::Rng;

/// Run `prop` for `cases` seeded cases. `prop` returns `Err(msg)` to fail.
/// Panics with the failing seed + message.
pub fn for_each_case(cases: usize, base_seed: u64, prop: impl Fn(&mut Rng) -> Result<(), String>) {
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (case {i}, seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper: check a relative error against a tolerance, with context.
pub fn check_rel(what: &str, err: f64, tol: f64) -> Result<(), String> {
    if !(err <= tol) {
        return Err(format!("{what}: rel err {err:.3e} > tol {tol:.1e}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut count = 0;
        // Property must be Fn, so count via cell.
        let counter = std::cell::Cell::new(0);
        for_each_case(10, 1, |_| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        for_each_case(3, 2, |r| {
            if r.uniform() >= 0.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn check_rel_works() {
        assert!(check_rel("x", 1e-14, 1e-12).is_ok());
        assert!(check_rel("x", 1e-10, 1e-12).is_err());
        assert!(check_rel("x", f64::NAN, 1e-12).is_err());
    }
}
