//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we ship a small, well-known
//! generator: SplitMix64 for seeding and xoshiro256** for the stream.
//! Determinism matters here — every experiment in EXPERIMENTS.md records
//! its seed so runs are exactly reproducible.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    cached_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Next raw 64-bit value (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal deviate (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.cached_normal.take() {
            return v;
        }
        // Box-Muller; u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(123);
        let n = 200_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
