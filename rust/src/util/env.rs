//! Centralized environment-variable handling.
//!
//! Every knob the crate reads from the process environment goes through
//! this module — one place for names, legacy aliases, parsing and
//! clamping, replacing the ad-hoc `std::env::var` parsing that used to be
//! scattered across `coordinator::pool`, `experiments::common`, the bench
//! targets and the stress tests.
//!
//! **Naming convention:** canonical names carry the `PALLAS_` prefix; the
//! crate-prefixed `PARAHT_` spellings are accepted everywhere as legacy
//! aliases, with the canonical name winning when both are set.
//!
//! | Variable (canonical)     | Meaning |
//! |--------------------------|---------|
//! | `PALLAS_POOL_THREADS`    | worker-team size *including* the caller ([`crate::coordinator::pool::global`]) |
//! | `PALLAS_ASSIST`          | `1`/`true`: work-assisting dynamic panel scheduling as the process default ([`crate::coordinator::assist`]) |
//! | `PALLAS_AUDIT`           | `1`/`true` forces the concurrency auditor on, anything else forces it off; unset defers to the `audit` feature (audit-capable builds only — see `coordinator::audit`) |
//! | `PALLAS_KERNEL`          | GEMM microkernel selection: `auto` (default), `scalar`, `avx2`, `neon` ([`crate::linalg::kernels`]; unavailable requests clamp to `scalar`) |
//! | `PALLAS_BENCH_SOFT`      | `1`/`true`: timing-sensitive bench asserts warn instead of aborting |
//! | `PALLAS_BENCH_TOL`       | multiplier `≥ 1` relaxing timing-sensitive bench thresholds |
//! | `PALLAS_STRESS_ITERS`    | iteration count for the pool stress hammer |
//! | `PALLAS_BENCH_N`         | problem size for single-size benches |
//! | `PALLAS_BENCH_SIZES`     | comma-separated size sweep for the fig benches |
//! | `PALLAS_GEMM_SIZES`      | comma-separated square sizes for the GEMM kernel bench |
//! | `PALLAS_BATCH_N`         | pencil size for the batch-throughput bench |
//! | `PALLAS_BATCH_SIZES`     | comma-separated batch sizes for the batch-throughput bench |
//! | `PALLAS_BENCH_OUT`       | output-path override for the `BENCH_*.json` artifacts |
//! | `PALLAS_SERVE_SHARDS`    | shard count for the serving router ([`crate::serve::ServeConfig`]) |
//! | `PALLAS_SERVE_THREADS`   | worker-pool executors per shard reduction |
//! | `PALLAS_SERVE_QUEUE_CAP` | per-shard submission-queue depth (backpressure bound) |
//! | `PALLAS_SERVE_CACHE_CAP` | result-cache entry bound (`0` disables caching) |
//! | `PALLAS_SERVE_CACHE_BYTES` | result-cache byte bound (keys + stored factors) |
//! | `PALLAS_SERVE_JOBS`      | flood size for the serve bench / `serve-bench` CLI mode |
//! | `PALLAS_SERVE_SIZES`     | comma-separated pencil sizes for the serve flood mix |
//! | `PALLAS_NET_ADDR`        | listen/connect address for the `serve-net` front door (`host:port`, or `unix:/path` for a Unix-domain socket) |
//! | `PALLAS_ADMIT_TIMEOUT_MS`| admission-control deadline for front-door submissions (ms; `0` sheds immediately on a full lane) |
//! | `PALLAS_SHARD_PROCS`     | shard child-process count for the supervised multi-process mode ([`crate::serve::supervisor`]) |
//! | `PALLAS_PROFILE`         | path to a tuned-profile artifact loaded at startup ([`crate::tune::TunedProfile`]; unreadable/corrupt profiles warn and fall back to defaults) |
//! | `PALLAS_TUNE_SIZES`      | comma-separated representative sizes for the `tune` CLI subcommand / autotune bench |
//! | `PALLAS_TUNE_BUDGET`     | traced candidates per size class for the autotuner (floor 1) |

use crate::config::MAX_THREADS;
use crate::linalg::kernels::KernelChoice;

/// Look a knob up by suffix: `PALLAS_<suffix>` first, then the legacy
/// `PARAHT_<suffix>` alias.
pub fn var(suffix: &str) -> Option<String> {
    first_from(|name| std::env::var(name).ok(), suffix)
}

/// Alias-resolution core, with the lookup injected so unit tests never
/// touch (or race on) the real process environment.
fn first_from(get: impl Fn(&str) -> Option<String>, suffix: &str) -> Option<String> {
    get(&format!("PALLAS_{suffix}")).or_else(|| get(&format!("PARAHT_{suffix}")))
}

/// Parse a boolean flag the way the bench knobs always have: `1` or
/// (case-insensitive) `true`; everything else is false.
pub fn parse_flag(s: &str) -> bool {
    s == "1" || s.eq_ignore_ascii_case("true")
}

/// Parse a `usize`, tolerating surrounding whitespace.
pub fn parse_usize(s: &str) -> Option<usize> {
    s.trim().parse().ok()
}

/// Parse an `f64`, tolerating surrounding whitespace.
pub fn parse_f64(s: &str) -> Option<f64> {
    s.trim().parse().ok()
}

/// Parse a comma-separated `usize` list, skipping malformed entries
/// (`"128, 256,junk,512"` → `[128, 256, 512]`).
pub fn parse_usize_list(s: &str) -> Vec<usize> {
    s.split(',').filter_map(parse_usize).collect()
}

/// Worker-team size for the process-global pool (`PALLAS_POOL_THREADS`,
/// total size including the submitting caller), clamped into
/// `[1, MAX_THREADS]`. `None` when unset/unparseable (callers fall back to
/// `available_parallelism`).
pub fn pool_threads() -> Option<usize> {
    var("POOL_THREADS").and_then(|s| parse_usize(&s)).map(|t| t.clamp(1, MAX_THREADS))
}

/// Whether work-assisting dynamic panel scheduling is the process-wide
/// default (`PALLAS_ASSIST`). Read once (and cached) by
/// [`crate::coordinator::assist::Schedule::from_env`]; the per-run
/// `Config::dynamic_schedule` gate and the explicit `*_sched` entry
/// points override it in both directions.
pub fn assist() -> bool {
    var("ASSIST").map(|v| parse_flag(&v)).unwrap_or(false)
}

/// Explicit concurrency-auditor setting (`PALLAS_AUDIT`): `Some(true)` /
/// `Some(false)` when the knob is set, `None` when unset (the
/// audit-capable build then falls back to its compile-time default — on
/// under `--features audit`, off in plain debug builds). Read once (and
/// cached) by `coordinator::audit::active`.
pub fn audit() -> Option<bool> {
    var("AUDIT").map(|v| parse_flag(&v))
}

/// Requested GEMM microkernel (`PALLAS_KERNEL`): `auto`, `scalar`,
/// `avx2` or `neon` (case-insensitive, whitespace-tolerant). Unset or
/// unrecognized spellings fall back to [`KernelChoice::Auto`] — pick the
/// best runtime-supported variant. Read once (and cached) by
/// [`crate::linalg::kernels::process_default`]; the per-run
/// [`crate::config::Config::kernel`] override wins over this knob.
pub fn kernel() -> KernelChoice {
    var("KERNEL").and_then(|s| KernelChoice::parse(&s)).unwrap_or(KernelChoice::Auto)
}

/// Whether the benches run in *soft* mode (`PALLAS_BENCH_SOFT`): the
/// timing-sensitive shape assertions warn instead of aborting.
pub fn bench_soft() -> bool {
    var("BENCH_SOFT").map(|v| parse_flag(&v)).unwrap_or(false)
}

/// Tolerance multiplier for timing thresholds (`PALLAS_BENCH_TOL`,
/// default and floor `1.0`; non-finite or sub-1 values are ignored).
pub fn bench_tol() -> f64 {
    tol_from(var("BENCH_TOL"))
}

fn tol_from(v: Option<String>) -> f64 {
    v.and_then(|s| parse_f64(&s)).filter(|t| t.is_finite() && *t >= 1.0).unwrap_or(1.0)
}

/// Iteration count for the pool stress hammer (`PALLAS_STRESS_ITERS`).
pub fn stress_iters(default: usize) -> usize {
    var("STRESS_ITERS").and_then(|s| parse_usize(&s)).unwrap_or(default)
}

/// Output path for a `BENCH_*.json` artifact (`PALLAS_BENCH_OUT`
/// override, else the bench's default name).
pub fn bench_out(default: &str) -> String {
    var("BENCH_OUT").unwrap_or_else(|| default.to_string())
}

/// Problem size for single-size benches (`PALLAS_BENCH_N`).
pub fn bench_n(default: usize) -> usize {
    var("BENCH_N").and_then(|s| parse_usize(&s)).unwrap_or(default)
}

/// Size sweep for the fig benches (`PALLAS_BENCH_SIZES`); an unset or
/// fully malformed list falls back to the default so a bench never runs on
/// an empty sweep.
pub fn bench_sizes(default: &[usize]) -> Vec<usize> {
    sizes_or(var("BENCH_SIZES"), default)
}

/// Square sizes for the GEMM kernel bench (`PALLAS_GEMM_SIZES`).
pub fn gemm_sizes(default: &[usize]) -> Vec<usize> {
    sizes_or(var("GEMM_SIZES"), default)
}

/// Pencil size for the batch-throughput bench (`PALLAS_BATCH_N`).
pub fn batch_n(default: usize) -> usize {
    var("BATCH_N").and_then(|s| parse_usize(&s)).unwrap_or(default)
}

/// Batch sizes for the batch-throughput bench (`PALLAS_BATCH_SIZES`).
pub fn batch_sizes(default: &[usize]) -> Vec<usize> {
    sizes_or(var("BATCH_SIZES"), default)
}

fn sizes_or(v: Option<String>, default: &[usize]) -> Vec<usize> {
    v.map(|s| parse_usize_list(&s))
        .filter(|l| !l.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

/// Shard count for the serving router (`PALLAS_SERVE_SHARDS`), clamped
/// into `[1, 1024]` (the router's shard budget).
pub fn serve_shards(default: usize) -> usize {
    var("SERVE_SHARDS").and_then(|s| parse_usize(&s)).map(|v| v.clamp(1, 1024)).unwrap_or(default)
}

/// Worker-pool executors per shard reduction (`PALLAS_SERVE_THREADS`),
/// clamped into `[1, MAX_THREADS]`.
pub fn serve_threads(default: usize) -> usize {
    var("SERVE_THREADS")
        .and_then(|s| parse_usize(&s))
        .map(|v| v.clamp(1, MAX_THREADS))
        .unwrap_or(default)
}

/// Per-shard submission-queue depth (`PALLAS_SERVE_QUEUE_CAP`), floor 1.
pub fn serve_queue_cap(default: usize) -> usize {
    var("SERVE_QUEUE_CAP").and_then(|s| parse_usize(&s)).map(|v| v.max(1)).unwrap_or(default)
}

/// Result-cache entry bound (`PALLAS_SERVE_CACHE_CAP`; `0` disables the
/// cache entirely).
pub fn serve_cache_entries(default: usize) -> usize {
    var("SERVE_CACHE_CAP").and_then(|s| parse_usize(&s)).unwrap_or(default)
}

/// Result-cache byte bound (`PALLAS_SERVE_CACHE_BYTES`).
pub fn serve_cache_bytes(default: usize) -> usize {
    var("SERVE_CACHE_BYTES").and_then(|s| parse_usize(&s)).unwrap_or(default)
}

/// Flood size for the serve bench / CLI mode (`PALLAS_SERVE_JOBS`).
pub fn serve_jobs(default: usize) -> usize {
    var("SERVE_JOBS").and_then(|s| parse_usize(&s)).unwrap_or(default)
}

/// Pencil-size mix for the serve flood (`PALLAS_SERVE_SIZES`); an unset
/// or fully malformed list falls back to the default.
pub fn serve_sizes(default: &[usize]) -> Vec<usize> {
    sizes_or(var("SERVE_SIZES"), default)
}

/// Listen/connect address for the network front door (`PALLAS_NET_ADDR`).
/// `host:port` for TCP, or a `unix:` prefix for a Unix-domain socket path
/// — parsed by [`crate::serve::net::NetConfig`], not here.
pub fn net_addr(default: &str) -> String {
    var("NET_ADDR").unwrap_or_else(|| default.to_string())
}

/// Admission-control deadline in milliseconds for front-door submissions
/// (`PALLAS_ADMIT_TIMEOUT_MS`; `0` sheds immediately on a full lane).
pub fn admit_timeout_ms(default: u64) -> u64 {
    var("ADMIT_TIMEOUT_MS").and_then(|s| s.trim().parse().ok()).unwrap_or(default)
}

/// Shard child-process count for the supervised multi-process mode
/// (`PALLAS_SHARD_PROCS`), clamped into `[1, 64]` — each child is a full
/// OS process with its own session, so the budget is much tighter than
/// the in-process shard budget.
pub fn shard_procs(default: usize) -> usize {
    var("SHARD_PROCS").and_then(|s| parse_usize(&s)).map(|v| v.clamp(1, 64)).unwrap_or(default)
}

/// Path of the tuned-profile artifact to load at startup
/// (`PALLAS_PROFILE`). `None` when unset — the untuned defaults. The
/// *loading* (and the warn-and-fall-back policy for unreadable or corrupt
/// artifacts) lives in [`crate::tune::TunedProfile::load_or_warn`], not
/// here.
pub fn profile() -> Option<String> {
    var("PROFILE")
}

/// Representative problem sizes for the autotuner (`PALLAS_TUNE_SIZES`);
/// an unset or fully malformed list falls back to the default so the
/// tuner never runs on an empty class set.
pub fn tune_sizes(default: &[usize]) -> Vec<usize> {
    sizes_or(var("TUNE_SIZES"), default)
}

/// Traced candidates per size class for the autotuner
/// (`PALLAS_TUNE_BUDGET`, floor 1).
pub fn tune_budget(default: usize) -> usize {
    var("TUNE_BUDGET").and_then(|s| parse_usize(&s)).map(|v| v.max(1)).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    // All tests go through the injected-lookup core or the pure parsers —
    // never the real process env, which other tests share.

    fn env_of(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn canonical_name_wins_over_legacy_alias() {
        let env = env_of(&[("PALLAS_BENCH_TOL", "2.0"), ("PARAHT_BENCH_TOL", "9.0")]);
        let got = first_from(|n| env.get(n).cloned(), "BENCH_TOL");
        assert_eq!(got.as_deref(), Some("2.0"));
    }

    #[test]
    fn legacy_alias_is_honored_when_canonical_unset() {
        let env = env_of(&[("PARAHT_BENCH_N", "384")]);
        let got = first_from(|n| env.get(n).cloned(), "BENCH_N");
        assert_eq!(got.as_deref(), Some("384"));
        assert_eq!(first_from(|n| env.get(n).cloned(), "BENCH_SIZES"), None);
    }

    #[test]
    fn flag_parsing() {
        assert!(parse_flag("1"));
        assert!(parse_flag("true"));
        assert!(parse_flag("TRUE"));
        assert!(!parse_flag("0"));
        assert!(!parse_flag(""));
        assert!(!parse_flag("yes"));
    }

    #[test]
    fn assist_knob_resolves_through_the_alias_chain() {
        // The assist knob is `parse_flag` over the standard alias lookup;
        // exercise the composition through the injected core.
        let env = env_of(&[("PARAHT_ASSIST", "true")]);
        let got = first_from(|n| env.get(n).cloned(), "ASSIST");
        assert!(got.map(|v| parse_flag(&v)).unwrap_or(false));
        let env = env_of(&[("PALLAS_ASSIST", "0"), ("PARAHT_ASSIST", "1")]);
        let got = first_from(|n| env.get(n).cloned(), "ASSIST");
        assert!(!got.map(|v| parse_flag(&v)).unwrap_or(false), "canonical 0 wins over legacy 1");
        assert_eq!(first_from(|_| None, "ASSIST"), None, "unset means static default");
    }

    #[test]
    fn audit_knob_is_tri_state() {
        // Set-to-truthy / set-to-falsy / unset must stay distinguishable:
        // the auditor treats unset as "defer to the compile-time default".
        let on = env_of(&[("PALLAS_AUDIT", "1")]);
        assert_eq!(first_from(|n| on.get(n).cloned(), "AUDIT").map(|v| parse_flag(&v)), Some(true));
        let off = env_of(&[("PARAHT_AUDIT", "0")]);
        assert_eq!(
            first_from(|n| off.get(n).cloned(), "AUDIT").map(|v| parse_flag(&v)),
            Some(false),
            "explicitly-off via the legacy alias"
        );
        assert_eq!(first_from(|_| None, "AUDIT").map(|v| parse_flag(&v)), None, "unset defers");
    }

    #[test]
    fn kernel_knob_resolves_through_the_alias_chain() {
        // The kernel knob composes `KernelChoice::parse` over the standard
        // alias lookup; exercise the composition through the injected core.
        let resolve = |env: &HashMap<String, String>| {
            first_from(|n| env.get(n).cloned(), "KERNEL")
                .and_then(|s| KernelChoice::parse(&s))
                .unwrap_or(KernelChoice::Auto)
        };
        let env = env_of(&[("PALLAS_KERNEL", "scalar"), ("PARAHT_KERNEL", "avx2")]);
        assert_eq!(resolve(&env), KernelChoice::Scalar, "canonical wins over legacy");
        let env = env_of(&[("PARAHT_KERNEL", " AVX2 ")]);
        assert_eq!(resolve(&env), KernelChoice::Avx2, "legacy alias, case/space tolerant");
        let env = env_of(&[("PALLAS_KERNEL", "sse9000")]);
        assert_eq!(resolve(&env), KernelChoice::Auto, "unrecognized falls back to auto");
        assert_eq!(resolve(&HashMap::new()), KernelChoice::Auto, "unset is auto");
    }

    #[test]
    fn numeric_parsing_tolerates_whitespace_and_junk() {
        assert_eq!(parse_usize(" 42 "), Some(42));
        assert_eq!(parse_usize("x"), None);
        assert_eq!(parse_f64(" 1.5 "), Some(1.5));
        assert_eq!(parse_usize_list("128, 256,junk,512"), vec![128, 256, 512]);
        assert!(parse_usize_list("nope").is_empty());
    }

    #[test]
    fn tolerance_has_a_floor_of_one() {
        assert_eq!(tol_from(None), 1.0);
        assert_eq!(tol_from(Some("1.5".into())), 1.5);
        assert_eq!(tol_from(Some("0.2".into())), 1.0, "sub-1 tolerances are ignored");
        assert_eq!(tol_from(Some("inf".into())), 1.0, "non-finite tolerances are ignored");
        assert_eq!(tol_from(Some("garbage".into())), 1.0);
    }

    #[test]
    fn net_knobs_parse_and_clamp_through_the_alias_chain() {
        // PALLAS_NET_ADDR resolves through the standard alias lookup.
        let env = env_of(&[("PARAHT_NET_ADDR", "unix:/tmp/pallas.sock")]);
        let got = first_from(|n| env.get(n).cloned(), "NET_ADDR");
        assert_eq!(got.as_deref(), Some("unix:/tmp/pallas.sock"));
        // Admission deadline: plain u64 millis, junk falls back.
        assert_eq!("250".trim().parse::<u64>().ok(), Some(250));
        assert_eq!("junk".trim().parse::<u64>().ok(), None);
        // Shard-process clamp band [1, 64].
        assert_eq!(parse_usize("0").map(|v| v.clamp(1, 64)), Some(1));
        assert_eq!(parse_usize("9000").map(|v| v.clamp(1, 64)), Some(64));
        assert_eq!(parse_usize("4").map(|v| v.clamp(1, 64)), Some(4));
    }

    #[test]
    fn tune_knobs_resolve_through_the_alias_chain() {
        // PALLAS_PROFILE is a plain path passthrough over the alias lookup.
        let env = env_of(&[("PARAHT_PROFILE", "/tmp/pallas_profile.json")]);
        let got = first_from(|n| env.get(n).cloned(), "PROFILE");
        assert_eq!(got.as_deref(), Some("/tmp/pallas_profile.json"));
        assert_eq!(first_from(|_| None, "PROFILE"), None, "unset means untuned defaults");
        // Tune sizes reuse the never-empty sweep rule.
        assert_eq!(sizes_or(Some("48, 96".into()), &[32, 64]), vec![48, 96]);
        assert_eq!(sizes_or(Some("junk".into()), &[32, 64]), vec![32, 64]);
        // Budget floor of 1: a zero budget would trace nothing.
        assert_eq!(parse_usize("0").map(|v| v.max(1)), Some(1));
        assert_eq!(parse_usize("6").map(|v| v.max(1)), Some(6));
    }

    #[test]
    fn size_sweeps_never_come_back_empty() {
        assert_eq!(sizes_or(None, &[128, 256]), vec![128, 256]);
        assert_eq!(sizes_or(Some("64,96".into()), &[128, 256]), vec![64, 96]);
        assert_eq!(
            sizes_or(Some("all junk".into()), &[128, 256]),
            vec![128, 256],
            "a malformed sweep falls back to the default"
        );
    }
}
