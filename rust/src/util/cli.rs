//! A tiny argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch is done by the caller (`main.rs`).

use std::collections::HashMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (excluding argv[0] and the
    /// subcommand name).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Self {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    args.options
                        .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Get an option parsed as `T`, or `default` if absent.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.options
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// Get a required option parsed as `T`.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> crate::Result<T> {
        self.options
            .get(key)
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| crate::Error::config(format!("missing/invalid --{key}")))
    }

    /// Get a string option.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Whether a boolean flag is present.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Parse a comma-separated list option, e.g. `--sizes 250,500,1000`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
    {
        match self.options.get(key) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .filter_map(|p| p.trim().parse().ok())
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_kv_and_flags() {
        // NOTE: a bare `--flag` followed by a non-dashed token is parsed as
        // an option with that value; put flags last or use `--k=v` forms.
        let a = parse("reduce file.txt --n 500 --r=16 --verbose");
        assert_eq!(a.positional, vec!["reduce", "file.txt"]);
        assert_eq!(a.get::<usize>("n", 0), 500);
        assert_eq!(a.get::<usize>("r", 0), 16);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.get::<usize>("n", 42), 42);
        assert_eq!(a.get_str("mode", "native"), "native");
        assert!(!a.has_flag("x"));
    }

    #[test]
    fn lists() {
        let a = parse("--sizes 1,2,3");
        assert_eq!(a.get_list::<usize>("sizes", &[9]), vec![1, 2, 3]);
        assert_eq!(a.get_list::<usize>("other", &[9]), vec![9]);
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse("--check --n 10");
        assert!(a.has_flag("check"));
        assert_eq!(a.get::<usize>("n", 0), 10);
    }
}
