//! Global flop accounting.
//!
//! The paper's cost analysis (§2.2: `(28p+14)/(3(p-1)) n³` for stage 1,
//! §3.1: `10 n³` for stage 2, `14 n³` for one-stage Moler-Stewart) is
//! reproduced by `benches/table_flops.rs` from *measured* counts. The
//! counters are cheap (one relaxed atomic add per block operation, never per
//! scalar) and enabled by default; `set_enabled(false)` removes even that.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static FLOPS: AtomicU64 = AtomicU64::new(0);
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Add `n` flops to the global counter (no-op when disabled).
#[inline]
pub fn add(n: u64) {
    if ENABLED.load(Ordering::Relaxed) {
        FLOPS.fetch_add(n, Ordering::Relaxed);
    }
}

/// Read the current counter.
pub fn get() -> u64 {
    FLOPS.load(Ordering::Relaxed)
}

/// Reset the counter to zero.
pub fn reset() {
    FLOPS.store(0, Ordering::Relaxed);
}

/// Enable/disable accounting.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether accounting is currently enabled (for save/restore around
/// measurements that must not leak a global toggle).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Count flops of a closure: resets, runs, returns (result, flops).
pub fn count<R>(f: impl FnOnce() -> R) -> (R, u64) {
    let before = get();
    let r = f();
    (r, get() - before)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_count() {
        set_enabled(true);
        let (_, n) = count(|| {
            add(123);
            add(7);
        });
        assert_eq!(n, 130);
    }
}
