//! Small utilities shared across the crate: deterministic RNG, timers,
//! flop accounting, a tiny CLI argument parser and a property-test helper.

pub mod cli;
pub mod flops;
pub mod proptest;
pub mod rng;
pub mod timer;
