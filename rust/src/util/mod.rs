//! Small utilities shared across the crate: deterministic RNG, timers,
//! flop accounting, centralized env-var handling, a tiny CLI argument
//! parser and a property-test helper.

pub mod cli;
pub mod env;
pub mod flops;
pub mod proptest;
pub mod rng;
pub mod timer;
