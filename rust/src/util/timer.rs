//! Wall-clock timing helpers used by the bench harness and the scheduler's
//! task-cost replay calibration.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed duration since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

/// Run `f` repeatedly until `min_time` seconds have elapsed (at least
/// `min_reps` repetitions) and report the *minimum* per-rep time — the
/// standard low-noise estimator for micro/mesobenchmarks.
pub fn bench_min<R>(min_reps: usize, min_time: f64, mut f: impl FnMut() -> R) -> f64 {
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    let mut reps = 0;
    loop {
        let t = Timer::start();
        std::hint::black_box(f());
        let dt = t.secs();
        best = best.min(dt);
        total += dt;
        reps += 1;
        if reps >= min_reps && total >= min_time {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
    }

    #[test]
    fn bench_min_runs() {
        let mut n = 0u64;
        let best = bench_min(3, 0.0, || {
            n += 1;
            n
        });
        assert!(n >= 3);
        assert!(best >= 0.0);
    }
}
