//! `paraht` — CLI launcher for the parallel two-stage Hessenberg-triangular
//! reduction.
//!
//! ```text
//! paraht reduce      --n 512 [--saddle] [--r 16 --p 8 --q 8] [--threads T]
//!                    [--mode seq|par|sim] [--check]
//! paraht experiment  fig9a|fig9b|fig10|fig11|flops|ablations [--n N]
//!                    [--sizes a,b,c] [--threads T]
//! paraht serve-bench [--jobs J] [--unique U] [--sizes a,b,c] [--shards N]
//!                    [--shard-threads M] [--queue-cap C] [--cache-cap K]
//! paraht serve-net   [--addr HOST:PORT|unix:PATH] [--acceptors N]
//!                    [--procs P] [--stats] [serve-bench geometry args]
//! paraht tune        [--sizes a,b,c] [--threads T] [--budget K] [--seed S]
//!                    [--r 16 --p 8 --q 8] [--out pallas_profile.json]
//! paraht validate    [--pjrt]
//! paraht info
//! ```
//!
//! The hidden `--shard-worker` mode (handled before normal argument
//! parsing) turns this binary into a frame-protocol worker on
//! stdin/stdout for [`paraht::serve::ShardSupervisor`] — it is spawned
//! by a supervising parent, not invoked by people.

use paraht::api::HtSession;
use paraht::config::Config;
use paraht::coordinator::driver::paraht_curve;
use paraht::experiments::{ablations, common, figures, flops_table};
use paraht::pencil::random::random_pencil;
use paraht::pencil::saddle::saddle_pencil;
use paraht::pencil::Pencil;
use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
use paraht::util::cli::Args;
use paraht::util::rng::Rng;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // Worker mode must win before any other parsing: the supervisor
    // re-invokes this very binary with `--shard-worker`, and the worker
    // must never print banners or parse job-count flags — its stdin and
    // stdout belong to the frame protocol.
    if raw.iter().any(|a| a == "--shard-worker") {
        std::process::exit(paraht::serve::worker_main());
    }
    let args = Args::parse(raw);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "reduce" => cmd_reduce(&args),
        "experiment" => cmd_experiment(&args),
        "serve-bench" => cmd_serve_bench(&args),
        "serve-net" => cmd_serve_net(&args),
        "tune" => cmd_tune(&args),
        "validate" => cmd_validate(&args),
        "info" => cmd_info(),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn config_from(args: &Args) -> Config {
    Config {
        r: args.get("r", 16),
        p: args.get("p", 8),
        q: args.get("q", 8),
        threads: args.get("threads", 4),
        slices: args.get("slices", 0),
        ..Config::default()
    }
}

fn cmd_reduce(args: &Args) -> i32 {
    let n = args.get("n", 512usize);
    let seed = args.get("seed", 0x5EEDu64);
    let cfg = config_from(args);
    let mode = args.get_str("mode", "par");
    let mut rng = Rng::new(seed);
    let pencil = if args.has_flag("saddle") {
        saddle_pencil(n, 0.25, &mut rng)
    } else {
        random_pencil(n, &mut rng)
    };
    println!(
        "reducing {} pencil n={n} (r={}, p={}, q={}, threads={}, mode={mode})",
        if args.has_flag("saddle") { "saddle-point" } else { "random" },
        cfg.r,
        cfg.p,
        cfg.q,
        cfg.threads
    );

    let builder = HtSession::builder().config(cfg.clone());
    let builder = match mode.as_str() {
        "seq" => builder.threads(1),
        "par" => builder,
        "sim" => builder.capture_traces(true),
        other => {
            eprintln!("unknown --mode {other}");
            return 2;
        }
    };
    let mut session = match builder.build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let run = match session.reduce(&pencil.a, &pencil.b) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "stage 1: {:.3}s   stage 2: {:.3}s   total: {:.3}s",
        run.stage1_secs,
        run.stage2_secs,
        run.total_secs()
    );
    if let Some(traces) = session.take_traces() {
        let ps = common::PAPER_THREADS;
        let curve = paraht_curve(&traces, ps);
        println!("simulated speedups (vs own 1-core):");
        for (p, t) in &curve.points {
            println!("  P={p:<3} makespan {:.3}s  speedup {:.2}x", t, curve.t1 / t);
        }
    }
    if args.has_flag("check") {
        let v = run.verify(&pencil.a, &pencil.b);
        println!(
            "verification: err_A {:.2e}  err_B {:.2e}  orth(Q) {:.2e}  orth(Z) {:.2e}",
            v.err_a, v.err_b, v.orth_q, v.orth_z
        );
        if v.worst() > 1e-10 {
            eprintln!("FAILED verification");
            return 1;
        }
        println!("verification OK (machine-precision backward error)");
    }
    0
}

fn cmd_experiment(args: &Args) -> i32 {
    let which = args.positional.get(1).map(String::as_str).unwrap_or("");
    let seed = args.get("seed", 42u64);
    match which {
        "fig9a" => {
            let n = args.get("n", 384usize);
            let series = figures::fig9a(n, seed);
            let header: Vec<String> =
                common::PAPER_THREADS.iter().map(|p| format!("P={p}")).collect();
            let rows = series
                .iter()
                .map(|s| (s.name.to_string(), s.points.iter().map(|&(_, v)| v).collect()))
                .collect::<Vec<_>>();
            common::print_table(
                &format!("Fig 9a — speedup over sequential LAPACK, random pencil n={n}"),
                &header,
                &rows,
            );
        }
        "fig9b" | "fig11" => {
            let sizes = args.get_list("sizes", &[128usize, 256, 384, 512]);
            let threads = args.get("threads", 28usize);
            let rows = if which == "fig9b" {
                figures::fig9b(&sizes, threads, seed)
            } else {
                figures::fig11(&sizes, threads, seed)
            };
            let header = vec!["/LAPACK".into(), "/HouseHT".into(), "/IterHT".into()];
            let trows = rows
                .iter()
                .map(|r| {
                    (format!("n={}", r.n), vec![r.over_lapack, r.over_househt, r.over_iterht])
                })
                .collect::<Vec<_>>();
            common::print_table(
                &format!(
                    "Fig {} — ParaHT speedup over comparators ({} pencils, P={threads})",
                    if which == "fig9b" { "9b" } else { "11" },
                    if which == "fig9b" { "random" } else { "saddle-point" }
                ),
                &header,
                &trows,
            );
        }
        "fig10" => {
            let sizes = args.get_list("sizes", &[192usize, 384]);
            let data = figures::fig10(&sizes, seed);
            for d in &data {
                let header: Vec<String> =
                    common::PAPER_THREADS.iter().map(|p| format!("P={p}")).collect();
                let rows = vec![
                    ("stage 1 speedup".to_string(), d.speedups.iter().map(|x| x.1).collect()),
                    ("stage 2 speedup".to_string(), d.speedups.iter().map(|x| x.2).collect()),
                    ("total speedup".to_string(), d.speedups.iter().map(|x| x.3).collect()),
                ];
                common::print_table(&format!("Fig 10 — phase speedups, n={}", d.n), &header, &rows);
                println!(
                    "relative runtime: stage1 {:.1}%  stage2 {:.1}%",
                    100.0 * d.stage1_fraction,
                    100.0 * d.stage2_fraction
                );
            }
        }
        "flops" => {
            let sizes = args.get_list("sizes", &[192usize, 320, 448]);
            let (r, p, q) = (args.get("r", 8), args.get("p", 4), args.get("q", 4));
            let rows = flops_table::measure(&sizes, r, p, q, seed);
            println!("\n== Flop-count table (measured / n^3; p={p}) ==");
            println!(
                "{:<8}{:>10}{:>10}{:>12}{:>12}",
                "n", "stage1", "stage2", "two-stage", "one-stage"
            );
            for row in &rows {
                println!(
                    "{:<8}{:>10.2}{:>10.2}{:>12.2}{:>12.2}",
                    row.n,
                    row.stage1,
                    row.stage2,
                    row.stage1 + row.stage2,
                    row.one_stage
                );
            }
            println!(
                "paper:  {:>8.2}{:>10.2}{:>12.2}{:>12.2}  (formulas at p={p})",
                flops_table::stage1_coeff(p),
                10.0,
                flops_table::stage1_coeff(p) + 10.0,
                14.0
            );
        }
        "ablations" => {
            let n = args.get("n", 256usize);
            println!("\n== p sweep (stage 1, n={n}) ==");
            for (p, secs, coeff) in ablations::p_sweep(n, 8, &[2, 4, 8, 12], seed) {
                println!("  p={p:<3} {secs:.3}s   flops/n^3 = {coeff:.2}");
            }
            println!("\n== q sweep (stage 2, n={n}; q=0 is unblocked Alg 2) ==");
            for (q, secs) in ablations::q_sweep(n, 8, &[2, 4, 8, 16], seed) {
                println!("  q={q:<3} {secs:.3}s");
            }
            let cfg = Config { r: 8, q: 4, ..Config::default() };
            let (with_look, without) = ablations::lookahead_ablation(n, &cfg, 14, seed);
            println!("\n== lookahead (stage 2, n={n}, P=14) ==");
            println!("  with lookahead:    {with_look:.4}s");
            println!("  without lookahead: {without:.4}s");
        }
        other => {
            eprintln!("unknown experiment '{other}' (fig9a|fig9b|fig10|fig11|flops|ablations)");
            return 2;
        }
    }
    0
}

/// Flood the serving tier (router → queue → cache) with a mixed-size
/// pencil stream and report throughput plus shard/cache counters. The
/// `--unique` knob controls duplication: `--jobs 200 --unique 25` submits
/// each distinct pencil 8 times, so the expected cache hit rate is 87.5%.
fn cmd_serve_bench(args: &Args) -> i32 {
    use std::time::Instant;
    let seed = args.get("seed", 0x5EEDu64);
    let jobs = args.get("jobs", paraht::util::env::serve_jobs(200)).max(1);
    let env_sizes = paraht::util::env::serve_sizes(&[16, 24, 32, 48]);
    let sizes = args.get_list("sizes", &env_sizes);
    let sizes = if sizes.is_empty() { env_sizes } else { sizes };
    let unique = args.get("unique", jobs.min(32)).clamp(1, jobs);

    let mut scfg = ServeConfig::from_env();
    scfg.shards = args.get("shards", scfg.shards);
    scfg.threads_per_shard = args.get("shard-threads", scfg.threads_per_shard);
    scfg.queue_capacity = args.get("queue-cap", scfg.queue_capacity);
    scfg.cache_entries = args.get("cache-cap", scfg.cache_entries);
    scfg.base = Config {
        r: args.get("r", 8),
        p: args.get("p", 4),
        q: args.get("q", 4),
        ..Config::default()
    };
    println!(
        "serve-bench: {jobs} jobs over {unique} distinct pencils (sizes {sizes:?}), \
         {} shards x {} threads, queue cap {}, cache cap {}",
        scfg.shards, scfg.threads_per_shard, scfg.queue_capacity, scfg.cache_entries
    );

    let router = match ShardRouter::new(scfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let queue = SubmitQueue::new(router);
    let handle = queue.handle();

    let mut rng = Rng::new(seed);
    let pool: Vec<Pencil> =
        (0..unique).map(|i| random_pencil(sizes[i % sizes.len()], &mut rng)).collect();

    let t = Instant::now();
    let tickets: Vec<_> = (0..jobs)
        .map(|i| {
            let p = &pool[i % unique];
            handle.submit(p.a.clone(), p.b.clone()).expect("flood submission accepted")
        })
        .collect();
    let mut failed = 0usize;
    for ticket in tickets {
        if ticket.wait().is_err() {
            failed += 1;
        }
    }
    let secs = t.elapsed().as_secs_f64();

    let rstats = queue.router().stats();
    let qstats = queue.stats();
    println!(
        "{jobs} jobs in {secs:.3}s  ->  {:.1} pencils/sec  ({failed} failed)",
        jobs as f64 / secs
    );
    println!("reduced per shard: {:?}", rstats.reduced_per_shard);
    // One atomic snapshot under the cache lock — the hit/miss/entry
    // numbers printed here are from a single consistent instant.
    if let Some(c) = queue.router().cache_stats() {
        println!(
            "cache: {} hits / {} misses (hit rate {:.1}%), {} entries, {} evictions",
            c.hits,
            c.misses,
            100.0 * c.hit_rate(),
            c.entries,
            c.evictions
        );
    }
    println!(
        "queue: {} submitted, {} completed, {} rejected, {} shed",
        qstats.submitted, qstats.completed, qstats.rejected, qstats.shed
    );
    for (class, h) in queue.latency_snapshot() {
        if h.count == 0 {
            continue;
        }
        println!(
            "latency[{}]: n={}  p50 {:.2}ms  p90 {:.2}ms  p99 {:.2}ms  mean {:.2}ms",
            class.label(),
            h.count,
            h.p50_ms(),
            h.p90_ms(),
            h.p99_ms(),
            h.mean_ms()
        );
    }
    queue.shutdown();
    if failed > 0 {
        1
    } else {
        0
    }
}

/// Serve the reduction tier over a socket (`--addr`, default from
/// `PALLAS_NET_ADDR`), backed either by the in-process queue (default)
/// or, with `--procs P` (or `PALLAS_SHARD_PROCS`), by supervised
/// per-size-class child processes. `--stats` connects as a client
/// instead and prints the server's statistics JSON.
fn cmd_serve_net(args: &Args) -> i32 {
    use paraht::serve::{NetClient, NetConfig, NetServer, ShardSupervisor, SupervisorConfig};

    let mut ncfg = NetConfig::from_env();
    ncfg.addr = args.get_str("addr", &ncfg.addr);
    ncfg.acceptors = args.get("acceptors", ncfg.acceptors);

    if args.has_flag("stats") {
        return match NetClient::connect(&ncfg.addr).and_then(|mut c| c.stats()) {
            Ok(json) => {
                println!("{json}");
                0
            }
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        };
    }

    let procs = args.get("procs", paraht::util::env::shard_procs(0));
    let server = if procs > 0 {
        let mut sup = SupervisorConfig::from_env();
        sup.procs = procs;
        sup.threads_per_proc = args.get("shard-threads", sup.threads_per_proc);
        sup.base = Config {
            r: args.get("r", sup.base.r),
            p: args.get("p", sup.base.p),
            q: args.get("q", sup.base.q),
            ..sup.base
        };
        println!(
            "serve-net: {} supervised worker processes x {} threads",
            sup.procs, sup.threads_per_proc
        );
        ShardSupervisor::new(sup).and_then(|s| NetServer::start_supervised(s, ncfg))
    } else {
        let mut scfg = ServeConfig::from_env();
        scfg.shards = args.get("shards", scfg.shards);
        scfg.threads_per_shard = args.get("shard-threads", scfg.threads_per_shard);
        scfg.queue_capacity = args.get("queue-cap", scfg.queue_capacity);
        scfg.cache_entries = args.get("cache-cap", scfg.cache_entries);
        println!(
            "serve-net: {} in-process shards x {} threads, queue cap {}, cache cap {}",
            scfg.shards, scfg.threads_per_shard, scfg.queue_capacity, scfg.cache_entries
        );
        ShardRouter::new(scfg).map(SubmitQueue::new).and_then(|q| NetServer::start(q, ncfg))
    };
    let server = match server {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "listening on {0} — query with `paraht serve-net --stats --addr {0}`",
        server.addr()
    );
    // Park forever: this process serves until killed. A ^C never runs
    // the server's Drop, which is fine — supervised workers exit on
    // stdin EOF, their documented shutdown path.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Record traces, search the geometry space, and write the tuned-profile
/// artifact in one run ([`paraht::tune`]). Point a serving tier at the
/// result with `PALLAS_PROFILE=<out>`.
fn cmd_tune(args: &Args) -> i32 {
    use paraht::tune::{Autotuner, TuneOptions};
    let out = args.get_str("out", "pallas_profile.json");
    let base = Config {
        r: args.get("r", 16),
        p: args.get("p", 8),
        q: args.get("q", 8),
        slices: args.get("slices", 0),
        ..Config::default()
    };
    let d = TuneOptions::default();
    let env_sizes = paraht::util::env::tune_sizes(&d.sizes);
    let opts = TuneOptions {
        sizes: args.get_list("sizes", &env_sizes),
        threads: args.get("threads", d.threads),
        budget: args.get("budget", paraht::util::env::tune_budget(d.budget)),
        seed: args.get("seed", d.seed),
    };
    let tuner = match Autotuner::new(base, opts) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!("tuning: tracing candidate geometries and replaying through the makespan simulator...");
    let (profile, reports) = match tuner.run() {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "{:<16}{:>5}{:>4}{:>4}{:>8}{:>9}{:>13}{:>13}{:>7}",
        "class", "r", "p", "q", "slices", "threads", "default(s)", "tuned(s)", "cands"
    );
    for (c, rep) in profile.classes.iter().zip(&reports) {
        let range = if c.n_max == 0 {
            format!("[{}, inf)", c.n_min)
        } else {
            format!("[{}, {}]", c.n_min, c.n_max)
        };
        println!(
            "{:<16}{:>5}{:>4}{:>4}{:>8}{:>9}{:>13.6}{:>13.6}{:>7}",
            range,
            c.r,
            c.p,
            c.q,
            c.slices,
            c.threads,
            rep.default_predicted,
            c.predicted_makespan,
            rep.candidates
        );
    }
    if let Err(e) = profile.save(&out) {
        eprintln!("error writing {out}: {e}");
        return 1;
    }
    println!("wrote {out} — serve with PALLAS_PROFILE={out}");
    0
}

fn cmd_validate(args: &Args) -> i32 {
    let n = args.get("n", 200usize);
    let mut rng = Rng::new(7);
    let pencil = random_pencil(n, &mut rng);
    let cfg = Config { r: 16, p: 8, q: 8, threads: 4, ..Config::default() };
    println!("validating ParaHT on random pencil n={n}...");
    let mut session = HtSession::builder().config(cfg).build().unwrap();
    let run = session.reduce(&pencil.a, &pencil.b).unwrap();
    let v = run.verify(&pencil.a, &pencil.b);
    println!(
        "  err_A {:.2e}  err_B {:.2e}  orth(Q) {:.2e}  orth(Z) {:.2e}  H-band {:.2e}  T-band {:.2e}",
        v.err_a, v.err_b, v.orth_q, v.orth_z, v.hess_residual, v.tri_residual
    );
    if v.worst() > 1e-10 {
        eprintln!("FAILED");
        return 1;
    }
    if args.has_flag("pjrt") {
        println!("validating PJRT offload parity...");
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match paraht::runtime::PjrtRuntime::load(&dir) {
            Ok(rt) => {
                let off = paraht::runtime::WyOffload::new(&rt);
                let a = paraht::Matrix::randn(128, 16, &mut rng);
                let wy = paraht::linalg::qr::QrFactor::compute_inplace(a).wy();
                let c0 = paraht::Matrix::randn(128, 200, &mut rng);
                let mut native = c0.clone();
                wy.apply(
                    paraht::linalg::Side::Left,
                    paraht::linalg::Trans::Yes,
                    native.as_mut(),
                );
                let mut offl = c0.clone();
                off.apply_left_t(&wy, offl.as_mut()).unwrap();
                let mut d = 0.0f64;
                for j in 0..200 {
                    for i in 0..128 {
                        d = d.max((native[(i, j)] - offl[(i, j)]).abs());
                    }
                }
                println!("  native vs PJRT max deviation: {d:.2e}");
                if d > 1e-12 {
                    eprintln!("PJRT parity FAILED");
                    return 1;
                }
            }
            Err(e) => {
                eprintln!("  could not load artifacts ({e}); run `make artifacts`");
                return 1;
            }
        }
    }
    println!("validation OK");
    0
}

fn cmd_info() -> i32 {
    println!(
        "paraht {} — parallel two-stage Hessenberg-triangular reduction",
        env!("CARGO_PKG_VERSION")
    );
    println!("paper: Steel & Vandebril, 2023");
    println!("defaults: r=16 p=8 q=8 (paper §4 tuning)");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match paraht::runtime::manifest::load_manifest(&dir) {
        Ok(specs) => {
            println!("artifacts ({}):", specs.len());
            for s in specs {
                println!("  {:<24} {:?} C={}x{} k={}", s.name, s.kind, s.m, s.n, s.k);
            }
        }
        Err(_) => println!("artifacts: not built (run `make artifacts`)"),
    }
    0
}

fn print_help() {
    println!(
        "paraht — parallel two-stage Hessenberg-triangular reduction\n\
         \n\
         USAGE:\n\
           paraht reduce      --n 512 [--saddle] [--r 16 --p 8 --q 8] [--threads T] [--mode seq|par|sim] [--check]\n\
           paraht experiment  fig9a|fig9b|fig10|fig11|flops|ablations [--n N] [--sizes a,b,c] [--threads T]\n\
           paraht serve-bench [--jobs J] [--unique U] [--sizes a,b,c] [--shards N] [--shard-threads M] [--queue-cap C] [--cache-cap K]\n\
           paraht serve-net   [--addr HOST:PORT|unix:PATH] [--acceptors N] [--procs P] [--stats] [geometry args as serve-bench]\n\
           paraht tune        [--sizes a,b,c] [--threads T] [--budget K] [--seed S] [--r 16 --p 8 --q 8] [--out pallas_profile.json]\n\
           paraht validate    [--pjrt] [--n N]\n\
           paraht info"
    );
}
