//! The front-door API: [`HtSession`], a builder-configured, long-lived
//! reduction session.
//!
//! The two-stage algorithm earns its parallel speed by amortizing setup —
//! a persistent worker team, hot per-worker GEMM pack buffers, reusable
//! reflector arenas. A session is the API-level expression of the same
//! idea: configure once with [`HtSession::builder`], then call
//! [`HtSession::reduce`] (one pencil, bitwise-identical to the sequential
//! oracle) or [`HtSession::reduce_batch`] (many independent pencils, one
//! per worker) as many times as needed. The session owns the resolved pool
//! handle and the per-`n` workspaces (panel plans, sweep groups, reflector
//! arenas), so repeat reductions skip every piece of setup that does not
//! depend on the matrix *values*.
//!
//! Telemetry goes through the [`TraceSink`] trait instead of the old
//! `ExecMode` enum-threading: the default [`NoopSink`] keeps threaded
//! execution, while a [`TraceRecorder`] (or [`HtSessionBuilder::capture_traces`])
//! switches the coordinator to sequential per-task-timed execution and
//! records [`TaskTrace`]s for the makespan simulator — exactly what
//! `ExecMode::Trace` used to do.
//!
//! The example below runs as a doctest (sequential session — a doctest
//! process should not spawn a worker team); swap `.threads(1)` for
//! `.threads(4)` to run the coordinator graphs on the persistent pool,
//! with bitwise-identical results:
//!
//! ```
//! use paraht::api::HtSession;
//! # use paraht::pencil::random::random_pencil;
//! # use paraht::util::rng::Rng;
//! let mut rng = Rng::new(1);
//! let p1 = random_pencil(64, &mut rng);
//! let p2 = random_pencil(64, &mut rng);
//! let mut session = HtSession::builder().threads(1).band(8).block(4).group(4).build().unwrap();
//! let d1 = session.reduce(&p1.a, &p1.b).unwrap(); // the sequential oracle
//! let d2 = session.reduce(&p2.a, &p2.b).unwrap(); // same warm session
//! assert!(d1.verify(&p1.a, &p1.b).worst() < 1e-10);
//! assert!(d2.verify(&p2.a, &p2.b).worst() < 1e-10);
//! ```

use crate::config::Config;
use crate::coordinator::graph::TaskTrace;
use crate::coordinator::access::MatId;
use crate::coordinator::pool::{self, WorkerPool};
use crate::coordinator::slices::SharedMat;
use crate::coordinator::stage1_par::{self, Stage1Arena};
use crate::coordinator::stage2_par::{self, sweep_groups, Stage2Arena};
use crate::error::{Error, Result};
use crate::ht::stage1::{panel_plans, PanelPlan};
use crate::linalg::matrix::Matrix;
use crate::linalg::verify::max_below_band;
use crate::pencil::random::pre_triangularize;
use crate::pencil::Pencil;
use crate::tune::profile::{ProfileHandle, TunedProfile};
use crate::util::timer::Timer;
use std::sync::{Arc, Mutex};

pub use crate::ht::two_stage::HtDecomposition;

/// Reduce one pencil with the sequential two-stage oracle — the free-
/// function form of [`HtSession::reduce`] at `threads = 1`.
///
/// `b` need not be triangular: a QR-based pre-triangularization is applied
/// first (accumulated into `Q`). This is the bitwise reference every
/// parallel execution path is pinned to by `tests/equivalence.rs`; the
/// deprecated `ht::reduce_to_hessenberg_triangular` shim delegates here
/// unchanged.
pub fn reduce_seq(a: &Matrix, b: &Matrix, cfg: &Config) -> Result<HtDecomposition> {
    let n = a.rows();
    check_pencil_shape(a, b)?;
    cfg.validate_for(n)?;
    // Every GEMM below (and in anything this call nests) runs under the
    // config's resolved microkernel; restored on return or unwind.
    let _kernel = crate::linalg::kernels::enter(cfg.resolved_kernel());
    let (mut h, mut t, mut q, mut z) = prepare_pencil(a, b);

    let t1 = Timer::start();
    crate::ht::stage1::reduce_to_banded(&mut h, &mut t, &mut q, &mut z, cfg);
    let stage1_secs = t1.secs();

    let t2 = Timer::start();
    crate::ht::stage2_blocked::reduce_blocked(&mut h, &mut t, &mut q, &mut z, cfg.r, cfg.q);
    let stage2_secs = t2.secs();

    Ok(HtDecomposition { h, t, q, z, stage1_secs, stage2_secs })
}

/// Shared reduction prologue: clone the pencil into working factors with
/// fresh accumulators, pre-triangularizing `B` if needed (not counted as a
/// stage; LAPACK users run dgeqrf+dormqr ahead of dgghd3 the same way).
/// Keeping the trigger in exactly one place protects the bitwise
/// oracle-equivalence contract between the sequential and graph paths.
fn prepare_pencil(a: &Matrix, b: &Matrix) -> (Matrix, Matrix, Matrix, Matrix) {
    let n = a.rows();
    let mut h = a.clone();
    let mut t = b.clone();
    let mut q = Matrix::identity(n);
    let z = Matrix::identity(n);
    if max_below_band(&t, 0) != 0.0 {
        pre_triangularize(&mut h, &mut t, &mut q);
    }
    (h, t, q, z)
}

fn check_pencil_shape(a: &Matrix, b: &Matrix) -> Result<()> {
    let n = a.rows();
    if a.cols() != n || b.rows() != n || b.cols() != n {
        return Err(Error::shape(format!(
            "pencil must be square and consistent: A {}x{}, B {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    Ok(())
}

/// One completed reduction, as reported to a [`TraceSink`].
#[derive(Clone)]
pub struct ReduceReport {
    /// Problem size.
    pub n: usize,
    /// Wall-clock seconds spent in stage 1.
    pub stage1_secs: f64,
    /// Wall-clock seconds spent in stage 2.
    pub stage2_secs: f64,
    /// Per-task traces of (stage 1, stage 2) — present only when the
    /// session captures traces (see [`TraceSink::wants_task_traces`]).
    pub traces: Option<(TaskTrace, TaskTrace)>,
    /// Whether this reduction ran as part of a [`HtSession::reduce_batch`]
    /// call (batch jobs never carry task traces).
    pub batched: bool,
}

/// Observer for per-reduction telemetry — the pluggable replacement for
/// threading `ExecMode::Trace` through every entry point.
///
/// Implementations decide two things: whether the session should run the
/// coordinator graphs *sequentially with per-task timing* so that
/// [`TaskTrace`]s exist ([`TraceSink::wants_task_traces`]), and what to do
/// with each completed reduction ([`TraceSink::on_reduce`]).
pub trait TraceSink: Send {
    /// Whether the session should capture per-task traces. Returning
    /// `true` forces sequential (timed) graph execution — the semantics of
    /// the old `ExecMode::Trace`. The default is `false`: threaded
    /// execution, phase timings only.
    fn wants_task_traces(&self) -> bool {
        false
    }

    /// Called once per completed reduction (including once per pencil of a
    /// batch).
    fn on_reduce(&mut self, report: &ReduceReport);
}

/// The default sink: ignores every report, keeps threaded execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn on_reduce(&mut self, _report: &ReduceReport) {}
}

/// A recording sink with shared interior: clone it, hand one clone to
/// [`HtSessionBuilder::trace`], and read [`TraceRecorder::reports`] from
/// the other after reducing. Requests task traces, so sessions carrying a
/// recorder run the coordinator sequentially with per-task timing (the
/// simulator-calibration mode).
#[derive(Clone, Default)]
pub struct TraceRecorder {
    inner: Arc<Mutex<Vec<ReduceReport>>>,
}

impl TraceRecorder {
    /// New, empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of every report recorded so far.
    pub fn reports(&self) -> Vec<ReduceReport> {
        self.inner.lock().unwrap().clone()
    }

    /// Number of recorded reports.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// Whether nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl TraceSink for TraceRecorder {
    fn wants_task_traces(&self) -> bool {
        true
    }

    fn on_reduce(&mut self, report: &ReduceReport) {
        self.inner.lock().unwrap().push(report.clone());
    }
}

/// Stage wall-clock times of one reduction (the cheap always-on log behind
/// [`HtSession::phases`]).
#[derive(Clone, Copy, Debug)]
pub struct PhaseTiming {
    /// Problem size.
    pub n: usize,
    /// Wall-clock seconds spent in stage 1.
    pub stage1_secs: f64,
    /// Wall-clock seconds spent in stage 2.
    pub stage2_secs: f64,
}

/// Per-`n` reusable workspace: everything a reduction sets up that depends
/// only on the problem *geometry*, not the matrix values.
struct Workspace {
    n: usize,
    /// The geometry the plans below were built for: a profile hot-swap
    /// can change `r`/`p`/`q` at an unchanged `n`, so staleness is
    /// keyed on all four.
    r: usize,
    p: usize,
    q: usize,
    /// Stage-1 panel plans (`panel_plans(n, r, p)`).
    plans: Vec<PanelPlan>,
    /// Stage-2 sweep groups (`sweep_groups(n, q)`).
    groups: Vec<(usize, usize)>,
    /// Stage-1 reflector slot arena (reset between runs).
    arena1: Stage1Arena,
    /// Stage-2 reflector-store + WY-cache arena (reset between runs).
    arena2: Stage2Arena,
}

/// Builder for [`HtSession`] — consumes and validates the [`Config`] once.
///
/// Built with [`HtSession::builder`]; every method takes and returns the
/// builder by value, so calls chain (runnable: a `threads(1)` build never
/// touches the worker pool):
///
/// ```
/// # use paraht::api::HtSession;
/// let session = HtSession::builder().threads(1).band(8).block(4).group(4).build().unwrap();
/// assert_eq!(session.config().r, 8);
/// ```
pub struct HtSessionBuilder {
    cfg: Config,
    clip_band: bool,
    capture: bool,
    sink: Option<Box<dyn TraceSink>>,
    profile: Option<ProfileHandle>,
}

impl HtSessionBuilder {
    /// Replace the whole configuration (other setters refine it).
    pub fn config(mut self, cfg: Config) -> Self {
        self.cfg = cfg;
        self
    }

    /// Number of worker threads (caller + pool helpers). `1` runs the
    /// sequential oracle path.
    pub fn threads(mut self, threads: usize) -> Self {
        self.cfg.threads = threads;
        self
    }

    /// Stage-1 target bandwidth / panel width `r` (= the paper's `n_b`).
    pub fn band(mut self, r: usize) -> Self {
        self.cfg.r = r;
        self
    }

    /// Stage-1 block-height multiplier `p` (QR blocks are `p·r × r`).
    pub fn block(mut self, p: usize) -> Self {
        self.cfg.p = p;
        self
    }

    /// Stage-2 sweep-group size `q`.
    pub fn group(mut self, q: usize) -> Self {
        self.cfg.q = q;
        self
    }

    /// Number of row/column slices per apply task (0 = auto).
    pub fn slices(mut self, slices: usize) -> Self {
        self.cfg.slices = slices;
        self
    }

    /// Enable/disable stage-2 lookahead tasks (ablation switch).
    pub fn lookahead(mut self, on: bool) -> Self {
        self.cfg.lookahead = on;
        self
    }

    /// Enable work-assisting dynamic panel scheduling
    /// ([`crate::coordinator::assist`]): executors claim panels from a
    /// shared atomic counter instead of receiving a static split. Results
    /// are bitwise identical either way (pinned by `tests/equivalence.rs`);
    /// only the work assignment changes. Off by default; the
    /// `PALLAS_ASSIST` env knob flips the process default instead.
    pub fn dynamic_schedule(mut self, on: bool) -> Self {
        self.cfg.dynamic_schedule = on;
        self
    }

    /// Select the GEMM microkernel ([`crate::linalg::kernels`]): `Auto`
    /// (the default) defers to the `PALLAS_KERNEL` knob / runtime feature
    /// detection, an explicit choice overrides both (unavailable SIMD
    /// requests clamp to scalar). For a fixed kernel results stay bitwise
    /// invariant across threads, slicing and scheduling; across kernels
    /// they differ by O(eps) — see `linalg::kernels`.
    pub fn kernel(mut self, choice: crate::linalg::KernelChoice) -> Self {
        self.cfg.kernel = choice;
        self
    }

    /// Clip the stage-1 bandwidth to `min(r, n - 1)` per pencil instead of
    /// rejecting `r >= n` — the small-pencil throughput mode that lets one
    /// session with the paper tuning serve [`HtSession::reduce_batch`]
    /// batches of pencils smaller than the configured band. Off by
    /// default: an unclipped session is bitwise the sequential oracle and
    /// errors on `r >= n` exactly like it.
    pub fn clip_band(mut self, on: bool) -> Self {
        self.clip_band = on;
        self
    }

    /// Capture per-task [`TaskTrace`]s on every [`HtSession::reduce`] call
    /// (forces sequential, per-task-timed coordinator execution — the old
    /// `ExecMode::Trace`). Implied by any sink whose
    /// [`TraceSink::wants_task_traces`] returns `true`.
    pub fn capture_traces(mut self, on: bool) -> Self {
        self.capture = on;
        self
    }

    /// Install a telemetry sink (default: [`NoopSink`]).
    pub fn trace(mut self, sink: impl TraceSink + 'static) -> Self {
        self.sink = Some(Box::new(sink));
        self
    }

    /// Install a tuned profile ([`crate::tune`]): per size class, the
    /// profile overlays its geometry (`r`, `p`, `q`, `slices`, and
    /// optionally `threads`) onto the session config before the per-`n`
    /// clip/validate step. Profiles change geometry only — a profiled
    /// reduce stays bitwise `reduce_seq` under the same effective config.
    pub fn profile(self, profile: TunedProfile) -> Self {
        self.profile_handle(ProfileHandle::of(profile))
    }

    /// Share a hot-swappable profile slot with this session (the router
    /// hands one handle to every shard, so
    /// [`crate::serve::ShardRouter::reload_profile`] retunes them all
    /// mid-traffic). An empty handle behaves like no profile.
    pub fn profile_handle(mut self, handle: ProfileHandle) -> Self {
        self.profile = Some(handle);
        self
    }

    /// Validate the configuration, resolve the worker-pool handle and
    /// construct the session. Configuration errors (zero threads,
    /// inconsistent blocking, budget violations) surface here as
    /// [`Error::Config`] — `reduce` calls only re-check the
    /// size-dependent constraint (`r < n`).
    pub fn build(self) -> Result<HtSession> {
        self.cfg.validate()?;
        let sink = self.sink.unwrap_or_else(|| Box::new(NoopSink));
        let capture = self.capture || sink.wants_task_traces();
        // Resolve (and thereby warm) the persistent team up front so the
        // one-time thread-startup cost lands in session construction, not
        // in the first reduction's stage timers. Trace capture runs
        // `reduce` sequentially and never touches the pool, so capture
        // sessions deliberately skip the spawn (a trace-only process
        // should not carry a parked worker team); if such a session later
        // calls `reduce_batch` with threads > 1, the team is resolved
        // lazily inside that first batch instead. A profile can raise the
        // thread count per size class (and a hot reload can do so after
        // build), so the eager warm-up also fires when any *currently
        // installed* class wants workers; `reduce_graph` still resolves
        // the team lazily as the backstop.
        let profile = self.profile.unwrap_or_default();
        let profiled_threads =
            profile.snapshot().map(|p| p.max_threads() > 1).unwrap_or(false);
        let pool = if (self.cfg.threads > 1 || profiled_threads) && !capture {
            Some(pool::global())
        } else {
            None
        };
        Ok(HtSession {
            cfg: self.cfg,
            clip_band: self.clip_band,
            capture,
            pool,
            sink,
            profile,
            ws: None,
            phase_log: Vec::new(),
            last_traces: None,
        })
    }
}

/// A long-lived Hessenberg-triangular reduction session (see the [module
/// docs](self) for the design rationale).
///
/// Configured once via [`HtSession::builder`]; [`HtSession::reduce`] and
/// [`HtSession::reduce_batch`] then reuse the resolved pool handle and the
/// per-`n` workspaces across calls.
pub struct HtSession {
    cfg: Config,
    clip_band: bool,
    capture: bool,
    pool: Option<&'static WorkerPool>,
    sink: Box<dyn TraceSink>,
    profile: ProfileHandle,
    ws: Option<Workspace>,
    phase_log: Vec<PhaseTiming>,
    last_traces: Option<(TaskTrace, TaskTrace)>,
}

impl std::fmt::Debug for HtSession {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HtSession")
            .field("cfg", &self.cfg)
            .field("clip_band", &self.clip_band)
            .field("capture", &self.capture)
            .field("pool_workers", &self.pool.map(|p| p.worker_count()))
            .field("profile", &self.profile)
            .field("reductions", &self.phase_log.len())
            .finish_non_exhaustive()
    }
}

impl HtSession {
    /// Start building a session from the paper-default [`Config`].
    pub fn builder() -> HtSessionBuilder {
        HtSessionBuilder {
            cfg: Config::default(),
            clip_band: false,
            capture: false,
            sink: None,
            profile: None,
        }
    }

    /// The session's (validated) configuration.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Stage timings of every reduction this session has run, in order
    /// (batch reductions appear once per pencil). The log grows with every
    /// call — long-lived sessions should drain it periodically with
    /// [`HtSession::clear_phases`].
    pub fn phases(&self) -> &[PhaseTiming] {
        &self.phase_log
    }

    /// Clear the phase log (see [`HtSession::phases`]).
    pub fn clear_phases(&mut self) {
        self.phase_log.clear();
    }

    /// Task traces of the most recent trace-captured [`HtSession::reduce`]
    /// call (`None` unless the session captures traces).
    pub fn trace(&self) -> Option<&(TaskTrace, TaskTrace)> {
        self.last_traces.as_ref()
    }

    /// Take ownership of the most recent task traces (see
    /// [`HtSession::trace`]), leaving `None` behind.
    pub fn take_traces(&mut self) -> Option<(TaskTrace, TaskTrace)> {
        self.last_traces.take()
    }

    /// The per-pencil effective configuration: the tuned profile's size
    /// class (if a profile is installed) overlaid on the session config,
    /// then the bandwidth clipped to the problem size (via
    /// [`Config::clipped_for`], the rule shared with the serving layer's
    /// cache keys) when [`HtSessionBuilder::clip_band`] is on, validated
    /// for `n`. Order matters: the overlay runs *before* the clip, so a
    /// tuned band wider than a small pencil still clips exactly like an
    /// untuned one would.
    fn effective_cfg(&self, n: usize) -> Result<Config> {
        let base = match self.profile.snapshot() {
            Some(p) => p.apply(&self.cfg, n),
            None => self.cfg.clone(),
        };
        let cfg = if self.clip_band { base.clipped_for(n) } else { base };
        cfg.validate_for(n)?;
        Ok(cfg)
    }

    /// (Re)build the per-`n` workspace if the problem size *or* the
    /// blocking geometry changed (a profile hot-swap can retune `r`/`p`/`q`
    /// between two reductions of the same size).
    fn ensure_workspace(&mut self, n: usize, cfg: &Config) {
        let stale = self
            .ws
            .as_ref()
            .map(|w| w.n != n || w.r != cfg.r || w.p != cfg.p || w.q != cfg.q)
            .unwrap_or(true);
        if stale {
            let plans = panel_plans(n, cfg.r, cfg.p);
            let groups = sweep_groups(n, cfg.q);
            let arena1 = Stage1Arena::new(&plans);
            let arena2 = Stage2Arena::new(n, cfg.r, &groups);
            self.ws =
                Some(Workspace { n, r: cfg.r, p: cfg.p, q: cfg.q, plans, groups, arena1, arena2 });
        }
    }

    /// Reduce one pencil to Hessenberg-triangular form: `A = Q H Zᵀ`,
    /// `B = Q T Zᵀ`. `b` need not be triangular (pre-triangularization is
    /// applied first, accumulated into `Q`).
    ///
    /// Every execution mode of the session — sequential (`threads = 1`),
    /// threaded, trace-capturing — produces bitwise-identical factors
    /// (pinned by `tests/equivalence.rs`).
    pub fn reduce(&mut self, a: &Matrix, b: &Matrix) -> Result<HtDecomposition> {
        self.reduce_tracked(a, b).map(|(dec, _)| dec)
    }

    /// [`HtSession::reduce`], also returning the effective [`Config`] the
    /// reduction actually ran with (profile overlay + band clip applied).
    /// The serving layer keys its result cache on this returned config:
    /// under a concurrent profile hot-swap, the config resolved *inside*
    /// this call is the only truthful description of the work done.
    pub fn reduce_tracked(
        &mut self,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<(HtDecomposition, Config)> {
        check_pencil_shape(a, b)?;
        let n = a.rows();
        let cfg = self.effective_cfg(n)?;

        let (dec, traces) = if self.capture || cfg.threads > 1 {
            self.reduce_graph(a, b, &cfg)?
        } else {
            (reduce_seq(a, b, &cfg)?, None)
        };

        self.phase_log.push(PhaseTiming {
            n,
            stage1_secs: dec.stage1_secs,
            stage2_secs: dec.stage2_secs,
        });
        let report = ReduceReport {
            n,
            stage1_secs: dec.stage1_secs,
            stage2_secs: dec.stage2_secs,
            traces,
            batched: false,
        };
        self.sink.on_reduce(&report);
        self.last_traces = report.traces;
        Ok((dec, cfg))
    }

    /// Coordinator path: build the stage task graphs over the session
    /// workspace and execute them on the pool (or sequentially with
    /// per-task timing when capturing traces).
    fn reduce_graph(
        &mut self,
        a: &Matrix,
        b: &Matrix,
        cfg: &Config,
    ) -> Result<(HtDecomposition, Option<(TaskTrace, TaskTrace)>)> {
        let n = a.rows();
        // Install the config's microkernel on the submitting thread; the
        // pool captures it into every stage batch, so graph tasks on the
        // workers compute under the same kernel (see `coordinator::pool`).
        let _kernel = crate::linalg::kernels::enter(cfg.resolved_kernel());
        self.ensure_workspace(n, cfg);
        let capture = self.capture;
        // When build-time warm-up skipped the pool (the session was built
        // single-threaded), resolve the team lazily at the run site: a
        // hot-reloaded profile can raise a size class's thread count after
        // build, and that must never panic mid-serve (same lazy rule as
        // `reduce_batch`). Capture runs never touch the pool at all, so a
        // trace-only process still spawns no worker team.
        let pool = self.pool;
        // Take the workspace out of the session for the duration of the
        // stage runs: the graphs borrow its plans and arenas, and an owned
        // local keeps those borrows fully disjoint from `self` — no
        // session borrow is live across the pool submits, so adding
        // `&mut self` telemetry between the stages can never trip over the
        // workspace again. Restored below; a panicking stage leaves the
        // slot `None`, which the next call simply rebuilds.
        let ws = self.ws.take().expect("workspace just ensured");
        ws.arena1.reset();
        ws.arena2.reset();

        let (mut h, mut t, mut q, mut z) = prepare_pencil(a, b);

        let t1 = Timer::start();
        let tr1 = {
            // Tagged handles so the concurrency auditor (when active) can
            // match views against the graph's declared regions.
            let sa = SharedMat::tagged(&mut h, MatId::A);
            let sb = SharedMat::tagged(&mut t, MatId::B);
            let sq = SharedMat::tagged(&mut q, MatId::Q);
            let sz = SharedMat::tagged(&mut z, MatId::Z);
            let graph = stage1_par::build_graph(&sa, &sb, &sq, &sz, &ws.arena1, &ws.plans, cfg);
            if capture {
                Some(graph.run_sequential())
            } else {
                pool.unwrap_or_else(pool::global).run_graph(graph, cfg.threads);
                None
            }
        };
        let stage1_secs = t1.secs();

        let t2 = Timer::start();
        let tr2 = {
            let sa = SharedMat::tagged(&mut h, MatId::A);
            let sb = SharedMat::tagged(&mut t, MatId::B);
            let sq = SharedMat::tagged(&mut q, MatId::Q);
            let sz = SharedMat::tagged(&mut z, MatId::Z);
            let graph = stage2_par::build_graph(&sa, &sb, &sq, &sz, &ws.arena2, &ws.groups, cfg);
            if capture {
                Some(graph.run_sequential())
            } else {
                pool.unwrap_or_else(pool::global).run_graph(graph, cfg.threads);
                None
            }
        };
        let stage2_secs = t2.secs();
        self.ws = Some(ws);

        Ok((HtDecomposition { h, t, q, z, stage1_secs, stage2_secs }, tr1.zip(tr2)))
    }

    /// Reduce a batch of independent pencils — the throughput mode for
    /// many small problems, where per-pencil task graphs would drown in
    /// scheduling overhead. Each pencil runs the *sequential* oracle as
    /// one indivisible job; jobs are dispatched across the session's
    /// worker team (one pencil per worker), so results are bitwise
    /// identical to calling [`HtSession::reduce`] (at `threads = 1`) on
    /// each pencil in order, regardless of scheduling.
    ///
    /// All pencils are validated up front: a shape or configuration error
    /// on any of them fails the whole call before any work starts. Batch
    /// reductions never capture task traces.
    pub fn reduce_batch(&mut self, pencils: &[Pencil]) -> Result<Vec<HtDecomposition>> {
        // Typed errors before any work: shapes and per-n config. Each job
        // runs strictly sequentially (threads = 1): the batch's
        // parallelism is one-pencil-per-worker, and a job fanning its own
        // trailing updates out on the same pool would only contend with
        // its sibling jobs. (Thread count never changes the numbers —
        // kernels are slicing-invariant — only the scheduling.)
        let mut cfgs = Vec::with_capacity(pencils.len());
        for p in pencils {
            check_pencil_shape(&p.a, &p.b)?;
            let mut cfg = self.effective_cfg(p.n())?;
            cfg.threads = 1;
            cfgs.push(cfg);
        }

        type Slot = Mutex<Option<Result<HtDecomposition>>>;
        let slots: Vec<Slot> = pencils.iter().map(|_| Mutex::new(None)).collect();
        let threads = self.cfg.threads.min(pencils.len().max(1));
        if threads <= 1 {
            for ((p, cfg), slot) in pencils.iter().zip(&cfgs).zip(&slots) {
                *slot.lock().unwrap() = Some(reduce_seq(&p.a, &p.b, cfg));
            }
        } else {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = pencils
                .iter()
                .zip(&cfgs)
                .zip(&slots)
                .map(|((p, cfg), slot)| {
                    Box::new(move || {
                        *slot.lock().unwrap() = Some(reduce_seq(&p.a, &p.b, cfg));
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            // Trace-capture sessions hold no pool handle (see `build`);
            // batches still run threaded (they are plain data-parallel
            // jobs), resolving the team lazily here on first use. The
            // session's dynamic-schedule gate applies: under it, workers
            // claim pencils from the assist counter instead of a static
            // assignment (bitwise irrelevant — each job is indivisible).
            let sched = crate::coordinator::assist::Schedule::for_config(&self.cfg);
            self.pool.unwrap_or_else(pool::global).run_tasks_sched(tasks, threads, sched);
        }

        let mut out = Vec::with_capacity(pencils.len());
        for slot in slots {
            let dec = slot
                .into_inner()
                .unwrap()
                .expect("batch job completed (pool propagates panics)")?;
            out.push(dec);
        }
        for dec in &out {
            let report = ReduceReport {
                n: dec.h.rows(),
                stage1_secs: dec.stage1_secs,
                stage2_secs: dec.stage2_secs,
                traces: None,
                batched: true,
            };
            self.phase_log.push(PhaseTiming {
                n: report.n,
                stage1_secs: report.stage1_secs,
                stage2_secs: report.stage2_secs,
            });
            self.sink.on_reduce(&report);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pencil::random::{random_pencil, random_pencil_general};
    use crate::util::proptest::max_abs_diff;
    use crate::util::rng::Rng;

    fn assert_same(x: &HtDecomposition, y: &HtDecomposition, label: &str) {
        assert_eq!(max_abs_diff(&x.h, &y.h), 0.0, "{label}: H");
        assert_eq!(max_abs_diff(&x.t, &y.t), 0.0, "{label}: T");
        assert_eq!(max_abs_diff(&x.q, &y.q), 0.0, "{label}: Q");
        assert_eq!(max_abs_diff(&x.z, &y.z), 0.0, "{label}: Z");
    }

    #[test]
    fn builder_rejects_zero_threads_as_config_error() {
        let e = HtSession::builder().threads(0).build().unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn builder_rejects_inconsistent_blocking() {
        let e = HtSession::builder().block(1).build().unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        let e = HtSession::builder().band(0).build().unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn reduce_rejects_band_at_least_n_without_clip() {
        let mut rng = Rng::new(0xA1_01);
        let p = random_pencil(10, &mut rng);
        let mut s = HtSession::builder().band(16).build().unwrap();
        let e = s.reduce(&p.a, &p.b).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        // Same surface for batches: typed error before any work.
        let e = s.reduce_batch(std::slice::from_ref(&p)).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
    }

    #[test]
    fn reduce_rejects_bad_shapes() {
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(4, 4);
        let mut s = HtSession::builder().build().unwrap();
        assert!(matches!(s.reduce(&a, &b).unwrap_err(), Error::Shape(_)));
    }

    #[test]
    fn clip_band_serves_pencils_below_the_band() {
        // Paper tuning (r=16) on n=10: clip mode reduces with r_eff = 9 and
        // matches the oracle run at that clipped bandwidth exactly.
        let mut rng = Rng::new(0xA1_02);
        let p = random_pencil(10, &mut rng);
        let mut s = HtSession::builder().clip_band(true).build().unwrap();
        let d = s.reduce(&p.a, &p.b).unwrap();
        d.verify(&p.a, &p.b).assert_ok(1e-11);
        let cfg = Config { r: 9, ..Config::default() };
        let oracle = reduce_seq(&p.a, &p.b, &cfg).unwrap();
        assert_same(&d, &oracle, "clip n=10");
        // Tiny pencils (n < 3) are no-ops for every stage: accepted too.
        let tiny = random_pencil(2, &mut rng);
        let d = s.reduce(&tiny.a, &tiny.b).unwrap();
        d.verify(&tiny.a, &tiny.b).assert_ok(1e-12);
    }

    #[test]
    fn session_reduce_handles_general_b() {
        let mut rng = Rng::new(0xA1_03);
        let p = random_pencil_general(36, &mut rng);
        let cfg = Config { r: 4, p: 3, q: 3, threads: 4, ..Config::default() };
        let mut s = HtSession::builder().config(cfg.clone()).build().unwrap();
        let d = s.reduce(&p.a, &p.b).unwrap();
        d.verify(&p.a, &p.b).assert_ok(1e-11);
        assert_same(&d, &reduce_seq(&p.a, &p.b, &cfg).unwrap(), "general B");
    }

    #[test]
    fn phases_accumulate_and_trace_absent_by_default() {
        let mut rng = Rng::new(0xA1_04);
        let p = random_pencil(24, &mut rng);
        let cfg = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let mut s = HtSession::builder().config(cfg).build().unwrap();
        s.reduce(&p.a, &p.b).unwrap();
        s.reduce(&p.a, &p.b).unwrap();
        assert_eq!(s.phases().len(), 2);
        assert!(s.phases().iter().all(|ph| ph.n == 24));
        assert!(s.trace().is_none(), "no trace capture by default");
    }

    #[test]
    fn trace_recorder_captures_reports_with_traces() {
        let mut rng = Rng::new(0xA1_05);
        let p = random_pencil(30, &mut rng);
        let cfg = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let rec = TraceRecorder::new();
        let mut s =
            HtSession::builder().config(cfg.clone()).trace(rec.clone()).build().unwrap();
        let d = s.reduce(&p.a, &p.b).unwrap();
        // Trace capture never changes the numbers.
        assert_same(&d, &reduce_seq(&p.a, &p.b, &cfg).unwrap(), "traced");
        assert_eq!(rec.len(), 1);
        assert!(!rec.is_empty());
        let reports = rec.reports();
        let traces = reports[0].traces.as_ref().expect("recorder requests task traces");
        assert!(!traces.0.durations.is_empty());
        assert!(!traces.1.durations.is_empty());
        assert!(s.trace().is_some());
        let owned = s.take_traces().expect("accessor hands the trace out once");
        assert_eq!(owned.0.durations.len(), traces.0.durations.len());
        assert!(s.trace().is_none());
    }

    #[test]
    fn builder_dynamic_schedule_gate_round_trips() {
        let s = HtSession::builder().dynamic_schedule(true).build().unwrap();
        assert!(s.config().dynamic_schedule);
        let s = HtSession::builder().build().unwrap();
        assert!(!s.config().dynamic_schedule, "gate defaults off");
    }

    #[test]
    fn builder_kernel_setter_round_trips() {
        use crate::linalg::KernelChoice;
        let s = HtSession::builder().kernel(KernelChoice::Scalar).build().unwrap();
        assert_eq!(s.config().kernel, KernelChoice::Scalar);
        let s = HtSession::builder().build().unwrap();
        assert_eq!(s.config().kernel, KernelChoice::Auto, "kernel defaults to auto");
    }

    #[test]
    fn session_reuse_under_tracing_rebuilds_workspace() {
        // The reduce_graph borrow restructure (owned workspace local):
        // a trace-capturing session reused across size changes must
        // rebuild its workspace and stay bitwise on the oracle on every
        // call — same-size reuse, rebuild on growth, rebuild on shrink.
        let mut rng = Rng::new(0xA1_08);
        let p1 = random_pencil(30, &mut rng);
        let p2 = random_pencil(41, &mut rng);
        let cfg = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let rec = TraceRecorder::new();
        let mut s = HtSession::builder().config(cfg.clone()).trace(rec.clone()).build().unwrap();
        for (i, p) in [&p1, &p1, &p2, &p1].iter().enumerate() {
            let d = s.reduce(&p.a, &p.b).unwrap();
            assert_same(
                &d,
                &reduce_seq(&p.a, &p.b, &cfg).unwrap(),
                &format!("traced reuse call {i} (n={})", p.n()),
            );
        }
        assert_eq!(rec.len(), 4);
        assert!(
            rec.reports().iter().all(|r| r.traces.is_some()),
            "every traced call must carry task traces"
        );
    }

    #[test]
    fn reduce_batch_empty_and_single() {
        let mut s = HtSession::builder().threads(4).build().unwrap();
        assert!(s.reduce_batch(&[]).unwrap().is_empty());
        let mut rng = Rng::new(0xA1_06);
        let p = random_pencil(20, &mut rng);
        let cfg = Config { r: 4, p: 2, q: 2, threads: 4, ..Config::default() };
        let mut s = HtSession::builder().config(cfg.clone()).build().unwrap();
        let out = s.reduce_batch(std::slice::from_ref(&p)).unwrap();
        assert_eq!(out.len(), 1);
        assert_same(&out[0], &reduce_seq(&p.a, &p.b, &cfg).unwrap(), "batch of one");
    }

    #[test]
    fn reduce_batch_mixed_sizes_with_clip() {
        // Mixed sizes including n below the configured band and a tiny
        // no-op pencil; clip mode must serve all of them, identically to
        // per-pencil sequential reduction.
        let mut rng = Rng::new(0xA1_07);
        let sizes = [2usize, 6, 10, 23, 40];
        let pencils: Vec<Pencil> = sizes.iter().map(|&n| random_pencil(n, &mut rng)).collect();
        let mut s =
            HtSession::builder().band(16).threads(4).clip_band(true).build().unwrap();
        let out = s.reduce_batch(&pencils).unwrap();
        assert_eq!(out.len(), pencils.len());
        let mut seq =
            HtSession::builder().band(16).threads(1).clip_band(true).build().unwrap();
        for (i, (p, d)) in pencils.iter().zip(&out).enumerate() {
            d.verify(&p.a, &p.b).assert_ok(1e-10);
            let oracle = seq.reduce(&p.a, &p.b).unwrap();
            assert_same(d, &oracle, &format!("batch pencil {i} (n={})", p.n()));
        }
        assert_eq!(s.phases().len(), pencils.len());
    }

    fn one_class_profile(n_min: usize, r: usize, p: usize, q: usize) -> TunedProfile {
        TunedProfile {
            classes: vec![crate::tune::ClassProfile {
                n_min,
                n_max: 0,
                r,
                p,
                q,
                slices: 0,
                threads: 0,
                predicted_makespan: 0.0,
                default_makespan: 0.0,
                trace_n: n_min,
            }],
        }
    }

    #[test]
    fn profiled_session_is_bitwise_the_oracle_under_the_tuned_config() {
        // A profile overlay changes the geometry the reduce runs with; the
        // result must be exactly reduce_seq *under that tuned config*.
        let mut rng = Rng::new(0xA1_09);
        let p = random_pencil(28, &mut rng);
        let profile = one_class_profile(9, 4, 2, 2);
        let mut s = HtSession::builder().profile(profile).build().unwrap();
        let (d, ran) = s.reduce_tracked(&p.a, &p.b).unwrap();
        assert_eq!((ran.r, ran.p, ran.q), (4, 2, 2), "class geometry applied");
        let oracle = reduce_seq(&p.a, &p.b, &ran).unwrap();
        assert_same(&d, &oracle, "profiled n=28");
        // Below the class floor the base config applies untouched — and
        // the unclipped default base (r = 16) is rejected at n = 5 exactly
        // like an unprofiled session would reject it.
        let tiny = random_pencil(5, &mut rng);
        let e = s.reduce(&tiny.a, &tiny.b).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        // With the clip: the uncovered size clips the *base* band, same as
        // an untuned clip session.
        let profile = one_class_profile(9, 4, 2, 2);
        let mut s = HtSession::builder().profile(profile).clip_band(true).build().unwrap();
        let (_, ran) = s.reduce_tracked(&tiny.a, &tiny.b).unwrap();
        assert_eq!(ran.r, 4, "n=5 clips the base r=16 to (n-1).max(2) = 4");
    }

    #[test]
    fn profile_hot_swap_retunes_at_unchanged_n() {
        // Same n, different geometry after a reload: the workspace must
        // rebuild (staleness is keyed on r/p/q, not just n) and the result
        // must track each installed geometry exactly.
        let mut rng = Rng::new(0xA1_0A);
        let p = random_pencil(26, &mut rng);
        let handle = ProfileHandle::of(one_class_profile(9, 4, 2, 2));
        let mut s = HtSession::builder().profile_handle(handle.clone()).build().unwrap();
        let (d1, ran1) = s.reduce_tracked(&p.a, &p.b).unwrap();
        assert_same(&d1, &reduce_seq(&p.a, &p.b, &ran1).unwrap(), "before swap");
        handle.install(one_class_profile(9, 8, 2, 4));
        let (d2, ran2) = s.reduce_tracked(&p.a, &p.b).unwrap();
        assert_eq!((ran2.r, ran2.q), (8, 4));
        assert_same(&d2, &reduce_seq(&p.a, &p.b, &ran2).unwrap(), "after swap");
        handle.clear();
        let (d3, ran3) = s.reduce_tracked(&p.a, &p.b).unwrap();
        assert_eq!(ran3.r, Config::default().r, "cleared handle falls back to base");
        assert_same(&d3, &reduce_seq(&p.a, &p.b, &ran3).unwrap(), "after clear");
    }
}
