//! Crate-wide error type (pure std — no external dependencies).

use std::fmt;

/// Errors produced by the paraht library.
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch or otherwise invalid matrix arguments.
    Shape(String),

    /// Invalid configuration parameter.
    Config(String),

    /// Numerical failure (e.g. non-convergence of an iterative baseline).
    Numerical(String),

    /// PJRT runtime failure (artifact loading / compilation / execution).
    Runtime(String),

    /// I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Numerical(msg) => write!(f, "numerical error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Helper for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Helper for numerical errors.
    pub fn numerical(msg: impl Into<String>) -> Self {
        Error::Numerical(msg.into())
    }
    /// Helper for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
}
