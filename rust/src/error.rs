//! Crate-wide error type.

/// Errors produced by the paraht library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Dimension mismatch or otherwise invalid matrix arguments.
    #[error("shape error: {0}")]
    Shape(String),

    /// Invalid configuration parameter.
    #[error("config error: {0}")]
    Config(String),

    /// Numerical failure (e.g. non-convergence of an iterative baseline).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// PJRT runtime failure (artifact loading / compilation / execution).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Helper for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Helper for numerical errors.
    pub fn numerical(msg: impl Into<String>) -> Self {
        Error::Numerical(msg.into())
    }
    /// Helper for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
}
