//! Crate-wide error type (pure std — no external dependencies).

use std::fmt;

/// Errors produced by the paraht library.
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch or otherwise invalid matrix arguments.
    Shape(String),

    /// Invalid configuration parameter.
    Config(String),

    /// Numerical failure (e.g. non-convergence of an iterative baseline).
    Numerical(String),

    /// PJRT runtime failure (artifact loading / compilation / execution).
    Runtime(String),

    /// Admission control shed this job: the target lane stayed full past
    /// the configured deadline ([`crate::serve::queue`]'s `try_submit` /
    /// `submit_timeout`). The job was never enqueued; resubmitting later
    /// is safe.
    Overloaded(String),

    /// A supervised shard child process died with this job in flight
    /// ([`crate::serve::supervisor`]). The supervisor restarts the child
    /// with capped backoff; resubmitting is safe (reductions are pure).
    ShardDown(String),

    /// Wire-protocol decode failure ([`crate::serve::proto`]): truncated,
    /// oversized or malformed frame, or an unsupported protocol version.
    Protocol(String),

    /// I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Numerical(msg) => write!(f, "numerical error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Overloaded(msg) => write!(f, "overloaded: {msg}"),
            Error::ShardDown(msg) => write!(f, "shard down: {msg}"),
            Error::Protocol(msg) => write!(f, "protocol error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// Helper for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Helper for numerical errors.
    pub fn numerical(msg: impl Into<String>) -> Self {
        Error::Numerical(msg.into())
    }
    /// Helper for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// Helper for admission-control shedding errors.
    pub fn overloaded(msg: impl Into<String>) -> Self {
        Error::Overloaded(msg.into())
    }
    /// Helper for dead-shard errors.
    pub fn shard_down(msg: impl Into<String>) -> Self {
        Error::ShardDown(msg.into())
    }
    /// Helper for wire-protocol errors.
    pub fn protocol(msg: impl Into<String>) -> Self {
        Error::Protocol(msg.into())
    }
}
