//! Versioned tuned-profile artifacts and the shared hot-reload handle.
//!
//! A [`TunedProfile`] is the autotuner's output: one [`ClassProfile`] per
//! size class, each pinning the geometry knobs (`r`, `p`, `q`, `slices`,
//! `threads`) the search chose for that class, plus the simulator
//! predictions that justified the choice. Profiles are persisted as a
//! versioned JSON artifact (the `run_summary.json` idiom: hand-written
//! writer, schema version + kind discriminator up front) and read back
//! through the minimal parser in [`crate::tune::json`].
//!
//! **Profiles change geometry, never results.** Every knob a class may
//! override is either result-determining-but-pinned (`r`, `p`, `q` — the
//! effective config carrying them flows into the serving cache key and
//! into the oracle comparison) or output-invariant by the determinism
//! contract (`threads`, `slices`). A profiled reduction is therefore
//! still bitwise `api::reduce_seq` *under its effective config* — that is
//! the contract `tests/tune.rs` pins.
//!
//! [`ProfileHandle`] is the hot-reload seam: the serving router and its
//! sessions share one handle, and [`ProfileHandle::set`] swaps the
//! profile atomically under all of them mid-traffic. Cache soundness
//! under a racing swap is the router's job (it keys inserts on the config
//! a job *actually ran with* — see [`crate::serve::router`]).

use crate::config::{Config, MAX_BLOCK_PRODUCT, MAX_SLICES, MAX_THREADS};
use crate::error::{Error, Result};
use crate::tune::json::{self, Json};
use std::path::Path;
use std::sync::{Arc, RwLock};

/// Schema version of the profile artifact. Bump on any incompatible
/// change; [`TunedProfile::parse`] rejects every other version with a
/// typed error (never a silent misread).
pub const PROFILE_SCHEMA_VERSION: u64 = 1;

/// Kind discriminator stored in the artifact, so a profile path pointed
/// at some *other* JSON file (a bench artifact, a run summary) fails
/// loudly instead of half-parsing.
pub const PROFILE_KIND: &str = "pallas_tuned_profile";

/// Tuned geometry for one size class `[n_min, n_max]`.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassProfile {
    /// Smallest problem size this class covers (inclusive). The tuner
    /// guarantees `n_min > r`, so the overlaid config passes
    /// [`Config::validate_for`] everywhere in the class.
    pub n_min: usize,
    /// Largest problem size this class covers (inclusive); `0` means
    /// unbounded (the last class).
    pub n_max: usize,
    /// Tuned stage-1 bandwidth / panel width.
    pub r: usize,
    /// Tuned stage-1 block-height multiplier.
    pub p: usize,
    /// Tuned stage-2 sweep-group size.
    pub q: usize,
    /// Tuned slice count (`0` = auto, like [`Config::slices`]).
    pub slices: usize,
    /// Tuned worker count (`0` = keep the base config's threads).
    pub threads: usize,
    /// Simulator-predicted makespan (seconds) of the chosen config on its
    /// recorded trace — advisory telemetry, never consulted at run time.
    pub predicted_makespan: f64,
    /// Simulator-predicted makespan of the *default* config on the same
    /// workload, for the tuned-vs-default comparison. The tuner
    /// guarantees `predicted_makespan <= default_makespan`.
    pub default_makespan: f64,
    /// Representative size the class's traces were recorded at.
    pub trace_n: usize,
}

impl ClassProfile {
    /// Whether this class covers problem size `n`.
    pub fn covers(&self, n: usize) -> bool {
        n >= self.n_min && (self.n_max == 0 || n <= self.n_max)
    }
}

/// A persisted set of per-size-class tuned configurations (see the
/// [module docs](self)).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TunedProfile {
    /// The size classes, first match wins in [`TunedProfile::class_for`].
    pub classes: Vec<ClassProfile>,
}

impl TunedProfile {
    /// The class covering problem size `n` (first match), if any. Sizes
    /// no class covers (e.g. tiny pencils below every `n_min`) fall back
    /// to the base config untouched.
    pub fn class_for(&self, n: usize) -> Option<&ClassProfile> {
        self.classes.iter().find(|c| c.covers(n))
    }

    /// Overlay the tuned geometry for size `n` onto a base config. Only
    /// geometry fields change (`r`, `p`, `q`, `slices`, and `threads`
    /// when the class pins one); everything result-relevant that the
    /// profile does not own — `lookahead`, `kernel`, `seed` — passes
    /// through from the base untouched.
    pub fn apply(&self, base: &Config, n: usize) -> Config {
        match self.class_for(n) {
            None => base.clone(),
            Some(c) => {
                let mut cfg = base.clone();
                cfg.r = c.r;
                cfg.p = c.p;
                cfg.q = c.q;
                cfg.slices = c.slices;
                if c.threads > 0 {
                    cfg.threads = c.threads;
                }
                cfg
            }
        }
    }

    /// The largest per-class thread override (0 when no class pins one) —
    /// the session builder's hint for resolving the worker pool up front.
    pub fn max_threads(&self) -> usize {
        self.classes.iter().map(|c| c.threads).max().unwrap_or(0)
    }

    /// Semantic validation: every class must hold geometry that the
    /// config layer would accept anywhere in the class ([`Config`]'s
    /// budgets, `r < n_min`). [`TunedProfile::parse`] runs this
    /// automatically; hand-built profiles (tests, tools) can call it
    /// directly.
    pub fn validate(&self) -> Result<()> {
        for (i, c) in self.classes.iter().enumerate() {
            let reject = |msg: String| Err(Error::config(format!("profile class {i}: {msg}")));
            if c.r < 2 {
                return reject(format!("r must be >= 2 (got {})", c.r));
            }
            if c.p < 2 {
                return reject(format!("p must be >= 2 (got {})", c.p));
            }
            if c.q < 1 {
                return reject(format!("q must be >= 1 (got {})", c.q));
            }
            match c.p.checked_mul(c.q) {
                None => return reject(format!("p*q overflows (p = {}, q = {})", c.p, c.q)),
                Some(pq) if pq > MAX_BLOCK_PRODUCT => {
                    return reject(format!("p*q = {pq} exceeds the task budget"));
                }
                Some(_) => {}
            }
            if c.threads > MAX_THREADS {
                return reject(format!("threads = {} exceeds the thread budget", c.threads));
            }
            if c.slices > MAX_SLICES {
                return reject(format!("slices = {} exceeds the slice budget", c.slices));
            }
            if c.n_min < 2 {
                return reject(format!("n_min must be >= 2 (got {})", c.n_min));
            }
            if c.n_max != 0 && c.n_max < c.n_min {
                return reject(format!("empty class: n_min {} > n_max {}", c.n_min, c.n_max));
            }
            // `r >= n` is rejected by validate_for at n >= 3; a class must
            // not cover any size its own band would be rejected at.
            if c.n_min >= 3 && c.r >= c.n_min {
                return reject(format!(
                    "r = {} does not fit the class floor n_min = {}",
                    c.r, c.n_min
                ));
            }
        }
        Ok(())
    }

    /// Serialize to the versioned JSON artifact (hand-written like every
    /// other JSON this crate emits; floats in Rust's shortest round-trip
    /// `Display` form, non-finite values as `null`).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let num = |v: f64| if v.is_finite() { format!("{v}") } else { "null".to_string() };
        let mut j = String::new();
        j.push_str("{\n");
        let _ = writeln!(j, "  \"schema_version\": {PROFILE_SCHEMA_VERSION},");
        let _ = writeln!(j, "  \"kind\": \"{PROFILE_KIND}\",");
        j.push_str("  \"classes\": [");
        for (i, c) in self.classes.iter().enumerate() {
            if i > 0 {
                j.push(',');
            }
            j.push_str("\n    {");
            let _ = write!(
                j,
                "\"n_min\": {}, \"n_max\": {}, \"r\": {}, \"p\": {}, \"q\": {}, \
                 \"slices\": {}, \"threads\": {}, \"predicted_makespan\": {}, \
                 \"default_makespan\": {}, \"trace_n\": {}",
                c.n_min,
                c.n_max,
                c.r,
                c.p,
                c.q,
                c.slices,
                c.threads,
                num(c.predicted_makespan),
                num(c.default_makespan),
                c.trace_n
            );
            j.push('}');
        }
        if !self.classes.is_empty() {
            j.push_str("\n  ");
        }
        j.push_str("]\n}\n");
        j
    }

    /// Parse and validate a profile document. Malformed JSON is a typed
    /// [`Error::Protocol`]; a well-formed document with the wrong kind,
    /// wrong schema version, missing fields or invalid geometry is a
    /// typed [`Error::Config`]. Never panics on untrusted bytes.
    pub fn parse(src: &str) -> Result<TunedProfile> {
        let doc = json::parse(src)?;
        let kind = doc.get("kind").and_then(Json::as_str).unwrap_or("");
        if kind != PROFILE_KIND {
            return Err(Error::config(format!(
                "profile: kind {kind:?} is not {PROFILE_KIND:?}"
            )));
        }
        let version = doc
            .get("schema_version")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::config("profile: missing schema_version"))?;
        if version as u64 != PROFILE_SCHEMA_VERSION {
            return Err(Error::config(format!(
                "profile: schema_version {version} is not supported (want {PROFILE_SCHEMA_VERSION})"
            )));
        }
        let classes = doc
            .get("classes")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::config("profile: missing classes array"))?;
        let field = |c: &Json, name: &str, i: usize| -> Result<usize> {
            c.get(name).and_then(Json::as_usize).ok_or_else(|| {
                Error::config(format!("profile class {i}: missing or non-integer {name:?}"))
            })
        };
        let fnum = |c: &Json, name: &str| -> f64 {
            match c.get(name) {
                Some(Json::Null) | None => f64::NAN,
                Some(v) => v.as_f64().unwrap_or(f64::NAN),
            }
        };
        let mut out = TunedProfile { classes: Vec::with_capacity(classes.len()) };
        for (i, c) in classes.iter().enumerate() {
            out.classes.push(ClassProfile {
                n_min: field(c, "n_min", i)?,
                n_max: field(c, "n_max", i)?,
                r: field(c, "r", i)?,
                p: field(c, "p", i)?,
                q: field(c, "q", i)?,
                slices: field(c, "slices", i)?,
                threads: field(c, "threads", i)?,
                predicted_makespan: fnum(c, "predicted_makespan"),
                default_makespan: fnum(c, "default_makespan"),
                trace_n: field(c, "trace_n", i)?,
            });
        }
        out.validate()?;
        Ok(out)
    }

    /// Read and parse a profile file (I/O errors are typed [`Error::Io`]).
    pub fn load(path: impl AsRef<Path>) -> Result<TunedProfile> {
        let src = std::fs::read_to_string(path.as_ref())?;
        TunedProfile::parse(&src)
    }

    /// Write the artifact to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json())?;
        Ok(())
    }

    /// The startup fallback path: load `path`, and on *any* failure
    /// (missing file, malformed JSON, wrong version) print one warning to
    /// stderr and return `None` so the caller serves with defaults — a
    /// bad profile must degrade a serving tier to untuned, never take it
    /// down. [`crate::serve::ServeConfig::from_env`] routes the
    /// `PALLAS_PROFILE` knob through here.
    pub fn load_or_warn(path: &str) -> Option<TunedProfile> {
        match TunedProfile::load(path) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("warning: ignoring tuned profile {path:?}: {e}; serving with defaults");
                None
            }
        }
    }
}

/// A shared, hot-swappable profile slot: the router and all of its
/// sessions hold clones of one handle, so a single [`ProfileHandle::set`]
/// retunes every shard mid-traffic. Reads are a brief `RwLock` read +
/// `Arc` clone per reduction; the lock is never held across any work.
#[derive(Clone, Default)]
pub struct ProfileHandle {
    inner: Arc<RwLock<Option<Arc<TunedProfile>>>>,
}

impl ProfileHandle {
    /// An empty handle (no profile installed; every lookup falls through
    /// to the base config).
    pub fn new() -> ProfileHandle {
        ProfileHandle::default()
    }

    /// A handle with `profile` pre-installed.
    pub fn of(profile: TunedProfile) -> ProfileHandle {
        let h = ProfileHandle::new();
        h.install(profile);
        h
    }

    /// The current profile, if one is installed. Lock poisoning is
    /// recovered, not propagated: the slot holds a plain `Option` swap
    /// with no invariant a panic could have broken mid-update (same
    /// policy as the serving tier's `lock_recover`).
    pub fn snapshot(&self) -> Option<Arc<TunedProfile>> {
        self.inner.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Install (replace) the profile.
    pub fn install(&self, profile: TunedProfile) {
        self.set(Some(profile));
    }

    /// Replace or clear the profile atomically.
    pub fn set(&self, profile: Option<TunedProfile>) {
        *self.inner.write().unwrap_or_else(|e| e.into_inner()) = profile.map(Arc::new);
    }

    /// Remove the profile (every later lookup uses the base config).
    pub fn clear(&self) {
        self.set(None);
    }
}

impl std::fmt::Debug for ProfileHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ProfileHandle")
            .field("classes", &self.snapshot().map(|p| p.classes.len()).unwrap_or(0))
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TunedProfile {
        TunedProfile {
            classes: vec![
                ClassProfile {
                    n_min: 9,
                    n_max: 48,
                    r: 4,
                    p: 2,
                    q: 2,
                    slices: 8,
                    threads: 2,
                    predicted_makespan: 0.125,
                    default_makespan: 0.25,
                    trace_n: 32,
                },
                ClassProfile {
                    n_min: 49,
                    n_max: 0,
                    r: 8,
                    p: 4,
                    q: 4,
                    slices: 0,
                    threads: 4,
                    predicted_makespan: 1.0 / 3.0,
                    default_makespan: 0.5,
                    trace_n: 64,
                },
            ],
        }
    }

    #[test]
    fn class_lookup_and_apply_overlay_geometry_only() {
        let p = sample();
        assert!(p.class_for(8).is_none(), "below every class: base config");
        assert_eq!(p.class_for(9).unwrap().trace_n, 32);
        assert_eq!(p.class_for(48).unwrap().trace_n, 32);
        assert_eq!(p.class_for(49).unwrap().trace_n, 64);
        assert_eq!(p.class_for(10_000).unwrap().trace_n, 64, "last class is open-ended");
        let base = Config { lookahead: false, seed: 99, ..Config::default() };
        let eff = p.apply(&base, 64);
        assert_eq!((eff.r, eff.p, eff.q, eff.slices, eff.threads), (8, 4, 4, 0, 4));
        assert!(!eff.lookahead, "non-geometry fields pass through");
        assert_eq!(eff.seed, 99);
        let untouched = p.apply(&base, 5);
        assert_eq!(untouched.r, base.r, "uncovered sizes keep the base config");
        assert_eq!(p.max_threads(), 4);
    }

    #[test]
    fn zero_threads_means_keep_base() {
        let mut p = sample();
        p.classes[0].threads = 0;
        let base = Config { threads: 3, ..Config::default() };
        assert_eq!(p.apply(&base, 32).threads, 3);
    }

    #[test]
    fn save_load_round_trip_is_identity() {
        let p = sample();
        let text = p.to_json();
        let back = TunedProfile::parse(&text).unwrap();
        assert_eq!(back, p, "parse(to_json(p)) must be p, bit-exact floats included");
        assert_eq!(
            back.classes[1].predicted_makespan.to_bits(),
            (1.0f64 / 3.0).to_bits(),
            "float fields survive exactly"
        );
        // Empty profiles round-trip too.
        let empty = TunedProfile::default();
        assert_eq!(TunedProfile::parse(&empty.to_json()).unwrap(), empty);
    }

    #[test]
    fn parse_rejects_wrong_version_kind_and_truncation() {
        let good = sample().to_json();
        // Truncated file: typed protocol error.
        let e = TunedProfile::parse(&good[..good.len() / 2]).unwrap_err();
        assert!(matches!(e, Error::Protocol(_)), "{e}");
        // Wrong schema version: typed config error.
        let e = TunedProfile::parse(&good.replace("\"schema_version\": 1", "\"schema_version\": 2"))
            .unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        // Wrong kind (profile path pointed at some other artifact).
        let e = TunedProfile::parse(&good.replace(PROFILE_KIND, "bench_artifact")).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        // Missing field.
        let e = TunedProfile::parse(&good.replace("\"r\": 4, ", "")).unwrap_err();
        assert!(matches!(e, Error::Config(_)), "{e}");
        // Not JSON at all.
        assert!(TunedProfile::parse("not json").is_err());
    }

    #[test]
    fn validation_rejects_impossible_classes() {
        let mut p = sample();
        p.classes[0].r = 16; // r >= n_min: rejected at some covered sizes
        assert!(matches!(p.validate().unwrap_err(), Error::Config(_)));
        let mut p = sample();
        p.classes[0].n_max = 5; // empty range
        assert!(p.validate().is_err());
        let mut p = sample();
        p.classes[0].p = 1;
        assert!(p.validate().is_err());
        let mut p = sample();
        p.classes[0].threads = MAX_THREADS + 1;
        assert!(p.validate().is_err());
    }

    #[test]
    fn handle_swaps_are_visible_to_clones() {
        let h = ProfileHandle::new();
        let h2 = h.clone();
        assert!(h2.snapshot().is_none());
        h.install(sample());
        assert_eq!(h2.snapshot().unwrap().classes.len(), 2, "clones share the slot");
        h2.clear();
        assert!(h.snapshot().is_none());
        let h3 = ProfileHandle::of(sample());
        assert!(h3.snapshot().is_some());
    }
}
