//! Self-tuning sessions: telemetry-driven autotuning with persisted
//! per-size-class profiles.
//!
//! The pieces, bottom-up:
//!
//! * [`json`] — the minimal pure-std JSON reader the profile artifact is
//!   loaded with (typed [`Error::Protocol`](crate::error::Error) on any
//!   malformed byte, never a panic).
//! * [`profile`] — the versioned [`TunedProfile`] artifact (schema
//!   version + kind discriminator, one [`ClassProfile`] per size class),
//!   its save/load round trip, and the hot-swappable [`ProfileHandle`]
//!   shared by a router and its sessions.
//! * [`search`] — the [`Autotuner`]: per class, trace candidate
//!   geometries once sequentially, replay the recorded DAGs through the
//!   memoized makespan simulator, keep the geometry with the best
//!   predicted makespan at the knee of its scaling curve.
//!
//! Wiring: `pallas tune` records, searches and writes the artifact in
//! one run; `PALLAS_PROFILE=<path>` (or
//! [`ServeConfig::profile`](crate::serve::ServeConfig)) loads it at
//! startup so each size class runs its tuned geometry; a corrupt or
//! stale artifact degrades to the untuned defaults with a warning, never
//! an outage. Tuned profiles change *geometry only* — every profiled
//! reduction stays bitwise-pinned to `api::reduce_seq` under its
//! effective config (`tests/tune.rs`, `benches/autotune.rs`).

pub mod json;
pub mod profile;
pub mod search;

pub use profile::{ClassProfile, ProfileHandle, TunedProfile, PROFILE_KIND, PROFILE_SCHEMA_VERSION};
pub use search::{Autotuner, ClassReport, TuneOptions};
