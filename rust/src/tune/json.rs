//! A minimal pure-std JSON reader for the tuned-profile artifacts.
//!
//! The crate *writes* JSON by hand everywhere (bench artifacts, run
//! summaries) but has never needed to read it back — profiles are the
//! first artifact that must survive a save → load round trip. This is a
//! deliberately small recursive-descent parser over the full JSON value
//! grammar (objects, arrays, strings with escapes, numbers, booleans,
//! null), not a streaming or zero-copy design: profile files are a few
//! kilobytes, read once at startup.
//!
//! Every malformed input is a typed [`Error::Protocol`] naming the byte
//! offset — the same "untrusted bytes get typed errors, never panics"
//! discipline as the wire codec in [`crate::serve::proto`]. Semantic
//! profile errors (wrong version, missing fields) are layered on top by
//! [`crate::tune::profile`] as [`Error::Config`].

use crate::error::{Error, Result};

/// One parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as `f64`; the profile schema only
    /// stores values that are exact in a double).
    Num(f64),
    /// A string (escapes decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, as insertion-ordered key/value pairs (duplicate keys
    /// are kept; [`Json::get`] returns the first).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The numeric value as a non-negative integer: rejects negatives,
    /// fractions, and anything above 2^53 (not exactly representable).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9_007_199_254_740_992.0 => {
                Some(*v as usize)
            }
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse one complete JSON document. Trailing non-whitespace bytes are an
/// error (a truncated or concatenated file must not silently parse).
pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing bytes after the JSON document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> Error {
        Error::protocol(format!("json: {msg} at byte {}", self.i))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    /// Consume a keyword (`true` / `false` / `null`) whose first byte has
    /// already been matched by the caller's peek.
    fn keyword(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected byte")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate halves never appear in the
                            // artifacts this crate writes; reject rather
                            // than mis-decode.
                            let c = char::from_u32(cp)
                                .filter(|_| !(0xD800..=0xDFFF).contains(&cp))
                                .ok_or_else(|| self.err("unsupported \\u escape"))?;
                            out.push(c);
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control byte in string")),
                Some(_) => {
                    // Copy one whole UTF-8 scalar (the input is a &str, so
                    // boundaries are guaranteed valid).
                    let rest = &self.b[self.i..];
                    let s = std::str::from_utf8(rest).map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.i.checked_add(4).filter(|&e| e <= self.b.len());
        let end = end.ok_or_else(|| self.err("truncated \\u escape"))?;
        let hex = std::str::from_utf8(&self.b[self.i..end])
            .ok()
            .and_then(|h| u32::from_str_radix(h, 16).ok())
            .ok_or_else(|| self.err("invalid \\u escape"))?;
        self.i = end;
        Ok(hex)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii number bytes");
        let v: f64 = text.parse().map_err(|_| self.err("invalid number"))?;
        if !v.is_finite() {
            return Err(self.err("non-finite number"));
        }
        Ok(Json::Num(v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_nesting() {
        let v = parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e1}}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_usize), Some(1));
        let arr = v.get("b").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_str(), Some("x\n"));
        assert_eq!(v.get("c").and_then(|c| c.get("d")).and_then(Json::as_f64), Some(-25.0));
    }

    #[test]
    fn rejects_malformed_inputs_with_typed_errors() {
        for bad in [
            "",
            "{",
            "[1, 2",
            r#"{"a": }"#,
            r#"{"a": 1} trailing"#,
            r#""unterminated"#,
            r#""bad \x escape""#,
            r#""half \u00""#,
            r#""surrogate \ud800""#,
            "1e999",
            "nul",
            "{1: 2}",
        ] {
            let e = parse(bad).unwrap_err();
            assert!(matches!(e, Error::Protocol(_)), "{bad:?}: {e}");
        }
    }

    #[test]
    fn numbers_round_trip_through_display_format() {
        // The profile writer emits f64s via `{}` (shortest round-trip
        // form); the reader must give the same bits back.
        for v in [0.0, 1.5, 1.0 / 3.0, 6.02e23, f64::MIN_POSITIVE, -0.125] {
            let parsed = parse(&format!("{v}")).unwrap();
            assert_eq!(parsed.as_f64().map(f64::to_bits), Some(v.to_bits()), "{v}");
        }
    }

    #[test]
    fn integer_accessor_rejects_lossy_values() {
        assert_eq!(parse("7").unwrap().as_usize(), Some(7));
        assert_eq!(parse("-1").unwrap().as_usize(), None);
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
        assert_eq!(parse("1e300").unwrap().as_usize(), None);
        assert_eq!(parse("\"7\"").unwrap().as_usize(), None);
    }

    #[test]
    fn whitespace_and_empty_containers() {
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("\t{ }\n").unwrap(), Json::Obj(vec![]));
    }
}
