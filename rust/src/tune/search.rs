//! The autotuner: trace-replay search over the geometry space.
//!
//! For each representative problem size the tuner reduces one seeded
//! random pencil per candidate geometry in a trace-capturing session
//! (sequential, per-task timed — see [`crate::api::TraceSink`]), then
//! replays the recorded DAG through the memoized
//! [`Simulator`](crate::coordinator::sim::Simulator) to predict the
//! parallel makespan at every worker count up to the tuning budget. The
//! simulator sweep is where the search gets cheap: one recorded trace
//! answers "how would this geometry scale?" for *all* thread counts at
//! once, so only the candidate geometries themselves cost a real
//! reduction.
//!
//! What is predicted vs what is trusted: the simulator *predicts*
//! makespans (its greedy-FIFO replay is a model of the pool, and the
//! prefix-minima memoization makes the prediction monotone in workers —
//! Graham-anomaly-proof); correctness is never predicted. Every emitted
//! config is validated against [`Config::validate_for`] across its whole
//! size class, and the bitwise contract (profiled result ==
//! `api::reduce_seq` under the same effective config) is pinned by
//! `tests/tune.rs` and the `autotune` bench, not assumed.
//!
//! The candidate grid is deliberately small (the budget default is a
//! dozen traces per class): stage-1 bandwidth `r`, block multiplier `p`,
//! sweep-group size `q`, then a slice-count refinement on the winner.
//! The default geometry is always candidate zero and is only replaced by
//! a *strictly* better prediction, so the chosen config's predicted
//! makespan is ≤ the default's by construction — the property
//! `tests/tune.rs` asserts.

use crate::api::HtSession;
use crate::config::Config;
use crate::coordinator::sim::Simulator;
use crate::error::{Error, Result};
use crate::pencil::{random_pencil, Pencil};
use crate::tune::profile::{ClassProfile, TunedProfile};
use crate::util::rng::Rng;

/// Extra predicted time (2%) we accept in exchange for fewer workers:
/// the per-class thread count is the *knee* of the scaling curve — the
/// smallest worker count within this factor of the best makespan — so a
/// tuned serving tier does not pin cores that buy nothing.
const KNEE_TOLERANCE: f64 = 1.02;

/// Candidate stage-1 bandwidths (filtered to `r < n` per class).
const R_GRID: [usize; 4] = [4, 8, 16, 32];
/// Candidate block-height multipliers.
const P_GRID: [usize; 3] = [2, 4, 8];
/// Candidate sweep-group sizes.
const Q_GRID: [usize; 3] = [2, 4, 8];
/// Slice-per-thread multipliers tried in the refinement pass.
const SLICE_MULS: [usize; 3] = [1, 2, 4];

/// Knobs of one tuning run (see [`Autotuner`]).
#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Representative problem sizes, one size class each (sorted and
    /// deduplicated by [`Autotuner::new`]; each must be ≥ 8).
    pub sizes: Vec<usize>,
    /// Largest worker count the thread sweep considers.
    pub threads: usize,
    /// Maximum traced candidates per size class (the default geometry
    /// always runs and counts against this).
    pub budget: usize,
    /// Seed for the per-class pencils (mixed with the class size, so
    /// every class sees a distinct but reproducible pencil).
    pub seed: u64,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions { sizes: vec![32, 64, 128], threads: 4, budget: 12, seed: 0x7A_57E5 }
    }
}

/// What the search did for one size class — telemetry for the CLI table
/// and the property tests; the load-bearing output is the
/// [`ClassProfile`] inside the returned [`TunedProfile`].
#[derive(Clone, Debug)]
pub struct ClassReport {
    /// Representative size the class was traced at.
    pub trace_n: usize,
    /// How many candidate geometries were actually traced.
    pub candidates: usize,
    /// Simulator-predicted makespan of the default (base) geometry.
    pub default_predicted: f64,
    /// The winning class entry (`chosen.predicted_makespan <=
    /// default_predicted` by construction).
    pub chosen: ClassProfile,
}

/// One simulator evaluation of a candidate: the predicted makespan at
/// the knee worker count (see [`KNEE_TOLERANCE`]).
#[derive(Clone, Copy, Debug)]
struct Eval {
    predicted: f64,
    threads: usize,
}

/// The telemetry-driven geometry search (see the [module docs](self)).
#[derive(Debug)]
pub struct Autotuner {
    base: Config,
    opts: TuneOptions,
}

impl Autotuner {
    /// Validate the inputs and build a tuner. The base config must
    /// itself validate; sizes must be non-empty and each ≥ 8 (below
    /// that the candidate grid collapses onto the clip path, which the
    /// profile deliberately leaves to the base config).
    pub fn new(base: Config, opts: TuneOptions) -> Result<Autotuner> {
        base.validate()?;
        let mut opts = opts;
        opts.sizes.sort_unstable();
        opts.sizes.dedup();
        if opts.sizes.is_empty() {
            return Err(Error::config("tune: at least one representative size is required"));
        }
        if let Some(&n) = opts.sizes.iter().find(|&&n| n < 8) {
            return Err(Error::config(format!("tune: size {n} is below the minimum of 8")));
        }
        if opts.threads < 1 {
            return Err(Error::config("tune: thread sweep needs at least one worker"));
        }
        if opts.budget < 1 {
            return Err(Error::config("tune: candidate budget must be at least 1"));
        }
        Ok(Autotuner { base, opts })
    }

    /// Run the search: one size class per representative size, midpoint
    /// class boundaries, open-ended last class. Returns the validated
    /// profile plus one [`ClassReport`] per class.
    pub fn run(&self) -> Result<(TunedProfile, Vec<ClassReport>)> {
        let mut classes = Vec::with_capacity(self.opts.sizes.len());
        let mut reports = Vec::with_capacity(self.opts.sizes.len());
        for (i, &n) in self.opts.sizes.iter().enumerate() {
            let (mut chosen, report) = self.tune_class(n)?;
            // Midpoint boundaries between neighbouring representative
            // sizes; the first class opens at the smallest size the band
            // fits (everything below falls through to the base config)
            // and the last is unbounded.
            let lo = if i == 0 {
                3
            } else {
                (self.opts.sizes[i - 1] + n) / 2 + 1
            };
            chosen.n_min = lo.max(chosen.r + 1).max(3);
            chosen.n_max = if i + 1 < self.opts.sizes.len() {
                (n + self.opts.sizes[i + 1]) / 2
            } else {
                0
            };
            reports.push(ClassReport { chosen: chosen.clone(), ..report });
            classes.push(chosen);
        }
        let profile = TunedProfile { classes };
        profile.validate()?;
        Ok((profile, reports))
    }

    /// Search one size class at representative size `n`.
    fn tune_class(&self, n: usize) -> Result<(ClassProfile, ClassReport)> {
        let mut rng = Rng::new(self.opts.seed ^ (n as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let pencil = random_pencil(n, &mut rng);
        // Slices are pinned up front (instead of left on auto) so the
        // traced DAG is the DAG the tuned session will actually run:
        // `effective_slices` depends on the thread count, which differs
        // between the sequential tracer and the tuned runtime.
        let slices = if self.base.slices > 0 {
            self.base.slices
        } else {
            (2 * self.opts.threads).max(4)
        };

        // Candidate 0: the default geometry, clipped exactly like an
        // untuned session would clip it.
        let default_cfg = Config { slices, ..self.base.clipped_for(n) };
        let default_eval = self.evaluate(&pencil, &default_cfg)?;
        let mut evals = 1usize;
        let mut best_cfg = default_cfg.clone();
        let mut best = default_eval;

        'grid: for &r in R_GRID.iter().filter(|&&r| r < n) {
            for &p in &P_GRID {
                for &q in &Q_GRID {
                    if (r, p, q) == (default_cfg.r, default_cfg.p, default_cfg.q) {
                        continue;
                    }
                    if evals >= self.opts.budget {
                        break 'grid;
                    }
                    let cfg = Config { r, p, q, ..default_cfg.clone() };
                    let eval = self.evaluate(&pencil, &cfg)?;
                    evals += 1;
                    // Strictly better only: ties keep the earlier (and
                    // ultimately the default) geometry, which makes
                    // "chosen prediction <= default prediction" a
                    // structural guarantee rather than a float accident.
                    if eval.predicted < best.predicted {
                        best = eval;
                        best_cfg = cfg;
                    }
                }
            }
        }

        // Refinement: re-slice the winning geometry. More slices expose
        // parallelism, fewer amortize task overhead; the traced grid is
        // tiny because each slice count is a fresh DAG (a fresh trace).
        for &m in &SLICE_MULS {
            let s = (m * self.opts.threads).max(4);
            if s == best_cfg.slices || evals >= self.opts.budget {
                continue;
            }
            let cfg = Config { slices: s, ..best_cfg.clone() };
            let eval = self.evaluate(&pencil, &cfg)?;
            evals += 1;
            if eval.predicted < best.predicted {
                best = eval;
                best_cfg = cfg;
            }
        }

        let chosen = ClassProfile {
            n_min: 3, // placeholder; `run` assigns the class boundaries
            n_max: 0,
            r: best_cfg.r,
            p: best_cfg.p,
            q: best_cfg.q,
            slices: best_cfg.slices,
            threads: best.threads,
            predicted_makespan: best.predicted,
            default_makespan: default_eval.predicted,
            trace_n: n,
        };
        let report = ClassReport {
            trace_n: n,
            candidates: evals,
            default_predicted: default_eval.predicted,
            chosen: chosen.clone(),
        };
        Ok((chosen, report))
    }

    /// Trace one reduction under `cfg` and predict its parallel
    /// makespan: stage-1 + stage-2 memoized simulators, swept from one
    /// worker up to the budget, keeping the knee.
    fn evaluate(&self, pencil: &Pencil, cfg: &Config) -> Result<Eval> {
        let trace_cfg = Config { threads: 1, ..cfg.clone() };
        let mut session =
            HtSession::builder().config(trace_cfg).capture_traces(true).build()?;
        session.reduce(&pencil.a, &pencil.b)?;
        let (t1, t2) = session
            .take_traces()
            .expect("trace-capturing sessions record traces on every reduce");
        let mut s1 = Simulator::new(&t1);
        let mut s2 = Simulator::new(&t2);
        let floor = s1.result(self.opts.threads).makespan + s2.result(self.opts.threads).makespan;
        for t in 1..=self.opts.threads {
            let m = s1.result(t).makespan + s2.result(t).makespan;
            if m <= floor * KNEE_TOLERANCE {
                return Ok(Eval { predicted: m, threads: t });
            }
        }
        // Unreachable (t = threads always satisfies the bound), but keep
        // the fallback total rather than a panic path.
        Ok(Eval { predicted: floor, threads: self.opts.threads })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts() -> TuneOptions {
        TuneOptions { sizes: vec![16, 32], threads: 2, budget: 3, seed: 7 }
    }

    #[test]
    fn rejects_bad_inputs() {
        let base = Config { r: 4, p: 2, q: 2, ..Config::default() };
        assert!(Autotuner::new(base.clone(), TuneOptions { sizes: vec![], ..tiny_opts() }).is_err());
        assert!(
            Autotuner::new(base.clone(), TuneOptions { sizes: vec![4], ..tiny_opts() }).is_err()
        );
        assert!(Autotuner::new(base.clone(), TuneOptions { threads: 0, ..tiny_opts() }).is_err());
        assert!(Autotuner::new(base.clone(), TuneOptions { budget: 0, ..tiny_opts() }).is_err());
        let bad = Config { r: 0, ..base };
        assert!(Autotuner::new(bad, tiny_opts()).is_err());
    }

    #[test]
    fn sizes_are_sorted_and_deduped() {
        let base = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let tuner = Autotuner::new(
            base,
            TuneOptions { sizes: vec![32, 16, 32], ..tiny_opts() },
        )
        .unwrap();
        assert_eq!(tuner.opts.sizes, vec![16, 32]);
    }

    #[test]
    fn emitted_profile_validates_and_never_predicts_slower_than_default() {
        let base = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let tuner = Autotuner::new(base, tiny_opts()).unwrap();
        let (profile, reports) = tuner.run().unwrap();
        assert_eq!(profile.classes.len(), 2);
        profile.validate().unwrap();
        assert_eq!(profile.classes[0].n_max + 1, profile.classes[1].n_min);
        assert_eq!(profile.classes[1].n_max, 0, "last class is open-ended");
        for (c, rep) in profile.classes.iter().zip(&reports) {
            assert!(c.predicted_makespan <= rep.default_predicted);
            assert!(c.threads >= 1 && c.threads <= 2);
            assert!(rep.candidates <= 3, "budget is a hard cap");
            assert!(c.n_min > c.r);
        }
    }

    #[test]
    fn budget_of_one_keeps_the_default_geometry() {
        let base = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let tuner = Autotuner::new(
            base.clone(),
            TuneOptions { sizes: vec![16], budget: 1, ..tiny_opts() },
        )
        .unwrap();
        let (profile, reports) = tuner.run().unwrap();
        let c = &profile.classes[0];
        assert_eq!((c.r, c.p, c.q), (base.r, base.p, base.q));
        assert_eq!(reports[0].candidates, 1);
        assert_eq!(c.predicted_makespan, c.default_makespan);
    }
}
