//! Workload generators: random pencils and saddle-point pencils with a
//! controlled fraction of infinite eigenvalues (§4 of the paper).

pub mod random;
pub mod saddle;

pub use random::{pre_triangularize, random_pencil, random_pencil_general, Pencil};
pub use saddle::saddle_pencil;
