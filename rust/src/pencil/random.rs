//! Random test pencils (§4 "Tests on random pencils").
//!
//! The paper generates random `(A, B)` and then QR-factors `B` so the input
//! satisfies Algorithm 1's precondition (upper-triangular `B`). A random
//! matrix is well conditioned with overwhelming probability, which matters
//! for the iterative baselines (`IterHT`, `HouseHT`).

use crate::linalg::matrix::Matrix;
use crate::linalg::qr::QrFactor;
use crate::util::rng::Rng;

/// A matrix pencil `(A, B)`.
#[derive(Clone, Debug)]
pub struct Pencil {
    /// The `A` matrix.
    pub a: Matrix,
    /// The `B` matrix.
    pub b: Matrix,
    /// Number of eigenvalues that are infinite by construction (0 for
    /// random pencils; `2k` for saddle-point pencils).
    pub infinite_eigenvalues: usize,
}

impl Pencil {
    /// Problem size.
    pub fn n(&self) -> usize {
        self.a.rows()
    }
}

/// Random dense pencil with `B` already upper triangular (via QR of a random
/// matrix, keeping `R`).
pub fn random_pencil(n: usize, rng: &mut Rng) -> Pencil {
    let a = Matrix::randn(n, n, rng);
    let braw = Matrix::randn(n, n, rng);
    let f = QrFactor::compute_inplace(braw);
    let mut b = Matrix::zeros(n, n);
    let r = f.r();
    for j in 0..n {
        for i in 0..=j {
            b[(i, j)] = r[(i, j)];
        }
    }
    Pencil { a, b, infinite_eigenvalues: 0 }
}

/// Random dense pencil with a *general* (not yet triangular) `B` — exercises
/// the pre-triangularization path of the public API.
pub fn random_pencil_general(n: usize, rng: &mut Rng) -> Pencil {
    Pencil {
        a: Matrix::randn(n, n, rng),
        b: Matrix::randn(n, n, rng),
        infinite_eigenvalues: 0,
    }
}

/// Make `B` upper triangular by an orthogonal left transformation shared
/// with `A`: `B = Q₀ R ⇒ (A, B) ← (Q₀ᵀ A, R)`, accumulating `Q₀` into `q`.
/// This is the standard preprocessing when the input `B` is dense.
pub fn pre_triangularize(a: &mut Matrix, b: &mut Matrix, q: &mut Matrix) {
    let n = b.rows();
    let f = QrFactor::compute(b);
    // A ← Q₀ᵀ A
    f.apply_qt_left(a.as_mut());
    // Q ← Q Q₀
    f.apply_q_right(q.as_mut());
    // B ← R (exact zeros below the diagonal)
    let r = f.r();
    for j in 0..n {
        for i in 0..n {
            b[(i, j)] = if i <= j { r[(i, j)] } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::{max_below_band, reconstruction_error};

    #[test]
    fn random_pencil_b_triangular() {
        let mut rng = Rng::new(1);
        let p = random_pencil(20, &mut rng);
        assert_eq!(max_below_band(&p.b, 0), 0.0);
        assert_eq!(p.n(), 20);
        assert!(p.a.norm_fro() > 0.0);
    }

    #[test]
    fn pre_triangularize_is_equivalence() {
        let mut rng = Rng::new(2);
        let p = random_pencil_general(15, &mut rng);
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let mut a = p.a;
        let mut b = p.b;
        let mut q = Matrix::identity(15);
        pre_triangularize(&mut a, &mut b, &mut q);
        assert_eq!(max_below_band(&b, 0), 0.0);
        let z = Matrix::identity(15);
        // A0 = Q A, B0 = Q B
        assert!(reconstruction_error(&a0, &q, &a, &z) < 1e-13);
        assert!(reconstruction_error(&b0, &q, &b, &z) < 1e-13);
    }
}
