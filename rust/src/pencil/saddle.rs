//! Saddle-point pencils (§4 "Tests on saddle point problems").
//!
//! ```text
//! (A, B) = ( [X  Y ]   [I  0] )
//!          ( [Yᵀ 0 ] , [0  0] )
//! ```
//!
//! with `X` symmetric positive definite (`m×m`), `Y` random (`m×k`). The
//! pencil has `2k` infinite eigenvalues (the determinant `det(A − λB)` has
//! degree `m − k`), so choosing `k = n·frac/2` puts `frac` of the spectrum
//! at infinity. The paper uses 25% (`k = n/8`). Such pencils break the
//! iterative comparators: `HouseHT` needs extra refinement and `IterHT`
//! fails to converge, while ParaHT and LAPACK are oblivious.

use super::random::Pencil;
use crate::linalg::gemm::{matmul_t, Trans};
use crate::linalg::matrix::Matrix;
use crate::util::rng::Rng;

/// Build a saddle-point pencil of order `n` with (approximately) the given
/// fraction of infinite eigenvalues.
pub fn saddle_pencil(n: usize, infinite_fraction: f64, rng: &mut Rng) -> Pencil {
    assert!((0.0..1.0).contains(&infinite_fraction));
    let k = ((infinite_fraction * n as f64) / 2.0).round() as usize;
    let k = k.min(n / 2);
    let m = n - k;

    // X = G Gᵀ/m + I : symmetric positive definite, eigenvalues in [1, ~5].
    let g = Matrix::randn(m, m, rng);
    let ggt = matmul_t(&g, Trans::No, &g, Trans::Yes);
    let mut x = Matrix::zeros(m, m);
    for j in 0..m {
        for i in 0..m {
            x[(i, j)] = ggt[(i, j)] / m as f64 + if i == j { 1.0 } else { 0.0 };
        }
    }
    let y = Matrix::randn(m, k, rng);

    let mut a = Matrix::zeros(n, n);
    for j in 0..m {
        for i in 0..m {
            a[(i, j)] = x[(i, j)];
        }
    }
    for j in 0..k {
        for i in 0..m {
            a[(i, m + j)] = y[(i, j)]; // Y block
            a[(m + j, i)] = y[(i, j)]; // Yᵀ block
        }
    }
    // A(m.., m..) = 0 by construction.

    let mut b = Matrix::zeros(n, n);
    for i in 0..m {
        b[(i, i)] = 1.0;
    }

    Pencil { a, b, infinite_eigenvalues: 2 * k }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::max_below_band;

    #[test]
    fn structure_is_correct() {
        let mut rng = Rng::new(3);
        let n = 16;
        let p = saddle_pencil(n, 0.25, &mut rng);
        // 25% infinite: k = 2, m = 14.
        assert_eq!(p.infinite_eigenvalues, 4);
        let m = n - 2;
        // B = diag(I_m, 0)
        assert_eq!(max_below_band(&p.b, 0), 0.0);
        for i in 0..n {
            assert_eq!(p.b[(i, i)], if i < m { 1.0 } else { 0.0 });
        }
        // A symmetric with zero lower-right block
        for i in 0..n {
            for j in 0..n {
                assert!((p.a[(i, j)] - p.a[(j, i)]).abs() < 1e-15);
            }
        }
        for i in m..n {
            for j in m..n {
                assert_eq!(p.a[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn x_block_positive_definite() {
        let mut rng = Rng::new(4);
        let p = saddle_pencil(24, 0.25, &mut rng);
        let m = 24 - 3;
        // Positive definiteness via Cholesky-ish check: all leading quadratic
        // forms vᵀXv > 0 for a few random v.
        for _ in 0..10 {
            let v: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
            let mut q = 0.0;
            for i in 0..m {
                for j in 0..m {
                    q += v[i] * p.a[(i, j)] * v[j];
                }
            }
            assert!(q > 0.0);
        }
    }

    #[test]
    fn fraction_zero_gives_regular_b() {
        let mut rng = Rng::new(5);
        let p = saddle_pencil(10, 0.0, &mut rng);
        assert_eq!(p.infinite_eigenvalues, 0);
        for i in 0..10 {
            assert_eq!(p.b[(i, i)], 1.0);
        }
    }
}
