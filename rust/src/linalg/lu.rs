//! Small dense LU factorization with partial pivoting (LAPACK `dgetrf`/
//! `dgetrs` analogue, unblocked — used on `p·n_b`-sized blocks only).
//!
//! This powers the *solve-based* opposite-reflector construction of the
//! `IterHT`/`HouseHT` baselines: solving with `B` instead of orthogonally
//! factoring it is cheaper but inherits `B`'s conditioning — exactly the
//! sensitivity the paper exploits in its saddle-point experiments (§4).

use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;
use crate::util::flops;

/// LU factorization with partial pivoting: `P A = L U` stored in place.
pub struct LuFactor {
    /// Combined `L\U` storage (unit diagonal of `L` implicit).
    pub lu: Matrix,
    /// Pivot row chosen at each step.
    pub piv: Vec<usize>,
}

impl LuFactor {
    /// Factor a copy of the square matrix `a`. Returns `Err(Numerical)` on
    /// an exactly-zero pivot (singular to working precision).
    pub fn compute(a: &Matrix) -> Result<LuFactor> {
        let n = a.rows();
        assert_eq!(a.cols(), n, "LU: square only");
        let mut lu = a.clone();
        let mut piv = vec![0usize; n];
        flops::add(2 * (n as u64).pow(3) / 3);
        for k in 0..n {
            // Partial pivot.
            let mut p = k;
            let mut best = lu[(k, k)].abs();
            for i in k + 1..n {
                if lu[(i, k)].abs() > best {
                    best = lu[(i, k)].abs();
                    p = i;
                }
            }
            piv[k] = p;
            if best == 0.0 {
                return Err(Error::numerical(format!("LU: zero pivot at column {k}")));
            }
            if p != k {
                for j in 0..n {
                    let t = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = t;
                }
            }
            let inv = 1.0 / lu[(k, k)];
            for i in k + 1..n {
                lu[(i, k)] *= inv;
            }
            for j in k + 1..n {
                let ukj = lu[(k, j)];
                if ukj != 0.0 {
                    for i in k + 1..n {
                        let l = lu[(i, k)];
                        lu[(i, j)] -= l * ukj;
                    }
                }
            }
        }
        Ok(LuFactor { lu, piv })
    }

    /// Solve `A x = b` in place (`b` overwritten by `x`).
    pub fn solve_vec(&self, b: &mut [f64]) {
        let n = self.lu.rows();
        debug_assert_eq!(b.len(), n);
        flops::add(2 * (n as u64).pow(2));
        // Apply row permutation.
        for k in 0..n {
            b.swap(k, self.piv[k]);
        }
        // L y = Pb (unit lower).
        for i in 1..n {
            let mut s = b[i];
            for j in 0..i {
                s -= self.lu[(i, j)] * b[j];
            }
            b[i] = s;
        }
        // U x = y.
        for i in (0..n).rev() {
            let mut s = b[i];
            for j in i + 1..n {
                s -= self.lu[(i, j)] * b[j];
            }
            b[i] = s / self.lu[(i, i)];
        }
    }

    /// Solve for several right-hand sides (columns of `rhs`, in place).
    pub fn solve(&self, rhs: &mut Matrix) {
        for j in 0..rhs.cols() {
            let mut col: Vec<f64> = (0..rhs.rows()).map(|i| rhs[(i, j)]).collect();
            self.solve_vec(&mut col);
            for (i, v) in col.into_iter().enumerate() {
                rhs[(i, j)] = v;
            }
        }
    }

    /// Crude reciprocal-condition estimate: `min |U_ii| / max |U_ii|`.
    pub fn rcond_estimate(&self) -> f64 {
        let n = self.lu.rows();
        let mut mn = f64::INFINITY;
        let mut mx = 0.0f64;
        for i in 0..n {
            let d = self.lu[(i, i)].abs();
            mn = mn.min(d);
            mx = mx.max(d);
        }
        if mx == 0.0 {
            0.0
        } else {
            mn / mx
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul;
    use crate::util::rng::Rng;

    #[test]
    fn solves_random_systems() {
        let mut rng = Rng::new(100);
        for &n in &[1usize, 2, 5, 17, 40] {
            let a = Matrix::randn(n, n, &mut rng);
            let xtrue = Matrix::randn(n, 1, &mut rng);
            let b = matmul(&a, &xtrue);
            let f = LuFactor::compute(&a).unwrap();
            let mut x: Vec<f64> = (0..n).map(|i| b[(i, 0)]).collect();
            f.solve_vec(&mut x);
            for i in 0..n {
                assert!((x[i] - xtrue[(i, 0)]).abs() < 1e-10, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn multi_rhs() {
        let mut rng = Rng::new(101);
        let n = 8;
        let a = Matrix::randn(n, n, &mut rng);
        let xt = Matrix::randn(n, 3, &mut rng);
        let mut b = matmul(&a, &xt);
        let f = LuFactor::compute(&a).unwrap();
        f.solve(&mut b);
        for j in 0..3 {
            for i in 0..n {
                assert!((b[(i, j)] - xt[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn detects_exact_singularity() {
        let mut a = Matrix::identity(4);
        a[(2, 2)] = 0.0;
        // Column 2 entirely zero below and above diag → zero pivot.
        assert!(LuFactor::compute(&a).is_err());
    }

    #[test]
    fn rcond_reflects_conditioning() {
        let good = LuFactor::compute(&Matrix::identity(5)).unwrap();
        assert!((good.rcond_estimate() - 1.0).abs() < 1e-15);
        let mut bad = Matrix::identity(5);
        bad[(4, 4)] = 1e-14;
        let f = LuFactor::compute(&bad).unwrap();
        assert!(f.rcond_estimate() < 1e-10);
    }
}
