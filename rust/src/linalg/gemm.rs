//! General matrix-matrix multiplication: `C = alpha·op(A)·op(B) + beta·C`.
//!
//! This is the substrate the paper gets from MKL; here it is built from
//! scratch. The no-transpose fast path packs `A` into an L2-resident block
//! and runs a column-axpy microkernel over contiguous columns of `B`/`C`;
//! the transpose cases use dot-product kernels over contiguous columns.
//! Absolute throughput is recorded in EXPERIMENTS.md §Perf; all paper plots
//! are relative so the algorithms only need a *consistent* GEMM.

use super::matrix::{MatMut, MatRef, Matrix};
use crate::util::flops;

/// Transposition selector for [`gemm`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trans {
    /// Use the matrix as stored.
    No,
    /// Use the transpose.
    Yes,
}

/// Cache block size in the k (inner) dimension.
const KC: usize = 256;
/// Cache block size in the m (row) dimension.
const MC: usize = 128;

/// `C = alpha·op(A)·op(B) + beta·C`.
///
/// Dimensions: `op(A)` is `m×k`, `op(B)` is `k×n`, `C` is `m×n`; asserts on
/// mismatch.
pub fn gemm(alpha: f64, a: MatRef<'_>, ta: Trans, b: MatRef<'_>, tb: Trans, beta: f64, mut c: MatMut<'_>) {
    let m = c.rows();
    let n = c.cols();
    let (am, ak) = match ta {
        Trans::No => (a.rows(), a.cols()),
        Trans::Yes => (a.cols(), a.rows()),
    };
    let (bk, bn) = match tb {
        Trans::No => (b.rows(), b.cols()),
        Trans::Yes => (b.cols(), b.rows()),
    };
    assert_eq!(am, m, "gemm: op(A) rows {am} != C rows {m}");
    assert_eq!(bn, n, "gemm: op(B) cols {bn} != C cols {n}");
    assert_eq!(ak, bk, "gemm: inner dims {ak} != {bk}");
    let k = ak;

    // beta scaling first (also handles k == 0).
    if beta != 1.0 {
        for j in 0..n {
            let cj = c.col_mut(j);
            if beta == 0.0 {
                cj.fill(0.0);
            } else {
                super::blas1::scal(beta, cj);
            }
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    flops::add(2 * (m as u64) * (n as u64) * (k as u64));

    match (ta, tb) {
        (Trans::No, Trans::No) => gemm_nn(alpha, a, b, c),
        (Trans::Yes, Trans::No) => gemm_tn(alpha, a, b, c),
        (Trans::No, Trans::Yes) => gemm_nt(alpha, a, b, c),
        (Trans::Yes, Trans::Yes) => gemm_tt(alpha, a, b, c),
    }
}

/// C += alpha * A * B  (A m×k, B k×n). Packed-A column-axpy kernel.
fn gemm_nn(alpha: f64, a: MatRef<'_>, b: MatRef<'_>, mut c: MatMut<'_>) {
    let m = c.rows();
    let n = c.cols();
    let k = a.cols();
    // Pack buffer reused across (l0, i0) blocks.
    let mut pack = vec![0.0f64; MC * KC];
    let mut l0 = 0;
    while l0 < k {
        let kb = KC.min(k - l0);
        let mut i0 = 0;
        while i0 < m {
            let mb = MC.min(m - i0);
            // Pack A(i0..i0+mb, l0..l0+kb) column-major into `pack`.
            for l in 0..kb {
                let src = a.sub(i0..i0 + mb, l0 + l..l0 + l + 1);
                pack[l * mb..(l + 1) * mb].copy_from_slice(src.col(0));
            }
            // For each column of C, accumulate the packed block.
            for j in 0..n {
                let bj = b.col(j);
                let cj = &mut c.col_mut(j)[i0..i0 + mb];
                // 4-way unroll over l for ILP.
                let mut l = 0;
                while l + 4 <= kb {
                    let x0 = alpha * bj[l0 + l];
                    let x1 = alpha * bj[l0 + l + 1];
                    let x2 = alpha * bj[l0 + l + 2];
                    let x3 = alpha * bj[l0 + l + 3];
                    let a0 = &pack[l * mb..(l + 1) * mb];
                    let a1 = &pack[(l + 1) * mb..(l + 2) * mb];
                    let a2 = &pack[(l + 2) * mb..(l + 3) * mb];
                    let a3 = &pack[(l + 3) * mb..(l + 4) * mb];
                    for i in 0..mb {
                        cj[i] += x0 * a0[i] + x1 * a1[i] + x2 * a2[i] + x3 * a3[i];
                    }
                    l += 4;
                }
                while l < kb {
                    let x = alpha * bj[l0 + l];
                    let al = &pack[l * mb..(l + 1) * mb];
                    for i in 0..mb {
                        cj[i] += x * al[i];
                    }
                    l += 1;
                }
            }
            i0 += mb;
        }
        l0 += kb;
    }
}

/// C += alpha * Aᵀ * B  (A k×m, B k×n). Columns of A and B are contiguous;
/// four B/C columns are processed together so each A column is loaded once
/// per quad (≈2× over the naive dot-product loop).
fn gemm_tn(alpha: f64, a: MatRef<'_>, b: MatRef<'_>, mut c: MatMut<'_>) {
    let m = c.rows();
    let n = c.cols();
    let k = a.rows();
    let mut j = 0;
    while j + 4 <= n {
        let (b0, b1, b2, b3) = (b.col(j), b.col(j + 1), b.col(j + 2), b.col(j + 3));
        for i in 0..m {
            let ai = a.col(i);
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for l in 0..k {
                let av = ai[l];
                s0 += av * b0[l];
                s1 += av * b1[l];
                s2 += av * b2[l];
                s3 += av * b3[l];
            }
            unsafe {
                let ld = c.ld();
                let base = c.ptr();
                *base.add(i + j * ld) += alpha * s0;
                *base.add(i + (j + 1) * ld) += alpha * s1;
                *base.add(i + (j + 2) * ld) += alpha * s2;
                *base.add(i + (j + 3) * ld) += alpha * s3;
            }
        }
        j += 4;
    }
    while j < n {
        // Same single-accumulator order as the quad path: a column's value
        // must not depend on which path computes it (the parallel slices
        // must match the sequential full-width call bit for bit).
        let bj = b.col(j);
        let cj = c.col_mut(j);
        for i in 0..m {
            let ai = a.col(i);
            let mut s = 0.0;
            for l in 0..k {
                s += ai[l] * bj[l];
            }
            cj[i] += alpha * s;
        }
        j += 1;
    }
}

/// C += alpha * A * Bᵀ  (A m×k, B n×k). Axpy over columns of C with scalars
/// read down rows of B.
fn gemm_nt(alpha: f64, a: MatRef<'_>, b: MatRef<'_>, mut c: MatMut<'_>) {
    let n = c.cols();
    let k = a.cols();
    for j in 0..n {
        let cj = c.col_mut(j);
        for l in 0..k {
            let x = alpha * b.at(j, l);
            if x != 0.0 {
                super::blas1::axpy(x, a.col(l), cj);
            }
        }
    }
}

/// C += alpha * Aᵀ * Bᵀ (rare; strided dot).
fn gemm_tt(alpha: f64, a: MatRef<'_>, b: MatRef<'_>, mut c: MatMut<'_>) {
    let m = c.rows();
    let n = c.cols();
    let k = a.rows();
    for j in 0..n {
        for i in 0..m {
            let mut s = 0.0;
            for l in 0..k {
                s += a.at(l, i) * b.at(j, l);
            }
            *c.at_mut(i, j) += alpha * s;
        }
    }
}

/// Convenience: allocate and return `A·B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut());
    c
}

/// Convenience: `op(A)·op(B)` into a fresh matrix.
pub fn matmul_t(a: &Matrix, ta: Trans, b: &Matrix, tb: Trans) -> Matrix {
    let m = if ta == Trans::No { a.rows() } else { a.cols() };
    let n = if tb == Trans::No { b.cols() } else { b.rows() };
    let mut c = Matrix::zeros(m, n);
    gemm(1.0, a.as_ref(), ta, b.as_ref(), tb, 0.0, c.as_mut());
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive reference multiply for validation.
    fn reference(a: &Matrix, ta: Trans, b: &Matrix, tb: Trans) -> Matrix {
        let (m, k) = if ta == Trans::No { (a.rows(), a.cols()) } else { (a.cols(), a.rows()) };
        let n = if tb == Trans::No { b.cols() } else { b.rows() };
        Matrix::from_fn(m, n, |i, j| {
            let mut s = 0.0;
            for l in 0..k {
                let av = if ta == Trans::No { a[(i, l)] } else { a[(l, i)] };
                let bv = if tb == Trans::No { b[(l, j)] } else { b[(j, l)] };
                s += av * bv;
            }
            s
        })
    }

    fn rel_err(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = x.clone();
        for j in 0..d.cols() {
            for i in 0..d.rows() {
                d[(i, j)] -= y[(i, j)];
            }
        }
        d.norm_fro() / y.norm_fro().max(1e-300)
    }

    #[test]
    fn small_exact() {
        let a = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_rows(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c[(0, 0)], 58.0);
        assert_eq!(c[(0, 1)], 64.0);
        assert_eq!(c[(1, 0)], 139.0);
        assert_eq!(c[(1, 1)], 154.0);
    }

    #[test]
    fn all_transpose_cases_match_reference() {
        let mut rng = Rng::new(99);
        for &(m, n, k) in &[(5usize, 7usize, 3usize), (17, 13, 33), (130, 70, 300), (1, 9, 4)] {
            for &ta in &[Trans::No, Trans::Yes] {
                for &tb in &[Trans::No, Trans::Yes] {
                    let a = if ta == Trans::No { Matrix::randn(m, k, &mut rng) } else { Matrix::randn(k, m, &mut rng) };
                    let b = if tb == Trans::No { Matrix::randn(k, n, &mut rng) } else { Matrix::randn(n, k, &mut rng) };
                    let got = matmul_t(&a, ta, &b, tb);
                    let want = reference(&a, ta, &b, tb);
                    assert!(rel_err(&got, &want) < 1e-13, "case {m}x{n}x{k} {ta:?}{tb:?}");
                }
            }
        }
    }

    #[test]
    fn alpha_beta_semantics() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(6, 4, &mut rng);
        let b = Matrix::randn(4, 5, &mut rng);
        let c0 = Matrix::randn(6, 5, &mut rng);
        // C = 2 A B + 3 C0
        let mut c = c0.clone();
        gemm(2.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 3.0, c.as_mut());
        let want = {
            let ab = matmul(&a, &b);
            Matrix::from_fn(6, 5, |i, j| 2.0 * ab[(i, j)] + 3.0 * c0[(i, j)])
        };
        assert!(rel_err(&c, &want) < 1e-13);
        // beta = 0 must overwrite even NaN-free garbage
        let mut c = Matrix::from_fn(6, 5, |_, _| 777.0);
        gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut());
        let want = matmul(&a, &b);
        assert!(rel_err(&c, &want) < 1e-13);
    }

    #[test]
    fn zero_inner_dim_scales_only() {
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 2);
        let mut c = Matrix::from_fn(3, 2, |_, _| 2.0);
        gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.5, c.as_mut());
        assert_eq!(c[(0, 0)], 1.0);
    }

    #[test]
    fn counts_flops() {
        crate::util::flops::set_enabled(true);
        let mut rng = Rng::new(1);
        let a = Matrix::randn(10, 20, &mut rng);
        let b = Matrix::randn(20, 30, &mut rng);
        let (_, n) = crate::util::flops::count(|| matmul(&a, &b));
        assert_eq!(n, 2 * 10 * 20 * 30);
    }

    #[test]
    fn submatrix_views_with_ld() {
        // gemm over views whose ld != rows.
        let mut rng = Rng::new(11);
        let big_a = Matrix::randn(10, 10, &mut rng);
        let big_b = Matrix::randn(10, 10, &mut rng);
        let a = big_a.sub(2..7, 1..9); // 5x8
        let b = big_b.sub(0..8, 3..9); // 8x6
        let mut c = Matrix::zeros(5, 6);
        gemm(1.0, a, Trans::No, b, Trans::No, 0.0, c.as_mut());
        let want = reference(&a.to_owned(), Trans::No, &b.to_owned(), Trans::No);
        assert!(rel_err(&c, &want) < 1e-13);
    }
}
