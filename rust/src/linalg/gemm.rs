//! General matrix-matrix multiplication: `C = alpha·op(A)·op(B) + beta·C`.
//!
//! This is the substrate the paper gets from MKL; here it is built from
//! scratch as a packed, register-tiled design (GotoBLAS/BLIS loop
//! structure): both operands are packed — `op(A)` into `MR`-row micro-panels
//! resident in L2, `op(B)` into `NR`-column micro-panels resident in L3 —
//! and a single unrolled `MR×NR` microkernel serves all four `Trans`
//! combinations (the transposition is absorbed entirely by the packing, so
//! the edge-case tails are shared too: short tiles are zero-padded to full
//! micro-panels and only the valid `mr×nr` corner is written back).
//!
//! The microkernel itself comes in runtime-dispatched variants
//! ([`super::kernels`]): the portable scalar reference, AVX2+FMA on
//! x86-64, NEON on aarch64 — selected once per process via
//! `PALLAS_KERNEL` / [`crate::config::Config::kernel`] and resolved here
//! once per [`gemm`] call from the thread-local [`kernels::current`].
//! All variants share the `MR×NR` tile and the pack layout, so the
//! blocking and panel geometry below are kernel-independent.
//!
//! **Determinism contract** (load-bearing — the parallel coordinator pins
//! its output bitwise to the sequential oracle): *for a fixed kernel*,
//! every element `C[i,j]` accumulates `op(A)[i,l]·op(B)[l,j]` in ascending
//! `l` order into its own accumulator (a scalar or a private SIMD lane —
//! lanes never mix), one `KC`-block at a time, and receives
//! `alpha·(block sum)` once per `KC` block. Neither the `m`/`n` blocking
//! nor the position of the element inside a tile affects that order, so the
//! result is *bitwise invariant* under row/column slicing — computing a
//! column slice of `C` gives exactly the bits of the corresponding columns
//! of the full product. [`gemm_par`] and the coordinator's sliced apply
//! tasks rely on this. *Across* kernels the bits differ by O(eps) (fused
//! vs unfused per-term rounding); the scalar kernel is the cross-kernel
//! reference — see `super::kernels` and `tests/kernels.rs`.
//!
//! Absolute throughput is recorded by `benches/gemm_kernels.rs` into
//! `BENCH_gemm.json` (per kernel variant, with a GFLOP/s column — see
//! EXPERIMENTS.md §Perf); all paper plots are relative so the algorithms
//! only need a *consistent* GEMM.

use super::kernels::{self, Kernel};
use super::matrix::{MatMut, MatRef, Matrix};
use crate::coordinator::assist::{self, Schedule};
use crate::coordinator::pool;
use crate::coordinator::slices::{partition, partition_capped};
use crate::util::flops;
use std::cell::RefCell;

/// Transposition selector for [`gemm`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Trans {
    /// Use the matrix as stored.
    No,
    /// Use the transpose.
    Yes,
}

pub use super::kernels::{MR, NR};
/// Cache block size in the k (inner) dimension: `MR·KC` doubles ≈ 16 KiB
/// per A micro-panel, `KC·NC` ≈ 1 MiB for the packed B panel.
const KC: usize = 256;
/// Cache block size in the m (row) dimension (multiple of `MR`;
/// `MC·KC` doubles = 256 KiB — L2 resident).
const MC: usize = 128;
/// Cache block size in the n (column) dimension (multiple of `NR`).
const NC: usize = 512;

/// Minimum `2mnk` flop count before [`gemm_par`] (and `WyRep::apply_par`,
/// which shares this constant) fans out to the pool; below this the
/// submit/wake/drain round trip through the persistent pool (cheap, but
/// not free) dominates the multiply itself.
pub(crate) const PAR_MIN_FLOPS: usize = 2_000_000;

thread_local! {
    /// Per-thread packing buffers (A panel, B panel), grown on demand and
    /// reused across calls on long-lived threads. The reuse pays off both
    /// on the *calling* thread (the sequential drivers' many small GEMMs)
    /// and on the persistent pool workers (`coordinator::pool`): workers
    /// live for the whole process, so their buffers are packed hot across
    /// every `gemm_par`/`apply_par` panel of a reduction instead of being
    /// reallocated per call as under the old scoped-spawn model.
    static PACK: RefCell<(Vec<f64>, Vec<f64>)> = const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Resolved `op` dimensions: (`op(A)` rows, inner dim) / (inner, `op(B)` cols).
fn op_dims(a: MatRef<'_>, ta: Trans) -> (usize, usize) {
    match ta {
        Trans::No => (a.rows(), a.cols()),
        Trans::Yes => (a.cols(), a.rows()),
    }
}

/// `C = alpha·op(A)·op(B) + beta·C`.
///
/// Dimensions: `op(A)` is `m×k`, `op(B)` is `k×n`, `C` is `m×n`; asserts on
/// mismatch.
pub fn gemm(alpha: f64, a: MatRef<'_>, ta: Trans, b: MatRef<'_>, tb: Trans, beta: f64, mut c: MatMut<'_>) {
    let m = c.rows();
    let n = c.cols();
    let (am, ak) = op_dims(a, ta);
    let (bk, bn) = op_dims(b, tb);
    assert_eq!(am, m, "gemm: op(A) rows {am} != C rows {m}");
    assert_eq!(bn, n, "gemm: op(B) cols {bn} != C cols {n}");
    assert_eq!(ak, bk, "gemm: inner dims {ak} != {bk}");
    let k = ak;

    // beta scaling first (also handles k == 0).
    scale_c(beta, c.rb_mut());
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    flops::add(2 * (m as u64) * (n as u64) * (k as u64));
    // Resolve the microkernel variant once per call from the thread-local
    // override (installed by the drivers / pool workers from
    // `Config::resolved_kernel`), falling back to the process default.
    let kernel = kernels::current();
    gemm_packed(alpha, a, ta, b, tb, c, kernel);
}

/// Apply the `beta` prescale to `C` (exactly as LAPACK: `beta == 0`
/// overwrites, so NaN/Inf garbage in `C` cannot leak through).
fn scale_c(beta: f64, mut c: MatMut<'_>) {
    if beta == 1.0 {
        return;
    }
    for j in 0..c.cols() {
        let cj = c.col_mut(j);
        if beta == 0.0 {
            cj.fill(0.0);
        } else {
            super::blas1::scal(beta, cj);
        }
    }
}

/// The packed kernel driver (post-validation, `beta` already applied,
/// non-degenerate dims). GotoBLAS loop order: `jc` (NC) → `l0` (KC, pack B)
/// → `ic` (MC, pack A) → `jr` (NR) → `ir` (MR) → microkernel (the
/// `kernel`-selected variant; the packing and blocking are shared).
fn gemm_packed(
    alpha: f64,
    a: MatRef<'_>,
    ta: Trans,
    b: MatRef<'_>,
    tb: Trans,
    mut c: MatMut<'_>,
    kernel: Kernel,
) {
    let m = c.rows();
    let n = c.cols();
    let k = if ta == Trans::No { a.cols() } else { a.rows() };

    // GEMV / GER shapes (the `larf_*` reflector applies): skip the packing
    // machinery — for n == 1 or k == 1 it would copy the whole large
    // operand per call and waste 3/4 of the microkernel lanes on
    // zero-padding. Both fast paths compute each element with *exactly*
    // the packed path's arithmetic under the same kernel (same KC
    // blocking, ascending-`l` per-element accumulation, fused per term
    // iff the kernel is, `alpha` applied once per block), so they are
    // bitwise identical to it and the slicing-invariance contract is
    // unaffected by which path a view takes. `ger_k1` is
    // kernel-independent: one product per element, k == 1 always routes
    // here for full calls and slices alike.
    if k == 1 {
        ger_k1(alpha, a, ta, b, tb, c);
        return;
    }
    if n == 1 {
        gemv_n1(alpha, a, ta, b, tb, c, kernel);
        return;
    }

    PACK.with(|bufs| {
        let mut bufs = bufs.borrow_mut();
        let (apack, bpack) = &mut *bufs;
        // Grow-only: keep capacity warm across the many small WY GEMMs.
        if apack.len() < MC * KC {
            apack.resize(MC * KC, 0.0);
        }
        let need_b = NC.min(round_up(n, NR)) * KC;
        if bpack.len() < need_b {
            bpack.resize(need_b, 0.0);
        }

        let mut jc = 0;
        while jc < n {
            let nb = NC.min(n - jc);
            let nb_pad = round_up(nb, NR);
            let mut l0 = 0;
            while l0 < k {
                let kb = KC.min(k - l0);
                pack_b(b, tb, l0, kb, jc, nb, &mut bpack[..nb_pad * kb]);
                let mut ic = 0;
                while ic < m {
                    let mb = MC.min(m - ic);
                    let mb_pad = round_up(mb, MR);
                    pack_a(a, ta, ic, mb, l0, kb, &mut apack[..mb_pad * kb]);
                    // Register tiles over the packed block.
                    let mut jr = 0;
                    while jr < nb {
                        let nr = NR.min(nb - jr);
                        let bpanel = &bpack[(jr / NR) * (NR * kb)..(jr / NR + 1) * (NR * kb)];
                        let mut ir = 0;
                        while ir < mb {
                            let mr = MR.min(mb - ir);
                            let apanel = &apack[(ir / MR) * (MR * kb)..(ir / MR + 1) * (MR * kb)];
                            let mut acc = [[0.0f64; MR]; NR];
                            kernels::microkernel(kernel, kb, apanel, bpanel, &mut acc);
                            // Write back the valid mr×nr corner.
                            for (j, accj) in acc.iter().enumerate().take(nr) {
                                let cj = &mut c.col_mut(jc + jr + j)[ic + ir..ic + ir + mr];
                                for (ci, &aij) in cj.iter_mut().zip(accj.iter()) {
                                    *ci += alpha * aij;
                                }
                            }
                            ir += MR;
                        }
                        jr += NR;
                    }
                    ic += mb;
                }
                l0 += kb;
            }
            jc += nb;
        }
    });
}

#[inline]
fn round_up(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// Rank-1 fast path (`k == 1`): `C[i,j] += alpha·(op(A)[i,0]·op(B)[0,j])`.
/// A single product per element — identical to the packed path's
/// `alpha·acc` with a one-term accumulator.
fn ger_k1(alpha: f64, a: MatRef<'_>, ta: Trans, b: MatRef<'_>, tb: Trans, mut c: MatMut<'_>) {
    let n = c.cols();
    for j in 0..n {
        let bj = match tb {
            Trans::No => b.at(0, j),
            Trans::Yes => b.at(j, 0),
        };
        let cj = c.col_mut(j);
        match ta {
            Trans::No => {
                let av = a.col(0);
                for (ci, &ai) in cj.iter_mut().zip(av.iter()) {
                    *ci += alpha * (ai * bj);
                }
            }
            Trans::Yes => {
                for (i, ci) in cj.iter_mut().enumerate() {
                    *ci += alpha * (a.at(0, i) * bj);
                }
            }
        }
    }
}

/// GEMV fast path (`n == 1`): `C[:,0] += alpha·op(A)·op(B)[:,0]`, with the
/// packed path's exact accumulation structure — one KC block at a time,
/// per-element ascending-`l` sums, `alpha` applied once per block. Because
/// 1-column slices of wider products also land here, each term must round
/// exactly like the packed microkernel under the same `kernel`: fused
/// variants use `f64::mul_add` (IEEE fma, bitwise equal to the SIMD
/// `fmadd`/`fmla` per element), scalar keeps the separate mul-then-add.
fn gemv_n1(
    alpha: f64,
    a: MatRef<'_>,
    ta: Trans,
    b: MatRef<'_>,
    tb: Trans,
    mut c: MatMut<'_>,
    kernel: Kernel,
) {
    let fused = kernel.fused();
    let m = c.rows();
    let k = if ta == Trans::No { a.cols() } else { a.rows() };
    // op(B) column 0 for the current KC block, materialized contiguously
    // (for tb == Yes the source is a strided row of B).
    let mut bblk = [0.0f64; KC];
    let cj = c.col_mut(0);
    // The ta == No path needs an m-length block accumulator; borrow the
    // thread-local A pack buffer as scratch (this fast path never reaches
    // the packed kernel, so the borrow cannot nest) instead of allocating
    // per call — larf_* sits in the panel-factorization inner loops.
    PACK.with(|bufs| {
        let mut bufs = bufs.borrow_mut();
        let apack = &mut bufs.0;
        if ta == Trans::No && apack.len() < m {
            apack.resize(m, 0.0);
        }
        let mut l0 = 0;
        while l0 < k {
            let kb = KC.min(k - l0);
            match tb {
                Trans::No => bblk[..kb].copy_from_slice(&b.col(0)[l0..l0 + kb]),
                Trans::Yes => {
                    for (l, x) in bblk[..kb].iter_mut().enumerate() {
                        *x = b.at(0, l0 + l);
                    }
                }
            }
            match ta {
                Trans::No => {
                    // Column-axpy over the block: per element i the adds
                    // land in ascending-l order (l is the outer loop). The
                    // fused/unfused branch is hoisted out of the hot loops.
                    let acc = &mut apack[..m];
                    acc.fill(0.0);
                    if fused {
                        for (l, &bv) in bblk[..kb].iter().enumerate() {
                            let al = a.col(l0 + l);
                            for (s, &av) in acc.iter_mut().zip(al.iter()) {
                                *s = av.mul_add(bv, *s);
                            }
                        }
                    } else {
                        for (l, &bv) in bblk[..kb].iter().enumerate() {
                            let al = a.col(l0 + l);
                            for (s, &av) in acc.iter_mut().zip(al.iter()) {
                                *s += av * bv;
                            }
                        }
                    }
                    for (ci, &s) in cj.iter_mut().zip(acc.iter()) {
                        *ci += alpha * s;
                    }
                }
                Trans::Yes => {
                    // Per-element dot over the block (columns of A
                    // contiguous).
                    for (i, ci) in cj.iter_mut().enumerate() {
                        let ai = &a.col(i)[l0..l0 + kb];
                        let mut s = 0.0;
                        if fused {
                            for (l, &av) in ai.iter().enumerate() {
                                s = av.mul_add(bblk[l], s);
                            }
                        } else {
                            for (l, &av) in ai.iter().enumerate() {
                                s += av * bblk[l];
                            }
                        }
                        *ci += alpha * s;
                    }
                }
            }
            l0 += kb;
        }
    });
}

/// Pack `op(A)(ic..ic+mb, l0..l0+kb)` into `MR`-row micro-panels:
/// `buf[p·MR·kb + l·MR + r] = op(A)(ic + p·MR + r, l0 + l)`, zero-padding
/// the short tail panel so the microkernel never branches on the edge.
fn pack_a(a: MatRef<'_>, ta: Trans, ic: usize, mb: usize, l0: usize, kb: usize, buf: &mut [f64]) {
    let mut p = 0;
    while p * MR < mb {
        let i0 = ic + p * MR;
        let mr = MR.min(mb - p * MR);
        let panel = &mut buf[p * MR * kb..(p + 1) * MR * kb];
        match ta {
            Trans::No => {
                // Columns of A are contiguous: copy mr rows per l.
                for l in 0..kb {
                    let src = &a.col(l0 + l)[i0..i0 + mr];
                    let dst = &mut panel[l * MR..l * MR + MR];
                    dst[..mr].copy_from_slice(src);
                    dst[mr..].fill(0.0);
                }
            }
            Trans::Yes => {
                // op(A)(i, l) = A(l, i): row r of the panel is a contiguous
                // stretch of column i0+r of A; scatter it across the lanes.
                if mr < MR {
                    panel.fill(0.0);
                }
                for r in 0..mr {
                    let src = &a.col(i0 + r)[l0..l0 + kb];
                    for (l, &v) in src.iter().enumerate() {
                        panel[l * MR + r] = v;
                    }
                }
            }
        }
        p += 1;
    }
}

/// Pack `op(B)(l0..l0+kb, jc..jc+nb)` into `NR`-column micro-panels:
/// `buf[q·NR·kb + l·NR + c] = op(B)(l0 + l, jc + q·NR + c)`, zero-padded.
fn pack_b(b: MatRef<'_>, tb: Trans, l0: usize, kb: usize, jc: usize, nb: usize, buf: &mut [f64]) {
    let mut q = 0;
    while q * NR < nb {
        let j0 = jc + q * NR;
        let nr = NR.min(nb - q * NR);
        let panel = &mut buf[q * NR * kb..(q + 1) * NR * kb];
        match tb {
            Trans::No => {
                // op(B)(l, j) = B(l, j): column j0+c is contiguous over l.
                if nr < NR {
                    panel.fill(0.0);
                }
                for c in 0..nr {
                    let src = &b.col(j0 + c)[l0..l0 + kb];
                    for (l, &v) in src.iter().enumerate() {
                        panel[l * NR + c] = v;
                    }
                }
            }
            Trans::Yes => {
                // op(B)(l, j) = B(j, l): lane values for one l sit in
                // column l0+l of B at rows j0..j0+nr.
                for l in 0..kb {
                    let src = &b.col(l0 + l)[j0..j0 + nr];
                    let dst = &mut panel[l * NR..l * NR + NR];
                    dst[..nr].copy_from_slice(src);
                    dst[nr..].fill(0.0);
                }
            }
        }
        q += 1;
    }
}

/// Parallel GEMM: identical (bitwise — see the module determinism contract)
/// to [`gemm`], with `C` split into column panels executed on the
/// process-global persistent worker pool ([`pool::global`]; the caller
/// participates, so `threads` is the total executor count). The panel
/// split is a pure function of `(n, threads)` — unchanged from the
/// scoped-spawn implementation — though by the slicing-invariance contract
/// the results are bitwise identical under *any* split. Falls back to the
/// sequential kernel when the problem is too small to amortize the pool
/// round trip or `threads <= 1`.
///
/// Runs under the process-default schedule (`PALLAS_ASSIST`; static unless
/// set) — see [`gemm_par_sched`] for explicit control.
#[allow(clippy::too_many_arguments)]
pub fn gemm_par(
    alpha: f64,
    a: MatRef<'_>,
    ta: Trans,
    b: MatRef<'_>,
    tb: Trans,
    beta: f64,
    c: MatMut<'_>,
    threads: usize,
) {
    gemm_par_sched(alpha, a, ta, b, tb, beta, c, threads, Schedule::from_env());
}

/// [`gemm_par`] under an explicit schedule.
///
/// * [`Schedule::Static`] — one panel per executor, assigned up front (the
///   historical split: a pure function of `(n, threads)`).
/// * [`Schedule::Dynamic`] — work assisting ([`crate::coordinator::assist`]):
///   `C` is oversplit into ~4× as many column panels (floor `2·NR` columns
///   each) and executors claim panels from a shared atomic counter, so an
///   executor stuck on a slow panel holds up only that panel.
///
/// Both schedules produce bitwise-identical results: by the module's
/// slicing-invariance contract every `C` element accumulates in the same
/// order under *any* column split, and claiming decides only who computes
/// a panel.
#[allow(clippy::too_many_arguments)]
pub fn gemm_par_sched(
    alpha: f64,
    a: MatRef<'_>,
    ta: Trans,
    b: MatRef<'_>,
    tb: Trans,
    beta: f64,
    c: MatMut<'_>,
    threads: usize,
    sched: Schedule,
) {
    let m = c.rows();
    let n = c.cols();
    let (_, k) = op_dims(a, ta);
    let work = 2usize
        .saturating_mul(m)
        .saturating_mul(n)
        .saturating_mul(k);
    if threads <= 1 || n < 2 * NR || work < PAR_MIN_FLOPS {
        gemm(alpha, a, ta, b, tb, beta, c);
        return;
    }
    // Static: one panel per worker — each re-packs its own A block
    // (duplicated pack work, but no sharing/synchronization inside the
    // kernel). Dynamic: finer panels for the claim loop to balance with,
    // kept at >= 2·NR columns so the kernel's register blocking stays
    // effective.
    let panels = match sched {
        Schedule::Static => partition(0..n, threads),
        Schedule::Dynamic => partition_capped(0..n, assist::oversplit(threads), 2 * NR),
    };
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(panels.len());
    let mut rest = c;
    let mut consumed = 0;
    for r in panels {
        let (panel, right) = rest.split_at_col(r.end - consumed);
        consumed = r.end;
        rest = right;
        let bp = match tb {
            Trans::No => b.sub(0..k, r),
            Trans::Yes => b.sub(r, 0..k),
        };
        tasks.push(Box::new(move || gemm(alpha, a, ta, bp, tb, beta, panel)));
    }
    pool::global().run_tasks_sched(tasks, threads, sched);
}

/// Convenience: allocate and return `A·B`.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut());
    c
}

/// Convenience: `op(A)·op(B)` into a fresh matrix.
pub fn matmul_t(a: &Matrix, ta: Trans, b: &Matrix, tb: Trans) -> Matrix {
    let m = if ta == Trans::No { a.rows() } else { a.cols() };
    let n = if tb == Trans::No { b.cols() } else { b.rows() };
    let mut c = Matrix::zeros(m, n);
    gemm(1.0, a.as_ref(), ta, b.as_ref(), tb, 0.0, c.as_mut());
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Naive reference multiply for validation.
    fn reference(a: &Matrix, ta: Trans, b: &Matrix, tb: Trans) -> Matrix {
        let (m, k) = if ta == Trans::No { (a.rows(), a.cols()) } else { (a.cols(), a.rows()) };
        let n = if tb == Trans::No { b.cols() } else { b.rows() };
        Matrix::from_fn(m, n, |i, j| {
            let mut s = 0.0;
            for l in 0..k {
                let av = if ta == Trans::No { a[(i, l)] } else { a[(l, i)] };
                let bv = if tb == Trans::No { b[(l, j)] } else { b[(j, l)] };
                s += av * bv;
            }
            s
        })
    }

    fn rel_err(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = x.clone();
        for j in 0..d.cols() {
            for i in 0..d.rows() {
                d[(i, j)] -= y[(i, j)];
            }
        }
        d.norm_fro() / y.norm_fro().max(1e-300)
    }

    #[test]
    fn small_exact() {
        let a = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_rows(3, 2, &[7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c[(0, 0)], 58.0);
        assert_eq!(c[(0, 1)], 64.0);
        assert_eq!(c[(1, 0)], 139.0);
        assert_eq!(c[(1, 1)], 154.0);
    }

    #[test]
    fn all_transpose_cases_match_reference() {
        let mut rng = Rng::new(99);
        for &(m, n, k) in &[(5usize, 7usize, 3usize), (17, 13, 33), (130, 70, 300), (1, 9, 4), (8, 4, 1)] {
            for &ta in &[Trans::No, Trans::Yes] {
                for &tb in &[Trans::No, Trans::Yes] {
                    let a = if ta == Trans::No { Matrix::randn(m, k, &mut rng) } else { Matrix::randn(k, m, &mut rng) };
                    let b = if tb == Trans::No { Matrix::randn(k, n, &mut rng) } else { Matrix::randn(n, k, &mut rng) };
                    let got = matmul_t(&a, ta, &b, tb);
                    let want = reference(&a, ta, &b, tb);
                    assert!(rel_err(&got, &want) < 1e-13, "case {m}x{n}x{k} {ta:?}{tb:?}");
                }
            }
        }
    }

    #[test]
    fn tile_boundary_shapes_match_reference() {
        // Sizes straddling every blocking boundary: MR/NR edges, exact
        // multiples, KC crossings.
        let mut rng = Rng::new(77);
        for &(m, n, k) in &[
            (MR, NR, 1usize),
            (MR - 1, NR - 1, 2),
            (MR + 1, NR + 1, KC),
            (MR * 2, NR * 3, KC + 1),
            (MC, NR, 3),
            (MC + 3, NC.min(64) + 5, KC + 7),
        ] {
            let a = Matrix::randn(m, k, &mut rng);
            let b = Matrix::randn(k, n, &mut rng);
            let got = matmul(&a, &b);
            let want = reference(&a, Trans::No, &b, Trans::No);
            assert!(rel_err(&got, &want) < 1e-13, "boundary {m}x{n}x{k}");
        }
    }

    #[test]
    fn alpha_beta_semantics() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(6, 4, &mut rng);
        let b = Matrix::randn(4, 5, &mut rng);
        let c0 = Matrix::randn(6, 5, &mut rng);
        // C = 2 A B + 3 C0
        let mut c = c0.clone();
        gemm(2.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 3.0, c.as_mut());
        let want = {
            let ab = matmul(&a, &b);
            Matrix::from_fn(6, 5, |i, j| 2.0 * ab[(i, j)] + 3.0 * c0[(i, j)])
        };
        assert!(rel_err(&c, &want) < 1e-13);
        // beta = 0 must overwrite even NaN-free garbage
        let mut c = Matrix::from_fn(6, 5, |_, _| 777.0);
        gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut());
        let want = matmul(&a, &b);
        assert!(rel_err(&c, &want) < 1e-13);
    }

    #[test]
    fn zero_inner_dim_scales_only() {
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 2);
        let mut c = Matrix::from_fn(3, 2, |_, _| 2.0);
        gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.5, c.as_mut());
        assert_eq!(c[(0, 0)], 1.0);
    }

    #[test]
    fn counts_flops() {
        // The FLOPS counter is process-global and `cargo test` runs tests
        // concurrently, so other tests may add to it mid-measurement:
        // assert at-least (exactness is covered by the delta arithmetic in
        // `util::flops::tests`, which uses no kernels).
        crate::util::flops::set_enabled(true);
        let mut rng = Rng::new(1);
        let a = Matrix::randn(10, 20, &mut rng);
        let b = Matrix::randn(20, 30, &mut rng);
        let (_, n) = crate::util::flops::count(|| matmul(&a, &b));
        assert!(n >= 2 * 10 * 20 * 30, "undercounted: {n}");
    }

    #[test]
    fn submatrix_views_with_ld() {
        // gemm over views whose ld != rows.
        let mut rng = Rng::new(11);
        let big_a = Matrix::randn(10, 10, &mut rng);
        let big_b = Matrix::randn(10, 10, &mut rng);
        let a = big_a.sub(2..7, 1..9); // 5x8
        let b = big_b.sub(0..8, 3..9); // 8x6
        let mut c = Matrix::zeros(5, 6);
        gemm(1.0, a, Trans::No, b, Trans::No, 0.0, c.as_mut());
        let want = reference(&a.to_owned(), Trans::No, &b.to_owned(), Trans::No);
        assert!(rel_err(&c, &want) < 1e-13);
    }

    #[test]
    fn column_slices_are_bitwise_identical_to_full_product() {
        // The determinism contract: computing C column-by-column (or in
        // arbitrary column panels) gives exactly the bits of the full call.
        let mut rng = Rng::new(21);
        let (m, n, k) = (37, 29, 300);
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let full = matmul(&a, &b);
        for split in [1usize, 5, 13, 28] {
            let mut c = Matrix::zeros(m, n);
            let mut j = 0;
            while j < n {
                let je = (j + split).min(n);
                gemm(
                    1.0,
                    a.as_ref(),
                    Trans::No,
                    b.sub(0..k, j..je),
                    Trans::No,
                    0.0,
                    c.sub_mut(0..m, j..je),
                );
                j = je;
            }
            for jj in 0..n {
                for ii in 0..m {
                    assert_eq!(c[(ii, jj)], full[(ii, jj)], "split={split} at ({ii},{jj})");
                }
            }
        }
    }

    #[test]
    fn row_slices_are_bitwise_identical_to_full_product() {
        let mut rng = Rng::new(22);
        let (m, n, k) = (41, 19, 111);
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let full = matmul(&a, &b);
        for split in [1usize, 7, 16] {
            let mut c = Matrix::zeros(m, n);
            let mut i = 0;
            while i < m {
                let ie = (i + split).min(m);
                gemm(
                    1.0,
                    a.sub(i..ie, 0..k),
                    Trans::No,
                    b.as_ref(),
                    Trans::No,
                    0.0,
                    c.sub_mut(i..ie, 0..n),
                );
                i = ie;
            }
            for jj in 0..n {
                for ii in 0..m {
                    assert_eq!(c[(ii, jj)], full[(ii, jj)], "split={split} at ({ii},{jj})");
                }
            }
        }
    }

    #[test]
    fn gemm_par_bitwise_equals_gemm() {
        let mut rng = Rng::new(23);
        // Big enough to clear PAR_MIN_FLOPS so the parallel path runs.
        let (m, n, k) = (160, 160, 64);
        let a = Matrix::randn(m, k, &mut rng);
        let b = Matrix::randn(k, n, &mut rng);
        let c0 = Matrix::randn(m, n, &mut rng);
        let mut want = c0.clone();
        gemm(1.5, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.5, want.as_mut());
        for threads in [1usize, 2, 3, 7] {
            let mut c = c0.clone();
            gemm_par(1.5, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.5, c.as_mut(), threads);
            for jj in 0..n {
                for ii in 0..m {
                    assert_eq!(c[(ii, jj)], want[(ii, jj)], "threads={threads} at ({ii},{jj})");
                }
            }
        }
    }

    #[test]
    fn gemm_par_transpose_panels() {
        // gemm_par must slice op(B) correctly in the transposed case too.
        let mut rng = Rng::new(24);
        let (m, n, k) = (96, 140, 80);
        let a = Matrix::randn(k, m, &mut rng);
        let b = Matrix::randn(n, k, &mut rng);
        let want = matmul_t(&a, Trans::Yes, &b, Trans::Yes);
        let mut c = Matrix::zeros(m, n);
        gemm_par(1.0, a.as_ref(), Trans::Yes, b.as_ref(), Trans::Yes, 0.0, c.as_mut(), 4);
        for jj in 0..n {
            for ii in 0..m {
                assert_eq!(c[(ii, jj)], want[(ii, jj)]);
            }
        }
    }

    #[test]
    fn gemm_par_counts_flops_once() {
        // At-least assertion for the same reason as `counts_flops` (the
        // counter is shared across concurrently running tests). The panel
        // sum is exactly 2mnk by construction: each panel adds 2·m·nⱼ·k.
        crate::util::flops::set_enabled(true);
        let mut rng = Rng::new(25);
        let a = Matrix::randn(128, 128, &mut rng);
        let b = Matrix::randn(128, 128, &mut rng);
        let mut c = Matrix::zeros(128, 128);
        let (_, nf) = crate::util::flops::count(|| {
            gemm_par(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 0.0, c.as_mut(), 4)
        });
        assert!(nf >= 2 * 128 * 128 * 128, "undercounted: {nf}");
    }
}
