//! Compact-WY representation of a product of Householder reflectors
//! (LAPACK `dlarft`/`dlarfb` analogues).
//!
//! For reflectors `H_1 … H_k` (forward, columnwise), `Q = H_1 H_2 ⋯ H_k =
//! I − V T Vᵀ` with `V` an `m×k` matrix whose `i`-th column is the `i`-th
//! Householder vector (unit diagonal materialized) and `T` a `k×k` upper
//! triangular factor. Applying `Q` costs two GEMMs instead of `k` rank-1
//! updates — this is the §2.1 WY mechanism the whole paper builds on, and
//! it is also the computation offloaded to the L1 Pallas kernel via PJRT.

use super::gemm::{gemm, Trans};
use super::matrix::{MatMut, MatRef, Matrix};
use crate::util::flops;

/// Side selector for applying a block reflector.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Side {
    /// `C := op(Q) C`.
    Left,
    /// `C := C op(Q)`.
    Right,
}

/// Compact-WY representation `Q = I − V T Vᵀ`.
#[derive(Clone, Debug)]
pub struct WyRep {
    /// `m×k` reflector matrix (unit diagonals materialized, zeros above).
    pub v: Matrix,
    /// `k×k` upper-triangular factor.
    pub t: Matrix,
}

impl WyRep {
    /// Build the `T` factor from explicit reflector columns and their τ's
    /// (LAPACK `dlarft`, forward columnwise):
    ///
    /// `T(0:i, i) = −τᵢ · T(0:i,0:i) · (Vᵀ vᵢ)`, `T(i,i) = τᵢ`.
    pub fn from_reflectors(v: Matrix, taus: &[f64]) -> WyRep {
        let k = taus.len();
        assert_eq!(v.cols(), k);
        let m = v.rows();
        let mut t = Matrix::zeros(k, k);
        for i in 0..k {
            let tau = taus[i];
            t[(i, i)] = tau;
            if i > 0 && tau != 0.0 {
                // w = V(:,0:i)ᵀ v_i
                let mut w = vec![0.0; i];
                for (jj, wj) in w.iter_mut().enumerate() {
                    *wj = super::blas1::dot(v.as_ref().col(jj), v.as_ref().col(i));
                }
                flops::add(2 * (m as u64) * (i as u64));
                // T(0:i, i) = -tau * T(0:i,0:i) * w   (T upper triangular)
                for row in 0..i {
                    let mut s = 0.0;
                    for (l, wl) in w.iter().enumerate().take(i).skip(row) {
                        s += t[(row, l)] * wl;
                    }
                    t[(row, i)] = -tau * s;
                }
            }
        }
        WyRep { v, t }
    }

    /// Number of reflectors `k`.
    pub fn k(&self) -> usize {
        self.t.rows()
    }

    /// Order `m` (length of the reflector vectors).
    pub fn m(&self) -> usize {
        self.v.rows()
    }

    /// Apply the block reflector: `C := op(Q)·C` (Left) or `C := C·op(Q)`
    /// (Right), where `Q = I − V T Vᵀ` and `op` is `Q` or `Qᵀ`
    /// (`trans = Trans::Yes` selects `Qᵀ = I − V Tᵀ Vᵀ`).
    pub fn apply(&self, side: Side, trans: Trans, mut c: MatMut<'_>) {
        let k = self.k();
        if k == 0 {
            return;
        }
        let v = self.v.as_ref();
        let topt = match trans {
            Trans::No => Trans::No,
            Trans::Yes => Trans::Yes,
        };
        match side {
            Side::Left => {
                assert_eq!(c.rows(), self.m(), "WY apply left: dim mismatch");
                // X = Vᵀ C (k×n); X = op(T)·X; C -= V X.
                let n = c.cols();
                let mut x = Matrix::zeros(k, n);
                gemm(1.0, v, Trans::Yes, c.rb(), Trans::No, 0.0, x.as_mut());
                trmm_upper(topt, self.t.as_ref(), x.as_mut());
                gemm(-1.0, v, Trans::No, x.as_ref(), Trans::No, 1.0, c.rb_mut());
            }
            Side::Right => {
                assert_eq!(c.cols(), self.m(), "WY apply right: dim mismatch");
                // X = C V (m×k); X = X·op(T); C -= X Vᵀ.
                let m = c.rows();
                let mut x = Matrix::zeros(m, k);
                gemm(1.0, c.rb(), Trans::No, v, Trans::No, 0.0, x.as_mut());
                trmm_upper_right(topt, self.t.as_ref(), x.as_mut());
                gemm(-1.0, x.as_ref(), Trans::No, v, Trans::Yes, 1.0, c.rb_mut());
            }
        }
    }

    /// Materialize `Q = I − V T Vᵀ` as a dense `m×m` matrix (tests/small use).
    pub fn form_q(&self) -> Matrix {
        let m = self.m();
        let mut q = Matrix::identity(m);
        self.apply(Side::Left, Trans::No, q.as_mut());
        q
    }

    /// Parallel block-reflector application: identical results to
    /// [`WyRep::apply`] **bitwise** — the free dimension of `C` (columns for
    /// `Left`, rows for `Right`) is split into panels and each panel runs
    /// the full apply pipeline (GEMM → `trmm_upper*` → GEMM) as an
    /// independent task on the process-global persistent worker pool
    /// (`coordinator::pool::global`; the caller participates, so `threads`
    /// is the total executor count and the panel split is unchanged from
    /// the scoped-spawn model). All three kernels are slicing-invariant
    /// (each output element's accumulation order does not depend on the
    /// panel it is computed in — see the determinism contract in
    /// [`crate::linalg::gemm`]), so any panel count, including 1, produces
    /// the same bits. Falls back to the sequential apply when
    /// `threads <= 1` or the update is too small to amortize the pool
    /// round trip.
    ///
    /// Runs under the process-default schedule (`PALLAS_ASSIST`; static
    /// unless set) — see [`WyRep::apply_par_sched`] for explicit control.
    pub fn apply_par(&self, side: Side, trans: Trans, c: MatMut<'_>, threads: usize) {
        self.apply_par_sched(side, trans, c, threads, crate::coordinator::assist::Schedule::from_env());
    }

    /// [`WyRep::apply_par`] under an explicit schedule: static assigns one
    /// free-dimension panel per executor up front; dynamic oversplits the
    /// free dimension (~4× the executor count, floor 4 rows/columns per
    /// panel) and lets executors claim panels from a shared atomic counter
    /// ([`crate::coordinator::assist`]). Bitwise-identical either way —
    /// the slicing-invariance argument above holds for any panel count.
    pub fn apply_par_sched(
        &self,
        side: Side,
        trans: Trans,
        c: MatMut<'_>,
        threads: usize,
        sched: crate::coordinator::assist::Schedule,
    ) {
        use crate::coordinator::assist::{self, Schedule};
        let k = self.k();
        if k == 0 {
            return;
        }
        // ~4mnk flops in the two GEMMs; below the shared gemm_par threshold
        // the pool submit/drain round trip costs more than it saves.
        let work = 4usize
            .saturating_mul(c.rows())
            .saturating_mul(c.cols())
            .saturating_mul(k);
        let free = match side {
            Side::Left => c.cols(),
            Side::Right => c.rows(),
        };
        if threads <= 1 || free < 2 || work < super::gemm::PAR_MIN_FLOPS {
            self.apply(side, trans, c);
            return;
        }
        let panels = match sched {
            Schedule::Static => crate::coordinator::slices::partition(0..free, threads),
            Schedule::Dynamic => {
                crate::coordinator::slices::partition_capped(0..free, assist::oversplit(threads), 4)
            }
        };
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(panels.len());
        let mut rest = c;
        let mut consumed = 0;
        for r in panels {
            let (panel, right) = match side {
                Side::Left => rest.split_at_col(r.end - consumed),
                Side::Right => rest.split_at_row(r.end - consumed),
            };
            consumed = r.end;
            rest = right;
            tasks.push(Box::new(move || self.apply(side, trans, panel)));
        }
        crate::coordinator::pool::global().run_tasks_sched(tasks, threads, sched);
    }
}

/// `X := op(T)·X` for `T` `k×k` upper triangular (small `k`; in-place).
pub fn trmm_upper(trans: Trans, t: MatRef<'_>, mut x: MatMut<'_>) {
    let k = t.rows();
    debug_assert_eq!(t.cols(), k);
    debug_assert_eq!(x.rows(), k);
    let n = x.cols();
    flops::add((k as u64) * (k as u64) * (n as u64));
    for j in 0..n {
        let xj = x.col_mut(j);
        match trans {
            Trans::No => {
                // x_i = sum_{l >= i} T[i,l] x_l : forward order safe.
                for i in 0..k {
                    let mut s = t.at(i, i) * xj[i];
                    for l in i + 1..k {
                        s += t.at(i, l) * xj[l];
                    }
                    xj[i] = s;
                }
            }
            Trans::Yes => {
                // x_i = sum_{l <= i} T[l,i] x_l : backward order safe.
                for i in (0..k).rev() {
                    let mut s = t.at(i, i) * xj[i];
                    for l in 0..i {
                        s += t.at(l, i) * xj[l];
                    }
                    xj[i] = s;
                }
            }
        }
    }
}

/// `X := X·op(T)` for `T` `k×k` upper triangular (small `k`; in-place).
pub fn trmm_upper_right(trans: Trans, t: MatRef<'_>, mut x: MatMut<'_>) {
    let k = t.rows();
    debug_assert_eq!(t.cols(), k);
    debug_assert_eq!(x.cols(), k);
    let m = x.rows();
    flops::add((k as u64) * (k as u64) * (m as u64));
    match trans {
        Trans::No => {
            // (X T)_col j = Σ_{l ≤ j} X_l T[l,j] : process j backward so
            // untouched columns still hold the original X.
            for j in (0..k).rev() {
                let tjj = t.at(j, j);
                // x_j ← x_j·t_jj + Σ_{l<j} x_l·t_lj, reading x_l in place.
                // SAFETY: every column of the exclusively-borrowed X is m
                // in-bounds contiguous elements; xj (mutable, column j)
                // and each xl (shared, column l < j) are distinct columns
                // of one `ld ≥ m` layout, so the borrows never alias.
                unsafe {
                    let base = x.ptr();
                    let ld = x.ld();
                    let xj = std::slice::from_raw_parts_mut(base.add(j * ld), m);
                    super::blas1::scal(tjj, xj);
                    for l in 0..j {
                        let tlj = t.at(l, j);
                        if tlj != 0.0 {
                            let xl = std::slice::from_raw_parts(base.add(l * ld) as *const f64, m);
                            super::blas1::axpy(tlj, xl, xj);
                        }
                    }
                }
            }
        }
        Trans::Yes => {
            // (X Tᵀ)_col j = Σ_{l ≥ j} X_l T[j,l] : process j forward.
            for j in 0..k {
                let tjj = t.at(j, j);
                // SAFETY: as in the `Trans::No` arm — xj is column j,
                // each xl is a distinct column l > j; disjoint columns of
                // an exclusive view cannot alias.
                unsafe {
                    let base = x.ptr();
                    let ld = x.ld();
                    let xj = std::slice::from_raw_parts_mut(base.add(j * ld), m);
                    super::blas1::scal(tjj, xj);
                    for l in j + 1..k {
                        let tjl = t.at(j, l);
                        if tjl != 0.0 {
                            let xl = std::slice::from_raw_parts(base.add(l * ld) as *const f64, m);
                            super::blas1::axpy(tjl, xl, xj);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::householder::Reflector;
    use crate::util::rng::Rng;

    /// Build k random reflectors with the unit-lower-trapezoidal structure
    /// of a QR factorization and return (V, taus, explicit Q product).
    fn random_reflectors(m: usize, k: usize, rng: &mut Rng) -> (Matrix, Vec<f64>, Matrix) {
        let mut v = Matrix::zeros(m, k);
        let mut taus = vec![0.0; k];
        let mut q = Matrix::identity(m);
        for i in 0..k {
            // Column i: zeros above i, 1 at i, random below.
            let x: Vec<f64> = (0..m - i).map(|_| rng.normal()).collect();
            let (refl, _) = Reflector::reducing(&x);
            for (l, &vl) in refl.v.iter().enumerate() {
                v[(i + l, i)] = vl;
            }
            taus[i] = refl.tau;
            // Accumulate Q := Q * H_i  (so Q = H_1 H_2 ... H_k).
            let mut vfull = vec![0.0; m];
            vfull[i..].copy_from_slice(&refl.v);
            crate::linalg::householder::larf_right(&vfull, refl.tau, q.as_mut());
        }
        (v, taus, q)
    }

    fn rel(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = 0.0;
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                d += (x[(i, j)] - y[(i, j)]).powi(2);
            }
        }
        d.sqrt() / y.norm_fro().max(1e-300)
    }

    #[test]
    fn wy_matches_reflector_product() {
        let mut rng = Rng::new(5);
        for &(m, k) in &[(6usize, 3usize), (20, 8), (33, 16), (5, 5)] {
            let (v, taus, q_explicit) = random_reflectors(m, k, &mut rng);
            let wy = WyRep::from_reflectors(v, &taus);
            let q_wy = wy.form_q();
            assert!(rel(&q_wy, &q_explicit) < 1e-13, "m={m} k={k}");
        }
    }

    #[test]
    fn apply_sides_and_trans_consistent() {
        let mut rng = Rng::new(6);
        let (m, k) = (12usize, 5usize);
        let (v, taus, q) = random_reflectors(m, k, &mut rng);
        let wy = WyRep::from_reflectors(v, &taus);
        let c = Matrix::randn(m, 7, &mut rng);

        // Left, no trans: Q C
        let mut got = c.clone();
        wy.apply(Side::Left, Trans::No, got.as_mut());
        let want = crate::linalg::gemm::matmul(&q, &c);
        assert!(rel(&got, &want) < 1e-13);

        // Left, trans: Qᵀ C
        let mut got = c.clone();
        wy.apply(Side::Left, Trans::Yes, got.as_mut());
        let want = crate::linalg::gemm::matmul_t(&q, Trans::Yes, &c, Trans::No);
        assert!(rel(&got, &want) < 1e-13);

        let d = Matrix::randn(7, m, &mut rng);
        // Right, no trans: D Q
        let mut got = d.clone();
        wy.apply(Side::Right, Trans::No, got.as_mut());
        let want = crate::linalg::gemm::matmul(&d, &q);
        assert!(rel(&got, &want) < 1e-13);

        // Right, trans: D Qᵀ
        let mut got = d.clone();
        wy.apply(Side::Right, Trans::Yes, got.as_mut());
        let want = crate::linalg::gemm::matmul_t(&d, Trans::No, &q, Trans::Yes);
        assert!(rel(&got, &want) < 1e-13);
    }

    #[test]
    fn trmm_matches_dense() {
        let mut rng = Rng::new(7);
        let k = 6;
        let mut t = Matrix::randn(k, k, &mut rng);
        for j in 0..k {
            for i in j + 1..k {
                t[(i, j)] = 0.0;
            }
        }
        let x0 = Matrix::randn(k, 4, &mut rng);
        for &tr in &[Trans::No, Trans::Yes] {
            let mut x = x0.clone();
            trmm_upper(tr, t.as_ref(), x.as_mut());
            let want = crate::linalg::gemm::matmul_t(&t, tr, &x0, Trans::No);
            assert!(rel(&x, &want) < 1e-13);
        }
        let y0 = Matrix::randn(4, k, &mut rng);
        for &tr in &[Trans::No, Trans::Yes] {
            let mut y = y0.clone();
            trmm_upper_right(tr, t.as_ref(), y.as_mut());
            let want = crate::linalg::gemm::matmul_t(&y0, Trans::No, &t, tr);
            assert!(rel(&y, &want) < 1e-13, "right trmm {tr:?}");
        }
    }

    #[test]
    fn apply_par_bitwise_equals_apply() {
        let mut rng = Rng::new(9);
        // Big enough that the parallel path actually engages
        // (4·m·n·k ≥ 2·10⁶ for the left case below).
        let (m, k) = (130usize, 16usize);
        let (v, taus, _) = random_reflectors(m, k, &mut rng);
        let wy = WyRep::from_reflectors(v, &taus);
        let c = Matrix::randn(m, 260, &mut rng);
        let d = Matrix::randn(260, m, &mut rng);
        for &tr in &[Trans::No, Trans::Yes] {
            let mut want = c.clone();
            wy.apply(Side::Left, tr, want.as_mut());
            for threads in [2usize, 3, 7] {
                let mut got = c.clone();
                wy.apply_par(Side::Left, tr, got.as_mut(), threads);
                assert_eq!(
                    crate::util::proptest::max_abs_diff(&got, &want),
                    0.0,
                    "left {tr:?} threads={threads}"
                );
            }
            let mut want = d.clone();
            wy.apply(Side::Right, tr, want.as_mut());
            for threads in [2usize, 5] {
                let mut got = d.clone();
                wy.apply_par(Side::Right, tr, got.as_mut(), threads);
                assert_eq!(
                    crate::util::proptest::max_abs_diff(&got, &want),
                    0.0,
                    "right {tr:?} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn wy_q_is_orthogonal() {
        let mut rng = Rng::new(8);
        let (v, taus, _) = random_reflectors(15, 6, &mut rng);
        let wy = WyRep::from_reflectors(v, &taus);
        let q = wy.form_q();
        let qtq = crate::linalg::gemm::matmul_t(&q, Trans::Yes, &q, Trans::No);
        let eye = Matrix::identity(15);
        assert!(rel(&qtq, &eye) < 1e-13);
    }
}
