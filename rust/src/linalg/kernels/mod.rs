//! GEMM microkernel variants and runtime dispatch.
//!
//! The packed GEMM driver (`linalg::gemm`) funnels every flop of both
//! reduction stages through one `MR×NR` register microkernel. This module
//! owns that kernel in three interchangeable variants — the portable
//! scalar reference ([`scalar`]), an AVX2+FMA variant on x86-64 ([`avx2`])
//! and a NEON variant on aarch64 ([`neon`]) — plus the machinery that
//! picks one at run time and threads the choice through every execution
//! path without touching the ~40 stage/WY call sites:
//!
//! * [`KernelChoice`] is the *request* level: what `PALLAS_KERNEL` and
//!   [`crate::config::Config::kernel`] express (`auto`/`scalar`/`avx2`/
//!   `neon`, parseable on every architecture).
//! * [`Kernel`] is the *resolved* level: a variant that is guaranteed
//!   runnable on this CPU. The only constructor is [`Kernel::detect`],
//!   which consults `std::arch` runtime feature detection and clamps
//!   unavailable requests to [`Kernel::Scalar`] — so holding a `Kernel`
//!   value *is* the proof that its intrinsics may be executed (the
//!   soundness argument for the `unsafe` dispatch below; see
//!   ARCHITECTURE.md "Kernel dispatch").
//! * [`process_default`] resolves `PALLAS_KERNEL` once per process
//!   (`auto` → best available); [`current`] reads a thread-local override
//!   installed by [`enter`]/[`with_kernel`], falling back to the process
//!   default. Driver entry points (`api::reduce_seq`, the session's graph
//!   path) install the config's resolved kernel around each reduction, and
//!   `coordinator::pool` captures the submitter's `current()` into every
//!   batch so pool workers run under the same kernel — batch mode, nested
//!   submits and the serving tier inherit the choice with no extra
//!   plumbing.
//!
//! **Determinism contract (narrowed, not broken).** For a *fixed* kernel,
//! results are bitwise invariant across threads, slicing and scheduling:
//! every variant accumulates each `C[i,j]` in ascending-`l` order into its
//! own per-element accumulator (scalar f64 or one SIMD lane — lanes never
//! mix), so the argument in `linalg::gemm`'s module docs holds per
//! variant. *Across* kernels results differ by O(eps): the SIMD variants
//! use fused multiply-add (one rounding per term instead of two), which is
//! a different — slightly more accurate — rounding sequence than the
//! scalar `mul` + `add`. The scalar kernel is the cross-kernel reference;
//! `tests/kernels.rs` pins both halves of the contract.
//!
//! All variants share the same `MR×NR = 8×4` tile and the same packed
//! micro-panel layout, so the pack buffers, the `2·NR` panel floors and
//! the work-assisting oversplit geometry are kernel-independent — choosing
//! a kernel never changes *what* is packed or how work is split, only the
//! arithmetic that consumes the panels.

use std::cell::Cell;
use std::sync::OnceLock;

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

#[cfg(target_arch = "aarch64")]
pub mod neon;

/// Microkernel tile height (rows of `C` per register tile). Shared by
/// every kernel variant — see the module docs for why the geometry is
/// kernel-independent.
pub const MR: usize = 8;
/// Microkernel tile width (columns of `C` per register tile).
pub const NR: usize = 4;

/// A *requested* kernel — the parse-level selector expressed by the
/// `PALLAS_KERNEL` env knob and [`crate::config::Config::kernel`].
///
/// Every variant exists on every architecture (a config file naming
/// `avx2` must parse on an aarch64 host); [`Kernel::detect`] clamps
/// requests the running CPU cannot honor to the scalar reference.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum KernelChoice {
    /// Pick the best kernel the CPU supports (the default).
    #[default]
    Auto,
    /// The portable scalar reference kernel.
    Scalar,
    /// The AVX2+FMA kernel (x86-64; clamped to scalar elsewhere or when
    /// the CPU lacks the features).
    Avx2,
    /// The NEON kernel (aarch64; clamped to scalar elsewhere).
    Neon,
}

impl KernelChoice {
    /// Parse a `PALLAS_KERNEL` value: `auto` / `scalar` / `avx2` / `neon`,
    /// case-insensitive, surrounding whitespace tolerated. `None` for
    /// anything else (callers fall back to [`KernelChoice::Auto`]).
    pub fn parse(s: &str) -> Option<KernelChoice> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Some(KernelChoice::Auto),
            "scalar" => Some(KernelChoice::Scalar),
            "avx2" => Some(KernelChoice::Avx2),
            "neon" => Some(KernelChoice::Neon),
            _ => None,
        }
    }

    /// The knob spelling of this choice (round-trips through
    /// [`KernelChoice::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Scalar => "scalar",
            KernelChoice::Avx2 => "avx2",
            KernelChoice::Neon => "neon",
        }
    }
}

/// A *resolved* kernel: a variant whose instructions are guaranteed
/// executable on this CPU.
///
/// Only [`Kernel::detect`] constructs non-scalar variants, and only after
/// the corresponding `std::arch` runtime feature check has passed in this
/// process — that invariant is what makes the `unsafe` calls in
/// [`microkernel`] sound. Variants that cannot exist on the compilation
/// target are compiled out entirely.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Kernel {
    /// Portable scalar reference ([`scalar::microkernel_8x4`]) — the
    /// cross-kernel O(eps) anchor, always available.
    Scalar,
    /// AVX2+FMA ([`avx2::microkernel_8x4`]): constructed only after
    /// `is_x86_feature_detected!("avx2")` and `("fma")` both passed.
    #[cfg(target_arch = "x86_64")]
    Avx2,
    /// NEON ([`neon::microkernel_8x4`]): constructed only after
    /// `is_aarch64_feature_detected!("neon")` passed.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Kernel {
    /// Resolve a request against this CPU: `Auto` picks the best available
    /// variant; an explicit request for a variant this host cannot run
    /// (wrong architecture, or the CPU lacks the features) clamps to
    /// [`Kernel::Scalar`] rather than erroring — a config naming `avx2`
    /// must stay runnable on every machine.
    pub fn detect(choice: KernelChoice) -> Kernel {
        match choice {
            KernelChoice::Auto => best_available(),
            KernelChoice::Scalar => Kernel::Scalar,
            KernelChoice::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    if avx2_runtime_available() {
                        return Kernel::Avx2;
                    }
                }
                Kernel::Scalar
            }
            KernelChoice::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    if neon_runtime_available() {
                        return Kernel::Neon;
                    }
                }
                Kernel::Scalar
            }
        }
    }

    /// Stable numeric id (0 = scalar, 1 = avx2, 2 = neon) — the value
    /// mixed into the serving tier's pencil fingerprints and compared in
    /// its cache keys, so results computed under different kernels (which
    /// differ by O(eps) bits) can never collide in the cache.
    pub fn id(self) -> u64 {
        match self {
            Kernel::Scalar => 0,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => 1,
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => 2,
        }
    }

    /// Display/bench label for this variant.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => "neon",
        }
    }

    /// The request that resolves back to exactly this kernel on this host
    /// (`Kernel::detect(k.choice()) == k`).
    pub fn choice(self) -> KernelChoice {
        match self {
            Kernel::Scalar => KernelChoice::Scalar,
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2 => KernelChoice::Avx2,
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => KernelChoice::Neon,
        }
    }

    /// Whether this variant accumulates with fused multiply-add (one
    /// rounding per term). The GEMV fast path in `linalg::gemm` branches
    /// on this so 1-column slices stay bitwise identical to the packed
    /// path *per kernel* — `f64::mul_add` is the same IEEE operation the
    /// SIMD fma instructions compute, bit for bit.
    pub fn fused(self) -> bool {
        !matches!(self, Kernel::Scalar)
    }

    /// Every kernel this CPU can run (scalar first). The bench sweeps and
    /// the cross-kernel parity tests iterate this.
    pub fn all_available() -> Vec<Kernel> {
        let mut v = vec![Kernel::Scalar];
        #[cfg(target_arch = "x86_64")]
        {
            if avx2_runtime_available() {
                v.push(Kernel::Avx2);
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if neon_runtime_available() {
                v.push(Kernel::Neon);
            }
        }
        v
    }
}

/// Runtime check for the AVX2 kernel's full feature set. Both features are
/// required: the kernel's loads are AVX, its accumulation is FMA.
#[cfg(target_arch = "x86_64")]
fn avx2_runtime_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

/// Runtime check for NEON (mandatory on AArch64, but asked anyway — the
/// detect-then-construct invariant stays uniform across variants).
#[cfg(target_arch = "aarch64")]
fn neon_runtime_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// Best kernel this CPU supports (the meaning of `auto`).
fn best_available() -> Kernel {
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_runtime_available() {
            return Kernel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if neon_runtime_available() {
            return Kernel::Neon;
        }
    }
    Kernel::Scalar
}

/// The process-default kernel: `PALLAS_KERNEL` resolved through
/// [`Kernel::detect`] exactly once (first use) and cached for the process
/// lifetime — dispatch-once, so the hot loops never re-run feature
/// detection or env parsing.
pub fn process_default() -> Kernel {
    static PROCESS_DEFAULT: OnceLock<Kernel> = OnceLock::new();
    *PROCESS_DEFAULT.get_or_init(|| Kernel::detect(crate::util::env::kernel()))
}

thread_local! {
    /// Thread-local kernel override, installed by [`enter`] /
    /// [`with_kernel`]. `None` means "use the process default".
    static CURRENT: Cell<Option<Kernel>> = const { Cell::new(None) };
}

/// The kernel in effect on this thread: the innermost [`enter`] override,
/// else [`process_default`]. `linalg::gemm` resolves this once per `gemm`
/// call; `coordinator::pool` captures it at batch submission so workers
/// execute under the submitter's kernel.
pub fn current() -> Kernel {
    CURRENT.with(|c| c.get()).unwrap_or_else(process_default)
}

/// Scoped kernel override: restores the previous thread-local state on
/// drop (including on unwind), so nested reductions with different
/// configured kernels compose correctly.
#[must_use = "the override lasts only while the guard is alive"]
pub struct KernelGuard {
    prev: Option<Kernel>,
}

/// Install `kernel` as this thread's current kernel until the returned
/// guard drops. Driver entry points call this with the config's resolved
/// kernel; [`crate::coordinator::pool`] calls it around every batch task
/// with the kernel captured at submission.
pub fn enter(kernel: Kernel) -> KernelGuard {
    let prev = CURRENT.with(|c| c.replace(Some(kernel)));
    KernelGuard { prev }
}

impl Drop for KernelGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
    }
}

/// Run `f` with `kernel` as the thread's current kernel (guard form of
/// [`enter`] for closures — the bench sweeps and parity tests use this).
pub fn with_kernel<R>(kernel: Kernel, f: impl FnOnce() -> R) -> R {
    let _guard = enter(kernel);
    f()
}

/// Dispatch one `MR×NR` register-tile accumulation to the resolved
/// kernel: `acc[j][i] += Σ_l Ap[l,i]·Bp[l,j]` over the packed micro-panels
/// (fused per term on the SIMD variants), ascending `l`, one accumulator
/// per element — the per-kernel determinism contract.
#[inline]
pub(crate) fn microkernel(
    kernel: Kernel,
    kb: usize,
    apanel: &[f64],
    bpanel: &[f64],
    acc: &mut [[f64; MR]; NR],
) {
    match kernel {
        Kernel::Scalar => scalar::microkernel_8x4(kb, apanel, bpanel, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Kernel::Avx2` values exist only via `Kernel::detect`,
        // which requires `is_x86_feature_detected!("avx2")` and `("fma")`
        // to have passed in this process — exactly the target features the
        // callee enables, so executing it cannot hit an illegal
        // instruction.
        Kernel::Avx2 => unsafe { avx2::microkernel_8x4(kb, apanel, bpanel, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: `Kernel::Neon` values exist only via `Kernel::detect`
        // after `is_aarch64_feature_detected!("neon")` passed — the one
        // target feature the callee enables.
        Kernel::Neon => unsafe { neon::microkernel_8x4(kb, apanel, bpanel, acc) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_knob_spellings_case_insensitively() {
        assert_eq!(KernelChoice::parse("auto"), Some(KernelChoice::Auto));
        assert_eq!(KernelChoice::parse("scalar"), Some(KernelChoice::Scalar));
        assert_eq!(KernelChoice::parse("AVX2"), Some(KernelChoice::Avx2));
        assert_eq!(KernelChoice::parse(" neon "), Some(KernelChoice::Neon));
        assert_eq!(KernelChoice::parse("avx512"), None);
        assert_eq!(KernelChoice::parse(""), None);
    }

    #[test]
    fn names_round_trip_through_parse() {
        for c in [KernelChoice::Auto, KernelChoice::Scalar, KernelChoice::Avx2, KernelChoice::Neon]
        {
            assert_eq!(KernelChoice::parse(c.name()), Some(c));
        }
    }

    #[test]
    fn detect_clamps_unavailable_requests_to_scalar() {
        // Whatever the host: an explicit scalar request resolves scalar,
        // and requests for the *other* architecture's kernel clamp.
        assert_eq!(Kernel::detect(KernelChoice::Scalar), Kernel::Scalar);
        #[cfg(target_arch = "x86_64")]
        assert_eq!(Kernel::detect(KernelChoice::Neon), Kernel::Scalar);
        #[cfg(target_arch = "aarch64")]
        assert_eq!(Kernel::detect(KernelChoice::Avx2), Kernel::Scalar);
        // Auto resolves to something this CPU can run — by construction a
        // member of `all_available`.
        assert!(Kernel::all_available().contains(&Kernel::detect(KernelChoice::Auto)));
    }

    #[test]
    fn resolved_kernels_resolve_back_to_themselves() {
        for k in Kernel::all_available() {
            assert_eq!(Kernel::detect(k.choice()), k, "{}", k.name());
        }
    }

    #[test]
    fn ids_are_distinct_and_scalar_is_zero() {
        let kernels = Kernel::all_available();
        assert_eq!(kernels[0], Kernel::Scalar);
        assert_eq!(kernels[0].id(), 0);
        assert!(!kernels[0].fused(), "scalar is the non-fused reference");
        let mut ids: Vec<u64> = kernels.iter().map(|k| k.id()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), kernels.len(), "kernel ids must be distinct");
        for k in &kernels[1..] {
            assert!(k.fused(), "every SIMD variant accumulates with fma");
        }
    }

    #[test]
    fn thread_local_override_nests_and_restores() {
        let default = current();
        let kernels = Kernel::all_available();
        let inner = *kernels.last().unwrap();
        with_kernel(Kernel::Scalar, || {
            assert_eq!(current(), Kernel::Scalar);
            with_kernel(inner, || assert_eq!(current(), inner));
            assert_eq!(current(), Kernel::Scalar, "inner guard must restore");
        });
        assert_eq!(current(), default, "outer guard must restore the default");
    }

    #[test]
    fn override_is_per_thread() {
        with_kernel(Kernel::Scalar, || {
            // A fresh thread sees the process default, not this override.
            let seen = std::thread::spawn(current).join().unwrap();
            assert_eq!(seen, process_default());
        });
    }
}
