//! The portable scalar reference microkernel — the exact kernel the crate
//! shipped with before runtime dispatch existed, moved here bitwise
//! unchanged. It is the cross-kernel O(eps) reference (`PALLAS_KERNEL=
//! scalar` reproduces the historical numbers exactly) and the clamp target
//! for unavailable SIMD requests.

use super::{MR, NR};

/// The scalar register microkernel: `acc[j][i] += Ap[l,i]·Bp[l,j]` over
/// the packed micro-panels. Per-element scalar accumulators in
/// ascending-`l` order — the determinism contract — with the `MR` lane
/// dimension left to LLVM to vectorize (fixed-size array views elide the
/// bounds checks). Each term is a separate `mul` then `add` (two
/// roundings), which is what makes this the non-fused reference the SIMD
/// variants are compared against.
#[inline]
pub fn microkernel_8x4(kb: usize, apanel: &[f64], bpanel: &[f64], acc: &mut [[f64; MR]; NR]) {
    debug_assert!(apanel.len() >= kb * MR && bpanel.len() >= kb * NR);
    for l in 0..kb {
        let av: &[f64; MR] = apanel[l * MR..l * MR + MR].try_into().unwrap();
        let bv: &[f64; NR] = bpanel[l * NR..l * NR + NR].try_into().unwrap();
        for (accj, &bj) in acc.iter_mut().zip(bv.iter()) {
            for (aij, &ai) in accj.iter_mut().zip(av.iter()) {
                *aij += ai * bj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_the_reference_sum() {
        // 2 k-steps over a fully populated 8x4 tile, checked against a
        // hand-rolled ascending-l scalar accumulation.
        let kb = 2;
        let apanel: Vec<f64> = (0..kb * MR).map(|i| (i as f64) * 0.5 - 3.0).collect();
        let bpanel: Vec<f64> = (0..kb * NR).map(|i| 1.0 - (i as f64) * 0.25).collect();
        let mut acc = [[0.0f64; MR]; NR];
        microkernel_8x4(kb, &apanel, &bpanel, &mut acc);
        for (j, accj) in acc.iter().enumerate() {
            for (i, &got) in accj.iter().enumerate() {
                let mut want = 0.0f64;
                for l in 0..kb {
                    want += apanel[l * MR + i] * bpanel[l * NR + j];
                }
                assert_eq!(got, want, "({i},{j})");
            }
        }
    }
}
