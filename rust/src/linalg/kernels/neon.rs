//! The NEON microkernel (aarch64).
//!
//! Sixteen 2-lane `float64x2_t` accumulators hold the full `8×4` tile —
//! four registers (row pairs 0-1, 2-3, 4-5, 6-7) per `C` column. Each
//! k-step broadcasts one element of the packed B panel per column and
//! issues four `fmla` per column: 16 fused multiply-adds per step, the
//! same ascending-`l`, one-accumulator-per-element order as the scalar
//! reference. Lanes never mix, so the bitwise slicing-invariance argument
//! of `linalg::gemm` holds per variant; the fused rounding makes this
//! kernel bitwise identical to the AVX2 variant per element (both compute
//! IEEE fma in the same order) and O(eps) from scalar.
//!
//! Compiled whenever the target is aarch64 but *executed* only behind
//! [`super::Kernel::detect`]'s runtime feature check — see the `# Safety`
//! contract on [`microkernel_8x4`] and the dispatch-site SAFETY comment in
//! [`super::microkernel`].

use super::{MR, NR};
use std::arch::aarch64::{float64x2_t, vdupq_n_f64, vfmaq_f64, vld1q_f64, vst1q_f64};

/// NEON register microkernel: `acc[j][i] += Σ_l Ap[l,i]·Bp[l,j]` (fused
/// per term) over the packed micro-panels.
///
/// # Safety
///
/// The caller must ensure the executing CPU supports the `neon` target
/// feature (`is_aarch64_feature_detected!("neon")` — mandatory on
/// AArch64, but the detect-then-construct invariant is kept uniform
/// across variants). The function body is compiled with that feature
/// enabled. In-bounds access is *not* part of the contract: panel lengths
/// are asserted at entry, and the tile geometry (`MR`/`NR`) is fixed by
/// the shared pack layout.
#[target_feature(enable = "neon")]
pub unsafe fn microkernel_8x4(kb: usize, apanel: &[f64], bpanel: &[f64], acc: &mut [[f64; MR]; NR]) {
    assert!(
        apanel.len() >= kb * MR && bpanel.len() >= kb * NR,
        "neon microkernel: panel shorter than kb tiles"
    );
    let ap = apanel.as_ptr();
    let bp = bpanel.as_ptr();

    // Four 2-lane accumulators per C column (row pairs of MR == 8).
    let mut accv: [[float64x2_t; 4]; NR] = [[vdupq_n_f64(0.0); 4]; NR];
    for (j, col) in accv.iter_mut().enumerate() {
        for (h, reg) in col.iter_mut().enumerate() {
            // SAFETY: `acc[j]` is an `[f64; 8]`; the 2-lane load at offset
            // 2·h (h < 4) ends at most at element 8.
            *reg = unsafe { vld1q_f64(acc[j].as_ptr().add(2 * h)) };
        }
    }

    for l in 0..kb {
        // SAFETY: l < kb and apanel.len() >= kb·MR (asserted above), so
        // the four 2-lane loads at l·MR + 2·h (h < 4) stay in bounds.
        let a: [float64x2_t; 4] = unsafe {
            let p = ap.add(l * MR);
            [vld1q_f64(p), vld1q_f64(p.add(2)), vld1q_f64(p.add(4)), vld1q_f64(p.add(6))]
        };
        for (j, col) in accv.iter_mut().enumerate() {
            // SAFETY: l·NR + j < kb·NR <= bpanel.len() (asserted above).
            let b = unsafe { vdupq_n_f64(*bp.add(l * NR + j)) };
            for (reg, &ah) in col.iter_mut().zip(a.iter()) {
                // fmla: reg + ah·b, fused — one rounding per term.
                *reg = vfmaq_f64(*reg, ah, b);
            }
        }
    }

    for (j, col) in accv.iter().enumerate() {
        for (h, &reg) in col.iter().enumerate() {
            // SAFETY: same bounds as the loads — `acc[j]` is `[f64; 8]`.
            unsafe { vst1q_f64(acc[j].as_mut_ptr().add(2 * h), reg) };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_fused_reference_bitwise() {
        if !super::super::neon_runtime_available() {
            eprintln!("skipping: CPU lacks neon");
            return;
        }
        let kb = 3;
        let apanel: Vec<f64> = (0..kb * MR).map(|i| ((i * 37 % 19) as f64) * 0.375 - 3.0).collect();
        let bpanel: Vec<f64> = (0..kb * NR).map(|i| 1.0 - ((i * 11 % 7) as f64) * 0.25).collect();
        let mut acc = [[0.0f64; MR]; NR];
        // SAFETY: guarded by the runtime feature check above.
        unsafe { microkernel_8x4(kb, &apanel, &bpanel, &mut acc) };
        for (j, accj) in acc.iter().enumerate() {
            for (i, &got) in accj.iter().enumerate() {
                let mut want = 0.0f64;
                for l in 0..kb {
                    want = apanel[l * MR + i].mul_add(bpanel[l * NR + j], want);
                }
                assert_eq!(got.to_bits(), want.to_bits(), "({i},{j})");
            }
        }
    }
}
