//! The AVX2+FMA microkernel (x86-64).
//!
//! Eight 4-lane `__m256d` accumulators hold the full `8×4` tile — two
//! registers (low/high half of the `MR = 8` row dimension) per `C` column.
//! Each k-step broadcasts one element of the packed B panel per column and
//! issues two `vfmadd` per column: 8 fused multiply-adds per step, the
//! same ascending-`l`, one-accumulator-per-element order as the scalar
//! reference. Lanes never mix (no horizontal reductions), so the bitwise
//! slicing-invariance argument of `linalg::gemm` holds for this variant
//! exactly as for scalar — only the per-term rounding differs (fused:
//! one rounding instead of two), which is the cross-kernel O(eps) delta.
//!
//! Compiled whenever the target is x86-64 but *executed* only behind
//! [`super::Kernel::detect`]'s runtime feature check — see the `# Safety`
//! contract on [`microkernel_8x4`] and the dispatch-site SAFETY comment in
//! [`super::microkernel`].

use super::{MR, NR};
use std::arch::x86_64::{
    __m256d, _mm256_fmadd_pd, _mm256_loadu_pd, _mm256_set1_pd, _mm256_setzero_pd,
    _mm256_storeu_pd,
};

/// AVX2+FMA register microkernel: `acc[j][i] += Σ_l Ap[l,i]·Bp[l,j]`
/// (fused per term) over the packed micro-panels.
///
/// # Safety
///
/// The caller must ensure the executing CPU supports the `avx2` and `fma`
/// target features (e.g. `is_x86_feature_detected!("avx2")` and
/// `("fma")` both true) — the function body is compiled with those
/// features enabled, so calling it on an older CPU is undefined behavior
/// (illegal instruction at best). In-bounds access is *not* part of the
/// contract: panel lengths are asserted at entry, and the tile geometry
/// (`MR`/`NR`) is fixed by the shared pack layout.
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn microkernel_8x4(kb: usize, apanel: &[f64], bpanel: &[f64], acc: &mut [[f64; MR]; NR]) {
    assert!(
        apanel.len() >= kb * MR && bpanel.len() >= kb * NR,
        "avx2 microkernel: panel shorter than kb tiles"
    );
    let ap = apanel.as_ptr();
    let bp = bpanel.as_ptr();

    // Two accumulator registers per C column: rows 0..4 and 4..8.
    let mut lo: [__m256d; NR] = [_mm256_setzero_pd(); NR];
    let mut hi: [__m256d; NR] = [_mm256_setzero_pd(); NR];
    for (j, (rlo, rhi)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
        // SAFETY: each `acc[j]` is an `[f64; 8]`, so the unaligned 4-lane
        // loads at offsets 0 and 4 end exactly at MR == 8.
        unsafe {
            *rlo = _mm256_loadu_pd(acc[j].as_ptr());
            *rhi = _mm256_loadu_pd(acc[j].as_ptr().add(4));
        }
    }

    for l in 0..kb {
        // SAFETY: l < kb and apanel.len() >= kb·MR (asserted above), so
        // the two 4-lane loads at l·MR and l·MR + 4 stay in bounds.
        let (a_lo, a_hi) = unsafe {
            let p = ap.add(l * MR);
            (_mm256_loadu_pd(p), _mm256_loadu_pd(p.add(4)))
        };
        for j in 0..NR {
            // SAFETY: l·NR + j < kb·NR <= bpanel.len() (asserted above).
            let b = unsafe { _mm256_set1_pd(*bp.add(l * NR + j)) };
            lo[j] = _mm256_fmadd_pd(a_lo, b, lo[j]);
            hi[j] = _mm256_fmadd_pd(a_hi, b, hi[j]);
        }
    }

    for j in 0..NR {
        // SAFETY: same bounds as the loads — `acc[j]` is `[f64; 8]`.
        unsafe {
            _mm256_storeu_pd(acc[j].as_mut_ptr(), lo[j]);
            _mm256_storeu_pd(acc[j].as_mut_ptr().add(4), hi[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_fused_reference_bitwise() {
        if !super::super::avx2_runtime_available() {
            eprintln!("skipping: CPU lacks avx2+fma");
            return;
        }
        // Three KC-ish steps of mixed-sign data: the AVX2 kernel must
        // equal a scalar fma accumulation (same order, same fusedness)
        // bit for bit, lane by lane.
        let kb = 3;
        let apanel: Vec<f64> = (0..kb * MR).map(|i| ((i * 37 % 19) as f64) * 0.375 - 3.0).collect();
        let bpanel: Vec<f64> = (0..kb * NR).map(|i| 1.0 - ((i * 11 % 7) as f64) * 0.25).collect();
        let mut acc = [[0.0f64; MR]; NR];
        // SAFETY: guarded by the runtime feature check above.
        unsafe { microkernel_8x4(kb, &apanel, &bpanel, &mut acc) };
        for (j, accj) in acc.iter().enumerate() {
            for (i, &got) in accj.iter().enumerate() {
                let mut want = 0.0f64;
                for l in 0..kb {
                    want = apanel[l * MR + i].mul_add(bpanel[l * NR + j], want);
                }
                assert_eq!(got.to_bits(), want.to_bits(), "({i},{j})");
            }
        }
    }
}
