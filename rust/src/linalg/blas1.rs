//! Level-1 vector kernels (dot, axpy, nrm2, scal).
//!
//! Written over plain slices; columns of col-major views are contiguous so
//! the factorization code calls these directly on `col`/`col_mut` slices.

/// Dot product `xᵀ y`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    // 4-way unrolled accumulators help LLVM vectorize without changing
    // results across calls (deterministic order).
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = 4 * c;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += x[i] * y[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Euclidean norm with overflow/underflow-safe scaling (LAPACK dnrm2 style).
pub fn nrm2(x: &[f64]) -> f64 {
    let mut scale = 0.0f64;
    let mut ssq = 1.0f64;
    for &xi in x {
        if xi != 0.0 {
            let a = xi.abs();
            if scale < a {
                ssq = 1.0 + ssq * (scale / a) * (scale / a);
                scale = a;
            } else {
                ssq += (a / scale) * (a / scale);
            }
        }
    }
    scale * ssq.sqrt()
}

/// Index of the element with maximum absolute value (0 if empty).
pub fn iamax(x: &[f64]) -> usize {
    let mut best = 0;
    let mut bv = 0.0;
    for (i, &xi) in x.iter().enumerate() {
        if xi.abs() > bv {
            bv = xi.abs();
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
        // length > 4 exercises the unrolled path
        let x: Vec<f64> = (0..11).map(|i| i as f64).collect();
        let y = vec![1.0; 11];
        assert_eq!(dot(&x, &y), 55.0);
    }

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, 4.0], &mut y);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    fn nrm2_safe_scaling() {
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        // values that would overflow naive sum of squares
        let big = 1e200;
        assert!((nrm2(&[big, big]) - big * 2f64.sqrt()).abs() / big < 1e-14);
        // values that would underflow
        let small = 1e-200;
        assert!((nrm2(&[small, small]) - small * 2f64.sqrt()).abs() / small < 1e-14);
        assert_eq!(nrm2(&[]), 0.0);
    }

    #[test]
    fn iamax_basic() {
        assert_eq!(iamax(&[1.0, -5.0, 3.0]), 1);
        assert_eq!(iamax(&[]), 0);
    }
}
