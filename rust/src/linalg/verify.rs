//! Form checks and backward-error verification.
//!
//! The paper (§4) reports that every tested algorithm attains relative
//! backward errors on the order of machine precision; our integration tests
//! assert exactly that through these helpers.

use super::gemm::{matmul_t, Trans};
use super::matrix::Matrix;

/// Largest `|A[i,j]|` with `i > j + band` (so `band = 1` checks Hessenberg
/// form, `band = 0` checks upper-triangular form, `band = r` checks
/// r-Hessenberg form).
pub fn max_below_band(a: &Matrix, band: usize) -> f64 {
    let mut m = 0.0f64;
    for j in 0..a.cols() {
        for i in (j + band + 1)..a.rows() {
            m = m.max(a[(i, j)].abs());
        }
    }
    m
}

/// Whether `a` is in r-Hessenberg form to tolerance `tol·‖A‖_F`.
pub fn is_banded_hessenberg(a: &Matrix, r: usize, tol: f64) -> bool {
    max_below_band(a, r) <= tol * a.norm_fro().max(1e-300)
}

/// Orthogonality residual `‖QᵀQ − I‖_F`.
pub fn orth_error(q: &Matrix) -> f64 {
    let n = q.cols();
    let mut qtq = matmul_t(q, Trans::Yes, q, Trans::No);
    for i in 0..n {
        qtq[(i, i)] -= 1.0;
    }
    qtq.norm_fro()
}

/// Relative reconstruction error `‖M − Q X Zᵀ‖_F / ‖M‖_F`.
pub fn reconstruction_error(m: &Matrix, q: &Matrix, x: &Matrix, z: &Matrix) -> f64 {
    let qx = matmul_t(q, Trans::No, x, Trans::No);
    let qxzt = matmul_t(&qx, Trans::No, z, Trans::Yes);
    let mut d = 0.0;
    for j in 0..m.cols() {
        for i in 0..m.rows() {
            d += (m[(i, j)] - qxzt[(i, j)]).powi(2);
        }
    }
    d.sqrt() / m.norm_fro().max(1e-300)
}

/// Full verification of a Hessenberg-triangular (or r-HT) decomposition
/// `(A₀, B₀) = Q (H, T) Zᵀ`.
#[derive(Clone, Copy, Debug)]
pub struct HtVerification {
    /// `‖A₀ − Q H Zᵀ‖/‖A₀‖`.
    pub err_a: f64,
    /// `‖B₀ − Q T Zᵀ‖/‖B₀‖`.
    pub err_b: f64,
    /// `‖QᵀQ − I‖_F`.
    pub orth_q: f64,
    /// `‖ZᵀZ − I‖_F`.
    pub orth_z: f64,
    /// Largest below-band entry of `H` relative to `‖H‖`.
    pub hess_residual: f64,
    /// Largest below-diagonal entry of `T` relative to `‖T‖`.
    pub tri_residual: f64,
}

impl HtVerification {
    /// Compute all residuals for a claimed decomposition with bandwidth `r`
    /// (`r = 1` for true Hessenberg form).
    #[allow(clippy::too_many_arguments)]
    pub fn compute(
        a0: &Matrix,
        b0: &Matrix,
        q: &Matrix,
        z: &Matrix,
        h: &Matrix,
        t: &Matrix,
        r: usize,
    ) -> HtVerification {
        HtVerification {
            err_a: reconstruction_error(a0, q, h, z),
            err_b: reconstruction_error(b0, q, t, z),
            orth_q: orth_error(q),
            orth_z: orth_error(z),
            hess_residual: max_below_band(h, r) / h.norm_fro().max(1e-300),
            tri_residual: max_below_band(t, 0) / t.norm_fro().max(1e-300),
        }
    }

    /// Assert everything is at the `tol` level (test helper).
    pub fn assert_ok(&self, tol: f64) {
        assert!(self.err_a < tol, "backward error A {:.3e} >= {tol:.1e}", self.err_a);
        assert!(self.err_b < tol, "backward error B {:.3e} >= {tol:.1e}", self.err_b);
        assert!(self.orth_q < tol, "Q orthogonality {:.3e}", self.orth_q);
        assert!(self.orth_z < tol, "Z orthogonality {:.3e}", self.orth_z);
        assert!(self.hess_residual < tol, "H below-band {:.3e}", self.hess_residual);
        assert!(self.tri_residual < tol, "T below-diag {:.3e}", self.tri_residual);
    }

    /// The worst of all residuals.
    pub fn worst(&self) -> f64 {
        self.err_a
            .max(self.err_b)
            .max(self.orth_q)
            .max(self.orth_z)
            .max(self.hess_residual)
            .max(self.tri_residual)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn band_checks() {
        let mut a = Matrix::zeros(5, 5);
        a[(3, 0)] = 2.0;
        assert_eq!(max_below_band(&a, 0), 2.0);
        assert_eq!(max_below_band(&a, 2), 2.0);
        assert_eq!(max_below_band(&a, 3), 0.0);
        assert!(is_banded_hessenberg(&a, 3, 1e-14));
        assert!(!is_banded_hessenberg(&a, 2, 1e-14));
    }

    #[test]
    fn orth_error_identity() {
        assert!(orth_error(&Matrix::identity(6)) < 1e-15);
        let mut m = Matrix::identity(3);
        m[(0, 1)] = 0.5;
        assert!(orth_error(&m) > 0.4);
    }

    #[test]
    fn reconstruction_trivial() {
        let mut rng = Rng::new(70);
        let a = Matrix::randn(5, 5, &mut rng);
        let i = Matrix::identity(5);
        assert!(reconstruction_error(&a, &i, &a, &i) < 1e-15);
    }

    #[test]
    fn verification_accepts_identity_decomposition() {
        let mut rng = Rng::new(71);
        let n = 6;
        // Build an exactly-HT pencil and verify with Q=Z=I.
        let mut h = Matrix::randn(n, n, &mut rng);
        let mut t = Matrix::randn(n, n, &mut rng);
        for j in 0..n {
            for i in j + 2..n {
                h[(i, j)] = 0.0;
            }
            for i in j + 1..n {
                t[(i, j)] = 0.0;
            }
        }
        let i = Matrix::identity(n);
        let v = HtVerification::compute(&h, &t, &i, &i, &h, &t, 1);
        v.assert_ok(1e-13);
    }
}
