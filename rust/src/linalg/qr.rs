//! QR and LQ factorizations built from Householder reflectors.
//!
//! `geqr2` is the unblocked LAPACK-style in-place factorization; `QrFactor`
//! wraps the factored storage with τ's and can hand out the compact-WY
//! representation used everywhere in stage 1 (panel QR of the `p·n_b × n_b`
//! blocks) and for the opposite-reflector LQ factorizations.

use super::householder::{larf_left, larfg};
use super::matrix::{MatMut, Matrix};
use super::wy::{Side, WyRep};
use crate::linalg::gemm::Trans;

/// Unblocked QR factorization in place (LAPACK `dgeqr2`).
///
/// On exit, the upper triangle of `a` holds `R` and the columns below the
/// diagonal hold the reflector tails (`v[0] = 1` implicit). Returns the τ's.
pub fn geqr2(mut a: MatMut<'_>) -> Vec<f64> {
    let m = a.rows();
    let n = a.cols();
    let k = m.min(n);
    let mut taus = vec![0.0; k];
    let mut vbuf = vec![0.0; m];
    for i in 0..k {
        // Generate reflector for column i, rows i..m.
        let (beta, tau) = {
            let col = a.col_mut(i);
            let (head, tail) = col[i..].split_at_mut(1);
            larfg(head[0], tail)
        };
        taus[i] = tau;
        if i + 1 < n && tau != 0.0 {
            // Materialize v (leading 1) and apply to trailing columns.
            let len = m - i;
            vbuf[0] = 1.0;
            vbuf[1..len].copy_from_slice(&a.rb().col(i)[i + 1..m]);
            let trailing = a.rb_mut().sub(i..m, i + 1..n);
            larf_left(&vbuf[..len], tau, trailing);
        }
        *a.at_mut(i, i) = beta;
    }
    taus
}

/// A QR factorization: factored storage + τ's.
#[derive(Clone, Debug)]
pub struct QrFactor {
    /// `m×n` factored matrix (R above, reflectors below).
    pub factored: Matrix,
    /// Reflector scalars, length `min(m,n)`.
    pub taus: Vec<f64>,
}

impl QrFactor {
    /// Factor a copy of `a`.
    pub fn compute(a: &Matrix) -> QrFactor {
        let mut f = a.clone();
        let taus = geqr2(f.as_mut());
        QrFactor { factored: f, taus }
    }

    /// Factor in place, consuming `a`.
    pub fn compute_inplace(mut a: Matrix) -> QrFactor {
        let taus = geqr2(a.as_mut());
        QrFactor { factored: a, taus }
    }

    /// Number of reflectors.
    pub fn k(&self) -> usize {
        self.taus.len()
    }

    /// The `R` factor (upper triangular `k×n`).
    pub fn r(&self) -> Matrix {
        let k = self.k();
        let n = self.factored.cols();
        Matrix::from_fn(k, n, |i, j| if j >= i { self.factored[(i, j)] } else { 0.0 })
    }

    /// Explicit `V` (`m×k`, unit diagonal, zeros above).
    pub fn v_matrix(&self) -> Matrix {
        let m = self.factored.rows();
        let k = self.k();
        Matrix::from_fn(m, k, |i, j| {
            if i == j {
                1.0
            } else if i > j {
                self.factored[(i, j)]
            } else {
                0.0
            }
        })
    }

    /// Compact-WY representation of `Q = H_1 ⋯ H_k`.
    pub fn wy(&self) -> WyRep {
        WyRep::from_reflectors(self.v_matrix(), &self.taus)
    }

    /// Materialize `Q` (`m×m`).
    pub fn form_q(&self) -> Matrix {
        self.wy().form_q()
    }

    /// Apply `Qᵀ` from the left: `C := Qᵀ C` (the usual "reduce" direction).
    pub fn apply_qt_left(&self, c: MatMut<'_>) {
        self.wy().apply(Side::Left, Trans::Yes, c);
    }

    /// Apply `Q` from the right: `C := C Q`.
    pub fn apply_q_right(&self, c: MatMut<'_>) {
        self.wy().apply(Side::Right, Trans::No, c);
    }

    /// Columns `cols` of the explicit `Q` (`m×|cols|`), formed by applying
    /// the reflectors to unit vectors — `O(k·m·|cols|)` instead of `O(m³)`.
    pub fn q_columns(&self, cols: std::ops::Range<usize>) -> Matrix {
        let m = self.factored.rows();
        let mut e = Matrix::zeros(m, cols.end - cols.start);
        for (jj, j) in cols.clone().enumerate() {
            e[(j, jj)] = 1.0;
        }
        // Q e = H_1 ... H_k e: apply H_k first.
        let wy = self.wy();
        wy.apply(Side::Left, Trans::No, e.as_mut());
        e
    }
}

/// LQ factorization of `a` (`m×n`): `A = L Q̂` with `L` lower triangular and
/// `Q̂` orthogonal (rows). Computed via QR of `Aᵀ`: `Aᵀ = Q R ⇒ A = Rᵀ Qᵀ`,
/// so `L = Rᵀ` and `Q̂ = Qᵀ`. Returns `(L, WY of Q)` — note the WY is for
/// `Q` (of the transposed problem); apply `Q̂ = Qᵀ` with `Trans::Yes`.
pub fn lq(a: &Matrix) -> (Matrix, WyRep) {
    let at = a.transposed();
    let f = QrFactor::compute_inplace(at);
    let l = f.r().transposed();
    (l, f.wy())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_t};
    use crate::util::proptest::{check_rel, for_each_case};
    use crate::util::rng::Rng;

    fn rel(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = 0.0;
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                d += (x[(i, j)] - y[(i, j)]).powi(2);
            }
        }
        d.sqrt() / y.norm_fro().max(1e-300)
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Rng::new(42);
        for &(m, n) in &[(8usize, 5usize), (5, 5), (12, 12), (40, 16), (3, 7)] {
            let a = Matrix::randn(m, n, &mut rng);
            let f = QrFactor::compute(&a);
            let q = f.form_q();
            let r = f.r();
            // A ≈ Q(:, :k) R
            let k = f.k();
            let qk = Matrix::from_fn(m, k, |i, j| q[(i, j)]);
            let qr = matmul(&qk, &r);
            assert!(rel(&qr, &a) < 1e-13, "m={m} n={n}");
            // Q orthogonal
            let qtq = matmul_t(&q, Trans::Yes, &q, Trans::No);
            assert!(rel(&qtq, &Matrix::identity(m)) < 1e-13);
        }
    }

    #[test]
    fn apply_qt_reduces_to_r() {
        let mut rng = Rng::new(43);
        let a = Matrix::randn(10, 4, &mut rng);
        let f = QrFactor::compute(&a);
        let mut c = a.clone();
        f.apply_qt_left(c.as_mut());
        // Qᵀ A = R (upper trapezoidal): below-diagonal ~ 0.
        for j in 0..4 {
            for i in j + 1..10 {
                assert!(c[(i, j)].abs() < 1e-12, "({i},{j}) = {}", c[(i, j)]);
            }
        }
    }

    #[test]
    fn q_columns_match_full_q() {
        let mut rng = Rng::new(44);
        let a = Matrix::randn(9, 9, &mut rng);
        let f = QrFactor::compute(&a);
        let q = f.form_q();
        let qc = f.q_columns(6..9);
        for i in 0..9 {
            for (jj, j) in (6..9).enumerate() {
                assert!((qc[(i, jj)] - q[(i, j)]).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn lq_reconstructs() {
        let mut rng = Rng::new(45);
        for &(m, n) in &[(4usize, 10usize), (6, 6), (16, 40)] {
            let a = Matrix::randn(m, n, &mut rng);
            let (l, wy) = lq(&a);
            // A = L Q̂ with Q̂ = Qᵀ; L is m×k so use the first k columns of Q.
            let q = wy.form_q(); // n×n
            let k = m.min(n);
            let qk = Matrix::from_fn(n, k, |i, j| q[(i, j)]);
            let want = matmul_t(&l, Trans::No, &qk, Trans::Yes);
            assert!(rel(&want, &a) < 1e-13, "m={m} n={n}");
            // L lower triangular
            for i in 0..m {
                for j in i + 1..k {
                    assert!(l[(i, j)].abs() < 1e-13);
                }
            }
        }
    }

    #[test]
    fn property_qr_random_shapes() {
        for_each_case(20, 0xABCD, |rng| {
            let m = 1 + rng.below(30);
            let n = 1 + rng.below(30);
            let a = Matrix::randn(m, n, rng);
            let f = QrFactor::compute(&a);
            let q = f.form_q();
            let r = f.r();
            let k = f.k();
            let qk = Matrix::from_fn(m, k, |i, j| q[(i, j)]);
            let qr = matmul(&qk, &r);
            check_rel("A-QR", rel(&qr, &a), 1e-12)?;
            let qtq = matmul_t(&q, Trans::Yes, &q, Trans::No);
            check_rel("QtQ-I", rel(&qtq, &Matrix::identity(m)), 1e-12)?;
            Ok(())
        });
    }
}
