//! RQ factorization of square blocks, plus the "selected rows of Q"
//! extraction that powers the *opposite Householder reflector* trick
//! (Watkins; §2.2 and §3.1 of the paper).
//!
//! For a square `s×s` block `A` we need `A = R Q̃` with `R` upper triangular
//! and `Q̃` orthogonal — and then only the **first rows** of `Q̃`: stage 1
//! LQ-factors the first `n_b` rows, stage 2 needs just the first row.
//!
//! Implementation: with `P` the exchange (anti-identity) matrix,
//! `M = P Aᵀ P = Q' R'` (ordinary QR) gives `A = (P R'ᵀ P)(P Q'ᵀ P)`,
//! an RQ factorization. Rows `0..t` of `Q̃ = P Q'ᵀ P` are the *last* `t`
//! columns of `Q'`, index-reversed — and selected columns of `Q'` cost only
//! `O(s·k·t)` via reflector application to unit vectors, never `O(s³)`.

use super::matrix::Matrix;
use super::qr::QrFactor;

/// RQ factorization `A = R·Q̃` of a square matrix.
#[derive(Clone, Debug)]
pub struct RqFactor {
    qr: QrFactor,
    s: usize,
}

impl RqFactor {
    /// Factor the square matrix `a`.
    pub fn compute(a: &Matrix) -> RqFactor {
        let s = a.rows();
        assert_eq!(a.cols(), s, "RQ: square blocks only (got {}x{})", s, a.cols());
        // M = P Aᵀ P : M[i,j] = A[s-1-j, s-1-i]
        let m = Matrix::from_fn(s, s, |i, j| a[(s - 1 - j, s - 1 - i)]);
        RqFactor { qr: QrFactor::compute_inplace(m), s }
    }

    /// Block order `s`.
    pub fn order(&self) -> usize {
        self.s
    }

    /// The upper-triangular `R` factor.
    pub fn r(&self) -> Matrix {
        let s = self.s;
        let rp = self.qr.r(); // R' (s×s upper)
        Matrix::from_fn(s, s, |i, j| if j >= i { rp[(s - 1 - j, s - 1 - i)] } else { 0.0 })
    }

    /// Rows `0..t` of `Q̃` as a `t×s` matrix (`G[i, j] = Q'[s-1-j, s-1-i]`).
    pub fn q_top_rows(&self, t: usize) -> Matrix {
        let s = self.s;
        assert!(t <= s);
        let qc = self.qr.q_columns(s - t..s); // s×t: columns s-t..s of Q'
        // Row i of Q̃ = column (s-1-i) of Q' reversed: G[i,j] = qc[s-1-j, t-1-i].
        Matrix::from_fn(t, s, |i, j| qc[(s - 1 - j, t - 1 - i)])
    }

    /// Materialize the full `Q̃` (tests / small blocks).
    pub fn form_q(&self) -> Matrix {
        self.q_top_rows(self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_t, Trans};
    use crate::util::proptest::{check_rel, for_each_case};
    use crate::util::rng::Rng;

    fn rel(x: &Matrix, y: &Matrix) -> f64 {
        let mut d = 0.0;
        for j in 0..x.cols() {
            for i in 0..x.rows() {
                d += (x[(i, j)] - y[(i, j)]).powi(2);
            }
        }
        d.sqrt() / y.norm_fro().max(1e-300)
    }

    #[test]
    fn rq_reconstructs() {
        let mut rng = Rng::new(50);
        for &s in &[1usize, 2, 5, 16, 40] {
            let a = Matrix::randn(s, s, &mut rng);
            let f = RqFactor::compute(&a);
            let r = f.r();
            let q = f.form_q();
            let rq = matmul(&r, &q);
            assert!(rel(&rq, &a) < 1e-12, "s={s}");
            // R upper triangular
            for i in 0..s {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
            // Q orthogonal
            let qtq = matmul_t(&q, Trans::Yes, &q, Trans::No);
            assert!(rel(&qtq, &Matrix::identity(s)) < 1e-12);
        }
    }

    #[test]
    fn top_rows_match_full_q() {
        let mut rng = Rng::new(51);
        let s = 12;
        let a = Matrix::randn(s, s, &mut rng);
        let f = RqFactor::compute(&a);
        let q = f.form_q();
        for t in [1usize, 3, 12] {
            let g = f.q_top_rows(t);
            for i in 0..t {
                for j in 0..s {
                    assert!((g[(i, j)] - q[(i, j)]).abs() < 1e-13, "t={t} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn property_rq_random_sizes() {
        for_each_case(20, 0xBEEF, |rng| {
            let s = 1 + rng.below(25);
            let a = Matrix::randn(s, s, rng);
            let f = RqFactor::compute(&a);
            let rq = matmul(&f.r(), &f.form_q());
            check_rel("A-RQ", rel(&rq, &a), 1e-12)
        });
    }
}
