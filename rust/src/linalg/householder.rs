//! Elementary Householder reflectors (LAPACK `dlarfg`/`dlarf` analogues).
//!
//! Convention (LAPACK): `H = I − τ v vᵀ` with `v[0] = 1`. `H` is symmetric
//! and orthogonal. `larfg` generates a reflector that maps a vector onto
//! `±‖x‖ e₁`; `larf_left`/`larf_right` apply one reflector to a matrix view.

use super::blas1::nrm2;
use super::gemm::{gemm, Trans};
use super::matrix::{MatMut, MatRef};
use crate::util::flops;

/// Generate a Householder reflector for the vector `[alpha, x...]`.
///
/// On return `x` holds the tail of `v` (with implicit `v[0] = 1`) and the
/// result is `(beta, tau)` such that `H [alpha; x] = [beta; 0]` for
/// `H = I − τ v vᵀ`. If the tail is zero, `tau = 0` (H = I) and
/// `beta = alpha`.
pub fn larfg(alpha: f64, x: &mut [f64]) -> (f64, f64) {
    let xnorm = nrm2(x);
    if xnorm == 0.0 {
        return (alpha, 0.0);
    }
    flops::add(3 * x.len() as u64);
    // beta = -sign(alpha) * hypot(alpha, xnorm): avoids cancellation.
    let beta = -alpha.signum() * (alpha * alpha + xnorm * xnorm).sqrt();
    let tau = (beta - alpha) / beta;
    let inv = 1.0 / (alpha - beta);
    for xi in x.iter_mut() {
        *xi *= inv;
    }
    (beta, tau)
}

/// View a slice as an `n×1` column (the GEMV/GER shapes below).
#[inline]
fn as_col(v: &[f64]) -> MatRef<'_> {
    // SAFETY: a slice borrow is exactly the contract from_raw_parts wants
    // (n contiguous elements, immutable for the view's lifetime).
    unsafe { MatRef::from_raw_parts(v.as_ptr(), v.len(), 1, v.len().max(1)) }
}

/// Apply `H = I − τ v vᵀ` from the left: `C := H C`.
///
/// `v` has length `C.rows()` with `v[0]` stored explicitly (callers pass the
/// materialized vector including the leading 1). Routed through `gemm` as
/// a GEMV + rank-1 update pair (`w = Cᵀv`, `C −= τ·v·wᵀ`); gemm dispatches
/// these `n == 1` / `k == 1` shapes to pack-free fast paths, and the calls
/// count the same `4·len·cols` flops the old scalar loop did.
pub fn larf_left(v: &[f64], tau: f64, c: MatMut<'_>) {
    debug_assert_eq!(v.len(), c.rows());
    if tau == 0.0 || c.rows() == 0 || c.cols() == 0 {
        return;
    }
    let n = c.cols();
    let mut w = vec![0.0; n];
    let vm = as_col(v);
    // w = Cᵀ v (n×1)
    {
        // SAFETY: `w` is a live local Vec of n elements, exclusively
        // borrowed for this block only.
        let wm = unsafe { MatMut::from_raw_parts(w.as_mut_ptr(), n, 1, n) };
        gemm(1.0, c.rb(), Trans::Yes, vm, Trans::No, 0.0, wm);
    }
    // C -= τ v wᵀ
    gemm(-tau, vm, Trans::No, as_col(&w), Trans::Yes, 1.0, c);
}

/// Apply `H = I − τ v vᵀ` from the right: `C := C H`.
///
/// `v` has length `C.cols()`. Same GEMM routing as [`larf_left`]:
/// `w = C·v`, then `C −= τ·w·vᵀ`.
pub fn larf_right(v: &[f64], tau: f64, c: MatMut<'_>) {
    debug_assert_eq!(v.len(), c.cols());
    if tau == 0.0 || c.rows() == 0 || c.cols() == 0 {
        return;
    }
    let m = c.rows();
    let mut w = vec![0.0; m];
    let vm = as_col(v);
    // w = C v (m×1)
    {
        // SAFETY: `w` is a live local Vec of m elements, exclusively
        // borrowed for this block only.
        let wm = unsafe { MatMut::from_raw_parts(w.as_mut_ptr(), m, 1, m) };
        gemm(1.0, c.rb(), Trans::No, vm, Trans::No, 0.0, wm);
    }
    // C -= τ w vᵀ
    gemm(-tau, as_col(&w), Trans::No, vm, Trans::Yes, 1.0, c);
}

/// A stored reflector: the full `v` (leading 1 materialized) and `τ`.
#[derive(Clone, Debug)]
pub struct Reflector {
    /// Householder vector (v[0] = 1).
    pub v: Vec<f64>,
    /// Scaling factor τ.
    pub tau: f64,
}

impl Reflector {
    /// Generate the reflector reducing the full vector `x` (length ≥ 1) to
    /// `±‖x‖ e₁`. Returns `(reflector, beta)`.
    pub fn reducing(x: &[f64]) -> (Reflector, f64) {
        assert!(!x.is_empty());
        let mut v = x.to_vec();
        let (head, tail) = v.split_at_mut(1);
        let (beta, tau) = larfg(head[0], tail);
        head[0] = 1.0;
        (Reflector { v, tau }, beta)
    }

    /// `C := H C`.
    pub fn apply_left(&self, c: MatMut<'_>) {
        larf_left(&self.v, self.tau, c);
    }

    /// `C := C H`.
    pub fn apply_right(&self, c: MatMut<'_>) {
        larf_right(&self.v, self.tau, c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn larfg_annihilates() {
        let mut rng = Rng::new(17);
        for len in [2usize, 3, 10, 50] {
            let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let (refl, beta) = Reflector::reducing(&x);
            // Apply H to x as a column matrix: expect [beta, 0, ..., 0].
            let mut m = Matrix::from_fn(len, 1, |i, _| x[i]);
            refl.apply_left(m.as_mut());
            assert!((m[(0, 0)] - beta).abs() < 1e-12 * beta.abs().max(1.0));
            for i in 1..len {
                assert!(m[(i, 0)].abs() < 1e-13, "tail not annihilated: {}", m[(i, 0)]);
            }
            // |beta| = ||x||
            let nx = nrm2(&x);
            assert!((beta.abs() - nx).abs() < 1e-12 * nx);
        }
    }

    #[test]
    fn larfg_zero_tail_is_identity() {
        let mut x = vec![0.0, 0.0];
        let (beta, tau) = larfg(5.0, &mut x);
        assert_eq!(tau, 0.0);
        assert_eq!(beta, 5.0);
    }

    #[test]
    fn reflector_is_orthogonal_and_symmetric() {
        let mut rng = Rng::new(23);
        let x: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let (refl, _) = Reflector::reducing(&x);
        // Build H explicitly: H = I - tau v v^T.
        let n = x.len();
        let h = Matrix::from_fn(n, n, |i, j| {
            (if i == j { 1.0 } else { 0.0 }) - refl.tau * refl.v[i] * refl.v[j]
        });
        // H^T H = I
        let hth = crate::linalg::gemm::matmul_t(&h, crate::linalg::gemm::Trans::Yes, &h, crate::linalg::gemm::Trans::No);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((hth[(i, j)] - want).abs() < 1e-13);
            }
        }
    }

    #[test]
    fn left_right_consistency() {
        // (H C)^T == C^T H because H is symmetric.
        let mut rng = Rng::new(31);
        let x: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let (refl, _) = Reflector::reducing(&x);
        let c = Matrix::randn(5, 4, &mut rng);
        let mut hc = c.clone();
        refl.apply_left(hc.as_mut());
        let mut ct_h = c.transposed();
        refl.apply_right(ct_h.as_mut());
        for i in 0..5 {
            for j in 0..4 {
                assert!((hc[(i, j)] - ct_h[(j, i)]).abs() < 1e-13);
            }
        }
    }
}
