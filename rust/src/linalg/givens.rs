//! Givens plane rotations (LAPACK `dlartg` analogue).
//!
//! Used by the one-stage baselines (`MolerStewart`, `Dgghd3`): the original
//! Hessenberg-triangular reduction of Moler & Stewart is rotation-based, as
//! is LAPACK's `dgghd3` which the paper compares against.

use super::matrix::MatMut;
use crate::util::flops;

/// A plane rotation `[c s; -s c]` with `c² + s² = 1`.
#[derive(Clone, Copy, Debug)]
pub struct Givens {
    /// Cosine component.
    pub c: f64,
    /// Sine component.
    pub s: f64,
}

impl Givens {
    /// Compute `(G, r)` with `[c s; -s c]·[a; b] = [r; 0]`.
    pub fn make(a: f64, b: f64) -> (Givens, f64) {
        if b == 0.0 {
            return (Givens { c: 1.0, s: 0.0 }, a);
        }
        if a == 0.0 {
            return (Givens { c: 0.0, s: 1.0 }, b);
        }
        let r = a.hypot(b);
        let r = if a.abs() > b.abs() { r.copysign(a) } else { r.copysign(b) };
        (Givens { c: a / r, s: b / r }, r)
    }

    /// Apply from the left to rows `i1`, `i2` over columns `cols` of `m`:
    /// `[row_i1; row_i2] := [c s; -s c]·[row_i1; row_i2]`.
    pub fn apply_left(&self, mut m: MatMut<'_>, i1: usize, i2: usize, cols: std::ops::Range<usize>) {
        flops::add(6 * (cols.end - cols.start) as u64);
        for j in cols {
            let x = m.at(i1, j);
            let y = m.at(i2, j);
            m.set(i1, j, self.c * x + self.s * y);
            m.set(i2, j, -self.s * x + self.c * y);
        }
    }

    /// Apply from the right to columns `j1`, `j2` over rows `rows` of `m`:
    /// `[col_j1, col_j2] := [col_j1, col_j2]·[c -s; s c]ᵀ`… i.e. the same
    /// rotation acting on column pairs: `col_j1 := c·col_j1 + s·col_j2`,
    /// `col_j2 := -s·col_j1 + c·col_j2`.
    pub fn apply_right(&self, mut m: MatMut<'_>, j1: usize, j2: usize, rows: std::ops::Range<usize>) {
        flops::add(6 * (rows.end - rows.start) as u64);
        for i in rows {
            let x = m.at(i, j1);
            let y = m.at(i, j2);
            m.set(i, j1, self.c * x + self.s * y);
            m.set(i, j2, -self.s * x + self.c * y);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn make_annihilates() {
        let mut rng = Rng::new(60);
        for _ in 0..100 {
            let a = rng.normal();
            let b = rng.normal();
            let (g, r) = Givens::make(a, b);
            assert!((g.c * a + g.s * b - r).abs() < 1e-13 * r.abs().max(1.0));
            assert!((-g.s * a + g.c * b).abs() < 1e-13);
            assert!((g.c * g.c + g.s * g.s - 1.0).abs() < 1e-14);
        }
        // degenerate cases
        let (g, r) = Givens::make(3.0, 0.0);
        assert_eq!((g.c, g.s, r), (1.0, 0.0, 3.0));
        let (g, r) = Givens::make(0.0, 2.0);
        assert_eq!((g.c, g.s, r), (0.0, 1.0, 2.0));
    }

    #[test]
    fn left_apply_zeroes_entry() {
        let mut rng = Rng::new(61);
        let mut m = Matrix::randn(4, 5, &mut rng);
        let (g, _) = Givens::make(m[(1, 2)], m[(3, 2)]);
        g.apply_left(m.as_mut(), 1, 3, 0..5);
        assert!(m[(3, 2)].abs() < 1e-13);
    }

    #[test]
    fn right_apply_zeroes_entry() {
        let mut rng = Rng::new(62);
        let mut m = Matrix::randn(5, 4, &mut rng);
        // Zero m[2,3] against m[2,1]: col pair (1,3):
        let (g, _) = Givens::make(m[(2, 1)], m[(2, 3)]);
        g.apply_right(m.as_mut(), 1, 3, 0..5);
        assert!(m[(2, 3)].abs() < 1e-13);
    }

    #[test]
    fn rotation_preserves_norm() {
        let mut rng = Rng::new(63);
        let mut m = Matrix::randn(6, 6, &mut rng);
        let before = m.norm_fro();
        let (g, _) = Givens::make(1.0, 2.0);
        g.apply_left(m.as_mut(), 0, 4, 0..6);
        g.apply_right(m.as_mut(), 2, 3, 0..6);
        assert!((m.norm_fro() - before).abs() < 1e-12 * before);
    }
}
