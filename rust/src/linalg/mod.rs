//! Dense linear-algebra substrate (the role MKL plays in the paper).
//!
//! Everything is built from scratch over column-major `f64` storage:
//! level-1 kernels, a blocked GEMM with runtime-dispatched SIMD
//! microkernels ([`kernels`]), Householder reflectors with compact-WY
//! block representations, QR/LQ/RQ factorizations, Givens rotations, and
//! the verification helpers that back the paper's accuracy claims.

pub mod blas1;
pub mod gemm;
pub mod givens;
pub mod householder;
pub mod kernels;
pub mod lu;
pub mod matrix;
pub mod qr;
pub mod rq;
pub mod verify;
pub mod wy;

pub use gemm::{gemm, gemm_par, matmul, matmul_t, Trans};
pub use kernels::{Kernel, KernelChoice};
pub use matrix::{MatMut, MatRef, Matrix};
pub use wy::{Side, WyRep};
