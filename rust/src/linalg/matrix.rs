//! Column-major dense `f64` matrices and borrowed views.
//!
//! The whole library operates on LAPACK-style column-major storage so that
//! (a) columns are contiguous — the slicing used by the parallel apply tasks
//! (§2.3 of the paper) hands out disjoint column panels as contiguous
//! memory, and (b) the index arithmetic matches the Fortran conventions of
//! the paper's pseudocode (translated to 0-based half-open ranges here).
//!
//! `Matrix` owns its storage; `MatRef`/`MatMut` are lightweight borrowed
//! views with an explicit leading dimension (`ld`), the unit all block
//! algorithms are written against.

use crate::util::rng::Rng;
use std::fmt;
use std::marker::PhantomData;
use std::ops::{Index, IndexMut, Range};

/// Owned column-major matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// `rows × cols` matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// `n × n` identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Matrix with i.i.d. standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        Matrix::from_fn(rows, cols, |_, _| rng.normal())
    }

    /// Build from a row-major slice (convenient in tests).
    pub fn from_rows(rows: usize, cols: usize, v: &[f64]) -> Self {
        assert_eq!(v.len(), rows * cols);
        Matrix::from_fn(rows, cols, |i, j| v[i * cols + j])
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw column-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw column-major data.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Immutable view of the whole matrix.
    pub fn as_ref(&self) -> MatRef<'_> {
        MatRef {
            ptr: self.data.as_ptr(),
            rows: self.rows,
            cols: self.cols,
            ld: self.rows,
            _marker: PhantomData,
        }
    }

    /// Mutable view of the whole matrix.
    pub fn as_mut(&mut self) -> MatMut<'_> {
        MatMut {
            ptr: self.data.as_mut_ptr(),
            rows: self.rows,
            cols: self.cols,
            ld: self.rows,
            _marker: PhantomData,
        }
    }

    /// Immutable subview over half-open ranges.
    pub fn sub(&self, r: Range<usize>, c: Range<usize>) -> MatRef<'_> {
        self.as_ref().sub(r, c)
    }

    /// Mutable subview over half-open ranges.
    pub fn sub_mut(&mut self, r: Range<usize>, c: Range<usize>) -> MatMut<'_> {
        self.as_mut().sub(r, c)
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Frobenius norm.
    pub fn norm_fro(&self) -> f64 {
        self.as_ref().norm_fro()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {}x{}", self.rows, self.cols);
        &self.data[i + j * self.rows]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {}x{}", self.rows, self.cols);
        &mut self.data[i + j * self.rows]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>11.4e} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > cmax { "..." } else { "" })?;
        }
        if self.rows > rmax {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

/// Immutable borrowed view (column-major, leading dimension `ld`).
#[derive(Clone, Copy)]
pub struct MatRef<'a> {
    ptr: *const f64,
    rows: usize,
    cols: usize,
    ld: usize,
    _marker: PhantomData<&'a f64>,
}

// SAFETY: a `MatRef` is a plain shared borrow of `f64` data (no interior
// mutability, no thread affinity); sending it to another thread is safe.
unsafe impl Send for MatRef<'_> {}
// SAFETY: shared reads of `f64` data from multiple threads are safe; the
// view offers no mutation.
unsafe impl Sync for MatRef<'_> {}

impl<'a> MatRef<'a> {
    /// Construct from raw parts. Caller guarantees the pointed-to region
    /// (`ld*(cols-1)+rows` elements) outlives `'a` and is not mutated.
    ///
    /// # Safety
    /// See above; standard borrowed-view contract.
    pub unsafe fn from_raw_parts(ptr: *const f64, rows: usize, cols: usize, ld: usize) -> Self {
        debug_assert!(ld >= rows || cols <= 1);
        MatRef { ptr, rows, cols, ld, _marker: PhantomData }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Leading dimension.
    #[inline]
    pub fn ld(&self) -> usize {
        self.ld
    }
    /// Raw pointer to (0,0).
    #[inline]
    pub fn ptr(&self) -> *const f64 {
        self.ptr
    }

    /// Element access.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols, "index ({i},{j}) out of {}x{}", self.rows, self.cols);
        // SAFETY: for in-bounds (i, j) — asserted in debug builds — the
        // offset i + j*ld lies inside the ld*(cols-1)+rows elements the
        // view's constructor contract guarantees live and readable.
        unsafe { *self.ptr.add(i + j * self.ld) }
    }

    /// Column `j` as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &'a [f64] {
        debug_assert!(j < self.cols);
        // SAFETY: column j starts at offset j*ld and spans `rows`
        // contiguous elements, all inside the constructor-guaranteed
        // region; the returned borrow inherits the view's lifetime 'a.
        unsafe { std::slice::from_raw_parts(self.ptr.add(j * self.ld), self.rows) }
    }

    /// Subview over half-open ranges.
    pub fn sub(&self, r: Range<usize>, c: Range<usize>) -> MatRef<'a> {
        assert!(r.start <= r.end && r.end <= self.rows, "row range {r:?} out of {}", self.rows);
        assert!(c.start <= c.end && c.end <= self.cols, "col range {c:?} out of {}", self.cols);
        MatRef {
            // SAFETY: the asserted ranges keep the offset (and the
            // subview's extent, with the same ld) inside this view.
            ptr: unsafe { self.ptr.add(r.start + c.start * self.ld) },
            rows: r.end - r.start,
            cols: c.end - c.start,
            ld: self.ld,
            _marker: PhantomData,
        }
    }

    /// Copy into a new owned matrix.
    pub fn to_owned(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            m.data[j * self.rows..(j + 1) * self.rows].copy_from_slice(self.col(j));
        }
        m
    }

    /// Frobenius norm (no overflow guard; fine for the well-scaled data here).
    pub fn norm_fro(&self) -> f64 {
        let mut s = 0.0;
        for j in 0..self.cols {
            for &x in self.col(j) {
                s += x * x;
            }
        }
        s.sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        let mut m = 0.0f64;
        for j in 0..self.cols {
            for &x in self.col(j) {
                m = m.max(x.abs());
            }
        }
        m
    }
}

/// Mutable borrowed view (column-major, leading dimension `ld`).
pub struct MatMut<'a> {
    ptr: *mut f64,
    rows: usize,
    cols: usize,
    ld: usize,
    _marker: PhantomData<&'a mut f64>,
}

// SAFETY: a `MatMut` is an exclusive borrow of `f64` data (its contract
// says no aliasing access for 'a), so moving it to another thread is safe
// — exactly like `&mut [f64]`. Deliberately NOT `Sync`: `&MatMut` still
// reads, and cross-thread shared access is the auditor's business.
unsafe impl Send for MatMut<'_> {}

impl<'a> MatMut<'a> {
    /// Construct from raw parts. Caller guarantees exclusive access to the
    /// region for `'a`.
    ///
    /// # Safety
    /// See above; standard exclusive-view contract.
    pub unsafe fn from_raw_parts(ptr: *mut f64, rows: usize, cols: usize, ld: usize) -> Self {
        debug_assert!(ld >= rows || cols <= 1);
        MatMut { ptr, rows, cols, ld, _marker: PhantomData }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }
    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }
    /// Leading dimension.
    #[inline]
    pub fn ld(&self) -> usize {
        self.ld
    }
    /// Raw pointer to (0,0).
    #[inline]
    pub fn ptr(&self) -> *mut f64 {
        self.ptr
    }

    /// Element access.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        // SAFETY: in-bounds (i, j) — asserted in debug builds — stays
        // inside the exclusively-borrowed region of the constructor
        // contract.
        unsafe { *self.ptr.add(i + j * self.ld) }
    }

    /// Mutable element access.
    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        // SAFETY: as in `at`; `&mut self` makes the returned exclusive
        // borrow unique (no other access through this view while it
        // lives).
        unsafe { &mut *self.ptr.add(i + j * self.ld) }
    }

    /// Set element.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        *self.at_mut(i, j) = v;
    }

    /// Column `j` as a contiguous mutable slice.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        // SAFETY: column j is `rows` contiguous in-bounds elements, and
        // `&mut self` guarantees no other borrow of them while the slice
        // lives.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(j * self.ld), self.rows) }
    }

    /// Immutable snapshot view of this view.
    pub fn rb(&self) -> MatRef<'_> {
        MatRef { ptr: self.ptr, rows: self.rows, cols: self.cols, ld: self.ld, _marker: PhantomData }
    }

    /// Reborrow mutably (shorter lifetime).
    pub fn rb_mut(&mut self) -> MatMut<'_> {
        MatMut { ptr: self.ptr, rows: self.rows, cols: self.cols, ld: self.ld, _marker: PhantomData }
    }

    /// Mutable subview over half-open ranges (consumes the borrow; use
    /// `rb_mut().sub(..)` to keep the parent).
    pub fn sub(self, r: Range<usize>, c: Range<usize>) -> MatMut<'a> {
        assert!(r.start <= r.end && r.end <= self.rows, "row range {r:?} out of {}", self.rows);
        assert!(c.start <= c.end && c.end <= self.cols, "col range {c:?} out of {}", self.cols);
        MatMut {
            // SAFETY: the asserted ranges keep the subview inside this
            // view's region; `self` is consumed, so exclusivity transfers.
            ptr: unsafe { self.ptr.add(r.start + c.start * self.ld) },
            rows: r.end - r.start,
            cols: c.end - c.start,
            ld: self.ld,
            _marker: PhantomData,
        }
    }

    /// Split into two disjoint column panels `[0, j)` and `[j, cols)`.
    pub fn split_at_col(self, j: usize) -> (MatMut<'a>, MatMut<'a>) {
        assert!(j <= self.cols);
        let left = MatMut {
            ptr: self.ptr,
            rows: self.rows,
            cols: j,
            ld: self.ld,
            _marker: PhantomData,
        };
        let right = MatMut {
            // SAFETY: j ≤ cols (asserted), so the offset is in bounds;
            // the two panels cover disjoint column ranges of a consumed
            // exclusive view, so neither aliases the other.
            ptr: unsafe { self.ptr.add(j * self.ld) },
            rows: self.rows,
            cols: self.cols - j,
            ld: self.ld,
            _marker: PhantomData,
        };
        (left, right)
    }

    /// Split into two disjoint row panels `[0, i)` and `[i, rows)`.
    pub fn split_at_row(self, i: usize) -> (MatMut<'a>, MatMut<'a>) {
        assert!(i <= self.rows);
        let top = MatMut {
            ptr: self.ptr,
            rows: i,
            cols: self.cols,
            ld: self.ld,
            _marker: PhantomData,
        };
        let bottom = MatMut {
            // SAFETY: i ≤ rows (asserted); with the shared ld the two
            // panels address disjoint row ranges of a consumed exclusive
            // view (they interleave in memory but never overlap).
            ptr: unsafe { self.ptr.add(i) },
            rows: self.rows - i,
            cols: self.cols,
            ld: self.ld,
            _marker: PhantomData,
        };
        (top, bottom)
    }

    /// Fill every entry with `v`.
    pub fn fill(&mut self, v: f64) {
        for j in 0..self.cols {
            self.col_mut(j).fill(v);
        }
    }

    /// Copy from an equally-shaped source view.
    pub fn copy_from(&mut self, src: MatRef<'_>) {
        assert_eq!(self.rows, src.rows());
        assert_eq!(self.cols, src.cols());
        for j in 0..self.cols {
            self.col_mut(j).copy_from_slice(src.col(j));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m[(2, 3)] = 5.0;
        m[(0, 1)] = -1.0;
        assert_eq!(m[(2, 3)], 5.0);
        assert_eq!(m[(0, 1)], -1.0);
        assert_eq!(m.data()[2 + 3 * 3], 5.0); // col-major layout
    }

    #[test]
    fn identity_and_from_fn() {
        let i3 = Matrix::identity(3);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(i3[(i, j)], if i == j { 1.0 } else { 0.0 });
            }
        }
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m[(1, 2)], 12.0);
    }

    #[test]
    fn from_rows_matches() {
        let m = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
    }

    #[test]
    fn subview_indexing() {
        let m = Matrix::from_fn(5, 5, |i, j| (i * 10 + j) as f64);
        let v = m.sub(1..4, 2..5);
        assert_eq!(v.rows(), 3);
        assert_eq!(v.cols(), 3);
        assert_eq!(v.at(0, 0), 12.0);
        assert_eq!(v.at(2, 2), 34.0);
        let vv = v.sub(1..3, 0..2);
        assert_eq!(vv.at(0, 0), 22.0);
    }

    #[test]
    fn subview_mut_writes_through() {
        let mut m = Matrix::zeros(4, 4);
        {
            let mut v = m.sub_mut(1..3, 1..3);
            v.set(0, 0, 7.0);
            v.set(1, 1, 8.0);
        }
        assert_eq!(m[(1, 1)], 7.0);
        assert_eq!(m[(2, 2)], 8.0);
    }

    #[test]
    fn split_cols_disjoint() {
        let mut m = Matrix::zeros(3, 6);
        let (mut l, mut r) = m.as_mut().split_at_col(2);
        l.fill(1.0);
        r.fill(2.0);
        assert_eq!(m[(0, 1)], 1.0);
        assert_eq!(m[(0, 2)], 2.0);
        assert_eq!(m[(2, 5)], 2.0);
    }

    #[test]
    fn split_rows_disjoint() {
        let mut m = Matrix::zeros(6, 3);
        let (mut t, mut b) = m.as_mut().split_at_row(4);
        t.fill(1.0);
        b.fill(2.0);
        assert_eq!(m[(3, 0)], 1.0);
        assert_eq!(m[(4, 0)], 2.0);
    }

    #[test]
    fn col_slices_contiguous() {
        let m = Matrix::from_fn(4, 3, |i, j| (j * 4 + i) as f64);
        assert_eq!(m.as_ref().col(1), &[4.0, 5.0, 6.0, 7.0]);
        let v = m.sub(1..3, 1..3);
        assert_eq!(v.col(0), &[5.0, 6.0]);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_rows(2, 2, &[3., 0., 0., 4.]);
        assert!((m.norm_fro() - 5.0).abs() < 1e-15);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn transpose() {
        let m = Matrix::from_rows(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let t = m.transposed();
        assert_eq!(t.rows(), 3);
        assert_eq!(t[(2, 1)], 6.0);
        assert_eq!(t[(0, 1)], 4.0);
    }

    #[test]
    fn copy_from_view() {
        let src = Matrix::from_fn(3, 3, |i, j| (i + j) as f64);
        let mut dst = Matrix::zeros(3, 3);
        dst.as_mut().copy_from(src.as_ref());
        assert_eq!(dst, src);
    }

    #[test]
    #[should_panic]
    fn subview_out_of_range_panics() {
        let m = Matrix::zeros(3, 3);
        let _ = m.sub(0..4, 0..3);
    }
}
