//! `HouseHT`-style comparator (Bujanovic, Karlsson & Kressner, SIMAX 2018:
//! "A Householder-based algorithm for Hessenberg-triangular reduction").
//!
//! Reproduced here as the one-stage Householder reduction with the
//! solve-based opposite-reflector fast path and per-block robust fallback
//! ("iterative refinement"): on well-conditioned pencils the cheap path
//! always wins; on ill-conditioned / singular `B` (the saddle-point
//! pencils of §4) every bad block pays a verification + robust redo —
//! which is exactly why the paper's Fig. 11 shows HouseHT losing ground
//! there while never failing outright. See DESIGN.md §5 for the
//! substitution notes relative to the authors' original C++ code.

use crate::baselines::one_stage::{self, OneStageOpts, OneStageStats, OppositeMethod};
use crate::error::Result;
use crate::linalg::matrix::Matrix;

/// HouseHT tuning (the paper runs the original with `n_b = 64`; our
/// reflector chains are governed by `p`).
#[derive(Clone, Copy, Debug)]
pub struct HouseHtOpts {
    /// Block height multiplier.
    pub p: usize,
}

impl Default for HouseHtOpts {
    fn default() -> Self {
        HouseHtOpts { p: 8 }
    }
}

/// Run the HouseHT-style reduction. Never fails on singular `B`; the
/// returned stats expose how much per-block refinement was paid.
pub fn reduce(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    opts: &HouseHtOpts,
) -> Result<OneStageStats> {
    let os = OneStageOpts {
        p: opts.p,
        method: OppositeMethod::SolveWithFallback,
        ..Default::default()
    };
    one_stage::reduce(a, b, q, z, &os)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::HtVerification;
    use crate::pencil::random::random_pencil;
    use crate::pencil::saddle::saddle_pencil;
    use crate::util::rng::Rng;

    #[test]
    fn random_pencil_no_refinement() {
        let mut rng = Rng::new(140);
        let p = random_pencil(40, &mut rng);
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(40);
        let mut z = Matrix::identity(40);
        let stats = reduce(&mut a, &mut b, &mut q, &mut z, &HouseHtOpts::default()).unwrap();
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
        assert_eq!(stats.fallbacks, 0);
    }

    #[test]
    fn saddle_pencil_pays_refinement_but_succeeds() {
        let mut rng = Rng::new(141);
        let p = saddle_pencil(48, 0.25, &mut rng);
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(48);
        let mut z = Matrix::identity(48);
        let stats = reduce(&mut a, &mut b, &mut q, &mut z, &HouseHtOpts::default()).unwrap();
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-11);
        assert!(stats.fallbacks > 0);
    }
}
