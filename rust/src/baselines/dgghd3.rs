//! `DGGHD3`-style blocked one-stage reduction (Kågström, Kressner,
//! Quintana-Ortí², BIT 2008 — LAPACK 3.9's `dgghd3`), the paper's main
//! library comparator.
//!
//! Same rotation sequence as Moler–Stewart (`14 n³` flops), but the
//! application of each column's rotations to the *trailing* matrix parts —
//! `A(:, j+1:n)`, `Q` and `Z` — is deferred and batched: a full rotation
//! sequence is swept down each column in one cache-friendly pass. These
//! batched updates are the "≥60% of operations via matrix-matrix-like
//! kernels" part of dgghd3 that parallel BLAS can spread over cores, while
//! the `B`-maintenance part stays sequential — exactly the Amdahl structure
//! §1 of the paper criticizes. The coordinator's simulator slices the
//! batched updates to model the parallel-BLAS execution of this baseline
//! (see DESIGN.md §5 on this substitution).

use crate::coordinator::graph::TaskClass;
use crate::coordinator::recorder::PhaseRecorder;
use crate::linalg::givens::Givens;
use crate::linalg::matrix::{MatMut, Matrix};

/// One rotation acting on adjacent lines `(i, i+1)` (rows for left batches,
/// columns for right batches), stored with its position.
#[derive(Clone, Copy)]
pub struct PosRot {
    /// First line index (acts on `i` and `i+1` is implicit? no — see apply).
    pub i1: usize,
    /// Second line index.
    pub i2: usize,
    /// The rotation.
    pub g: Givens,
}

/// Apply a batch of *left* rotation pairs to a column slice of `m`,
/// sweeping every rotation down each column in one pass.
pub fn apply_left_batch(rots: &[PosRot], mut m: MatMut<'_>, cols: std::ops::Range<usize>) {
    crate::util::flops::add(6 * rots.len() as u64 * (cols.end - cols.start) as u64);
    for c in cols {
        let col = m.col_mut(c);
        for r in rots {
            let x = col[r.i1];
            let y = col[r.i2];
            col[r.i1] = r.g.c * x + r.g.s * y;
            col[r.i2] = -r.g.s * x + r.g.c * y;
        }
    }
}

/// Apply a batch of *right* rotation pairs (`col_{i1} ← c·col_{i1} +
/// s·col_{i2}`, `col_{i2} ← −s·col_{i1} + c·col_{i2}`) over a row range.
pub fn apply_right_batch(rots: &[PosRot], mut m: MatMut<'_>, rows: std::ops::Range<usize>) {
    for r in rots {
        r.g.apply_right(m.rb_mut(), r.i1, r.i2, rows.clone());
    }
}

/// Blocked one-stage reduction; mathematically identical to
/// [`crate::baselines::moler_stewart::reduce`], deferred/batched updates.
pub fn reduce(a: &mut Matrix, b: &mut Matrix, q: &mut Matrix, z: &mut Matrix) {
    let mut rec = PhaseRecorder::new();
    reduce_recorded(a, b, q, z, &mut rec);
}

/// As [`reduce`], recording each phase (sequential rotation generation +
/// `B` maintenance vs. batched "parallel-BLAS" trailing updates) into the
/// recorder for comparator simulation.
pub fn reduce_recorded(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    rec: &mut PhaseRecorder,
) {
    let n = a.rows();
    if n < 3 {
        return;
    }
    for j in 0..n - 2 {
        // --- Sequential part: generate rotations, maintain B and A(:,j). ---
        let (lefts, rights) = rec.record(TaskClass::BaseSeq, false, || {
            let mut lefts: Vec<PosRot> = Vec::with_capacity(n - j);
            let mut rights: Vec<PosRot> = Vec::with_capacity(n - j);
            for i in (j + 2..n).rev() {
                let (g, _) = Givens::make(a[(i - 1, j)], a[(i, j)]);
                // A column j only (the rest is deferred).
                let x = a[(i - 1, j)];
                let y = a[(i, j)];
                a[(i - 1, j)] = g.c * x + g.s * y;
                a[(i, j)] = 0.0;
                g.apply_left(b.as_mut(), i - 1, i, i - 1..n);
                lefts.push(PosRot { i1: i - 1, i2: i, g });

                let (gr, _) = Givens::make(b[(i, i)], b[(i, i - 1)]);
                gr.apply_right(b.as_mut(), i, i - 1, 0..i + 1);
                b[(i, i - 1)] = 0.0;
                rights.push(PosRot { i1: i, i2: i - 1, g: gr });
            }
            (lefts, rights)
        });

        // --- Batched ("BLAS") part: trailing A, Q, Z — one barrier each. ---
        rec.record(TaskClass::BaseBlas, true, || {
            apply_left_batch(&lefts, a.as_mut(), j + 1..n);
        });
        // Q accumulates Gᵀ of each left rotation, in order — as a column
        // update that is `apply_right` with the same (c, s).
        rec.record(TaskClass::BaseBlas, true, || {
            apply_right_batch(&lefts, q.as_mut(), 0..n);
        });
        rec.record(TaskClass::BaseBlas, true, || {
            apply_right_batch(&rights, a.as_mut(), 0..n);
        });
        rec.record(TaskClass::BaseBlas, true, || {
            apply_right_batch(&rights, z.as_mut(), 0..n);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::moler_stewart;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    #[test]
    fn equals_moler_stewart_to_rounding() {
        let mut rng = Rng::new(120);
        let p = random_pencil(40, &mut rng);
        let (mut a1, mut b1) = (p.a.clone(), p.b.clone());
        let (mut q1, mut z1) = (Matrix::identity(40), Matrix::identity(40));
        moler_stewart::reduce(&mut a1, &mut b1, &mut q1, &mut z1);
        let (mut a2, mut b2) = (p.a.clone(), p.b.clone());
        let (mut q2, mut z2) = (Matrix::identity(40), Matrix::identity(40));
        reduce(&mut a2, &mut b2, &mut q2, &mut z2);
        let mut d = 0.0f64;
        for jj in 0..40 {
            for i in 0..40 {
                d = d.max((a1[(i, jj)] - a2[(i, jj)]).abs());
                d = d.max((b1[(i, jj)] - b2[(i, jj)]).abs());
                d = d.max((q1[(i, jj)] - q2[(i, jj)]).abs());
                d = d.max((z1[(i, jj)] - z2[(i, jj)]).abs());
            }
        }
        assert!(d < 1e-11, "max deviation {d:.3e}");
    }

    #[test]
    fn reduces_correctly() {
        let mut rng = Rng::new(121);
        let p = random_pencil(60, &mut rng);
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(60);
        let mut z = Matrix::identity(60);
        reduce(&mut a, &mut b, &mut q, &mut z);
        assert_eq!(max_below_band(&a, 1), 0.0);
        assert!(max_below_band(&b, 0) < 1e-13 * b.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-12);
    }
}
