//! `IterHT`-style comparator (Steel & Vandebril, EJLA 2023: "A novel,
//! blocked algorithm for the reduction to Hessenberg-triangular form").
//!
//! The solve-based one-stage reduction run *without* per-block fallback,
//! wrapped in global iterative refinement: a pass either completes with
//! small residuals (one iteration on well-conditioned pencils — the common
//! case in §4's random tests) or aborts on an ill-conditioned block, after
//! which the pass is retried on the partially-reduced pencil. Pencils with
//! many infinite eigenvalues keep producing singular blocks, so the
//! algorithm "fails to converge within 10 iterations of iterative
//! refinement" — verbatim the behaviour reported under Fig. 11.

use crate::baselines::one_stage::{self, OneStageOpts, OppositeMethod};
use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;

/// IterHT options.
#[derive(Clone, Copy, Debug)]
pub struct IterHtOpts {
    /// Block height multiplier.
    pub p: usize,
    /// Maximum refinement iterations (paper: 10).
    pub max_iters: usize,
    /// Residual level accepted as converged.
    pub tol: f64,
}

impl Default for IterHtOpts {
    fn default() -> Self {
        IterHtOpts { p: 8, max_iters: 10, tol: 1e-10 }
    }
}

/// Outcome of an IterHT run.
#[derive(Clone, Copy, Debug)]
pub struct IterHtStats {
    /// Iterations actually used (≥ 1).
    pub iterations: usize,
    /// Worst per-block residual of the final pass.
    pub final_residual: f64,
}

/// Run the IterHT-style reduction. Fails with `Error::Numerical` when
/// `max_iters` passes cannot produce a clean reduction.
pub fn reduce(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    opts: &IterHtOpts,
) -> Result<IterHtStats> {
    let os = OneStageOpts {
        p: opts.p,
        method: OppositeMethod::Solve,
        residual_tol: opts.tol,
        ..Default::default()
    };
    for iter in 1..=opts.max_iters {
        match one_stage::reduce(a, b, q, z, &os) {
            Ok(stats) => {
                return Ok(IterHtStats { iterations: iter, final_residual: stats.worst_residual })
            }
            Err(_) => {
                // Partial progress is an orthogonal equivalence — retrying
                // on the current state is sound. Singular blocks will keep
                // failing, bounded by max_iters.
                continue;
            }
        }
    }
    Err(Error::numerical(format!(
        "IterHT failed to converge within {} iterations of iterative refinement",
        opts.max_iters
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::HtVerification;
    use crate::pencil::random::random_pencil;
    use crate::pencil::saddle::saddle_pencil;
    use crate::util::rng::Rng;

    #[test]
    fn single_iteration_on_random_pencil() {
        let mut rng = Rng::new(150);
        let p = random_pencil(40, &mut rng);
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(40);
        let mut z = Matrix::identity(40);
        let stats = reduce(&mut a, &mut b, &mut q, &mut z, &IterHtOpts::default()).unwrap();
        assert_eq!(stats.iterations, 1, "well-conditioned pencil needs one pass");
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-10);
    }

    #[test]
    fn fails_on_saddle_pencil() {
        // 25% infinite eigenvalues → singular B blocks → non-convergence,
        // as reported for IterHT in the paper's Fig. 11.
        let mut rng = Rng::new(151);
        let p = saddle_pencil(40, 0.25, &mut rng);
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(40);
        let mut z = Matrix::identity(40);
        let err = reduce(&mut a, &mut b, &mut q, &mut z, &IterHtOpts::default());
        assert!(err.is_err());
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("failed to converge"), "{msg}");
    }
}
