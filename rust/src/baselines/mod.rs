//! Every comparator of the paper's §4 experiments, built from scratch:
//!
//! * [`moler_stewart`] — the original Givens one-stage reduction
//!   (LAPACK `dgghrd`; the "sequential LAPACK" normalizer).
//! * [`dgghd3`] — blocked one-stage (Kågström et al. 2008 / LAPACK 3.9)
//!   with batched trailing updates.
//! * [`househt`] — Householder-based one-stage with per-block refinement
//!   (Bujanovic–Karlsson–Kressner style).
//! * [`iterht`] — solve-based blocked one-stage with global iterative
//!   refinement (Steel–Vandebril style); fails on many ∞ eigenvalues.

pub mod dgghd3;
pub mod househt;
pub mod iterht;
pub mod moler_stewart;
pub mod one_stage;
