//! Generalized *one-stage* Householder-based Hessenberg-triangular
//! reduction — the engine behind the `HouseHT` and `IterHT` comparators.
//!
//! Structure: Algorithm 1 of the paper with panel width `n_b = 1` (so the
//! result is true Hessenberg form, not banded): each column is reduced by a
//! chain of `p`-row Householder reflectors bottom-up, and `B`'s fill is
//! removed block-wise by *opposite* reflectors. The two comparators differ
//! in how the opposite reflector is constructed:
//!
//! * [`OppositeMethod::Rq`] — orthogonal RQ factorization of the block
//!   (robust; insensitive to `B`'s conditioning).
//! * [`OppositeMethod::Solve`] — solve `B_blk x = e₁` and reduce `x`
//!   (cheap, BLAS-friendly — but the error scales with `cond(B_blk)`;
//!   singular blocks fail outright). This is the Steel–Vandebril/IterHT
//!   style construction and the mechanism behind the paper's saddle-point
//!   results (§4, Fig. 11).
//! * [`OppositeMethod::SolveWithFallback`] — try the solve, verify the
//!   produced column, redo robustly on failure (HouseHT-style per-block
//!   iterative refinement: correct everywhere, pays extra on bad blocks).

use crate::coordinator::graph::TaskClass;
use crate::coordinator::recorder::PhaseRecorder;
use crate::error::{Error, Result};
use crate::linalg::householder::Reflector;
use crate::linalg::lu::LuFactor;
use crate::linalg::matrix::Matrix;
use crate::linalg::qr::QrFactor;
use crate::linalg::rq::RqFactor;
use crate::linalg::wy::Side;
use crate::linalg::Trans;

/// Opposite-reflector construction strategy.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum OppositeMethod {
    /// Robust RQ-based construction.
    Rq,
    /// Triangular-solve construction; `Err(Numerical)` on bad blocks.
    Solve,
    /// Solve, verify, fall back to RQ per block.
    SolveWithFallback,
}

/// Options for the one-stage reduction.
#[derive(Clone, Copy, Debug)]
pub struct OneStageOpts {
    /// Block height multiplier (reflectors have `≤ p` rows).
    pub p: usize,
    /// Opposite-reflector construction.
    pub method: OppositeMethod,
    /// Reciprocal-condition threshold below which a solve is rejected.
    pub rcond_tol: f64,
    /// Relative residual threshold on the reduced `B` column.
    pub residual_tol: f64,
}

impl Default for OneStageOpts {
    fn default() -> Self {
        OneStageOpts { p: 8, method: OppositeMethod::Rq, rcond_tol: 1e-12, residual_tol: 1e-8 }
    }
}

/// Statistics of one reduction pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct OneStageStats {
    /// Blocks where the solve path was rejected and RQ was used instead.
    pub fallbacks: usize,
    /// Blocks processed in total.
    pub blocks: usize,
    /// Worst relative residual seen on a solve-reduced column.
    pub worst_residual: f64,
}

/// Opposite reflector via RQ (first row of `Q̃`), as in stage 1/2.
fn opposite_rq(blk: &Matrix) -> Reflector {
    let rq = RqFactor::compute(blk);
    let row = rq.q_top_rows(1);
    let x: Vec<f64> = (0..blk.rows()).map(|c| row[(0, c)]).collect();
    Reflector::reducing(&x).0
}

/// Opposite reflector via `B_blk x = e₁`: `Ẑ` reduces `x`, so
/// `B_blk Ẑ e₁ = B_blk x / γ = e₁/γ` — the first block column is clean.
fn opposite_solve(blk: &Matrix, rcond_tol: f64) -> Result<Reflector> {
    let s = blk.rows();
    let lu = LuFactor::compute(blk)?;
    if lu.rcond_estimate() < rcond_tol {
        return Err(Error::numerical(format!(
            "opposite solve: block rcond {:.2e} below {rcond_tol:.1e}",
            lu.rcond_estimate()
        )));
    }
    let mut x = vec![0.0; s];
    x[0] = 1.0;
    lu.solve_vec(&mut x);
    if !x.iter().all(|v| v.is_finite()) {
        return Err(Error::numerical("opposite solve: non-finite solution"));
    }
    Ok(Reflector::reducing(&x).0)
}

/// One-stage reduction of `(A, B)` (B upper triangular) to
/// Hessenberg-triangular form, accumulating into `q`, `z`.
pub fn reduce(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    opts: &OneStageOpts,
) -> Result<OneStageStats> {
    let mut rec = PhaseRecorder::new();
    reduce_recorded(a, b, q, z, opts, &mut rec)
}

/// As [`reduce`], recording sequential vs. BLAS-sliceable phases for the
/// comparator simulation (HouseHT/IterHT parallelize through BLAS with a
/// barrier per call; the trailing applications are deferred per column to
/// expose them as batched phases — left/right updates commute, so the
/// result changes only at rounding level).
pub fn reduce_recorded(
    a: &mut Matrix,
    b: &mut Matrix,
    q: &mut Matrix,
    z: &mut Matrix,
    opts: &OneStageOpts,
    rec: &mut PhaseRecorder,
) -> Result<OneStageStats> {
    let n = a.rows();
    let p = opts.p.max(2);
    let mut stats = OneStageStats::default();
    if n < 3 {
        return Ok(stats);
    }
    for j in 0..n - 2 {
        // Block geometry: rows j+1..n in chains of p with overlap 1.
        let rows = n - j - 1;
        if rows < 2 {
            continue;
        }
        let step = p - 1;
        let nblocks = rows.div_ceil(step);
        let block = |k: usize| {
            let i1 = j + 1 + k * step;
            (i1, (i1 + p).min(n))
        };

        // ---- Left pass (bottom-up), sequential core: generate the
        // reflectors, reduce A(:, j), maintain B.
        let mut hs: Vec<(usize, usize, Reflector)> = Vec::new();
        rec.record(TaskClass::BaseSeq, false, || {
            for k in (0..nblocks).rev() {
                let (i1, i2e) = block(k);
                if i2e <= i1 + 1 {
                    continue;
                }
                let x: Vec<f64> = (i1..i2e).map(|i| a[(i, j)]).collect();
                let (h, beta) = Reflector::reducing(&x);
                a[(i1, j)] = beta;
                for i in i1 + 1..i2e {
                    a[(i, j)] = 0.0;
                }
                h.apply_left(b.sub_mut(i1..i2e, i1..n));
                hs.push((i1, i2e, h));
            }
        });
        // ---- Deferred BLAS phases: trailing A columns and Q.
        rec.record(TaskClass::BaseBlas, true, || {
            for (i1, i2e, h) in &hs {
                h.apply_left(a.sub_mut(*i1..*i2e, j + 1..n));
            }
        });
        rec.record(TaskClass::BaseBlas, true, || {
            for (i1, i2e, h) in &hs {
                h.apply_right(q.sub_mut(0..n, *i1..*i2e));
            }
        });

        // ---- Right pass (bottom-up), sequential core: opposite
        // reflectors + B update (incl. fallback logic).
        let mut zs: Vec<(usize, usize, Reflector)> = Vec::new();
        let mut fail: Option<Error> = None;
        rec.record(TaskClass::BaseSeq, false, || {
            for k in (0..nblocks).rev() {
                let (i1, i2e) = block(k);
                let s = i2e - i1;
                if s < 2 {
                    continue;
                }
                stats.blocks += 1;
                let blk = b.sub(i1..i2e, i1..i2e).to_owned();

                let mut redone_robustly = false;
                let mut zk = match opts.method {
                    OppositeMethod::Rq => opposite_rq(&blk),
                    OppositeMethod::Solve => match opposite_solve(&blk, opts.rcond_tol) {
                        Ok(r) => r,
                        Err(e) => {
                            fail = Some(e);
                            return;
                        }
                    },
                    OppositeMethod::SolveWithFallback => {
                        match opposite_solve(&blk, opts.rcond_tol) {
                            Ok(r) => r,
                            Err(_) => {
                                stats.fallbacks += 1;
                                redone_robustly = true;
                                opposite_rq(&blk)
                            }
                        }
                    }
                };
                loop {
                    // Tentatively check the produced column on a copy.
                    let mut test = blk.clone();
                    zk.apply_right(test.as_mut());
                    let mut junk = 0.0f64;
                    for i in 1..s {
                        junk = junk.max(test[(i, 0)].abs());
                    }
                    let rel = junk / blk.norm_fro().max(1e-300);
                    stats.worst_residual = stats.worst_residual.max(rel);
                    if rel <= opts.residual_tol {
                        break;
                    }
                    match opts.method {
                        OppositeMethod::Rq => break,
                        OppositeMethod::Solve => {
                            fail = Some(Error::numerical(format!(
                                "solve-based opposite reflector residual {rel:.2e} at block ({i1},{i2e})"
                            )));
                            return;
                        }
                        OppositeMethod::SolveWithFallback => {
                            // The RQ redo is the robust endpoint; if even it
                            // misses the tolerance the residual is as good
                            // as this block gets — retrying the identical
                            // construction would loop forever.
                            if redone_robustly {
                                break;
                            }
                            stats.fallbacks += 1;
                            zk = opposite_rq(&blk);
                            redone_robustly = true;
                        }
                    }
                }

                zk.apply_right(b.sub_mut(0..i2e, i1..i2e));
                for i in i1 + 1..i2e {
                    b[(i, i1)] = 0.0;
                }
                zs.push((i1, i2e, zk));
            }
        });
        if let Some(e) = fail {
            return Err(e);
        }
        // ---- Deferred BLAS phases: A columns and Z.
        rec.record(TaskClass::BaseBlas, true, || {
            for (i1, i2e, zk) in &zs {
                zk.apply_right(a.sub_mut(0..n, *i1..*i2e));
            }
        });
        rec.record(TaskClass::BaseBlas, true, || {
            for (i1, i2e, zk) in &zs {
                zk.apply_right(z.sub_mut(0..n, *i1..*i2e));
            }
        });
    }
    Ok(stats)
}

/// Convenience used by tests: blocked left reflectors as WY (kept for API
/// parity with stage 1; the `p`-row chains here are single reflectors).
pub fn left_block_wy(a: &Matrix, i1: usize, i2e: usize, j: usize) -> crate::linalg::wy::WyRep {
    let blk = a.sub(i1..i2e, j..j + 1).to_owned();
    let f = QrFactor::compute_inplace(blk);
    f.wy()
}

/// Apply helper re-exported for the parallel driver.
pub fn apply_wy_right(wy: &crate::linalg::wy::WyRep, c: crate::linalg::matrix::MatMut<'_>) {
    wy.apply(Side::Right, Trans::No, c);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::pencil::saddle::saddle_pencil;
    use crate::util::rng::Rng;

    fn run(n: usize, opts: &OneStageOpts, seed: u64, saddle: bool) -> Result<(f64, OneStageStats)> {
        let mut rng = Rng::new(seed);
        let p = if saddle { saddle_pencil(n, 0.25, &mut rng) } else { random_pencil(n, &mut rng) };
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(n);
        let mut z = Matrix::identity(n);
        let stats = reduce(&mut a, &mut b, &mut q, &mut z, opts)?;
        assert_eq!(max_below_band(&a, 1), 0.0, "A not Hessenberg");
        let v = HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1);
        Ok((v.worst(), stats))
    }

    #[test]
    fn rq_method_reduces_random() {
        let opts = OneStageOpts::default();
        let (worst, stats) = run(50, &opts, 130, false).unwrap();
        assert!(worst < 1e-11, "worst residual {worst:.3e}");
        assert_eq!(stats.fallbacks, 0);
    }

    #[test]
    fn solve_method_reduces_well_conditioned() {
        let opts = OneStageOpts { method: OppositeMethod::Solve, ..Default::default() };
        let (worst, _) = run(50, &opts, 131, false).unwrap();
        assert!(worst < 1e-10, "worst residual {worst:.3e}");
    }

    #[test]
    fn solve_method_fails_on_saddle() {
        // Singular B blocks → LU failure → Err (the IterHT failure mode).
        let opts = OneStageOpts { method: OppositeMethod::Solve, ..Default::default() };
        assert!(run(40, &opts, 132, true).is_err());
    }

    #[test]
    fn fallback_method_succeeds_on_saddle_with_fallbacks() {
        // HouseHT-style: correct on singular B, but pays fallbacks.
        let opts = OneStageOpts { method: OppositeMethod::SolveWithFallback, ..Default::default() };
        let (worst, stats) = run(40, &opts, 133, true).unwrap();
        assert!(worst < 1e-11, "worst {worst:.3e}");
        assert!(stats.fallbacks > 0, "expected fallbacks on singular B");
    }

    #[test]
    fn fallback_rarely_triggers_on_random() {
        let opts = OneStageOpts { method: OppositeMethod::SolveWithFallback, ..Default::default() };
        let (_, stats) = run(50, &opts, 134, false).unwrap();
        assert_eq!(stats.fallbacks, 0, "well-conditioned pencil should not fall back");
    }

    #[test]
    fn p_variants() {
        for p in [2usize, 4, 12] {
            let opts = OneStageOpts { p, ..Default::default() };
            let (worst, _) = run(30, &opts, 135, false).unwrap();
            assert!(worst < 1e-11, "p={p}");
        }
    }
}
