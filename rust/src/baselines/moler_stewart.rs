//! The original one-stage Hessenberg-triangular reduction of Moler &
//! Stewart (1973) — Givens-rotation based, the algorithm behind LAPACK's
//! `dgghrd`. Cost: `14 n³ + O(n²)` flops including the accumulation of
//! `Q` and `Z` (§3.1 of the paper).
//!
//! This is the "LAPACK sequential" normalizer of every figure in §4.

use crate::linalg::givens::Givens;
use crate::linalg::matrix::Matrix;

/// One-stage reduction: `A ← Hessenberg`, `B ← triangular` (B must start
/// upper triangular), accumulating into `q`, `z`.
///
/// For each column `j`, entries `A(i, j)` are annihilated bottom-up with a
/// left rotation of rows `(i−1, i)`; the resulting fill `B(i, i−1)` is
/// immediately removed by a right rotation of columns `(i−1, i)`.
pub fn reduce(a: &mut Matrix, b: &mut Matrix, q: &mut Matrix, z: &mut Matrix) {
    let n = a.rows();
    if n < 3 {
        return;
    }
    for j in 0..n - 2 {
        for i in (j + 2..n).rev() {
            // Left rotation zeroing A(i, j) against A(i-1, j).
            let (g, _) = Givens::make(a[(i - 1, j)], a[(i, j)]);
            g.apply_left(a.as_mut(), i - 1, i, j..n);
            a[(i, j)] = 0.0;
            g.apply_left(b.as_mut(), i - 1, i, i - 1..n);
            // Q accumulates the transpose of the left rotations:
            // A0 = Q H Zᵀ with H = Gᵀ A ⇒ Q ← Q Gᵀ (columns i-1, i).
            g_t_right(q, &g, i - 1, i);

            // Right rotation zeroing the fill B(i, i-1) against B(i, i).
            // Columns (i-1, i): choose G so that col_{i-1} gets the zero.
            let (gr, _) = Givens::make(b[(i, i)], b[(i, i - 1)]);
            // Apply to columns (i, i-1) in that order: c*col_i + s*col_{i-1}
            // → col_i ; -s*col_i + c*col_{i-1} → col_{i-1}.
            gr.apply_right(b.as_mut(), i, i - 1, 0..i + 1);
            b[(i, i - 1)] = 0.0;
            gr.apply_right(a.as_mut(), i, i - 1, 0..n);
            gr.apply_right(z.as_mut(), i, i - 1, 0..n);
        }
    }
}

/// `M(:, [c1, c2]) ← M(:, [c1, c2]) · Gᵀ` for the rotation `G = [c s; -s c]`
/// applied to the row pair. Columns transform as `col_{c1} ← c·col_{c1} +
/// s·col_{c2}`, `col_{c2} ← −s·col_{c1} + c·col_{c2}` — which is exactly
/// `Givens::apply_right` with the *same* `(c, s)`.
fn g_t_right(m: &mut Matrix, g: &Givens, c1: usize, c2: usize) {
    let rows = 0..m.rows();
    g.apply_right(m.as_mut(), c1, c2, rows);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::verify::{max_below_band, HtVerification};
    use crate::pencil::random::random_pencil;
    use crate::pencil::saddle::saddle_pencil;
    use crate::util::rng::Rng;

    #[test]
    fn reduces_random_pencil() {
        let mut rng = Rng::new(110);
        let p = random_pencil(50, &mut rng);
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(50);
        let mut z = Matrix::identity(50);
        reduce(&mut a, &mut b, &mut q, &mut z);
        assert_eq!(max_below_band(&a, 1), 0.0);
        assert!(max_below_band(&b, 0) < 1e-13 * b.norm_fro());
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-12);
    }

    #[test]
    fn handles_singular_b() {
        // Rotations are oblivious to B's conditioning — the paper's point
        // about LAPACK in the saddle-point experiments.
        let mut rng = Rng::new(111);
        let p = saddle_pencil(40, 0.25, &mut rng);
        let (a0, b0) = (p.a.clone(), p.b.clone());
        let (mut a, mut b) = (p.a, p.b);
        let mut q = Matrix::identity(40);
        let mut z = Matrix::identity(40);
        reduce(&mut a, &mut b, &mut q, &mut z);
        assert_eq!(max_below_band(&a, 1), 0.0);
        HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1).assert_ok(1e-12);
    }

    #[test]
    fn small_sizes_noop() {
        let mut a = Matrix::identity(2);
        let mut b = Matrix::identity(2);
        let mut q = Matrix::identity(2);
        let mut z = Matrix::identity(2);
        reduce(&mut a, &mut b, &mut q, &mut z);
        assert_eq!(a, Matrix::identity(2));
    }
}
