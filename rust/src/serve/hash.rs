//! Content hashing for pencils — the fingerprint half of the serving
//! layer's result cache.
//!
//! The cache contract is *bitwise*: two submissions hit the same entry iff
//! their pencil bytes (the `f64` bit patterns of `A` and `B`, in storage
//! order) and their effective tuning (`r`, `p`, `q`, `lookahead`, plus the
//! *resolved* GEMM kernel — the parameters that change the computed
//! factors; `threads` does not, by the per-kernel determinism contract)
//! are identical. `-0.0` and `0.0`, or two different
//! NaN payloads, are therefore *different* keys — exactly the semantics
//! the bitwise-oracle tests pin.
//!
//! The hasher is an FxHash-style multiply-rotate-xor mix (the pure-std
//! cousin of rustc's `FxHasher`), chosen for speed on long `u64` streams.
//! It is **not** collision-free, which is why [`crate::serve::cache`]
//! stores the full key bytes and compares them on lookup: the 64-bit
//! fingerprint only buckets, it never decides a hit on its own.
//!
//! One property *is* guaranteed, and the `tests/serve.rs` property suite
//! leans on it: every mixing step `h' = (rotl₅(h) ^ w) · K` with odd `K`
//! is a bijection in each argument when the other is fixed, so the whole
//! stream hash is a bijection in any *single* input word given the rest.
//! Flipping any single bit of any single element therefore always changes
//! the fingerprint; only multi-word differences can collide.

use crate::config::Config;
use crate::linalg::matrix::Matrix;

/// The FxHash multiplier (the 64-bit golden-ratio-derived odd constant
/// used by rustc's hasher). Odd, so multiplication mod 2⁶⁴ is a bijection.
const K: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Incremental FxHash-style hasher over a stream of `u64` words.
///
/// Pure std, no allocation, deterministic across runs and platforms
/// (always little-endian-free: inputs are whole `u64` words, never raw
/// native-endian byte slices).
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher64 {
    state: u64,
}

impl FxHasher64 {
    /// Fresh hasher (state 0).
    pub fn new() -> Self {
        FxHasher64 { state: 0 }
    }

    /// Mix one word into the state: `h ← (rotl₅(h) ^ w) · K`.
    #[inline]
    pub fn write_u64(&mut self, w: u64) {
        self.state = (self.state.rotate_left(5) ^ w).wrapping_mul(K);
    }

    /// Mix a `usize` (widened to `u64`, so 32- and 64-bit targets agree).
    #[inline]
    pub fn write_usize(&mut self, w: usize) {
        self.write_u64(w as u64);
    }

    /// Mix an `f64` by bit pattern (bitwise semantics: `-0.0 != 0.0`,
    /// NaN payloads distinguish).
    #[inline]
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    /// The current 64-bit fingerprint.
    #[inline]
    pub fn finish(&self) -> u64 {
        self.state
    }
}

/// Size-class routing shared by the in-process [`crate::serve::ShardRouter`]
/// and the multi-process [`crate::serve::supervisor::ShardSupervisor`]: the
/// shard (out of `count`) responsible for problem size `n`. A hash of `n`
/// rather than `n % count`, so arithmetic size progressions spread instead
/// of piling onto one shard; the same `n` always maps to the same shard,
/// which is what keeps that shard's per-`n` workspace warm. Keeping the one
/// definition here means a pencil floods to the *same* size class whether
/// the shard is a thread or a child process.
pub fn size_class_shard(n: usize, count: usize) -> usize {
    let mut h = FxHasher64::new();
    h.write_usize(n);
    (h.finish() % count.max(1) as u64) as usize
}

/// Fingerprint a pencil together with the effective tuning that determines
/// the reduction's output.
///
/// The stream is: a domain tag, the dimensions of both matrices, the
/// result-relevant config fields (`r`, `p`, `q`, `lookahead`, and the
/// *resolved* kernel id — pass the config *after* [`Config::clipped_for`]
/// so the key matches what actually runs), then every element of `A` and
/// `B` by bit pattern in column-major storage order. `threads` and
/// `slices` are deliberately excluded: the determinism contract makes them
/// output-invariant for a fixed kernel, so including them would only split
/// cache entries that are bitwise interchangeable. The kernel *is*
/// included — and at the resolved level, not the request level, so `auto`
/// and an explicit spelling of the same variant share entries while
/// kernels with genuinely different bits (fused vs unfused) never do.
pub fn pencil_fingerprint(a: &Matrix, b: &Matrix, cfg: &Config) -> u64 {
    let mut h = FxHasher64::new();
    h.write_u64(0x70_65_6e_63_69_6c_31_u64); // "pencil1" domain tag
    h.write_usize(a.rows());
    h.write_usize(a.cols());
    h.write_usize(b.rows());
    h.write_usize(b.cols());
    h.write_usize(cfg.r);
    h.write_usize(cfg.p);
    h.write_usize(cfg.q);
    h.write_u64(cfg.lookahead as u64);
    h.write_u64(cfg.resolved_kernel().id());
    for &v in a.data() {
        h.write_f64(v);
    }
    for &v in b.data() {
        h.write_f64(v);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    #[test]
    fn size_class_shard_is_stable_in_range_and_zero_count_safe() {
        for n in [0usize, 2, 16, 23, 400] {
            for count in [1usize, 2, 3, 7] {
                let s = size_class_shard(n, count);
                assert!(s < count);
                assert_eq!(s, size_class_shard(n, count), "same n, same shard");
            }
        }
        // Degenerate count clamps instead of dividing by zero.
        assert_eq!(size_class_shard(10, 0), 0);
    }

    #[test]
    fn fingerprint_is_deterministic_and_clone_invariant() {
        let mut rng = Rng::new(0x5E21);
        let p = random_pencil(12, &mut rng);
        let cfg = Config::default();
        let h1 = pencil_fingerprint(&p.a, &p.b, &cfg);
        let h2 = pencil_fingerprint(&p.a.clone(), &p.b.clone(), &cfg);
        assert_eq!(h1, h2);
    }

    #[test]
    fn fingerprint_distinguishes_config_fields() {
        let mut rng = Rng::new(0x5E22);
        let p = random_pencil(10, &mut rng);
        let base = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let h = pencil_fingerprint(&p.a, &p.b, &base);
        for cfg in [
            Config { r: 5, ..base.clone() },
            Config { p: 3, ..base.clone() },
            Config { q: 3, ..base.clone() },
            Config { lookahead: false, ..base.clone() },
        ] {
            assert_ne!(h, pencil_fingerprint(&p.a, &p.b, &cfg), "{cfg:?}");
        }
        // threads/slices are output-invariant and excluded from the key.
        let t = Config { threads: 7, slices: 3, ..base.clone() };
        assert_eq!(h, pencil_fingerprint(&p.a, &p.b, &t));
    }

    #[test]
    fn fingerprint_keys_on_the_resolved_kernel() {
        use crate::linalg::{Kernel, KernelChoice};
        let mut rng = Rng::new(0x5E24);
        let p = random_pencil(10, &mut rng);
        let base = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let kernels = Kernel::all_available();
        if kernels.len() >= 2 {
            // Two genuinely different kernels must never share a key.
            let ka = Config { kernel: kernels[0].choice(), ..base.clone() };
            let kb = Config { kernel: kernels[1].choice(), ..base.clone() };
            assert_ne!(
                pencil_fingerprint(&p.a, &p.b, &ka),
                pencil_fingerprint(&p.a, &p.b, &kb)
            );
        }
        // Resolved-level keying: a request that clamps (or auto-resolves)
        // to the same kernel as an explicit spelling shares its entry.
        let auto = Config { kernel: KernelChoice::Auto, ..base.clone() };
        let explicit =
            Config { kernel: auto.resolved_kernel().choice(), ..base.clone() };
        assert_eq!(
            pencil_fingerprint(&p.a, &p.b, &auto),
            pencil_fingerprint(&p.a, &p.b, &explicit)
        );
    }

    #[test]
    fn single_word_change_always_changes_the_hash() {
        // The bijectivity argument in the module docs, spot-checked: any
        // single-element change (including sign-of-zero) flips the hash.
        let mut rng = Rng::new(0x5E23);
        let p = random_pencil(8, &mut rng);
        let cfg = Config::default();
        let h = pencil_fingerprint(&p.a, &p.b, &cfg);
        let mut a2 = p.a.clone();
        a2[(3, 4)] = f64::from_bits(a2[(3, 4)].to_bits() ^ 1);
        assert_ne!(h, pencil_fingerprint(&a2, &p.b, &cfg));
        let mut b2 = p.b.clone();
        b2[(7, 7)] = -b2[(7, 7)]; // sign-bit flip
        assert_ne!(h, pencil_fingerprint(&p.a, &b2, &cfg));
        // 0.0 vs -0.0 below the triangle: still a different key.
        let mut b3 = p.b.clone();
        b3[(5, 0)] = -0.0; // was exactly 0.0 (B is upper triangular)
        assert_eq!(p.b[(5, 0)], 0.0);
        assert_ne!(h, pencil_fingerprint(&p.a, &b3, &cfg));
    }
}
