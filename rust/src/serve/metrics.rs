//! Serving-tier latency observability: fixed-bucket histograms with
//! lock-cheap atomic counters, one per pencil size class.
//!
//! The look-ahead literature (Rodríguez-Sánchez et al., 1709.00302) makes
//! the point that *saturation* behaviour — what the tail looks like when
//! every lane is busy — is the metric that matters for a serving tier, not
//! single-job latency. This module makes that measurable: every completed
//! ticket records its submit→completion time into a [`LatencyHistogram`]
//! selected by the job's [`SizeClass`], and snapshots report p50/p90/p99
//! next to the cache hit/miss counters.
//!
//! **Design.** Buckets are fixed at construction (powers of two in
//! microseconds, [`BUCKETS`] of them), so recording is one atomic
//! increment on a precomputed index — no locks, no allocation, no
//! contention beyond cache-line sharing on hot buckets. Quantiles are
//! computed at *snapshot* time by walking the cumulative distribution and
//! reporting the upper edge of the bucket where the target rank lands —
//! an upper bound with relative error ≤ 2× (one bucket), which is the
//! right trade for a histogram that must be recordable from every
//! dispatcher thread at once.
//!
//! Everything here is pure std and shared by value inside `Arc`s: the
//! submission queue owns one [`ServeMetrics`] and records at ticket
//! completion; the network front door ([`crate::serve::net`]) exports the
//! same snapshots through the protocol's `Stats` request; the CLI and the
//! `serve_net` bench print them.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of histogram buckets. Bucket `i` counts latencies in
/// `[2^i, 2^(i+1))` microseconds; bucket 0 also absorbs sub-microsecond
/// samples and the last bucket absorbs everything above `2^BUCKETS` µs
/// (~1.2 hours — far past any sane reduction).
pub const BUCKETS: usize = 32;

/// Fixed-bucket latency histogram with atomic counters.
///
/// `record` is wait-free (one relaxed `fetch_add` per counter); `snapshot`
/// reads every bucket without stopping writers, so a snapshot taken under
/// load is a consistent-enough view (individual counters are exact, the
/// set is racy by at most the samples recorded mid-walk — fine for
/// percentile reporting, documented here so nobody "fixes" it with a
/// lock).
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let micros = d.as_micros().min(u128::from(u64::MAX)) as u64;
        self.buckets[bucket_of(micros)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(micros, Ordering::Relaxed);
    }

    /// Copy the counters out (see the type docs for the consistency
    /// contract under concurrent writers).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum_micros: self.sum_micros.load(Ordering::Relaxed),
        }
    }
}

/// Bucket index for a sample of `micros` microseconds: `floor(log2)`,
/// clamped into the fixed bucket range.
fn bucket_of(micros: u64) -> usize {
    if micros == 0 {
        0
    } else {
        (63 - micros.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// Plain-value copy of a [`LatencyHistogram`] at one instant; quantiles
/// are computed here, off the hot path.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Per-bucket sample counts (bucket `i` = `[2^i, 2^(i+1))` µs).
    pub buckets: [u64; BUCKETS],
    /// Total samples recorded.
    pub count: u64,
    /// Sum of all samples in microseconds (for the mean).
    pub sum_micros: u64,
}

impl HistogramSnapshot {
    /// Upper-bound estimate of the `q`-quantile in milliseconds (`q` in
    /// `[0, 1]`): the upper edge of the bucket where the target rank
    /// lands. Returns 0 for an empty histogram.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // Rank of the target sample, 1-based, clamped into [1, count].
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Upper edge of bucket i is 2^(i+1) µs.
                return (1u64 << (i + 1)) as f64 / 1_000.0;
            }
        }
        // Unreachable when the counters are consistent; racy snapshots can
        // leave count ahead of the bucket sum — report the top edge.
        (1u64 << BUCKETS) as f64 / 1_000.0
    }

    /// Median latency upper bound in milliseconds.
    pub fn p50_ms(&self) -> f64 {
        self.quantile_ms(0.50)
    }

    /// 90th-percentile latency upper bound in milliseconds.
    pub fn p90_ms(&self) -> f64 {
        self.quantile_ms(0.90)
    }

    /// 99th-percentile latency upper bound in milliseconds (the tail the
    /// admission-control deadline is tuned against).
    pub fn p99_ms(&self) -> f64 {
        self.quantile_ms(0.99)
    }

    /// Mean latency in milliseconds (exact, unlike the quantiles: the sum
    /// is tracked directly).
    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_micros as f64 / self.count as f64 / 1_000.0
        }
    }
}

/// Pencil size classes for latency accounting. Boundaries are fixed (not
/// config-dependent) so that dashboards and bench artifacts are comparable
/// across serving geometries: latency scales with `n³` work, so mixing a
/// `n = 16` flood into a `n = 512` histogram would bury the tail the
/// histogram exists to show.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SizeClass {
    /// `n < 32` — band-clip territory, sub-millisecond reductions.
    Tiny,
    /// `32 <= n < 128`.
    Small,
    /// `128 <= n < 512` — the paper's figure range.
    Medium,
    /// `n >= 512`.
    Large,
}

impl SizeClass {
    /// All classes, in ascending size order (stable across releases — the
    /// `BENCH_serve_net.json` schema and the `Stats` reply index by it).
    pub const ALL: [SizeClass; 4] =
        [SizeClass::Tiny, SizeClass::Small, SizeClass::Medium, SizeClass::Large];

    /// The class a problem size `n` falls into.
    pub fn of(n: usize) -> SizeClass {
        match n {
            0..=31 => SizeClass::Tiny,
            32..=127 => SizeClass::Small,
            128..=511 => SizeClass::Medium,
            _ => SizeClass::Large,
        }
    }

    /// Stable lowercase label (JSON keys, table rows).
    pub fn label(self) -> &'static str {
        match self {
            SizeClass::Tiny => "tiny",
            SizeClass::Small => "small",
            SizeClass::Medium => "medium",
            SizeClass::Large => "large",
        }
    }

    fn index(self) -> usize {
        match self {
            SizeClass::Tiny => 0,
            SizeClass::Small => 1,
            SizeClass::Medium => 2,
            SizeClass::Large => 3,
        }
    }
}

/// One latency histogram per size class — the serving tier's shared
/// observability block. Lives in an `Arc` next to the submission queue's
/// counters; recording picks the class from the job's `n`.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    per_class: [LatencyHistogram; 4],
}

impl ServeMetrics {
    /// Empty metrics block.
    pub fn new() -> ServeMetrics {
        ServeMetrics::default()
    }

    /// Record one completed job: size `n`, submit→completion latency `d`.
    pub fn record(&self, n: usize, d: Duration) {
        self.per_class[SizeClass::of(n).index()].record(d);
    }

    /// Snapshot every class (including empty ones — consumers filter).
    pub fn snapshot(&self) -> Vec<(SizeClass, HistogramSnapshot)> {
        SizeClass::ALL
            .iter()
            .map(|&c| (c, self.per_class[c.index()].snapshot()))
            .collect()
    }

    /// Render the non-empty classes as a JSON object fragment
    /// (`{"tiny": {"count": …, "p50_ms": …, …}, …}`) — the shape exported
    /// through the protocol's `Stats` reply and printed by the CLI.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("{");
        let mut first = true;
        for (class, snap) in self.snapshot() {
            if snap.count == 0 {
                continue;
            }
            if !first {
                out.push_str(", ");
            }
            first = false;
            let _ = write!(
                out,
                "\"{}\": {{\"count\": {}, \"mean_ms\": {:.3}, \"p50_ms\": {:.3}, \
                 \"p90_ms\": {:.3}, \"p99_ms\": {:.3}}}",
                class.label(),
                snap.count,
                snap.mean_ms(),
                snap.p50_ms(),
                snap.p90_ms(),
                snap.p99_ms()
            );
        }
        out.push('}');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_log2() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(1023), 9);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1, "huge samples clamp to the top bucket");
    }

    #[test]
    fn quantiles_walk_the_cumulative_distribution() {
        let h = LatencyHistogram::new();
        // 90 samples at ~1 ms (bucket 9: [512, 1024) µs), 10 at ~100 ms
        // (bucket 16: [65536, 131072) µs).
        for _ in 0..90 {
            h.record(Duration::from_micros(600));
        }
        for _ in 0..10 {
            h.record(Duration::from_micros(100_000));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        // p50 and p90 land in the 1 ms bucket: upper edge 1024 µs.
        assert_eq!(s.p50_ms(), 1.024);
        assert_eq!(s.p90_ms(), 1.024);
        // p99 lands in the 100 ms bucket: upper edge 131072 µs.
        assert_eq!(s.p99_ms(), 131.072);
        assert!((s.mean_ms() - 10.54).abs() < 0.01, "mean is exact: {}", s.mean_ms());
    }

    #[test]
    fn empty_histogram_reports_zero() {
        let s = LatencyHistogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50_ms(), 0.0);
        assert_eq!(s.p99_ms(), 0.0);
        assert_eq!(s.mean_ms(), 0.0);
    }

    #[test]
    fn size_classes_have_fixed_boundaries() {
        assert_eq!(SizeClass::of(0), SizeClass::Tiny);
        assert_eq!(SizeClass::of(31), SizeClass::Tiny);
        assert_eq!(SizeClass::of(32), SizeClass::Small);
        assert_eq!(SizeClass::of(127), SizeClass::Small);
        assert_eq!(SizeClass::of(128), SizeClass::Medium);
        assert_eq!(SizeClass::of(511), SizeClass::Medium);
        assert_eq!(SizeClass::of(512), SizeClass::Large);
        for c in SizeClass::ALL {
            assert!(!c.label().is_empty());
        }
    }

    #[test]
    fn metrics_route_by_size_class_and_render_json() {
        let m = ServeMetrics::new();
        m.record(16, Duration::from_micros(300));
        m.record(16, Duration::from_micros(400));
        m.record(200, Duration::from_millis(50));
        let snap = m.snapshot();
        assert_eq!(snap.len(), 4, "every class is reported");
        assert_eq!(snap[0].1.count, 2, "tiny got both small-n samples");
        assert_eq!(snap[2].1.count, 1, "medium got the n=200 sample");
        assert_eq!(snap[1].1.count, 0);
        let json = m.to_json();
        assert!(json.contains("\"tiny\""), "{json}");
        assert!(json.contains("\"medium\""), "{json}");
        assert!(!json.contains("\"small\""), "empty classes are omitted: {json}");
    }
}
