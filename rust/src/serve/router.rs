//! The shard router: N long-lived [`HtSession`]s behind one front door.
//!
//! [`HtSession`] caches one per-`n` workspace at a time (panel plans,
//! sweep groups, reflector arenas); a mixed-size request stream through a
//! *single* session would rebuild that workspace on every size change.
//! The router replicates the session N ways and routes every request by
//! its **size class** (a hash of `n`), so each shard sees a stable slice
//! of the size distribution and its cached workspace stays hot. Shards
//! share the process-global worker pool — `threads_per_shard` (the
//! paper's `M` in "N sessions × M threads") sets how many pool executors
//! one shard's reduction uses.
//!
//! A shared [`ResultCache`] sits in front of the shards: bitwise-repeat
//! submissions are answered without touching a session (see
//! [`crate::serve::cache`] for why that is sound, not merely probable).
//!
//! The router is synchronous and `Sync` — each shard is a `Mutex`, so
//! concurrent callers (e.g. the per-shard dispatcher threads of
//! [`crate::serve::SubmitQueue`]) proceed in parallel as long as they
//! target different shards.

use crate::api::HtSession;
use crate::config::Config;
use crate::error::{Error, Result};
use crate::ht::two_stage::HtDecomposition;
use crate::linalg::matrix::Matrix;
use crate::serve::cache::{CacheKey, CacheStats, ResultCache};
use crate::tune::profile::{ProfileHandle, TunedProfile};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Lock a serving-tier mutex, recovering from poisoning instead of
/// propagating it. A panic inside one reduction must cost exactly that
/// job, not the shard: sessions are safe to reuse after an unwind (the
/// working factors are locals that unwound with the panic, and the
/// per-`n` arenas are `reset()` at the start of every graph run), and the
/// cache has no panic point between its accounting updates — so the
/// poison flag carries no information here, and honoring it would turn
/// one bad pencil into a permanently dead shard (every later
/// `lock().unwrap()` re-panicking behind the queue's `catch_unwind`).
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Serving-layer configuration: shard/queue/cache geometry around a base
/// reduction [`Config`]. Defaults are modest (2 shards × 1 thread, a
/// 64-entry / 256 MiB cache, 256-deep queues); [`ServeConfig::from_env`]
/// applies the `PALLAS_SERVE_*` knobs on top.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Number of router shards (`N` — one `HtSession` plus one dispatcher
    /// thread each).
    pub shards: usize,
    /// Worker-pool executors per shard reduction (`M`; `1` runs the
    /// sequential oracle per job, which is the right shape for floods of
    /// small pencils).
    pub threads_per_shard: usize,
    /// Per-shard submission-queue depth; submitters block (backpressure)
    /// when their shard's queue is full.
    pub queue_capacity: usize,
    /// Result-cache entry bound (`0` disables caching entirely).
    pub cache_entries: usize,
    /// Result-cache byte bound (keys + stored factors).
    pub cache_bytes: usize,
    /// Clip the stage-1 band to each pencil's size
    /// ([`Config::clipped_for`]) instead of rejecting `r >= n` — on by
    /// default: a serving tier sees arbitrary sizes and should not bounce
    /// small pencils off the paper tuning.
    pub clip_band: bool,
    /// Admission-control deadline in milliseconds for front-door
    /// submissions ([`crate::serve::queue::SubmitHandle::submit_timeout`]):
    /// how long the network tier waits for lane capacity before shedding
    /// with a typed `Overloaded` reply. `0` sheds immediately on a full
    /// lane. Direct in-process `submit` calls are unaffected (they keep
    /// the blocking-backpressure semantics).
    pub admit_timeout_ms: u64,
    /// Base reduction tuning for every shard (`threads` is overridden by
    /// `threads_per_shard`).
    pub base: Config,
    /// Tuned per-size-class profile ([`crate::tune`]), installed into
    /// every shard at startup; `None` serves the untuned base everywhere.
    /// [`ServeConfig::from_env`] loads it from the `PALLAS_PROFILE` path
    /// knob, warning and falling back to `None` on any load failure —
    /// a corrupt profile degrades the tier to untuned, never down.
    pub profile: Option<TunedProfile>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            shards: 2,
            threads_per_shard: 1,
            queue_capacity: 256,
            cache_entries: 64,
            cache_bytes: 256 << 20,
            clip_band: true,
            admit_timeout_ms: 1000,
            base: Config::default(),
            profile: None,
        }
    }
}

impl ServeConfig {
    /// Defaults overridden by the `PALLAS_SERVE_*` environment knobs
    /// (parsed centrally in [`crate::util::env`]).
    pub fn from_env() -> ServeConfig {
        let d = ServeConfig::default();
        ServeConfig {
            shards: crate::util::env::serve_shards(d.shards),
            threads_per_shard: crate::util::env::serve_threads(d.threads_per_shard),
            queue_capacity: crate::util::env::serve_queue_cap(d.queue_capacity),
            cache_entries: crate::util::env::serve_cache_entries(d.cache_entries),
            cache_bytes: crate::util::env::serve_cache_bytes(d.cache_bytes),
            admit_timeout_ms: crate::util::env::admit_timeout_ms(d.admit_timeout_ms),
            profile: crate::util::env::profile()
                .as_deref()
                .and_then(TunedProfile::load_or_warn),
            ..d
        }
    }

    /// Validate the serving geometry plus the base tuning (the same typed
    /// [`Error::Config`] surface as the session builder).
    pub fn validate(&self) -> Result<()> {
        if self.shards < 1 {
            return Err(Error::config("serve: shards must be >= 1"));
        }
        if self.shards > 1024 {
            return Err(Error::config(format!(
                "serve: shards = {} exceeds the shard budget (1024)",
                self.shards
            )));
        }
        if self.queue_capacity < 1 {
            return Err(Error::config("serve: queue_capacity must be >= 1"));
        }
        let session_cfg = Config { threads: self.threads_per_shard, ..self.base.clone() };
        session_cfg.validate()?;
        if let Some(profile) = &self.profile {
            profile.validate()?;
        }
        Ok(())
    }
}

/// Router-level counters (cache counters live in [`CacheStats`]).
#[derive(Clone, Debug, Default)]
pub struct RouterStats {
    /// Reductions actually executed per shard (cache hits never reach a
    /// shard and are not counted here).
    pub reduced_per_shard: Vec<u64>,
    /// Cache counters, when a cache is configured.
    pub cache: Option<CacheStats>,
}

impl RouterStats {
    /// Total reductions executed across all shards.
    pub fn reduced_total(&self) -> u64 {
        self.reduced_per_shard.iter().sum()
    }
}

/// N sharded sessions + shared result cache (see the [module docs](self)).
pub struct ShardRouter {
    cfg: ServeConfig,
    shards: Vec<Mutex<HtSession>>,
    reduced: Vec<AtomicU64>,
    cache: Option<Mutex<ResultCache>>,
    /// The profile slot shared with every shard session;
    /// [`ShardRouter::reload_profile`] swaps it mid-traffic.
    profile: ProfileHandle,
}

impl std::fmt::Debug for ShardRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardRouter")
            .field("shards", &self.shards.len())
            .field("threads_per_shard", &self.cfg.threads_per_shard)
            .field("cache", &self.cache.as_ref().map(|c| lock_recover(c).stats()))
            .finish_non_exhaustive()
    }
}

impl ShardRouter {
    /// Build the router: validates the config once and constructs one
    /// session per shard (resolving the shared worker pool when
    /// `threads_per_shard > 1`, exactly like a hand-built session).
    pub fn new(cfg: ServeConfig) -> Result<ShardRouter> {
        cfg.validate()?;
        let session_cfg = Config { threads: cfg.threads_per_shard, ..cfg.base.clone() };
        // One shared profile slot for the router and all of its sessions:
        // a single reload retunes every shard.
        let profile = ProfileHandle::new();
        if let Some(p) = &cfg.profile {
            profile.install(p.clone());
        }
        let mut shards = Vec::with_capacity(cfg.shards);
        for _ in 0..cfg.shards {
            let session = HtSession::builder()
                .config(session_cfg.clone())
                .clip_band(cfg.clip_band)
                .profile_handle(profile.clone())
                .build()?;
            shards.push(Mutex::new(session));
        }
        let reduced = (0..cfg.shards).map(|_| AtomicU64::new(0)).collect();
        let cache = if cfg.cache_entries > 0 {
            Some(Mutex::new(ResultCache::new(cfg.cache_entries, cfg.cache_bytes)))
        } else {
            None
        };
        Ok(ShardRouter { cfg, shards, reduced, cache, profile })
    }

    /// Swap the tuned profile under every shard, mid-traffic (`None`
    /// reverts to the untuned base). In-flight reductions finish under
    /// whichever profile they resolved at entry; cache soundness is
    /// unaffected because inserts are keyed on the config each job
    /// *actually ran with* (see [`ShardRouter::reduce_on`]). The new
    /// profile must validate — reloading never degrades a healthy tier
    /// into one serving invalid geometry.
    pub fn reload_profile(&self, profile: Option<TunedProfile>) -> Result<()> {
        if let Some(p) = &profile {
            p.validate()?;
        }
        self.profile.set(profile);
        Ok(())
    }

    /// The validated serving configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Size-class routing: the shard responsible for problem size `n` —
    /// the shared [`crate::serve::hash::size_class_shard`] rule, so the
    /// multi-process supervisor routes a given `n` to the same size class
    /// this in-process router would.
    pub fn shard_for(&self, n: usize) -> usize {
        crate::serve::hash::size_class_shard(n, self.shards.len())
    }

    /// Reduce one pencil through the serving path: shape check → cache
    /// lookup → size-class shard → session reduce → cache fill. The
    /// result is bitwise identical to [`crate::api::reduce_seq`] under the
    /// same effective config, whether it came from a shard or the cache.
    pub fn reduce(&self, a: &Matrix, b: &Matrix) -> Result<Arc<HtDecomposition>> {
        check_square_pencil(a, b)?;
        self.reduce_on(self.shard_for(a.rows()), a, b)
    }

    /// Reduce on an explicit shard — the entry the per-shard dispatcher
    /// threads use (they already routed at submit time). Still consults
    /// the shared cache first.
    pub(crate) fn reduce_on(
        &self,
        shard: usize,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<Arc<HtDecomposition>> {
        check_square_pencil(a, b)?;
        let n = a.rows();
        let Some(cache) = &self.cache else {
            return Ok(Arc::new(self.run_on_shard(shard, a, b)?.0));
        };
        // Key with the *effective* tuning — profile overlay then band clip
        // — so the key describes the reduction that actually runs; tuned
        // geometry differing across size classes therefore can never
        // alias. `threads` is excluded from the key (determinism
        // contract), so every shard shares entries. The hit path is
        // allocation-free (`ResultCache::lookup` compares stored bits
        // against the borrowed pencil); the owned key is only built on a
        // miss, for the insert.
        let eff = self.effective_for(n);
        if let Some(hit) = lock_recover(cache).lookup(a, b, &eff) {
            return Ok(hit);
        }
        // The lock is *not* held while reducing: two racing misses on the
        // same pencil compute bitwise-identical results and the second
        // insert degrades to an LRU refresh. The insert is keyed on the
        // config the session says it *ran* — not on `eff` — so a profile
        // reload racing between the lookup above and the reduce below can
        // only cost a spurious miss, never a mislabeled cache entry.
        let (d, ran) = self.run_on_shard(shard, a, b)?;
        let d = Arc::new(d);
        lock_recover(cache).insert(CacheKey::new(a, b, &ran), d.clone());
        Ok(d)
    }

    /// The effective config the router *expects* size `n` to run with
    /// right now: the current profile's class overlaid on the base, then
    /// the band clip — the same pipeline a shard session applies. Used
    /// for cache lookups only; inserts use the config a job actually ran
    /// with (see [`ShardRouter::reduce_on`]).
    fn effective_for(&self, n: usize) -> Config {
        let base = match self.profile.snapshot() {
            Some(p) => p.apply(&self.cfg.base, n),
            None => self.cfg.base.clone(),
        };
        if self.cfg.clip_band { base.clipped_for(n) } else { base }
    }

    /// Run the reduction on one shard's session, counting it. Returns the
    /// decomposition together with the effective config the session
    /// resolved for this job (the truthful cache key under profile
    /// hot-swaps).
    fn run_on_shard(
        &self,
        shard: usize,
        a: &Matrix,
        b: &Matrix,
    ) -> Result<(HtDecomposition, Config)> {
        self.reduced[shard].fetch_add(1, Ordering::Relaxed);
        let mut session = lock_recover(&self.shards[shard]);
        let result = session.reduce_tracked(a, b);
        // A serving shard runs unboundedly many reductions: the session's
        // per-call phase log must not grow with traffic (the router's own
        // counters are the serving-tier telemetry).
        session.clear_phases();
        result
    }

    /// Counter snapshot (per-shard executed reductions + cache counters).
    pub fn stats(&self) -> RouterStats {
        RouterStats {
            reduced_per_shard: self.reduced.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            cache: self.cache_stats(),
        }
    }

    /// Atomic cache-counter snapshot, taken in one critical section under
    /// the cache lock ([`crate::serve::cache::ResultCache::snapshot`]) —
    /// the printer-facing accessor, so hits/misses/entries/bytes in one
    /// report always describe the same instant. `None` when caching is
    /// disabled.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| lock_recover(c).snapshot())
    }
}

/// Typed shape check shared by the router and the submission queue: a
/// serving request must be a square, consistent pencil.
pub(crate) fn check_square_pencil(a: &Matrix, b: &Matrix) -> Result<()> {
    let n = a.rows();
    if a.cols() != n || b.rows() != n || b.cols() != n {
        return Err(Error::shape(format!(
            "serve: pencil must be square and consistent: A {}x{}, B {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::reduce_seq;
    use crate::pencil::random::random_pencil;
    use crate::util::proptest::max_abs_diff;
    use crate::util::rng::Rng;

    fn small_serve_cfg() -> ServeConfig {
        ServeConfig {
            shards: 3,
            base: Config { r: 4, p: 2, q: 2, ..Config::default() },
            ..ServeConfig::default()
        }
    }

    #[test]
    fn rejects_bad_geometry_and_bad_base() {
        let cfg = ServeConfig { shards: 0, ..ServeConfig::default() };
        assert!(matches!(ShardRouter::new(cfg).unwrap_err(), Error::Config(_)));
        let cfg = ServeConfig { queue_capacity: 0, ..ServeConfig::default() };
        assert!(matches!(ShardRouter::new(cfg).unwrap_err(), Error::Config(_)));
        let cfg = ServeConfig {
            base: Config { p: 1, ..Config::default() },
            ..ServeConfig::default()
        };
        assert!(matches!(ShardRouter::new(cfg).unwrap_err(), Error::Config(_)));
        let cfg = ServeConfig { threads_per_shard: 0, ..ServeConfig::default() };
        assert!(matches!(ShardRouter::new(cfg).unwrap_err(), Error::Config(_)));
    }

    #[test]
    fn routing_is_stable_and_in_range() {
        let r = ShardRouter::new(small_serve_cfg()).unwrap();
        for n in [2usize, 6, 10, 23, 40, 64] {
            let s = r.shard_for(n);
            assert!(s < r.shard_count());
            assert_eq!(s, r.shard_for(n), "same n must always route to the same shard");
        }
    }

    #[test]
    fn routed_reduce_is_bitwise_the_oracle() {
        let mut rng = Rng::new(0x50_01);
        let r = ShardRouter::new(small_serve_cfg()).unwrap();
        for &n in &[2usize, 6, 10, 23, 40] {
            let p = random_pencil(n, &mut rng);
            let d = r.reduce(&p.a, &p.b).unwrap();
            let eff = r.config().base.clipped_for(n);
            let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
            assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "n={n}: H");
            assert_eq!(max_abs_diff(&d.t, &oracle.t), 0.0, "n={n}: T");
            assert_eq!(max_abs_diff(&d.q, &oracle.q), 0.0, "n={n}: Q");
            assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "n={n}: Z");
        }
        let stats = r.stats();
        assert_eq!(stats.reduced_total(), 5);
    }

    #[test]
    fn repeat_submission_hits_the_cache() {
        let mut rng = Rng::new(0x50_02);
        let p = random_pencil(12, &mut rng);
        let r = ShardRouter::new(small_serve_cfg()).unwrap();
        let d1 = r.reduce(&p.a, &p.b).unwrap();
        let d2 = r.reduce(&p.a, &p.b).unwrap();
        assert!(Arc::ptr_eq(&d1, &d2), "second submission must be served from the cache");
        let stats = r.stats();
        assert_eq!(stats.reduced_total(), 1, "only one reduction actually ran");
        let cache = stats.cache.expect("cache is on by default");
        assert_eq!((cache.hits, cache.misses), (1, 1));
    }

    #[test]
    fn cache_disabled_reduces_every_time() {
        let mut rng = Rng::new(0x50_03);
        let p = random_pencil(10, &mut rng);
        let cfg = ServeConfig { cache_entries: 0, ..small_serve_cfg() };
        let r = ShardRouter::new(cfg).unwrap();
        let d1 = r.reduce(&p.a, &p.b).unwrap();
        let d2 = r.reduce(&p.a, &p.b).unwrap();
        assert_eq!(max_abs_diff(&d1.h, &d2.h), 0.0, "recomputation is still bitwise");
        assert_eq!(r.stats().reduced_total(), 2);
        assert!(r.stats().cache.is_none());
    }

    #[test]
    fn poisoned_shard_lock_recovers_and_keeps_serving() {
        let mut rng = Rng::new(0x50_04);
        let p = random_pencil(10, &mut rng);
        let r = ShardRouter::new(small_serve_cfg()).unwrap();
        let shard = r.shard_for(10);
        // Poison the shard mutex the way a panicking reduction would.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = r.shards[shard].lock().unwrap();
            panic!("simulated job panic while holding the shard lock");
        }));
        assert!(r.shards[shard].is_poisoned());
        // One bad job must cost that job only — the shard keeps serving,
        // and correctly.
        let d = r.reduce(&p.a, &p.b).unwrap();
        let oracle = reduce_seq(&p.a, &p.b, &r.config().base.clipped_for(10)).unwrap();
        assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "post-poison result is still bitwise");
    }

    #[test]
    fn shape_errors_are_typed_and_early() {
        let r = ShardRouter::new(small_serve_cfg()).unwrap();
        let a = Matrix::zeros(4, 5);
        let b = Matrix::zeros(4, 4);
        assert!(matches!(r.reduce(&a, &b).unwrap_err(), Error::Shape(_)));
        assert_eq!(r.stats().reduced_total(), 0, "nothing ran");
    }

    fn one_class(n_min: usize, r: usize, p: usize, q: usize) -> crate::tune::ClassProfile {
        crate::tune::ClassProfile {
            n_min,
            n_max: 0,
            r,
            p,
            q,
            slices: 0,
            threads: 0,
            predicted_makespan: 0.0,
            default_makespan: 0.0,
            trace_n: n_min,
        }
    }

    #[test]
    fn profiled_router_serves_bitwise_under_the_tuned_config() {
        let mut rng = Rng::new(0x50_05);
        let profile = TunedProfile { classes: vec![one_class(17, 8, 4, 4)] };
        let cfg = ServeConfig { profile: Some(profile.clone()), ..small_serve_cfg() };
        let r = ShardRouter::new(cfg).unwrap();
        for &n in &[10usize, 17, 40] {
            let p = random_pencil(n, &mut rng);
            let d = r.reduce(&p.a, &p.b).unwrap();
            // Oracle under the same overlay-then-clip pipeline.
            let eff = profile.apply(&r.config().base, n).clipped_for(n);
            let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
            assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "n={n}: H");
            assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "n={n}: Z");
        }
    }

    #[test]
    fn rejects_invalid_profile_at_build_and_reload() {
        // A class whose band cannot fit its own floor is a typed config
        // error, both at construction and on hot reload.
        let bad = TunedProfile { classes: vec![one_class(5, 8, 2, 2)] };
        let cfg = ServeConfig { profile: Some(bad.clone()), ..small_serve_cfg() };
        assert!(matches!(ShardRouter::new(cfg).unwrap_err(), Error::Config(_)));
        let r = ShardRouter::new(small_serve_cfg()).unwrap();
        assert!(matches!(r.reload_profile(Some(bad)).unwrap_err(), Error::Config(_)));
        // The failed reload left the tier serving (untuned).
        let mut rng = Rng::new(0x50_06);
        let p = random_pencil(12, &mut rng);
        assert!(r.reduce(&p.a, &p.b).is_ok());
    }

    #[test]
    fn reload_retunes_and_cache_stays_sound_across_geometries() {
        let mut rng = Rng::new(0x50_07);
        let p = random_pencil(24, &mut rng);
        let r = ShardRouter::new(small_serve_cfg()).unwrap();
        let base = r.config().base.clone();
        let untuned = r.reduce(&p.a, &p.b).unwrap();
        // Install a profile that changes the geometry for n=24: the same
        // pencil must now miss the cache (different effective config) and
        // come back bitwise under the *tuned* oracle.
        let profile = TunedProfile { classes: vec![one_class(9, 8, 4, 4)] };
        r.reload_profile(Some(profile.clone())).unwrap();
        let tuned = r.reduce(&p.a, &p.b).unwrap();
        let tuned_oracle =
            reduce_seq(&p.a, &p.b, &profile.apply(&base, 24).clipped_for(24)).unwrap();
        assert_eq!(max_abs_diff(&tuned.h, &tuned_oracle.h), 0.0, "tuned H");
        assert_eq!(r.stats().reduced_total(), 2, "tuned geometry cannot reuse untuned entries");
        // Reverting reuses the original entry: same key, same bits.
        r.reload_profile(None).unwrap();
        let again = r.reduce(&p.a, &p.b).unwrap();
        assert!(Arc::ptr_eq(&untuned, &again), "untuned entry survived the tuned interlude");
        assert_eq!(r.stats().reduced_total(), 2);
    }
}
