//! Supervised multi-process serving: each size-class shard is a child
//! process, restarted on crash with capped exponential backoff.
//!
//! The in-process tier ([`crate::serve::router`]) contains a panicking job
//! with `catch_unwind` and mutex poison-recovery — but a segfault, an
//! abort, or an OOM kill still takes the whole server with it. This module
//! is the stronger isolation boundary: the [`ShardSupervisor`] runs one
//! `--shard-worker` child per size class (`std::process::Command`
//! re-invoking the serving binary), speaks the same frame protocol
//! ([`crate::serve::proto`]) over the child's stdin/stdout pipes, and when
//! a child dies it fails only that child's in-flight job — with a typed
//! [`Error::ShardDown`] — then respawns it lazily with capped exponential
//! backoff. Reductions are pure, so resubmitting a `ShardDown` job is
//! always safe.
//!
//! **Supervisor state machine** (per shard): `Up` — child alive, jobs
//! flow; `Dying` — an I/O error or EOF on the pipes marks the child dead,
//! the in-flight job fails with `ShardDown`, the child is reaped;
//! `Backoff` — subsequent submissions wait out
//! `min(backoff_initial << (consecutive_deaths - 1), backoff_max)` before
//! respawning; `Respawn` — a fresh child is spawned on the next job, and
//! its first completed job resets the consecutive-death counter. There is
//! no respawn thread: restart work rides on the next submission (lazy),
//! so an idle dead shard costs nothing.
//!
//! **Determinism across the process boundary.** The supervisor always
//! sends the *explicit effective* tuning (band-clipped for each pencil's
//! `n`, exactly like the in-process router), never the wire sentinel, so
//! a worker needs no configuration of its own and computes bitwise what
//! [`crate::api::reduce_seq`] computes under that effective config —
//! `tests/serve_proc.rs` pins this end to end. Workers inherit the parent
//! environment, so kernel selection (`PALLAS_KERNEL`) resolves
//! identically on both sides of the pipe; [`SupervisorConfig::validate`]
//! rejects a base config with an explicit non-default kernel override,
//! which (unlike the env knob) does not cross the process boundary.
//!
//! **Persistence** (peal's supervise-and-persist idiom): when
//! [`SupervisorConfig::summary_dir`] is set, each shard's lifetime
//! counters are written to `shard-<i>.run_summary.json` on every spawn,
//! death and shutdown — a crash post-mortem that survives the process.
//!
//! **Locking.** Each shard has two locks, ordered `io → life`:
//! `io` (the pipe pair) is held for a job's full write→read round trip —
//! one job at a time per shard, the same serialization the in-process
//! dispatcher gives — while `life` (child handle + counters) is only ever
//! held briefly. The chaos hook [`ShardSupervisor::kill_shard`] takes
//! `life` alone and kills without reaping, so it can fire mid-job
//! without deadlocking against the in-flight round trip; the job then
//! discovers the death as an I/O error/EOF and runs the `Dying` path.

use crate::config::Config;
use crate::error::{Error, Result};
use crate::ht::two_stage::HtDecomposition;
use crate::linalg::kernels::KernelChoice;
use crate::linalg::matrix::Matrix;
use crate::serve::hash::size_class_shard;
use crate::serve::proto::{read_frame, write_frame, Frame, WireConfig};
use crate::serve::router::check_square_pencil;
use std::io::{BufReader, BufWriter, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Serving-tier poison recovery (same rationale as the router's): a panic
/// between supervisor bookkeeping steps must cost that job, not wedge the
/// shard forever.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Configuration of the multi-process serving mode.
#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    /// Number of shard child processes (`PALLAS_SHARD_PROCS`; each is a
    /// full OS process, so the budget is `[1, 64]`).
    pub procs: usize,
    /// Worker-pool executors inside each child (exported to the child as
    /// `PALLAS_SERVE_THREADS`).
    pub threads_per_proc: usize,
    /// Base reduction tuning; band-clipped per pencil when `clip_band` is
    /// set, then sent explicitly with every job.
    pub base: Config,
    /// Clip the stage-1 band per pencil size ([`Config::clipped_for`]) —
    /// on by default, mirroring the in-process router.
    pub clip_band: bool,
    /// Worker command line. Empty (the default) means "re-invoke
    /// `current_exe()` with `--shard-worker`" — correct for the `paraht`
    /// binary; test/bench binaries override it with their own argv so the
    /// supervisor never accidentally re-invokes a test harness that
    /// doesn't speak the protocol.
    pub worker_argv: Vec<String>,
    /// Where to persist per-shard `shard-<i>.run_summary.json` files
    /// (`None` disables persistence).
    pub summary_dir: Option<PathBuf>,
    /// First-death respawn delay in milliseconds (doubles per consecutive
    /// death).
    pub backoff_initial_ms: u64,
    /// Backoff ceiling in milliseconds.
    pub backoff_max_ms: u64,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            procs: 2,
            threads_per_proc: 1,
            base: Config::default(),
            clip_band: true,
            worker_argv: Vec::new(),
            summary_dir: None,
            backoff_initial_ms: 25,
            backoff_max_ms: 2000,
        }
    }
}

impl SupervisorConfig {
    /// Defaults overridden by the environment knobs (`PALLAS_SHARD_PROCS`,
    /// `PALLAS_SERVE_THREADS`).
    pub fn from_env() -> SupervisorConfig {
        let d = SupervisorConfig::default();
        SupervisorConfig {
            procs: crate::util::env::shard_procs(d.procs),
            threads_per_proc: crate::util::env::serve_threads(d.threads_per_proc),
            ..d
        }
    }

    /// Validate geometry and base tuning (typed [`Error::Config`]).
    pub fn validate(&self) -> Result<()> {
        if self.procs < 1 || self.procs > 64 {
            return Err(Error::config(format!(
                "supervisor: procs = {} outside the child-process budget [1, 64]",
                self.procs
            )));
        }
        if self.backoff_initial_ms == 0 || self.backoff_max_ms < self.backoff_initial_ms {
            return Err(Error::config(format!(
                "supervisor: backoff window [{}, {}] ms must be non-empty with a positive floor",
                self.backoff_initial_ms, self.backoff_max_ms
            )));
        }
        if self.base.kernel != KernelChoice::Auto {
            return Err(Error::config(
                "supervisor: an explicit Config::kernel override does not cross the \
                 process boundary; set PALLAS_KERNEL in the environment instead \
                 (workers inherit it)",
            ));
        }
        let worker_cfg = Config { threads: self.threads_per_proc, ..self.base.clone() };
        worker_cfg.validate()
    }

    /// The worker argv, resolving the empty default to
    /// `current_exe() --shard-worker`.
    fn resolved_worker_argv(&self) -> Result<Vec<String>> {
        if !self.worker_argv.is_empty() {
            return Ok(self.worker_argv.clone());
        }
        let exe = std::env::current_exe().map_err(Error::Io)?;
        Ok(vec![exe.to_string_lossy().into_owned(), "--shard-worker".to_string()])
    }
}

/// The live pipe pair of one child (present iff a child is up).
struct ChildIo {
    stdin: ChildStdin,
    stdout: BufReader<ChildStdout>,
}

/// Lifecycle state of one shard (the brief-hold lock).
#[derive(Default)]
struct Life {
    /// The child handle, for kill/reap. `Some` iff `ChildIo` is `Some`
    /// (both are cleared together on death, under `io` then `life`).
    child: Option<Child>,
    /// When the current child was spawned (uptime accounting).
    spawned_at: Option<Instant>,
    /// Total children ever spawned for this shard.
    spawns: u64,
    /// Jobs answered successfully (including typed job errors — the child
    /// stayed up) by this shard's children, lifetime.
    jobs_ok: u64,
    /// Jobs failed with `ShardDown` (child died mid-job), lifetime.
    jobs_failed: u64,
    /// Deaths since the last successful job (drives the backoff
    /// exponent; reset on success).
    consecutive_deaths: u64,
    /// Earliest instant the next respawn may happen.
    backoff_until: Option<Instant>,
    /// Accumulated uptime of already-dead children (so `uptime_secs` in
    /// the summary is lifetime-total, not current-child-only).
    uptime_dead_secs: f64,
    /// Message of the most recent death, for the run summary.
    last_error: Option<String>,
}

/// One supervised shard: the pipe lock and the lifecycle lock (ordered
/// `io → life`; see the module docs).
struct Shard {
    io: Mutex<Option<ChildIo>>,
    life: Mutex<Life>,
}

/// Lifetime counters of one shard, exported by
/// [`ShardSupervisor::stats`].
#[derive(Clone, Debug, Default)]
pub struct ShardProcStats {
    /// Whether a child is currently up.
    pub up: bool,
    /// Total children ever spawned (`spawns - 1` = restarts).
    pub spawns: u64,
    /// Jobs answered by a live child (success or typed job error).
    pub jobs_ok: u64,
    /// Jobs failed with `ShardDown`.
    pub jobs_failed: u64,
    /// Lifetime child uptime in seconds (dead children + current).
    pub uptime_secs: f64,
    /// Most recent death message, if any child ever died.
    pub last_error: Option<String>,
}

/// Counters for all shards.
#[derive(Clone, Debug, Default)]
pub struct SupervisorStats {
    /// Per-shard lifetime counters, indexed by shard.
    pub shards: Vec<ShardProcStats>,
}

impl SupervisorStats {
    /// Total restarts across all shards (spawns beyond each shard's
    /// first).
    pub fn restarts(&self) -> u64 {
        self.shards.iter().map(|s| s.spawns.saturating_sub(1)).sum()
    }
}

/// The parent-side supervisor (see the [module docs](self)).
pub struct ShardSupervisor {
    cfg: SupervisorConfig,
    shards: Vec<Shard>,
    /// Resolved once at build time so a `current_exe` failure surfaces at
    /// construction, not mid-flood.
    worker_argv: Vec<String>,
}

impl std::fmt::Debug for ShardSupervisor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardSupervisor")
            .field("procs", &self.shards.len())
            .field("threads_per_proc", &self.cfg.threads_per_proc)
            .finish_non_exhaustive()
    }
}

impl ShardSupervisor {
    /// Validate the config and set up the (empty) shard table. Children
    /// are spawned lazily on first use — constructing a supervisor is
    /// cheap and cannot fail on a missing worker binary until a job
    /// actually needs one.
    pub fn new(cfg: SupervisorConfig) -> Result<ShardSupervisor> {
        cfg.validate()?;
        let worker_argv = cfg.resolved_worker_argv()?;
        let shards = (0..cfg.procs)
            .map(|_| Shard { io: Mutex::new(None), life: Mutex::new(Life::default()) })
            .collect();
        Ok(ShardSupervisor { cfg, shards, worker_argv })
    }

    /// The validated configuration.
    pub fn config(&self) -> &SupervisorConfig {
        &self.cfg
    }

    /// Number of shard child processes.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard child responsible for problem size `n` (the shared
    /// size-class rule, identical to the in-process router's).
    pub fn shard_for(&self, n: usize) -> usize {
        size_class_shard(n, self.shards.len())
    }

    /// Reduce one pencil on its size-class child. Serializes per shard
    /// (the `io` lock is held for the round trip); different shards run
    /// concurrently. A dead child fails this job with
    /// [`Error::ShardDown`] and arms the backoff; the *next* job on the
    /// shard respawns and succeeds — resubmission is always safe because
    /// reductions are pure.
    pub fn reduce(&self, a: &Matrix, b: &Matrix) -> Result<Arc<HtDecomposition>> {
        check_square_pencil(a, b)?;
        let n = a.rows();
        let shard = self.shard_for(n);
        let eff =
            if self.cfg.clip_band { self.cfg.base.clipped_for(n) } else { self.cfg.base.clone() };
        eff.validate_for(n)?;
        let wire = WireConfig::from_config(&eff);

        let mut io = lock_recover(&self.shards[shard].io);
        self.ensure_child(shard, &mut io)?;
        let req_id = {
            let life = lock_recover(&self.shards[shard].life);
            // Monotone per shard: spawn count in the high bits keeps ids
            // from ever repeating across restarts.
            (life.spawns << 32) | (life.jobs_ok + life.jobs_failed)
        };
        let outcome = self.round_trip(&mut io, req_id, &wire, a, b);
        match outcome {
            Ok(reply) => {
                let mut life = lock_recover(&self.shards[shard].life);
                life.jobs_ok += 1;
                life.consecutive_deaths = 0;
                life.backoff_until = None;
                reply
            }
            Err(death_msg) => {
                self.record_death(shard, &mut io, death_msg);
                Err(Error::shard_down(format!(
                    "serve: shard {shard} child died with this job in flight; \
                     it will be respawned (backoff applies) — resubmit"
                )))
            }
        }
    }

    /// One write→read round trip on a live child. The outer `Result`
    /// distinguishes transport death (`Err(message)` → the `Dying` path)
    /// from a completed exchange whose inner `Result` is the job's typed
    /// outcome (the child is fine either way).
    #[allow(clippy::type_complexity)]
    fn round_trip(
        &self,
        io: &mut Option<ChildIo>,
        req_id: u64,
        wire: &WireConfig,
        a: &Matrix,
        b: &Matrix,
    ) -> std::result::Result<Result<Arc<HtDecomposition>>, String> {
        let pipes = io.as_mut().expect("ensure_child leaves a live child on success");
        let submit =
            Frame::Submit { req_id, cfg: *wire, a: a.clone(), b: b.clone() };
        if let Err(e) = write_frame(&mut pipes.stdin, &submit) {
            return Err(format!("write to child failed: {e}"));
        }
        match read_frame(&mut pipes.stdout) {
            Ok(Some(Frame::ResultOk { req_id: got, stage1_secs, stage2_secs, h, t, q, z })) => {
                if got != req_id {
                    return Err(format!("child replied to req {got}, expected {req_id}"));
                }
                Ok(Ok(Arc::new(HtDecomposition { h, t, q, z, stage1_secs, stage2_secs })))
            }
            Ok(Some(Frame::ResultErr { req_id: got, err })) => {
                if got != req_id {
                    return Err(format!("child replied to req {got}, expected {req_id}"));
                }
                // Typed job failure with the child still healthy: pass the
                // error through, count it as an answered job.
                Ok(Err(err))
            }
            Ok(Some(other)) => Err(format!("child sent an unexpected frame: {other:?}")),
            Ok(None) => Err("child closed its pipe (EOF) mid-job".to_string()),
            Err(e) => Err(format!("read from child failed: {e}")),
        }
    }

    /// Spawn this shard's child if it is not up, honoring the backoff
    /// window. Called with the shard's `io` lock held, so concurrent jobs
    /// on the shard cannot double-spawn; the backoff sleep happens under
    /// that lock (the shard is unusable until the window passes anyway —
    /// other shards are unaffected).
    fn ensure_child(&self, shard: usize, io: &mut Option<ChildIo>) -> Result<()> {
        if io.is_some() {
            return Ok(());
        }
        let wait = {
            let life = lock_recover(&self.shards[shard].life);
            life.backoff_until.map(|until| until.saturating_duration_since(Instant::now()))
        };
        if let Some(wait) = wait {
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        let mut cmd = Command::new(&self.worker_argv[0]);
        cmd.args(&self.worker_argv[1..])
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            // Worker panics/logs land on the parent's stderr — crash
            // output must survive the child.
            .stderr(Stdio::inherit())
            .env("PALLAS_SERVE_THREADS", self.cfg.threads_per_proc.to_string());
        let mut child = cmd.spawn().map_err(|e| {
            Error::shard_down(format!(
                "serve: cannot spawn shard {shard} worker ({}): {e}",
                self.worker_argv[0]
            ))
        })?;
        let stdin = child.stdin.take().expect("stdin was piped");
        let stdout = BufReader::new(child.stdout.take().expect("stdout was piped"));
        *io = Some(ChildIo { stdin, stdout });
        {
            let mut life = lock_recover(&self.shards[shard].life);
            life.child = Some(child);
            life.spawned_at = Some(Instant::now());
            life.spawns += 1;
        }
        self.persist_summary(shard);
        Ok(())
    }

    /// The `Dying` path: drop the pipes, reap the child, bump the failure
    /// counters, arm the backoff, persist the summary. Called with the
    /// shard's `io` lock held (the in-flight job's).
    fn record_death(&self, shard: usize, io: &mut Option<ChildIo>, msg: String) {
        *io = None; // dropping ChildIo closes our pipe ends
        {
            let mut life = lock_recover(&self.shards[shard].life);
            if let Some(mut child) = life.child.take() {
                let _ = child.kill(); // idempotent if already dead
                let _ = child.wait(); // reap — no zombie
            }
            if let Some(spawned) = life.spawned_at.take() {
                life.uptime_dead_secs += spawned.elapsed().as_secs_f64();
            }
            life.jobs_failed += 1;
            life.consecutive_deaths += 1;
            let exp = life.consecutive_deaths.min(32) - 1;
            let backoff_ms = self
                .cfg
                .backoff_initial_ms
                .saturating_mul(1u64 << exp.min(20))
                .min(self.cfg.backoff_max_ms);
            life.backoff_until = Some(Instant::now() + Duration::from_millis(backoff_ms));
            life.last_error = Some(msg);
        }
        self.persist_summary(shard);
    }

    /// Chaos hook (tests, fault drills): kill one shard's child without
    /// reaping or notifying. Takes only the `life` lock, so it can fire
    /// while a job round trip holds `io` — that job then observes
    /// EOF/EPIPE and runs the `Dying` path itself. Returns whether a
    /// child was there to kill.
    pub fn kill_shard(&self, shard: usize) -> bool {
        let mut life = lock_recover(&self.shards[shard].life);
        match life.child.as_mut() {
            Some(child) => {
                let _ = child.kill();
                true
            }
            None => false,
        }
    }

    /// Lifetime counters for every shard.
    pub fn stats(&self) -> SupervisorStats {
        SupervisorStats {
            shards: (0..self.shards.len()).map(|i| self.shard_stats(i)).collect(),
        }
    }

    fn shard_stats(&self, shard: usize) -> ShardProcStats {
        let life = lock_recover(&self.shards[shard].life);
        ShardProcStats {
            up: life.child.is_some(),
            spawns: life.spawns,
            jobs_ok: life.jobs_ok,
            jobs_failed: life.jobs_failed,
            uptime_secs: life.uptime_dead_secs
                + life.spawned_at.map_or(0.0, |s| s.elapsed().as_secs_f64()),
            last_error: life.last_error.clone(),
        }
    }

    /// Per-shard stats as a JSON object (embedded in the protocol's
    /// `Stats` reply when the front door runs in multi-process mode).
    pub fn stats_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("[");
        for (i, s) in self.stats().shards.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let _ = write!(
                out,
                "{{\"shard\": {i}, \"up\": {}, \"spawns\": {}, \"jobs_ok\": {}, \
                 \"jobs_failed\": {}, \"uptime_secs\": {:.3}}}",
                s.up, s.spawns, s.jobs_ok, s.jobs_failed, s.uptime_secs
            );
        }
        out.push(']');
        out
    }

    /// Best-effort `shard-<i>.run_summary.json` write (no-op without a
    /// `summary_dir`; I/O errors are swallowed — persistence must never
    /// fail a job).
    fn persist_summary(&self, shard: usize) {
        let Some(dir) = &self.cfg.summary_dir else {
            return;
        };
        let s = self.shard_stats(shard);
        let json = format!(
            "{{\n  \"schema_version\": 1,\n  \"shard\": {shard},\n  \"up\": {},\n  \
             \"spawns\": {},\n  \"restarts\": {},\n  \"jobs_ok\": {},\n  \
             \"jobs_failed\": {},\n  \"uptime_secs\": {:.3},\n  \"last_error\": {}\n}}\n",
            s.up,
            s.spawns,
            s.spawns.saturating_sub(1),
            s.jobs_ok,
            s.jobs_failed,
            s.uptime_secs,
            match &s.last_error {
                Some(e) => format!("\"{}\"", json_escape(e)),
                None => "null".to_string(),
            }
        );
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(format!("shard-{shard}.run_summary.json")), json);
    }

    /// Stop every child: close its stdin (a well-behaved worker exits on
    /// EOF), wait briefly, then kill. Persists final summaries. Drop runs
    /// the same sequence.
    pub fn shutdown(self) {
        drop(self);
    }

    fn stop_children(&mut self) {
        for shard in 0..self.shards.len() {
            // Dropping ChildIo closes the child's stdin → worker sees a
            // clean frame-boundary EOF and exits 0.
            *lock_recover(&self.shards[shard].io) = None;
            let mut life = lock_recover(&self.shards[shard].life);
            if let Some(mut child) = life.child.take() {
                let deadline = Instant::now() + Duration::from_secs(2);
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                    }
                }
                if let Some(spawned) = life.spawned_at.take() {
                    life.uptime_dead_secs += spawned.elapsed().as_secs_f64();
                }
            }
            drop(life);
            self.persist_summary(shard);
        }
    }
}

impl Drop for ShardSupervisor {
    fn drop(&mut self) {
        self.stop_children();
    }
}

/// Minimal JSON string escaping for the run summary (quotes, backslashes,
/// control characters).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------
// The worker side
// ---------------------------------------------------------------------

/// Entry point of the hidden `--shard-worker` mode: serve frames from
/// stdin to stdout until a clean EOF. Exposed as a library function so
/// every binary that may be named in [`SupervisorConfig::worker_argv`]
/// (the `paraht` CLI, the `serve_net` bench, the `serve_proc` test
/// harness) can dispatch to the *same* worker loop before parsing its own
/// arguments.
///
/// Exit codes: `0` clean EOF (supervisor closed stdin), `2` protocol
/// misuse on stdin, `3` the reply pipe broke (the parent died).
///
/// The worker is deliberately configuration-free: every `Submit` carries
/// its explicit effective tuning (the supervisor never sends the wire
/// sentinel), and the worker caches one [`HtSession`] keyed by that
/// tuning — consecutive same-class jobs reuse the session's per-`n`
/// workspace exactly like an in-process shard would. Thread count comes
/// from `PALLAS_SERVE_THREADS` (set by the supervisor at spawn).
pub fn worker_main() -> i32 {
    use crate::api::HtSession;

    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut input = stdin.lock();
    let mut output = BufWriter::new(stdout.lock());
    let threads = crate::util::env::serve_threads(1);
    // (tuning key, session) — rebuilt when a job's tuning differs.
    let mut cached: Option<(WireConfig, HtSession)> = None;
    let mut jobs: u64 = 0;

    loop {
        let frame = match read_frame(&mut input) {
            Ok(Some(f)) => f,
            Ok(None) => return 0, // clean frame-boundary EOF: supervisor shutdown
            Err(e) => {
                eprintln!("shard-worker: protocol error on stdin: {e}");
                return 2;
            }
        };
        let reply = match frame {
            Frame::Submit { req_id, cfg, a, b } => {
                jobs += 1;
                match worker_reduce(&mut cached, threads, cfg, &a, &b) {
                    Ok(d) => Frame::ResultOk {
                        req_id,
                        stage1_secs: d.stage1_secs,
                        stage2_secs: d.stage2_secs,
                        h: d.h,
                        t: d.t,
                        q: d.q,
                        z: d.z,
                    },
                    Err(err) => Frame::ResultErr { req_id, err },
                }
            }
            Frame::StatsReq { req_id } => {
                Frame::StatsReply { req_id, json: format!("{{\"worker_jobs\": {jobs}}}") }
            }
            other => {
                eprintln!("shard-worker: unexpected frame on stdin: {other:?}");
                return 2;
            }
        };
        if write_frame(&mut output, &reply).and_then(|()| output.flush().map_err(Error::Io)).is_err()
        {
            // Nobody is listening; stderr is the only channel left.
            eprintln!("shard-worker: reply pipe broke; exiting");
            return 3;
        }
    }
}

/// One worker-side reduction: resolve the session for this job's tuning
/// (reusing the cached one when the tuning repeats) and run. A panicking
/// reduction is *not* caught here — process isolation is the whole point:
/// the panic unwinds, the worker dies, the supervisor's `Dying` path
/// turns it into `ShardDown` and a respawn.
fn worker_reduce(
    cached: &mut Option<(WireConfig, crate::api::HtSession)>,
    threads: usize,
    wire: WireConfig,
    a: &Matrix,
    b: &Matrix,
) -> Result<HtDecomposition> {
    use crate::api::HtSession;
    if wire.is_default() {
        // The supervisor always sends explicit tuning; the sentinel here
        // means a non-supervisor peer is driving the pipe wrong.
        return Err(Error::protocol(
            "shard-worker: Submit carried the default-tuning sentinel; workers \
             require explicit effective tuning",
        ));
    }
    let cfg = wire.apply_to(&Config { threads, ..Config::default() });
    let rebuild = match cached {
        Some((key, _)) => *key != wire,
        None => true,
    };
    if rebuild {
        // clip_band(true): the tuning is already clipped by the
        // supervisor, so this is an idempotent safety net, and it lets
        // hand-driven pipes (tests) submit unclipped tunings too.
        let session = HtSession::builder().config(cfg).clip_band(true).build()?;
        *cached = Some((wire, session));
    }
    let (_, session) = cached.as_mut().expect("session cached above");
    let result = session.reduce(a, b);
    // A worker serves unboundedly many jobs: the per-call phase log must
    // not grow with traffic (same hygiene as the in-process router).
    session.clear_phases();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rejects_bad_geometry() {
        let ok = SupervisorConfig::default();
        assert!(ok.validate().is_ok());
        let bad = SupervisorConfig { procs: 0, ..SupervisorConfig::default() };
        assert!(matches!(bad.validate().unwrap_err(), Error::Config(_)));
        let bad = SupervisorConfig { procs: 65, ..SupervisorConfig::default() };
        assert!(matches!(bad.validate().unwrap_err(), Error::Config(_)));
        let bad = SupervisorConfig { backoff_initial_ms: 0, ..SupervisorConfig::default() };
        assert!(matches!(bad.validate().unwrap_err(), Error::Config(_)));
        let bad = SupervisorConfig {
            backoff_initial_ms: 100,
            backoff_max_ms: 50,
            ..SupervisorConfig::default()
        };
        assert!(matches!(bad.validate().unwrap_err(), Error::Config(_)));
        let bad = SupervisorConfig {
            base: Config { kernel: KernelChoice::Scalar, ..Config::default() },
            ..SupervisorConfig::default()
        };
        let e = bad.validate().unwrap_err();
        assert!(format!("{e}").contains("PALLAS_KERNEL"), "{e}");
    }

    #[test]
    fn worker_argv_default_is_current_exe_shard_worker() {
        let cfg = SupervisorConfig::default();
        let argv = cfg.resolved_worker_argv().unwrap();
        assert_eq!(argv.len(), 2);
        assert_eq!(argv[1], "--shard-worker");
        let explicit = SupervisorConfig {
            worker_argv: vec!["/bin/worker".into(), "--flag".into()],
            ..SupervisorConfig::default()
        };
        assert_eq!(explicit.resolved_worker_argv().unwrap(), vec!["/bin/worker", "--flag"]);
    }

    #[test]
    fn routing_agrees_with_the_in_process_router_rule() {
        let sup = ShardSupervisor::new(SupervisorConfig {
            procs: 3,
            ..SupervisorConfig::default()
        })
        .unwrap();
        for n in [2usize, 16, 23, 40, 400] {
            assert_eq!(sup.shard_for(n), size_class_shard(n, 3));
        }
        // Nothing spawned yet: construction is lazy.
        assert!(sup.stats().shards.iter().all(|s| !s.up && s.spawns == 0));
    }

    #[test]
    fn json_escaping_covers_quotes_and_control_chars() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("line\nbreak\ttab"), "line\\nbreak\\ttab");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }

    #[test]
    fn backoff_exponent_caps_at_the_ceiling() {
        // The arithmetic inside record_death, spot-checked standalone:
        // initial 25ms doubling, ceiling 2000ms.
        let initial: u64 = 25;
        let max: u64 = 2000;
        let backoff = |deaths: u64| -> u64 {
            let exp = deaths.min(32) - 1;
            initial.saturating_mul(1u64 << exp.min(20)).min(max)
        };
        assert_eq!(backoff(1), 25);
        assert_eq!(backoff(2), 50);
        assert_eq!(backoff(4), 200);
        assert_eq!(backoff(8), 2000, "capped");
        assert_eq!(backoff(40), 2000, "huge death counts saturate, no overflow");
    }
}
