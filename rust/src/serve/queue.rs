//! The async submission queue: bounded MPSC lanes in front of the shard
//! router, one dispatcher thread per shard, condvar-backed result tickets.
//!
//! Clients call [`SubmitHandle::submit`] (cheap: shape check, route,
//! enqueue) and get a [`JobTicket`] back; [`JobTicket::wait`] blocks until
//! that job's dispatcher has filled the ticket. Submission is **bounded**:
//! each shard has its own FIFO of depth `queue_capacity`, and a submitter
//! whose target lane is full blocks until the dispatcher drains it — the
//! backpressure that keeps a flood from buffering unboundedly.
//!
//! **Admission control.** Unbounded blocking is the right default for
//! in-process batch producers, but a serving front door must be able to
//! *shed*: [`SubmitHandle::try_submit`] fails immediately with a typed
//! [`Error::Overloaded`] when the lane is full, and
//! [`SubmitHandle::submit_timeout`] waits at most a deadline before
//! shedding — the network tier ([`crate::serve::net`]) admits through the
//! latter with the `PALLAS_ADMIT_TIMEOUT_MS` knob. A shed job is never
//! enqueued and gets no ticket; the `shed` counter in [`QueueStats`]
//! makes load shedding visible next to `rejected` (shutdown refusals).
//!
//! **Latency observability.** Each accepted job is stamped at enqueue;
//! when its dispatcher fills the ticket, the elapsed submit→completion
//! time is recorded into the per-size-class histograms of
//! [`crate::serve::metrics::ServeMetrics`] (lock-free atomic buckets).
//! [`SubmitQueue::latency_snapshot`] exposes p50/p90/p99 per class.
//!
//! **Threading model.** Routing happens at submit time (the size-class
//! hash of [`crate::serve::ShardRouter::shard_for`]), so each dispatcher
//! owns exactly one lane and locks exactly one shard session — N shards
//! serve N jobs concurrently, each on `threads_per_shard` pool executors.
//! Tickets are `(Mutex<Option<Result>>, Condvar)` pairs: the dispatcher
//! stores the result under the mutex and `notify_all`s, the waiter loops
//! on the condvar — the same park/notify shape as the worker pool.
//!
//! **Shutdown protocol** (the pool's documented sequence, adapted):
//!
//! 1. [`SubmitQueue::shutdown`] (or drop) sets each lane's `closed` flag
//!    *under that lane's mutex* and notifies both condvars — a submitter
//!    or dispatcher is either already waiting (woken, re-checks, sees the
//!    flag) or between its check and `wait` (the flag write is ordered
//!    before its re-check by the mutex): no lost wakeup.
//! 2. Submitters that observe `closed` fail with a typed
//!    [`Error::Runtime`] *without* enqueuing; no ticket is created.
//! 3. Each dispatcher **drains its lane before exiting** — it only
//!    returns when its FIFO is empty *and* closed — so every ticket
//!    handed out before shutdown completes with a real result (the
//!    graceful-drain contract pinned by `tests/serve.rs`).
//! 4. Every dispatcher `JoinHandle` is joined; after `shutdown` returns,
//!    no serving thread survives.

use crate::error::{Error, Result};
use crate::ht::two_stage::HtDecomposition;
use crate::linalg::matrix::Matrix;
use crate::serve::metrics::{HistogramSnapshot, ServeMetrics, SizeClass};
use crate::serve::router::{check_square_pencil, ShardRouter};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
#[cfg(any(feature = "audit", debug_assertions))]
use std::sync::atomic::AtomicBool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One queued job: the pencil plus the ticket to fill.
struct Job {
    a: Matrix,
    b: Matrix,
    /// Problem size, captured at submit (selects the latency size class).
    n: usize,
    /// Enqueue stamp; completion minus this is the recorded latency.
    enqueued: Instant,
    ticket: Arc<TicketShared>,
}

/// How long a submitter is willing to wait for lane capacity.
enum Admit {
    /// Block until capacity (the original `submit` semantics).
    Block,
    /// Shed immediately when the lane is full.
    NoWait,
    /// Shed if the lane stays full past this deadline.
    Deadline(Instant),
}

/// Completion slot shared by a dispatcher and one waiter.
struct TicketShared {
    slot: Mutex<Option<Result<Arc<HtDecomposition>>>>,
    cv: Condvar,
    /// Concurrency-audit shadow (`coordinator::audit`): set when the
    /// dispatcher fills the ticket. A second fill — which would clobber a
    /// result a waiter may already have taken, or signal a job that ran
    /// twice — trips an assert. Absent from release builds without the
    /// `audit` feature.
    #[cfg(any(feature = "audit", debug_assertions))]
    filled: AtomicBool,
}

/// Handle to one submitted job; redeem with [`JobTicket::wait`].
pub struct JobTicket {
    shared: Arc<TicketShared>,
}

impl JobTicket {
    /// Block until the job completes and take its result. Every accepted
    /// submission completes — including across shutdown, which drains the
    /// lanes before the dispatchers exit — so `wait` cannot hang on a
    /// ticket that `submit` actually returned.
    pub fn wait(self) -> Result<Arc<HtDecomposition>> {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.shared.cv.wait(slot).unwrap();
        }
    }

    /// Non-blocking probe: whether the result is ready (a `wait` after
    /// `true` returns immediately).
    pub fn is_ready(&self) -> bool {
        self.shared.slot.lock().unwrap().is_some()
    }
}

/// One bounded lane (per shard).
struct Lane {
    state: Mutex<LaneState>,
    /// Wakes the lane's dispatcher when a job arrives (or on shutdown).
    not_empty: Condvar,
    /// Wakes blocked submitters when the dispatcher pops (or on shutdown).
    not_full: Condvar,
}

struct LaneState {
    jobs: VecDeque<Job>,
    closed: bool,
    /// Test-only dispatcher brake: while set (and the lane is open), the
    /// dispatcher parks instead of popping, so a test can fill a lane to
    /// capacity deterministically and observe `try_submit`/`submit_timeout`
    /// shedding. `closed` overrides it — shutdown still drains.
    #[cfg(test)]
    paused: bool,
}

impl Lane {
    fn new() -> Lane {
        Lane {
            state: Mutex::new(LaneState {
                jobs: VecDeque::new(),
                closed: false,
                #[cfg(test)]
                paused: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }
}

/// State shared by the queue owner, every [`SubmitHandle`] clone, and the
/// dispatcher threads.
struct QueueShared {
    router: ShardRouter,
    lanes: Vec<Lane>,
    capacity: usize,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    shed: AtomicU64,
    metrics: ServeMetrics,
}

/// Queue-level counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    /// Jobs accepted into a lane.
    pub submitted: u64,
    /// Jobs whose ticket has been filled (success or typed error).
    pub completed: u64,
    /// Submissions refused because the queue was shut down.
    pub rejected: u64,
    /// Submissions shed by admission control (`try_submit` on a full lane,
    /// `submit_timeout` past its deadline) — never enqueued, no ticket.
    pub shed: u64,
    /// Jobs currently waiting in the lanes.
    pub pending: usize,
}

/// Cloneable submission endpoint (see the [module docs](self)).
///
/// Handles stay valid after [`SubmitQueue::shutdown`]; their `submit`
/// calls then fail fast with a typed [`Error::Runtime`].
#[derive(Clone)]
pub struct SubmitHandle {
    shared: Arc<QueueShared>,
}

impl SubmitHandle {
    /// Enqueue one pencil for reduction. Blocks while the target shard's
    /// lane is full (backpressure); fails fast with [`Error::Shape`] on a
    /// non-square pencil or [`Error::Runtime`] after shutdown.
    pub fn submit(&self, a: Matrix, b: Matrix) -> Result<JobTicket> {
        self.submit_with(a, b, Admit::Block)
    }

    /// Non-blocking enqueue: like [`SubmitHandle::submit`] but a full lane
    /// sheds immediately with a typed [`Error::Overloaded`] instead of
    /// blocking. Nothing is enqueued on shed — resubmitting later is safe.
    pub fn try_submit(&self, a: Matrix, b: Matrix) -> Result<JobTicket> {
        self.submit_with(a, b, Admit::NoWait)
    }

    /// Bounded-wait enqueue: wait up to `timeout` for lane capacity, then
    /// shed with [`Error::Overloaded`]. `Duration::ZERO` behaves like
    /// [`SubmitHandle::try_submit`]. This is the admission-control entry
    /// the network front door uses (`PALLAS_ADMIT_TIMEOUT_MS`).
    pub fn submit_timeout(&self, a: Matrix, b: Matrix, timeout: Duration) -> Result<JobTicket> {
        self.submit_with(a, b, Admit::Deadline(Instant::now() + timeout))
    }

    /// The router's configured admission deadline in milliseconds
    /// ([`crate::serve::router::ServeConfig::admit_timeout_ms`]), so
    /// front doors holding only a handle can build the
    /// [`SubmitHandle::submit_timeout`] argument.
    pub fn admit_timeout_ms(&self) -> u64 {
        self.shared.router.config().admit_timeout_ms
    }

    /// The one admission path behind all three submit variants. The
    /// `closed` / capacity / deadline checks all happen under the lane
    /// mutex, and the push shares the critical section with the final
    /// check — identical closed-race discipline for every variant.
    fn submit_with(&self, a: Matrix, b: Matrix, admit: Admit) -> Result<JobTicket> {
        check_square_pencil(&a, &b)?;
        let n = a.rows();
        let shard = self.shared.router.shard_for(n);
        let lane = &self.shared.lanes[shard];
        let ticket = Arc::new(TicketShared {
            slot: Mutex::new(None),
            cv: Condvar::new(),
            #[cfg(any(feature = "audit", debug_assertions))]
            filled: AtomicBool::new(false),
        });
        {
            let mut st = lane.state.lock().unwrap();
            loop {
                // `closed` is re-checked at the top of every iteration —
                // i.e. after *every* wakeup from `not_full.wait`, spurious
                // or broadcast — while holding the lane mutex, and the
                // push below sits in the same critical section as the last
                // check. A submitter parked in `not_full` while the queue
                // closes therefore always lands in the rejection branch:
                // it can never act on a stale pre-close capacity check and
                // enqueue a job no dispatcher will drain (pinned by
                // `submit_racing_close_never_enqueues_after_shutdown`).
                if st.closed {
                    self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::runtime(
                        "serve: submission queue is shut down; no new jobs accepted",
                    ));
                }
                if st.jobs.len() < self.shared.capacity {
                    break;
                }
                st = match &admit {
                    Admit::Block => lane.not_full.wait(st).unwrap(),
                    Admit::NoWait => {
                        self.shared.shed.fetch_add(1, Ordering::Relaxed);
                        return Err(Error::overloaded(format!(
                            "serve: shard {shard} lane is full ({} jobs)",
                            self.shared.capacity
                        )));
                    }
                    Admit::Deadline(deadline) => {
                        let now = Instant::now();
                        if now >= *deadline {
                            self.shared.shed.fetch_add(1, Ordering::Relaxed);
                            return Err(Error::overloaded(format!(
                                "serve: shard {shard} lane stayed full past the \
                                 admission deadline ({} jobs)",
                                self.shared.capacity
                            )));
                        }
                        // Spurious wakeups re-enter this arm and re-derive
                        // the remaining budget from the absolute deadline,
                        // so the total wait never exceeds the timeout.
                        let (guard, _) = lane.not_full.wait_timeout(st, *deadline - now).unwrap();
                        guard
                    }
                };
            }
            st.jobs.push_back(Job { a, b, n, enqueued: Instant::now(), ticket: ticket.clone() });
        }
        lane.not_empty.notify_one();
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(JobTicket { shared: ticket })
    }

    /// Queue-level counter snapshot.
    pub fn stats(&self) -> QueueStats {
        stats_of(&self.shared)
    }

    /// Per-size-class latency snapshot (submit→completion, recorded when
    /// the dispatcher fills each ticket).
    pub fn latency_snapshot(&self) -> Vec<(SizeClass, HistogramSnapshot)> {
        self.shared.metrics.snapshot()
    }
}

fn stats_of(shared: &QueueShared) -> QueueStats {
    QueueStats {
        submitted: shared.submitted.load(Ordering::Relaxed),
        completed: shared.completed.load(Ordering::Relaxed),
        rejected: shared.rejected.load(Ordering::Relaxed),
        shed: shared.shed.load(Ordering::Relaxed),
        pending: shared.lanes.iter().map(|l| l.state.lock().unwrap().jobs.len()).sum(),
    }
}

/// Body of one per-shard dispatcher: pop a job from the lane (or park),
/// reduce it on this shard via the router (cache consulted first), fill
/// the ticket; exit only when the lane is drained *and* closed.
fn dispatcher_loop(shared: Arc<QueueShared>, shard: usize) {
    loop {
        let job = {
            let lane = &shared.lanes[shard];
            let mut st = lane.state.lock().unwrap();
            loop {
                // Test brake (see `LaneState::paused`): park without
                // popping so tests can hold a lane at capacity. Closed
                // lanes ignore it — shutdown always drains.
                #[cfg(test)]
                if st.paused && !st.closed {
                    st = lane.not_empty.wait(st).unwrap();
                    continue;
                }
                if let Some(job) = st.jobs.pop_front() {
                    // Wake one blocked submitter into the freed slot.
                    lane.not_full.notify_one();
                    break Some(job);
                }
                if st.closed {
                    break None;
                }
                st = lane.not_empty.wait(st).unwrap();
            }
        };
        let Some(job) = job else {
            return; // drained and closed: graceful exit
        };
        // A panicking reduction must not kill the dispatcher (its lane
        // would silently hang every later waiter): trap it into the
        // ticket as a typed error and keep serving.
        let result = catch_unwind(AssertUnwindSafe(|| {
            shared.router.reduce_on(shard, &job.a, &job.b)
        }))
        .unwrap_or_else(|_| Err(Error::runtime("serve: reduction panicked; job dropped")));
        shared.completed.fetch_add(1, Ordering::Relaxed);
        // Submit→completion latency into the per-size-class histogram —
        // recorded at ticket fill so queueing delay is included (that is
        // the latency a front-door client actually observes).
        shared.metrics.record(job.n, job.enqueued.elapsed());
        // Ticket lifecycle audit: every accepted ticket is filled
        // (completed-or-poisoned) exactly once. Jobs are moved out of the
        // lane by `pop_front`, so a double fill can only mean a duplicated
        // job — catch it here rather than as a clobbered result.
        #[cfg(any(feature = "audit", debug_assertions))]
        assert!(
            !job.ticket.filled.swap(true, Ordering::Relaxed),
            "concurrency audit failed: serve ticket filled twice (shard {shard})"
        );
        *job.ticket.slot.lock().unwrap() = Some(result);
        job.ticket.cv.notify_all();
    }
}

/// The owning half of the serving queue: holds the router, the lanes and
/// the dispatcher threads. Create with [`SubmitQueue::new`], hand out
/// [`SubmitHandle`]s via [`SubmitQueue::handle`], stop with
/// [`SubmitQueue::shutdown`] (drop runs the same protocol).
pub struct SubmitQueue {
    shared: Arc<QueueShared>,
    dispatchers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for SubmitQueue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SubmitQueue")
            .field("shards", &self.shared.lanes.len())
            .field("capacity", &self.shared.capacity)
            .field("stats", &stats_of(&self.shared))
            .finish_non_exhaustive()
    }
}

impl SubmitQueue {
    /// Spawn the serving tier around a router: one lane + one named
    /// dispatcher thread (`paraht-serve-<shard>`) per shard, each lane
    /// bounded at the router's configured `queue_capacity`.
    pub fn new(router: ShardRouter) -> SubmitQueue {
        let capacity = router.config().queue_capacity;
        let shards = router.shard_count();
        let shared = Arc::new(QueueShared {
            router,
            lanes: (0..shards).map(|_| Lane::new()).collect(),
            capacity,
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            metrics: ServeMetrics::new(),
        });
        let dispatchers = (0..shards)
            .map(|shard| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("paraht-serve-{shard}"))
                    .spawn(move || dispatcher_loop(sh, shard))
                    .expect("spawn serve dispatcher")
            })
            .collect();
        SubmitQueue { shared, dispatchers }
    }

    /// A new submission endpoint (cheap to clone, one per client thread).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle { shared: self.shared.clone() }
    }

    /// The router behind the queue (for stats and direct synchronous use).
    pub fn router(&self) -> &ShardRouter {
        &self.shared.router
    }

    /// Queue-level counter snapshot.
    pub fn stats(&self) -> QueueStats {
        stats_of(&self.shared)
    }

    /// Per-size-class latency snapshot (see
    /// [`SubmitHandle::latency_snapshot`]).
    pub fn latency_snapshot(&self) -> Vec<(SizeClass, HistogramSnapshot)> {
        self.shared.metrics.snapshot()
    }

    /// Per-size-class latency histograms rendered as a JSON object (the
    /// shape the protocol's `Stats` reply embeds).
    pub fn latency_json(&self) -> String {
        self.shared.metrics.to_json()
    }

    /// Test brake: pause/unpause one shard's dispatcher (see
    /// `LaneState::paused`).
    #[cfg(test)]
    fn set_paused(&self, shard: usize, paused: bool) {
        let lane = &self.shared.lanes[shard];
        lane.state.lock().unwrap().paused = paused;
        lane.not_empty.notify_all();
    }

    /// Graceful shutdown (the documented protocol): close every lane,
    /// wake everyone, join every dispatcher. Already-accepted jobs are
    /// drained and their tickets filled; concurrent and later submissions
    /// fail with a typed error. Consuming `self` makes "no further
    /// owner-side use" a compile-time fact; outstanding [`SubmitHandle`]s
    /// remain safe to call.
    pub fn shutdown(self) {
        drop(self);
    }

    fn close_and_join(&mut self) {
        for lane in &self.shared.lanes {
            lane.state.lock().unwrap().closed = true;
            lane.not_empty.notify_all();
            lane.not_full.notify_all();
        }
        for h in self.dispatchers.drain(..) {
            // Dispatchers trap job panics, so join failure is unreachable;
            // don't double-panic during drop if it somehow happens.
            let _ = h.join();
        }
    }
}

impl Drop for SubmitQueue {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::reduce_seq;
    use crate::config::Config;
    use crate::pencil::random::random_pencil;
    use crate::serve::router::ServeConfig;
    use crate::util::proptest::max_abs_diff;
    use crate::util::rng::Rng;

    fn small_queue(shards: usize, capacity: usize) -> SubmitQueue {
        let cfg = ServeConfig {
            shards,
            queue_capacity: capacity,
            base: Config { r: 4, p: 2, q: 2, ..Config::default() },
            ..ServeConfig::default()
        };
        SubmitQueue::new(ShardRouter::new(cfg).unwrap())
    }

    #[test]
    fn submit_wait_roundtrip_is_bitwise_the_oracle() {
        let mut rng = Rng::new(0x0E_01);
        let q = small_queue(2, 8);
        let h = q.handle();
        let p = random_pencil(14, &mut rng);
        let ticket = h.submit(p.a.clone(), p.b.clone()).unwrap();
        let d = ticket.wait().unwrap();
        let eff = q.router().config().base.clipped_for(14);
        let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
        assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0);
        assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0);
        let stats = q.stats();
        assert_eq!((stats.submitted, stats.completed, stats.rejected), (1, 1, 0));
        q.shutdown();
    }

    #[test]
    fn shape_error_fails_fast_without_a_ticket() {
        let q = small_queue(1, 4);
        let h = q.handle();
        let e = h.submit(Matrix::zeros(3, 4), Matrix::zeros(3, 3)).unwrap_err();
        assert!(matches!(e, Error::Shape(_)));
        assert_eq!(q.stats().submitted, 0);
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let q = small_queue(2, 4);
        let h = q.handle();
        q.shutdown();
        let mut rng = Rng::new(0x0E_02);
        let p = random_pencil(8, &mut rng);
        let e = h.submit(p.a, p.b).unwrap_err();
        assert!(matches!(e, Error::Runtime(_)), "{e}");
        assert_eq!(h.stats().rejected, 1);
    }

    #[test]
    fn tickets_accepted_before_shutdown_complete() {
        let mut rng = Rng::new(0x0E_03);
        let q = small_queue(1, 32);
        let h = q.handle();
        let pencils: Vec<_> = (0..6).map(|_| random_pencil(10, &mut rng)).collect();
        let tickets: Vec<_> = pencils
            .iter()
            .map(|p| h.submit(p.a.clone(), p.b.clone()).unwrap())
            .collect();
        q.shutdown(); // drains the lane before the dispatcher exits
        for (p, t) in pencils.iter().zip(tickets) {
            let d = t.wait().expect("accepted job completes across shutdown");
            let eff = Config { r: 4, p: 2, q: 2, ..Config::default() };
            let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
            assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0);
        }
    }

    #[test]
    fn submit_racing_close_never_enqueues_after_shutdown() {
        // Regression for the shutdown race: submitters blocked in
        // `not_full.wait` on a full lane while the queue closes must
        // observe the closed flag on wakeup (under the lane mutex) and
        // fail with the typed error — never push a job that no dispatcher
        // will drain. A capacity-1 single-shard lane forces the blocking.
        let mut rng = Rng::new(0x0E_05);
        let q = small_queue(1, 1);
        let h = q.handle();
        let pencils: Vec<_> = (0..24).map(|_| random_pencil(16, &mut rng)).collect();
        std::thread::scope(|s| {
            let workers: Vec<_> = pencils
                .chunks(6)
                .map(|chunk| {
                    let h = h.clone();
                    s.spawn(move || {
                        let mut oks = Vec::new();
                        let mut errs = 0u64;
                        for p in chunk {
                            match h.submit(p.a.clone(), p.b.clone()) {
                                Ok(t) => oks.push(t),
                                Err(e) => {
                                    assert!(
                                        matches!(e, Error::Runtime(_)),
                                        "closed-lane rejection must be typed: {e}"
                                    );
                                    errs += 1;
                                }
                            }
                        }
                        (oks, errs)
                    })
                })
                .collect();
            // Let some submissions land and some block, then close while
            // the rest race the flag.
            std::thread::sleep(std::time::Duration::from_millis(2));
            q.shutdown();
            let mut total_errs = 0;
            for w in workers {
                let (oks, errs) = w.join().unwrap();
                total_errs += errs;
                for t in oks {
                    t.wait().expect("every accepted job completes across shutdown");
                }
            }
            let stats = h.stats();
            assert_eq!(
                stats.submitted, stats.completed,
                "a job enqueued after close would leave submitted > completed"
            );
            assert_eq!(stats.rejected, total_errs, "every rejection surfaced as an error");
            assert_eq!(stats.pending, 0, "no job left stranded in a lane");
        });
    }

    #[test]
    fn try_submit_sheds_on_a_full_lane_and_recovers() {
        // Pause the single dispatcher, fill the capacity-2 lane, and the
        // third submission must shed immediately with Overloaded — never
        // enqueue, never block. Unpausing drains everything.
        let mut rng = Rng::new(0x0E_10);
        let q = small_queue(1, 2);
        let h = q.handle();
        q.set_paused(0, true);
        let pencils: Vec<_> = (0..3).map(|_| random_pencil(8, &mut rng)).collect();
        let t0 = h.try_submit(pencils[0].a.clone(), pencils[0].b.clone()).unwrap();
        let t1 = h.try_submit(pencils[1].a.clone(), pencils[1].b.clone()).unwrap();
        let e = h.try_submit(pencils[2].a.clone(), pencils[2].b.clone()).unwrap_err();
        assert!(matches!(e, Error::Overloaded(_)), "{e}");
        let stats = h.stats();
        assert_eq!(stats.shed, 1);
        assert_eq!(stats.submitted, 2, "the shed job was never enqueued");
        q.set_paused(0, false);
        t0.wait().unwrap();
        t1.wait().unwrap();
        // With the dispatcher running again, try_submit admits normally.
        let t2 = h.try_submit(pencils[2].a.clone(), pencils[2].b.clone()).unwrap();
        let d = t2.wait().unwrap();
        let eff = q.router().config().base.clipped_for(8);
        let oracle = reduce_seq(&pencils[2].a, &pencils[2].b, &eff).unwrap();
        assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "post-shed result is still bitwise");
        q.shutdown();
    }

    #[test]
    fn submit_timeout_sheds_after_the_deadline() {
        let mut rng = Rng::new(0x0E_11);
        let q = small_queue(1, 1);
        let h = q.handle();
        q.set_paused(0, true);
        let p0 = random_pencil(8, &mut rng);
        let p1 = random_pencil(8, &mut rng);
        let t0 = h.submit(p0.a.clone(), p0.b.clone()).unwrap();
        let start = Instant::now();
        let e = h
            .submit_timeout(p1.a.clone(), p1.b.clone(), Duration::from_millis(30))
            .unwrap_err();
        assert!(matches!(e, Error::Overloaded(_)), "{e}");
        assert!(
            start.elapsed() >= Duration::from_millis(30),
            "deadline admission must actually wait out its budget"
        );
        assert_eq!(h.stats().shed, 1);
        // A zero timeout behaves like try_submit.
        let e = h.submit_timeout(p1.a.clone(), p1.b.clone(), Duration::ZERO).unwrap_err();
        assert!(matches!(e, Error::Overloaded(_)), "{e}");
        q.set_paused(0, false);
        t0.wait().unwrap();
        // Capacity is back: the deadline path admits without shedding.
        let t1 = h.submit_timeout(p1.a, p1.b, Duration::from_secs(5)).unwrap();
        t1.wait().unwrap();
        q.shutdown();
    }

    #[test]
    fn completed_jobs_show_up_in_the_latency_histograms() {
        let mut rng = Rng::new(0x0E_12);
        let q = small_queue(2, 8);
        let h = q.handle();
        let tickets: Vec<_> = (0..4)
            .map(|_| {
                let p = random_pencil(10, &mut rng);
                h.submit(p.a, p.b).unwrap()
            })
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let snap = q.latency_snapshot();
        let tiny = snap.iter().find(|(c, _)| *c == crate::serve::metrics::SizeClass::Tiny);
        let (_, hist) = tiny.expect("tiny class present in every snapshot");
        assert_eq!(hist.count, 4, "every completion recorded exactly once");
        assert!(hist.p99_ms() > 0.0);
        assert!(q.latency_json().contains("\"tiny\""));
        q.shutdown();
    }

    #[test]
    fn is_ready_becomes_true_after_wait_would_succeed() {
        let mut rng = Rng::new(0x0E_04);
        let q = small_queue(1, 4);
        let h = q.handle();
        let p = random_pencil(8, &mut rng);
        let ticket = h.submit(p.a, p.b).unwrap();
        // Shutdown drains the lane, so afterwards the ticket must be ready.
        q.shutdown();
        assert!(ticket.is_ready());
        ticket.wait().unwrap();
    }
}
