//! The async submission queue: bounded MPSC lanes in front of the shard
//! router, one dispatcher thread per shard, condvar-backed result tickets.
//!
//! Clients call [`SubmitHandle::submit`] (cheap: shape check, route,
//! enqueue) and get a [`JobTicket`] back; [`JobTicket::wait`] blocks until
//! that job's dispatcher has filled the ticket. Submission is **bounded**:
//! each shard has its own FIFO of depth `queue_capacity`, and a submitter
//! whose target lane is full blocks until the dispatcher drains it — the
//! backpressure that keeps a flood from buffering unboundedly.
//!
//! **Threading model.** Routing happens at submit time (the size-class
//! hash of [`crate::serve::ShardRouter::shard_for`]), so each dispatcher
//! owns exactly one lane and locks exactly one shard session — N shards
//! serve N jobs concurrently, each on `threads_per_shard` pool executors.
//! Tickets are `(Mutex<Option<Result>>, Condvar)` pairs: the dispatcher
//! stores the result under the mutex and `notify_all`s, the waiter loops
//! on the condvar — the same park/notify shape as the worker pool.
//!
//! **Shutdown protocol** (the pool's documented sequence, adapted):
//!
//! 1. [`SubmitQueue::shutdown`] (or drop) sets each lane's `closed` flag
//!    *under that lane's mutex* and notifies both condvars — a submitter
//!    or dispatcher is either already waiting (woken, re-checks, sees the
//!    flag) or between its check and `wait` (the flag write is ordered
//!    before its re-check by the mutex): no lost wakeup.
//! 2. Submitters that observe `closed` fail with a typed
//!    [`Error::Runtime`] *without* enqueuing; no ticket is created.
//! 3. Each dispatcher **drains its lane before exiting** — it only
//!    returns when its FIFO is empty *and* closed — so every ticket
//!    handed out before shutdown completes with a real result (the
//!    graceful-drain contract pinned by `tests/serve.rs`).
//! 4. Every dispatcher `JoinHandle` is joined; after `shutdown` returns,
//!    no serving thread survives.

use crate::error::{Error, Result};
use crate::ht::two_stage::HtDecomposition;
use crate::linalg::matrix::Matrix;
use crate::serve::router::{check_square_pencil, ShardRouter};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
#[cfg(any(feature = "audit", debug_assertions))]
use std::sync::atomic::AtomicBool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// One queued job: the pencil plus the ticket to fill.
struct Job {
    a: Matrix,
    b: Matrix,
    ticket: Arc<TicketShared>,
}

/// Completion slot shared by a dispatcher and one waiter.
struct TicketShared {
    slot: Mutex<Option<Result<Arc<HtDecomposition>>>>,
    cv: Condvar,
    /// Concurrency-audit shadow (`coordinator::audit`): set when the
    /// dispatcher fills the ticket. A second fill — which would clobber a
    /// result a waiter may already have taken, or signal a job that ran
    /// twice — trips an assert. Absent from release builds without the
    /// `audit` feature.
    #[cfg(any(feature = "audit", debug_assertions))]
    filled: AtomicBool,
}

/// Handle to one submitted job; redeem with [`JobTicket::wait`].
pub struct JobTicket {
    shared: Arc<TicketShared>,
}

impl JobTicket {
    /// Block until the job completes and take its result. Every accepted
    /// submission completes — including across shutdown, which drains the
    /// lanes before the dispatchers exit — so `wait` cannot hang on a
    /// ticket that `submit` actually returned.
    pub fn wait(self) -> Result<Arc<HtDecomposition>> {
        let mut slot = self.shared.slot.lock().unwrap();
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.shared.cv.wait(slot).unwrap();
        }
    }

    /// Non-blocking probe: whether the result is ready (a `wait` after
    /// `true` returns immediately).
    pub fn is_ready(&self) -> bool {
        self.shared.slot.lock().unwrap().is_some()
    }
}

/// One bounded lane (per shard).
struct Lane {
    state: Mutex<LaneState>,
    /// Wakes the lane's dispatcher when a job arrives (or on shutdown).
    not_empty: Condvar,
    /// Wakes blocked submitters when the dispatcher pops (or on shutdown).
    not_full: Condvar,
}

struct LaneState {
    jobs: VecDeque<Job>,
    closed: bool,
}

impl Lane {
    fn new() -> Lane {
        Lane {
            state: Mutex::new(LaneState { jobs: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }
}

/// State shared by the queue owner, every [`SubmitHandle`] clone, and the
/// dispatcher threads.
struct QueueShared {
    router: ShardRouter,
    lanes: Vec<Lane>,
    capacity: usize,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
}

/// Queue-level counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    /// Jobs accepted into a lane.
    pub submitted: u64,
    /// Jobs whose ticket has been filled (success or typed error).
    pub completed: u64,
    /// Submissions refused because the queue was shut down.
    pub rejected: u64,
    /// Jobs currently waiting in the lanes.
    pub pending: usize,
}

/// Cloneable submission endpoint (see the [module docs](self)).
///
/// Handles stay valid after [`SubmitQueue::shutdown`]; their `submit`
/// calls then fail fast with a typed [`Error::Runtime`].
#[derive(Clone)]
pub struct SubmitHandle {
    shared: Arc<QueueShared>,
}

impl SubmitHandle {
    /// Enqueue one pencil for reduction. Blocks while the target shard's
    /// lane is full (backpressure); fails fast with [`Error::Shape`] on a
    /// non-square pencil or [`Error::Runtime`] after shutdown.
    pub fn submit(&self, a: Matrix, b: Matrix) -> Result<JobTicket> {
        check_square_pencil(&a, &b)?;
        let shard = self.shared.router.shard_for(a.rows());
        let lane = &self.shared.lanes[shard];
        let ticket = Arc::new(TicketShared {
            slot: Mutex::new(None),
            cv: Condvar::new(),
            #[cfg(any(feature = "audit", debug_assertions))]
            filled: AtomicBool::new(false),
        });
        {
            let mut st = lane.state.lock().unwrap();
            loop {
                // `closed` is re-checked at the top of every iteration —
                // i.e. after *every* wakeup from `not_full.wait`, spurious
                // or broadcast — while holding the lane mutex, and the
                // push below sits in the same critical section as the last
                // check. A submitter parked in `not_full` while the queue
                // closes therefore always lands in the rejection branch:
                // it can never act on a stale pre-close capacity check and
                // enqueue a job no dispatcher will drain (pinned by
                // `submit_racing_close_never_enqueues_after_shutdown`).
                if st.closed {
                    self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(Error::runtime(
                        "serve: submission queue is shut down; no new jobs accepted",
                    ));
                }
                if st.jobs.len() < self.shared.capacity {
                    break;
                }
                st = lane.not_full.wait(st).unwrap();
            }
            st.jobs.push_back(Job { a, b, ticket: ticket.clone() });
        }
        lane.not_empty.notify_one();
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(JobTicket { shared: ticket })
    }

    /// Queue-level counter snapshot.
    pub fn stats(&self) -> QueueStats {
        stats_of(&self.shared)
    }
}

fn stats_of(shared: &QueueShared) -> QueueStats {
    QueueStats {
        submitted: shared.submitted.load(Ordering::Relaxed),
        completed: shared.completed.load(Ordering::Relaxed),
        rejected: shared.rejected.load(Ordering::Relaxed),
        pending: shared.lanes.iter().map(|l| l.state.lock().unwrap().jobs.len()).sum(),
    }
}

/// Body of one per-shard dispatcher: pop a job from the lane (or park),
/// reduce it on this shard via the router (cache consulted first), fill
/// the ticket; exit only when the lane is drained *and* closed.
fn dispatcher_loop(shared: Arc<QueueShared>, shard: usize) {
    loop {
        let job = {
            let lane = &shared.lanes[shard];
            let mut st = lane.state.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    // Wake one blocked submitter into the freed slot.
                    lane.not_full.notify_one();
                    break Some(job);
                }
                if st.closed {
                    break None;
                }
                st = lane.not_empty.wait(st).unwrap();
            }
        };
        let Some(job) = job else {
            return; // drained and closed: graceful exit
        };
        // A panicking reduction must not kill the dispatcher (its lane
        // would silently hang every later waiter): trap it into the
        // ticket as a typed error and keep serving.
        let result = catch_unwind(AssertUnwindSafe(|| {
            shared.router.reduce_on(shard, &job.a, &job.b)
        }))
        .unwrap_or_else(|_| Err(Error::runtime("serve: reduction panicked; job dropped")));
        shared.completed.fetch_add(1, Ordering::Relaxed);
        // Ticket lifecycle audit: every accepted ticket is filled
        // (completed-or-poisoned) exactly once. Jobs are moved out of the
        // lane by `pop_front`, so a double fill can only mean a duplicated
        // job — catch it here rather than as a clobbered result.
        #[cfg(any(feature = "audit", debug_assertions))]
        assert!(
            !job.ticket.filled.swap(true, Ordering::Relaxed),
            "concurrency audit failed: serve ticket filled twice (shard {shard})"
        );
        *job.ticket.slot.lock().unwrap() = Some(result);
        job.ticket.cv.notify_all();
    }
}

/// The owning half of the serving queue: holds the router, the lanes and
/// the dispatcher threads. Create with [`SubmitQueue::new`], hand out
/// [`SubmitHandle`]s via [`SubmitQueue::handle`], stop with
/// [`SubmitQueue::shutdown`] (drop runs the same protocol).
pub struct SubmitQueue {
    shared: Arc<QueueShared>,
    dispatchers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for SubmitQueue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SubmitQueue")
            .field("shards", &self.shared.lanes.len())
            .field("capacity", &self.shared.capacity)
            .field("stats", &stats_of(&self.shared))
            .finish_non_exhaustive()
    }
}

impl SubmitQueue {
    /// Spawn the serving tier around a router: one lane + one named
    /// dispatcher thread (`paraht-serve-<shard>`) per shard, each lane
    /// bounded at the router's configured `queue_capacity`.
    pub fn new(router: ShardRouter) -> SubmitQueue {
        let capacity = router.config().queue_capacity;
        let shards = router.shard_count();
        let shared = Arc::new(QueueShared {
            router,
            lanes: (0..shards).map(|_| Lane::new()).collect(),
            capacity,
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        });
        let dispatchers = (0..shards)
            .map(|shard| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("paraht-serve-{shard}"))
                    .spawn(move || dispatcher_loop(sh, shard))
                    .expect("spawn serve dispatcher")
            })
            .collect();
        SubmitQueue { shared, dispatchers }
    }

    /// A new submission endpoint (cheap to clone, one per client thread).
    pub fn handle(&self) -> SubmitHandle {
        SubmitHandle { shared: self.shared.clone() }
    }

    /// The router behind the queue (for stats and direct synchronous use).
    pub fn router(&self) -> &ShardRouter {
        &self.shared.router
    }

    /// Queue-level counter snapshot.
    pub fn stats(&self) -> QueueStats {
        stats_of(&self.shared)
    }

    /// Graceful shutdown (the documented protocol): close every lane,
    /// wake everyone, join every dispatcher. Already-accepted jobs are
    /// drained and their tickets filled; concurrent and later submissions
    /// fail with a typed error. Consuming `self` makes "no further
    /// owner-side use" a compile-time fact; outstanding [`SubmitHandle`]s
    /// remain safe to call.
    pub fn shutdown(self) {
        drop(self);
    }

    fn close_and_join(&mut self) {
        for lane in &self.shared.lanes {
            lane.state.lock().unwrap().closed = true;
            lane.not_empty.notify_all();
            lane.not_full.notify_all();
        }
        for h in self.dispatchers.drain(..) {
            // Dispatchers trap job panics, so join failure is unreachable;
            // don't double-panic during drop if it somehow happens.
            let _ = h.join();
        }
    }
}

impl Drop for SubmitQueue {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::reduce_seq;
    use crate::config::Config;
    use crate::pencil::random::random_pencil;
    use crate::serve::router::ServeConfig;
    use crate::util::proptest::max_abs_diff;
    use crate::util::rng::Rng;

    fn small_queue(shards: usize, capacity: usize) -> SubmitQueue {
        let cfg = ServeConfig {
            shards,
            queue_capacity: capacity,
            base: Config { r: 4, p: 2, q: 2, ..Config::default() },
            ..ServeConfig::default()
        };
        SubmitQueue::new(ShardRouter::new(cfg).unwrap())
    }

    #[test]
    fn submit_wait_roundtrip_is_bitwise_the_oracle() {
        let mut rng = Rng::new(0x0E_01);
        let q = small_queue(2, 8);
        let h = q.handle();
        let p = random_pencil(14, &mut rng);
        let ticket = h.submit(p.a.clone(), p.b.clone()).unwrap();
        let d = ticket.wait().unwrap();
        let eff = q.router().config().base.clipped_for(14);
        let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
        assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0);
        assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0);
        let stats = q.stats();
        assert_eq!((stats.submitted, stats.completed, stats.rejected), (1, 1, 0));
        q.shutdown();
    }

    #[test]
    fn shape_error_fails_fast_without_a_ticket() {
        let q = small_queue(1, 4);
        let h = q.handle();
        let e = h.submit(Matrix::zeros(3, 4), Matrix::zeros(3, 3)).unwrap_err();
        assert!(matches!(e, Error::Shape(_)));
        assert_eq!(q.stats().submitted, 0);
    }

    #[test]
    fn submit_after_shutdown_is_a_typed_error() {
        let q = small_queue(2, 4);
        let h = q.handle();
        q.shutdown();
        let mut rng = Rng::new(0x0E_02);
        let p = random_pencil(8, &mut rng);
        let e = h.submit(p.a, p.b).unwrap_err();
        assert!(matches!(e, Error::Runtime(_)), "{e}");
        assert_eq!(h.stats().rejected, 1);
    }

    #[test]
    fn tickets_accepted_before_shutdown_complete() {
        let mut rng = Rng::new(0x0E_03);
        let q = small_queue(1, 32);
        let h = q.handle();
        let pencils: Vec<_> = (0..6).map(|_| random_pencil(10, &mut rng)).collect();
        let tickets: Vec<_> = pencils
            .iter()
            .map(|p| h.submit(p.a.clone(), p.b.clone()).unwrap())
            .collect();
        q.shutdown(); // drains the lane before the dispatcher exits
        for (p, t) in pencils.iter().zip(tickets) {
            let d = t.wait().expect("accepted job completes across shutdown");
            let eff = Config { r: 4, p: 2, q: 2, ..Config::default() };
            let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
            assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0);
        }
    }

    #[test]
    fn submit_racing_close_never_enqueues_after_shutdown() {
        // Regression for the shutdown race: submitters blocked in
        // `not_full.wait` on a full lane while the queue closes must
        // observe the closed flag on wakeup (under the lane mutex) and
        // fail with the typed error — never push a job that no dispatcher
        // will drain. A capacity-1 single-shard lane forces the blocking.
        let mut rng = Rng::new(0x0E_05);
        let q = small_queue(1, 1);
        let h = q.handle();
        let pencils: Vec<_> = (0..24).map(|_| random_pencil(16, &mut rng)).collect();
        std::thread::scope(|s| {
            let workers: Vec<_> = pencils
                .chunks(6)
                .map(|chunk| {
                    let h = h.clone();
                    s.spawn(move || {
                        let mut oks = Vec::new();
                        let mut errs = 0u64;
                        for p in chunk {
                            match h.submit(p.a.clone(), p.b.clone()) {
                                Ok(t) => oks.push(t),
                                Err(e) => {
                                    assert!(
                                        matches!(e, Error::Runtime(_)),
                                        "closed-lane rejection must be typed: {e}"
                                    );
                                    errs += 1;
                                }
                            }
                        }
                        (oks, errs)
                    })
                })
                .collect();
            // Let some submissions land and some block, then close while
            // the rest race the flag.
            std::thread::sleep(std::time::Duration::from_millis(2));
            q.shutdown();
            let mut total_errs = 0;
            for w in workers {
                let (oks, errs) = w.join().unwrap();
                total_errs += errs;
                for t in oks {
                    t.wait().expect("every accepted job completes across shutdown");
                }
            }
            let stats = h.stats();
            assert_eq!(
                stats.submitted, stats.completed,
                "a job enqueued after close would leave submitted > completed"
            );
            assert_eq!(stats.rejected, total_errs, "every rejection surfaced as an error");
            assert_eq!(stats.pending, 0, "no job left stranded in a lane");
        });
    }

    #[test]
    fn is_ready_becomes_true_after_wait_would_succeed() {
        let mut rng = Rng::new(0x0E_04);
        let q = small_queue(1, 4);
        let h = q.handle();
        let p = random_pencil(8, &mut rng);
        let ticket = h.submit(p.a, p.b).unwrap();
        // Shutdown drains the lane, so afterwards the ticket must be ready.
        q.shutdown();
        assert!(ticket.is_ready());
        ticket.wait().unwrap();
    }
}
