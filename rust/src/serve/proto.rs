//! The length-prefixed binary wire protocol of the serving front door.
//!
//! One frame format is spoken on every process boundary this crate has:
//! TCP / Unix-domain connections into [`crate::serve::net::NetServer`],
//! and the stdin/stdout pipes between a
//! [`crate::serve::supervisor::ShardSupervisor`] and its `--shard-worker`
//! children. Keeping the codec in one module (and the framing fully
//! symmetric — both sides use the same [`read_frame`] / [`write_frame`])
//! is what lets the supervisor test a child with exactly the bytes a
//! network client would produce.
//!
//! ## Frame layout
//!
//! ```text
//! [u32 LE  payload_len]                    — excludes these 4 bytes
//! [u8      version]     = PROTO_VERSION
//! [u8      kind]        = Submit | ResultOk | ResultErr | StatsReq | StatsReply
//! [u64 LE  req_id]
//! [kind-specific payload …]
//! ```
//!
//! Matrices travel as `[u32 rows][u32 cols]` followed by `rows*cols`
//! `u64` LE IEEE-754 **bit patterns** in column-major order — never a
//! decimal round trip, because the serving tier's whole contract is
//! bitwise equality with [`crate::api::reduce_seq`]. Configs travel as
//! [`WireConfig`] (the tuning subset that participates in the determinism
//! contract); the all-zero encoding is the "use the server's configured
//! tuning" sentinel.
//!
//! ## Error discipline
//!
//! Decoding is total: every malformed input — truncated stream, oversized
//! or undersized length prefix, unknown version or kind, dimension
//! overflow — comes back as a typed [`Error::Protocol`], never a panic
//! and never a partially-consumed *well-formed* stream. Clean EOF **at a
//! frame boundary** is `Ok(None)` (how workers notice supervisor
//! shutdown); EOF anywhere inside a frame is a protocol error. After any
//! decode error the stream position is unspecified, so peers treat
//! protocol errors as connection-fatal — documented here so nobody tries
//! to resynchronize mid-stream.

use crate::config::Config;
use crate::error::{Error, Result};
use crate::linalg::matrix::Matrix;
use std::io::{Read, Write};

/// Protocol version carried in every frame. Bump on any layout change;
/// decoders reject other versions with a typed error rather than
/// misparse.
pub const PROTO_VERSION: u8 = 1;

/// Hard bound on one frame's payload (256 MiB — a ~2896×2896 four-factor
/// result still fits). A length prefix above this is rejected *before*
/// any payload is read, so a corrupt or hostile prefix cannot make the
/// server allocate unboundedly.
pub const MAX_FRAME_BYTES: usize = 256 << 20;

/// Matrix dimension bound (per side). `MAX_DIM² · 8` bytes stays inside
/// [`MAX_FRAME_BYTES`]; anything larger is a malformed frame by
/// definition.
const MAX_DIM: u32 = 4096;

// Frame kind tags (wire bytes).
const KIND_SUBMIT: u8 = 1;
const KIND_RESULT_OK: u8 = 2;
const KIND_RESULT_ERR: u8 = 3;
const KIND_STATS_REQ: u8 = 4;
const KIND_STATS_REPLY: u8 = 5;

/// The reduction-tuning subset that travels with a `Submit` frame: the
/// parameters that participate in the bitwise-determinism contract
/// (`r`, `p`, `q`, lookahead). Thread counts and scheduling mode are
/// deliberately absent — they are output-invariant, so they remain the
/// *server's* capacity decision, never the client's.
///
/// The all-zero value ([`WireConfig::is_default`]) is the wire sentinel
/// for "run my job under the server's configured tuning" — what
/// [`crate::serve::net::NetClient::reduce`] sends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireConfig {
    /// Stage-1 bandwidth `r` (0 = server default).
    pub r: u32,
    /// Stage-1 block-height multiplier `p` (0 = server default).
    pub p: u32,
    /// Stage-2 sweep-group size `q` (0 = server default).
    pub q: u32,
    /// Stage-2 lookahead gate (ignored when the sentinel is in effect).
    pub lookahead: bool,
}

impl WireConfig {
    /// The "server default" sentinel.
    pub fn default_sentinel() -> WireConfig {
        WireConfig { r: 0, p: 0, q: 0, lookahead: false }
    }

    /// Whether this is the all-zero "server default" sentinel.
    pub fn is_default(&self) -> bool {
        self.r == 0 && self.p == 0 && self.q == 0
    }

    /// Capture the determinism-relevant tuning of a concrete [`Config`]
    /// (what the supervisor sends its workers: always explicit, never the
    /// sentinel, so a worker needs no config of its own).
    pub fn from_config(cfg: &Config) -> WireConfig {
        WireConfig {
            r: cfg.r.min(u32::MAX as usize) as u32,
            p: cfg.p.min(u32::MAX as usize) as u32,
            q: cfg.q.min(u32::MAX as usize) as u32,
            lookahead: cfg.lookahead,
        }
    }

    /// Materialize onto a base config: the sentinel returns `base`
    /// unchanged; an explicit wire tuning overrides `r`/`p`/`q`/
    /// `lookahead` and keeps everything capacity-related (threads,
    /// slices, scheduling, kernel) from `base`.
    pub fn apply_to(&self, base: &Config) -> Config {
        if self.is_default() {
            return base.clone();
        }
        Config {
            r: self.r as usize,
            p: self.p as usize,
            q: self.q as usize,
            lookahead: self.lookahead,
            ..base.clone()
        }
    }
}

/// One decoded protocol frame (see the [module docs](self) for layout).
#[derive(Debug)]
pub enum Frame {
    /// Client → server: reduce this pencil under `cfg`.
    Submit {
        /// Client-chosen id echoed in the reply.
        req_id: u64,
        /// Requested tuning (sentinel = server default).
        cfg: WireConfig,
        /// Left pencil matrix `A`.
        a: Matrix,
        /// Right pencil matrix `B`.
        b: Matrix,
    },
    /// Server → client: the four factors plus phase timings.
    ResultOk {
        /// Echo of the submit's id.
        req_id: u64,
        /// Stage-1 wall-clock seconds (informational; not bitwise-pinned).
        stage1_secs: f64,
        /// Stage-2 wall-clock seconds.
        stage2_secs: f64,
        /// Hessenberg factor `H`.
        h: Matrix,
        /// Triangular factor `T`.
        t: Matrix,
        /// Left orthogonal factor `Q`.
        q: Matrix,
        /// Right orthogonal factor `Z`.
        z: Matrix,
    },
    /// Server → client: the job failed with this typed error.
    ResultErr {
        /// Echo of the submit's id.
        req_id: u64,
        /// The typed failure (error kind survives the wire round trip).
        err: Error,
    },
    /// Client → server: report serving statistics.
    StatsReq {
        /// Client-chosen id echoed in the reply.
        req_id: u64,
    },
    /// Server → client: statistics as a JSON document.
    StatsReply {
        /// Echo of the request's id.
        req_id: u64,
        /// JSON text (schema documented in EXPERIMENTS.md §Serving).
        json: String,
    },
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_matrix(buf: &mut Vec<u8>, m: &Matrix) {
    put_u32(buf, m.rows() as u32);
    put_u32(buf, m.cols() as u32);
    for &x in m.data() {
        put_u64(buf, x.to_bits());
    }
}

fn put_wire_config(buf: &mut Vec<u8>, cfg: &WireConfig) {
    put_u32(buf, cfg.r);
    put_u32(buf, cfg.p);
    put_u32(buf, cfg.q);
    buf.push(u8::from(cfg.lookahead));
}

/// Typed-error code map (wire byte ↔ [`Error`] variant). `Io` collapses
/// to its message — an `io::Error` does not round-trip and the receiving
/// side only needs the classification.
fn error_code(e: &Error) -> u8 {
    match e {
        Error::Shape(_) => 1,
        Error::Config(_) => 2,
        Error::Numerical(_) => 3,
        Error::Runtime(_) => 4,
        Error::Io(_) => 5,
        Error::Overloaded(_) => 6,
        Error::ShardDown(_) => 7,
        Error::Protocol(_) => 8,
    }
}

fn error_from_code(code: u8, msg: String) -> Error {
    match code {
        1 => Error::Shape(msg),
        2 => Error::Config(msg),
        3 => Error::Numerical(msg),
        4 => Error::Runtime(msg),
        5 => Error::Io(std::io::Error::other(msg)),
        6 => Error::Overloaded(msg),
        7 => Error::ShardDown(msg),
        8 => Error::Protocol(msg),
        // Unknown code: a newer peer's variant — degrade to Runtime
        // rather than failing the decode (the message is preserved).
        _ => Error::Runtime(msg),
    }
}

/// Encode and write one frame (length prefix + version + kind + payload),
/// then flush. Serialization is into one buffer so the frame hits the
/// stream as a single write — a reader never observes a torn prefix from
/// a non-panicking writer.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let mut payload = Vec::new();
    let kind = match frame {
        Frame::Submit { req_id, cfg, a, b } => {
            put_u64(&mut payload, *req_id);
            put_wire_config(&mut payload, cfg);
            put_matrix(&mut payload, a);
            put_matrix(&mut payload, b);
            KIND_SUBMIT
        }
        Frame::ResultOk { req_id, stage1_secs, stage2_secs, h, t, q, z } => {
            put_u64(&mut payload, *req_id);
            put_u64(&mut payload, stage1_secs.to_bits());
            put_u64(&mut payload, stage2_secs.to_bits());
            for m in [h, t, q, z] {
                put_matrix(&mut payload, m);
            }
            KIND_RESULT_OK
        }
        Frame::ResultErr { req_id, err } => {
            put_u64(&mut payload, *req_id);
            payload.push(error_code(err));
            let msg = err.to_string();
            put_u32(&mut payload, msg.len() as u32);
            payload.extend_from_slice(msg.as_bytes());
            KIND_RESULT_ERR
        }
        Frame::StatsReq { req_id } => {
            put_u64(&mut payload, *req_id);
            KIND_STATS_REQ
        }
        Frame::StatsReply { req_id, json } => {
            put_u64(&mut payload, *req_id);
            put_u32(&mut payload, json.len() as u32);
            payload.extend_from_slice(json.as_bytes());
            KIND_STATS_REPLY
        }
    };
    let len = payload.len() + 2; // version + kind
    if len > MAX_FRAME_BYTES {
        return Err(Error::protocol(format!(
            "outgoing frame of {len} bytes exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})"
        )));
    }
    let mut buf = Vec::with_capacity(4 + len);
    put_u32(&mut buf, len as u32);
    buf.push(PROTO_VERSION);
    buf.push(kind);
    buf.extend_from_slice(&payload);
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

/// Cursor over one fully-read payload: every accessor is bounds-checked
/// and returns a typed protocol error on underrun, so a short payload can
/// never panic the decoder.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| Error::protocol("truncated frame payload"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::protocol("frame string is not valid UTF-8"))
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u32()?;
        let cols = self.u32()?;
        if rows > MAX_DIM || cols > MAX_DIM {
            return Err(Error::protocol(format!(
                "matrix dims {rows}x{cols} exceed the wire bound ({MAX_DIM})"
            )));
        }
        let mut m = Matrix::zeros(rows as usize, cols as usize);
        for x in m.data_mut() {
            *x = f64::from_bits(self.u64()?);
        }
        Ok(m)
    }

    fn wire_config(&mut self) -> Result<WireConfig> {
        Ok(WireConfig {
            r: self.u32()?,
            p: self.u32()?,
            q: self.u32()?,
            lookahead: self.u8()? != 0,
        })
    }

    fn finish(self) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(Error::protocol(format!(
                "frame payload has {} trailing bytes",
                self.buf.len() - self.pos
            )))
        }
    }
}

/// Read exactly `buf.len()` bytes. `Ok(false)` on EOF *before the first
/// byte* (a clean boundary); EOF after at least one byte is a truncation
/// and comes back as [`Error::Protocol`].
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(false);
                }
                return Err(Error::protocol(format!(
                    "stream truncated mid-frame ({filled} of {} bytes)",
                    buf.len()
                )));
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Io(e)),
        }
    }
    Ok(true)
}

/// Read one frame. Returns `Ok(None)` on clean EOF at a frame boundary
/// (the peer closed between frames — normal shutdown); any other
/// malformation is a typed [`Error::Protocol`]. An oversized or
/// undersized length prefix is rejected before its payload is read; see
/// the [module docs](self) for why all decode errors are
/// connection-fatal.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut len_buf = [0u8; 4];
    if !read_exact_or_eof(r, &mut len_buf)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(Error::protocol(format!(
            "frame length {len} exceeds MAX_FRAME_BYTES ({MAX_FRAME_BYTES})"
        )));
    }
    if len < 2 {
        return Err(Error::protocol(format!("frame length {len} below the 2-byte header")));
    }
    let mut body = vec![0u8; len];
    if !read_exact_or_eof(r, &mut body)? {
        return Err(Error::protocol("stream truncated after length prefix"));
    }
    let version = body[0];
    if version != PROTO_VERSION {
        return Err(Error::protocol(format!(
            "unsupported protocol version {version} (this build speaks {PROTO_VERSION})"
        )));
    }
    let kind = body[1];
    let mut c = Cursor::new(&body[2..]);
    let frame = match kind {
        KIND_SUBMIT => {
            let req_id = c.u64()?;
            let cfg = c.wire_config()?;
            let a = c.matrix()?;
            let b = c.matrix()?;
            Frame::Submit { req_id, cfg, a, b }
        }
        KIND_RESULT_OK => {
            let req_id = c.u64()?;
            let stage1_secs = f64::from_bits(c.u64()?);
            let stage2_secs = f64::from_bits(c.u64()?);
            let h = c.matrix()?;
            let t = c.matrix()?;
            let q = c.matrix()?;
            let z = c.matrix()?;
            Frame::ResultOk { req_id, stage1_secs, stage2_secs, h, t, q, z }
        }
        KIND_RESULT_ERR => {
            let req_id = c.u64()?;
            let code = c.u8()?;
            let msg = c.string()?;
            Frame::ResultErr { req_id, err: error_from_code(code, msg) }
        }
        KIND_STATS_REQ => Frame::StatsReq { req_id: c.u64()? },
        KIND_STATS_REPLY => {
            let req_id = c.u64()?;
            let json = c.string()?;
            Frame::StatsReply { req_id, json }
        }
        other => return Err(Error::protocol(format!("unknown frame kind {other}"))),
    };
    c.finish()?;
    Ok(Some(frame))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pencil::random::random_pencil;
    use crate::util::proptest::max_abs_diff;
    use crate::util::rng::Rng;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        let mut r = buf.as_slice();
        let decoded = read_frame(&mut r).unwrap().expect("one frame present");
        assert!(r.is_empty(), "decode must consume the whole frame");
        decoded
    }

    #[test]
    fn submit_roundtrip_is_bitwise() {
        let mut rng = Rng::new(0x9_01);
        for n in [1usize, 2, 7, 23] {
            let p = random_pencil(n, &mut rng);
            let f = Frame::Submit {
                req_id: 42,
                cfg: WireConfig { r: 4, p: 2, q: 2, lookahead: true },
                a: p.a.clone(),
                b: p.b.clone(),
            };
            match roundtrip(&f) {
                Frame::Submit { req_id, cfg, a, b } => {
                    assert_eq!(req_id, 42);
                    assert_eq!(cfg, WireConfig { r: 4, p: 2, q: 2, lookahead: true });
                    assert_eq!(max_abs_diff(&a, &p.a), 0.0, "n={n}: A bits");
                    assert_eq!(max_abs_diff(&b, &p.b), 0.0, "n={n}: B bits");
                }
                other => panic!("wrong kind: {other:?}"),
            }
        }
    }

    #[test]
    fn result_ok_roundtrip_preserves_special_values() {
        // The wire format carries bit patterns, so NaN payloads, signed
        // zeros and infinities all survive — bitwise, not just value-wise.
        let mut m = Matrix::zeros(2, 2);
        m.data_mut().copy_from_slice(&[f64::NAN, -0.0, f64::INFINITY, f64::MIN_POSITIVE]);
        let f = Frame::ResultOk {
            req_id: 7,
            stage1_secs: 0.25,
            stage2_secs: f64::NAN,
            h: m.clone(),
            t: m.clone(),
            q: m.clone(),
            z: m.clone(),
        };
        match roundtrip(&f) {
            Frame::ResultOk { req_id, stage1_secs, stage2_secs, h, .. } => {
                assert_eq!(req_id, 7);
                assert_eq!(stage1_secs.to_bits(), 0.25f64.to_bits());
                assert!(stage2_secs.is_nan());
                for (got, want) in h.data().iter().zip(m.data()) {
                    assert_eq!(got.to_bits(), want.to_bits());
                }
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn error_frames_keep_their_variant_across_the_wire() {
        let cases: Vec<Error> = vec![
            Error::shape("bad pencil"),
            Error::config("bad tuning"),
            Error::numerical("diverged"),
            Error::runtime("panicked"),
            Error::Io(std::io::Error::other("pipe")),
            Error::overloaded("lane full"),
            Error::shard_down("child died"),
            Error::protocol("bad frame"),
        ];
        for err in cases {
            let want = std::mem::discriminant(&err);
            let f = Frame::ResultErr { req_id: 1, err };
            match roundtrip(&f) {
                Frame::ResultErr { err, .. } => {
                    assert_eq!(std::mem::discriminant(&err), want, "{err}");
                }
                other => panic!("wrong kind: {other:?}"),
            }
        }
    }

    #[test]
    fn stats_frames_roundtrip() {
        match roundtrip(&Frame::StatsReq { req_id: 9 }) {
            Frame::StatsReq { req_id } => assert_eq!(req_id, 9),
            other => panic!("wrong kind: {other:?}"),
        }
        let json = "{\"hits\": 3}".to_string();
        match roundtrip(&Frame::StatsReply { req_id: 9, json: json.clone() }) {
            Frame::StatsReply { req_id, json: j } => {
                assert_eq!(req_id, 9);
                assert_eq!(j, json);
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn clean_eof_at_boundary_is_none_mid_frame_is_protocol_error() {
        // Empty stream: clean boundary.
        assert!(read_frame(&mut (&[][..])).unwrap().is_none());
        // Truncations at every prefix of a valid frame: typed error, no
        // panic (the property the codec tests pin for the whole family).
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::StatsReq { req_id: 3 }).unwrap();
        for cut in 1..buf.len() {
            let e = read_frame(&mut (&buf[..cut])).unwrap_err();
            assert!(matches!(e, Error::Protocol(_)), "cut={cut}: {e}");
        }
    }

    #[test]
    fn oversized_and_undersized_prefixes_are_rejected_without_reading() {
        // Length prefix over the bound: rejected before any payload read.
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_le_bytes();
        let e = read_frame(&mut (&huge[..])).unwrap_err();
        assert!(matches!(e, Error::Protocol(_)), "{e}");
        // Below the 2-byte version+kind header.
        let tiny = 1u32.to_le_bytes();
        let e = read_frame(&mut (&tiny[..])).unwrap_err();
        assert!(matches!(e, Error::Protocol(_)), "{e}");
    }

    #[test]
    fn bad_version_unknown_kind_and_bad_dims_are_typed_errors() {
        // Version mismatch.
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::StatsReq { req_id: 1 }).unwrap();
        buf[4] = PROTO_VERSION + 1;
        assert!(matches!(read_frame(&mut buf.as_slice()).unwrap_err(), Error::Protocol(_)));
        // Unknown kind byte.
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::StatsReq { req_id: 1 }).unwrap();
        buf[5] = 0xEE;
        assert!(matches!(read_frame(&mut buf.as_slice()).unwrap_err(), Error::Protocol(_)));
        // Submit frame whose matrix header claims dims over the wire
        // bound: rejected by the dim check, not by an allocation attempt.
        let mut payload = Vec::new();
        put_u64(&mut payload, 1); // req_id
        put_wire_config(&mut payload, &WireConfig::default_sentinel());
        put_u32(&mut payload, MAX_DIM + 1);
        put_u32(&mut payload, 1);
        let mut buf = Vec::new();
        put_u32(&mut buf, (payload.len() + 2) as u32);
        buf.push(PROTO_VERSION);
        buf.push(KIND_SUBMIT);
        buf.extend_from_slice(&payload);
        let e = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(matches!(e, Error::Protocol(_)), "{e}");
    }

    #[test]
    fn trailing_garbage_inside_a_frame_is_rejected() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::StatsReq { req_id: 1 }).unwrap();
        // Grow the declared payload by one byte of garbage.
        let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) + 1;
        buf[..4].copy_from_slice(&len.to_le_bytes());
        buf.push(0xAB);
        let e = read_frame(&mut buf.as_slice()).unwrap_err();
        assert!(matches!(e, Error::Protocol(_)), "{e}");
    }

    #[test]
    fn back_to_back_frames_decode_in_order() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::StatsReq { req_id: 1 }).unwrap();
        write_frame(&mut buf, &Frame::StatsReq { req_id: 2 }).unwrap();
        let mut r = buf.as_slice();
        for want in [1u64, 2] {
            match read_frame(&mut r).unwrap().unwrap() {
                Frame::StatsReq { req_id } => assert_eq!(req_id, want),
                other => panic!("wrong kind: {other:?}"),
            }
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "then a clean boundary");
    }

    #[test]
    fn wire_config_sentinel_and_override_semantics() {
        let base = Config { r: 8, p: 4, q: 4, ..Config::default() };
        let sentinel = WireConfig::default_sentinel();
        assert!(sentinel.is_default());
        let applied = sentinel.apply_to(&base);
        assert_eq!((applied.r, applied.p, applied.q), (8, 4, 4));
        let explicit = WireConfig { r: 6, p: 2, q: 3, lookahead: false };
        assert!(!explicit.is_default());
        let applied = explicit.apply_to(&base);
        assert_eq!((applied.r, applied.p, applied.q), (6, 2, 3));
        assert!(!applied.lookahead);
        assert_eq!(applied.threads, base.threads, "capacity knobs stay the server's");
        let captured = WireConfig::from_config(&base);
        assert_eq!((captured.r, captured.p, captured.q), (8, 4, 4));
        assert!(captured.lookahead);
    }
}
