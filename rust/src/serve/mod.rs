//! The serving layer: sharded sessions, an async submission queue, and a
//! content-addressed result cache — the batch-throughput tier on top of
//! [`crate::api::HtSession`].
//!
//! One warm session makes one reduction fast; this module is what turns
//! that into *sustained throughput* when many pencils flow through the
//! process:
//!
//! * [`ShardRouter`] ([`router`]) — N sessions, requests routed by size
//!   class so each shard's per-`n` workspace stays hot; shards share the
//!   persistent worker pool (`threads_per_shard` executors per job).
//! * [`SubmitQueue`] / [`SubmitHandle`] / [`JobTicket`] ([`queue`]) — a
//!   bounded per-shard MPSC with one dispatcher thread per shard and
//!   condvar-backed tickets; shutdown drains every accepted job
//!   (the pool's park/notify protocol, adapted).
//! * [`ResultCache`] ([`cache`]) keyed by [`hash`] fingerprints — bitwise
//!   repeat submissions are answered without running anything, soundly:
//!   full key bytes are compared on every hit, the 64-bit hash only
//!   buckets.
//!
//! Everything is pure std, like the rest of the crate, and everything is
//! pinned to the same bitwise contract: a result served through
//! router + queue + cache is bit-for-bit what [`crate::api::reduce_seq`]
//! returns for that pencil under the effective (band-clipped) config —
//! `tests/serve.rs` asserts exactly that, including under mixed-size
//! floods, cache eviction pressure, and shutdown mid-flood.
//!
//! ```no_run
//! use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
//! # use paraht::pencil::random::random_pencil;
//! # use paraht::util::rng::Rng;
//! let router = ShardRouter::new(ServeConfig::from_env()).unwrap();
//! let queue = SubmitQueue::new(router);
//! let handle = queue.handle(); // Clone one per client thread
//! let mut rng = Rng::new(7);
//! let p = random_pencil(64, &mut rng);
//! let ticket = handle.submit(p.a, p.b).unwrap(); // routed + enqueued
//! let d = ticket.wait().unwrap();                // bitwise = oracle
//! assert_eq!(d.h.rows(), 64);
//! println!("cache: {:?}", queue.router().stats().cache);
//! queue.shutdown();                              // drains, then joins
//! ```

pub mod cache;
pub mod hash;
pub mod queue;
pub mod router;

pub use cache::{CacheKey, CacheStats, ResultCache};
pub use hash::{pencil_fingerprint, FxHasher64};
pub use queue::{JobTicket, QueueStats, SubmitHandle, SubmitQueue};
pub use router::{RouterStats, ServeConfig, ShardRouter};
