//! The serving layer: sharded sessions, an async submission queue, and a
//! content-addressed result cache — the batch-throughput tier on top of
//! [`crate::api::HtSession`].
//!
//! One warm session makes one reduction fast; this module is what turns
//! that into *sustained throughput* when many pencils flow through the
//! process:
//!
//! * [`ShardRouter`] ([`router`]) — N sessions, requests routed by size
//!   class so each shard's per-`n` workspace stays hot; shards share the
//!   persistent worker pool (`threads_per_shard` executors per job).
//! * [`SubmitQueue`] / [`SubmitHandle`] / [`JobTicket`] ([`queue`]) — a
//!   bounded per-shard MPSC with one dispatcher thread per shard and
//!   condvar-backed tickets; shutdown drains every accepted job
//!   (the pool's park/notify protocol, adapted).
//! * [`ResultCache`] ([`cache`]) keyed by [`hash`] fingerprints — bitwise
//!   repeat submissions are answered without running anything, soundly:
//!   full key bytes are compared on every hit, the 64-bit hash only
//!   buckets.
//!
//! PR 9 pushes the same tier across process and machine boundaries:
//!
//! * [`proto`] — a length-prefixed binary frame codec (f64 *bit
//!   patterns*, never decimal text) with typed, connection-fatal decode
//!   errors and a bounded frame size.
//! * [`NetServer`] / [`NetClient`] ([`net`]) — a blocking acceptor pool
//!   over TCP or Unix sockets that routes decoded jobs through the same
//!   [`SubmitHandle`], with admission control (bounded-wait lane entry,
//!   typed `Overloaded` shed) so a flooded server degrades loudly, not
//!   slowly.
//! * [`ShardSupervisor`] ([`supervisor`]) — each size-class shard as a
//!   *child process* speaking the same frames over stdin/stdout,
//!   restarted on crash with capped exponential backoff; a dead child
//!   fails only its in-flight job, with a typed `ShardDown`.
//! * [`ServeMetrics`] ([`metrics`]) — lock-cheap atomic log2-bucket
//!   latency histograms per size class, recorded at ticket completion
//!   and exported through the protocol's `Stats` request.
//!
//! The tier is self-tuning ([`crate::tune`]): `PALLAS_PROFILE` (or
//! [`ServeConfig::profile`]) loads a per-size-class tuned profile at
//! startup, every shard session shares one hot-swappable profile slot
//! ([`ShardRouter::reload_profile`]), and cache keys always carry the
//! effective config a job actually ran with — so tuned geometry differing
//! across size classes (or changing under a live reload) can never alias
//! cache entries. `tests/tune.rs` pins all of it.
//!
//! Everything is pure std, like the rest of the crate, and everything is
//! pinned to the same bitwise contract: a result served through
//! router + queue + cache — or through a socket, or through a supervised
//! child process — is bit-for-bit what [`crate::api::reduce_seq`]
//! returns for that pencil under the effective (band-clipped) config —
//! `tests/serve.rs`, `tests/serve_net.rs`, and `tests/serve_proc.rs`
//! assert exactly that, including under mixed-size floods, cache
//! eviction pressure, shutdown mid-flood, and a child killed mid-job.
//!
//! ```no_run
//! use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
//! # use paraht::pencil::random::random_pencil;
//! # use paraht::util::rng::Rng;
//! let router = ShardRouter::new(ServeConfig::from_env()).unwrap();
//! let queue = SubmitQueue::new(router);
//! let handle = queue.handle(); // Clone one per client thread
//! let mut rng = Rng::new(7);
//! let p = random_pencil(64, &mut rng);
//! let ticket = handle.submit(p.a, p.b).unwrap(); // routed + enqueued
//! let d = ticket.wait().unwrap();                // bitwise = oracle
//! assert_eq!(d.h.rows(), 64);
//! println!("cache: {:?}", queue.router().stats().cache);
//! queue.shutdown();                              // drains, then joins
//! ```

pub mod cache;
pub mod hash;
pub mod metrics;
pub mod net;
pub mod proto;
pub mod queue;
pub mod router;
pub mod supervisor;

pub use cache::{CacheKey, CacheStats, ResultCache};
pub use hash::{pencil_fingerprint, size_class_shard, FxHasher64};
pub use metrics::{HistogramSnapshot, LatencyHistogram, ServeMetrics, SizeClass};
pub use net::{NetClient, NetConfig, NetServer};
pub use proto::{Frame, WireConfig, MAX_FRAME_BYTES, PROTO_VERSION};
pub use queue::{JobTicket, QueueStats, SubmitHandle, SubmitQueue};
pub use router::{RouterStats, ServeConfig, ShardRouter};
pub use supervisor::{
    worker_main, ShardProcStats, ShardSupervisor, SupervisorConfig, SupervisorStats,
};
