//! The network front door: a blocking frame-protocol server over TCP or
//! Unix-domain sockets, backed by either the in-process serving queue or
//! the multi-process shard supervisor.
//!
//! [`NetServer`] binds one listener and runs a small **acceptor pool**:
//! each acceptor thread accepts a connection and serves it to completion
//! (frame in → job → frame out, repeated until the client closes), so the
//! pool size is also the concurrent-connection cap — deliberate for a
//! blocking pure-std tier, and documented so nobody mistakes it for an
//! async server. Clients that need parallelism open one connection per
//! thread, which is exactly what the `serve_net` bench does.
//!
//! Two backends, same wire surface:
//!
//! * **Queue** ([`NetServer::start`]) — decoded jobs go through the
//!   existing [`SubmitHandle`] with `submit_timeout` admission control
//!   ([`crate::serve::router::ServeConfig::admit_timeout_ms`]): a full
//!   lane past the deadline returns a typed `Overloaded` reply instead of
//!   stalling the connection. Results are **bitwise identical** to
//!   in-process submission — the server adds framing, never arithmetic.
//! * **Procs** ([`NetServer::start_supervised`]) — jobs go to the
//!   [`ShardSupervisor`]'s per-size-class child processes; a crashed
//!   child yields a typed `ShardDown` reply and the supervisor respawns
//!   it with backoff.
//!
//! A `Submit` may carry explicit tuning; the server *verifies* it against
//! its own effective config ([`Config::same_tuning`]) and answers a typed
//! `Config` error on mismatch rather than silently computing something
//! else — the serving tier's results are pinned bitwise to its configured
//! tuning, so "run whatever the client asks" would quietly break the
//! cache-key contract. The usual client path is the sentinel
//! ("server default"), which [`NetClient::reduce`] sends.
//!
//! Shutdown: flip the closing flag, then self-connect once per acceptor
//! so every `accept` parked in the kernel wakes and observes the flag;
//! join the pool; drop the backend (which drains the queue or stops the
//! children). In-flight connections finish their current frame exchange.

use crate::config::Config;
use crate::error::{Error, Result};
use crate::ht::two_stage::HtDecomposition;
use crate::linalg::matrix::Matrix;
use crate::serve::cache::CacheStats;
use crate::serve::proto::{read_frame, write_frame, Frame, WireConfig};
use crate::serve::queue::{SubmitHandle, SubmitQueue};
use crate::serve::supervisor::ShardSupervisor;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Network-tier configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Listen address: `host:port` for TCP (port `0` picks a free port —
    /// the resolved address is available via [`NetServer::addr`]), or a
    /// `unix:` prefix for a Unix-domain socket path.
    pub addr: String,
    /// Acceptor-pool size — also the concurrent-connection cap (see the
    /// [module docs](self)).
    pub acceptors: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { addr: "127.0.0.1:7343".to_string(), acceptors: 2 }
    }
}

impl NetConfig {
    /// Defaults overridden by `PALLAS_NET_ADDR`.
    pub fn from_env() -> NetConfig {
        let d = NetConfig::default();
        NetConfig { addr: crate::util::env::net_addr(&d.addr), ..d }
    }

    /// Validate the geometry (typed [`Error::Config`]).
    pub fn validate(&self) -> Result<()> {
        if self.addr.is_empty() {
            return Err(Error::config("net: addr must not be empty"));
        }
        if self.acceptors < 1 || self.acceptors > 64 {
            return Err(Error::config(format!(
                "net: acceptors = {} outside [1, 64]",
                self.acceptors
            )));
        }
        Ok(())
    }
}

/// One listener, TCP or Unix-domain. `accept` takes `&self` on both std
/// types, so the acceptor pool shares this behind an `Arc`.
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

impl Listener {
    fn accept(&self) -> std::io::Result<NetStream> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| NetStream::Tcp(s)),
            #[cfg(unix)]
            Listener::Unix(l) => l.accept().map(|(s, _)| NetStream::Unix(s)),
        }
    }
}

/// One connected stream, either family. The frame codec only needs
/// `Read + Write`; framing keeps syscalls at two reads and one write per
/// frame, so no userspace buffering layer is needed.
enum NetStream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            NetStream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            NetStream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            NetStream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            NetStream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            NetStream::Unix(s) => s.flush(),
        }
    }
}

/// The job-execution side of the server: the in-process queue or the
/// process-per-shard supervisor.
enum Backend {
    Queue(SubmitQueue),
    Procs(ShardSupervisor),
}

/// State shared by the server handle and the acceptor threads.
struct ServerShared {
    backend: Backend,
    closing: AtomicBool,
    /// Connections fully served (diagnostics; exported in `Stats`).
    served: AtomicU64,
}

/// The blocking socket server (see the [module docs](self)). Construct
/// with [`NetServer::start`] / [`NetServer::start_supervised`]; stop with
/// [`NetServer::shutdown`] (drop runs the same protocol).
pub struct NetServer {
    shared: Arc<ServerShared>,
    acceptors: Vec<JoinHandle<()>>,
    /// Resolved address in the same syntax `connect` takes (`host:port`
    /// or `unix:/path`) — for TCP this has any port-0 already resolved.
    addr: String,
    #[cfg(unix)]
    unix_path: Option<PathBuf>,
}

impl std::fmt::Debug for NetServer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NetServer")
            .field("addr", &self.addr)
            .field("acceptors", &self.acceptors.len())
            .finish_non_exhaustive()
    }
}

impl NetServer {
    /// Serve the in-process queue backend over `cfg.addr`.
    pub fn start(queue: SubmitQueue, cfg: NetConfig) -> Result<NetServer> {
        NetServer::start_backend(Backend::Queue(queue), cfg)
    }

    /// Serve the multi-process supervisor backend over `cfg.addr`.
    pub fn start_supervised(sup: ShardSupervisor, cfg: NetConfig) -> Result<NetServer> {
        NetServer::start_backend(Backend::Procs(sup), cfg)
    }

    fn start_backend(backend: Backend, cfg: NetConfig) -> Result<NetServer> {
        cfg.validate()?;
        #[cfg(unix)]
        let mut unix_path: Option<PathBuf> = None;
        let (listener, addr) = if let Some(path) = cfg.addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                let l = UnixListener::bind(path)?;
                unix_path = Some(PathBuf::from(path));
                (Listener::Unix(l), cfg.addr.clone())
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(Error::config(
                    "net: unix: addresses are only supported on unix targets",
                ));
            }
        } else {
            let l = TcpListener::bind(&cfg.addr)?;
            let resolved = l.local_addr()?.to_string();
            (Listener::Tcp(l), resolved)
        };
        let listener = Arc::new(listener);
        let shared = Arc::new(ServerShared {
            backend,
            closing: AtomicBool::new(false),
            served: AtomicU64::new(0),
        });
        let acceptors = (0..cfg.acceptors)
            .map(|i| {
                let listener = listener.clone();
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("paraht-net-{i}"))
                    .spawn(move || acceptor_loop(&listener, &shared))
                    .expect("spawn net acceptor")
            })
            .collect();
        Ok(NetServer {
            shared,
            acceptors,
            addr,
            #[cfg(unix)]
            unix_path,
        })
    }

    /// The resolved listen address, in the syntax [`NetClient::connect`]
    /// takes (`host:port`, or `unix:/path`). For a TCP bind to port 0
    /// this is the actual port.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Connections fully served so far.
    pub fn served(&self) -> u64 {
        self.shared.served.load(Ordering::Relaxed)
    }

    /// Stop accepting, join the acceptor pool, and shut the backend down
    /// (queue drain / child stop). Consuming `self` makes further use a
    /// compile-time error; drop runs the same sequence.
    pub fn shutdown(self) {
        drop(self);
    }

    fn close_and_join(&mut self) {
        self.shared.closing.store(true, Ordering::SeqCst);
        // Wake every acceptor parked in `accept` with one self-connect
        // each; a connect can fail (listener backlog races, file already
        // unlinked) — best effort, the flag is what actually stops them.
        for _ in 0..self.acceptors.len() {
            match &self.addr {
                a if a.starts_with("unix:") => {
                    #[cfg(unix)]
                    {
                        let _ = UnixStream::connect(a.trim_start_matches("unix:"));
                    }
                }
                a => {
                    let _ = TcpStream::connect(a);
                }
            }
        }
        for h in self.acceptors.drain(..) {
            let _ = h.join();
        }
        #[cfg(unix)]
        if let Some(path) = self.unix_path.take() {
            let _ = std::fs::remove_file(path);
        }
        // The backend (queue or supervisor) shuts down when `shared`
        // drops with this server — the last owner at this point, since
        // acceptors are joined.
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

/// One acceptor: accept → serve the connection to completion → repeat,
/// until the closing flag is observed.
fn acceptor_loop(listener: &Listener, shared: &ServerShared) {
    loop {
        if shared.closing.load(Ordering::SeqCst) {
            return;
        }
        let stream = match listener.accept() {
            Ok(s) => s,
            // Transient accept errors (EMFILE, aborted handshakes) must
            // not kill the acceptor; the closing check above bounds the
            // retry loop.
            Err(_) => continue,
        };
        if shared.closing.load(Ordering::SeqCst) {
            return; // the wake-up self-connect, not a real client
        }
        serve_connection(stream, shared);
        shared.served.fetch_add(1, Ordering::Relaxed);
    }
}

/// Serve one connection: frames in, frames out, until clean EOF. A
/// malformed frame or a dead socket drops the connection (protocol errors
/// are connection-fatal by the codec's contract); job-level failures are
/// *replies*, not disconnects.
fn serve_connection(mut stream: NetStream, shared: &ServerShared) {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) => return, // client closed between frames
            Err(_) => return,
        };
        let reply = match frame {
            Frame::Submit { req_id, cfg, a, b } => handle_submit(shared, req_id, cfg, a, b),
            Frame::StatsReq { req_id } => Frame::StatsReply { req_id, json: stats_json(shared) },
            // Clients must not send server-to-client kinds; drop them.
            _ => return,
        };
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// Run one submitted job through the backend and build the reply frame.
fn handle_submit(shared: &ServerShared, req_id: u64, cfg: WireConfig, a: Matrix, b: Matrix) -> Frame {
    let result = match &shared.backend {
        Backend::Queue(queue) => {
            let base = &queue.router().config().base;
            let clip = queue.router().config().clip_band;
            check_tuning(&cfg, base, clip, a.rows())
                .and_then(|()| submit_through_queue(queue.handle(), a, b))
        }
        Backend::Procs(sup) => {
            check_tuning(&cfg, &sup.config().base, sup.config().clip_band, a.rows())
                .and_then(|()| sup.reduce(&a, &b))
        }
    };
    match result {
        Ok(d) => Frame::ResultOk {
            req_id,
            stage1_secs: d.stage1_secs,
            stage2_secs: d.stage2_secs,
            h: d.h.clone(),
            t: d.t.clone(),
            q: d.q.clone(),
            z: d.z.clone(),
        },
        Err(err) => Frame::ResultErr { req_id, err },
    }
}

/// Admission-controlled queue submission: bounded wait for lane capacity
/// (`admit_timeout_ms`), then wait for the ticket. The admission deadline
/// bounds *queue entry*, not job runtime — an accepted job always
/// completes (the queue's graceful-drain contract).
fn submit_through_queue(
    handle: SubmitHandle,
    a: Matrix,
    b: Matrix,
) -> Result<Arc<HtDecomposition>> {
    let timeout = Duration::from_millis(handle.admit_timeout_ms());
    handle.submit_timeout(a, b, timeout)?.wait()
}

/// Verify explicit client tuning against the server's effective config
/// for this problem size (see the [module docs](self) for why mismatches
/// are typed errors, not best-effort execution).
fn check_tuning(wire: &WireConfig, base: &Config, clip: bool, n: usize) -> Result<()> {
    if wire.is_default() {
        return Ok(());
    }
    let eff = if clip { base.clipped_for(n) } else { base.clone() };
    let requested = wire.apply_to(&eff);
    if eff.same_tuning(&requested) {
        Ok(())
    } else {
        Err(Error::config(format!(
            "net: requested tuning (r={}, p={}, q={}, lookahead={}) does not match \
             this server's effective tuning (r={}, p={}, q={}, lookahead={}); \
             submit with the default sentinel or reconfigure the server",
            requested.r, requested.p, requested.q, requested.lookahead,
            eff.r, eff.p, eff.q, eff.lookahead
        )))
    }
}

fn cache_stats_json(c: &CacheStats) -> String {
    format!(
        "{{\"hits\": {}, \"misses\": {}, \"hit_rate\": {:.4}, \"insertions\": {}, \
         \"evictions\": {}, \"entries\": {}, \"bytes\": {}}}",
        c.hits,
        c.misses,
        c.hit_rate(),
        c.insertions,
        c.evictions,
        c.entries,
        c.bytes
    )
}

/// The `Stats` reply body (schema documented in EXPERIMENTS.md §Serving).
fn stats_json(shared: &ServerShared) -> String {
    let served = shared.served.load(Ordering::Relaxed);
    match &shared.backend {
        Backend::Queue(queue) => {
            let q = queue.stats();
            let cache = queue
                .router()
                .cache_stats()
                .map_or("null".to_string(), |c| cache_stats_json(&c));
            format!(
                "{{\"mode\": \"queue\", \"served_connections\": {served}, \
                 \"queue\": {{\"submitted\": {}, \"completed\": {}, \"rejected\": {}, \
                 \"shed\": {}, \"pending\": {}}}, \"cache\": {cache}, \"latency\": {}}}",
                q.submitted,
                q.completed,
                q.rejected,
                q.shed,
                q.pending,
                queue.latency_json()
            )
        }
        Backend::Procs(sup) => {
            let stats = sup.stats();
            format!(
                "{{\"mode\": \"procs\", \"served_connections\": {served}, \
                 \"restarts\": {}, \"shards\": {}}}",
                stats.restarts(),
                sup.stats_json()
            )
        }
    }
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// A blocking protocol client: one connection, synchronous
/// request/response. Open one client per thread for parallel floods.
pub struct NetClient {
    stream: NetStream,
    next_id: u64,
}

impl std::fmt::Debug for NetClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NetClient").field("next_id", &self.next_id).finish_non_exhaustive()
    }
}

impl NetClient {
    /// Connect to a server address (`host:port`, or `unix:/path`).
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = if let Some(path) = addr.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                NetStream::Unix(UnixStream::connect(path)?)
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                return Err(Error::config(
                    "net: unix: addresses are only supported on unix targets",
                ));
            }
        } else {
            NetStream::Tcp(TcpStream::connect(addr)?)
        };
        Ok(NetClient { stream, next_id: 1 })
    }

    /// Reduce one pencil under the server's configured tuning (the
    /// sentinel). The returned factors are bitwise what the server
    /// computed — the wire carries bit patterns.
    pub fn reduce(&mut self, a: &Matrix, b: &Matrix) -> Result<HtDecomposition> {
        self.reduce_with(a, b, WireConfig::default_sentinel())
    }

    /// Reduce with explicit tuning; the server verifies it against its
    /// own effective config and answers a typed `Config` error on
    /// mismatch.
    pub fn reduce_with(
        &mut self,
        a: &Matrix,
        b: &Matrix,
        cfg: WireConfig,
    ) -> Result<HtDecomposition> {
        let req_id = self.fresh_id();
        write_frame(
            &mut self.stream,
            &Frame::Submit { req_id, cfg, a: a.clone(), b: b.clone() },
        )?;
        match read_frame(&mut self.stream)? {
            Some(Frame::ResultOk { req_id: got, stage1_secs, stage2_secs, h, t, q, z }) => {
                check_echo(got, req_id)?;
                Ok(HtDecomposition { h, t, q, z, stage1_secs, stage2_secs })
            }
            Some(Frame::ResultErr { req_id: got, err }) => {
                check_echo(got, req_id)?;
                Err(err)
            }
            Some(other) => {
                Err(Error::protocol(format!("server sent an unexpected frame: {other:?}")))
            }
            None => Err(Error::protocol("server closed the connection mid-request")),
        }
    }

    /// Fetch the server's statistics JSON.
    pub fn stats(&mut self) -> Result<String> {
        let req_id = self.fresh_id();
        write_frame(&mut self.stream, &Frame::StatsReq { req_id })?;
        match read_frame(&mut self.stream)? {
            Some(Frame::StatsReply { req_id: got, json }) => {
                check_echo(got, req_id)?;
                Ok(json)
            }
            Some(other) => {
                Err(Error::protocol(format!("server sent an unexpected frame: {other:?}")))
            }
            None => Err(Error::protocol("server closed the connection mid-request")),
        }
    }

    fn fresh_id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }
}

fn check_echo(got: u64, want: u64) -> Result<()> {
    if got == want {
        Ok(())
    } else {
        Err(Error::protocol(format!("server echoed req {got}, expected {want}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_config_validation() {
        assert!(NetConfig::default().validate().is_ok());
        let bad = NetConfig { addr: String::new(), ..NetConfig::default() };
        assert!(matches!(bad.validate().unwrap_err(), Error::Config(_)));
        let bad = NetConfig { acceptors: 0, ..NetConfig::default() };
        assert!(matches!(bad.validate().unwrap_err(), Error::Config(_)));
        let bad = NetConfig { acceptors: 65, ..NetConfig::default() };
        assert!(matches!(bad.validate().unwrap_err(), Error::Config(_)));
    }

    #[test]
    fn tuning_check_accepts_sentinel_and_matching_explicit_only() {
        let base = Config { r: 8, p: 4, q: 4, ..Config::default() };
        // Sentinel always passes.
        assert!(check_tuning(&WireConfig::default_sentinel(), &base, true, 40).is_ok());
        // Explicit match passes.
        let ok = WireConfig { r: 8, p: 4, q: 4, lookahead: true };
        assert!(check_tuning(&ok, &base, true, 40).is_ok());
        // Explicit mismatch is a typed Config error.
        let bad = WireConfig { r: 6, p: 4, q: 4, lookahead: true };
        assert!(matches!(check_tuning(&bad, &base, true, 40).unwrap_err(), Error::Config(_)));
        // Clipping is applied before comparison: for n = 6 the effective
        // band is r = 5, so the *clipped* spelling matches and the
        // unclipped base spelling does not.
        let clipped = WireConfig { r: 5, p: 4, q: 4, lookahead: true };
        assert!(check_tuning(&clipped, &base, true, 6).is_ok());
        let unclipped = WireConfig { r: 8, p: 4, q: 4, lookahead: true };
        assert!(check_tuning(&unclipped, &base, true, 6).is_err());
    }
}
