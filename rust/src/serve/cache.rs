//! LRU result cache keyed by pencil content — the memo half of the
//! serving layer.
//!
//! A serving tier sees repeated work: parameter sweeps resubmit the same
//! pencil under the same tuning, retries resubmit failed floods, and
//! batch clients deduplicate poorly. Since reductions are deterministic
//! (bitwise, per the crate's determinism contract), a result computed once
//! is the *exact* answer for every bitwise-equal resubmission — so caching
//! is sound with no tolerance knobs at all.
//!
//! **Correctness before probability.** The [`CacheKey`] carries the full
//! bit pattern of both matrices plus the result-relevant config fields,
//! and lookups compare those bytes after the 64-bit
//! [fingerprint](crate::serve::hash) has bucketed the candidates. A
//! fingerprint collision therefore costs one extra comparison, never a
//! wrong answer — the cache can be handed to the bitwise-oracle tests
//! without a carve-out.
//!
//! **Bounded two ways.** `max_entries` caps the entry count and
//! `max_bytes` caps the summed footprint (key bits + the four result
//! factors); either bound evicts least-recently-used entries first. An
//! entry that alone exceeds `max_bytes` is not cached (counted in
//! [`CacheStats::skipped_too_large`]) — one oversized pencil must not
//! flush an otherwise warm cache.

use crate::config::Config;
use crate::ht::two_stage::HtDecomposition;
use crate::linalg::matrix::Matrix;
use crate::serve::hash::pencil_fingerprint;
use std::collections::HashMap;
use std::sync::Arc;

/// Full content key: the pencil's bit patterns plus the result-relevant
/// tuning. Construct with [`CacheKey::new`] from the *effective* (clipped)
/// config so the key describes the reduction that actually runs.
#[derive(Clone, Debug)]
pub struct CacheKey {
    n: usize,
    r: usize,
    p: usize,
    q: usize,
    lookahead: bool,
    /// Resolved GEMM-kernel id ([`crate::linalg::Kernel::id`]): kernels
    /// differ by O(eps) bits, so results computed under different kernels
    /// must never alias in the cache.
    kernel: u64,
    /// Bit patterns of `A` then `B`, column-major storage order.
    bits: Box<[u64]>,
    fingerprint: u64,
}

impl CacheKey {
    /// Key a square pencil under an effective config (callers pass the
    /// output of [`Config::clipped_for`] when band clipping is active).
    pub fn new(a: &Matrix, b: &Matrix, cfg: &Config) -> CacheKey {
        let mut bits = Vec::with_capacity(a.data().len() + b.data().len());
        bits.extend(a.data().iter().map(|v| v.to_bits()));
        bits.extend(b.data().iter().map(|v| v.to_bits()));
        CacheKey {
            n: a.rows(),
            r: cfg.r,
            p: cfg.p,
            q: cfg.q,
            lookahead: cfg.lookahead,
            kernel: cfg.resolved_kernel().id(),
            bits: bits.into_boxed_slice(),
            fingerprint: pencil_fingerprint(a, b, cfg),
        }
    }

    /// The 64-bit bucketing fingerprint (see [`crate::serve::hash`]).
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Approximate heap footprint of the key itself.
    fn bytes(&self) -> usize {
        self.bits.len() * 8 + std::mem::size_of::<CacheKey>()
    }

    /// Compare this stored key against a *borrowed* pencil + effective
    /// config without materializing a `CacheKey` — the allocation-free
    /// comparison behind [`ResultCache::lookup`] (the hit path must not
    /// copy 2·n² words just to ask a question).
    fn matches_pencil(&self, fp: u64, a: &Matrix, b: &Matrix, cfg: &Config) -> bool {
        self.fingerprint == fp
            && self.n == a.rows()
            && self.r == cfg.r
            && self.p == cfg.p
            && self.q == cfg.q
            && self.lookahead == cfg.lookahead
            && self.kernel == cfg.resolved_kernel().id()
            && self.bits.len() == a.data().len() + b.data().len()
            && {
                let (ka, kb) = self.bits.split_at(a.data().len());
                ka.iter().zip(a.data()).all(|(&k, v)| k == v.to_bits())
                    && kb.iter().zip(b.data()).all(|(&k, v)| k == v.to_bits())
            }
    }
}

impl PartialEq for CacheKey {
    fn eq(&self, other: &CacheKey) -> bool {
        self.fingerprint == other.fingerprint
            && self.n == other.n
            && self.r == other.r
            && self.p == other.p
            && self.q == other.q
            && self.lookahead == other.lookahead
            && self.kernel == other.kernel
            && self.bits == other.bits
    }
}

impl Eq for CacheKey {}

/// Hit/miss/eviction counters, exported for benches and dashboards.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that returned a stored result.
    pub hits: u64,
    /// Lookups that found nothing (including fingerprint-collision
    /// near-misses, which compare unequal on the full key).
    pub misses: u64,
    /// Entries stored.
    pub insertions: u64,
    /// Entries removed to satisfy the entry or byte bound.
    pub evictions: u64,
    /// Insertions refused because one entry alone exceeded the byte bound.
    pub skipped_too_large: u64,
    /// Entries currently resident.
    pub entries: usize,
    /// Bytes currently resident (keys + results).
    pub bytes: usize,
}

impl CacheStats {
    /// Hits as a fraction of all lookups (`NaN`-free: 0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One resident entry.
struct Slot {
    key: CacheKey,
    value: Arc<HtDecomposition>,
    bytes: usize,
    /// Monotone use stamp; smallest = least recently used.
    last_used: u64,
}

/// The LRU result cache. Not internally synchronized — the serving layer
/// wraps it in a `Mutex` shared across shards (one cache, N shards: a
/// pencil routed to shard 2 must hit a result computed on shard 0).
pub struct ResultCache {
    max_entries: usize,
    max_bytes: usize,
    /// Dense slot storage; `None` slots are reusable (indices must stay
    /// stable because `index` points into this vector).
    slots: Vec<Option<Slot>>,
    free: Vec<usize>,
    /// Fingerprint → candidate slot indices (collision chain).
    index: HashMap<u64, Vec<usize>>,
    tick: u64,
    bytes: usize,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
    skipped_too_large: u64,
}

impl std::fmt::Debug for ResultCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResultCache")
            .field("max_entries", &self.max_entries)
            .field("max_bytes", &self.max_bytes)
            .field("stats", &self.stats())
            .finish()
    }
}

impl ResultCache {
    /// Cache bounded by entry count and by summed byte footprint.
    /// `max_entries == 0` is a valid always-miss cache (the router uses
    /// `None` instead, but the degenerate bound must not panic).
    pub fn new(max_entries: usize, max_bytes: usize) -> ResultCache {
        ResultCache {
            max_entries,
            max_bytes,
            slots: Vec::new(),
            free: Vec::new(),
            index: HashMap::new(),
            tick: 0,
            bytes: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            evictions: 0,
            skipped_too_large: 0,
        }
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Atomic counter snapshot. `ResultCache` is not internally
    /// synchronized — callers hold the serving layer's cache mutex for the
    /// duration of this call, so the returned [`CacheStats`] is one
    /// consistent instant: `hits + misses` always equals the lookups that
    /// actually happened, and `entries`/`bytes` describe the same resident
    /// set. Contrast with reading the counters through several separate
    /// lock acquisitions, which can tear (a hit recorded between reads
    /// shows up in one field but not another). The CLI and bench printers
    /// route through [`crate::serve::ShardRouter::cache_stats`], which
    /// takes the lock once around this.
    pub fn snapshot(&self) -> CacheStats {
        self.stats()
    }

    /// Counter snapshot (alias of [`ResultCache::snapshot`]; kept as the
    /// historical name).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            insertions: self.insertions,
            evictions: self.evictions,
            skipped_too_large: self.skipped_too_large,
            entries: self.len(),
            bytes: self.bytes,
        }
    }

    /// Record the outcome of a probe: refresh the hit's LRU stamp and hand
    /// out the stored result, or count the miss. The stamp refresh goes
    /// through [`refresh_stamp`], which borrows only `slots`/`tick` — the
    /// `index` chain the probe iterated is untouched by construction.
    fn touch(&mut self, found: Option<usize>) -> Option<Arc<HtDecomposition>> {
        match found {
            Some(i) => {
                self.hits += 1;
                Some(refresh_stamp(&mut self.slots, &mut self.tick, i))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Look a key up; a hit refreshes its LRU stamp and returns a shared
    /// handle to the stored result.
    pub fn get(&mut self, key: &CacheKey) -> Option<Arc<HtDecomposition>> {
        let found = find_in(&self.index, &self.slots, key.fingerprint, |k| k == key);
        self.touch(found)
    }

    /// Allocation-free lookup for the serving hot path: fingerprint the
    /// borrowed pencil and compare stored key bits directly against its
    /// data — no `CacheKey` (and no 2·n²-word copy) is materialized. A
    /// hit is exactly a [`ResultCache::get`] hit on `CacheKey::new(a, b,
    /// cfg)`; callers build the owned key only on the miss path, for
    /// [`ResultCache::insert`].
    pub fn lookup(&mut self, a: &Matrix, b: &Matrix, cfg: &Config) -> Option<Arc<HtDecomposition>> {
        let fp = pencil_fingerprint(a, b, cfg);
        let found = find_in(&self.index, &self.slots, fp, |k| k.matches_pencil(fp, a, b, cfg));
        self.touch(found)
    }

    /// Store a result, evicting least-recently-used entries as needed to
    /// respect both bounds. Re-inserting a resident key refreshes its LRU
    /// stamp instead of duplicating it (two dispatchers can race the same
    /// miss; both computed the identical bits, so either value serves).
    pub fn insert(&mut self, key: CacheKey, value: Arc<HtDecomposition>) {
        if self.max_entries == 0 {
            return;
        }
        let entry_bytes = key.bytes() + result_bytes(&value);
        if entry_bytes > self.max_bytes {
            self.skipped_too_large += 1;
            return;
        }
        // Refresh, don't duplicate, if the key is already resident. The
        // probe borrows `index`+`slots` immutably and completes before the
        // mutable `slots`/`tick` borrow starts — no chain is ever iterated
        // while the slot storage is mutably held.
        if let Some(i) = find_in(&self.index, &self.slots, key.fingerprint, |k| *k == key) {
            let _ = refresh_stamp(&mut self.slots, &mut self.tick, i);
            return;
        }
        while self.len() >= self.max_entries || self.bytes + entry_bytes > self.max_bytes {
            if !self.evict_lru() {
                break;
            }
        }
        self.tick += 1;
        let slot = Slot { key, value, bytes: entry_bytes, last_used: self.tick };
        let fp = slot.key.fingerprint;
        let idx = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(slot);
                i
            }
            None => {
                self.slots.push(Some(slot));
                self.slots.len() - 1
            }
        };
        self.index.entry(fp).or_default().push(idx);
        self.bytes += entry_bytes;
        self.insertions += 1;
    }

    /// Remove the least-recently-used entry. Returns whether anything was
    /// evicted (false only on an empty cache).
    ///
    /// Deliberately an O(entries) scan rather than an intrusive LRU list:
    /// entries are whole decompositions (megabytes each), so both bounds
    /// keep the slot count small — the scan is noise next to one matrix
    /// copy, and the flat structure keeps the index/slot invariants easy
    /// to audit. Revisit if a workload ever wants a many-thousand-entry
    /// cache under byte pressure (each insert may then scan repeatedly).
    fn evict_lru(&mut self) -> bool {
        let victim = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|s| (i, s.last_used)))
            .min_by_key(|&(_, stamp)| stamp)
            .map(|(i, _)| i);
        let Some(i) = victim else {
            return false;
        };
        let slot = self.slots[i].take().expect("victim slot is live");
        self.bytes -= slot.bytes;
        // `slot` is owned by now (taken out of `slots`), so the chain
        // unlink borrows only `index` — the disjointness is structural,
        // not an ordering convention.
        unlink(&mut self.index, slot.key.fingerprint, i);
        self.free.push(i);
        self.evictions += 1;
        true
    }
}

// ---- Disjoint-field helpers. ----
//
// `lookup`/`insert`/`evict_lru` interleave reads of the fingerprint index
// with mutations of the slot storage and the LRU clock. Routing those
// steps through free functions that take exactly the fields they touch
// makes the non-aliasing *structural*: the borrow checker proves (under
// plain NLL, no `unsafe`, no whole-`&mut self` methods mid-probe) that an
// `index` chain can never be iterated while `slots`/`tick` are mutably
// borrowed — the failure mode flagged as riskiest-if-wrong in the original
// method-based version, where every step borrowed all of `self` and the
// safety argument was "trust the call order".

/// Find the live slot whose key satisfies `pred` in the fingerprint
/// chain. Borrows `index` and `slots` immutably — nothing else.
fn find_in(
    index: &HashMap<u64, Vec<usize>>,
    slots: &[Option<Slot>],
    fp: u64,
    pred: impl Fn(&CacheKey) -> bool,
) -> Option<usize> {
    index
        .get(&fp)?
        .iter()
        .copied()
        .find(|&i| pred(&slots[i].as_ref().expect("indexed slot is live").key))
}

/// Refresh slot `i`'s LRU stamp and hand out its stored result. Borrows
/// exactly the fields it mutates (`slots`, `tick`), so it cannot alias an
/// `index` chain held by the caller.
fn refresh_stamp(slots: &mut [Option<Slot>], tick: &mut u64, i: usize) -> Arc<HtDecomposition> {
    *tick += 1;
    let slot = slots[i].as_mut().expect("indexed slot is live");
    slot.last_used = *tick;
    slot.value.clone()
}

/// Unlink slot `i` from its fingerprint chain, dropping the chain when it
/// empties. Borrows `index` only; callers own the evicted `Slot` already.
fn unlink(index: &mut HashMap<u64, Vec<usize>>, fp: u64, i: usize) {
    let chain = index.get_mut(&fp).expect("victim is indexed");
    chain.retain(|&j| j != i);
    if chain.is_empty() {
        index.remove(&fp);
    }
}

/// Heap footprint of a stored decomposition: four `n × n` factors.
fn result_bytes(d: &HtDecomposition) -> usize {
    8 * (d.h.data().len() + d.t.data().len() + d.q.data().len() + d.z.data().len())
        + std::mem::size_of::<HtDecomposition>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::reduce_seq;
    use crate::pencil::random::random_pencil;
    use crate::util::rng::Rng;

    fn small_cfg() -> Config {
        Config { r: 4, p: 2, q: 2, ..Config::default() }
    }

    fn entry(n: usize, seed: u64) -> (CacheKey, Arc<HtDecomposition>) {
        let mut rng = Rng::new(seed);
        let p = random_pencil(n, &mut rng);
        let cfg = small_cfg();
        let d = reduce_seq(&p.a, &p.b, &cfg).unwrap();
        (CacheKey::new(&p.a, &p.b, &cfg), Arc::new(d))
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let mut c = ResultCache::new(8, usize::MAX);
        let (k, v) = entry(10, 1);
        assert!(c.get(&k).is_none());
        c.insert(k.clone(), v.clone());
        let got = c.get(&k).expect("hit after insert");
        assert!(Arc::ptr_eq(&got, &v), "cache returns the stored result");
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions, s.entries), (1, 1, 1, 1));
        assert!(s.bytes > 0);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn capacity_bound_evicts_lru() {
        let mut c = ResultCache::new(2, usize::MAX);
        let (k1, v1) = entry(8, 11);
        let (k2, v2) = entry(8, 12);
        let (k3, v3) = entry(8, 13);
        c.insert(k1.clone(), v1);
        c.insert(k2.clone(), v2);
        assert!(c.get(&k1).is_some(), "touch k1 so k2 is the LRU");
        c.insert(k3.clone(), v3);
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        assert!(c.get(&k2).is_none(), "LRU entry was evicted");
        assert!(c.get(&k1).is_some());
        assert!(c.get(&k3).is_some());
    }

    #[test]
    fn byte_bound_evicts_and_oversized_entries_are_skipped() {
        let (k, v) = entry(12, 21);
        let one = k.bytes() + result_bytes(&v);
        // Room for exactly one entry of this size.
        let mut c = ResultCache::new(64, one + one / 2);
        c.insert(k.clone(), v);
        assert_eq!(c.len(), 1);
        let (k2, v2) = entry(12, 22);
        c.insert(k2.clone(), v2);
        assert_eq!(c.len(), 1, "byte bound forces eviction");
        assert_eq!(c.stats().evictions, 1);
        assert!(c.get(&k2).is_some());
        // An entry alone above the bound is refused, cache untouched.
        let (k3, v3) = entry(24, 23);
        c.insert(k3.clone(), v3);
        assert!(c.get(&k3).is_none());
        assert_eq!(c.stats().skipped_too_large, 1);
        assert!(c.get(&k2).is_some(), "resident entry survives the refusal");
    }

    #[test]
    fn reinsert_refreshes_instead_of_duplicating() {
        let mut c = ResultCache::new(4, usize::MAX);
        let (k, v) = entry(8, 31);
        c.insert(k.clone(), v.clone());
        c.insert(k.clone(), v);
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().insertions, 1);
    }

    #[test]
    fn different_config_same_pencil_is_a_different_key() {
        let mut rng = Rng::new(41);
        let p = random_pencil(10, &mut rng);
        let cfg1 = small_cfg();
        let cfg2 = Config { q: 3, ..small_cfg() };
        let k1 = CacheKey::new(&p.a, &p.b, &cfg1);
        let k2 = CacheKey::new(&p.a, &p.b, &cfg2);
        assert_ne!(k1, k2);
        let mut c = ResultCache::new(4, usize::MAX);
        c.insert(k1, Arc::new(reduce_seq(&p.a, &p.b, &cfg1).unwrap()));
        assert!(c.get(&k2).is_none(), "tuning is part of the key");
    }

    #[test]
    fn different_kernel_same_pencil_is_a_different_key() {
        use crate::linalg::Kernel;
        let kernels = Kernel::all_available();
        let mut rng = Rng::new(42);
        let p = random_pencil(10, &mut rng);
        if kernels.len() >= 2 {
            let cfg1 = Config { kernel: kernels[0].choice(), ..small_cfg() };
            let cfg2 = Config { kernel: kernels[1].choice(), ..small_cfg() };
            let k1 = CacheKey::new(&p.a, &p.b, &cfg1);
            let k2 = CacheKey::new(&p.a, &p.b, &cfg2);
            assert_ne!(k1, k2, "kernel id is part of the key");
            let mut c = ResultCache::new(4, usize::MAX);
            c.insert(k1, Arc::new(reduce_seq(&p.a, &p.b, &cfg1).unwrap()));
            assert!(c.get(&k2).is_none());
            assert!(c.lookup(&p.a, &p.b, &cfg2).is_none());
            assert!(c.lookup(&p.a, &p.b, &cfg1).is_some());
        } else {
            // Scalar-only host: a clamped SIMD request keys identically to
            // an explicit scalar request (both resolve to the same kernel).
            let cfg1 = Config { kernel: crate::linalg::KernelChoice::Scalar, ..small_cfg() };
            let cfg2 = Config { kernel: crate::linalg::KernelChoice::Avx2, ..small_cfg() };
            assert_eq!(CacheKey::new(&p.a, &p.b, &cfg1), CacheKey::new(&p.a, &p.b, &cfg2));
        }
    }

    #[test]
    fn index_and_slots_stay_consistent_under_churn() {
        // Hammer the restructured lookup/insert/evict paths: every
        // operation interleaves index-chain probes with slot mutation, so
        // any aliasing or stale-chain bug shows up as a wrong hit, a
        // panic on a dead slot, or divergent bookkeeping.
        let mut c = ResultCache::new(3, usize::MAX);
        let entries: Vec<_> = (0..6).map(|i| entry(8, 100 + i)).collect();
        for round in 0..4 {
            for (i, (k, v)) in entries.iter().enumerate() {
                c.insert(k.clone(), v.clone());
                // Refresh an older entry so eviction order churns.
                let older = &entries[(i + round) % entries.len()].0;
                let _ = c.get(older);
                assert!(c.len() <= 3, "entry bound must hold after every insert");
                assert!(c.get(k).is_some(), "just-inserted key must be resident");
            }
        }
        let s = c.stats();
        assert_eq!(s.entries, c.len());
        assert!(s.evictions > 0, "churn must actually exercise eviction");
        // Re-inserting every resident key must refresh, not duplicate.
        let before = c.stats().insertions;
        for (k, v) in &entries {
            if c.get(k).is_some() {
                c.insert(k.clone(), v.clone());
            }
        }
        assert_eq!(c.stats().insertions, before, "resident re-inserts never duplicate");
    }

    #[test]
    fn zero_capacity_cache_never_stores() {
        let mut c = ResultCache::new(0, usize::MAX);
        let (k, v) = entry(8, 51);
        c.insert(k.clone(), v);
        assert!(c.get(&k).is_none());
        assert_eq!(c.len(), 0);
    }
}
