//! Backward-error regression tests for every baseline, pinning the §4
//! claim: each algorithm attains a relative backward error on the order of
//! the machine precision, with `T` *exactly* upper triangular and `H`
//! *exactly* Hessenberg (the annihilated entries are flushed to true
//! zeros, so `verify::max_below_band` must return 0.0, not merely small).

use paraht::baselines::one_stage::{OneStageOpts, OppositeMethod};
use paraht::baselines::{dgghd3, househt, iterht, moler_stewart, one_stage};
use paraht::linalg::matrix::Matrix;
use paraht::linalg::verify::{max_below_band, HtVerification};
use paraht::pencil::random::random_pencil;
use paraht::pencil::saddle::saddle_pencil;
use paraht::util::rng::Rng;

/// Shared scaffold: run `reduce` on a fresh random pencil and assert the
/// O(ε) backward error plus the exact-form invariants.
fn assert_backward_error(
    name: &str,
    n: usize,
    seed: u64,
    reduce: impl FnOnce(&mut Matrix, &mut Matrix, &mut Matrix, &mut Matrix),
) {
    let mut rng = Rng::new(seed);
    let p = random_pencil(n, &mut rng);
    let (a0, b0) = (p.a.clone(), p.b.clone());
    let (mut a, mut b) = (p.a, p.b);
    let mut q = Matrix::identity(n);
    let mut z = Matrix::identity(n);
    reduce(&mut a, &mut b, &mut q, &mut z);

    // Exact structural zeros below the band.
    assert_eq!(max_below_band(&a, 1), 0.0, "{name}: H not exactly Hessenberg");
    assert_eq!(max_below_band(&b, 0), 0.0, "{name}: T not exactly upper triangular");

    // Relative backward error O(ε): reconstruction, orthogonality, bands.
    // 1e-11 is the level the integration suite pins for these sizes
    // (≈ c·n·ε with a comfortable constant at n ≤ 100).
    let v = HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1);
    let tol = 1e-11;
    assert!(
        v.worst() < tol,
        "{name}: worst residual {:.3e} >= {tol:.1e} (err_a {:.1e} err_b {:.1e} orthQ {:.1e} orthZ {:.1e})",
        v.worst(),
        v.err_a,
        v.err_b,
        v.orth_q,
        v.orth_z
    );
}

#[test]
fn moler_stewart_backward_error() {
    for (n, seed) in [(32usize, 0xBE01u64), (57, 0xBE02), (80, 0xBE03)] {
        assert_backward_error("MolerStewart", n, seed, |a, b, q, z| {
            moler_stewart::reduce(a, b, q, z);
        });
    }
}

#[test]
fn dgghd3_backward_error() {
    for (n, seed) in [(32usize, 0xBE11u64), (57, 0xBE12), (80, 0xBE13)] {
        assert_backward_error("DGGHD3", n, seed, |a, b, q, z| {
            dgghd3::reduce(a, b, q, z);
        });
    }
}

#[test]
fn one_stage_rq_backward_error() {
    for (n, seed) in [(32usize, 0xBE21u64), (57, 0xBE22)] {
        assert_backward_error("OneStage/Rq", n, seed, |a, b, q, z| {
            let opts = OneStageOpts { method: OppositeMethod::Rq, ..Default::default() };
            one_stage::reduce(a, b, q, z, &opts).expect("RQ method never fails");
        });
    }
}

#[test]
fn one_stage_solve_backward_error() {
    // The solve path on a well-conditioned random pencil (the §4 common
    // case) must also reach O(ε).
    assert_backward_error("OneStage/Solve", 48, 0xBE31, |a, b, q, z| {
        let opts = OneStageOpts { method: OppositeMethod::Solve, ..Default::default() };
        one_stage::reduce(a, b, q, z, &opts).expect("solve method on well-conditioned pencil");
    });
}

#[test]
fn househt_backward_error() {
    for (n, seed) in [(32usize, 0xBE41u64), (57, 0xBE42)] {
        assert_backward_error("HouseHT", n, seed, |a, b, q, z| {
            househt::reduce(a, b, q, z, &Default::default()).expect("HouseHT never fails");
        });
    }
}

#[test]
fn iterht_backward_error() {
    for (n, seed) in [(32usize, 0xBE51u64), (57, 0xBE52)] {
        assert_backward_error("IterHT", n, seed, |a, b, q, z| {
            iterht::reduce(a, b, q, z, &Default::default())
                .expect("IterHT converges on random pencils");
        });
    }
}

#[test]
fn househt_backward_error_on_saddle() {
    // HouseHT must stay at O(ε) even where the solve fast path keeps
    // failing (singular B blocks) — the robustness half of Fig. 11.
    let n = 48;
    let mut rng = Rng::new(0xBE61);
    let p = saddle_pencil(n, 0.25, &mut rng);
    let (a0, b0) = (p.a.clone(), p.b.clone());
    let (mut a, mut b) = (p.a, p.b);
    let mut q = Matrix::identity(n);
    let mut z = Matrix::identity(n);
    let stats = househt::reduce(&mut a, &mut b, &mut q, &mut z, &Default::default()).unwrap();
    assert!(stats.fallbacks > 0, "saddle pencil must trigger fallbacks");
    assert_eq!(max_below_band(&a, 1), 0.0);
    assert_eq!(max_below_band(&b, 0), 0.0);
    let v = HtVerification::compute(&a0, &b0, &q, &z, &a, &b, 1);
    assert!(v.worst() < 1e-11, "HouseHT saddle: {:.3e}", v.worst());
}
