//! End-to-end tests for the socket front door (`serve::net` + `proto`):
//! everything served over a real loopback connection must be bitwise
//! identical to the sequential oracle under the effective (band-clipped)
//! config, job-level failures must come back as *typed replies* on a
//! still-healthy connection, and the frame codec must survive hostile
//! prefixes without panicking. The multi-process door has its own
//! harness-free suite in `tests/serve_proc.rs`.

use paraht::api::reduce_seq;
use paraht::config::Config;
use paraht::ht::two_stage::HtDecomposition;
use paraht::pencil::random::random_pencil;
use paraht::pencil::Pencil;
use paraht::serve::proto::{read_frame, write_frame, Frame};
use paraht::serve::{
    NetClient, NetConfig, NetServer, ServeConfig, ShardRouter, SubmitQueue, WireConfig,
};
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;
use paraht::{Error, Matrix};

/// Mixed sizes incl. `n` at or below the default band (clipping path).
const SIZES: &[usize] = &[2, 6, 10, 17, 23, 40];

fn oracle(p: &Pencil, base: &Config) -> HtDecomposition {
    reduce_seq(&p.a, &p.b, &base.clipped_for(p.n())).unwrap()
}

fn assert_bitwise(label: &str, d: &HtDecomposition, want: &HtDecomposition) {
    assert_eq!(max_abs_diff(&d.h, &want.h), 0.0, "{label}: H diverges");
    assert_eq!(max_abs_diff(&d.t, &want.t), 0.0, "{label}: T diverges");
    assert_eq!(max_abs_diff(&d.q, &want.q), 0.0, "{label}: Q diverges");
    assert_eq!(max_abs_diff(&d.z, &want.z), 0.0, "{label}: Z diverges");
}

/// Queue-backed server on an OS-assigned loopback port.
fn start_server(scfg: ServeConfig) -> NetServer {
    let queue = SubmitQueue::new(ShardRouter::new(scfg).unwrap());
    let ncfg = NetConfig { addr: "127.0.0.1:0".to_string(), acceptors: 4 };
    NetServer::start(queue, ncfg).unwrap()
}

#[test]
fn socket_flood_is_bitwise_identical_to_the_sequential_oracle() {
    let base = Config::default();
    let server = start_server(ServeConfig { base: base.clone(), ..ServeConfig::default() });
    let mut rng = Rng::new(0xD00);
    let pencils: Vec<Pencil> = SIZES.iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let mut client = NetClient::connect(server.addr()).unwrap();
    // Two rounds: the second is served from the result cache, and must
    // be bitwise the same bytes.
    for round in 0..2 {
        for p in &pencils {
            let d = client.reduce(&p.a, &p.b).unwrap();
            assert_bitwise(&format!("round {round} n={}", p.n()), &d, &oracle(p, &base));
        }
    }
    // The cache hits are visible through the protocol's Stats request.
    let stats = client.stats().unwrap();
    assert!(stats.contains("\"mode\": \"queue\""), "backend named: {stats}");
    assert!(
        stats.contains(&format!("\"hits\": {}", SIZES.len())),
        "one cache hit per repeated pencil: {stats}"
    );
    assert!(stats.contains("\"latency\""), "latency histograms exported: {stats}");
    server.shutdown();
}

#[test]
fn concurrent_clients_share_one_server() {
    let base = Config::default();
    let server = start_server(ServeConfig {
        base: base.clone(),
        cache_entries: 0, // all work real: exercise concurrent execution
        ..ServeConfig::default()
    });
    let addr = server.addr().to_string();
    std::thread::scope(|s| {
        for t in 0..4usize {
            let addr = &addr;
            let base = &base;
            s.spawn(move || {
                let mut rng = Rng::new(0xC0FFEE + t as u64);
                let mut client = NetClient::connect(addr).unwrap();
                for i in 0..3 {
                    let p = random_pencil(SIZES[(t + i) % SIZES.len()], &mut rng);
                    let d = client.reduce(&p.a, &p.b).unwrap();
                    assert_bitwise(&format!("client {t} job {i}"), &d, &oracle(&p, base));
                }
            });
        }
    });
    server.shutdown();
}

#[test]
fn explicit_tuning_is_verified_against_the_server() {
    let base = Config::default();
    let server = start_server(ServeConfig { base: base.clone(), ..ServeConfig::default() });
    let mut rng = Rng::new(0x7E57);
    let p = random_pencil(40, &mut rng);
    let mut client = NetClient::connect(server.addr()).unwrap();
    // Spelling out the server's own effective tuning is accepted...
    let wire = WireConfig::from_config(&base.clipped_for(40));
    let d = client.reduce_with(&p.a, &p.b, wire).unwrap();
    assert_bitwise("matching explicit tuning", &d, &oracle(&p, &base));
    // ...a different tuning is a typed Config reply, not silent drift.
    let wrong = WireConfig { r: 7, ..wire };
    match client.reduce_with(&p.a, &p.b, wrong) {
        Err(Error::Config(msg)) => {
            assert!(msg.contains("tuning"), "actionable message: {msg}")
        }
        other => panic!("expected a Config error, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn job_failures_are_typed_replies_on_a_healthy_connection() {
    let base = Config::default();
    let server = start_server(ServeConfig { base: base.clone(), ..ServeConfig::default() });
    let mut rng = Rng::new(0xBAD);
    let mut client = NetClient::connect(server.addr()).unwrap();
    // A malformed *job* (non-square pencil) is a typed Shape reply...
    let a = Matrix::randn(6, 6, &mut rng);
    let b = Matrix::randn(7, 7, &mut rng);
    assert!(matches!(client.reduce(&a, &b), Err(Error::Shape(_))));
    // ...and the connection stays usable for the next, well-formed job.
    let p = random_pencil(10, &mut rng);
    let d = client.reduce(&p.a, &p.b).unwrap();
    assert_bitwise("after typed failure", &d, &oracle(&p, &base));
    server.shutdown();
}

#[cfg(unix)]
#[test]
fn unix_socket_round_trip() {
    let base = Config::default();
    let path = std::env::temp_dir().join(format!("paraht-net-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path); // stale socket from a killed run
    let addr = format!("unix:{}", path.display());
    let queue = SubmitQueue::new(
        ShardRouter::new(ServeConfig { base: base.clone(), ..ServeConfig::default() }).unwrap(),
    );
    let server =
        NetServer::start(queue, NetConfig { addr: addr.clone(), acceptors: 1 }).unwrap();
    assert_eq!(server.addr(), addr);
    let mut rng = Rng::new(0x0111);
    let p = random_pencil(17, &mut rng);
    let mut client = NetClient::connect(server.addr()).unwrap();
    let d = client.reduce(&p.a, &p.b).unwrap();
    assert_bitwise("unix socket", &d, &oracle(&p, &base));
    drop(client);
    server.shutdown();
    assert!(!path.exists(), "socket file removed on shutdown");
}

#[test]
fn shutdown_closes_the_listener() {
    let server = start_server(ServeConfig::default());
    let addr = server.addr().to_string();
    server.shutdown();
    // The port is released: a fresh connect must fail outright, or at
    // most accept the TCP handshake and then yield no reply.
    if let Ok(mut client) = NetClient::connect(&addr) {
        let mut rng = Rng::new(1);
        let p = random_pencil(6, &mut rng);
        assert!(client.reduce(&p.a, &p.b).is_err(), "no server behind {addr} anymore");
    }
}

/// Integration-level codec property: random frames (including NaN and
/// negative-zero payload entries) survive encode → decode bit-for-bit
/// through an in-memory buffer, and truncating the buffer anywhere
/// inside a frame is a typed protocol error, never a panic.
#[test]
fn frames_survive_round_trips_and_reject_truncation() {
    let mut rng = Rng::new(0xF0F0);
    for case in 0..8u64 {
        let n = 2 + (case as usize % 5);
        let mut a = Matrix::randn(n, n, &mut rng);
        let b = Matrix::randn(n, n, &mut rng);
        a.data_mut()[0] = f64::NAN;
        a.data_mut()[1] = -0.0;
        let frame = Frame::Submit {
            req_id: 0x1000 + case,
            cfg: WireConfig { r: 4, p: 2, q: 2, lookahead: case % 2 == 0 },
            a: a.clone(),
            b: b.clone(),
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame).unwrap();
        let got = read_frame(&mut &buf[..]).unwrap().expect("one whole frame");
        match got {
            Frame::Submit { req_id, cfg, a: ga, b: gb } => {
                assert_eq!(req_id, 0x1000 + case);
                assert_eq!(cfg, WireConfig { r: 4, p: 2, q: 2, lookahead: case % 2 == 0 });
                // Bit-level comparison — NaN != NaN under ==, so compare
                // the raw patterns.
                let bits = |m: &Matrix| m.data().iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&ga), bits(&a), "A payload bits");
                assert_eq!(bits(&gb), bits(&b), "B payload bits");
            }
            other => panic!("wrong frame kind decoded: {other:?}"),
        }
        // Truncation at a few depths: empty stream is a clean EOF, any
        // cut inside the frame is a typed protocol error.
        assert!(read_frame(&mut &buf[..0]).unwrap().is_none());
        for cut in [1, 4, buf.len() / 2, buf.len() - 1] {
            match read_frame(&mut &buf[..cut]) {
                Err(Error::Protocol(_)) => {}
                other => panic!("cut at {cut} must be a Protocol error, got {other:?}"),
            }
        }
    }
}
