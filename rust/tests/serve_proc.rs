//! Crash-isolation tests for the multi-process serving door
//! (`serve::supervisor`): child workers really are separate processes,
//! killing one mid-job fails exactly that job with a typed
//! `Error::ShardDown`, the supervisor respawns it (with backoff), and
//! everything served before and after the crash is bitwise the
//! sequential oracle.
//!
//! This suite re-invokes its **own executable** with `--shard-worker` as
//! the child process, which the stock libtest harness would misparse as
//! a test filter — so `Cargo.toml` marks it `harness = false` and the
//! tiny `main` below speaks enough of libtest's dialect for CI:
//! positional arguments are substring filters, `--ignored` selects only
//! ignored tests (the `pool_stress_supervisor` hammer), and other
//! dashed flags (`--nocapture`, ...) are accepted and ignored.

use paraht::api::reduce_seq;
use paraht::config::Config;
use paraht::ht::two_stage::HtDecomposition;
use paraht::pencil::random::random_pencil;
use paraht::pencil::Pencil;
use paraht::serve::{ShardSupervisor, SupervisorConfig};
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;
use paraht::Error;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};

fn assert_bitwise(label: &str, p: &Pencil, base: &Config, d: &HtDecomposition) {
    let oracle = reduce_seq(&p.a, &p.b, &base.clipped_for(p.n())).unwrap();
    assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "{label}: H diverges (n={})", p.n());
    assert_eq!(max_abs_diff(&d.t, &oracle.t), 0.0, "{label}: T diverges (n={})", p.n());
    assert_eq!(max_abs_diff(&d.q, &oracle.q), 0.0, "{label}: Q diverges (n={})", p.n());
    assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "{label}: Z diverges (n={})", p.n());
}

/// A scratch directory that cleans itself up (best effort).
struct TempDir(std::path::PathBuf);

impl TempDir {
    fn new(tag: &str) -> TempDir {
        let p = std::env::temp_dir().join(format!("paraht-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        TempDir(p)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

/// Mixed sizes (incl. band-clip cases) through two child processes,
/// bitwise against the oracle, with `run_summary.json` persisted per
/// shard on shutdown.
fn supervisor_mixed_sizes_bitwise_and_summary() {
    let dir = TempDir::new("sup-summary");
    let base = Config::default();
    let sup = ShardSupervisor::new(SupervisorConfig {
        procs: 2,
        base: base.clone(),
        summary_dir: Some(dir.0.clone()),
        ..SupervisorConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(0x9906);
    let pencils: Vec<Pencil> =
        [2usize, 6, 10, 17, 23, 40].iter().map(|&n| random_pencil(n, &mut rng)).collect();
    for p in &pencils {
        let d = sup.reduce(&p.a, &p.b).unwrap();
        assert_bitwise("mixed flood", p, &base, &d);
    }
    let stats = sup.stats();
    assert_eq!(stats.restarts(), 0, "healthy flood must not restart anything");
    let jobs_ok: u64 = stats.shards.iter().map(|s| s.jobs_ok).sum();
    assert_eq!(jobs_ok, pencils.len() as u64);
    sup.shutdown();
    // Both shards persisted a summary, and the fields survive a
    // round-trip through a dumb substring check (full JSON parsing is
    // the monitoring stack's job, not this test's).
    let mut seen_jobs = 0u64;
    for shard in 0..2 {
        let text =
            std::fs::read_to_string(dir.0.join(format!("shard-{shard}.run_summary.json")))
                .expect("summary persisted on shutdown");
        assert!(text.contains("\"schema_version\": 1"), "shard {shard}: {text}");
        assert!(text.contains(&format!("\"shard\": {shard}")), "shard {shard}: {text}");
        assert!(text.contains("\"restarts\": 0"), "shard {shard}: {text}");
        for part in text.split(',') {
            if let Some(v) = part.split("\"jobs_ok\": ").nth(1) {
                seen_jobs += v.trim_matches(|c: char| !c.is_ascii_digit()).parse::<u64>().unwrap_or(0);
            }
        }
    }
    assert_eq!(seen_jobs, pencils.len() as u64, "summaries account for every job");
}

/// Kill the only child while a large job is in flight: that job fails
/// with a typed `ShardDown`, the supervisor respawns (spawns >= 2), and
/// the resubmitted job is bitwise correct.
fn supervisor_kill_mid_job_shard_down_then_restart() {
    let base = Config::default();
    let sup = ShardSupervisor::new(SupervisorConfig {
        procs: 1,
        base: base.clone(),
        ..SupervisorConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(0xDEAD);
    // Big enough that the kill below lands mid-reduction with margin
    // (a single-threaded n=400 two-stage run is comfortably > 100ms).
    let p = random_pencil(400, &mut rng);
    let outcome = std::thread::scope(|s| {
        let job = s.spawn(|| sup.reduce(&p.a, &p.b));
        std::thread::sleep(std::time::Duration::from_millis(60));
        assert!(sup.kill_shard(0), "one child to kill");
        job.join().expect("submitting thread must not panic")
    });
    match outcome {
        Err(Error::ShardDown(msg)) => assert!(msg.contains("resubmit"), "actionable: {msg}"),
        other => panic!("killed child must fail the in-flight job with ShardDown, got {other:?}"),
    }
    // Resubmit until the respawned child answers (the first attempt may
    // still land inside the backoff window — that's the design).
    let mut done = None;
    for _ in 0..20 {
        match sup.reduce(&p.a, &p.b) {
            Ok(d) => {
                done = Some(d);
                break;
            }
            Err(Error::ShardDown(_)) => continue,
            Err(e) => panic!("unexpected error after restart: {e}"),
        }
    }
    let d = done.expect("supervisor must recover after a kill");
    assert_bitwise("after restart", &p, &base, &d);
    let stats = sup.stats();
    assert!(stats.restarts() >= 1, "the kill must show up as a restart: {stats:?}");
    assert!(stats.shards[0].jobs_failed >= 1, "the killed job was failed: {stats:?}");
    sup.shutdown();
}

/// Ignored hammer (CI pool-stress job): concurrent clients flood the
/// supervisor while a chaos thread keeps killing random children. Every
/// job either completes bitwise-correct or fails with a typed
/// `ShardDown` and succeeds on a bounded retry.
fn pool_stress_supervisor() {
    let iters = paraht::util::env::stress_iters(60);
    let base = Config::default();
    let sup = ShardSupervisor::new(SupervisorConfig {
        procs: 2,
        base: base.clone(),
        backoff_initial_ms: 5,
        backoff_max_ms: 50,
        ..SupervisorConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(0x57E55);
    let pool: Vec<Pencil> =
        (0..12).map(|i| random_pencil([2, 6, 11, 16, 21][i % 5], &mut rng)).collect();
    let done = AtomicBool::new(false);
    std::thread::scope(|s| {
        // Chaos: keep killing alternating children until the clients are
        // done. The flag must flip *inside* the scope — scoped threads
        // are joined when the closure returns, flag or no flag.
        s.spawn(|| {
            let mut k = 0usize;
            while !done.load(Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(23));
                sup.kill_shard(k % 2);
                k += 1;
            }
        });
        let clients: Vec<_> = (0..4usize)
            .map(|t| {
                let pool = &pool;
                let sup = &sup;
                let base = &base;
                s.spawn(move || {
                    for i in 0..iters {
                        let p = &pool[(t * 31 + i) % pool.len()];
                        let mut served = false;
                        for _attempt in 0..200 {
                            match sup.reduce(&p.a, &p.b) {
                                Ok(d) => {
                                    assert_bitwise("stress", p, base, &d);
                                    served = true;
                                    break;
                                }
                                Err(Error::ShardDown(_)) => continue,
                                Err(e) => panic!("stress job {t}/{i}: unexpected error {e}"),
                            }
                        }
                        assert!(served, "job {t}/{i} starved despite bounded retries");
                    }
                })
            })
            .collect();
        let mut client_panic = false;
        for c in clients {
            client_panic |= c.join().is_err();
        }
        done.store(true, Ordering::Relaxed);
        assert!(!client_panic, "a stress client failed; see output above");
    });
    let stats = sup.stats();
    eprintln!(
        "pool_stress_supervisor: {} restarts over {} jobs",
        stats.restarts(),
        stats.shards.iter().map(|s| s.jobs_ok).sum::<u64>()
    );
    sup.shutdown();
}

struct TestCase {
    name: &'static str,
    ignored: bool,
    run: fn(),
}

const TESTS: &[TestCase] = &[
    TestCase {
        name: "supervisor_mixed_sizes_bitwise_and_summary",
        ignored: false,
        run: supervisor_mixed_sizes_bitwise_and_summary,
    },
    TestCase {
        name: "supervisor_kill_mid_job_shard_down_then_restart",
        ignored: false,
        run: supervisor_kill_mid_job_shard_down_then_restart,
    },
    TestCase { name: "pool_stress_supervisor", ignored: true, run: pool_stress_supervisor },
];

fn main() {
    // Worker mode first: the supervisor under test re-invokes this very
    // executable, and the worker owns stdin/stdout.
    if std::env::args().any(|a| a == "--shard-worker") {
        std::process::exit(paraht::serve::worker_main());
    }
    let mut filters: Vec<String> = Vec::new();
    let mut ignored_only = false;
    let mut skip_value = false;
    for a in std::env::args().skip(1) {
        if skip_value {
            skip_value = false;
            continue;
        }
        match a.as_str() {
            "--ignored" => ignored_only = true,
            // libtest flags that take a value we don't use
            "--test-threads" | "--skip" | "--color" | "--format" | "--logfile" => {
                skip_value = true
            }
            s if s.starts_with('-') => {} // --nocapture, --exact, ...
            _ => filters.push(a),
        }
    }
    let mut passed = 0u32;
    let mut failed = 0u32;
    for t in TESTS {
        if t.ignored != ignored_only {
            continue;
        }
        if !filters.is_empty() && !filters.iter().any(|f| t.name.contains(f.as_str())) {
            continue;
        }
        print!("test {} ... ", t.name);
        let _ = std::io::stdout().flush();
        match std::panic::catch_unwind(t.run) {
            Ok(()) => {
                println!("ok");
                passed += 1;
            }
            Err(_) => {
                println!("FAILED");
                failed += 1;
            }
        }
    }
    println!(
        "\ntest result: {}. {passed} passed; {failed} failed",
        if failed == 0 { "ok" } else { "FAILED" }
    );
    if failed > 0 {
        std::process::exit(101);
    }
}
