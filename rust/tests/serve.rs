//! Serving-layer integration tests: every result that comes out of the
//! shard router, the async submission queue, or the result cache must be
//! bitwise identical to the sequential oracle (`api::reduce_seq`) under
//! the effective (band-clipped) config — across mixed-size floods, cache
//! eviction pressure, concurrent submitters, and shutdown mid-flood.

use paraht::api::reduce_seq;
use paraht::config::Config;
use paraht::error::Error;
use paraht::ht::two_stage::HtDecomposition;
use paraht::pencil::random::random_pencil;
use paraht::pencil::Pencil;
use paraht::serve::{pencil_fingerprint, ServeConfig, ShardRouter, SubmitQueue};
use paraht::util::proptest::for_each_case;
use paraht::util::rng::Rng;
use std::time::Duration;

fn assert_bitwise(d: &HtDecomposition, oracle: &HtDecomposition, label: &str) {
    use paraht::util::proptest::max_abs_diff;
    assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0, "{label}: H");
    assert_eq!(max_abs_diff(&d.t, &oracle.t), 0.0, "{label}: T");
    assert_eq!(max_abs_diff(&d.q, &oracle.q), 0.0, "{label}: Q");
    assert_eq!(max_abs_diff(&d.z, &oracle.z), 0.0, "{label}: Z");
}

/// Oracle for the serving path: the sequential reduction under the
/// band-clipped config (the routers in these tests keep the default
/// `clip_band = true`).
fn serve_oracle(p: &Pencil, base: &Config) -> HtDecomposition {
    reduce_seq(&p.a, &p.b, &base.clipped_for(p.n())).unwrap()
}

/// A paper-tuned (r = 16) serving config over `shards` shards — mixed
/// sizes below the band exercise the clipping path.
fn paper_serve(shards: usize) -> ServeConfig {
    ServeConfig { shards, ..ServeConfig::default() }
}

/// A small-pencil serving config (r = 4) for the flood tests.
fn small_serve(shards: usize, queue_capacity: usize) -> ServeConfig {
    ServeConfig {
        shards,
        queue_capacity,
        base: Config { r: 4, p: 2, q: 2, ..Config::default() },
        ..ServeConfig::default()
    }
}

/// Router path, paper tuning, mixed sizes including `n` below the band
/// and a tiny no-op pencil: every routed result is bitwise the oracle.
#[test]
fn router_mixed_size_flood_is_bitwise_oracle() {
    let mut rng = Rng::new(0x5EA1);
    let sizes = [2usize, 6, 10, 17, 23, 40, 10, 6, 23];
    let pencils: Vec<Pencil> = sizes.iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let router = ShardRouter::new(paper_serve(3)).unwrap();
    for (i, p) in pencils.iter().enumerate() {
        let d = router.reduce(&p.a, &p.b).unwrap();
        let oracle = serve_oracle(p, &router.config().base);
        assert_bitwise(&d, &oracle, &format!("router pencil {i} (n={})", p.n()));
    }
    let stats = router.stats();
    assert_eq!(stats.reduced_total(), pencils.len() as u64, "all distinct: no cache hit");
    assert_eq!(stats.reduced_per_shard.len(), 3);
}

/// Queue path under concurrent submitters: three client threads flood a
/// two-shard queue with mixed sizes; every ticket resolves bitwise.
#[test]
fn queue_concurrent_submitters_bitwise_oracle() {
    let mut rng = Rng::new(0x5EA2);
    let sizes = [2usize, 6, 12, 20, 33];
    let pencils: Vec<Pencil> = sizes.iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let base = small_serve(2, 4).base.clone();
    let oracles: Vec<HtDecomposition> = pencils.iter().map(|p| serve_oracle(p, &base)).collect();

    let queue = SubmitQueue::new(ShardRouter::new(small_serve(2, 4)).unwrap());
    let handle = queue.handle();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..3)
            .map(|t| {
                let handle = handle.clone();
                let pencils = &pencils;
                s.spawn(move || {
                    let mut results = Vec::new();
                    for k in 0..pencils.len() {
                        // Offset start so the submitters interleave.
                        let i = (k + t) % pencils.len();
                        let ticket = handle
                            .submit(pencils[i].a.clone(), pencils[i].b.clone())
                            .expect("queue accepts while open");
                        results.push((i, ticket.wait().expect("served reduction succeeds")));
                    }
                    results
                })
            })
            .collect();
        for join in joins {
            for (i, d) in join.join().expect("submitter thread completes") {
                assert_bitwise(&d, &oracles[i], &format!("queued pencil {i}"));
            }
        }
    });
    let qstats = queue.stats();
    assert_eq!(qstats.submitted, 15);
    assert_eq!(qstats.completed, 15);
    assert_eq!(qstats.pending, 0);
    queue.shutdown();
}

/// Cache eviction under pressure: a 2-entry cache cycled over 5 distinct
/// pencils in a hit-friendly pattern must evict repeatedly while every
/// answer (cached or recomputed) stays bitwise.
#[test]
fn cache_eviction_pressure_stays_bitwise() {
    let mut rng = Rng::new(0x5EA3);
    let pencils: Vec<Pencil> = (0..5).map(|_| random_pencil(12, &mut rng)).collect();
    let cfg = ServeConfig { cache_entries: 2, ..small_serve(2, 8) };
    let router = ShardRouter::new(cfg).unwrap();
    let oracles: Vec<HtDecomposition> =
        pencils.iter().map(|p| serve_oracle(p, &router.config().base)).collect();
    for round in 0..3 {
        for (i, p) in pencils.iter().enumerate() {
            // Submit each pencil twice back-to-back: the second is a hit
            // (just inserted), while cycling 5 keys through 2 slots forces
            // evictions between rounds.
            for rep in 0..2 {
                let d = router.reduce(&p.a, &p.b).unwrap();
                assert_bitwise(&d, &oracles[i], &format!("round {round} rep {rep} pencil {i}"));
            }
        }
    }
    let cache = router.stats().cache.expect("cache configured");
    assert!(cache.evictions > 0, "2-entry cache over 5 keys must evict: {cache:?}");
    assert!(cache.hits >= 15, "back-to-back repeats hit: {cache:?}");
    assert!(cache.entries <= 2, "entry bound respected: {cache:?}");
}

/// Eviction racing concurrent submitters through the queue: correctness
/// (bitwise parity) must survive a thrashing cache.
#[test]
fn cache_eviction_race_through_queue_stays_bitwise() {
    let mut rng = Rng::new(0x5EA4);
    // One size: every pencil lands on one lane; a second size exercises
    // the other shard concurrently.
    let pencils: Vec<Pencil> = (0..4)
        .map(|i| random_pencil(if i % 2 == 0 { 10 } else { 14 }, &mut rng))
        .collect();
    let cfg = ServeConfig { cache_entries: 2, ..small_serve(2, 4) };
    let queue = SubmitQueue::new(ShardRouter::new(cfg).unwrap());
    let base = queue.router().config().base.clone();
    let oracles: Vec<HtDecomposition> =
        pencils.iter().map(|p| serve_oracle(p, &base)).collect();
    std::thread::scope(|s| {
        let joins: Vec<_> = (0..3)
            .map(|_| {
                let handle = queue.handle();
                let pencils = &pencils;
                s.spawn(move || {
                    let mut out = Vec::new();
                    for _round in 0..4 {
                        for (i, p) in pencils.iter().enumerate() {
                            let t = handle.submit(p.a.clone(), p.b.clone()).unwrap();
                            out.push((i, t.wait().unwrap()));
                        }
                    }
                    out
                })
            })
            .collect();
        for join in joins {
            for (i, d) in join.join().unwrap() {
                assert_bitwise(&d, &oracles[i], &format!("raced pencil {i}"));
            }
        }
    });
    let cache = queue.router().stats().cache.expect("cache configured");
    assert!(cache.hits + cache.misses == 48, "every submission consulted the cache: {cache:?}");
    queue.shutdown();
}

/// Shutdown mid-flood: submitters race a shutdown. Every *accepted*
/// ticket must complete with a bitwise-correct result (graceful drain);
/// every refused submission must be the typed shutdown error.
#[test]
fn shutdown_mid_flood_completes_every_accepted_ticket() {
    let mut rng = Rng::new(0x5EA5);
    let pencils: Vec<Pencil> =
        [6usize, 10, 14, 6, 10].iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let base = small_serve(2, 2).base.clone();
    let oracles: Vec<HtDecomposition> = pencils.iter().map(|p| serve_oracle(p, &base)).collect();

    // Repeat to vary the race window (the ignored stress hammer below
    // runs many more iterations with randomized geometry).
    for round in 0..4 {
        let queue = SubmitQueue::new(ShardRouter::new(small_serve(2, 2)).unwrap());
        let handle = queue.handle();
        std::thread::scope(|s| {
            let joins: Vec<_> = (0..3)
                .map(|_| {
                    let handle = handle.clone();
                    let pencils = &pencils;
                    s.spawn(move || {
                        let mut accepted = Vec::new();
                        let mut rejected = 0usize;
                        for _rep in 0..6 {
                            for (i, p) in pencils.iter().enumerate() {
                                match handle.submit(p.a.clone(), p.b.clone()) {
                                    Ok(t) => accepted.push((i, t)),
                                    Err(e) => {
                                        assert!(
                                            matches!(e, Error::Runtime(_)),
                                            "only the typed shutdown error is allowed: {e}"
                                        );
                                        rejected += 1;
                                    }
                                }
                            }
                        }
                        (accepted, rejected)
                    })
                })
                .collect();
            // Let some submissions land, then pull the plug mid-flood.
            std::thread::sleep(Duration::from_millis(2 + round as u64));
            queue.shutdown();
            for join in joins {
                let (accepted, _rejected) = join.join().expect("submitter survives shutdown");
                for (i, ticket) in accepted {
                    let d = ticket.wait().expect("accepted ticket completes across shutdown");
                    assert_bitwise(&d, &oracles[i], &format!("round {round} pencil {i}"));
                }
            }
        });
    }
}

/// Property: the pencil fingerprint is invariant under cloning and
/// sensitive to any single-element bitflip (the bijectivity argument in
/// `serve::hash` — a single changed word always changes the hash).
#[test]
fn hash_clone_invariant_and_bitflip_sensitive() {
    for_each_case(24, 0x5EA6, |rng| {
        let n = 2 + rng.below(18);
        let p = random_pencil(n, rng);
        let cfg = Config { r: 4, p: 2, q: 2, ..Config::default() };
        let h0 = pencil_fingerprint(&p.a, &p.b, &cfg);
        if h0 != pencil_fingerprint(&p.a.clone(), &p.b.clone(), &cfg) {
            return Err("clone changed the fingerprint".into());
        }
        // Flip one random bit of one random element of A or B.
        let in_a = rng.below(2) == 0;
        let i = rng.below(n);
        let j = rng.below(n);
        let bit = rng.below(64) as u32;
        let flip = |m: &paraht::Matrix| {
            let mut m = m.clone();
            m[(i, j)] = f64::from_bits(m[(i, j)].to_bits() ^ (1u64 << bit));
            m
        };
        let h1 = if in_a {
            pencil_fingerprint(&flip(&p.a), &p.b, &cfg)
        } else {
            pencil_fingerprint(&p.a, &flip(&p.b), &cfg)
        };
        if h1 == h0 {
            return Err(format!(
                "bitflip (in_a={in_a}, i={i}, j={j}, bit={bit}) did not change the fingerprint"
            ));
        }
        Ok(())
    });
}

/// Queue stress hammer: randomized geometry, concurrent submitters,
/// shutdown at random points mid-flood. Every accepted ticket must
/// complete bitwise-correct; refused submissions must carry the typed
/// shutdown error; shutdown must never hang (a hang here is a queue
/// drain/wakeup race).
///
/// Ignored by default; the CI pool-stress job's `pool_stress` name filter
/// runs it alongside the worker-pool hammer. Locally:
/// `cargo test --release pool_stress -- --ignored`.
#[test]
#[ignore = "stress hammer; run explicitly or via the CI pool-stress job"]
fn pool_stress_serve_queue() {
    let iters = paraht::util::env::stress_iters(30);
    let mut rng = Rng::new(0x5EA7);
    let sizes = [2usize, 6, 10, 16];
    // Shared pencil/oracle pool across iterations (small, cheap).
    let base = Config { r: 4, p: 2, q: 2, ..Config::default() };
    let pencils: Vec<Pencil> = sizes.iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let oracles: Vec<HtDecomposition> =
        pencils.iter().map(|p| serve_oracle(p, &base)).collect();

    for iter in 0..iters {
        let cfg = ServeConfig {
            shards: 1 + rng.below(4),
            queue_capacity: 1 + rng.below(6),
            cache_entries: [0usize, 2, 64][rng.below(3)],
            base: base.clone(),
            ..ServeConfig::default()
        };
        let queue = SubmitQueue::new(ShardRouter::new(cfg).unwrap());
        let handle = queue.handle();
        let reps = 1 + rng.below(5);
        let shutdown_early = iter % 2 == 0;
        let delay_us = rng.below(500) as u64;
        std::thread::scope(|s| {
            let joins: Vec<_> = (0..3)
                .map(|_| {
                    let handle = handle.clone();
                    let pencils = &pencils;
                    s.spawn(move || {
                        let mut accepted = Vec::new();
                        for _ in 0..reps {
                            for (i, p) in pencils.iter().enumerate() {
                                match handle.submit(p.a.clone(), p.b.clone()) {
                                    Ok(t) => accepted.push((i, t)),
                                    Err(e) => {
                                        assert!(matches!(e, Error::Runtime(_)), "{e}")
                                    }
                                }
                            }
                        }
                        accepted
                    })
                })
                .collect();
            if shutdown_early {
                std::thread::sleep(Duration::from_micros(delay_us));
                queue.shutdown(); // mid-flood: drain + join must not hang
            } else {
                // Drain by waiting first, then shut down idle.
                for join in joins {
                    for (i, t) in join.join().unwrap() {
                        let d = t.wait().expect("ticket completes");
                        assert_bitwise(&d, &oracles[i], &format!("iter {iter} pencil {i}"));
                    }
                }
                queue.shutdown();
                return;
            }
            for join in joins {
                for (i, t) in join.join().unwrap() {
                    let d = t.wait().expect("accepted ticket completes across shutdown");
                    assert_bitwise(&d, &oracles[i], &format!("iter {iter} pencil {i}"));
                }
            }
        });
    }
}
