//! Seeded property tests for the linalg substrate, driven by the
//! `util::proptest` case-sweep harness: factor-reconstruct round-trips,
//! orthogonality at machine precision, and WY-vs-naive reflector
//! application equivalence, over randomized square / rectangular /
//! degenerate shapes.

use paraht::coordinator::access::{MatId, Region};
use paraht::linalg::gemm::{gemm, gemm_par, matmul, matmul_t, Trans};
use paraht::linalg::householder::{larf_left, Reflector};
use paraht::linalg::lu::LuFactor;
use paraht::linalg::matrix::Matrix;
use paraht::linalg::qr::{lq, QrFactor};
use paraht::linalg::rq::RqFactor;
use paraht::linalg::wy::Side;
use paraht::util::proptest::{
    check_rel, check_that, for_each_case, gen_shape, gen_square_dim, max_abs_diff, rel_diff,
};
use paraht::util::rng::Rng;

/// Orthogonality residual `‖QᵀQ − I‖_F` scaled by the order.
fn orth_residual(q: &Matrix) -> f64 {
    let n = q.cols();
    let qtq = matmul_t(q, Trans::Yes, q, Trans::No);
    rel_diff(&qtq, &Matrix::identity(n)) / (n as f64).max(1.0).sqrt()
}

/// Naive triple-loop reference: `alpha·op(A)·op(B) + beta·C0`.
fn gemm_reference(
    alpha: f64,
    a: &Matrix,
    ta: Trans,
    b: &Matrix,
    tb: Trans,
    beta: f64,
    c0: &Matrix,
) -> Matrix {
    let (m, k) = if ta == Trans::No { (a.rows(), a.cols()) } else { (a.cols(), a.rows()) };
    let n = if tb == Trans::No { b.cols() } else { b.rows() };
    Matrix::from_fn(m, n, |i, j| {
        let mut s = 0.0;
        for l in 0..k {
            let av = if ta == Trans::No { a[(i, l)] } else { a[(l, i)] };
            let bv = if tb == Trans::No { b[(l, j)] } else { b[(j, l)] };
            s += av * bv;
        }
        alpha * s + beta * c0[(i, j)]
    })
}

#[test]
fn property_gemm_matches_naive_reference() {
    // All four Trans combos × alpha/beta corner cases over randomized
    // shapes biased toward tile boundaries and degenerate (1×1, odd,
    // tall-skinny) cases. Tolerance: the packed kernel and the naive loop
    // differ only by summation-order rounding, O(k·eps) relative.
    for_each_case(60, 0x9a01, |rng| {
        let (m, n) = gen_shape(rng, 40);
        // Inner dim: 1-in-3 degenerate/small, else up to a KC-crossing 300.
        let k = match rng.below(3) {
            0 => 1 + rng.below(3),
            1 => 1 + rng.below(40),
            _ => 250 + rng.below(60),
        };
        let alphas = [1.0, -1.0, 0.0, 2.5];
        let betas = [0.0, 1.0, -0.5];
        let alpha = alphas[rng.below(alphas.len())];
        let beta = betas[rng.below(betas.len())];
        let ta = if rng.below(2) == 0 { Trans::No } else { Trans::Yes };
        let tb = if rng.below(2) == 0 { Trans::No } else { Trans::Yes };
        let a = if ta == Trans::No { Matrix::randn(m, k, rng) } else { Matrix::randn(k, m, rng) };
        let b = if tb == Trans::No { Matrix::randn(k, n, rng) } else { Matrix::randn(n, k, rng) };
        let c0 = Matrix::randn(m, n, rng);
        let want = gemm_reference(alpha, &a, ta, &b, tb, beta, &c0);
        let mut got = c0.clone();
        gemm(alpha, a.as_ref(), ta, b.as_ref(), tb, beta, got.as_mut());
        // ~ulp-scale with √k rounding growth; floor at 1e-13.
        let tol = (1e-14 * (k as f64 + 1.0).sqrt()).max(1e-13);
        check_rel(
            &format!("gemm {m}x{n}x{k} {ta:?}{tb:?} a={alpha} b={beta}"),
            rel_diff(&got, &want),
            tol,
        )?;
        Ok(())
    });
}

#[test]
fn property_gemm_par_bitwise_equals_gemm() {
    // The determinism contract: any thread count gives exactly the bits of
    // the sequential kernel (this is what lets the coordinator slice the
    // trailing updates freely).
    for_each_case(20, 0x9a02, |rng| {
        let m = 40 + rng.below(120);
        let n = 40 + rng.below(120);
        let k = 30 + rng.below(260);
        let a = Matrix::randn(m, k, rng);
        let b = Matrix::randn(k, n, rng);
        let c0 = Matrix::randn(m, n, rng);
        let mut want = c0.clone();
        gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 1.0, want.as_mut());
        let threads = 2 + rng.below(6);
        let mut got = c0.clone();
        gemm_par(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 1.0, got.as_mut(), threads);
        check_that(
            &format!("gemm_par {m}x{n}x{k} threads={threads} bitwise"),
            max_abs_diff(&got, &want) == 0.0,
        )?;
        Ok(())
    });
}

#[test]
fn property_static_and_dynamic_schedules_are_bitwise_identical() {
    // Slicing invariance, exercised through both schedulers: the static
    // one-panel-per-executor split and the work-assisting oversplit (~4×
    // panels claimed from an atomic counter) must both reproduce the
    // sequential kernel's bits exactly, for random shapes and thread
    // counts — including counts that do not divide the panel dimension.
    use paraht::coordinator::assist::Schedule;
    use paraht::linalg::gemm::gemm_par_sched;
    const SCHEDS: [(Schedule, &str); 2] =
        [(Schedule::Static, "static"), (Schedule::Dynamic, "dynamic")];
    for_each_case(16, 0x9a04, |rng| {
        // GEMM: shapes above the parallel flop threshold.
        let m = 40 + rng.below(120);
        let n = 40 + rng.below(120);
        let k = 30 + rng.below(260);
        let a = Matrix::randn(m, k, rng);
        let b = Matrix::randn(k, n, rng);
        let c0 = Matrix::randn(m, n, rng);
        let mut seq = c0.clone();
        gemm(1.0, a.as_ref(), Trans::No, b.as_ref(), Trans::No, 1.0, seq.as_mut());
        let threads = 2 + rng.below(6);
        for (sched, label) in SCHEDS {
            let mut got = c0.clone();
            gemm_par_sched(
                1.0,
                a.as_ref(),
                Trans::No,
                b.as_ref(),
                Trans::No,
                1.0,
                got.as_mut(),
                threads,
                sched,
            );
            check_that(
                &format!("gemm {label} {m}x{n}x{k} threads={threads} bitwise"),
                max_abs_diff(&got, &seq) == 0.0,
            )?;
        }

        // WY block-reflector application (the stage kernels' workhorse).
        let mw = 30 + rng.below(40);
        let kw = 1 + rng.below(12);
        let nc = 20 + rng.below(40);
        let (_, wy) = qr_reflectors(mw, kw, rng);
        let cw = Matrix::randn(mw, nc, rng);
        let mut seq_wy = cw.clone();
        wy.apply(Side::Left, Trans::Yes, seq_wy.as_mut());
        for (sched, label) in SCHEDS {
            let mut got = cw.clone();
            wy.apply_par_sched(Side::Left, Trans::Yes, got.as_mut(), threads, sched);
            check_that(
                &format!("wy {label} m={mw} k={kw} nc={nc} threads={threads} bitwise"),
                max_abs_diff(&got, &seq_wy) == 0.0,
            )?;
        }
        Ok(())
    });
}

#[test]
fn property_gemm_column_slicing_invariance() {
    // Computing C in arbitrary column panels reproduces the full-call bits
    // — the exact property the parallel apply tasks rely on.
    for_each_case(20, 0x9a03, |rng| {
        let m = 10 + rng.below(60);
        let n = 10 + rng.below(60);
        let k = 1 + rng.below(280);
        let a = Matrix::randn(m, k, rng);
        let b = Matrix::randn(k, n, rng);
        let full = matmul(&a, &b);
        let split = 1 + rng.below(n);
        let mut c = Matrix::zeros(m, n);
        let mut j = 0;
        while j < n {
            let je = (j + split).min(n);
            gemm(
                1.0,
                a.as_ref(),
                Trans::No,
                b.sub(0..k, j..je),
                Trans::No,
                0.0,
                c.sub_mut(0..m, j..je),
            );
            j = je;
        }
        check_that(
            &format!("column slicing {m}x{n}x{k} split={split}"),
            max_abs_diff(&c, &full) == 0.0,
        )?;
        Ok(())
    });
}

#[test]
fn property_qr_roundtrip_and_orthogonality() {
    for_each_case(40, 0x9121, |rng| {
        let (m, n) = gen_shape(rng, 36);
        let a = Matrix::randn(m, n, rng);
        let f = QrFactor::compute(&a);
        let q = f.form_q();
        let r = f.r();
        let k = f.k();
        // A = Q(:, :k) R
        let qk = Matrix::from_fn(m, k, |i, j| q[(i, j)]);
        check_rel(&format!("A-QR ({m}x{n})"), rel_diff(&matmul(&qk, &r), &a), 1e-12)?;
        // Q orthogonal at machine precision.
        check_rel(&format!("QtQ-I ({m}x{n})"), orth_residual(&q), 1e-13)?;
        // R upper triangular by construction (exact zeros).
        for j in 0..r.cols() {
            for i in j + 1..r.rows() {
                check_that("R strictly upper", r[(i, j)] == 0.0)?;
            }
        }
        Ok(())
    });
}

#[test]
fn property_lq_roundtrip() {
    for_each_case(25, 0x9122, |rng| {
        let (m, n) = gen_shape(rng, 30);
        let a = Matrix::randn(m, n, rng);
        let (l, wy) = lq(&a);
        let q = wy.form_q(); // n×n, A = L · Q̂ with Q̂ = Qᵀ
        let k = m.min(n);
        let qk = Matrix::from_fn(n, k, |i, j| q[(i, j)]);
        let back = matmul_t(&l, Trans::No, &qk, Trans::Yes);
        check_rel(&format!("A-LQ ({m}x{n})"), rel_diff(&back, &a), 1e-12)?;
        check_rel("LQ Q orth", orth_residual(&q), 1e-13)?;
        Ok(())
    });
}

#[test]
fn property_rq_roundtrip_and_orthogonality() {
    for_each_case(40, 0x9123, |rng| {
        let s = gen_square_dim(rng, 30);
        let a = Matrix::randn(s, s, rng);
        let f = RqFactor::compute(&a);
        let r = f.r();
        let q = f.form_q();
        check_rel(&format!("A-RQ (s={s})"), rel_diff(&matmul(&r, &q), &a), 1e-12)?;
        check_rel(&format!("RQ Q orth (s={s})"), orth_residual(&q), 1e-13)?;
        for j in 0..s {
            for i in j + 1..s {
                check_that("R strictly upper", r[(i, j)] == 0.0)?;
            }
        }
        // Top rows of Q̃ match the materialized Q for every prefix height.
        let t = 1 + rng.below(s);
        let g = f.q_top_rows(t);
        let qt = Matrix::from_fn(t, s, |i, j| q[(i, j)]);
        check_rel("RQ top rows", max_abs_diff(&g, &qt), 1e-13)?;
        Ok(())
    });
}

#[test]
fn property_lu_reconstruct_and_solve() {
    for_each_case(40, 0x9124, |rng| {
        let s = gen_square_dim(rng, 30);
        let a = Matrix::randn(s, s, rng);
        let f = match LuFactor::compute(&a) {
            Ok(f) => f,
            Err(e) => return Err(format!("LU failed on random matrix (s={s}): {e}")),
        };
        // Reconstruct: P A = L U with the recorded row swaps.
        let mut pa = a.clone();
        for (k, &p) in f.piv.iter().enumerate() {
            if p != k {
                for j in 0..s {
                    let t = pa[(k, j)];
                    pa[(k, j)] = pa[(p, j)];
                    pa[(p, j)] = t;
                }
            }
        }
        let l = Matrix::from_fn(s, s, |i, j| {
            if i == j {
                1.0
            } else if i > j {
                f.lu[(i, j)]
            } else {
                0.0
            }
        });
        let u = Matrix::from_fn(s, s, |i, j| if j >= i { f.lu[(i, j)] } else { 0.0 });
        check_rel(&format!("PA-LU (s={s})"), rel_diff(&matmul(&l, &u), &pa), 1e-12)?;

        // Solve round-trip, tolerance scaled by the conditioning.
        let xt = Matrix::randn(s, 1, rng);
        let b = matmul(&a, &xt);
        let mut x: Vec<f64> = (0..s).map(|i| b[(i, 0)]).collect();
        f.solve_vec(&mut x);
        let err = (0..s).map(|i| (x[i] - xt[(i, 0)]).abs()).fold(0.0f64, f64::max);
        let tol = 1e-9 / f.rcond_estimate().max(1e-6);
        check_that(
            &format!("LU solve (s={s}): err {err:.2e} tol {tol:.2e}"),
            err <= tol,
        )?;
        Ok(())
    });
}

#[test]
fn property_householder_annihilation_and_orthogonality() {
    for_each_case(60, 0x9125, |rng| {
        let len = 1 + rng.below(40);
        let x: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let (refl, beta) = Reflector::reducing(&x);
        // H x = beta e1.
        let mut m = Matrix::from_fn(len, 1, |i, _| x[i]);
        refl.apply_left(m.as_mut());
        let scale = beta.abs().max(1.0);
        check_rel("Hx head", (m[(0, 0)] - beta).abs() / scale, 1e-13)?;
        for i in 1..len {
            check_rel("Hx tail", m[(i, 0)].abs() / scale, 1e-13)?;
        }
        // |beta| = ‖x‖.
        let nx = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        check_rel("norm preserved", (beta.abs() - nx).abs() / nx.max(1e-300), 1e-12)?;
        // H = I − τ v vᵀ is orthogonal and symmetric.
        let h = Matrix::from_fn(len, len, |i, j| {
            (if i == j { 1.0 } else { 0.0 }) - refl.tau * refl.v[i] * refl.v[j]
        });
        check_rel("H orth", orth_residual(&h), 1e-13)?;
        Ok(())
    });
}

/// Build `k` reflectors with QR (unit-lower-trapezoidal) structure and
/// return their full-length vectors + τ's and the compact-WY form.
fn qr_reflectors(m: usize, k: usize, rng: &mut Rng) -> (Vec<Reflector>, paraht::linalg::wy::WyRep) {
    let a = Matrix::randn(m, k, rng);
    let f = QrFactor::compute_inplace(a);
    let v = f.v_matrix();
    let refls = (0..f.k())
        .map(|i| Reflector {
            v: (0..m).map(|r| v[(r, i)]).collect(),
            tau: f.taus[i],
        })
        .collect();
    (refls, f.wy())
}

#[test]
fn property_wy_equals_naive_reflector_application() {
    for_each_case(30, 0x9126, |rng| {
        let m = 2 + rng.below(30);
        let k = 1 + rng.below(m.min(12));
        let nc = 1 + rng.below(20);
        let (refls, wy) = qr_reflectors(m, k, rng);
        let c = Matrix::randn(m, nc, rng);

        // Left, no transpose: Q C = H_1 ⋯ H_k C (apply H_k first).
        let mut got = c.clone();
        wy.apply(Side::Left, Trans::No, got.as_mut());
        let mut naive = c.clone();
        for h in refls.iter().rev() {
            larf_left(&h.v, h.tau, naive.as_mut());
        }
        check_rel(
            &format!("WY left (m={m} k={k})"),
            rel_diff(&got, &naive),
            1e-12,
        )?;

        // Left, transpose: Qᵀ C = H_k ⋯ H_1 C (apply H_1 first).
        let mut got = c.clone();
        wy.apply(Side::Left, Trans::Yes, got.as_mut());
        let mut naive = c.clone();
        for h in refls.iter() {
            larf_left(&h.v, h.tau, naive.as_mut());
        }
        check_rel(
            &format!("WY left^T (m={m} k={k})"),
            rel_diff(&got, &naive),
            1e-12,
        )?;

        // Right: D Q = ((Qᵀ Dᵀ))ᵀ — check against the transposed naive path.
        let d = Matrix::randn(nc, m, rng);
        let mut got = d.clone();
        wy.apply(Side::Right, Trans::No, got.as_mut());
        let mut naive_t = d.transposed();
        for h in refls.iter() {
            larf_left(&h.v, h.tau, naive_t.as_mut());
        }
        check_rel(
            &format!("WY right (m={m} k={k})"),
            rel_diff(&got, &naive_t.transposed()),
            1e-12,
        )?;

        // The materialized Q is orthogonal at machine precision.
        check_rel("WY Q orth", orth_residual(&wy.form_q()), 1e-13)?;
        Ok(())
    });
}

/// Random half-open range over `0..=max`, biased toward the interesting
/// degenerate shapes: ~1/4 zero-width (`k..k`, including the boundary
/// positions 0 and `max`), ~1/8 reversed (`hi..lo`, which must behave as
/// empty), the rest proper non-empty ranges.
fn gen_range(rng: &mut Rng, max: usize) -> std::ops::Range<usize> {
    match rng.below(8) {
        0 | 1 => {
            let k = rng.below(max + 1);
            k..k
        }
        2 => {
            let lo = rng.below(max);
            let hi = lo + 1 + rng.below(max - lo);
            hi..lo
        }
        _ => {
            let lo = rng.below(max);
            let hi = lo + 1 + rng.below(max - lo);
            lo..hi
        }
    }
}

/// Element-level reference for the `Region` predicates: a point is in a
/// region iff both its half-open ranges contain it.
fn points(r: &Region, max: usize) -> Vec<(usize, usize)> {
    (0..max)
        .flat_map(|i| (0..max).map(move |j| (i, j)))
        .filter(|&(i, j)| r.rows.contains(&i) && r.cols.contains(&j))
        .collect()
}

#[test]
fn property_region_intersects_matches_pointwise_reference_and_is_symmetric() {
    const MAX: usize = 9;
    for_each_case(300, 0x9140, |rng| {
        let a = Region::new(MatId::A, gen_range(rng, MAX), gen_range(rng, MAX));
        let same_mat = rng.below(4) != 0; // mostly same matrix, sometimes not
        let b = Region::new(
            if same_mat { MatId::A } else { MatId::B },
            gen_range(rng, MAX),
            gen_range(rng, MAX),
        );
        // Symmetry.
        check_that("intersect symmetry", a.intersects(&b) == b.intersects(&a))?;
        // Pointwise reference: regions intersect iff they share a point
        // (on the same matrix).
        let pa = points(&a, MAX);
        let pb = points(&b, MAX);
        let shared = same_mat && pa.iter().any(|p| pb.contains(p));
        check_that("intersect = shares a point", a.intersects(&b) == shared)?;
        // Empty regions are inert: no intersection, vacuously contained.
        if a.is_empty() {
            check_that("empty never intersects", !a.intersects(&b) && !b.intersects(&a))?;
            check_that("empty is vacuously contained", b.contains(&a))?;
            check_that("empty region spans no points", pa.is_empty())?;
        }
        Ok(())
    });
}

#[test]
fn property_region_contains_matches_pointwise_reference() {
    const MAX: usize = 9;
    for_each_case(300, 0x9141, |rng| {
        let a = Region::new(MatId::A, gen_range(rng, MAX), gen_range(rng, MAX));
        let same_mat = rng.below(4) != 0;
        let b = Region::new(
            if same_mat { MatId::A } else { MatId::B },
            gen_range(rng, MAX),
            gen_range(rng, MAX),
        );
        // Pointwise reference: a contains b iff every point of b is a
        // point of a (and they name the same matrix, unless b is empty).
        let pa = points(&a, MAX);
        let pb = points(&b, MAX);
        let reference = pb.is_empty() || (same_mat && pb.iter().all(|p| pa.contains(p)));
        check_that("contains = pointwise subset", a.contains(&b) == reference)?;
        // Containment of a non-empty region implies intersection.
        if a.contains(&b) && !b.is_empty() {
            check_that("contains(non-empty) implies intersects", a.intersects(&b))?;
        }
        // A region always contains itself.
        check_that("contains is reflexive", a.contains(&a))?;
        Ok(())
    });
}
