//! Integration tests: the full system across module boundaries.
//!
//! Unit tests live next to each module; these exercise whole pipelines —
//! the §4 accuracy claim ("relative backward errors on the order of the
//! machine precision") for every algorithm, equivalence between execution
//! modes, and the simulator's contracts on real traces.

use paraht::api::{reduce_seq as reduce_to_hessenberg_triangular, HtSession};
use paraht::baselines::one_stage::{OneStageOpts, OppositeMethod};
use paraht::baselines::{dgghd3, iterht, moler_stewart, one_stage};
use paraht::config::Config;
use paraht::coordinator::driver::iterht_recorded;
use paraht::coordinator::sim::simulate_makespan;
use paraht::linalg::matrix::Matrix;
use paraht::linalg::verify::{max_below_band, HtVerification};
use paraht::pencil::random::{random_pencil, random_pencil_general};
use paraht::pencil::saddle::saddle_pencil;
use paraht::util::proptest::for_each_case;
use paraht::util::rng::Rng;

/// §4 accuracy claim, for every algorithm on random pencils.
#[test]
fn all_algorithms_reach_machine_precision() {
    let n = 96;
    let mut rng = Rng::new(900);
    let p = random_pencil(n, &mut rng);

    // ParaHT (sequential driver).
    let cfg = Config { r: 8, p: 4, q: 4, ..Config::default() };
    let d = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
    assert!(d.verify(&p.a, &p.b).worst() < 1e-11, "ParaHT");

    // Moler–Stewart.
    let (mut a, mut b) = (p.a.clone(), p.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    moler_stewart::reduce(&mut a, &mut b, &mut q, &mut z);
    assert!(HtVerification::compute(&p.a, &p.b, &q, &z, &a, &b, 1).worst() < 1e-11, "MolerStewart");

    // DGGHD3.
    let (mut a, mut b) = (p.a.clone(), p.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    dgghd3::reduce(&mut a, &mut b, &mut q, &mut z);
    assert!(HtVerification::compute(&p.a, &p.b, &q, &z, &a, &b, 1).worst() < 1e-11, "DGGHD3");

    // HouseHT-style (one-stage with fallback).
    let (mut a, mut b) = (p.a.clone(), p.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    let opts = OneStageOpts { method: OppositeMethod::SolveWithFallback, ..Default::default() };
    one_stage::reduce(&mut a, &mut b, &mut q, &mut z, &opts).unwrap();
    assert!(HtVerification::compute(&p.a, &p.b, &q, &z, &a, &b, 1).worst() < 1e-10, "HouseHT");

    // IterHT-style.
    let (mut a, mut b) = (p.a.clone(), p.b.clone());
    let (mut q, mut z) = (Matrix::identity(n), Matrix::identity(n));
    iterht::reduce(&mut a, &mut b, &mut q, &mut z, &Default::default()).unwrap();
    assert!(HtVerification::compute(&p.a, &p.b, &q, &z, &a, &b, 1).worst() < 1e-10, "IterHT");
}

/// The three ParaHT execution paths agree: sequential two-stage,
/// coordinator with real threads, coordinator in trace mode.
#[test]
fn execution_modes_agree() {
    let n = 72;
    let mut rng = Rng::new(901);
    let p = random_pencil(n, &mut rng);
    let cfg = Config { r: 6, p: 3, q: 3, threads: 3, ..Config::default() };

    let d_seq = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
    let d_par = HtSession::builder()
        .config(cfg.clone())
        .threads(3)
        .build()
        .unwrap()
        .reduce(&p.a, &p.b)
        .unwrap();
    let d_tr = HtSession::builder()
        .config(cfg)
        .capture_traces(true)
        .build()
        .unwrap()
        .reduce(&p.a, &p.b)
        .unwrap();

    let mut dmax = 0.0f64;
    for j in 0..n {
        for i in 0..n {
            dmax = dmax.max((d_seq.h[(i, j)] - d_par.h[(i, j)]).abs());
            dmax = dmax.max((d_par.h[(i, j)] - d_tr.h[(i, j)]).abs());
            dmax = dmax.max((d_par.t[(i, j)] - d_tr.t[(i, j)]).abs());
        }
    }
    // Threads vs Trace run identical task bodies: bitwise equal. The
    // sequential driver uses the same kernels in the same order.
    assert_eq!(dmax, 0.0, "execution modes diverge: {dmax:.3e}");
}

/// Saddle-point behaviour matrix (Fig. 11 claims).
#[test]
fn saddle_point_behaviour() {
    let n = 64;
    let mut rng = Rng::new(902);
    let p = saddle_pencil(n, 0.25, &mut rng);

    // ParaHT succeeds at machine precision.
    let cfg = Config { r: 8, p: 4, q: 4, ..Config::default() };
    let d = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
    assert!(d.verify(&p.a, &p.b).worst() < 1e-11);

    // IterHT fails to converge.
    assert!(iterht_recorded(&p.a, &p.b).is_err());
}

/// General (non-triangular B) input goes through pre-triangularization.
#[test]
fn general_b_api() {
    let mut rng = Rng::new(903);
    let p = random_pencil_general(60, &mut rng);
    let cfg = Config { r: 6, p: 3, q: 3, ..Config::default() };
    let d = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
    d.verify(&p.a, &p.b).assert_ok(1e-11);
    assert!(max_below_band(&d.h, 1) < 1e-12 * d.h.norm_fro());
    assert_eq!(max_below_band(&d.t, 0), 0.0);
}

/// Property sweep: random shapes/tunings, ParaHT always verifies.
#[test]
fn property_random_tunings() {
    for_each_case(6, 0xF00D, |rng| {
        let n = 24 + rng.below(60);
        let r = 2 + rng.below(8);
        let p = 2 + rng.below(4);
        let q = 1 + rng.below(6);
        let pencil = random_pencil(n, rng);
        let cfg = Config { r, p, q, ..Config::default() };
        let d = reduce_to_hessenberg_triangular(&pencil.a, &pencil.b, &cfg)
            .map_err(|e| format!("reduce failed (n={n} r={r} p={p} q={q}): {e}"))?;
        let v = d.verify(&pencil.a, &pencil.b);
        if v.worst() > 1e-10 {
            return Err(format!(
                "verification n={n} r={r} p={p} q={q}: worst {:.3e}",
                v.worst()
            ));
        }
        Ok(())
    });
}

/// Simulator contracts on a *real* ParaHT trace.
#[test]
fn simulator_contracts_on_real_trace() {
    let mut rng = Rng::new(904);
    let p = random_pencil(80, &mut rng);
    let cfg = Config { r: 8, p: 4, q: 4, slices: 16, ..Config::default() };
    let mut session = HtSession::builder().config(cfg).capture_traces(true).build().unwrap();
    session.reduce(&p.a, &p.b).unwrap();
    let (t1, t2) = session.take_traces().unwrap();
    for tr in [&t1, &t2] {
        let s1 = simulate_makespan(tr, 1);
        assert!((s1.makespan - tr.total().as_secs_f64()).abs() < 1e-9);
        let mut last = f64::INFINITY;
        for p in [1usize, 2, 4, 8, 16, 32] {
            let s = simulate_makespan(tr, p);
            assert!(s.makespan <= last + 1e-12, "monotone violated at P={p}");
            assert!(s.makespan + 1e-12 >= s.critical_path);
            assert!(s.makespan + 1e-12 >= s.total_work / p as f64);
            last = s.makespan;
        }
    }
}

/// Scheduler stress: many runs with different thread counts all agree.
#[test]
fn scheduler_stress_determinism() {
    let n = 48;
    let mut rng = Rng::new(905);
    let p = random_pencil(n, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 2, slices: 8, ..Config::default() };
    let reference = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
    for threads in [2usize, 3, 5, 8] {
        let mut session =
            HtSession::builder().config(cfg.clone()).threads(threads).build().unwrap();
        let run = session.reduce(&p.a, &p.b).unwrap();
        let mut dmax = 0.0f64;
        for j in 0..n {
            for i in 0..n {
                dmax = dmax.max((reference.h[(i, j)] - run.h[(i, j)]).abs());
                dmax = dmax.max((reference.q[(i, j)] - run.q[(i, j)]).abs());
            }
        }
        assert_eq!(dmax, 0.0, "threads={threads} diverged");
    }
}
