//! Stress tests: randomized scheduler DAGs and factorization shape sweeps.
//!
//! These push the coordinator and the linalg substrate beyond the shapes
//! the algorithms naturally produce.

use paraht::coordinator::access::{Access, MatId};
use paraht::coordinator::graph::{TaskClass, TaskGraph};
use paraht::coordinator::pool::run_parallel;
use paraht::coordinator::sim::simulate_makespan;
use paraht::linalg::gemm::{matmul, matmul_t, Trans};
use paraht::linalg::lu::LuFactor;
use paraht::linalg::matrix::Matrix;
use paraht::linalg::qr::QrFactor;
use paraht::linalg::rq::RqFactor;
use paraht::util::proptest::{check_rel, for_each_case};
use paraht::util::rng::Rng;
use std::sync::atomic::{AtomicU64, Ordering};

fn rel(x: &Matrix, y: &Matrix) -> f64 {
    let mut d = 0.0;
    for j in 0..x.cols() {
        for i in 0..x.rows() {
            d += (x[(i, j)] - y[(i, j)]).powi(2);
        }
    }
    d.sqrt() / y.norm_fro().max(1e-300)
}

/// Random DAGs over a shared "ledger": each task multiplies its cell region
/// by a prime; the final product is order-independent only if the schedule
/// respects every conflict edge — so any race or missed edge shows up as a
/// wrong product with high probability (the per-cell sequences are checked,
/// not just the commutative product).
#[test]
fn random_dag_scheduler_stress() {
    for_each_case(8, 0xDA6, |rng| {
        let cells: Vec<AtomicU64> = (0..16).map(|_| AtomicU64::new(0)).collect();
        let ntasks = 40 + rng.below(60);
        // Build the same graph twice (regions drawn deterministically from
        // a recorded plan), run sequentially and in parallel, compare.
        let plan: Vec<(usize, usize, bool)> = (0..ntasks)
            .map(|_| (rng.below(16), 1 + rng.below(4), rng.below(3) == 0))
            .collect();

        let run_with = |threads: usize| -> Vec<u64> {
            for c in &cells {
                c.store(0, Ordering::SeqCst);
            }
            let mut g = TaskGraph::new();
            for (i, &(start, width, wide)) in plan.iter().enumerate() {
                let end = (start + width).min(16);
                let acc = if wide {
                    vec![Access::write(MatId::A, 0..1, 0..16)]
                } else {
                    vec![Access::write(MatId::A, 0..1, start..end)]
                };
                let cells = &cells;
                let (s, e) = if wide { (0, 16) } else { (start, end) };
                g.add(TaskClass::Upd2, acc, move || {
                    for c in &cells[s..e] {
                        // Mix the task id in — order within conflicts fixed
                        // by the DAG, so the fold below is deterministic.
                        let old = c.load(Ordering::SeqCst);
                        c.store(old.wrapping_mul(31).wrapping_add(i as u64 + 1), Ordering::SeqCst);
                    }
                });
            }
            g.finalize();
            run_parallel(g, threads);
            cells.iter().map(|c| c.load(Ordering::SeqCst)).collect()
        };

        let seq = run_with(1);
        for threads in [2usize, 4] {
            let par = run_with(threads);
            if par != seq {
                return Err(format!("scheduler divergence at {threads} threads"));
            }
        }
        Ok(())
    });
}

/// Simulator sanity on randomized DAG structures.
#[test]
fn simulator_random_dags() {
    for_each_case(10, 0x51A, |rng| {
        let n = 20 + rng.below(50);
        let mut deps: Vec<Vec<usize>> = Vec::with_capacity(n);
        for i in 0..n {
            let ndeps = rng.below(3.min(i + 1));
            let mut d: Vec<usize> = (0..ndeps).map(|_| rng.below(i.max(1))).collect();
            d.dedup();
            deps.push(d);
        }
        let trace = paraht::coordinator::graph::TaskTrace {
            durations: (0..n)
                .map(|_| std::time::Duration::from_micros(1 + rng.below(500) as u64))
                .collect(),
            classes: vec![TaskClass::Upd2; n],
            deps,
        };
        let s1 = simulate_makespan(&trace, 1);
        if (s1.makespan - trace.total().as_secs_f64()).abs() > 1e-9 {
            return Err("P=1 != total work".into());
        }
        let mut last = f64::INFINITY;
        for p in [1usize, 2, 3, 5, 9, 17] {
            let s = simulate_makespan(&trace, p);
            if s.makespan > last + 1e-12 {
                return Err(format!("not monotone at P={p}"));
            }
            if s.makespan + 1e-12 < s.critical_path {
                return Err("below critical path".into());
            }
            last = s.makespan;
        }
        Ok(())
    });
}

/// Factorization sweep over adversarial shapes (tall, wide, tiny, square).
#[test]
fn factorization_shape_sweep() {
    for_each_case(25, 0xFAC7, |rng| {
        let m = 1 + rng.below(40);
        let n = 1 + rng.below(40);
        let a = Matrix::randn(m, n, rng);

        // QR
        let f = QrFactor::compute(&a);
        let q = f.form_q();
        let k = f.k();
        let qk = Matrix::from_fn(m, k, |i, j| q[(i, j)]);
        check_rel("A-QR", rel(&matmul(&qk, &f.r()), &a), 1e-11)?;

        // RQ (square only)
        let s = m.min(n).max(1);
        let sq = Matrix::randn(s, s, rng);
        let rq = RqFactor::compute(&sq);
        check_rel("A-RQ", rel(&matmul(&rq.r(), &rq.form_q()), &sq), 1e-11)?;

        // LU solve (square, likely well conditioned)
        let lu = LuFactor::compute(&sq).map_err(|e| format!("LU: {e}"))?;
        let xt = Matrix::randn(s, 1, rng);
        let b = matmul(&sq, &xt);
        let mut x: Vec<f64> = (0..s).map(|i| b[(i, 0)]).collect();
        lu.solve_vec(&mut x);
        let xerr = (0..s)
            .map(|i| (x[i] - xt[(i, 0)]).abs())
            .fold(0.0f64, f64::max);
        // Random square matrices can be ill-conditioned; scale tolerance.
        if xerr > 1e-6 / lu.rcond_estimate().max(1e-8) {
            return Err(format!("LU solve err {xerr:.2e} rcond {:.2e}", lu.rcond_estimate()));
        }
        Ok(())
    });
}

/// GEMM sweep: random shapes, all transpose combinations vs naive.
#[test]
fn gemm_shape_sweep() {
    for_each_case(20, 0x6E33, |rng| {
        let m = 1 + rng.below(50);
        let n = 1 + rng.below(50);
        let k = 1 + rng.below(70);
        for (ta, tb) in [
            (Trans::No, Trans::No),
            (Trans::Yes, Trans::No),
            (Trans::No, Trans::Yes),
            (Trans::Yes, Trans::Yes),
        ] {
            let a = if ta == Trans::No { Matrix::randn(m, k, rng) } else { Matrix::randn(k, m, rng) };
            let b = if tb == Trans::No { Matrix::randn(k, n, rng) } else { Matrix::randn(n, k, rng) };
            let got = matmul_t(&a, ta, &b, tb);
            let want = Matrix::from_fn(m, n, |i, j| {
                let mut s = 0.0;
                for l in 0..k {
                    let av = if ta == Trans::No { a[(i, l)] } else { a[(l, i)] };
                    let bv = if tb == Trans::No { b[(l, j)] } else { b[(j, l)] };
                    s += av * bv;
                }
                s
            });
            check_rel("gemm", rel(&got, &want), 1e-11)?;
        }
        Ok(())
    });
}

/// Saddle pencils across the ∞-eigenvalue fraction range reduce correctly.
#[test]
fn saddle_fraction_sweep() {
    use paraht::api::reduce_seq as reduce_to_hessenberg_triangular;
    use paraht::config::Config;
    use paraht::pencil::saddle::saddle_pencil;
    for frac in [0.0, 0.1, 0.25, 0.5] {
        let mut rng = Rng::new(0xF4AC + (frac * 100.0) as u64);
        let p = saddle_pencil(48, frac, &mut rng);
        let cfg = Config { r: 6, p: 3, q: 3, ..Config::default() };
        let d = reduce_to_hessenberg_triangular(&p.a, &p.b, &cfg).unwrap();
        assert!(
            d.verify(&p.a, &p.b).worst() < 1e-10,
            "saddle frac {frac}: worst {:.3e}",
            d.verify(&p.a, &p.b).worst()
        );
    }
}

/// Submit/drain/shutdown hammer for the persistent worker pool
/// (`coordinator::pool::WorkerPool`): rapid pool lifecycles, batches of
/// varied shapes (empty, single-task, dependency chains, wide fan-outs),
/// concurrent submitters sharing one team, nested submission from inside a
/// job, and panicking batches — the lost-wakeup and shutdown-race surface.
///
/// Ignored by default (it is a hammer, not a unit test); CI runs it in the
/// non-blocking pool-stress job with a high `PALLAS_STRESS_ITERS`.
/// Locally: `cargo test --release pool_stress -- --ignored`.
#[test]
#[ignore = "stress hammer; run explicitly or via the CI pool-stress job"]
fn pool_stress() {
    use paraht::coordinator::pool::WorkerPool;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let iters: usize = paraht::util::env::stress_iters(40);
    let mut rng = Rng::new(0x500_57);
    for iter in 0..iters {
        // Fresh pool per iteration: spawn → submit → drain → shutdown.
        let pool = WorkerPool::new(rng.below(5));
        let batches = 1 + rng.below(4);
        for _ in 0..batches {
            let n = rng.below(65); // includes the empty batch
            let threads = 1 + rng.below(8);
            let counter = AtomicU64::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks(tasks, threads);
            assert_eq!(counter.load(Ordering::SeqCst), n as u64, "lost task (iter {iter})");
        }

        // A dependency chain: order must hold under any worker count.
        {
            let chain = 2 + rng.below(24);
            let last = AtomicU64::new(0);
            let mut g = TaskGraph::new();
            for i in 0..chain {
                let last = &last;
                g.add(
                    TaskClass::Upd2,
                    vec![Access::write(MatId::A, 0..1, 0..1)],
                    move || {
                        let prev = last.swap(i as u64 + 1, Ordering::SeqCst);
                        assert_eq!(prev, i as u64, "chain order violated");
                    },
                );
            }
            g.finalize();
            pool.run_graph(g, 1 + rng.below(6));
            assert_eq!(last.load(Ordering::SeqCst), chain as u64);
        }

        // Concurrent submitters sharing the team (every 4th iteration).
        if iter % 4 == 0 {
            std::thread::scope(|s| {
                for _ in 0..3 {
                    let pool = &pool;
                    s.spawn(move || {
                        let c = AtomicU64::new(0);
                        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32)
                            .map(|_| {
                                Box::new(|| {
                                    c.fetch_add(1, Ordering::SeqCst);
                                }) as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        pool.run_tasks(tasks, 4);
                        assert_eq!(c.load(Ordering::SeqCst), 32);
                    });
                }
            });
        }

        // Nested submission from inside a job (every 5th iteration).
        if iter % 5 == 0 {
            let c = AtomicU64::new(0);
            let mut g = TaskGraph::new();
            {
                let pool = &pool;
                let c = &c;
                g.add(TaskClass::Gemm, vec![], move || {
                    let inner: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                        .map(|_| {
                            Box::new(|| {
                                c.fetch_add(1, Ordering::SeqCst);
                            }) as Box<dyn FnOnce() + Send + '_>
                        })
                        .collect();
                    pool.run_tasks(inner, 3);
                });
            }
            g.finalize();
            pool.run_graph(g, 2);
            assert_eq!(c.load(Ordering::SeqCst), 8);
        }

        // A panicking batch must fail fast, not deadlock, and must leave
        // the pool reusable (every 8th iteration; kept sparse to limit
        // panic-hook stderr noise in CI logs).
        if iter % 8 == 0 {
            let r = catch_unwind(AssertUnwindSafe(|| {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..16)
                    .map(|i| {
                        Box::new(move || {
                            if i == 3 {
                                panic!("stress panic");
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_tasks(tasks, 4);
            }));
            assert!(r.is_err(), "panic must propagate (iter {iter})");
            let c = AtomicU64::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        c.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks(tasks, 4);
            assert_eq!(c.load(Ordering::SeqCst), 8, "pool unusable after panic");
        }

        pool.shutdown(); // joins every worker; a hang here is a shutdown race
    }
}

/// Work-assisting twin of [`pool_stress`]: the same submit/drain/shutdown
/// hammer, but every dependency-free batch is forced through the dynamic
/// claim-counter drain (`run_tasks_sched(.., Schedule::Dynamic)`), with
/// randomized panel counts (including 0, 1, and more panels than workers),
/// randomized helper counts, concurrent submitters racing on one team, and
/// mid-run panics that must poison the batch without hanging the counter
/// wait (`claimed != completed` is exactly the window a lost wakeup hides
/// in). Name keeps the `pool_stress` prefix so the CI pool-stress job's
/// name filter picks both hammers up.
///
/// Ignored by default; locally:
/// `cargo test --release pool_stress -- --ignored`.
#[test]
#[ignore = "stress hammer; run explicitly or via the CI pool-stress job"]
fn pool_stress_assist() {
    use paraht::coordinator::assist::Schedule;
    use paraht::coordinator::pool::WorkerPool;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    let iters: usize = paraht::util::env::stress_iters(40);
    let mut rng = Rng::new(0xA5_5157);
    for iter in 0..iters {
        // Fresh pool per iteration: spawn → submit → drain → shutdown.
        let pool = WorkerPool::new(rng.below(5));
        let batches = 1 + rng.below(4);
        for _ in 0..batches {
            // Panel-count extremes on purpose: empty (no claimable index),
            // one panel (exactly one claimer wins), and counts far above
            // the worker count (every worker's claim loop must drain).
            let n = match rng.below(4) {
                0 => 0,
                1 => 1,
                _ => 2 + rng.below(63),
            };
            let threads = 1 + rng.below(8);
            let counter = AtomicU64::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks_sched(tasks, threads, Schedule::Dynamic);
            assert_eq!(
                counter.load(Ordering::SeqCst),
                n as u64,
                "lost or double-claimed panel (iter {iter})"
            );
        }

        // Concurrent submitters racing assisted batches on the shared team
        // (every 3rd iteration): each batch owns its own claim counter, so
        // interleaved claims from two batches must never cross-complete.
        if iter % 3 == 0 {
            std::thread::scope(|s| {
                for _ in 0..3 {
                    let pool = &pool;
                    s.spawn(move || {
                        let c = AtomicU64::new(0);
                        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32)
                            .map(|_| {
                                Box::new(|| {
                                    c.fetch_add(1, Ordering::SeqCst);
                                }) as Box<dyn FnOnce() + Send + '_>
                            })
                            .collect();
                        pool.run_tasks_sched(tasks, 4, Schedule::Dynamic);
                        assert_eq!(c.load(Ordering::SeqCst), 32);
                    });
                }
            });
        }

        // A panic at a random claimed index must poison the batch (later
        // claims are dropped, not run), propagate to the submitter, and
        // leave the pool reusable for the next assisted batch (every 6th
        // iteration; sparse to limit panic-hook stderr noise).
        if iter % 6 == 0 {
            let n = 8 + rng.below(24);
            let bomb = rng.below(n);
            let r = catch_unwind(AssertUnwindSafe(|| {
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
                    .map(|i| {
                        Box::new(move || {
                            if i == bomb {
                                panic!("assist stress panic");
                            }
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_tasks_sched(tasks, 1 + rng.below(6), Schedule::Dynamic);
            }));
            assert!(r.is_err(), "panic must propagate (iter {iter})");
            let c = AtomicU64::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    Box::new(|| {
                        c.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_tasks_sched(tasks, 4, Schedule::Dynamic);
            assert_eq!(c.load(Ordering::SeqCst), 8, "pool unusable after panic");
        }

        pool.shutdown(); // joins every worker; a hang here is a claim-wait race
    }
}

/// Profile-reload hammer for the serving tier: submitter threads flood a
/// router+queue with mixed-size pencils while a reloader thread hot-swaps
/// tuned profiles (install / replace / clear) under them the whole time.
/// Every accepted ticket must complete, and every result must match
/// `reduce_seq` under *one of* the candidate effective configs — the
/// reload race decides which geometry a job ran with, never whether its
/// bits are right. Name keeps the `pool_stress` prefix so the CI
/// pool-stress job's name filter picks this hammer up too.
///
/// Ignored by default; locally:
/// `cargo test --release pool_stress -- --ignored`.
#[test]
#[ignore = "stress hammer; run explicitly or via the CI pool-stress job"]
fn pool_stress_tune() {
    use paraht::api::reduce_seq;
    use paraht::config::Config;
    use paraht::pencil::random::random_pencil;
    use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
    use paraht::tune::{ClassProfile, TunedProfile};
    use paraht::util::proptest::max_abs_diff;
    use std::sync::atomic::AtomicBool;

    let iters: usize = paraht::util::env::stress_iters(40);
    let mut rng = Rng::new(0x7_0E_5157);

    // The candidate profiles the reloader cycles through (None = untuned).
    // Distinct geometry per candidate, so a stale-workspace or mislabeled
    // cache bug cannot hide behind identical configs.
    let one_class = |r: usize, p: usize, q: usize| TunedProfile {
        classes: vec![ClassProfile {
            n_min: r + 1,
            n_max: 0,
            r,
            p,
            q,
            slices: 0,
            threads: 0,
            predicted_makespan: 0.0,
            default_makespan: 0.0,
            trace_n: 32,
        }],
    };
    let candidates: Vec<Option<TunedProfile>> =
        vec![None, Some(one_class(4, 2, 2)), Some(one_class(8, 4, 4)), Some(one_class(6, 2, 4))];

    for iter in 0..iters {
        let scfg = ServeConfig {
            shards: 1 + rng.below(3),
            // Small cache some iterations, none on others: both the
            // hit/miss path and the pure-reduce path race the reloads.
            cache_entries: if iter % 2 == 0 { 32 } else { 0 },
            base: Config { r: 8, p: 4, q: 4, ..Config::default() },
            ..ServeConfig::default()
        };
        let base = scfg.base.clone();
        let queue = SubmitQueue::new(ShardRouter::new(scfg).unwrap());
        let sizes = [2usize, 6, 12, 20, 33];
        let pool: Vec<_> = sizes.iter().map(|&n| random_pencil(n, &mut rng)).collect();

        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            // Reloader: swap profiles as fast as the router accepts them.
            let reloader = {
                let queue = &queue;
                let stop = &stop;
                let candidates = &candidates;
                s.spawn(move || {
                    let mut i = 0usize;
                    while !stop.load(Ordering::SeqCst) {
                        let p = candidates[i % candidates.len()].clone();
                        queue.router().reload_profile(p).unwrap();
                        i += 1;
                        std::thread::yield_now();
                    }
                })
            };

            // Submitters: flood while the geometry shifts underneath.
            let submitters: Vec<_> = (0..3)
                .map(|_| {
                    let handle = queue.handle();
                    let pool = &pool;
                    let base = &base;
                    let candidates = &candidates;
                    s.spawn(move || {
                        for round in 0..12 {
                            let p = &pool[round % pool.len()];
                            let n = p.n();
                            let d = handle
                                .submit(p.a.clone(), p.b.clone())
                                .expect("submission accepted")
                                .wait()
                                .expect("served reduction succeeds");
                            // The job ran under *some* candidate's effective
                            // config; its bits must match that oracle exactly.
                            let matched = candidates.iter().any(|cand| {
                                let eff = match cand {
                                    Some(prof) => prof.apply(base, n).clipped_for(n),
                                    None => base.clipped_for(n),
                                };
                                let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
                                max_abs_diff(&d.h, &oracle.h) == 0.0
                                    && max_abs_diff(&d.t, &oracle.t) == 0.0
                                    && max_abs_diff(&d.q, &oracle.q) == 0.0
                                    && max_abs_diff(&d.z, &oracle.z) == 0.0
                            });
                            assert!(matched, "n={n}: result matches no candidate oracle");
                        }
                    })
                })
                .collect();
            // Join the flood first (propagating any assert panic), *then*
            // stop the reloader — otherwise the scope would wait forever
            // on a reloader that never sees `stop` flip.
            for sub in submitters {
                sub.join().expect("submitter thread panicked");
            }
            stop.store(true, Ordering::SeqCst);
            reloader.join().expect("reloader thread panicked");
        });
        queue.shutdown(); // drains accepted jobs; a hang here is a reload race
    }
}
