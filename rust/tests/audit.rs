//! End-to-end tests for the concurrency auditor (`coordinator::audit`):
//! the seeded-violation negative tests the subsystem exists for (a view
//! escaping its declared region; a dependency edge deliberately dropped
//! through the epoch window), activation gating, and audit-on smoke runs
//! of the claim counter, the serving queue, and a full threaded reduction.
//!
//! This file owns its process, which is what makes flipping the global
//! [`audit::set_override`] safe: the lib unit tests and the other
//! integration binaries never see it. Tests here serialize on a local
//! mutex because the override is process-global even within this binary.
#![cfg(any(feature = "audit", debug_assertions))]

use paraht::api::{reduce_seq, HtSession};
use paraht::config::Config;
use paraht::coordinator::access::{Access, MatId};
use paraht::coordinator::assist::{assist_loop, ClaimCounter};
use paraht::coordinator::audit;
use paraht::coordinator::graph::{TaskClass, TaskGraph};
use paraht::coordinator::slices::SharedMat;
use paraht::linalg::matrix::Matrix;
use paraht::pencil::random::random_pencil;
use paraht::serve::{ServeConfig, ShardRouter, SubmitQueue};
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Serialize every test in this binary: they all manipulate the
/// process-global auditor override. Robust against a failed (panicked)
/// test poisoning the lock — the next test just takes it over.
fn exclusive() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

#[test]
fn view_exceeding_declared_region_is_caught_with_diagnostics() {
    let _lock = exclusive();
    audit::set_override(Some(true));
    let mut m = Matrix::zeros(8, 8);
    let sh = SharedMat::tagged(&mut m, MatId::A);
    let mut g = TaskGraph::new();
    // Declares a 2×2 write but views 3×3 — the off-by-one the auditor
    // exists to catch.
    g.add(TaskClass::GL, vec![Access::write(MatId::A, 0..2, 0..2)], || {
        // SAFETY: single task, in bounds; intentionally outside the
        // declaration so the auditor (not UB) trips.
        let mut v = unsafe { sh.view(0..3, 0..3) };
        v.set(0, 0, 1.0);
    });
    g.finalize();
    let err = catch_unwind(AssertUnwindSafe(move || g.run_sequential())).unwrap_err();
    let msg = panic_message(err);
    assert!(msg.contains("concurrency audit failed"), "{msg}");
    assert!(msg.contains("containment"), "{msg}");
    assert!(msg.contains("task 0"), "names the offending task: {msg}");
    assert!(msg.contains("A[0..3, 0..3]"), "names the actual rectangle: {msg}");
    assert!(msg.contains("A[0..2, 0..2]"), "names the declared rectangle: {msg}");
    audit::set_override(None);
}

#[test]
fn deliberately_dropped_edge_is_reported_as_named_race() {
    let _lock = exclusive();
    audit::set_override(Some(true));
    let mut m = Matrix::zeros(8, 8);
    let sh = SharedMat::tagged(&mut m, MatId::A);
    let mut g = TaskGraph::new();
    // Task 0 writes A[0..4, 0..4]...
    g.add(TaskClass::Upd2, vec![Access::write(MatId::A, 0..4, 0..4)], || {
        // SAFETY: in bounds, inside the declaration.
        let mut v = unsafe { sh.view(0..4, 0..4) };
        v.set(0, 0, 1.0);
    });
    // ...then three epoch boundaries with B-only filler tasks push task 0
    // out of the conflict-scan window (EPOCH_WINDOW = 3), so the
    // conflicting task below gets NO edge — the exact failure mode of a
    // misused `new_epoch` (the fillers do not collectively rewrite A).
    for i in 0..3usize {
        g.new_epoch();
        g.add(TaskClass::LB, vec![Access::write(MatId::B, i..i + 1, 0..1)], || {});
    }
    g.new_epoch();
    // Task 4 overlaps task 0 on A[2..4, 2..4] with no ordering path.
    g.add(TaskClass::Upd2, vec![Access::write(MatId::A, 2..6, 2..6)], || {
        // SAFETY: in bounds, inside the declaration.
        let mut v = unsafe { sh.view(2..6, 2..6) };
        v.set(0, 0, 2.0);
    });
    assert!(
        g.tasks[4].deps.is_empty(),
        "precondition: the epoch window must actually have dropped the edge"
    );
    g.finalize();
    let err = catch_unwind(AssertUnwindSafe(move || g.run_sequential())).unwrap_err();
    let msg = panic_message(err);
    assert!(msg.contains("race"), "{msg}");
    assert!(msg.contains("no path 0 → 4"), "names both tasks and the missing path: {msg}");
    assert!(msg.contains("A[0..4, 0..4]"), "names task 0's rectangle: {msg}");
    assert!(msg.contains("A[2..6, 2..6]"), "names task 4's rectangle: {msg}");
    audit::set_override(None);
}

#[test]
fn scope_is_skipped_when_inactive_or_nothing_is_declared() {
    let _lock = exclusive();
    // Accessless graphs have nothing to check even with the auditor on.
    audit::set_override(Some(true));
    let mut g = TaskGraph::new();
    g.add(TaskClass::Gemm, vec![], || {});
    g.finalize();
    assert!(audit::scope_for(&g).is_none(), "accessless graph needs no scope");
    // A forced-off auditor skips scopes entirely, declared or not.
    audit::set_override(Some(false));
    assert!(!audit::active());
    let mut g = TaskGraph::new();
    g.add(TaskClass::GL, vec![Access::write(MatId::A, 0..2, 0..2)], || {});
    g.finalize();
    assert!(audit::scope_for(&g).is_none(), "forced-off auditor builds no scope");
    audit::set_override(Some(true));
    assert!(audit::active());
    assert!(audit::scope_for(&g).is_some(), "forced-on auditor audits declared graphs");
    audit::set_override(None);
}

#[test]
fn claim_counter_uniqueness_shadow_is_armed_under_audit() {
    let _lock = exclusive();
    audit::set_override(Some(true));
    // With the auditor on, the counter carries the hand-out shadow; a
    // clean drain must pass it (each index handed out exactly once).
    let c = ClaimCounter::new(64);
    let mut got = Vec::new();
    assist_loop(&c, |i| got.push(i));
    assert_eq!(got, (0..64).collect::<Vec<_>>());
    assert_eq!(c.claim(), None, "exhausted counter stays exhausted");
    audit::set_override(None);
}

#[test]
fn serve_tickets_complete_exactly_once_under_audit() {
    let _lock = exclusive();
    audit::set_override(Some(true));
    // Flood a small queue and drain it across shutdown: every ticket must
    // be filled exactly once (the dispatcher's lifecycle assert is armed
    // in this build) and match the sequential oracle.
    let mut rng = Rng::new(0xAD_01);
    let cfg = ServeConfig {
        shards: 2,
        queue_capacity: 4,
        base: Config { r: 4, p: 2, q: 2, ..Config::default() },
        ..ServeConfig::default()
    };
    let q = SubmitQueue::new(ShardRouter::new(cfg).unwrap());
    let h = q.handle();
    let pencils: Vec<_> = (0..6).map(|_| random_pencil(12, &mut rng)).collect();
    let tickets: Vec<_> =
        pencils.iter().map(|p| h.submit(p.a.clone(), p.b.clone()).unwrap()).collect();
    q.shutdown();
    let eff = Config { r: 4, p: 2, q: 2, ..Config::default() }.clipped_for(12);
    for (p, t) in pencils.iter().zip(tickets) {
        let d = t.wait().expect("accepted ticket completes across shutdown");
        let oracle = reduce_seq(&p.a, &p.b, &eff).unwrap();
        assert_eq!(max_abs_diff(&d.h, &oracle.h), 0.0);
    }
    audit::set_override(None);
}

#[test]
fn threaded_reduction_is_audit_clean_and_bitwise_the_oracle() {
    let _lock = exclusive();
    audit::set_override(Some(true));
    // The positive half of the acceptance criteria: a real stage-1 +
    // stage-2 graph run, fully audited (tagged handles, per-task context,
    // end-of-batch check), finishes with zero violations and does not
    // perturb a single bit. Non-divisible blocking exercises the clipped
    // edge rectangles — exactly where an off-by-one would hide.
    let mut rng = Rng::new(0xAD_02);
    let pencil = random_pencil(45, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 6, ..Config::default() };
    let oracle = reduce_seq(&pencil.a, &pencil.b, &cfg).unwrap();
    let before = audit::recorded_total();
    let mut session = HtSession::builder().config(cfg).threads(4).build().unwrap();
    let run = session.reduce(&pencil.a, &pencil.b).unwrap();
    assert!(audit::recorded_total() > before, "the audited run must record accesses");
    assert_eq!(max_abs_diff(&run.h, &oracle.h), 0.0);
    assert_eq!(max_abs_diff(&run.t, &oracle.t), 0.0);
    assert_eq!(max_abs_diff(&run.q, &oracle.q), 0.0);
    assert_eq!(max_abs_diff(&run.z, &oracle.z), 0.0);
    audit::set_override(None);
}

#[test]
fn simd_kernel_reduction_is_audit_clean_and_bitwise_its_own_oracle() {
    let _lock = exclusive();
    audit::set_override(Some(true));
    // Same positive acceptance run, forced onto the best kernel this CPU
    // has (AVX2/NEON when present, scalar otherwise): the SIMD microkernel
    // changes the *bits inside* each declared rectangle, never which
    // rectangles are touched — so the audited graph stays violation-free
    // and the threaded run stays bitwise the sequential oracle *under the
    // same kernel*. On scalar-only hosts this degenerates to the test
    // above, which is exactly the clamping contract.
    use paraht::linalg::Kernel;
    let best = *Kernel::all_available().last().unwrap();
    let mut rng = Rng::new(0xAD_03);
    let pencil = random_pencil(45, &mut rng);
    let cfg = Config {
        r: 4,
        p: 3,
        q: 3,
        slices: 6,
        kernel: best.choice(),
        ..Config::default()
    };
    let oracle = reduce_seq(&pencil.a, &pencil.b, &cfg).unwrap();
    let before = audit::recorded_total();
    let mut session = HtSession::builder().config(cfg).threads(4).build().unwrap();
    let run = session.reduce(&pencil.a, &pencil.b).unwrap();
    assert!(
        audit::recorded_total() > before,
        "the audited SIMD ({}) run must record accesses",
        best.name()
    );
    assert_eq!(max_abs_diff(&run.h, &oracle.h), 0.0, "{} H", best.name());
    assert_eq!(max_abs_diff(&run.t, &oracle.t), 0.0, "{} T", best.name());
    assert_eq!(max_abs_diff(&run.q, &oracle.q), 0.0, "{} Q", best.name());
    assert_eq!(max_abs_diff(&run.z, &oracle.z), 0.0, "{} Z", best.name());
    audit::set_override(None);
}
