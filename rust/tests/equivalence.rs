//! Equivalence tests pinning the parallel coordinator paths to the
//! sequential oracle: `coordinator::{stage1_par, stage2_par}` (driven
//! through `run_paraht`) must produce the same `(H, T, Q, Z)` as
//! `ht::two_stage::reduce_to_hessenberg_triangular` under every execution
//! mode — including block sizes that do not divide the problem size.
//!
//! The task bodies are the same kernels executed in a valid topological
//! order, and every slice kernel is bitwise independent of the slicing
//! (see the per-column/per-row notes in `linalg::gemm`), so the comparison
//! is exact equality, not a tolerance.

use paraht::config::Config;
use paraht::coordinator::driver::run_paraht;
use paraht::coordinator::stage1_par::ExecMode;
use paraht::ht::reduce_to_hessenberg_triangular;
use paraht::linalg::verify::max_below_band;
use paraht::pencil::random::{random_pencil, Pencil};
use paraht::pencil::saddle::saddle_pencil;
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;

/// Every execution mode exercised by the equivalence sweep.
fn exec_modes() -> Vec<ExecMode> {
    vec![
        ExecMode::Threads(1),
        ExecMode::Threads(2),
        ExecMode::Threads(4),
        ExecMode::Threads(7),
        ExecMode::Trace,
    ]
}

fn assert_modes_match_oracle(pencil: &Pencil, cfg: &Config, label: &str) {
    let oracle = reduce_to_hessenberg_triangular(&pencil.a, &pencil.b, cfg)
        .unwrap_or_else(|e| panic!("{label}: oracle failed: {e}"));
    // The oracle output itself is a valid HT decomposition.
    oracle.verify(&pencil.a, &pencil.b).assert_ok(1e-10);
    assert!(max_below_band(&oracle.h, 1) < 1e-12 * oracle.h.norm_fro().max(1.0));
    assert_eq!(max_below_band(&oracle.t, 0), 0.0, "{label}: T not exactly triangular");

    for mode in exec_modes() {
        let run = run_paraht(&pencil.a, &pencil.b, cfg, mode)
            .unwrap_or_else(|e| panic!("{label}: {mode:?} failed: {e}"));
        assert_eq!(
            max_abs_diff(&oracle.h, &run.h),
            0.0,
            "{label}: H diverges under {mode:?}"
        );
        assert_eq!(
            max_abs_diff(&oracle.t, &run.t),
            0.0,
            "{label}: T diverges under {mode:?}"
        );
        assert_eq!(
            max_abs_diff(&oracle.q, &run.q),
            0.0,
            "{label}: Q diverges under {mode:?}"
        );
        assert_eq!(
            max_abs_diff(&oracle.z, &run.z),
            0.0,
            "{label}: Z diverges under {mode:?}"
        );
    }
}

#[test]
fn random_pencil_all_modes_divisible_blocking() {
    // n a multiple of r·p: the uniform-block fast case.
    let mut rng = Rng::new(0xE0_01);
    let pencil = random_pencil(48, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "random n=48 r=4 p=3");
}

#[test]
fn random_pencil_all_modes_non_divisible_blocking() {
    // n NOT a multiple of r·p (45 % 12 != 0): clipped edge blocks on every
    // panel, partial last sweep group.
    let mut rng = Rng::new(0xE0_02);
    let pencil = random_pencil(45, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 4, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "random n=45 r=4 p=3");
}

#[test]
fn random_pencil_block_larger_than_matrix() {
    // p·r = 128 > n = 40: every block is clipped; the paper tuning on a
    // problem too small for it must still agree with the oracle.
    let mut rng = Rng::new(0xE0_03);
    let pencil = random_pencil(40, &mut rng);
    let cfg = Config { r: 16, p: 8, q: 8, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "random n=40 r=16 p=8");
}

#[test]
fn saddle_pencil_all_modes() {
    // Singular B (25% infinite eigenvalues) through every execution mode,
    // with non-divisible blocking (58 % 18 != 0).
    let mut rng = Rng::new(0xE0_04);
    let pencil = saddle_pencil(58, 0.25, &mut rng);
    let cfg = Config { r: 6, p: 3, q: 3, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "saddle n=58 r=6 p=3");
}

#[test]
fn saddle_pencil_odd_tuning() {
    let mut rng = Rng::new(0xE0_05);
    let pencil = saddle_pencil(37, 0.25, &mut rng);
    let cfg = Config { r: 5, p: 4, q: 2, slices: 5, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "saddle n=37 r=5 p=4");
}

#[test]
fn repeated_parallel_runs_are_deterministic() {
    // The same threaded configuration run twice must be bitwise identical
    // (schedule nondeterminism must never leak into the numbers).
    let mut rng = Rng::new(0xE0_06);
    let pencil = random_pencil(41, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    let r1 = run_paraht(&pencil.a, &pencil.b, &cfg, ExecMode::Threads(5)).unwrap();
    let r2 = run_paraht(&pencil.a, &pencil.b, &cfg, ExecMode::Threads(5)).unwrap();
    assert_eq!(max_abs_diff(&r1.h, &r2.h), 0.0);
    assert_eq!(max_abs_diff(&r1.t, &r2.t), 0.0);
    assert_eq!(max_abs_diff(&r1.q, &r2.q), 0.0);
    assert_eq!(max_abs_diff(&r1.z, &r2.z), 0.0);
}

#[test]
fn pool_reuse_across_consecutive_runs_matches_oracle() {
    // Two back-to-back threaded reductions reuse the same persistent
    // worker team (`coordinator::pool::global`); the second run — executed
    // by workers whose pack buffers and parked threads survived the first —
    // must still be bitwise the oracle. Guards the pool's drain/reuse
    // path: a leaked task, stale batch entry, or lost wakeup from run 1
    // would corrupt or hang run 2.
    let mut rng = Rng::new(0xE0_07);
    let pencil = random_pencil(48, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    let oracle = reduce_to_hessenberg_triangular(&pencil.a, &pencil.b, &cfg).unwrap();
    for pass in 0..2 {
        let run = run_paraht(&pencil.a, &pencil.b, &cfg, ExecMode::Threads(4))
            .unwrap_or_else(|e| panic!("pass {pass}: {e}"));
        assert_eq!(max_abs_diff(&oracle.h, &run.h), 0.0, "H diverges on pass {pass}");
        assert_eq!(max_abs_diff(&oracle.t, &run.t), 0.0, "T diverges on pass {pass}");
        assert_eq!(max_abs_diff(&oracle.q, &run.q), 0.0, "Q diverges on pass {pass}");
        assert_eq!(max_abs_diff(&oracle.z, &run.z), 0.0, "Z diverges on pass {pass}");
    }
}
