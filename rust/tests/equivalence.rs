//! Equivalence tests pinning every execution path to the sequential
//! oracle: the session front door (`api::HtSession::reduce` at 1/2/4/7
//! threads, static and work-assisting dynamic schedules, trace capture,
//! and `reduce_batch`) and the deprecated `run_paraht` shim must all
//! produce the same `(H, T, Q, Z)` as the sequential two-stage driver
//! (`api::reduce_seq`) — including block sizes that do not divide the
//! problem size.
//!
//! The task bodies are the same kernels executed in a valid topological
//! order, and every slice kernel is bitwise independent of the slicing
//! (see the per-column/per-row notes in `linalg::gemm`), so the comparison
//! is exact equality, not a tolerance.

use paraht::api::{reduce_seq, HtSession, TraceRecorder};
use paraht::config::Config;
#[allow(deprecated)] // shim coverage: the wrappers must delegate unchanged
use paraht::coordinator::driver::run_paraht;
use paraht::coordinator::stage1_par::ExecMode;
use paraht::ht::HtDecomposition;
use paraht::linalg::verify::max_below_band;
use paraht::pencil::random::{random_pencil, Pencil};
use paraht::pencil::saddle::saddle_pencil;
use paraht::util::proptest::max_abs_diff;
use paraht::util::rng::Rng;

/// Thread counts exercised by the session sweep.
const SESSION_THREADS: &[usize] = &[1, 2, 4, 7];

/// Representative legacy modes exercised through the deprecated shim in
/// the per-pencil sweep. The shim is a pure delegation to the session
/// paths already swept exhaustively above it, so one threaded mode and
/// one trace mode per pencil suffice here; full-delegation pinning lives
/// in `deprecated_shims_compile_and_delegate_unchanged`.
fn exec_modes() -> Vec<ExecMode> {
    vec![ExecMode::Threads(4), ExecMode::Trace]
}

fn assert_same(
    (h, t, q, z): (
        &paraht::Matrix,
        &paraht::Matrix,
        &paraht::Matrix,
        &paraht::Matrix,
    ),
    oracle: &HtDecomposition,
    label: &str,
) {
    assert_eq!(max_abs_diff(&oracle.h, h), 0.0, "{label}: H diverges");
    assert_eq!(max_abs_diff(&oracle.t, t), 0.0, "{label}: T diverges");
    assert_eq!(max_abs_diff(&oracle.q, q), 0.0, "{label}: Q diverges");
    assert_eq!(max_abs_diff(&oracle.z, z), 0.0, "{label}: Z diverges");
}

#[allow(deprecated)] // the mode sweep doubles as run_paraht shim coverage
fn assert_modes_match_oracle(pencil: &Pencil, cfg: &Config, label: &str) {
    let oracle = reduce_seq(&pencil.a, &pencil.b, cfg)
        .unwrap_or_else(|e| panic!("{label}: oracle failed: {e}"));
    // The oracle output itself is a valid HT decomposition.
    oracle.verify(&pencil.a, &pencil.b).assert_ok(1e-10);
    assert!(max_below_band(&oracle.h, 1) < 1e-12 * oracle.h.norm_fro().max(1.0));
    assert_eq!(max_below_band(&oracle.t, 0), 0.0, "{label}: T not exactly triangular");

    // The session front door, at every thread count.
    for &threads in SESSION_THREADS {
        let mut session = HtSession::builder()
            .config(cfg.clone())
            .threads(threads)
            .build()
            .unwrap_or_else(|e| panic!("{label}: build({threads}) failed: {e}"));
        let run = session
            .reduce(&pencil.a, &pencil.b)
            .unwrap_or_else(|e| panic!("{label}: session({threads}) failed: {e}"));
        assert_same(
            (&run.h, &run.t, &run.q, &run.z),
            &oracle,
            &format!("{label}: session threads={threads}"),
        );
    }

    // Work-assisting dynamic scheduling (`Config::dynamic_schedule`), at
    // every thread count: claiming panels from the shared atomic counter
    // decides only *who* computes each panel, so not a single bit may
    // move. Swept twice per thread count — with the pencil's pinned slice
    // count, and with auto slices (slices = 0), where the dynamic gate
    // additionally oversplits the stage graphs' slice goal (the finest
    // panels the claim loop and the graph FIFO ever see).
    for &threads in SESSION_THREADS {
        for (slices, tag) in [(cfg.slices, "pinned"), (0usize, "auto-oversplit")] {
            let dyn_cfg =
                Config { dynamic_schedule: true, slices, threads, ..cfg.clone() };
            let mut session = HtSession::builder()
                .config(dyn_cfg)
                .build()
                .unwrap_or_else(|e| panic!("{label}: dynamic build({threads}) failed: {e}"));
            let run = session
                .reduce(&pencil.a, &pencil.b)
                .unwrap_or_else(|e| panic!("{label}: dynamic({threads},{tag}) failed: {e}"));
            assert_same(
                (&run.h, &run.t, &run.q, &run.z),
                &oracle,
                &format!("{label}: dynamic threads={threads} slices={tag}"),
            );
        }
    }

    // Trace capture (the old ExecMode::Trace) through the session.
    {
        let mut session = HtSession::builder()
            .config(cfg.clone())
            .capture_traces(true)
            .build()
            .unwrap();
        let run = session.reduce(&pencil.a, &pencil.b).unwrap();
        assert_same((&run.h, &run.t, &run.q, &run.z), &oracle, &format!("{label}: traced"));
        assert!(session.trace().is_some(), "{label}: trace capture must record traces");
    }

    // The batch path: the whole pencil repeated must match element-wise.
    {
        let mut session =
            HtSession::builder().config(cfg.clone()).threads(4).build().unwrap();
        let batch = vec![pencil.clone(), pencil.clone(), pencil.clone()];
        let out = session.reduce_batch(&batch).unwrap();
        assert_eq!(out.len(), 3);
        for (i, d) in out.iter().enumerate() {
            assert_same(
                (&d.h, &d.t, &d.q, &d.z),
                &oracle,
                &format!("{label}: batch item {i}"),
            );
        }
    }

    // The deprecated shim, under every legacy mode.
    for mode in exec_modes() {
        let run = run_paraht(&pencil.a, &pencil.b, cfg, mode)
            .unwrap_or_else(|e| panic!("{label}: {mode:?} failed: {e}"));
        assert_same(
            (&run.h, &run.t, &run.q, &run.z),
            &oracle,
            &format!("{label}: shim {mode:?}"),
        );
    }
}

#[test]
fn random_pencil_all_modes_divisible_blocking() {
    // n a multiple of r·p: the uniform-block fast case.
    let mut rng = Rng::new(0xE0_01);
    let pencil = random_pencil(48, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "random n=48 r=4 p=3");
}

#[test]
fn random_pencil_all_modes_non_divisible_blocking() {
    // n NOT a multiple of r·p (45 % 12 != 0): clipped edge blocks on every
    // panel, partial last sweep group.
    let mut rng = Rng::new(0xE0_02);
    let pencil = random_pencil(45, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 4, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "random n=45 r=4 p=3");
}

#[test]
fn random_pencil_block_larger_than_matrix() {
    // p·r = 128 > n = 40: every block is clipped; the paper tuning on a
    // problem too small for it must still agree with the oracle.
    let mut rng = Rng::new(0xE0_03);
    let pencil = random_pencil(40, &mut rng);
    let cfg = Config { r: 16, p: 8, q: 8, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "random n=40 r=16 p=8");
}

#[test]
fn saddle_pencil_all_modes() {
    // Singular B (25% infinite eigenvalues) through every execution mode,
    // with non-divisible blocking (58 % 18 != 0).
    let mut rng = Rng::new(0xE0_04);
    let pencil = saddle_pencil(58, 0.25, &mut rng);
    let cfg = Config { r: 6, p: 3, q: 3, slices: 8, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "saddle n=58 r=6 p=3");
}

#[test]
fn saddle_pencil_odd_tuning() {
    let mut rng = Rng::new(0xE0_05);
    let pencil = saddle_pencil(37, 0.25, &mut rng);
    let cfg = Config { r: 5, p: 4, q: 2, slices: 5, ..Config::default() };
    assert_modes_match_oracle(&pencil, &cfg, "saddle n=37 r=5 p=4");
}

#[test]
fn repeated_parallel_runs_are_deterministic() {
    // The same threaded configuration run twice must be bitwise identical
    // (schedule nondeterminism must never leak into the numbers).
    let mut rng = Rng::new(0xE0_06);
    let pencil = random_pencil(41, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    let mut s1 = HtSession::builder().config(cfg.clone()).threads(5).build().unwrap();
    let mut s2 = HtSession::builder().config(cfg).threads(5).build().unwrap();
    let r1 = s1.reduce(&pencil.a, &pencil.b).unwrap();
    let r2 = s2.reduce(&pencil.a, &pencil.b).unwrap();
    assert_eq!(max_abs_diff(&r1.h, &r2.h), 0.0);
    assert_eq!(max_abs_diff(&r1.t, &r2.t), 0.0);
    assert_eq!(max_abs_diff(&r1.q, &r2.q), 0.0);
    assert_eq!(max_abs_diff(&r1.z, &r2.z), 0.0);
}

#[test]
fn repeated_dynamic_runs_are_deterministic() {
    // Work-assisting claims race on an atomic counter, so *which worker*
    // computes a panel varies run to run — the numbers must not. Two
    // dynamic runs must be bitwise identical to each other and to the
    // static run at the same thread count.
    let mut rng = Rng::new(0xE0_0C);
    let pencil = random_pencil(41, &mut rng);
    let cfg = Config {
        r: 4,
        p: 3,
        q: 3,
        slices: 0, // auto: let the dynamic gate oversplit the slice goal
        dynamic_schedule: true,
        ..Config::default()
    };
    let mut s1 = HtSession::builder().config(cfg.clone()).threads(5).build().unwrap();
    let mut s2 = HtSession::builder().config(cfg.clone()).threads(5).build().unwrap();
    let static_cfg = Config { dynamic_schedule: false, ..cfg };
    let mut s3 = HtSession::builder().config(static_cfg).threads(5).build().unwrap();
    let r1 = s1.reduce(&pencil.a, &pencil.b).unwrap();
    let r2 = s2.reduce(&pencil.a, &pencil.b).unwrap();
    let r3 = s3.reduce(&pencil.a, &pencil.b).unwrap();
    for (other, label) in [(&r2, "dynamic repeat"), (&r3, "static twin")] {
        assert_eq!(max_abs_diff(&r1.h, &other.h), 0.0, "{label}: H diverges");
        assert_eq!(max_abs_diff(&r1.t, &other.t), 0.0, "{label}: T diverges");
        assert_eq!(max_abs_diff(&r1.q, &other.q), 0.0, "{label}: Q diverges");
        assert_eq!(max_abs_diff(&r1.z, &other.z), 0.0, "{label}: Z diverges");
    }
}

#[test]
fn session_reuse_across_consecutive_reduces_matches_oracle() {
    // Two back-to-back reductions on ONE session reuse the persistent
    // worker team AND the session workspaces (panel plans, sweep groups,
    // reflector arenas); both runs must be bitwise two fresh oracle runs.
    // Guards the arena reset path: a stale reflector slot or cached WY
    // application surviving run 1 would corrupt run 2.
    let mut rng = Rng::new(0xE0_07);
    let pencil = random_pencil(48, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    let oracle = reduce_seq(&pencil.a, &pencil.b, &cfg).unwrap();
    let mut session = HtSession::builder().config(cfg).threads(4).build().unwrap();
    for pass in 0..2 {
        let run = session
            .reduce(&pencil.a, &pencil.b)
            .unwrap_or_else(|e| panic!("pass {pass}: {e}"));
        assert_same(
            (&run.h, &run.t, &run.q, &run.z),
            &oracle,
            &format!("session reuse pass {pass}"),
        );
    }
    assert_eq!(session.phases().len(), 2, "both reductions logged");
}

#[test]
fn session_reuse_across_different_sizes_matches_oracle() {
    // A size change mid-session rebuilds the workspace; both pencils (and
    // a return to the first size) must stay bitwise the oracle.
    let mut rng = Rng::new(0xE0_08);
    let p_small = random_pencil(33, &mut rng);
    let p_large = random_pencil(52, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 6, ..Config::default() };
    let o_small = reduce_seq(&p_small.a, &p_small.b, &cfg).unwrap();
    let o_large = reduce_seq(&p_large.a, &p_large.b, &cfg).unwrap();
    let mut session = HtSession::builder().config(cfg).threads(4).build().unwrap();
    for (pencil, oracle, label) in [
        (&p_small, &o_small, "small #1"),
        (&p_large, &o_large, "large"),
        (&p_small, &o_small, "small #2"),
    ] {
        let run = session.reduce(&pencil.a, &pencil.b).unwrap();
        assert_same((&run.h, &run.t, &run.q, &run.z), oracle, label);
    }
}

#[test]
fn reduce_batch_matches_sequential_per_pencil_on_mixed_sizes() {
    // Batch dispatch (one pencil per worker) vs a sequential per-pencil
    // loop: bitwise identical on a mixed-size batch, including edge cases
    // below the configured band (clip mode) and a tiny no-op pencil.
    let mut rng = Rng::new(0xE0_09);
    let sizes = [2usize, 7, 12, 19, 33, 46];
    let pencils: Vec<Pencil> = sizes.iter().map(|&n| random_pencil(n, &mut rng)).collect();
    let mut batch_session = HtSession::builder()
        .band(16)
        .threads(4)
        .clip_band(true)
        .build()
        .unwrap();
    let out = batch_session.reduce_batch(&pencils).unwrap();
    assert_eq!(out.len(), pencils.len());
    let mut seq_session =
        HtSession::builder().band(16).threads(1).clip_band(true).build().unwrap();
    for (pencil, d) in pencils.iter().zip(&out) {
        if pencil.n() >= 3 {
            d.verify(&pencil.a, &pencil.b).assert_ok(1e-10);
        }
        let oracle = seq_session.reduce(&pencil.a, &pencil.b).unwrap();
        assert_same(
            (&d.h, &d.t, &d.q, &d.z),
            &oracle,
            &format!("mixed batch n={}", pencil.n()),
        );
    }
}

#[test]
#[allow(deprecated)]
fn deprecated_shims_compile_and_delegate_unchanged() {
    // Acceptance pin: both legacy entry points still compile and are
    // bitwise the session paths they delegate to.
    use paraht::ht::reduce_to_hessenberg_triangular;
    let mut rng = Rng::new(0xE0_0A);
    let pencil = random_pencil(40, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };

    let oracle = reduce_seq(&pencil.a, &pencil.b, &cfg).unwrap();
    let via_shim = reduce_to_hessenberg_triangular(&pencil.a, &pencil.b, &cfg).unwrap();
    assert_same(
        (&via_shim.h, &via_shim.t, &via_shim.q, &via_shim.z),
        &oracle,
        "reduce_to_hessenberg_triangular shim",
    );

    let run = run_paraht(&pencil.a, &pencil.b, &cfg, ExecMode::Threads(4)).unwrap();
    assert_same((&run.h, &run.t, &run.q, &run.z), &oracle, "run_paraht shim");
    assert!(run.traces.is_none());
    let run = run_paraht(&pencil.a, &pencil.b, &cfg, ExecMode::Trace).unwrap();
    assert_same((&run.h, &run.t, &run.q, &run.z), &oracle, "run_paraht trace shim");
    assert!(run.traces.is_some(), "Trace mode still returns traces through the shim");
}

#[cfg(any(feature = "audit", debug_assertions))]
#[test]
fn audit_hooks_do_not_perturb_results() {
    // The concurrency auditor's zero-interference contract: the same
    // threaded reduction with the auditor forced off and forced on must be
    // bitwise identical (the hooks only *observe* view rectangles), and
    // the audited run must actually have recorded accesses. Flipping the
    // process-global override concurrently with the other tests in this
    // binary is benign either way: audited runs are audit-clean, and this
    // very test is the proof the bits never move.
    use paraht::coordinator::audit;
    let mut rng = Rng::new(0xE0_0D);
    let pencil = random_pencil(44, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    let mut reduce = |cfg: &Config| {
        let mut s = HtSession::builder().config(cfg.clone()).threads(4).build().unwrap();
        s.reduce(&pencil.a, &pencil.b).unwrap()
    };
    audit::set_override(Some(false));
    let off = reduce(&cfg);
    audit::set_override(Some(true));
    let before = audit::recorded_total();
    let on = reduce(&cfg);
    audit::set_override(None);
    assert!(audit::recorded_total() > before, "the audited run must record accesses");
    assert_eq!(max_abs_diff(&off.h, &on.h), 0.0, "audit hooks must not perturb H");
    assert_eq!(max_abs_diff(&off.t, &on.t), 0.0, "audit hooks must not perturb T");
    assert_eq!(max_abs_diff(&off.q, &on.q), 0.0, "audit hooks must not perturb Q");
    assert_eq!(max_abs_diff(&off.z, &on.z), 0.0, "audit hooks must not perturb Z");
}

#[test]
fn trace_recorder_sink_observes_identical_reduction() {
    // The TraceSink replacement for ExecMode::Trace: a recorder-equipped
    // session produces the oracle bits AND a usable task trace.
    let mut rng = Rng::new(0xE0_0B);
    let pencil = random_pencil(44, &mut rng);
    let cfg = Config { r: 4, p: 3, q: 3, slices: 8, ..Config::default() };
    let oracle = reduce_seq(&pencil.a, &pencil.b, &cfg).unwrap();
    let recorder = TraceRecorder::new();
    let mut session = HtSession::builder()
        .config(cfg)
        .trace(recorder.clone())
        .build()
        .unwrap();
    let run = session.reduce(&pencil.a, &pencil.b).unwrap();
    assert_same((&run.h, &run.t, &run.q, &run.z), &oracle, "recorded session");
    let reports = recorder.reports();
    assert_eq!(reports.len(), 1);
    let (t1, t2) = reports[0].traces.as_ref().expect("recorder requests traces");
    assert!(!t1.durations.is_empty() && !t2.durations.is_empty());
}
